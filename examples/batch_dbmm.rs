//! Batch DBMM: the paper's core contribution (Theorem III.2) on a realistic
//! workload — a batch of n = 2 independent matrix products (e.g. two layers
//! of a fixed-point ML inference) computed by ONE coded job, and the same
//! batch through the CSA/GCSA baseline for comparison.
//!
//! ```bash
//! cargo run --release --example batch_dbmm [-- --size 256]
//! ```

use gr_cdmm::codes::batch_ep_rmfe::BatchEpRmfe;
use gr_cdmm::codes::csa::CsaCode;
use gr_cdmm::codes::scheme::DmmScheme;
use gr_cdmm::coordinator::runner::{run_batch, NativeCompute};
use gr_cdmm::coordinator::{Coordinator, StragglerModel};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::cli::Args;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let size = args.get_usize("size", 256);
    let n_batch = 2usize;
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(11);

    let a: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, size, size, &mut rng)).collect();
    let b: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, size, size, &mut rng)).collect();
    let expected: Vec<_> = (0..n_batch).map(|k| Matrix::matmul(&base, &a[k], &b[k])).collect();

    // ---- Batch-EP_RMFE (ours): N = 8, u = v = 2, w = 1 ⇒ R = 4 ------------
    let scheme = Arc::new(BatchEpRmfe::new(base.clone(), 8, n_batch, 2, 1, 2)?);
    println!("== {}", scheme.name());
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, backend, StragglerModel::None, 2);
    let (c, m) = run_batch(scheme.as_ref(), &mut coord, &a, &b)?;
    coord.shutdown();
    assert_eq!(c, expected);
    println!("   R = {}  (independent of the batch size!)", scheme.recovery_threshold());
    println!("   encode {:?}  decode {:?}", m.encode, m.decode);
    println!(
        "   upload {:.2} MB  download {:.2} MB",
        m.upload_bytes as f64 / 1e6,
        m.download_bytes as f64 / 1e6
    );
    println!("   mean worker compute {:?}", m.mean_worker_compute());

    // ---- CSA baseline (the runnable GCSA point, uvw = 1, κ = n) ----------
    let ext = Extension::with_capacity(base.clone(), n_batch + 8);
    let csa = Arc::new(CsaCode::new(ext.clone(), 8, n_batch)?);
    println!("== {}", csa.name());
    let ae: Vec<_> = a.iter().map(|mat| mat.map(|x| ext.from_base(x))).collect();
    let be: Vec<_> = b.iter().map(|mat| mat.map(|x| ext.from_base(x))).collect();
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&csa)));
    let mut coord = Coordinator::new(8, backend, StragglerModel::None, 3);
    let (c2, m2) = run_batch(csa.as_ref(), &mut coord, &ae, &be)?;
    coord.shutdown();
    for k in 0..n_batch {
        assert_eq!(c2[k].map(|x| x[0]), expected[k]);
    }
    println!("   R = {}  (grows as 2n−1 with the batch)", csa.recovery_threshold());
    println!("   encode {:?}  decode {:?}", m2.encode, m2.decode);
    println!(
        "   upload {:.2} MB  download {:.2} MB",
        m2.upload_bytes as f64 / 1e6,
        m2.download_bytes as f64 / 1e6
    );
    println!("   mean worker compute {:?}", m2.mean_worker_compute());
    Ok(())
}
