//! Straggler mitigation in a serving loop — the phenomenon coded computation
//! exists for (§I). A stream of multiplication requests is served by an
//! 8-worker pool where two workers are persistently slow; the coded scheme
//! (R = 4 of N = 8) never waits for them.
//!
//! ```bash
//! cargo run --release --example straggler_serving
//! ```

use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
use gr_cdmm::codes::scheme::DmmScheme;
use gr_cdmm::coordinator::runner::{run_single, NativeCompute};
use gr_cdmm::coordinator::{Coordinator, StragglerModel};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let ring = Zq::z2e(64);
    let size = 128usize;
    let requests = 5usize;
    let slow = Duration::from_millis(250);

    // Two slow nodes — well within the N − R = 4 straggler budget.
    let straggler = StragglerModel::FixedSlow {
        slow: [2usize, 5].into_iter().collect(),
        delay: slow,
    };
    let scheme = Arc::new(EpRmfeI::new(ring.clone(), 8, 2, 1, 2, 2)?);
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, backend, straggler, 17);

    let mut rng = Rng64::seeded(23);
    println!("serving {requests} requests on 8 workers (workers 2 and 5 slow by {slow:?})");
    println!("recovery threshold R = {}", scheme.recovery_threshold());

    let mut coded_total = Duration::ZERO;
    for req in 0..requests {
        let a = Matrix::random(&ring, size, size, &mut rng);
        let b = Matrix::random(&ring, size, size, &mut rng);
        let t0 = Instant::now();
        let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b)?;
        let wall = t0.elapsed();
        coded_total += wall;
        assert_eq!(c, Matrix::matmul(&ring, &a, &b));
        println!(
            "  req {req}: {wall:?} (used workers {:?}; stragglers bypassed: {})",
            m.used_workers,
            !m.used_workers.contains(&2) && !m.used_workers.contains(&5)
        );
    }
    coord.shutdown();

    // Uncoded baseline: an N-way split must wait for ALL workers, so every
    // request eats the full straggler delay.
    println!("\ncoded mean latency:  {:?}", coded_total / requests as u32);
    println!("uncoded lower bound: ≥ {slow:?} per request (must wait for the stragglers)");
    println!(
        "straggler speedup:   ≥ {:.1}×",
        slow.as_secs_f64() / (coded_total / requests as u32).as_secs_f64()
    );
    Ok(())
}
