//! Straggler mitigation in a *serving loop* — the phenomenon coded
//! computation exists for (§I), now pipelined. A stream of multiplication
//! requests is served by an 8-worker pool where two workers are persistently
//! slow; the coded scheme (R = 4 of N = 8) never waits for them, and the
//! multi-job coordinator keeps several requests in flight so the master's
//! encode/decode overlaps the workers' compute.
//!
//! The same stream is run three times — sequentially (`submit` then `wait`
//! per request), pipelined (up to 4 `JobHandle`s outstanding), and
//! pipelined again over **real TCP sockets** (one loopback worker daemon
//! per worker, same straggler model and seed, so the draws are identical
//! and the only delta is the wire) — and the jobs/sec of each pass is
//! reported, along with the decode-plan cache counters: in steady state the
//! same fast-4 subset keeps responding, so decode interpolation setup
//! becomes a cache lookup.
//!
//! ```bash
//! cargo run --release --example straggler_serving
//! ```

use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
use gr_cdmm::codes::scheme::{DmmScheme, Response};
use gr_cdmm::coordinator::{
    Coordinator, JobHandle, NativeCompute, ShareCompute, StragglerModel, WorkerDaemon,
};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::plane::PlaneMatrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

const SIZE: usize = 96;
const REQUESTS: usize = 12;
const INFLIGHT: usize = 4;

type Scheme = EpRmfeI<Zq>;

fn encode_request(
    scheme: &Scheme,
    a: &Matrix<u64>,
    b: &Matrix<u64>,
) -> anyhow::Result<Vec<Vec<u8>>> {
    let ring = scheme.share_ring();
    Ok(scheme.encode(a, b)?.iter().map(|s| s.to_bytes(ring)).collect())
}

fn decode_request(scheme: &Scheme, handle: JobHandle) -> anyhow::Result<(Matrix<u64>, Vec<usize>)> {
    let (collected, _) = handle.wait()?;
    let ring = scheme.share_ring();
    let responses: Vec<Response<Extension<Zq>>> = collected
        .iter()
        .map(|c| PlaneMatrix::from_bytes(ring, &c.payload).map(|m| (c.worker_id, m)))
        .collect::<anyhow::Result<_>>()?;
    let used = collected.iter().map(|c| c.worker_id).collect();
    Ok((scheme.decode(&responses)?, used))
}

/// One pipelined pass: up to [`INFLIGHT`] `JobHandle`s outstanding, every
/// decoded product checked against the local reference. The same loop runs
/// over the in-process pool and the TCP pool — only the coordinator differs.
fn run_pipelined_pass(
    scheme: &Scheme,
    coord: &mut Coordinator,
    requests: &[(Matrix<u64>, Matrix<u64>)],
    expected: &[Matrix<u64>],
    need: usize,
) -> anyhow::Result<Duration> {
    let mut window: VecDeque<(usize, JobHandle)> = VecDeque::new();
    let t0 = Instant::now();
    for (req, (a, b)) in requests.iter().enumerate() {
        if window.len() == INFLIGHT {
            let (oldest, handle) = window.pop_front().expect("window is non-empty");
            let (c, _) = decode_request(scheme, handle)?;
            assert_eq!(c, expected[oldest]);
        }
        window.push_back((req, coord.submit(encode_request(scheme, a, b)?, need)?));
    }
    while let Some((req, handle)) = window.pop_front() {
        let (c, _) = decode_request(scheme, handle)?;
        assert_eq!(c, expected[req]);
    }
    Ok(t0.elapsed())
}

fn main() -> anyhow::Result<()> {
    let ring = Zq::z2e(64);
    let slow = Duration::from_millis(40);
    let straggler = StragglerModel::fixed_slow([2usize, 5], slow);
    let scheme = Arc::new(EpRmfeI::new(ring.clone(), 8, 2, 1, 2, 2)?);
    let need = scheme.recovery_threshold();

    let mut rng = Rng64::seeded(23);
    let requests: Vec<(Matrix<u64>, Matrix<u64>)> = (0..REQUESTS)
        .map(|_| {
            let a = Matrix::random(&ring, SIZE, SIZE, &mut rng);
            let b = Matrix::random(&ring, SIZE, SIZE, &mut rng);
            (a, b)
        })
        .collect();
    let expected: Vec<Matrix<u64>> =
        requests.iter().map(|(a, b)| Matrix::matmul(&ring, a, b)).collect();

    println!("serving {REQUESTS} requests on 8 workers (workers 2 and 5 slow by {slow:?})");
    println!("recovery threshold R = {need}\n");

    // --- sequential baseline: one request at a time ----------------------
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, backend, straggler.clone(), 17);
    let t0 = Instant::now();
    for (req, (a, b)) in requests.iter().enumerate() {
        let handle = coord.submit(encode_request(&scheme, a, b)?, need)?;
        let (c, used) = decode_request(&scheme, handle)?;
        assert_eq!(c, expected[req]);
        if req == 0 {
            println!("  sequential req 0 used workers {used:?} (stragglers bypassed)");
        }
    }
    let seq = t0.elapsed();
    coord.shutdown();

    // --- pipelined: up to INFLIGHT JobHandles outstanding ----------------
    let scheme2 = Arc::new(EpRmfeI::new(ring.clone(), 8, 2, 1, 2, 2)?);
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme2)));
    let mut coord = Coordinator::new(8, backend, straggler.clone(), 17);
    let pipe = run_pipelined_pass(&scheme2, &mut coord, &requests, &expected, need)?;
    coord.shutdown();

    // --- pipelined over TCP: one loopback daemon per worker --------------
    // Same straggler model and seed as the in-process passes, so the draws
    // are identical and the only delta is the real wire (framed payloads
    // over sockets instead of in-process channels).
    let scheme3 = Arc::new(EpRmfeI::new(ring.clone(), 8, 2, 1, 2, 2)?);
    let backend: Arc<dyn ShareCompute> =
        Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme3)));
    let daemons: Vec<WorkerDaemon> = (0..8)
        .map(|_| WorkerDaemon::spawn_local(Arc::clone(&backend), straggler.clone(), 17, 1))
        .collect::<anyhow::Result<_>>()?;
    let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
    let mut coord = Coordinator::connect_tcp(&addrs)?;
    let tcp = run_pipelined_pass(&scheme3, &mut coord, &requests, &expected, need)?;
    coord.shutdown();
    for daemon in daemons {
        daemon.join()?;
    }

    let seq_rate = REQUESTS as f64 / seq.as_secs_f64();
    let pipe_rate = REQUESTS as f64 / pipe.as_secs_f64();
    let tcp_rate = REQUESTS as f64 / tcp.as_secs_f64();
    let (hits, misses) = scheme2.plan_cache_stats();
    println!("\nsequential: {seq:?} total → {seq_rate:.2} jobs/s");
    println!("pipelined ({INFLIGHT} in flight): {pipe:?} total → {pipe_rate:.2} jobs/s");
    println!("pipelined over TCP loopback: {tcp:?} total → {tcp_rate:.2} jobs/s");
    println!("pipelining speedup: {:.2}x", pipe_rate / seq_rate);
    println!("transport cost (channel vs TCP): {:.2}x", pipe_rate / tcp_rate);
    println!("decode-plan cache (pipelined pass): {hits} hits / {misses} misses");
    println!(
        "\nuncoded lower bound: ≥ {slow:?} per request (an 8-way split must wait for \
         the stragglers); coded serving never does — on either transport"
    );
    Ok(())
}
