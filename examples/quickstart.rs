//! Quickstart: one coded distributed multiplication over `Z_{2^64}` with the
//! paper's 8-worker configuration, start to finish.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
use gr_cdmm::codes::scheme::DmmScheme;
use gr_cdmm::coordinator::runner::{run_single, NativeCompute};
use gr_cdmm::coordinator::{Coordinator, StragglerModel};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // The input ring: Z_{2^64} — native machine words (§I of the paper).
    let ring = Zq::z2e(64);
    let mut rng = Rng64::seeded(7);

    // Two 256×256 matrices to multiply.
    let a = Matrix::random(&ring, 256, 256, &mut rng);
    let b = Matrix::random(&ring, 256, 256, &mut rng);

    // EP_RMFE-I over GR(2^64, 3): N = 8 workers, partition u = v = 2, w = 1,
    // batch-split n = 2 (the paper's §V.A Fig. 2 configuration, R = 4).
    let scheme = Arc::new(EpRmfeI::new(ring.clone(), 8, 2, 1, 2, 2)?);
    println!("scheme:   {}", scheme.name());
    println!(
        "workers:  {} (recovery threshold {})",
        scheme.n_workers(),
        scheme.recovery_threshold()
    );

    // Spin up the worker pool (one native backend for every scheme) and run.
    let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, backend, StragglerModel::None, 1);
    let (c, metrics) = run_single(scheme.as_ref(), &mut coord, &a, &b)?;
    coord.shutdown();

    // Verify against a local multiplication.
    assert_eq!(c, Matrix::matmul(&ring, &a, &b));
    println!("verified: C = A·B");
    println!("encode:   {:?}", metrics.encode);
    println!("decode:   {:?}", metrics.decode);
    println!("upload:   {:.2} MB", metrics.upload_bytes as f64 / 1e6);
    println!("download: {:.2} MB", metrics.download_bytes as f64 / 1e6);
    println!("workers used: {:?}", metrics.used_workers);
    Ok(())
}
