//! END-TO-END DRIVER — proves all layers compose on a real small workload.
//!
//! The pipeline exercised (the paper's headline metrics on a live system):
//!
//!   L2/L1 (build time): JAX + Pallas worker task, AOT-lowered to
//!       `artifacts/worker_gr_m3_128x256x128.hlo.txt`  (`make artifacts`)
//!   runtime: rust PJRT client loads + compiles the artifact
//!   L3: 8-worker coordinator, EP codes over GR(2^64, 3), u=v=2, w=1, R=4,
//!       with straggler injection — workers execute their share products
//!       **through XLA**, the master encodes/decodes natively.
//!
//! The plane-major share wire format is already the artifact's input layout,
//! so the XLA path does zero layout conversion. Reports per-phase latency,
//! throughput, and the paper's Fig-2/4 metrics, for both the XLA backend and
//! the native backend (same job), and verifies bit-exact agreement with a
//! local product. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_driver
//! ```

use gr_cdmm::codes::ep::PlainEp;
use gr_cdmm::codes::scheme::DmmScheme;
use gr_cdmm::coordinator::runner::{run_single, NativeCompute};
use gr_cdmm::coordinator::{Coordinator, StragglerModel};
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::runtime::gr_backend::XlaShareCompute;
use gr_cdmm::runtime::XlaRuntime;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("GR_CDMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let runtime = XlaRuntime::open(&artifacts)?;
    println!("PJRT platform: {}", runtime.platform());
    println!("artifacts:");
    for s in runtime.specs() {
        println!("  {} (m={}, {}x{}x{})", s.name, s.m, s.t, s.r, s.s);
    }

    // Job: 256×256 over Z_2^64 → shares 128×256 · 256×128 (matches the m=3
    // artifact). 8 workers, one slow straggler to show R-of-N collection.
    let base = Zq::z2e(64);
    let size = 256usize;
    let scheme = Arc::new(PlainEp::with_m(base.clone(), 3, 8, 2, 1, 2)?);
    let ext = scheme.share_ring().clone();
    let straggler = StragglerModel::fixed_slow([3], Duration::from_millis(100));

    let mut rng = Rng64::seeded(42);
    let a = Matrix::random(&base, size, size, &mut rng);
    let b = Matrix::random(&base, size, size, &mut rng);
    let expected = Matrix::matmul(&base, &a, &b);

    // --- XLA worker backend (AOT Pallas kernel through PJRT) --------------
    println!("\n== coded job, workers on the AOT XLA backend ==");
    let xla_backend = Arc::new(XlaShareCompute::for_shapes(&artifacts, ext, 128, 256, 128)?);
    let mut coord = Coordinator::new(8, xla_backend, straggler.clone(), 5);
    // Warm-up job: each worker thread compiles its artifact once (PJRT
    // executables are per-thread; deployment = long-lived worker processes).
    let (warm, warm_m) = run_single(scheme.as_ref(), &mut coord, &a, &b)?;
    assert_eq!(warm, expected);
    println!("(warm-up job incl. per-worker PJRT compile: {:?})", warm_m.total);
    let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b)?;
    coord.shutdown();
    assert_eq!(c, expected, "XLA path must be bit-exact");
    println!("verified bit-exact: C = A·B");
    println!("encode {:?} | wait-for-R {:?} | decode {:?}", m.encode, m.wait_for_r, m.decode);
    println!(
        "upload {:.2} MB | download {:.2} MB | mean worker {:?} (straggler 3 bypassed: {})",
        m.upload_bytes as f64 / 1e6,
        m.download_bytes as f64 / 1e6,
        m.mean_worker_compute(),
        !m.used_workers.contains(&3)
    );
    let xla_total = m.total;

    // --- Native backend on the same job ------------------------------------
    println!("\n== same job, native rust worker kernels ==");
    let native_backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
    let mut coord = Coordinator::new(8, native_backend, straggler, 5);
    let (c2, m2) = run_single(scheme.as_ref(), &mut coord, &a, &b)?;
    coord.shutdown();
    assert_eq!(c2, expected);
    println!("encode {:?} | wait-for-R {:?} | decode {:?}", m2.encode, m2.wait_for_r, m2.decode);
    println!("mean worker {:?}", m2.mean_worker_compute());

    // --- summary -----------------------------------------------------------
    let gflop = 2.0 * (size as f64).powi(3) / 1e9;
    println!("\n== summary ==");
    println!("problem: {0}×{0} · {0}×{0} over Z_2^64 ({gflop:.3} G-mulacc)", size);
    println!("xla end-to-end:    {xla_total:?}");
    println!("native end-to-end: {:?}", m2.total);
    println!("all layers compose: JAX/Pallas → HLO text → PJRT → coded L3 ✓");
    Ok(())
}
