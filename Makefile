# Convenience targets. The rust build is fully offline; `artifacts` needs a
# Python environment with JAX (build-time only — Python is never on the
# request path).

.PHONY: build test bench bench-json bench-serving bench-simd serve-tcp-demo serve-shm-demo serve-elastic-demo serve-prepared-demo serve-byzantine-demo artifacts clean

build:
	cargo build --release

test:
	cargo test -q

# Every bench target prints markdown AND writes BENCH_<name>.json into the
# invoking directory (override with GR_CDMM_BENCH_OUT=dir).
bench:
	cargo bench --bench fig2_master8
	cargo bench --bench fig3_master16
	cargo bench --bench fig4_worker8
	cargo bench --bench fig5_worker16
	cargo bench --bench table1_gcsa
	cargo bench --bench encode_decode
	cargo bench --bench serving_throughput
	cargo bench --bench simd_kernels

# Per-kernel SIMD dispatch bench only: reference vs generic vs native slice
# kernels per base ring (mask, Montgomery, GF(2^8) tower); asserts every
# backend bit-identical to reference before timing and writes
# BENCH_simd_kernels.json. Force a family with GR_CDMM_SIMD=... to compare
# against the full sweep.
bench-simd:
	cargo bench --bench simd_kernels

# Serving throughput only: pipelined multi-job coordinator vs sequential
# baseline, on all three transports (channel + tcp-loopback + shm), every
# row also running the prepared (encode-once) pass — one fixed A staged on
# the workers, B-only per-job upload, in-run encode-once assertions — and
# reporting the memory-discipline probes (pool hits, large allocs, copied
# bytes/job) plus a final pooled-vs-unpooled (GR_CDMM_POOL_CAP=0) pair;
# writes BENCH_serving_throughput.json.
bench-serving:
	cargo bench --bench serving_throughput

# Multi-process demo: 4 `gr-cdmm worker` daemons on loopback ports, one
# pipelined serve batch over --connect (decoded products are verified
# against a local matmul). Each daemon exits after the serve's two passes
# (--conns 2), so the recipe reaps them with `wait`.
serve-tcp-demo: build
	@set -e; \
	trap 'kill $$(jobs -p) 2>/dev/null || true' EXIT; \
	for port in 7851 7852 7853 7854; do \
	  ./target/release/gr-cdmm worker --listen 127.0.0.1:$$port \
	    --scheme ep-rmfe-1 --workers 4 --conns 2 & \
	done; \
	./target/release/gr-cdmm serve --scheme ep-rmfe-1 --workers 4 --size 64 \
	  --jobs 8 --inflight 4 \
	  --connect 127.0.0.1:7851,127.0.0.1:7852,127.0.0.1:7853,127.0.0.1:7854; \
	wait; \
	trap - EXIT

# Shared-memory data-plane demo: `serve --transport shm` spawns its own
# loopback daemons whose control frames ride TCP while every payload moves
# out-of-line through per-worker file-backed rings. Decoded products are
# verified against a local matmul, and the report's memory-discipline
# columns (pool hits, large allocs, copied/job) surface the zero-copy
# steady state.
serve-shm-demo: build
	./target/release/gr-cdmm serve --scheme ep-rmfe-1 --workers 4 --size 64 \
	  --jobs 8 --inflight 4 --transport shm

# Flapping-daemon variant: the :7854 daemon is killed mid-batch and
# restarted one second later; `serve --speculate` re-dispatches its overdue
# shards to healthy spares and auto-reconnects the daemon once it is back,
# so the batch completes and verifies anyway. The master's connect path
# also retries refused connections for ~5s, so a restart landing between
# the serve's two passes is absorbed too. The three stable daemons exit on
# their own (--conns 2); the flapping one runs unbounded and is reaped by
# the trap.
serve-elastic-demo: build
	@set -e; \
	trap 'kill $$(jobs -p) 2>/dev/null || true' EXIT; \
	for port in 7851 7852 7853; do \
	  ./target/release/gr-cdmm worker --listen 127.0.0.1:$$port \
	    --scheme ep-rmfe-1 --workers 4 --conns 2 & \
	done; \
	./target/release/gr-cdmm worker --listen 127.0.0.1:7854 \
	  --scheme ep-rmfe-1 --workers 4 & \
	flap=$$!; \
	( sleep 1; echo "[demo] killing the :7854 daemon mid-batch"; \
	  kill $$flap 2>/dev/null || true; sleep 1; \
	  echo "[demo] restarting the :7854 daemon"; \
	  exec ./target/release/gr-cdmm worker --listen 127.0.0.1:7854 \
	    --scheme ep-rmfe-1 --workers 4 ) & \
	./target/release/gr-cdmm serve --scheme ep-rmfe-1 --workers 4 --size 96 \
	  --jobs 12 --inflight 4 --speculate \
	  --connect 127.0.0.1:7851,127.0.0.1:7852,127.0.0.1:7853,127.0.0.1:7854; \
	echo "[demo] batch completed and verified despite the flap"

# Encode-once (prepared-operand) demo against real daemons: stage A's share
# halves on 4 TCP workers once, stream B-only jobs — and kill the :7864
# daemon mid-batch, restarting it a second later. `--speculate` rescues the
# in-flight shards (speculative copies of prepared jobs ship the full
# share), auto-reconnect re-dials the daemon, and the master re-stages its
# A-half on the fresh connection before any further prepared job can reach
# it — the batch completes, verifies, and the serve's own encode-once
# assertions (one A-encode, B-only upload) hold throughout. The three
# stable daemons exit after the serve's three passes (--conns 3); the
# flapping one runs unbounded and is reaped by the trap.
serve-prepared-demo: build
	@set -e; \
	trap 'kill $$(jobs -p) 2>/dev/null || true' EXIT; \
	for port in 7861 7862 7863; do \
	  ./target/release/gr-cdmm worker --listen 127.0.0.1:$$port \
	    --scheme ep-rmfe-1 --workers 4 --conns 3 & \
	done; \
	./target/release/gr-cdmm worker --listen 127.0.0.1:7864 \
	  --scheme ep-rmfe-1 --workers 4 & \
	flap=$$!; \
	( sleep 1; echo "[demo] killing the :7864 daemon mid-batch"; \
	  kill $$flap 2>/dev/null || true; sleep 1; \
	  echo "[demo] restarting the :7864 daemon"; \
	  exec ./target/release/gr-cdmm worker --listen 127.0.0.1:7864 \
	    --scheme ep-rmfe-1 --workers 4 ) & \
	./target/release/gr-cdmm serve --scheme ep-rmfe-1 --workers 4 --size 96 \
	  --jobs 12 --inflight 4 --prepared --speculate \
	  --connect 127.0.0.1:7861,127.0.0.1:7862,127.0.0.1:7863,127.0.0.1:7864; \
	echo "[demo] prepared batch completed and verified despite the flap"

# Byzantine-fault demo: four daemons on loopback, one of them started with
# --corrupt silent-wrong-share (wrong-but-wellformed responses on every
# job). The N = 4 CSA preset has R = 3, one unit of slack: `serve
# --verify-products` cross-checks each decode against the surplus share,
# isolates the corrupt daemon by leave-one-out re-decode, quarantines it,
# and serves every product bit-identical to the local reference (serve
# exits nonzero otherwise — never an unverified wrong product). Each daemon
# exits after the single verified pass (--conns 1), so `wait` reaps them.
serve-byzantine-demo: build
	@set -e; \
	trap 'kill $$(jobs -p) 2>/dev/null || true' EXIT; \
	for port in 7871 7872 7873; do \
	  ./target/release/gr-cdmm worker --listen 127.0.0.1:$$port \
	    --scheme csa --workers 4 --conns 1 & \
	done; \
	echo "[demo] the :7874 daemon silently corrupts every response"; \
	./target/release/gr-cdmm worker --listen 127.0.0.1:7874 \
	  --scheme csa --workers 4 --conns 1 --corrupt silent-wrong-share & \
	./target/release/gr-cdmm serve --scheme csa --workers 4 --size 64 \
	  --jobs 8 --inflight 4 --verify-products \
	  --connect 127.0.0.1:7871,127.0.0.1:7872,127.0.0.1:7873,127.0.0.1:7874; \
	echo "[demo] every product verified; the corrupt daemon was quarantined"; \
	wait; \
	trap - EXIT

# Machine-readable run of the full bench suite (quick settings): refreshes
# every BENCH_<name>.json at the repo root, including the kernel and
# eval-ablation benches that `bench` skips.
bench-json:
	GR_CDMM_BENCH_REPS=2 cargo bench --bench fig2_master8
	GR_CDMM_BENCH_REPS=2 cargo bench --bench fig3_master16
	GR_CDMM_BENCH_REPS=2 cargo bench --bench fig4_worker8
	GR_CDMM_BENCH_REPS=2 cargo bench --bench fig5_worker16
	GR_CDMM_BENCH_REPS=2 cargo bench --bench table1_gcsa
	GR_CDMM_BENCH_REPS=2 cargo bench --bench matmul_kernels
	GR_CDMM_BENCH_REPS=2 cargo bench --bench encode_decode
	GR_CDMM_BENCH_REPS=2 cargo bench --bench eval_crossover
	GR_CDMM_BENCH_REPS=2 cargo bench --bench serving_throughput
	GR_CDMM_BENCH_REPS=2 cargo bench --bench simd_kernels

# AOT-lower the worker kernels to artifacts/*.hlo.txt + manifest.json
# (see rust/src/runtime/mod.rs rustdoc for the manifest contract).
# The symlink makes the default `artifacts` lookup work from both cwds in
# play: `cargo run`/benches keep the invoking cwd (repo root), while
# `cargo test` binaries run with cwd = rust/.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
	ln -sfn ../artifacts rust/artifacts

clean:
	cargo clean
	rm -rf artifacts results BENCH_*.json
