# Convenience targets. The rust build is fully offline; `artifacts` needs a
# Python environment with JAX (build-time only — Python is never on the
# request path).

.PHONY: build test bench artifacts clean

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench fig2_master8
	cargo bench --bench fig3_master16
	cargo bench --bench fig4_worker8
	cargo bench --bench fig5_worker16
	cargo bench --bench table1_gcsa

# AOT-lower the worker kernels to artifacts/*.hlo.txt + manifest.json
# (see rust/src/runtime/mod.rs rustdoc for the manifest contract).
# The symlink makes the default `artifacts` lookup work from both cwds in
# play: `cargo run`/benches keep the invoking cwd (repo root), while
# `cargo test` binaries run with cwd = rust/.
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts
	ln -sfn ../artifacts rust/artifacts

clean:
	cargo clean
	rm -rf artifacts results
