//! Timing and volume breakdown of one coded job — the quantities Figures
//! 2–5 plot: master encode/decode time, upload/download volume, per-worker
//! compute time and per-worker communication.

use crate::util::json::Json;
use std::time::Duration;

/// Full breakdown of one distributed multiplication job.
#[derive(Clone, Debug, Default)]
pub struct JobMetrics {
    /// Coordinator-assigned job id (jobs may overlap; ids tie metrics to
    /// [`super::master::JobHandle`]s).
    pub job_id: u64,
    /// Master-side encoding time (partition + polynomial evaluation, incl.
    /// RMFE packing where applicable).
    pub encode: Duration,
    /// Master-side decoding time (interpolation + unpacking + assembly).
    pub decode: Duration,
    /// Wall time from dispatch of the first share until the `R`-th response
    /// arrived (includes worker compute and injected straggler delays).
    pub wait_for_r: Duration,
    /// Bytes master → workers (all `N` shares; for a prepared job only the
    /// B-halves that actually crossed per job).
    pub upload_bytes: u64,
    /// Bytes of prepared A-halves staged master → workers on behalf of
    /// this job's `prepare` call (0 for unprepared jobs and for prepared
    /// jobs after the first — staging is encode-once by construction).
    pub staged_upload_bytes: u64,
    /// Bytes of the `R` responses used for decoding.
    pub download_bytes: u64,
    /// Pure compute durations of the responses used (length = `R`).
    pub worker_compute: Vec<Duration>,
    /// Injected straggler delays of the used responses.
    pub worker_delay: Vec<Duration>,
    /// Worker indices that contributed to the decode, in arrival order.
    pub used_workers: Vec<usize>,
    /// Decode-plan cache hits during this job's decode (see
    /// [`crate::codes::plan_cache`]): nonzero when the responding subset's
    /// interpolation setup was already cached.
    pub plan_cache_hits: u64,
    /// Decode-plan cache misses during this job's decode.
    pub plan_cache_misses: u64,
    /// Prepared-operand store hits during this job (see
    /// [`crate::coordinator::prepared`]): 1 on a prepared job whose
    /// operand was found staged.
    pub prepared_hits: u64,
    /// Prepared-operand store misses during this job (an unknown or
    /// evicted id).
    pub prepared_misses: u64,
    /// Prepared operands LRU-evicted during this job's submission window
    /// (capacity pressure on the store).
    pub prepared_evictions: u64,
    /// Speculative shard re-dispatches the elastic coordinator sent for
    /// this job (0 unless speculation is enabled; their payload bytes are
    /// included in `upload_bytes`).
    pub speculative_dispatches: u64,
    /// Responses the verified-decode path rejected as corrupt (malformed
    /// payloads plus shares flagged by surplus / leave-one-out
    /// consistency). 0 unless verification ran.
    pub corrupt_responses_detected: u64,
    /// Freivalds probabilistic product-check trials run for this job.
    pub verify_trials: u64,
    /// Workers this job put into quarantine after a failed verification.
    pub quarantines: u64,
    /// Leave-one-out re-decodes performed to isolate an inconsistent share.
    pub leave_one_out_decodes: u64,
    /// Byte-pool buffer reuses during this job's window (see
    /// [`crate::util::bytepool`]): a warm steady-state job serves every
    /// payload-sized buffer from the pool.
    pub pool_hits: u64,
    /// Byte-pool misses (fresh heap allocations) during this job's window;
    /// 0 once the pool is warm.
    pub pool_misses: u64,
    /// Hot-path heap allocations ≥ 64 KiB during this job's window — the
    /// zero-alloc counter-proof probe, mirroring
    /// `scalar_table_builds()` for encode tables. 0 in the pooled steady
    /// state.
    pub large_allocs: u64,
    /// Total end-to-end wall time at the master.
    pub total: Duration,
}

impl JobMetrics {
    /// Mean pure compute time across the used workers.
    pub fn mean_worker_compute(&self) -> Duration {
        if self.worker_compute.is_empty() {
            return Duration::ZERO;
        }
        self.worker_compute.iter().sum::<Duration>() / self.worker_compute.len() as u32
    }

    /// Maximum worker compute among used responses (the critical path).
    pub fn max_worker_compute(&self) -> Duration {
        self.worker_compute.iter().max().copied().unwrap_or(Duration::ZERO)
    }

    /// Master compute = encode + decode (Figures 2a/3a).
    pub fn master_compute(&self) -> Duration {
        self.encode + self.decode
    }

    /// Per-worker download volume (= the master's upload / N): what Fig. 4b/5b
    /// call the worker's communication "download" side.
    pub fn per_worker_download(&self, n_workers: usize) -> u64 {
        self.upload_bytes / n_workers as u64
    }

    /// Per-worker upload volume (= master download / R).
    pub fn per_worker_upload(&self) -> u64 {
        if self.used_workers.is_empty() {
            0
        } else {
            self.download_bytes / self.used_workers.len() as u64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("job_id", self.job_id)
            .set("plan_cache_hits", self.plan_cache_hits)
            .set("plan_cache_misses", self.plan_cache_misses)
            .set("prepared_hits", self.prepared_hits)
            .set("prepared_misses", self.prepared_misses)
            .set("prepared_evictions", self.prepared_evictions)
            .set("encode_s", self.encode.as_secs_f64())
            .set("decode_s", self.decode.as_secs_f64())
            .set("wait_for_r_s", self.wait_for_r.as_secs_f64())
            .set("upload_bytes", self.upload_bytes)
            .set("staged_upload_bytes", self.staged_upload_bytes)
            .set("download_bytes", self.download_bytes)
            .set("speculative_dispatches", self.speculative_dispatches)
            .set("corrupt_responses_detected", self.corrupt_responses_detected)
            .set("verify_trials", self.verify_trials)
            .set("quarantines", self.quarantines)
            .set("leave_one_out_decodes", self.leave_one_out_decodes)
            .set("pool_hits", self.pool_hits)
            .set("pool_misses", self.pool_misses)
            .set("large_allocs", self.large_allocs)
            .set("mean_worker_compute_s", self.mean_worker_compute().as_secs_f64())
            .set("max_worker_compute_s", self.max_worker_compute().as_secs_f64())
            .set(
                "used_workers",
                Json::Arr(self.used_workers.iter().map(|&w| Json::Int(w as i64)).collect()),
            )
            .set("total_s", self.total.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = JobMetrics {
            encode: Duration::from_millis(10),
            decode: Duration::from_millis(5),
            worker_compute: vec![
                Duration::from_millis(2),
                Duration::from_millis(6),
                Duration::from_millis(4),
            ],
            used_workers: vec![0, 2, 4],
            upload_bytes: 800,
            download_bytes: 300,
            ..Default::default()
        };
        assert_eq!(m.master_compute(), Duration::from_millis(15));
        assert_eq!(m.mean_worker_compute(), Duration::from_millis(4));
        assert_eq!(m.max_worker_compute(), Duration::from_millis(6));
        assert_eq!(m.per_worker_download(8), 100);
        assert_eq!(m.per_worker_upload(), 100);
    }

    #[test]
    fn json_renders() {
        let j = JobMetrics::default().to_json().render();
        assert!(j.contains("encode_s"));
        assert!(j.contains("upload_bytes"));
        assert!(j.contains("job_id"));
        assert!(j.contains("plan_cache_hits"));
        assert!(j.contains("prepared_hits"));
        assert!(j.contains("staged_upload_bytes"));
        assert!(j.contains("speculative_dispatches"));
        assert!(j.contains("corrupt_responses_detected"));
        assert!(j.contains("verify_trials"));
        assert!(j.contains("quarantines"));
        assert!(j.contains("leave_one_out_decodes"));
        assert!(j.contains("pool_hits"));
        assert!(j.contains("large_allocs"));
    }
}
