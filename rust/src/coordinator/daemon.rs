//! The worker daemon: the [`super::worker`] receive → compute → reply loop
//! served over a TCP socket — the process behind `gr-cdmm worker --listen
//! ADDR` and the peer of [`super::tcp::TcpTransport`].
//!
//! A daemon is scheme-agnostic at the protocol level but is configured with
//! a concrete [`ShareCompute`] backend (built from the scheme registry by
//! `main.rs`, so master and workers must agree on the scheme name and
//! worker count — exactly like any deployed executor fleet). It serves one
//! coordinator connection at a time: frames are processed strictly in
//! order ([`process_job`] per job frame, straggler injection included), and
//! a `Shutdown` frame or EOF ends the connection, after which the daemon
//! goes back to accepting — so one daemon survives any number of
//! `gr-cdmm serve`/`run` invocations.
//!
//! The daemon learns *which machine* it is from the coordinator's hello
//! frame (the first thing an elastic master writes on a fresh connection)
//! and echoes the id back so the master can verify it reached the peer it
//! meant to. The machine id keys the straggler RNG stream —
//! [`worker_rng`]`(seed, machine_id)`, the identical stream an in-process
//! pool worker with that id would draw, which is what makes channel and
//! TCP runs comparable draw-for-draw under the same seed. Job frames carry
//! the **shard** index, echoed verbatim on the response; when no hello was
//! received (legacy peers, hand-rolled test frames) the shard index doubles
//! as the machine id, preserving the pre-elastic behavior. Ping frames are
//! answered with pongs; a shutdown frame is acknowledged with a goodbye
//! before the connection closes.
//!
//! **Prepared operands** (wire v3): a stage frame stores a serialized
//! A-side share half under its `prepared_id` (acknowledged with a
//! stage-ack echoing the machine id), an evict frame drops it, and a job
//! frame tagged with a prepared id is computed on the staged bytes
//! prepended to the job payload — byte-for-byte the full share an
//! unprepared job would carry. Staged state is **per connection**: a
//! reconnecting master starts blank and re-stages, so prepared jobs can
//! never silently read stale bytes; a prepared job naming an unknown id is
//! fail-stopped (byte-free response), same as any other dropped job.
//!
//! A malformed peer (garbage bytes, truncated frames, oversized declared
//! payloads) errors the *connection*, never the daemon: the error is
//! logged and the daemon accepts the next connection.

use super::straggler::{CorruptionModel, StragglerModel};
use super::wire::{self, Frame, FrameKind};
use super::worker::{assemble_prepared, process_job_faulty, worker_rng, ShareCompute};
use crate::util::rng::Rng64;
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Upper bound on the worker id a daemon accepts in a job frame. Deriving a
/// worker's RNG stream costs `worker_id` PRNG steps ([`worker_rng`]), so an
/// unbounded id from a malicious coordinator could wedge the accept loop;
/// real ids are < N ≤ 32, so this is pure headroom.
pub const MAX_WORKER_ID: u64 = 1 << 16;

/// Worker-side configuration shared by every connection the daemon serves.
#[derive(Clone, Debug, Default)]
pub struct DaemonConfig {
    /// Straggler injection applied at the worker (the daemon *is* the
    /// remote node, so delays and fail-stop draws happen here, not at the
    /// master).
    pub straggler: StragglerModel,
    /// Byzantine corruption injection applied at the worker, after a
    /// successful compute — the `--corrupt` knob of `gr-cdmm worker`.
    /// Draws share the straggler RNG streams, so a channel pool with the
    /// same seed and model corrupts byte-for-byte identically.
    pub corrupt: CorruptionModel,
    /// Seed deriving the per-worker-id RNG streams ([`worker_rng`]).
    pub seed: u64,
    /// Shared-memory ring directory for the [`super::shm::ShmTransport`]
    /// data plane. When set, the daemon opens `m2w-<id>.ring` /
    /// `w2m-<id>.ring` here on the coordinator's hello, accepts
    /// job-ref/stage-ref doorbells, and ships fitting responses back
    /// through its ring (oversize ones fall back inline). `None` (the
    /// default) serves classic inline frames only.
    pub shm_dir: Option<std::path::PathBuf>,
}

/// Per-connection shared-memory state: the two rings opened on hello plus
/// the next worker→master payload sequence number.
struct ShmState {
    m2w: super::shm::ShmRing,
    w2m: super::shm::ShmRing,
    next_seq: u64,
}

/// Serve one coordinator connection to completion: `Ok(())` on a clean
/// shutdown frame or EOF, `Err` if the peer broke protocol mid-stream.
fn serve_conn(
    stream: TcpStream,
    compute: &dyn ShareCompute,
    cfg: &DaemonConfig,
) -> anyhow::Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    // The machine id the coordinator assigned with its hello frame. Absent
    // a hello, each job's shard index doubles as the machine id (the
    // pre-elastic behavior, still exercised by raw-frame tests).
    let mut identity: Option<usize> = None;
    // One RNG stream per machine id seen on this connection. A coordinator
    // addresses one daemon as one machine, so this map has a single entry
    // in practice; keying by id keeps the draws right even if it doesn't.
    let mut rngs: HashMap<usize, Rng64> = HashMap::new();
    // Per-machine previous *clean* response, feeding the stale-replay
    // corruption model. Per connection, like the RNG streams.
    let mut replays: HashMap<usize, Option<Vec<u8>>> = HashMap::new();
    // Staged prepared operands, **per connection**: a reconnecting master
    // starts from a blank slate and must re-stage (which its prepared store
    // does automatically), so stale staged bytes can never leak across
    // coordinator sessions.
    let mut staged: HashMap<u64, crate::util::bytepool::PooledBuf> = HashMap::new();
    // Shared-memory rings, opened when a hello arrives and `cfg.shm_dir` is
    // set. The master creates the ring files *before* sending the hello, so
    // by the time it is read here both files exist with zeroed slots.
    let mut shm: Option<ShmState> = None;
    loop {
        let Some(frame) = wire::read_frame(&mut reader)? else {
            return Ok(()); // coordinator hung up
        };
        match frame.kind {
            FrameKind::Shutdown => {
                // Acknowledge the graceful leave. The coordinator may have
                // already closed its read side — a failed write is fine.
                let _ =
                    wire::write_frame(&mut writer, &Frame::goodbye(identity.unwrap_or(0)));
                return Ok(());
            }
            FrameKind::Goodbye => return Ok(()), // coordinator left
            FrameKind::Hello => {
                anyhow::ensure!(
                    frame.worker_id < MAX_WORKER_ID,
                    "hello worker id {} exceeds the {MAX_WORKER_ID} limit",
                    frame.worker_id
                );
                let id = usize::try_from(frame.worker_id)?;
                identity = Some(id);
                if let Some(dir) = &cfg.shm_dir {
                    let (m2w, w2m) = super::shm::ring_paths(dir, id);
                    shm = Some(ShmState {
                        m2w: super::shm::ShmRing::open(m2w)?,
                        w2m: super::shm::ShmRing::open(w2m)?,
                        next_seq: 0,
                    });
                }
                // Echo the claim so the master can verify it reached the
                // peer it meant to.
                wire::write_frame(&mut writer, &Frame::hello(id))?;
            }
            FrameKind::Ping => {
                wire::write_frame(
                    &mut writer,
                    &Frame::pong(frame.job_id, identity.unwrap_or(0)),
                )?;
            }
            FrameKind::Stage | FrameKind::StageRef => {
                let bytes = if frame.kind == FrameKind::StageRef {
                    // Out-of-line staged half: resolve the doorbell's slot
                    // (with full header validation) from the m2w ring.
                    let (seq, len) = frame.ref_slot()?;
                    let Some(st) = shm.as_ref() else {
                        anyhow::bail!("stage-ref frame on a connection without shm rings")
                    };
                    st.m2w.read_payload(seq, len)?
                } else {
                    frame.payload
                };
                staged.insert(frame.job_id, bytes);
                // Confirm, echoing the assigned machine id so the master
                // can verify it staged onto the peer it meant to.
                wire::write_frame(
                    &mut writer,
                    &Frame::stage_ack(frame.job_id, identity.unwrap_or(0)),
                )?;
            }
            FrameKind::Evict => {
                // Unknown ids are a no-op: an evict may race a reconnect
                // that already wiped this connection's staged state.
                staged.remove(&frame.job_id);
            }
            FrameKind::Job | FrameKind::JobRef => {
                anyhow::ensure!(
                    frame.worker_id < MAX_WORKER_ID,
                    "worker id {} exceeds the {MAX_WORKER_ID} limit",
                    frame.worker_id
                );
                let shard = usize::try_from(frame.worker_id)?;
                let machine = identity.unwrap_or(shard);
                // A job-ref's share bytes sit in the m2w ring; an inline
                // job's ride the frame. Either way the buffer is shared,
                // not copied.
                let incoming: crate::util::bytepool::PooledBuf =
                    if frame.kind == FrameKind::JobRef {
                        let (seq, len) = frame.ref_slot()?;
                        let Some(st) = shm.as_ref() else {
                            anyhow::bail!("job-ref frame on a connection without shm rings")
                        };
                        st.m2w.read_payload(seq, len)?
                    } else {
                        frame.payload.clone()
                    };
                let full;
                let payload: &[u8] = match frame.job_prepared_id() {
                    None => &incoming,
                    Some(id) => match staged.get(&id) {
                        Some(a_half) => {
                            full = assemble_prepared(a_half, &incoming);
                            &full
                        }
                        None => {
                            // A prepared job naming an operand this
                            // connection was never staged with (e.g. the
                            // job raced a reconnect before the master's
                            // re-stage): fail-stop the shard, byte-free.
                            wire::write_frame(
                                &mut writer,
                                &Frame::from_report(super::transport::fail_report(
                                    frame.job_id,
                                    shard,
                                )),
                            )?;
                            continue;
                        }
                    },
                };
                let rng = rngs.entry(machine).or_insert_with(|| worker_rng(cfg.seed, machine));
                let replay = replays.entry(machine).or_default();
                let report = process_job_faulty(
                    machine,
                    shard,
                    frame.job_id,
                    payload,
                    compute,
                    &cfg.straggler,
                    &cfg.corrupt,
                    rng,
                    replay,
                );
                // When the rings are up and the response fits a slot, ship
                // it out-of-line: ring write first, then the response-ref
                // doorbell. Fail reports (byte-free) and oversize payloads
                // go inline — correctness never depends on ring geometry.
                let mut shipped = false;
                if let (Some(st), Some(p)) = (shm.as_mut(), report.payload.as_ref()) {
                    if p.len() as u64 <= st.w2m.slot_size() {
                        let seq = st.next_seq;
                        st.w2m.write_payload(seq, p, super::shm::SLOT_WAIT)?;
                        wire::write_frame(
                            &mut writer,
                            &Frame::resp_ref(
                                report.job_id,
                                report.worker_id,
                                report.compute,
                                report.injected_delay,
                                seq,
                                p.len() as u64,
                            ),
                        )?;
                        st.next_seq += 1;
                        shipped = true;
                    }
                }
                if !shipped {
                    wire::write_frame(&mut writer, &Frame::from_report(report))?;
                }
            }
            other => anyhow::bail!("unexpected {other:?} frame from the coordinator"),
        }
    }
}

/// Accept loop: serve connections sequentially, `max_conns` of them (or
/// forever when `None`). Connection-level protocol errors are logged and
/// survived; only listener-level errors propagate.
fn serve(
    listener: &TcpListener,
    compute: &dyn ShareCompute,
    cfg: &DaemonConfig,
    max_conns: Option<usize>,
) -> anyhow::Result<()> {
    let mut served = 0usize;
    loop {
        let (stream, peer) = listener.accept()?;
        if let Err(e) = serve_conn(stream, compute, cfg) {
            eprintln!("gr-cdmm worker: connection from {peer} failed: {e}");
        }
        served += 1;
        if max_conns.is_some_and(|max| served >= max) {
            return Ok(());
        }
    }
}

/// Run a worker daemon in the current thread: bind `listen` and serve
/// `max_conns` coordinator connections (forever when `None`). This is the
/// `gr-cdmm worker` subcommand's engine.
pub fn run(
    listen: &str,
    compute: Arc<dyn ShareCompute>,
    cfg: DaemonConfig,
    max_conns: Option<usize>,
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(listen)?;
    eprintln!(
        "gr-cdmm worker [{}] listening on {} (straggler: {:?}, corrupt: {}, seed: {})",
        compute.backend_name(),
        listener.local_addr()?,
        cfg.straggler,
        cfg.corrupt.label(),
        cfg.seed
    );
    serve(&listener, &*compute, &cfg, max_conns)
}

/// A worker daemon on its own thread, bound to an ephemeral loopback port —
/// how tests, benches and the serving experiment's `tcp-loopback` mode get
/// real-socket workers without fixed ports or extra processes.
pub struct WorkerDaemon {
    addr: std::net::SocketAddr,
    handle: JoinHandle<anyhow::Result<()>>,
}

impl WorkerDaemon {
    /// Bind `127.0.0.1:0` and serve exactly `conns` coordinator
    /// connections on a background thread.
    pub fn spawn_local(
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
        conns: usize,
    ) -> anyhow::Result<WorkerDaemon> {
        let cfg = DaemonConfig { straggler, seed, ..DaemonConfig::default() };
        Self::spawn_local_cfg(compute, cfg, conns)
    }

    /// [`WorkerDaemon::spawn_local`] taking a full [`DaemonConfig`], for
    /// daemons that also inject Byzantine corruption.
    pub fn spawn_local_cfg(
        compute: Arc<dyn ShareCompute>,
        cfg: DaemonConfig,
        conns: usize,
    ) -> anyhow::Result<WorkerDaemon> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let handle = std::thread::Builder::new()
            .name(format!("gr-cdmm-daemon-{addr}"))
            .spawn(move || serve(&listener, &*compute, &cfg, Some(conns)))?;
        Ok(WorkerDaemon { addr, handle })
    }

    /// The bound `host:port`, ready for `TcpTransport::connect`.
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Wait for the daemon to finish its connection budget.
    pub fn join(self) -> anyhow::Result<()> {
        self.handle
            .join()
            .map_err(|_| anyhow::anyhow!("worker daemon thread panicked"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    struct Echo;
    impl ShareCompute for Echo {
        fn compute(
            &self,
            _w: usize,
            payload: &[u8],
        ) -> anyhow::Result<crate::util::bytepool::PooledBuf> {
            Ok(payload.to_vec().into())
        }
    }

    #[test]
    fn daemon_serves_jobs_and_honors_shutdown_frames() {
        let daemon =
            WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::None, 1, 1).unwrap();
        let stream = TcpStream::connect(daemon.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        wire::write_frame(&mut writer, &Frame::job(3, 0, vec![7u8; 20])).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("one response");
        assert_eq!(resp.kind, FrameKind::RespOk);
        assert_eq!((resp.job_id, resp.worker_id), (3, 0));
        assert_eq!(resp.payload, vec![7u8; 20]);
        wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        daemon.join().unwrap();
    }

    #[test]
    fn daemon_reports_fail_stop_draws_byte_free() {
        let daemon =
            WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::fail_stop([2]), 1, 1)
                .unwrap();
        let stream = TcpStream::connect(daemon.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        // worker id 2 fail-stops, worker id 0 answers (one daemon can stand
        // in for either — identity comes from the job frame)
        wire::write_frame(&mut writer, &Frame::job(1, 2, vec![1u8; 8])).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("fail report");
        assert_eq!(resp.kind, FrameKind::RespFail);
        assert_eq!((resp.job_id, resp.worker_id), (1, 2));
        assert!(resp.payload.is_empty());
        wire::write_frame(&mut writer, &Frame::job(2, 0, vec![1u8; 8])).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("echo");
        assert_eq!(resp.kind, FrameKind::RespOk);
        wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        daemon.join().unwrap();
    }

    #[test]
    fn daemon_answers_hello_ping_and_says_goodbye() {
        let daemon =
            WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::fail_stop([2]), 1, 1)
                .unwrap();
        let stream = TcpStream::connect(daemon.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;

        // hello assigns machine id 2; the daemon echoes the claim
        wire::write_frame(&mut writer, &Frame::hello(2)).unwrap();
        let echo = wire::read_frame(&mut reader).unwrap().expect("hello echo");
        assert_eq!((echo.kind, echo.worker_id), (FrameKind::Hello, 2));

        // pings come back as pongs echoing the nonce
        wire::write_frame(&mut writer, &Frame::ping(0xC0FFEE)).unwrap();
        let pong = wire::read_frame(&mut reader).unwrap().expect("pong");
        assert_eq!((pong.kind, pong.job_id, pong.worker_id), (FrameKind::Pong, 0xC0FFEE, 2));

        // straggler draws key off the hello identity (machine 2 fail-stops)
        // even when the job frame carries another worker's shard index —
        // and the response still echoes the shard.
        wire::write_frame(&mut writer, &Frame::job(9, 0, vec![4u8; 6])).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("fail report");
        assert_eq!((resp.kind, resp.job_id, resp.worker_id), (FrameKind::RespFail, 9, 0));

        // shutdown is acknowledged with a goodbye
        wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        let bye = wire::read_frame(&mut reader).unwrap().expect("goodbye");
        assert_eq!((bye.kind, bye.worker_id), (FrameKind::Goodbye, 2));
        daemon.join().unwrap();
    }

    #[test]
    fn daemon_stages_prepends_and_forgets_across_connections() {
        let daemon =
            WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::None, 1, 2).unwrap();
        {
            let stream = TcpStream::connect(daemon.addr()).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            wire::write_frame(&mut writer, &Frame::hello(1)).unwrap();
            let _ = wire::read_frame(&mut reader).unwrap().expect("hello echo");

            // Stage operand 7; the ack echoes the id and the machine id.
            wire::write_frame(&mut writer, &Frame::stage(7, vec![0xA, 0xB])).unwrap();
            let ack = wire::read_frame(&mut reader).unwrap().expect("stage ack");
            assert_eq!((ack.kind, ack.job_id, ack.worker_id), (FrameKind::StageAck, 7, 1));

            // A prepared job ships only the B-half; the echo proves the
            // daemon computed on staged ++ payload.
            wire::write_job_frame(&mut writer, 4, 0, Some(7), &[0xC, 0xD]).unwrap();
            let resp = wire::read_frame(&mut reader).unwrap().expect("echo");
            assert_eq!(resp.kind, FrameKind::RespOk);
            assert_eq!(resp.payload, vec![0xA, 0xB, 0xC, 0xD]);

            // An unknown prepared id fail-stops the shard, byte-free.
            wire::write_job_frame(&mut writer, 5, 0, Some(99), &[0xC]).unwrap();
            let resp = wire::read_frame(&mut reader).unwrap().expect("fail report");
            assert_eq!((resp.kind, resp.job_id, resp.worker_id), (FrameKind::RespFail, 5, 0));
            assert!(resp.payload.is_empty());

            // Evicting makes the id unknown again.
            wire::write_frame(&mut writer, &Frame::evict(7)).unwrap();
            wire::write_job_frame(&mut writer, 6, 0, Some(7), &[0xC]).unwrap();
            let resp = wire::read_frame(&mut reader).unwrap().expect("fail report");
            assert_eq!(resp.kind, FrameKind::RespFail);
            wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        }
        // A fresh connection has no staged state: prepared jobs referencing
        // the old connection's operands fail-stop until re-staged.
        let stream = TcpStream::connect(daemon.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        wire::write_job_frame(&mut writer, 9, 0, Some(7), &[0xC]).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("fail report");
        assert_eq!(resp.kind, FrameKind::RespFail);
        wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        daemon.join().unwrap();
    }

    #[test]
    fn daemon_corrupts_responses_identically_to_an_in_process_worker() {
        let corrupt = CorruptionModel::bit_flip([0]);
        let cfg = DaemonConfig {
            straggler: StragglerModel::None,
            corrupt: corrupt.clone(),
            seed: 11,
            ..DaemonConfig::default()
        };
        let daemon = WorkerDaemon::spawn_local_cfg(Arc::new(Echo), cfg, 1).unwrap();
        let stream = TcpStream::connect(daemon.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        wire::write_frame(&mut writer, &Frame::hello(0)).unwrap();
        let _ = wire::read_frame(&mut reader).unwrap().expect("hello echo");
        let payload = vec![0u8; 40];
        wire::write_frame(&mut writer, &Frame::job(1, 0, payload.clone())).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("corrupted echo");
        assert_eq!(resp.kind, FrameKind::RespOk, "corruption is silent, not a failure");
        assert_ne!(resp.payload, payload);
        // Byte-for-byte the draw an in-process worker 0 with the same seed
        // and model would make (the channel ↔ TCP parity property).
        let expected = process_job_faulty(
            0,
            0,
            1,
            &payload,
            &Echo,
            &StragglerModel::None,
            &corrupt,
            &mut worker_rng(11, 0),
            &mut None,
        );
        assert_eq!(resp.payload, expected.payload.unwrap());
        wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        daemon.join().unwrap();
    }

    #[test]
    fn daemon_survives_a_malformed_connection() {
        let daemon =
            WorkerDaemon::spawn_local(Arc::new(Echo), StragglerModel::None, 1, 2).unwrap();
        // connection 1: garbage — errors the connection, not the daemon
        {
            let mut stream = TcpStream::connect(daemon.addr()).unwrap();
            stream.write_all(&[0xAB; 64]).unwrap();
        }
        // connection 2: still served normally
        let stream = TcpStream::connect(daemon.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        wire::write_frame(&mut writer, &Frame::job(5, 1, vec![2u8; 4])).unwrap();
        let resp = wire::read_frame(&mut reader).unwrap().expect("echo after bad peer");
        assert_eq!(resp.kind, FrameKind::RespOk);
        assert_eq!(resp.payload, vec![2u8; 4]);
        wire::write_frame(&mut writer, &Frame::shutdown()).unwrap();
        daemon.join().unwrap();
    }
}
