//! The worker node: a receive → compute → reply loop, runnable either as an
//! in-process OS thread ([`spawn_worker`], used by
//! [`super::transport::ChannelTransport`]) or inside a TCP daemon serving a
//! socket ([`super::daemon`]). Both paths share [`process_job`], so a job
//! is handled identically wherever the worker lives.
//!
//! Workers are scheme-agnostic: they apply a [`ShareCompute`] backend
//! (native ring kernels, or the AOT XLA executable via
//! [`crate::runtime::gr_backend`]) to opaque serialized shares. This mirrors
//! the deployment model where worker binaries are generic executors and the
//! master owns all code-specific logic.
//!
//! Since speculative re-dispatch, a job carries two identities: the
//! **machine id** (which physical worker is computing — keys the straggler
//! draw and the RNG stream) and the **shard id** (which piece of the job
//! this is — what the report must echo so the master can match it). They
//! coincide on the primary dispatch path and differ when a spare machine
//! recomputes another worker's shard.

use super::straggler::{CorruptionModel, StragglerModel};
use super::transport::{fail_report, FromWorker, ToWorker, WorkerLink};
use crate::util::bytepool::{note_copy, BytePool, PooledBuf};
use crate::util::rng::Rng64;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// The worker-side compute backend: serialized share in, serialized response
/// out. Implementations in [`crate::coordinator::runner`] (native) and
/// [`crate::runtime::gr_backend`] (XLA).
pub trait ShareCompute: Send + Sync {
    fn compute(&self, worker_id: usize, payload: &[u8]) -> anyhow::Result<PooledBuf>;
    /// Human-readable backend name for logs.
    fn backend_name(&self) -> String {
        "native".to_string()
    }
}

/// The deterministic RNG stream of worker `worker_id` under coordinator
/// seed `seed`: the `worker_id`-th fork of a seeder over `seed`. A TCP
/// daemon configured with the same seed draws the identical straggler
/// stream for worker `i` that an in-process pool thread `i` would — which
/// is what makes channel-vs-TCP runs comparable draw-for-draw.
pub fn worker_rng(seed: u64, worker_id: usize) -> Rng64 {
    let mut seeder = Rng64::seeded(seed);
    for _ in 0..worker_id {
        seeder.next_u64();
    }
    seeder.fork()
}

/// Handle one job exactly as the worker loop does: sample the straggler
/// model (a `None` draw = fail-stop — the job is dropped and reported
/// byte-free so the master's job retirement stays deterministic), sleep any
/// injected delay, run the compute backend, and package the report. A
/// compute error (e.g. a malformed payload) is reported as a clean job
/// failure, never a panic.
///
/// `machine_id` is the physical worker doing the computing (keys the
/// straggler draw and the backend); `shard` is the job piece being computed
/// and is what the report's `worker_id` field echoes. They differ only when
/// a spare machine recomputes a re-dispatched shard.
pub fn process_job(
    machine_id: usize,
    shard: usize,
    job_id: u64,
    payload: &[u8],
    compute: &dyn ShareCompute,
    straggler: &StragglerModel,
    rng: &mut Rng64,
) -> FromWorker {
    process_job_faulty(
        machine_id,
        shard,
        job_id,
        payload,
        compute,
        straggler,
        &CorruptionModel::None,
        rng,
        &mut None,
    )
}

/// [`process_job`] with Byzantine fault injection. After a successful
/// compute, a worker targeted by `corrupt` mutates its response bytes
/// according to the model before replying — the master receives a
/// well-formed-looking but wrong share, exactly the failure class verified
/// decode must catch. `replay` is the worker's previous *clean* response
/// (fed to [`CorruptionModel::StaleReplay`]); callers hold one slot per
/// worker so the replay state survives across jobs like it would in a
/// long-lived daemon connection.
///
/// Corruption draws come from the same per-worker RNG stream as straggler
/// draws and are taken only for targeted workers, so channel and TCP
/// transports configured with the same seed corrupt byte-for-byte
/// identically (the parity property the straggler models already have).
#[allow(clippy::too_many_arguments)]
pub fn process_job_faulty(
    machine_id: usize,
    shard: usize,
    job_id: u64,
    payload: &[u8],
    compute: &dyn ShareCompute,
    straggler: &StragglerModel,
    corrupt: &CorruptionModel,
    rng: &mut Rng64,
    replay: &mut Option<Vec<u8>>,
) -> FromWorker {
    let Some(delay) = straggler.sample(machine_id, rng) else {
        // Fail-stop: drop the job. The master never sees response *bytes*
        // (`payload: None` is invisible to collection, exactly like silence
        // on a network), but the empty report lets the response router
        // retire the job's table entry once every worker has been heard
        // from.
        return fail_report(job_id, shard);
    };
    if !delay.is_zero() {
        std::thread::sleep(delay);
    }
    let t0 = Instant::now();
    let result = compute.compute(machine_id, payload);
    let compute_time = t0.elapsed();
    let response = match result.ok() {
        Some(clean) if corrupt.targets(machine_id) => {
            // Fault-injection path only: the deliberate copy-out lets the
            // model mutate bytes without touching the shared clean buffer.
            let mut bytes = clean.to_vec();
            corrupt.apply(machine_id, rng, &mut bytes, replay.as_deref());
            *replay = Some(clean.to_vec());
            Some(PooledBuf::from_vec(bytes))
        }
        other => other,
    };
    FromWorker {
        job_id,
        worker_id: shard,
        payload: response,
        compute: compute_time,
        injected_delay: delay,
    }
}

/// Reassemble a prepared job's full share payload: the staged left half's
/// bytes followed by the job's right-half bytes. [`Share::to_bytes`]
/// concatenates the serialized `a`-planes then the `b`-planes, so this is
/// byte-for-byte what an unprepared dispatch of the same job would carry —
/// the compute path downstream is completely unaware of staging.
///
/// The output buffer comes from the global [`BytePool`], and the (inherent,
/// deliberate) byte duplication is charged to the
/// [`copied_bytes`](crate::util::bytepool::copied_bytes) probe — prepared
/// serving is the one hot-path site where a payload-sized copy is part of
/// the protocol rather than an accident.
///
/// [`Share::to_bytes`]: crate::codes::Share::to_bytes
pub fn assemble_prepared(staged: &[u8], b_half: &[u8]) -> PooledBuf {
    let total = staged.len() + b_half.len();
    let mut full = BytePool::global().lease(total);
    full.extend_from_slice(staged);
    full.extend_from_slice(b_half);
    note_copy(total);
    full.freeze()
}

/// Spawn one in-process worker thread. Returns its join handle.
///
/// The worker holds a map of **staged operands** (prepared left halves,
/// keyed by `prepared_id`): a [`ToWorker::Stage`] inserts, a
/// [`ToWorker::Evict`] removes, and a job carrying `prepared: Some(id)`
/// prepends the staged bytes to its payload before computing — or
/// fail-stops the shard if the id is unknown (e.g. the job raced a
/// reconnect before the master re-staged), exactly like a TCP daemon whose
/// fresh connection has no staged state yet.
///
/// `link` is the master-shared membership state: while `link.dead` is set
/// the worker fail-stops every job it dequeues (the payload was never
/// "sent" — the master's send path already returned 0 bytes for jobs
/// dispatched after the death, and this covers jobs that were already
/// queued) and swallows pings, exactly like a dead socket. Clearing the
/// flag revives the worker with its RNG stream intact.
#[allow(clippy::too_many_arguments)]
pub fn spawn_worker(
    worker_id: usize,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    compute: Arc<dyn ShareCompute>,
    straggler: StragglerModel,
    corrupt: CorruptionModel,
    mut rng: Rng64,
    link: Arc<WorkerLink>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("gr-cdmm-worker-{worker_id}"))
        .spawn(move || {
            let mut staged: HashMap<u64, PooledBuf> = HashMap::new();
            let mut replay: Option<Vec<u8>> = None;
            while let Ok(msg) = rx.recv() {
                match msg {
                    ToWorker::Shutdown => break,
                    ToWorker::Ping { sent, .. } => {
                        if !link.dead.load(Ordering::Relaxed) {
                            *link.last_rtt.lock().unwrap() = Some(sent.elapsed());
                            *link.last_heard.lock().unwrap() = Some(Instant::now());
                        }
                    }
                    ToWorker::Stage { prepared_id, payload } => {
                        if !link.dead.load(Ordering::Relaxed) {
                            staged.insert(prepared_id, payload);
                            *link.last_heard.lock().unwrap() = Some(Instant::now());
                        }
                        // A dead worker never received the bytes — exactly
                        // like a closed socket; the master re-stages on
                        // reconnect.
                    }
                    ToWorker::Evict { prepared_id } => {
                        if !link.dead.load(Ordering::Relaxed) {
                            staged.remove(&prepared_id);
                        }
                    }
                    ToWorker::Job { job_id, shard, prepared, payload } => {
                        let report = if link.dead.load(Ordering::Relaxed) {
                            fail_report(job_id, shard)
                        } else {
                            let full;
                            let bytes: &[u8] = match prepared {
                                None => &payload,
                                Some(id) => match staged.get(&id) {
                                    Some(a_half) => {
                                        full = assemble_prepared(a_half, &payload);
                                        &full
                                    }
                                    None => {
                                        // Unknown prepared id: fail-stop the
                                        // shard (byte-free report), same as
                                        // a daemon connection that has not
                                        // been (re-)staged yet.
                                        let _ = tx.send(fail_report(job_id, shard));
                                        continue;
                                    }
                                },
                            };
                            let r = process_job_faulty(
                                worker_id,
                                shard,
                                job_id,
                                bytes,
                                &*compute,
                                &straggler,
                                &corrupt,
                                &mut rng,
                                &mut replay,
                            );
                            *link.last_heard.lock().unwrap() = Some(Instant::now());
                            r
                        };
                        // master may have hung up (job already satisfied) —
                        // a send error is not a worker error.
                        let _ = tx.send(report);
                    }
                }
            }
        })
        .expect("failed to spawn worker thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<PooledBuf> {
            Ok(payload.to_vec().into())
        }
    }

    struct AlwaysErr;
    impl ShareCompute for AlwaysErr {
        fn compute(&self, _w: usize, _payload: &[u8]) -> anyhow::Result<PooledBuf> {
            anyhow::bail!("broken backend")
        }
    }

    #[test]
    fn worker_rng_matches_sequential_forking() {
        // worker_rng(seed, i) must equal the i-th fork of one shared seeder
        // (the pre-daemon pool construction), stream-for-stream.
        let mut seeder = Rng64::seeded(77);
        for wid in 0..8 {
            let mut from_pool = seeder.fork();
            let mut from_fn = worker_rng(77, wid);
            for _ in 0..16 {
                assert_eq!(from_pool.next_u64(), from_fn.next_u64(), "worker {wid}");
            }
        }
    }

    #[test]
    fn process_job_success_failure_and_fail_stop() {
        let mut rng = Rng64::seeded(1);
        let ok = process_job(0, 0, 7, &[1, 2], &Echo, &StragglerModel::None, &mut rng);
        assert_eq!((ok.job_id, ok.worker_id), (7, 0));
        assert_eq!(ok.payload.as_deref(), Some(&[1u8, 2][..]));

        let err = process_job(0, 0, 8, &[1], &AlwaysErr, &StragglerModel::None, &mut rng);
        assert!(err.payload.is_none(), "compute errors are clean job failures");

        let dropped =
            process_job(3, 3, 9, &[1], &Echo, &StragglerModel::fail_stop([3]), &mut rng);
        assert!(dropped.payload.is_none());
        assert_eq!(dropped.compute, Duration::ZERO);
    }

    #[test]
    fn process_job_reports_injected_delay() {
        let mut rng = Rng64::seeded(2);
        let slow = StragglerModel::fixed_slow([0], Duration::from_millis(15));
        let report = process_job(0, 0, 1, &[9], &Echo, &slow, &mut rng);
        assert_eq!(report.injected_delay, Duration::from_millis(15));
        assert!(report.payload.is_some());
    }

    #[test]
    fn staged_operand_is_prepended_and_unknown_id_fail_stops() {
        use std::sync::mpsc::channel;
        let (to_tx, to_rx) = channel();
        let (from_tx, from_rx) = channel();
        let link = Arc::new(WorkerLink::default());
        let handle = spawn_worker(
            0,
            to_rx,
            from_tx,
            Arc::new(Echo),
            StragglerModel::None,
            CorruptionModel::None,
            Rng64::seeded(5),
            Arc::clone(&link),
        );
        // Stage id 3, then a prepared job carrying only the right half:
        // the echo must see staged ++ payload.
        to_tx.send(ToWorker::Stage { prepared_id: 3, payload: vec![0xA, 0xB].into() }).unwrap();
        to_tx
            .send(ToWorker::Job {
                job_id: 1,
                shard: 0,
                prepared: Some(3),
                payload: vec![0xC].into(),
            })
            .unwrap();
        let r = from_rx.recv().unwrap();
        assert_eq!(r.payload.as_deref(), Some(&[0xA, 0xB, 0xC][..]));
        // Unknown id: byte-free fail report, not a panic.
        to_tx
            .send(ToWorker::Job {
                job_id: 2,
                shard: 0,
                prepared: Some(99),
                payload: vec![0xC].into(),
            })
            .unwrap();
        let r = from_rx.recv().unwrap();
        assert!(r.payload.is_none(), "unknown prepared id fail-stops the shard");
        // Evicted id behaves like an unknown one.
        to_tx.send(ToWorker::Evict { prepared_id: 3 }).unwrap();
        to_tx
            .send(ToWorker::Job {
                job_id: 3,
                shard: 0,
                prepared: Some(3),
                payload: vec![0xC].into(),
            })
            .unwrap();
        assert!(from_rx.recv().unwrap().payload.is_none());
        to_tx.send(ToWorker::Shutdown).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn corrupting_worker_mutates_bytes_and_untargeted_worker_stays_clean() {
        let model = CorruptionModel::bit_flip([1]);
        let payload = vec![0u8; 32];
        // Worker 0 is untargeted: report matches clean and draws nothing.
        let mut rng0 = Rng64::seeded(9);
        let mut replay0 = None;
        let clean = process_job_faulty(
            0, 0, 1, &payload, &Echo, &StragglerModel::None, &model, &mut rng0, &mut replay0,
        );
        assert_eq!(clean.payload.as_deref(), Some(&payload[..]));
        assert!(replay0.is_none(), "untargeted worker keeps no replay state");
        // Worker 1 is targeted: exactly one bit flipped, clean copy retained
        // as the replay state for a future stale-replay draw.
        let mut rng1 = Rng64::seeded(9);
        let mut replay1 = None;
        let bad = process_job_faulty(
            1, 1, 1, &payload, &Echo, &StragglerModel::None, &model, &mut rng1, &mut replay1,
        );
        let got = bad.payload.unwrap();
        assert_ne!(got, payload, "targeted worker's response is corrupted");
        let flipped: u32 =
            got.iter().zip(&payload).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(flipped, 1, "bit-flip changes exactly one bit");
        assert_eq!(replay1.as_deref(), Some(&payload[..]), "clean bytes stored for replay");
    }

    #[test]
    fn stale_replay_worker_resends_its_previous_clean_response() {
        let model = CorruptionModel::stale_replay([0]);
        let mut rng = Rng64::seeded(4);
        let mut replay = None;
        // First job: no previous response to replay — passes through clean.
        let first = process_job_faulty(
            0, 0, 1, &[1, 2, 3], &Echo, &StragglerModel::None, &model, &mut rng, &mut replay,
        );
        assert_eq!(first.payload.as_deref(), Some(&[1u8, 2, 3][..]));
        // Second job: replays job 1's clean bytes instead of its own.
        let second = process_job_faulty(
            0, 0, 2, &[4, 5, 6], &Echo, &StragglerModel::None, &model, &mut rng, &mut replay,
        );
        assert_eq!(second.payload.as_deref(), Some(&[1u8, 2, 3][..]));
    }

    #[test]
    fn spare_machine_reports_the_shard_id_and_draws_its_own_straggler_stream() {
        // Machine 3 recomputes shard 0: the report must carry shard 0, and
        // the straggler draw must be keyed by the machine — a fail-stop
        // model targeting shard 0's machine does NOT hit the spare.
        let mut rng = Rng64::seeded(3);
        let model = StragglerModel::fail_stop([0]);
        let report = process_job(3, 0, 11, &[5, 6], &Echo, &model, &mut rng);
        assert_eq!(report.worker_id, 0, "report echoes the shard id");
        assert!(report.payload.is_some(), "straggler draw keys off the machine id");
    }
}
