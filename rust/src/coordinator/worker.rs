//! The worker node: an OS thread running a receive → compute → reply loop.
//!
//! Workers are scheme-agnostic: they apply a [`ShareCompute`] backend
//! (native ring kernels, or the AOT XLA executable via
//! [`crate::runtime::gr_backend`]) to opaque serialized shares. This mirrors
//! the deployment model where worker binaries are generic executors and the
//! master owns all code-specific logic.

use super::straggler::StragglerModel;
use super::transport::{FromWorker, ToWorker};
use crate::util::rng::Rng64;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The worker-side compute backend: serialized share in, serialized response
/// out. Implementations in [`crate::coordinator::runner`] (native) and
/// [`crate::runtime::gr_backend`] (XLA).
pub trait ShareCompute: Send + Sync {
    fn compute(&self, worker_id: usize, payload: &[u8]) -> anyhow::Result<Vec<u8>>;
    /// Human-readable backend name for logs.
    fn backend_name(&self) -> String {
        "native".to_string()
    }
}

/// Spawn one worker thread. Returns its join handle.
pub fn spawn_worker(
    worker_id: usize,
    rx: Receiver<ToWorker>,
    tx: Sender<FromWorker>,
    compute: Arc<dyn ShareCompute>,
    straggler: StragglerModel,
    mut rng: Rng64,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("gr-cdmm-worker-{worker_id}"))
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    ToWorker::Shutdown => break,
                    ToWorker::Job { job_id, payload } => {
                        let delay = straggler.sample(worker_id, &mut rng);
                        let Some(delay) = delay else {
                            // Fail-stop: drop the job. The master never sees
                            // response *bytes* (`payload: None` is invisible
                            // to collection, exactly like silence on a
                            // network), but the empty report lets the
                            // response router retire the job's table entry
                            // once every worker has been heard from.
                            let _ = tx.send(FromWorker {
                                job_id,
                                worker_id,
                                payload: None,
                                compute: Duration::ZERO,
                                injected_delay: Duration::ZERO,
                            });
                            continue;
                        };
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        let t0 = Instant::now();
                        let result = compute.compute(worker_id, &payload);
                        let compute_time = t0.elapsed();
                        let payload = match result {
                            Ok(bytes) => Some(bytes),
                            Err(_) => None,
                        };
                        // master may have hung up (job already satisfied) —
                        // a send error is not a worker error.
                        let _ = tx.send(FromWorker {
                            job_id,
                            worker_id,
                            payload,
                            compute: compute_time,
                            injected_delay: delay,
                        });
                    }
                }
            }
        })
        .expect("failed to spawn worker thread")
}
