//! [`TcpTransport`]: the socket-backed [`Transport`] — one TCP connection
//! per worker to a `gr-cdmm worker` daemon ([`super::daemon`]), speaking
//! the length-prefixed [`super::wire`] protocol.
//!
//! # Fail-stop semantics
//!
//! A worker's link can die at any point: connection reset, daemon crash,
//! malformed or truncated frames, an oversized declared payload — the
//! per-connection reader treats every one of these as the worker turning
//! **fail-stop**. It synthesizes a byte-free
//! [`fail_report`](super::transport::fail_report) for every job sent on the
//! link but not yet answered, and the writer side does the same for jobs
//! submitted after the death, so the master's router still hears from every
//! worker exactly once per job and PR 3's deterministic job retirement
//! keeps working. A dead worker is indistinguishable from the
//! [`StragglerModel::FailStop`](super::straggler::StragglerModel) model —
//! jobs fail fast with "cannot complete" when the threshold becomes
//! unreachable, never hang, and never panic.
//!
//! # Byte accounting
//!
//! [`Transport::send`] returns the serialized share payload length actually
//! written (0 if the worker is already dead); response payload bytes are
//! counted by the router as messages arrive — the same quantities at the
//! same boundaries as [`super::transport::ChannelTransport`]. Frame headers
//! are deliberately *excluded* so measured volume stays equal to the
//! schemes' analytic `upload_bytes`/`download_bytes` across transports.
//!
//! # Identity
//!
//! The connection index — the position of the endpoint in the `connect`
//! list — is the authoritative worker id: the id echoed in response frames
//! is ignored, so a confused (or byzantine) daemon cannot impersonate
//! another worker. Duplicate responses are additionally dropped by the
//! master's router (see [`super::master`]).

use super::transport::{fail_report, FromWorker, ToWorker, Transport};
use super::wire::{self, Frame, FrameKind};
use std::collections::BTreeSet;
use std::io::{BufReader, ErrorKind};
use std::net::{Shutdown as SockShutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Connection attempts before giving up on an endpoint (daemons may still
/// be binding when the coordinator starts — e.g. the CI loopback e2e).
const CONNECT_ATTEMPTS: usize = 40;
/// Pause between connection attempts.
const CONNECT_RETRY: Duration = Duration::from_millis(125);
/// How long [`TcpTransport::shutdown`] waits for a peer to finish its
/// queued work and close before force-closing the socket. A healthy daemon
/// closes as soon as it reads the shutdown frame; a wedged one (frozen
/// host, SIGSTOP'd process) must not hang the master's shutdown forever.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(30);

/// Writer/reader-shared per-connection state. `pending` holds the job ids
/// sent on the link but not yet answered; whoever observes the death
/// (reader *or* writer) flips `alive` and drains `pending` into synthetic
/// fail-stop reports under the same lock, so every job is reported exactly
/// once.
struct ConnState {
    alive: bool,
    pending: BTreeSet<u64>,
}

type SharedState = Arc<Mutex<ConnState>>;

/// Take every pending job id and mark the connection dead. Returns the jobs
/// to report as fail-stopped (empty if another path already drained them).
fn drain_dead(state: &SharedState) -> BTreeSet<u64> {
    let mut st = state.lock().unwrap();
    st.alive = false;
    std::mem::take(&mut st.pending)
}

fn spawn_reader(
    worker_id: usize,
    stream: TcpStream,
    state: SharedState,
    funnel: Sender<FromWorker>,
    peer: String,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("gr-cdmm-tcp-reader-{worker_id}"))
        .spawn(move || {
            let mut reader = BufReader::new(stream);
            loop {
                let report = match wire::read_frame(&mut reader) {
                    Ok(Some(frame))
                        if matches!(frame.kind, FrameKind::RespOk | FrameKind::RespFail) =>
                    {
                        frame.into_report()
                    }
                    Ok(Some(frame)) => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) sent an unexpected \
                             {:?} frame; treating it as fail-stopped",
                            frame.kind
                        );
                        break;
                    }
                    Ok(None) => break, // clean close
                    Err(e) => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) link broke: {e}; \
                             treating it as fail-stopped"
                        );
                        break;
                    }
                };
                let mut msg = match report {
                    Ok(msg) => msg,
                    Err(e) => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) sent a malformed \
                             response ({e}); treating it as fail-stopped"
                        );
                        break;
                    }
                };
                // The connection index is the authoritative identity.
                msg.worker_id = worker_id;
                state.lock().unwrap().pending.remove(&msg.job_id);
                if funnel.send(msg).is_err() {
                    break; // coordinator gone
                }
            }
            // Fail-stop: report every job this link still owed an answer.
            for job_id in drain_dead(&state) {
                if funnel.send(fail_report(job_id, worker_id)).is_err() {
                    break;
                }
            }
        })
        .expect("failed to spawn tcp reader thread")
}

fn connect_retry(addr: &str) -> anyhow::Result<TcpStream> {
    let mut last_err = String::new();
    for attempt in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) if e.kind() == ErrorKind::ConnectionRefused => {
                last_err = e.to_string();
                if attempt + 1 < CONNECT_ATTEMPTS {
                    std::thread::sleep(CONNECT_RETRY);
                }
            }
            Err(e) => anyhow::bail!("connecting to worker at {addr}: {e}"),
        }
    }
    anyhow::bail!(
        "worker at {addr} refused {CONNECT_ATTEMPTS} connection attempts \
         (is `gr-cdmm worker --listen {addr}` running?): {last_err}"
    )
}

/// The socket transport. Build with [`TcpTransport::connect`]; endpoint `i`
/// in the list is worker `i`.
pub struct TcpTransport {
    streams: Vec<TcpStream>,
    states: Vec<SharedState>,
    readers: Vec<JoinHandle<()>>,
    funnel: Option<Sender<FromWorker>>,
    rx: Option<Receiver<FromWorker>>,
    shut: bool,
}

impl TcpTransport {
    /// Connect to one `gr-cdmm worker` daemon per endpoint (retrying
    /// refused connections for a few seconds, so daemons may still be
    /// starting). All endpoints must accept before any job traffic flows;
    /// an unreachable endpoint is a hard error — a worker that dies *after*
    /// connecting degrades to fail-stop instead.
    pub fn connect(endpoints: &[String]) -> anyhow::Result<TcpTransport> {
        anyhow::ensure!(!endpoints.is_empty(), "need at least one worker endpoint");
        let mut streams = Vec::with_capacity(endpoints.len());
        for addr in endpoints {
            let stream = connect_retry(addr)?;
            stream.set_nodelay(true)?;
            streams.push(stream);
        }
        // Only spawn reader threads once every endpoint is connected, so a
        // failed connect leaks nothing.
        let (funnel_tx, rx) = channel::<FromWorker>();
        let mut states = Vec::with_capacity(endpoints.len());
        let mut readers = Vec::with_capacity(endpoints.len());
        for (wid, (stream, addr)) in streams.iter().zip(endpoints).enumerate() {
            let state: SharedState =
                Arc::new(Mutex::new(ConnState { alive: true, pending: BTreeSet::new() }));
            readers.push(spawn_reader(
                wid,
                stream.try_clone()?,
                Arc::clone(&state),
                funnel_tx.clone(),
                addr.clone(),
            ));
            states.push(state);
        }
        Ok(TcpTransport {
            streams,
            states,
            readers,
            funnel: Some(funnel_tx),
            rx: Some(rx),
            shut: false,
        })
    }

    /// Report `job_id` as fail-stopped at `worker_id` (link already dead).
    fn synthesize_fail(&self, worker_id: usize, job_id: u64) {
        if let Some(tx) = &self.funnel {
            let _ = tx.send(fail_report(job_id, worker_id));
        }
    }
}

impl Transport for TcpTransport {
    fn n_workers(&self) -> usize {
        self.streams.len()
    }

    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
        anyhow::ensure!(worker_id < self.streams.len(), "worker id {worker_id} out of range");
        match msg {
            ToWorker::Shutdown => {
                if self.states[worker_id].lock().unwrap().alive {
                    let _ = wire::write_frame(&mut &self.streams[worker_id], &Frame::shutdown());
                }
                Ok(0)
            }
            ToWorker::Job { job_id, payload } => {
                {
                    let mut st = self.states[worker_id].lock().unwrap();
                    if !st.alive {
                        // Dead link = fail-stop worker: report byte-free so
                        // the job still retires deterministically.
                        drop(st);
                        self.synthesize_fail(worker_id, job_id);
                        return Ok(0);
                    }
                    st.pending.insert(job_id);
                }
                let len = payload.len();
                let frame = Frame::job(job_id, worker_id, payload);
                if wire::write_frame(&mut &self.streams[worker_id], &frame).is_err() {
                    // The link died mid-write: whatever the daemon received
                    // is now moot. Unblock the reader and fail-stop every
                    // job this link still owed (including this one, unless
                    // the reader drained it first).
                    let _ = self.streams[worker_id].shutdown(SockShutdown::Both);
                    for job in drain_dead(&self.states[worker_id]) {
                        self.synthesize_fail(worker_id, job);
                    }
                    return Ok(0);
                }
                Ok(len)
            }
        }
    }

    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
        self.rx.take()
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        for (stream, state) in self.streams.iter().zip(&self.states) {
            if state.lock().unwrap().alive {
                let _ = wire::write_frame(&mut &*stream, &Frame::shutdown());
            }
            // Half-close: the daemon still drains queued jobs and writes
            // their responses before it sees the shutdown frame / EOF and
            // closes, at which point the reader thread exits.
            let _ = stream.shutdown(SockShutdown::Write);
        }
        // Join every reader, but never hang on a wedged peer: past the
        // grace deadline the socket is force-closed, which errors the
        // blocked read and lets the reader run its fail-stop drain.
        let deadline = std::time::Instant::now() + SHUTDOWN_GRACE;
        for (i, h) in self.readers.drain(..).enumerate() {
            while !h.is_finished() && std::time::Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            if !h.is_finished() {
                let _ = self.streams[i].shutdown(SockShutdown::Both);
            }
            let _ = h.join();
        }
        // Dropping the last funnel sender disconnects the router's stream
        // once every forwarded report has been consumed.
        self.funnel = None;
    }

    fn name(&self) -> &'static str {
        "tcp"
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        Transport::shutdown(self);
    }
}
