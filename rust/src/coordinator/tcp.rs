//! [`TcpTransport`]: the socket-backed [`Transport`] — one TCP connection
//! per worker to a `gr-cdmm worker` daemon ([`super::daemon`]), speaking
//! the length-prefixed [`super::wire`] protocol.
//!
//! # Fail-stop semantics
//!
//! A worker's link can die at any point: connection reset, daemon crash,
//! malformed or truncated frames, an oversized declared payload — the
//! per-connection reader treats every one of these as the worker turning
//! **fail-stop**. It synthesizes a byte-free
//! [`fail_report`](super::transport::fail_report) for every `(job, shard)`
//! sent on the link but not yet answered, and the writer side does the same
//! for jobs submitted after the death, so the master's router still hears
//! exactly one report per dispatched shard copy and PR 3's deterministic
//! job retirement keeps working. A dead worker is indistinguishable from
//! the [`StragglerModel::FailStop`](super::straggler::StragglerModel) model
//! — jobs fail fast with "cannot complete" when the threshold becomes
//! unreachable, never hang, and never panic.
//!
//! # Byte accounting
//!
//! [`Transport::send`] returns the serialized share payload length actually
//! written (0 if the worker is already dead); response payload bytes are
//! counted by the router as messages arrive — the same quantities at the
//! same boundaries as [`super::transport::ChannelTransport`]. Frame headers
//! are deliberately *excluded* so measured volume stays equal to the
//! schemes' analytic `upload_bytes`/`download_bytes` across transports.
//!
//! # Identity
//!
//! The connection index — the position of the endpoint in the `connect`
//! list — is the authoritative worker id. Every connection opens with a
//! hello frame assigning the daemon that id; a daemon whose hello echo
//! *claims a different id* is treated as a rogue peer and fail-stopped on
//! the spot. Response frames carry the **shard** index (under speculative
//! re-dispatch a spare daemon answers for another worker's shard), so the
//! reader validates each response against the link's own outstanding
//! `(job, shard)` set instead of trusting — or overwriting — the id: an
//! unsolicited response is a protocol violation and kills the link, and a
//! confused or byzantine daemon still cannot impersonate another worker.
//! Duplicate responses are additionally dropped by the master's router
//! (see [`super::master`]).
//!
//! # Elastic membership
//!
//! Links are dynamic: [`Transport::disconnect_worker`] force-closes a
//! socket (fail-stopping whatever it owed), [`Transport::reconnect_worker`]
//! re-dials the remembered (or a new) endpoint into the same worker slot,
//! and [`Transport::add_worker`] appends a fresh slot. [`Transport::ping`]
//! writes a ping frame whose pong stamps the link's `last_rtt`/freshness
//! for [`Transport::link_status`].

use super::transport::{fail_report, FromWorker, LinkStatus, ToWorker, Transport};
use super::wire::{self, Frame, FrameKind};
use std::collections::BTreeSet;
use std::io::{BufReader, ErrorKind};
use std::net::{Shutdown as SockShutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Connection attempts before giving up on an endpoint (daemons may still
/// be binding when the coordinator starts — e.g. the CI loopback e2e).
const CONNECT_ATTEMPTS: usize = 40;
/// Pause between connection attempts.
const CONNECT_RETRY: Duration = Duration::from_millis(125);
/// How long [`TcpTransport::shutdown`] waits for a peer to finish its
/// queued work and close before force-closing the socket. A healthy daemon
/// closes as soon as it reads the shutdown frame; a wedged one (frozen
/// host, SIGSTOP'd process) must not hang the master's shutdown forever.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(30);

/// Writer/reader-shared per-connection state. `pending` holds the
/// `(job_id, shard)` pairs sent on the link but not yet answered; whoever
/// observes the death (reader *or* writer) flips `alive` and drains
/// `pending` into synthetic fail-stop reports under the same lock, so every
/// dispatched copy is reported exactly once.
struct ConnState {
    alive: bool,
    pending: BTreeSet<(u64, u64)>,
    /// When the link last produced *any* frame (response, pong, hello).
    last_heard: Option<Instant>,
    /// Outstanding health-check: (nonce, send time).
    ping_sent: Option<(u64, Instant)>,
    /// Most recent answered ping's round-trip time.
    last_rtt: Option<Duration>,
}

impl ConnState {
    fn fresh() -> ConnState {
        ConnState {
            alive: true,
            pending: BTreeSet::new(),
            last_heard: None,
            ping_sent: None,
            last_rtt: None,
        }
    }
}

type SharedState = Arc<Mutex<ConnState>>;

/// One worker slot: the socket, its reader thread, and the endpoint to
/// re-dial on reconnect.
struct Conn {
    stream: TcpStream,
    state: SharedState,
    reader: Option<JoinHandle<()>>,
    endpoint: String,
}

/// Take every pending `(job, shard)` and mark the connection dead. Returns
/// the pairs to report as fail-stopped (empty if another path already
/// drained them).
fn drain_dead(state: &SharedState) -> BTreeSet<(u64, u64)> {
    let mut st = state.lock().unwrap();
    st.alive = false;
    std::mem::take(&mut st.pending)
}

fn spawn_reader(
    worker_id: usize,
    stream: TcpStream,
    state: SharedState,
    funnel: Sender<FromWorker>,
    peer: String,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("gr-cdmm-tcp-reader-{worker_id}"))
        .spawn(move || {
            let mut reader = BufReader::new(stream);
            loop {
                let frame = match wire::read_frame(&mut reader) {
                    Ok(Some(frame)) => frame,
                    Ok(None) => break, // clean close
                    Err(e) => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) link broke: {e}; \
                             treating it as fail-stopped"
                        );
                        break;
                    }
                };
                match frame.kind {
                    FrameKind::RespOk | FrameKind::RespFail => {
                        let msg = match frame.into_report() {
                            Ok(msg) => msg,
                            Err(e) => {
                                eprintln!(
                                    "gr-cdmm: worker {worker_id} ({peer}) sent a malformed \
                                     response ({e}); treating it as fail-stopped"
                                );
                                break;
                            }
                        };
                        // A response is only valid if this link actually
                        // owes that (job, shard): anything else is a rogue
                        // or badly confused peer — kill the link rather
                        // than let it answer for work it was never sent.
                        let key = (msg.job_id, msg.worker_id as u64);
                        {
                            let mut st = state.lock().unwrap();
                            if !st.pending.remove(&key) {
                                drop(st);
                                eprintln!(
                                    "gr-cdmm: worker {worker_id} ({peer}) sent an \
                                     unsolicited response for job {} shard {}; treating \
                                     the link as rogue (fail-stopped)",
                                    msg.job_id, msg.worker_id
                                );
                                break;
                            }
                            st.last_heard = Some(Instant::now());
                        }
                        if funnel.send(msg).is_err() {
                            break; // coordinator gone
                        }
                    }
                    FrameKind::Pong => {
                        let mut st = state.lock().unwrap();
                        st.last_heard = Some(Instant::now());
                        if let Some((nonce, sent)) = st.ping_sent {
                            if nonce == frame.job_id {
                                st.last_rtt = Some(sent.elapsed());
                                st.ping_sent = None;
                            }
                        }
                    }
                    FrameKind::Hello => {
                        // The daemon echoes the id we assigned at connect;
                        // a different claim means we are talking to the
                        // wrong (or a lying) peer.
                        if frame.worker_id != worker_id as u64 {
                            eprintln!(
                                "gr-cdmm: peer at {peer} claims worker id {} but is \
                                 connected as worker {worker_id}; rejecting the link \
                                 as rogue (fail-stopped)",
                                frame.worker_id
                            );
                            break;
                        }
                        state.lock().unwrap().last_heard = Some(Instant::now());
                    }
                    FrameKind::StageAck => {
                        // The daemon confirms a staged operand, echoing the
                        // machine id we assigned; a different claim is the
                        // same rogue-peer condition as a bad hello echo.
                        if frame.worker_id != worker_id as u64 {
                            eprintln!(
                                "gr-cdmm: peer at {peer} acked a staged operand as worker \
                                 {} but is connected as worker {worker_id}; rejecting the \
                                 link as rogue (fail-stopped)",
                                frame.worker_id
                            );
                            break;
                        }
                        state.lock().unwrap().last_heard = Some(Instant::now());
                    }
                    FrameKind::Goodbye => break, // graceful leave
                    FrameKind::Job
                    | FrameKind::Shutdown
                    | FrameKind::Ping
                    | FrameKind::Stage
                    | FrameKind::Evict => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) sent an unexpected \
                             {:?} frame; treating it as fail-stopped",
                            frame.kind
                        );
                        break;
                    }
                }
            }
            // Fail-stop: report every (job, shard) this link still owed.
            for (job_id, shard) in drain_dead(&state) {
                if funnel.send(fail_report(job_id, shard as usize)).is_err() {
                    break;
                }
            }
        })
        .expect("failed to spawn tcp reader thread")
}

/// Dial `addr` with patient retries (shared with the shm transport, whose
/// control channel is the same kind of socket).
pub(crate) fn connect_retry(addr: &str) -> anyhow::Result<TcpStream> {
    let mut last_err = String::new();
    for attempt in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) if e.kind() == ErrorKind::ConnectionRefused => {
                last_err = e.to_string();
                if attempt + 1 < CONNECT_ATTEMPTS {
                    std::thread::sleep(CONNECT_RETRY);
                }
            }
            Err(e) => anyhow::bail!("connecting to worker at {addr}: {e}"),
        }
    }
    anyhow::bail!(
        "worker at {addr} refused {CONNECT_ATTEMPTS} connection attempts \
         (is `gr-cdmm worker --listen {addr}` running?): {last_err}"
    )
}

/// Wrap an accepted stream into a live worker slot: reader thread plus the
/// hello frame assigning the daemon its machine id. A hello write failure
/// is a link that died at birth — the reader observes it and fail-stops.
fn open_link(
    worker_id: usize,
    endpoint: String,
    stream: TcpStream,
    funnel: &Sender<FromWorker>,
) -> anyhow::Result<Conn> {
    stream.set_nodelay(true)?;
    let state: SharedState = Arc::new(Mutex::new(ConnState::fresh()));
    let reader = spawn_reader(
        worker_id,
        stream.try_clone()?,
        Arc::clone(&state),
        funnel.clone(),
        endpoint.clone(),
    );
    let _ = wire::write_frame(&mut &stream, &Frame::hello(worker_id));
    Ok(Conn { stream, state, reader: Some(reader), endpoint })
}

/// The socket transport. Build with [`TcpTransport::connect`]; endpoint `i`
/// in the list is worker `i`.
pub struct TcpTransport {
    conns: Vec<Conn>,
    funnel: Option<Sender<FromWorker>>,
    rx: Option<Receiver<FromWorker>>,
    shut: bool,
}

impl TcpTransport {
    /// Connect to one `gr-cdmm worker` daemon per endpoint (retrying
    /// refused connections for a few seconds, so daemons may still be
    /// starting). All endpoints must accept before any job traffic flows;
    /// an unreachable endpoint is a hard error — a worker that dies *after*
    /// connecting degrades to fail-stop instead.
    pub fn connect(endpoints: &[String]) -> anyhow::Result<TcpTransport> {
        anyhow::ensure!(!endpoints.is_empty(), "need at least one worker endpoint");
        let mut streams = Vec::with_capacity(endpoints.len());
        for addr in endpoints {
            streams.push(connect_retry(addr)?);
        }
        // Only spawn reader threads once every endpoint is connected, so a
        // failed connect leaks nothing.
        let (funnel_tx, rx) = channel::<FromWorker>();
        let mut conns = Vec::with_capacity(endpoints.len());
        for (wid, (stream, addr)) in streams.into_iter().zip(endpoints).enumerate() {
            conns.push(open_link(wid, addr.clone(), stream, &funnel_tx)?);
        }
        Ok(TcpTransport { conns, funnel: Some(funnel_tx), rx: Some(rx), shut: false })
    }

    /// Report `shard` of `job_id` as fail-stopped (link already dead).
    fn synthesize_fail(&self, shard: usize, job_id: u64) {
        if let Some(tx) = &self.funnel {
            let _ = tx.send(fail_report(job_id, shard));
        }
    }

    /// Kill `worker_id`'s link and fail-stop everything it still owed.
    fn kill_link(&mut self, worker_id: usize) {
        let _ = self.conns[worker_id].stream.shutdown(SockShutdown::Both);
        for (job, shard) in drain_dead(&self.conns[worker_id].state) {
            self.synthesize_fail(shard as usize, job);
        }
    }
}

impl Transport for TcpTransport {
    fn n_workers(&self) -> usize {
        self.conns.len()
    }

    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
        anyhow::ensure!(worker_id < self.conns.len(), "worker id {worker_id} out of range");
        match msg {
            ToWorker::Shutdown => {
                if self.conns[worker_id].state.lock().unwrap().alive {
                    let _ =
                        wire::write_frame(&mut &self.conns[worker_id].stream, &Frame::shutdown());
                }
                Ok(0)
            }
            ToWorker::Ping { nonce, .. } => {
                {
                    let mut st = self.conns[worker_id].state.lock().unwrap();
                    if !st.alive {
                        return Ok(0); // dead links don't answer probes
                    }
                    st.ping_sent = Some((nonce, Instant::now()));
                }
                if wire::write_frame(&mut &self.conns[worker_id].stream, &Frame::ping(nonce))
                    .is_err()
                {
                    self.kill_link(worker_id);
                }
                Ok(0)
            }
            ToWorker::Stage { prepared_id, payload } => {
                if !self.conns[worker_id].state.lock().unwrap().alive {
                    // Staging traffic to a dead link is silently lost (the
                    // master re-stages on reconnect) — no report is owed.
                    return Ok(0);
                }
                let len = payload.len();
                // Zero-copy: the frame takes the shared PooledBuf by
                // reference count, and the wire layer writes it borrowed —
                // no join buffer, no payload clone.
                if wire::write_frame(
                    &mut &self.conns[worker_id].stream,
                    &Frame::stage(prepared_id, payload),
                )
                .is_err()
                {
                    self.kill_link(worker_id);
                    return Ok(0);
                }
                Ok(len)
            }
            ToWorker::Evict { prepared_id } => {
                if !self.conns[worker_id].state.lock().unwrap().alive {
                    return Ok(0);
                }
                if wire::write_frame(
                    &mut &self.conns[worker_id].stream,
                    &Frame::evict(prepared_id),
                )
                .is_err()
                {
                    self.kill_link(worker_id);
                }
                Ok(0)
            }
            ToWorker::Job { job_id, shard, prepared, payload } => {
                {
                    let mut st = self.conns[worker_id].state.lock().unwrap();
                    if !st.alive {
                        // Dead link = fail-stop worker: report byte-free so
                        // the job still retires deterministically.
                        drop(st);
                        self.synthesize_fail(shard, job_id);
                        return Ok(0);
                    }
                    st.pending.insert((job_id, shard as u64));
                }
                let len = payload.len();
                if wire::write_job_frame(
                    &mut &self.conns[worker_id].stream,
                    job_id,
                    shard,
                    prepared,
                    &payload,
                )
                .is_err()
                {
                    // The link died mid-write: whatever the daemon received
                    // is now moot. Unblock the reader and fail-stop every
                    // (job, shard) this link still owed (including this
                    // one, unless the reader drained it first).
                    self.kill_link(worker_id);
                    return Ok(0);
                }
                Ok(len)
            }
        }
    }

    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
        self.rx.take()
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        for conn in &self.conns {
            if conn.state.lock().unwrap().alive {
                let _ = wire::write_frame(&mut &conn.stream, &Frame::shutdown());
            }
            // Half-close: the daemon still drains queued jobs and writes
            // their responses before it sees the shutdown frame / EOF,
            // answers with a goodbye and closes, at which point the reader
            // thread exits.
            let _ = conn.stream.shutdown(SockShutdown::Write);
        }
        // Join every reader, but never hang on a wedged peer: past the
        // grace deadline the socket is force-closed, which errors the
        // blocked read and lets the reader run its fail-stop drain.
        let deadline = Instant::now() + SHUTDOWN_GRACE;
        for conn in &mut self.conns {
            let Some(h) = conn.reader.take() else { continue };
            while !h.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            if !h.is_finished() {
                let _ = conn.stream.shutdown(SockShutdown::Both);
            }
            let _ = h.join();
        }
        // Dropping the last funnel sender disconnects the router's stream
        // once every forwarded report has been consumed.
        self.funnel = None;
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn link_status(&self, worker_id: usize) -> LinkStatus {
        match self.conns.get(worker_id) {
            Some(conn) => {
                let st = conn.state.lock().unwrap();
                LinkStatus {
                    alive: st.alive,
                    idle: st.last_heard.map(|t| t.elapsed()),
                    last_rtt: st.last_rtt,
                }
            }
            None => LinkStatus { alive: false, idle: None, last_rtt: None },
        }
    }

    fn ping(&mut self, worker_id: usize, nonce: u64) -> anyhow::Result<()> {
        self.send(worker_id, ToWorker::Ping { nonce, sent: Instant::now() })?;
        Ok(())
    }

    fn disconnect_worker(&mut self, worker_id: usize) -> anyhow::Result<()> {
        anyhow::ensure!(worker_id < self.conns.len(), "worker id {worker_id} out of range");
        self.kill_link(worker_id);
        // The reader exits on the closed socket; reap it so a later
        // reconnect can install a fresh one.
        if let Some(h) = self.conns[worker_id].reader.take() {
            let _ = h.join();
        }
        Ok(())
    }

    fn reconnect_worker(&mut self, worker_id: usize, endpoint: Option<&str>) -> anyhow::Result<()> {
        anyhow::ensure!(!self.shut, "transport is shut down");
        anyhow::ensure!(worker_id < self.conns.len(), "worker id {worker_id} out of range");
        let funnel = self
            .funnel
            .clone()
            .ok_or_else(|| anyhow::anyhow!("transport is shutting down"))?;
        if let Some(ep) = endpoint {
            self.conns[worker_id].endpoint = ep.to_string();
        }
        anyhow::ensure!(
            !self.conns[worker_id].state.lock().unwrap().alive,
            "worker {worker_id} link is still alive"
        );
        if let Some(h) = self.conns[worker_id].reader.take() {
            let _ = h.join();
        }
        // One fast dial per attempt: a refused connection fails immediately
        // and the caller (the health monitor, typically) just retries on
        // its next tick.
        let addr = self.conns[worker_id].endpoint.clone();
        let stream = TcpStream::connect(&addr)
            .map_err(|e| anyhow::anyhow!("re-dialing worker {worker_id} at {addr}: {e}"))?;
        self.conns[worker_id] = open_link(worker_id, addr, stream, &funnel)?;
        Ok(())
    }

    fn add_worker(&mut self, endpoint: Option<&str>) -> anyhow::Result<usize> {
        anyhow::ensure!(!self.shut, "transport is shut down");
        let addr = endpoint
            .ok_or_else(|| anyhow::anyhow!("tcp add_worker needs a host:port endpoint"))?;
        let funnel = self
            .funnel
            .clone()
            .ok_or_else(|| anyhow::anyhow!("transport is shutting down"))?;
        let wid = self.conns.len();
        let stream = connect_retry(addr)?;
        self.conns.push(open_link(wid, addr.to_string(), stream, &funnel)?);
        Ok(wid)
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        Transport::shutdown(self);
    }
}
