//! The master node / coordinator: owns a [`Transport`] to the worker pool,
//! dispatches encoded shares, and serves **multiple jobs in flight** — the
//! serving model the paper motivates (§I: any `R` of `N` workers finish a
//! request, so stragglers never gate latency).
//!
//! Architecture:
//!
//! * the worker pool is behind the object-safe [`Transport`] trait:
//!   [`Coordinator::new`] spawns the in-process
//!   [`ChannelTransport`](super::transport::ChannelTransport) (mpsc
//!   channels, unchanged semantics), [`Coordinator::connect_tcp`] dials
//!   `gr-cdmm worker` daemons over sockets, and
//!   [`Coordinator::with_transport`] accepts anything else (tests inject
//!   mock transports this way);
//! * [`Coordinator::submit`] is non-blocking: it registers the job in a
//!   shared job table, dispatches one payload per worker, and returns a
//!   [`JobHandle`];
//! * a dedicated **response-router thread** receives every [`FromWorker`]
//!   message and forwards it to the owning job's channel by `job_id` — a
//!   straggler answering job `k` while job `k+3` is collecting is routed,
//!   never misattributed or dropped. The router also enforces
//!   **exactly-one response per worker per job**: a duplicate (a
//!   retransmitting or byzantine peer) is counted as arrived bytes and
//!   dropped before it can reach a decoder, and an out-of-range worker id
//!   is dropped outright;
//! * each job owns its [`ByteCounters`]: upload is counted at dispatch
//!   (with the byte count the transport reports), arrived download at the
//!   router, used download by the job's collector. Overlapping jobs
//!   therefore account independently (asserted against the schemes'
//!   analytic volumes in `tests/integration_serving.rs`), and the
//!   accounting is transport-independent (asserted channel-vs-TCP in
//!   `tests/integration_transport.rs`);
//! * [`JobHandle::wait`] / [`JobHandle::try_wait`] collect the first `need`
//!   successful responses with a per-job timeout.
//!
//! Lifecycle details are on [`JobHandle`]; the single-job convenience path
//! is `submit(..)?.wait()`.

use super::straggler::StragglerModel;
use super::tcp::TcpTransport;
use super::transport::{ByteCounters, ChannelTransport, FromWorker, ToWorker, Transport};
use super::worker::ShareCompute;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One collected response.
#[derive(Debug)]
pub struct Collected {
    pub worker_id: usize,
    pub payload: Vec<u8>,
    pub compute: Duration,
    pub injected_delay: Duration,
}

/// The uniform "not enough responses in time" error of both the deadline
/// pre-check and the blocking-receive timeout.
fn timeout_error(got: usize, need: usize) -> anyhow::Error {
    anyhow::anyhow!("timed out with {got}/{need} responses (too many stragglers/failures?)")
}

/// The job's channel disconnected before the threshold: every worker has
/// already reported (with too many failures) or the coordinator shut down —
/// either way no further response can arrive, so collection fails fast
/// instead of sleeping until the deadline.
fn incomplete_error(job_id: u64, got: usize, need: usize) -> anyhow::Error {
    anyhow::anyhow!(
        "job {job_id} cannot complete: {got}/{need} responses and none still pending \
         (worker failures or coordinator shutdown)"
    )
}

/// A pending job's routing entry: where its responses go, its counters, and
/// which workers have been heard from. Every worker reports exactly once
/// per job (success, failure, or fail-stop drop — enforced here against
/// duplicating peers), so `outstanding` reaching 0 retires the entry: the
/// table stays bounded by the number of genuinely in-flight jobs.
struct JobEntry {
    /// `None` once the job's [`JobHandle`] is gone; late responses are then
    /// only accounted, not forwarded.
    tx: Option<Sender<FromWorker>>,
    counters: ByteCounters,
    outstanding: usize,
    /// Per-worker heard-from bits; a second report from the same worker is
    /// dropped (duplicate-response guard).
    reported: Vec<bool>,
}

type JobTable = Arc<Mutex<HashMap<u64, JobEntry>>>;

/// The response router: drains the transport's single worker→master stream
/// and fans messages out to the owning job, attributing download bytes to
/// that job's counters — a straggler from an old job can never pollute a
/// newer one, and a worker can never be heard twice for one job. Exits when
/// the transport shuts down, and clears the table on the way out so pending
/// [`JobHandle`]s observe a disconnect instead of sleeping until their
/// timeout.
fn spawn_router(
    rx: Receiver<FromWorker>,
    jobs: JobTable,
    aggregate: ByteCounters,
    n_workers: usize,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("gr-cdmm-router".to_string())
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                let len = msg.payload.as_ref().map_or(0, Vec::len);
                aggregate.add_download_arrived(len);
                if msg.worker_id >= n_workers {
                    // Malformed/byzantine peer: unattributable, drop. The
                    // bytes stay visible in the aggregate discarded count.
                    continue;
                }
                let mut table = jobs.lock().unwrap();
                let Some(entry) = table.get_mut(&msg.job_id) else {
                    // Entry already retired (all workers heard from, or the
                    // coordinator restarted routing) — the bytes stay
                    // visible in the aggregate discarded count.
                    continue;
                };
                let job_id = msg.job_id;
                entry.counters.add_download_arrived(len);
                if entry.reported[msg.worker_id] {
                    // Duplicate-response guard: this worker already
                    // reported for this job. Never forwarded — a duplicate
                    // row must not reach a decoder — and `outstanding` is
                    // not decremented twice.
                    continue;
                }
                entry.reported[msg.worker_id] = true;
                entry.outstanding -= 1;
                let send_failed = match &entry.tx {
                    Some(tx) => tx.send(msg).is_err(),
                    None => false,
                };
                if send_failed {
                    // The handle was dropped: the job is over; keep the
                    // entry (for late-byte attribution) but stop forwarding.
                    entry.tx = None;
                }
                if entry.outstanding == 0 {
                    table.remove(&job_id);
                }
            }
            jobs.lock().unwrap().clear();
        })
        .expect("failed to spawn router thread")
}

/// A handle to one in-flight job.
///
/// # Lifecycle
///
/// 1. [`Coordinator::submit`] registers the job and dispatches its payloads;
///    the handle's deadline starts there (override with
///    [`JobHandle::set_timeout`] before collecting).
/// 2. Responses routed to this job accumulate in its private channel;
///    [`JobHandle::counters`] observes the job's byte traffic live.
/// 3. Collect either blocking — [`JobHandle::wait`] — or by polling
///    [`JobHandle::try_wait`]. Both deliver `(Vec<Collected>, Duration)`:
///    the first `need` successful responses in arrival order and the
///    dispatch→threshold wall time. Worker-side failures are treated as
///    stragglers (never collected); if the deadline passes first, a
///    "timed out with k/need" error is returned.
/// 4. Dropping the handle (with or without collecting) ends the job: the
///    router unregisters it on the next routed response, and late bytes are
///    accounted as discarded in the job's and the coordinator's counters.
///
/// Handles are independent — any number of jobs may be in flight, collected
/// in any order.
pub struct JobHandle {
    job_id: u64,
    need: usize,
    rx: Receiver<FromWorker>,
    counters: ByteCounters,
    aggregate: ByteCounters,
    submitted: Instant,
    timeout: Duration,
    collected: Vec<Collected>,
    done_at: Option<Duration>,
}

impl JobHandle {
    /// The coordinator-assigned job id.
    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// The recovery threshold this job collects to.
    pub fn need(&self) -> usize {
        self.need
    }

    /// This job's byte counters (upload at dispatch, download as routed).
    /// Clone them to keep observing after the handle is consumed.
    pub fn counters(&self) -> &ByteCounters {
        &self.counters
    }

    /// Override the per-job deadline (measured from submission).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Absorb one routed response: the first `need` successful ones are
    /// collected (and their bytes counted as used), everything after is
    /// left as arrived-only, i.e. discarded. A second successful response
    /// from a worker that already contributed is dropped here too (the
    /// router's guard makes this unreachable in practice; the collector
    /// keeps its own last line of defense so a duplicate row can never
    /// reach a decode).
    fn absorb(&mut self, msg: FromWorker) {
        debug_assert_eq!(msg.job_id, self.job_id, "router must filter by job id");
        let FromWorker { worker_id, payload, compute, injected_delay, .. } = msg;
        let Some(payload) = payload else {
            return; // worker-side compute error: treat as a straggler
        };
        if self.collected.iter().any(|c| c.worker_id == worker_id) {
            return; // duplicate-response guard (bytes stay arrived-only)
        }
        if self.collected.len() < self.need {
            self.counters.add_download_used(payload.len());
            self.aggregate.add_download_used(payload.len());
            self.collected.push(Collected { worker_id, payload, compute, injected_delay });
            if self.collected.len() == self.need {
                self.done_at = Some(self.submitted.elapsed());
            }
        }
    }

    /// Block until the job has `need` successful responses (or its deadline
    /// passes). Returns them in arrival order plus the dispatch→threshold
    /// wall time.
    pub fn wait(mut self) -> anyhow::Result<(Vec<Collected>, Duration)> {
        anyhow::ensure!(self.done_at.is_none(), "job {} was already collected", self.job_id);
        while self.collected.len() < self.need {
            // Absorb whatever already arrived before consulting the
            // deadline: a handle collected late (the pipelined pattern)
            // must not report a timeout for a job whose responses all
            // arrived in time and are sitting unread in its channel.
            match self.rx.try_recv() {
                Ok(msg) => {
                    self.absorb(msg);
                    continue;
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
            let remaining = self
                .timeout
                .checked_sub(self.submitted.elapsed())
                .ok_or_else(|| timeout_error(self.collected.len(), self.need))?;
            match self.rx.recv_timeout(remaining) {
                Ok(msg) => self.absorb(msg),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(timeout_error(self.collected.len(), self.need));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
        }
        let wait = self.done_at.expect("threshold reached");
        Ok((std::mem::take(&mut self.collected), wait))
    }

    /// Non-blocking poll. `Ok(None)` while the job is still pending within
    /// its deadline; `Ok(Some(..))` exactly once when the threshold is met;
    /// the same timeout error as [`JobHandle::wait`] once the deadline has
    /// passed.
    pub fn try_wait(&mut self) -> anyhow::Result<Option<(Vec<Collected>, Duration)>> {
        anyhow::ensure!(self.done_at.is_none(), "job {} was already collected", self.job_id);
        loop {
            match self.rx.try_recv() {
                Ok(msg) => {
                    self.absorb(msg);
                    if self.done_at.is_some() {
                        let wait = self.done_at.expect("threshold reached");
                        return Ok(Some((std::mem::take(&mut self.collected), wait)));
                    }
                }
                Err(TryRecvError::Empty) => {
                    if self.submitted.elapsed() > self.timeout {
                        return Err(timeout_error(self.collected.len(), self.need));
                    }
                    return Ok(None);
                }
                Err(TryRecvError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
        }
    }
}

/// The coordinator: a [`Transport`] to `N` persistent workers, a response
/// router, and the job table that lets any number of jobs overlap.
pub struct Coordinator {
    transport: Box<dyn Transport>,
    router: Option<JoinHandle<()>>,
    jobs: JobTable,
    aggregate: ByteCounters,
    next_job: u64,
    open: bool,
    /// Default per-job deadline, captured by [`Coordinator::submit`].
    pub timeout: Duration,
}

impl Coordinator {
    /// Spawn an in-process pool of `n_workers` worker threads applying
    /// `compute`, with straggler injection, joined by mpsc channels. `seed`
    /// derives the per-worker RNG streams.
    pub fn new(
        n_workers: usize,
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
    ) -> Self {
        Self::with_transport(Box::new(ChannelTransport::spawn(
            n_workers, compute, straggler, seed,
        )))
    }

    /// Connect to one `gr-cdmm worker` daemon per endpoint; endpoint `i` is
    /// worker `i`. Straggler injection (and the compute backend) live at
    /// the daemons in this mode.
    pub fn connect_tcp(endpoints: &[String]) -> anyhow::Result<Self> {
        Ok(Self::with_transport(Box::new(TcpTransport::connect(endpoints)?)))
    }

    /// Build over any [`Transport`].
    pub fn with_transport(mut transport: Box<dyn Transport>) -> Self {
        let rx = transport.take_receiver().expect("transport's receiver was already taken");
        let jobs: JobTable = Arc::new(Mutex::new(HashMap::new()));
        let aggregate = ByteCounters::new();
        let router =
            spawn_router(rx, Arc::clone(&jobs), aggregate.clone(), transport.n_workers());
        Coordinator {
            transport,
            router: Some(router),
            jobs,
            aggregate,
            next_job: 0,
            open: true,
            timeout: Duration::from_secs(120),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.transport.n_workers()
    }

    /// The transport's short name (`"channel"`, `"tcp"`), for reports.
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Coordinator-lifetime byte totals, summed over every job (never
    /// reset). Per-job accounting lives on each [`JobHandle::counters`].
    pub fn counters(&self) -> &ByteCounters {
        &self.aggregate
    }

    /// Number of jobs currently registered with the router.
    pub fn jobs_in_flight(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    /// Dispatch one payload per worker and return immediately with a
    /// [`JobHandle`] that collects the first `need` successful responses.
    /// Any number of submitted jobs may overlap; responses are routed to
    /// their owning job by id.
    pub fn submit(&mut self, payloads: Vec<Vec<u8>>, need: usize) -> anyhow::Result<JobHandle> {
        let n_workers = self.n_workers();
        anyhow::ensure!(
            payloads.len() == n_workers,
            "need exactly one payload per worker ({} != {})",
            payloads.len(),
            n_workers
        );
        anyhow::ensure!(
            (1..=n_workers).contains(&need),
            "need must be in 1..={} (got {need})",
            n_workers
        );
        anyhow::ensure!(self.open, "coordinator is shut down");
        let job_id = self.next_job;
        self.next_job += 1;

        let counters = ByteCounters::new();
        let (job_tx, job_rx) = channel::<FromWorker>();
        // Register before dispatching: a response must never beat the entry.
        self.jobs.lock().unwrap().insert(
            job_id,
            JobEntry {
                tx: Some(job_tx),
                counters: counters.clone(),
                outstanding: n_workers,
                reported: vec![false; n_workers],
            },
        );

        let submitted = Instant::now();
        for (worker_id, payload) in payloads.into_iter().enumerate() {
            match self.transport.send(worker_id, ToWorker::Job { job_id, payload }) {
                Ok(sent) => {
                    // Credit the bytes the transport reports actually
                    // crossing the link — identical across transports.
                    counters.add_upload(sent);
                    self.aggregate.add_upload(sent);
                }
                Err(e) => {
                    self.jobs.lock().unwrap().remove(&job_id);
                    return Err(e);
                }
            }
        }
        Ok(JobHandle {
            job_id,
            need,
            rx: job_rx,
            counters,
            aggregate: self.aggregate.clone(),
            submitted,
            timeout: self.timeout,
            collected: Vec::with_capacity(need),
            done_at: None,
        })
    }

    fn shutdown_impl(&mut self) {
        self.open = false;
        self.transport.shutdown();
        if let Some(router) = self.router.take() {
            let _ = router.join();
        }
    }

    /// Graceful shutdown: signal the transport (every worker joins / every
    /// connection closes), then join the router. Queued jobs are still
    /// processed and routed before workers exit.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }
}

/// Dropping the coordinator performs the same shutdown as
/// [`Coordinator::shutdown`], so a panicking test or an early `?` return
/// never leaks the pool/router threads.
impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo backend: replies with the payload itself.
    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<Vec<u8>> {
            Ok(payload.to_vec())
        }
    }

    fn payloads(n: usize, byte: u8, len: usize) -> Vec<Vec<u8>> {
        (0..n).map(|_| vec![byte; len]).collect()
    }

    #[test]
    fn collects_first_r() {
        let mut c = Coordinator::new(4, Arc::new(Echo), StragglerModel::None, 1);
        assert_eq!(c.transport_name(), "channel");
        let payloads: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 10]).collect();
        let handle = c.submit(payloads, 3).unwrap();
        let job_counters = handle.counters().clone();
        let (got, _) = handle.wait().unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(job_counters.upload_total(), 40);
        assert_eq!(job_counters.download_used_total(), 30);
        // single job: aggregate equals the job's view
        assert_eq!(c.counters().upload_total(), 40);
        assert_eq!(c.counters().download_used_total(), 30);
        c.shutdown();
    }

    #[test]
    fn overlapping_jobs_route_by_job_id() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 6);
        let h1 = c.submit(payloads(3, 0xA1, 5), 3).unwrap();
        let h2 = c.submit(payloads(3, 0xB2, 9), 3).unwrap();
        let h3 = c.submit(payloads(3, 0xC3, 2), 3).unwrap();
        assert_eq!((h1.job_id(), h2.job_id(), h3.job_id()), (0, 1, 2));
        // collect out of submission order: routing must not care
        for (h, byte, len) in [(h2, 0xB2u8, 9usize), (h3, 0xC3, 2), (h1, 0xA1, 5)] {
            let counters = h.counters().clone();
            let (got, _) = h.wait().unwrap();
            assert_eq!(got.len(), 3);
            for resp in &got {
                assert_eq!(resp.payload, vec![byte; len], "response bytes belong to the job");
            }
            assert_eq!(counters.upload_total(), (3 * len) as u64);
            assert_eq!(counters.download_used_total(), (3 * len) as u64);
        }
        c.shutdown();
    }

    #[test]
    fn tolerates_fail_stop_up_to_n_minus_r() {
        let straggler = StragglerModel::fail_stop([0, 2]);
        let mut c = Coordinator::new(5, Arc::new(Echo), straggler, 2);
        let (got, _) = c.submit(payloads(5, 7, 4), 3).unwrap().wait().unwrap();
        let ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        assert!(!ids.contains(&0) && !ids.contains(&2));
        c.shutdown();
    }

    #[test]
    fn fails_fast_when_too_many_fail() {
        let straggler = StragglerModel::fail_stop([0, 1, 2]);
        let mut c = Coordinator::new(4, Arc::new(Echo), straggler, 3);
        // No short timeout needed: once all four workers have reported
        // (three of them as drops) the threshold is unreachable and the
        // collector fails fast.
        let err = c.submit(payloads(4, 1, 1), 2).unwrap().wait().unwrap_err();
        assert!(err.to_string().contains("1/2"), "{err}");
        c.shutdown();
    }

    #[test]
    fn times_out_on_slow_workers() {
        let straggler = StragglerModel::fixed_slow([0, 1], Duration::from_millis(400));
        let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 11);
        c.timeout = Duration::from_millis(80);
        let err = c.submit(payloads(2, 1, 1), 1).unwrap().wait().unwrap_err();
        assert!(err.to_string().contains("timed out with 0/1"), "{err}");
        c.shutdown(); // joins the still-sleeping workers
    }

    #[test]
    fn slow_workers_not_in_first_r() {
        let straggler = StragglerModel::fixed_slow([0], Duration::from_millis(300));
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 4);
        let (got, wait) = c.submit(payloads(3, 1, 8), 2).unwrap().wait().unwrap();
        let ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        assert!(!ids.contains(&0), "slow worker 0 should not be among first 2");
        assert!(wait < Duration::from_millis(250), "did not wait for the straggler");
        c.shutdown();
    }

    #[test]
    fn multiple_jobs_reuse_pool() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 5);
        for _ in 0..5 {
            let (got, _) = c.submit(payloads(3, 9, 2), 3).unwrap().wait().unwrap();
            assert_eq!(got.len(), 3);
        }
        c.shutdown();
    }

    #[test]
    fn try_wait_polls_to_completion() {
        let straggler = StragglerModel::fixed_slow([0, 1, 2], Duration::from_millis(150));
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 7);
        let mut handle = c.submit(payloads(3, 4, 3), 2).unwrap();
        // workers are still sleeping: pending
        assert!(handle.try_wait().unwrap().is_none());
        let deadline = Instant::now() + Duration::from_secs(5);
        let (got, _) = loop {
            if let Some(done) = handle.try_wait().unwrap() {
                break done;
            }
            assert!(Instant::now() < deadline, "try_wait never completed");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(got.len(), 2);
        // the handle is spent now
        assert!(handle.try_wait().is_err());
        c.shutdown();
    }

    #[test]
    fn dropping_handle_keeps_pool_serving() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 8);
        let abandoned = c.submit(payloads(3, 1, 6), 3).unwrap();
        let abandoned_counters = abandoned.counters().clone();
        drop(abandoned);
        // the pool still serves the next job
        let (got, _) = c.submit(payloads(3, 2, 4), 3).unwrap().wait().unwrap();
        assert_eq!(got.len(), 3);
        // the abandoned job's responses were routed/accounted, never used
        let deadline = Instant::now() + Duration::from_secs(5);
        while abandoned_counters.download_arrived_total() < 18 {
            assert!(Instant::now() < deadline, "late responses were not attributed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(abandoned_counters.download_used_total(), 0);
        assert_eq!(abandoned_counters.download_discarded_total(), 18);
        c.shutdown();
    }

    #[test]
    fn drop_joins_pool_and_drains_in_flight_job() {
        // No explicit shutdown: Drop must signal and join workers + router
        // (this test would hang otherwise). The job queued before the drop
        // is still processed and routed, so its handle collects normally.
        let handle = {
            let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 9);
            c.submit(payloads(2, 3, 2), 2).unwrap()
        };
        let (got, _) = handle.wait().unwrap();
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn handle_errors_cleanly_after_unserved_shutdown() {
        // Workers fail-stop, coordinator dropped: the handle can never be
        // served and reports that instead of hanging.
        let straggler = StragglerModel::fail_stop([0, 1]);
        let handle = {
            let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 12);
            c.submit(payloads(2, 3, 2), 1).unwrap()
        };
        let err = handle.wait().unwrap_err();
        assert!(err.to_string().contains("cannot complete"), "{err}");
    }

    #[test]
    fn job_table_drains_after_all_workers_report() {
        // Worker 1 fail-stops; it still reports the drop, so the entry
        // retires once every worker has been heard from — the table stays
        // bounded by the genuinely in-flight jobs.
        let straggler = StragglerModel::fail_stop([1]);
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 10);
        let h = c.submit(payloads(3, 5, 1), 2).unwrap();
        let _ = h.wait().unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.jobs_in_flight() != 0 {
            assert!(Instant::now() < deadline, "job entry never retired");
            std::thread::sleep(Duration::from_millis(5));
        }
        c.shutdown();
    }

    /// A transport double whose "workers" echo every job TWICE, plus one
    /// response under a bogus worker id: a retransmitting / byzantine peer
    /// distilled. Exercises the master-side duplicate-response and
    /// id-bounds guards end-to-end through submit → router → collect.
    struct DuplicatingTransport {
        n: usize,
        tx: Option<Sender<FromWorker>>,
        rx: Option<Receiver<FromWorker>>,
    }

    impl DuplicatingTransport {
        fn new(n: usize) -> Self {
            let (tx, rx) = channel();
            DuplicatingTransport { n, tx: Some(tx), rx: Some(rx) }
        }
    }

    impl Transport for DuplicatingTransport {
        fn n_workers(&self) -> usize {
            self.n
        }

        fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
            let ToWorker::Job { job_id, payload } = msg else {
                return Ok(0);
            };
            let tx = self.tx.as_ref().expect("transport is open");
            let echo = |wid: usize| FromWorker {
                job_id,
                worker_id: wid,
                payload: Some(payload.clone()),
                compute: Duration::ZERO,
                injected_delay: Duration::ZERO,
            };
            // every worker answers twice, and worker 0's peer additionally
            // spoofs an out-of-range id
            tx.send(echo(worker_id)).unwrap();
            tx.send(echo(worker_id)).unwrap();
            if worker_id == 0 {
                tx.send(echo(self.n + 7)).unwrap();
            }
            Ok(payload.len())
        }

        fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
            self.rx.take()
        }

        fn shutdown(&mut self) {
            self.tx = None;
        }

        fn name(&self) -> &'static str {
            "mock-duplicating"
        }
    }

    #[test]
    fn duplicate_responses_are_dropped_before_decode() {
        let mut c = Coordinator::with_transport(Box::new(DuplicatingTransport::new(3)));
        let handle = c.submit(payloads(3, 0xEE, 10), 3).unwrap();
        let job_counters = handle.counters().clone();
        let (got, _) = handle.wait().unwrap();
        // exactly one collected response per worker, despite the double
        // echo — a duplicate must never be fed to a decoder
        let mut ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        // duplicates and the spoofed id were counted as arrived, not used.
        // Job view: 3 used + the two duplicates routed before the entry
        // retired (worker 2's duplicate lands after retirement, and the
        // spoofed id is never attributable) = 50 bytes arrived. Safe to
        // assert here: wait() returning implies the router processed
        // through worker 2's first response (message 6 of 7).
        assert_eq!(job_counters.download_used_total(), 30);
        assert_eq!(job_counters.download_arrived_total(), 50);
        // the entry retired exactly once every *distinct* worker reported
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.jobs_in_flight() != 0 {
            assert!(Instant::now() < deadline, "duplicates confused retirement");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Aggregate view: all 7 responses = 70 bytes arrived. Asserted
        // after shutdown (which joins the router), because the 7th message
        // (worker 2's duplicate) may still be in flight when wait() returns.
        let aggregate = c.counters().clone();
        c.shutdown();
        assert_eq!(aggregate.download_arrived_total(), 70);
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 13);
        let (got, _) = c.submit(payloads(2, 1, 3), 2).unwrap().wait().unwrap();
        assert_eq!(got.len(), 2);
        c.shutdown_impl(); // internal: a consumed-by-shutdown coordinator can't be called
        let err = c.submit(payloads(2, 1, 3), 2).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }
}
