//! The master node / coordinator: owns a [`Transport`] to the worker pool,
//! dispatches encoded shares, and serves **multiple jobs in flight** — the
//! serving model the paper motivates (§I: any `R` of `N` workers finish a
//! request, so stragglers never gate latency).
//!
//! Architecture:
//!
//! * the worker pool is behind the object-safe [`Transport`] trait:
//!   [`Coordinator::new`] spawns the in-process
//!   [`ChannelTransport`](super::transport::ChannelTransport) (mpsc
//!   channels, unchanged semantics), [`Coordinator::connect_tcp`] dials
//!   `gr-cdmm worker` daemons over sockets, and
//!   [`Coordinator::with_transport`] accepts anything else (tests inject
//!   mock transports this way);
//! * [`Coordinator::submit`] is non-blocking: it registers the job in a
//!   shared job table, dispatches one payload per **shard** (to the
//!   healthiest workers when fewer payloads than workers are given), and
//!   returns a [`JobHandle`];
//! * a dedicated **response-router thread** receives every [`FromWorker`]
//!   message and forwards it to the owning job's channel by `job_id` — a
//!   straggler answering job `k` while job `k+3` is collecting is routed,
//!   never misattributed or dropped. The router also enforces
//!   **exactly-one forwarded response per shard per job**: a duplicate (a
//!   retransmitting or byzantine peer, or the loser of a speculative race)
//!   is counted as arrived bytes and dropped before it can reach a decoder,
//!   and an out-of-range shard id is dropped outright. Successful response
//!   latencies feed the per-worker estimators in [`super::pool`];
//! * a **health-monitor thread** drives the elastic-pool machinery on a
//!   fixed tick: it classifies every worker live/suspect/dead from the
//!   transport's [`link_status`](Transport::link_status) plus periodic
//!   pings, optionally re-dials dead links, and — when
//!   [`ElasticConfig::speculate`] is on — re-dispatches shards that have
//!   been outstanding past their deadline (`max(floor, mean + k·dev)` of
//!   the assigned worker's latency EWMA) to a live spare. The router's
//!   duplicate guard drops whichever copy loses the race. With the default
//!   config (speculation off) the monitor only observes, and the job path
//!   behaves exactly as the pre-elastic coordinator;
//! * each job owns its [`ByteCounters`]: upload is counted at dispatch
//!   (with the byte count the transport reports), arrived download at the
//!   router, used download by the job's collector, and speculative
//!   re-dispatches on their own counter. Overlapping jobs therefore account
//!   independently (asserted against the schemes' analytic volumes in
//!   `tests/integration_serving.rs`), and the accounting is
//!   transport-independent (asserted channel-vs-TCP in
//!   `tests/integration_transport.rs`);
//! * [`JobHandle::wait`] / [`JobHandle::try_wait`] collect the first `need`
//!   successful responses with a per-job timeout.
//!
//! Lifecycle details are on [`JobHandle`]; the single-job convenience path
//! is `submit(..)?.wait()`.

use super::pool::{ElasticConfig, PingAction, PoolState, WorkerHealth, WorkerSnapshot};
use super::prepared::{PreparedStore, DEFAULT_PREPARED_CAP};
use super::straggler::StragglerModel;
use super::tcp::TcpTransport;
use super::transport::{
    fail_report, ByteCounters, ChannelTransport, FromWorker, ToWorker, Transport,
};
use super::worker::{assemble_prepared, ShareCompute};
use crate::util::bytepool::PooledBuf;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One collected response. The payload is the pool-recycled buffer the
/// transport produced — cloning it is a reference-count bump, and dropping
/// the last clone returns the storage to the global
/// [`BytePool`](crate::util::bytepool::BytePool).
#[derive(Debug)]
pub struct Collected {
    pub worker_id: usize,
    pub payload: PooledBuf,
    pub compute: Duration,
    pub injected_delay: Duration,
}

/// The uniform "not enough responses in time" error of both the deadline
/// pre-check and the blocking-receive timeout.
fn timeout_error(got: usize, need: usize) -> anyhow::Error {
    anyhow::anyhow!("timed out with {got}/{need} responses (too many stragglers/failures?)")
}

/// The job's channel disconnected before the threshold: every shard has
/// already been resolved (with too many failures) or the coordinator shut
/// down — either way no further response can arrive, so collection fails
/// fast instead of sleeping until the deadline.
fn incomplete_error(job_id: u64, got: usize, need: usize) -> anyhow::Error {
    anyhow::anyhow!(
        "job {job_id} cannot complete: {got}/{need} responses and none still pending \
         (worker failures or coordinator shutdown)"
    )
}

/// One shard's dispatch state within a pending job. A shard may have
/// several copies in flight at once (primary + speculative re-dispatches);
/// it is `done` once one copy succeeded or every recovery avenue is
/// exhausted, and exactly one report per shard is ever forwarded to the
/// job's collector.
struct ShardState {
    /// The shard has been resolved (success forwarded, or declared failed);
    /// any further report for it is a duplicate and is dropped.
    done: bool,
    /// Dispatched copies not yet reported back.
    in_flight: usize,
    /// Every worker this shard has been dispatched to, primary first.
    /// `len()` is the attempt count; also the speculative-spare exclusion
    /// set (never hand a copy to a worker that already has one).
    assigned: Vec<usize>,
    /// When the most recent copy was dispatched; the overdue clock.
    last_dispatch: Instant,
}

/// A pending job's routing entry: where its responses go, its counters, and
/// the per-shard dispatch state. Every dispatched copy of a shard reports
/// exactly once (success, failure, or fail-stop drop), and every shard is
/// eventually resolved, so `outstanding` reaching 0 retires the entry: the
/// table stays bounded by the number of genuinely in-flight jobs.
struct JobEntry {
    /// `None` once the job's [`JobHandle`] is gone; late responses are then
    /// only accounted, not forwarded.
    tx: Option<Sender<FromWorker>>,
    counters: ByteCounters,
    /// Shards not yet resolved.
    outstanding: usize,
    shards: Vec<ShardState>,
    /// Retained payloads for speculative re-dispatch; dropped per shard as
    /// soon as the shard is resolved (returning the buffer to the pool).
    /// For a prepared job these are only the B-halves — a speculative copy
    /// re-assembles the full share from the prepared store.
    payloads: Vec<Option<PooledBuf>>,
    /// The prepared operand this job references, if any. A spare machine
    /// has its *own* A-half staged, not this shard's, so speculative copies
    /// of a prepared job ship the re-assembled full share instead.
    prepared: Option<u64>,
}

type JobTable = Arc<Mutex<HashMap<u64, JobEntry>>>;

/// The response router: drains the transport's single worker→master stream
/// and fans messages out to the owning job, attributing download bytes to
/// that job's counters — a straggler from an old job can never pollute a
/// newer one, and a shard can never be collected twice for one job. Exits
/// when the transport shuts down, and clears the table on the way out so
/// pending [`JobHandle`]s observe a disconnect instead of sleeping until
/// their timeout.
fn spawn_router(
    rx: Receiver<FromWorker>,
    jobs: JobTable,
    aggregate: ByteCounters,
    pool: PoolState,
    elastic: Arc<Mutex<ElasticConfig>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("gr-cdmm-router".to_string())
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                let len = msg.payload.as_ref().map_or(0, PooledBuf::len);
                aggregate.add_download_arrived(len);
                let mut table = jobs.lock().unwrap();
                let Some(entry) = table.get_mut(&msg.job_id) else {
                    // Entry already retired (all shards resolved, or the
                    // coordinator restarted routing) — the bytes stay
                    // visible in the aggregate discarded count.
                    continue;
                };
                let job_id = msg.job_id;
                let shard_id = msg.worker_id;
                if shard_id >= entry.shards.len() {
                    // Malformed/byzantine peer: unattributable, drop. The
                    // bytes stay visible in the aggregate discarded count.
                    continue;
                }
                entry.counters.add_download_arrived(len);
                let shard = &mut entry.shards[shard_id];
                if shard.done {
                    // Duplicate-response guard: this shard was already
                    // resolved (a retransmitting peer, or the loser of a
                    // speculative race). Never forwarded — a duplicate row
                    // must not reach a decoder — and `outstanding` is not
                    // decremented twice.
                    continue;
                }
                if msg.payload.is_some() {
                    if shard.assigned.len() == 1 {
                        // Unambiguous attribution: only one copy was ever
                        // dispatched, so this worker's latency estimate
                        // learns from the response.
                        pool.observe_latency(shard.assigned[0], shard.last_dispatch.elapsed());
                    }
                    shard.done = true;
                } else {
                    shard.in_flight = shard.in_flight.saturating_sub(1);
                    if shard.in_flight > 0 {
                        // A failed copy, but another copy of the shard is
                        // still out — not resolved yet either way.
                        continue;
                    }
                    let cfg = elastic.lock().unwrap().clone();
                    let may_retry = cfg.speculate
                        && shard.assigned.len() < cfg.max_attempts
                        && pool.live_spare(&shard.assigned).is_some();
                    if may_retry {
                        // Every copy failed but a retry is possible: leave
                        // the shard unresolved for the monitor to
                        // re-dispatch (in_flight == 0 makes it overdue
                        // immediately).
                        continue;
                    }
                    shard.done = true;
                }
                entry.outstanding -= 1;
                entry.payloads[shard_id] = None;
                let send_failed = match &entry.tx {
                    Some(tx) => tx.send(msg).is_err(),
                    None => false,
                };
                if send_failed {
                    // The handle was dropped: the job is over; keep the
                    // entry (for late-byte attribution) but stop forwarding.
                    entry.tx = None;
                }
                if entry.outstanding == 0 {
                    table.remove(&job_id);
                }
            }
            jobs.lock().unwrap().clear();
        })
        .expect("failed to spawn router thread")
}

/// Everything the health-monitor thread shares with the coordinator.
struct MonitorShared {
    transport: Arc<Mutex<Box<dyn Transport>>>,
    jobs: JobTable,
    pool: PoolState,
    aggregate: ByteCounters,
    elastic: Arc<Mutex<ElasticConfig>>,
    prepared: PreparedStore,
    stop: Arc<AtomicBool>,
}

/// A speculative copy the monitor decided to send, planned under the job
/// lock and executed under the transport lock (never both at once).
struct SpecDispatch {
    job_id: u64,
    shard: usize,
    target: usize,
    payload: PooledBuf,
    counters: ByteCounters,
}

/// One membership pass: refresh every worker's live/suspect/dead verdict
/// from the transport's link status, fire due health-check pings, and
/// (with [`ElasticConfig::auto_reconnect`]) re-dial dead links at most once
/// per `reconnect_interval`. Locks: transport, then pool.
fn health_pass(
    shared: &MonitorShared,
    cfg: &ElasticConfig,
    last_redial: &mut HashMap<usize, Instant>,
) {
    let mut t = shared.transport.lock().unwrap();
    let n = t.n_workers();
    shared.pool.ensure_len(n);
    for w in 0..n {
        let status = t.link_status(w);
        if let PingAction::Send(nonce) = shared.pool.health_check(w, status.alive, status.idle, cfg)
        {
            if t.ping(w, nonce).is_err() {
                shared.pool.set_health(w, WorkerHealth::Dead);
            }
        }
        if !status.alive && cfg.auto_reconnect {
            let due = last_redial.get(&w).is_none_or(|at| at.elapsed() >= cfg.reconnect_interval);
            if due {
                last_redial.insert(w, Instant::now());
                if t.reconnect_worker(w, None).is_ok() {
                    shared.pool.set_health(w, WorkerHealth::Live);
                    // Re-stage every prepared operand before any job can be
                    // routed to the revived link (the transport lock is
                    // held across reconnect + re-stage, so a prepared job
                    // can never slip in between).
                    restage_worker(t.as_mut(), w, &shared.prepared, &shared.aggregate);
                }
            }
        }
    }
}

/// Push every live prepared operand's `worker_id`-th A-half onto a freshly
/// (re)connected link, crediting the bytes to the aggregate staged-upload
/// counter. Workers beyond an operand's share count (pool grown since it
/// was prepared) are skipped — no half exists for them. Call with the
/// transport lock held.
fn restage_worker(
    t: &mut dyn Transport,
    worker_id: usize,
    prepared: &PreparedStore,
    aggregate: &ByteCounters,
) {
    for (id, shares) in prepared.entries() {
        let Some(half) = shares.get(worker_id) else { continue };
        let msg = ToWorker::Stage { prepared_id: id, payload: half.clone() };
        if let Ok(sent) = t.send(worker_id, msg) {
            aggregate.add_staged_upload(sent);
        }
    }
}

/// One speculation pass: find overdue shards and plan a copy for each on a
/// live spare; declare a shard failed when no copy is in flight and no
/// spare exists (so the job fails fast instead of hanging). Only plans —
/// the sends happen in [`execute_dispatches`] without the job lock held.
/// Locks: jobs, then pool.
fn plan_speculation(shared: &MonitorShared, cfg: &ElasticConfig) -> Vec<SpecDispatch> {
    let mut dispatches = Vec::new();
    let mut retired = Vec::new();
    let mut table = shared.jobs.lock().unwrap();
    for (&job_id, entry) in table.iter_mut() {
        for shard_id in 0..entry.shards.len() {
            let (in_flight, assigned) = {
                let s = &entry.shards[shard_id];
                if s.done {
                    continue;
                }
                let overdue = s.in_flight == 0
                    || (cfg.speculate
                        && s.last_dispatch.elapsed()
                            > shared.pool.deadline(s.assigned.first().copied(), cfg));
                if !overdue || s.in_flight >= cfg.max_copies {
                    continue;
                }
                (s.in_flight, s.assigned.clone())
            };
            let spare = if cfg.speculate && assigned.len() < cfg.max_attempts {
                shared.pool.live_spare(&assigned)
            } else {
                None
            };
            match spare {
                Some(target) => {
                    let Some(retained) = entry.payloads[shard_id].clone() else {
                        continue;
                    };
                    // A prepared job's retained payload is only the B-half,
                    // and the spare has *its own* A-half staged, not this
                    // shard's — so a speculative copy ships the full share,
                    // re-assembled from the prepared store (a pool-leased
                    // buffer; the inherent copy is charged to the
                    // copied-bytes probe). If the operand was evicted since
                    // submit, no retry is possible.
                    let payload = match entry.prepared {
                        None => retained,
                        Some(pid) => match shared.prepared.peek(pid) {
                            Some(halves) => assemble_prepared(&halves[shard_id], &retained),
                            None => continue,
                        },
                    };
                    let s = &mut entry.shards[shard_id];
                    s.in_flight += 1;
                    s.assigned.push(target);
                    s.last_dispatch = Instant::now();
                    dispatches.push(SpecDispatch {
                        job_id,
                        shard: shard_id,
                        target,
                        payload,
                        counters: entry.counters.clone(),
                    });
                }
                None if in_flight == 0 => {
                    // Every copy failed and no spare is available: the
                    // shard is unrecoverable. Resolve it as failed so the
                    // collector learns now (fail fast, never hang).
                    entry.shards[shard_id].done = true;
                    entry.outstanding -= 1;
                    entry.payloads[shard_id] = None;
                    let send_failed = match &entry.tx {
                        Some(tx) => tx.send(fail_report(job_id, shard_id)).is_err(),
                        None => false,
                    };
                    if send_failed {
                        entry.tx = None;
                    }
                    if entry.outstanding == 0 {
                        retired.push(job_id);
                    }
                }
                None => {}
            }
        }
    }
    for id in &retired {
        table.remove(id);
    }
    dispatches
}

/// Send the planned speculative copies and credit their bytes (and the
/// speculative-dispatch count) to the owning job and the aggregate.
/// Locks: transport only.
fn execute_dispatches(shared: &MonitorShared, dispatches: Vec<SpecDispatch>) {
    if dispatches.is_empty() {
        return;
    }
    let mut t = shared.transport.lock().unwrap();
    for d in dispatches {
        // Speculative copies always carry the full share (prepared jobs
        // were re-assembled at planning time), so `prepared` is None.
        let msg = ToWorker::Job {
            job_id: d.job_id,
            shard: d.shard,
            prepared: None,
            payload: d.payload,
        };
        match t.send(d.target, msg) {
            Ok(sent) => {
                d.counters.add_upload(sent);
                shared.aggregate.add_upload(sent);
                d.counters.add_speculative(1);
                shared.aggregate.add_speculative(1);
            }
            Err(e) => {
                // Transport-level error (not a dead link — those fail-stop
                // through the receiver): nothing to do but surface it.
                eprintln!(
                    "gr-cdmm: speculative re-dispatch of job {} shard {} to worker {} failed: {e}",
                    d.job_id, d.shard, d.target
                );
            }
        }
    }
}

/// The health-monitor thread: membership refresh, pings, reconnects and
/// speculative re-dispatch on a fixed tick. With the default config it
/// only observes (no speculation, no reconnects), so the job path is
/// byte-for-byte the pre-elastic coordinator's.
fn spawn_monitor(shared: MonitorShared) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("gr-cdmm-monitor".to_string())
        .spawn(move || {
            let mut last_redial: HashMap<usize, Instant> = HashMap::new();
            while !shared.stop.load(Ordering::Acquire) {
                let cfg = shared.elastic.lock().unwrap().clone();
                health_pass(&shared, &cfg, &mut last_redial);
                let dispatches = plan_speculation(&shared, &cfg);
                execute_dispatches(&shared, dispatches);
                std::thread::sleep(cfg.tick);
            }
        })
        .expect("failed to spawn monitor thread")
}

/// A handle to one in-flight job.
///
/// # Lifecycle
///
/// 1. [`Coordinator::submit`] registers the job and dispatches its payloads;
///    the handle's deadline starts there (override with
///    [`JobHandle::set_timeout`] before collecting).
/// 2. Responses routed to this job accumulate in its private channel;
///    [`JobHandle::counters`] observes the job's byte traffic live.
/// 3. Collect either blocking — [`JobHandle::wait`] — or by polling
///    [`JobHandle::try_wait`]. Both deliver `(Vec<Collected>, Duration)`:
///    the first `need` successful responses in arrival order and the
///    dispatch→threshold wall time. Worker-side failures are treated as
///    stragglers (never collected); if the deadline passes first, a
///    "timed out with k/need" error is returned.
/// 4. Dropping the handle (with or without collecting) ends the job: the
///    router unregisters it on the next routed response, and late bytes are
///    accounted as discarded in the job's and the coordinator's counters.
///
/// Handles are independent — any number of jobs may be in flight, collected
/// in any order.
pub struct JobHandle {
    job_id: u64,
    need: usize,
    /// How many successful responses to keep: `need` for the plain path,
    /// raised to `n_shards` by [`JobHandle::wait_surplus`] so verification
    /// can cross-check the decode against the extra responses.
    cap: usize,
    /// Shards dispatched for this job.
    n_shards: usize,
    /// Shards resolved as failed (worker-side error / fail-stop).
    failures: usize,
    /// Whether [`JobHandle::absorb`] credits collected bytes as used.
    /// [`JobHandle::wait_surplus`] turns this off: the verified-decode
    /// caller classifies each response as used or rejected *after*
    /// verification, so the `arrived == used + discarded + rejected`
    /// identity holds even when responses are thrown out as corrupt.
    count_used: bool,
    rx: Receiver<FromWorker>,
    counters: ByteCounters,
    aggregate: ByteCounters,
    submitted: Instant,
    timeout: Duration,
    collected: Vec<Collected>,
    done_at: Option<Duration>,
}

impl JobHandle {
    /// The coordinator-assigned job id.
    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// The recovery threshold this job collects to.
    pub fn need(&self) -> usize {
        self.need
    }

    /// Shards dispatched for this job (`need ≤ n_shards`).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// This job's byte counters (upload at dispatch, download as routed).
    /// Clone them to keep observing after the handle is consumed.
    pub fn counters(&self) -> &ByteCounters {
        &self.counters
    }

    /// Override the per-job deadline (measured from submission).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Absorb one routed response: the first `need` successful ones are
    /// collected (and their bytes counted as used), everything after is
    /// left as arrived-only, i.e. discarded. A second successful response
    /// from a shard that already contributed is dropped here too (the
    /// router's guard makes this unreachable in practice; the collector
    /// keeps its own last line of defense so a duplicate row can never
    /// reach a decode).
    fn absorb(&mut self, msg: FromWorker) {
        debug_assert_eq!(msg.job_id, self.job_id, "router must filter by job id");
        let FromWorker { worker_id, payload, compute, injected_delay, .. } = msg;
        let Some(payload) = payload else {
            self.failures += 1;
            return; // worker-side compute error: treat as a straggler
        };
        if self.collected.iter().any(|c| c.worker_id == worker_id) {
            return; // duplicate-response guard (bytes stay arrived-only)
        }
        if self.collected.len() < self.cap {
            if self.count_used {
                self.counters.add_download_used(payload.len());
                self.aggregate.add_download_used(payload.len());
            }
            self.collected.push(Collected { worker_id, payload, compute, injected_delay });
            if self.collected.len() == self.need {
                self.done_at = Some(self.submitted.elapsed());
            }
        }
    }

    /// Block until the job has `need` successful responses (or its deadline
    /// passes). Returns them in arrival order plus the dispatch→threshold
    /// wall time.
    pub fn wait(mut self) -> anyhow::Result<(Vec<Collected>, Duration)> {
        anyhow::ensure!(self.done_at.is_none(), "job {} was already collected", self.job_id);
        while self.collected.len() < self.need {
            // Absorb whatever already arrived before consulting the
            // deadline: a handle collected late (the pipelined pattern)
            // must not report a timeout for a job whose responses all
            // arrived in time and are sitting unread in its channel.
            match self.rx.try_recv() {
                Ok(msg) => {
                    self.absorb(msg);
                    continue;
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
            let remaining = self
                .timeout
                .checked_sub(self.submitted.elapsed())
                .ok_or_else(|| timeout_error(self.collected.len(), self.need))?;
            match self.rx.recv_timeout(remaining) {
                Ok(msg) => self.absorb(msg),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(timeout_error(self.collected.len(), self.need));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
        }
        let wait = self.done_at.expect("threshold reached");
        Ok((std::mem::take(&mut self.collected), wait))
    }

    /// Like [`JobHandle::wait`], but after the threshold is met keeps
    /// draining for up to `grace` so late (surplus) responses are collected
    /// too — the raw material for Byzantine verification: with more than
    /// `need` responses in hand the decoder can cross-check the product
    /// against the surplus shares. Returns between `need` and `n_shards`
    /// responses in arrival order, plus the dispatch→threshold wall time
    /// (the grace drain is excluded — it is verification overhead, not
    /// serving latency). The deadline/timeout semantics of phase 1 are
    /// exactly [`JobHandle::wait`]'s.
    ///
    /// Used-byte accounting is deferred to the caller (see
    /// [`ByteCounters::add_download_used`] /
    /// [`ByteCounters::add_download_rejected`]): until classified, the
    /// collected bytes show as arrived-only.
    pub fn wait_surplus(mut self, grace: Duration) -> anyhow::Result<(Vec<Collected>, Duration)> {
        anyhow::ensure!(self.done_at.is_none(), "job {} was already collected", self.job_id);
        self.cap = self.n_shards;
        self.count_used = false;
        while self.collected.len() < self.need {
            match self.rx.try_recv() {
                Ok(msg) => {
                    self.absorb(msg);
                    continue;
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
            let remaining = self
                .timeout
                .checked_sub(self.submitted.elapsed())
                .ok_or_else(|| timeout_error(self.collected.len(), self.need))?;
            match self.rx.recv_timeout(remaining) {
                Ok(msg) => self.absorb(msg),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(timeout_error(self.collected.len(), self.need));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
        }
        let wait = self.done_at.expect("threshold reached");
        let grace_deadline = Instant::now() + grace;
        while self.collected.len() + self.failures < self.n_shards {
            let Some(remaining) = grace_deadline.checked_duration_since(Instant::now()) else {
                break;
            };
            match self.rx.recv_timeout(remaining) {
                Ok(msg) => self.absorb(msg),
                // Grace expired or the channel closed: verification works
                // with whatever surplus arrived in time.
                Err(_) => break,
            }
        }
        Ok((std::mem::take(&mut self.collected), wait))
    }

    /// Non-blocking poll. `Ok(None)` while the job is still pending within
    /// its deadline; `Ok(Some(..))` exactly once when the threshold is met;
    /// the same timeout error as [`JobHandle::wait`] once the deadline has
    /// passed.
    pub fn try_wait(&mut self) -> anyhow::Result<Option<(Vec<Collected>, Duration)>> {
        anyhow::ensure!(self.done_at.is_none(), "job {} was already collected", self.job_id);
        loop {
            match self.rx.try_recv() {
                Ok(msg) => {
                    self.absorb(msg);
                    if self.done_at.is_some() {
                        let wait = self.done_at.expect("threshold reached");
                        return Ok(Some((std::mem::take(&mut self.collected), wait)));
                    }
                }
                Err(TryRecvError::Empty) => {
                    if self.submitted.elapsed() > self.timeout {
                        return Err(timeout_error(self.collected.len(), self.need));
                    }
                    return Ok(None);
                }
                Err(TryRecvError::Disconnected) => {
                    return Err(incomplete_error(self.job_id, self.collected.len(), self.need));
                }
            }
        }
    }
}

/// The coordinator: a [`Transport`] to an elastic pool of workers, a
/// response router, a health monitor, and the job table that lets any
/// number of jobs overlap.
pub struct Coordinator {
    transport: Arc<Mutex<Box<dyn Transport>>>,
    router: Option<JoinHandle<()>>,
    monitor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    jobs: JobTable,
    pool: PoolState,
    elastic: Arc<Mutex<ElasticConfig>>,
    aggregate: ByteCounters,
    prepared: PreparedStore,
    next_job: u64,
    open: bool,
    /// Default per-job deadline, captured by [`Coordinator::submit`].
    pub timeout: Duration,
}

impl Coordinator {
    /// Spawn an in-process pool of `n_workers` worker threads applying
    /// `compute`, with straggler injection, joined by mpsc channels. `seed`
    /// derives the per-worker RNG streams.
    pub fn new(
        n_workers: usize,
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
    ) -> Self {
        Self::with_transport(Box::new(ChannelTransport::spawn(
            n_workers, compute, straggler, seed,
        )))
    }

    /// Connect to one `gr-cdmm worker` daemon per endpoint; endpoint `i` is
    /// worker `i`. Straggler injection (and the compute backend) live at
    /// the daemons in this mode.
    pub fn connect_tcp(endpoints: &[String]) -> anyhow::Result<Self> {
        Ok(Self::with_transport(Box::new(TcpTransport::connect(endpoints)?)))
    }

    /// Connect to same-host daemons over the shared-memory transport:
    /// control frames ride TCP to each endpoint, payloads travel through
    /// ring files under `dir` — which must be the daemons'
    /// [`DaemonConfig::shm_dir`](super::daemon::DaemonConfig).
    pub fn connect_shm(
        endpoints: &[String],
        dir: impl Into<std::path::PathBuf>,
    ) -> anyhow::Result<Self> {
        Ok(Self::with_transport(Box::new(super::shm::ShmTransport::connect(endpoints, dir)?)))
    }

    /// Build over any [`Transport`].
    pub fn with_transport(mut transport: Box<dyn Transport>) -> Self {
        let rx = transport.take_receiver().expect("transport's receiver was already taken");
        let n_workers = transport.n_workers();
        let transport = Arc::new(Mutex::new(transport));
        let jobs: JobTable = Arc::new(Mutex::new(HashMap::new()));
        let aggregate = ByteCounters::new();
        let pool = PoolState::new(n_workers);
        let elastic = Arc::new(Mutex::new(ElasticConfig::default()));
        let prepared = PreparedStore::new(DEFAULT_PREPARED_CAP);
        let stop = Arc::new(AtomicBool::new(false));
        let router = spawn_router(
            rx,
            Arc::clone(&jobs),
            aggregate.clone(),
            pool.clone(),
            Arc::clone(&elastic),
        );
        let monitor = spawn_monitor(MonitorShared {
            transport: Arc::clone(&transport),
            jobs: Arc::clone(&jobs),
            pool: pool.clone(),
            aggregate: aggregate.clone(),
            elastic: Arc::clone(&elastic),
            prepared: prepared.clone(),
            stop: Arc::clone(&stop),
        });
        Coordinator {
            transport,
            router: Some(router),
            monitor: Some(monitor),
            stop,
            jobs,
            pool,
            elastic,
            aggregate,
            prepared,
            next_job: 0,
            open: true,
            timeout: Duration::from_secs(120),
        }
    }

    /// Worker slots the transport reaches, dead links included (the pool
    /// only ever grows; see [`Coordinator::live_workers`]).
    pub fn n_workers(&self) -> usize {
        self.transport.lock().unwrap().n_workers()
    }

    /// Workers whose link is currently up.
    pub fn live_workers(&self) -> usize {
        let t = self.transport.lock().unwrap();
        (0..t.n_workers()).filter(|&w| t.link_status(w).alive).count()
    }

    /// The health monitor's current verdict for one worker (link state
    /// always wins: a down link is dead no matter what the monitor last
    /// recorded).
    pub fn worker_health(&self, worker_id: usize) -> WorkerHealth {
        if !self.transport.lock().unwrap().link_status(worker_id).alive {
            return WorkerHealth::Dead;
        }
        self.pool.health(worker_id)
    }

    /// Per-worker health + latency snapshot, for reports and tests.
    pub fn pool_snapshot(&self) -> Vec<WorkerSnapshot> {
        self.pool.snapshot()
    }

    /// Mark a worker [`WorkerHealth::Quarantined`] — verified-decode found
    /// its response inconsistent with the codeword. A quarantined worker is
    /// excluded from placement and speculative spares until it earns its
    /// way back through the pool's ping probation (see
    /// [`super::pool::PROBATION_CLEAN_PINGS`]).
    pub fn quarantine_worker(&mut self, worker_id: usize) {
        self.pool.quarantine(worker_id);
    }

    /// Replace the elastic-pool tuning (health cadence, speculation,
    /// reconnect policy). Takes effect on the monitor's next tick.
    pub fn set_elastic(&mut self, cfg: ElasticConfig) {
        *self.elastic.lock().unwrap() = cfg;
    }

    /// The current elastic-pool tuning.
    pub fn elastic_config(&self) -> ElasticConfig {
        self.elastic.lock().unwrap().clone()
    }

    /// Take one worker's link down (jobs it owes fail-stop). The monitor
    /// marks it dead on its next pass; this also records it eagerly so
    /// placement decisions made before that pass already avoid it.
    pub fn disconnect_worker(&mut self, worker_id: usize) -> anyhow::Result<()> {
        self.transport.lock().unwrap().disconnect_worker(worker_id)?;
        self.pool.set_health(worker_id, WorkerHealth::Dead);
        Ok(())
    }

    /// Bring a worker's link back up (TCP re-dials, optionally at a new
    /// endpoint; the channel transport revives the worker in place), then
    /// re-stage every prepared operand onto it before releasing the
    /// transport — a prepared job can never reach a revived worker ahead
    /// of its staged A-half.
    pub fn reconnect_worker(
        &mut self,
        worker_id: usize,
        endpoint: Option<&str>,
    ) -> anyhow::Result<()> {
        let mut t = self.transport.lock().unwrap();
        t.reconnect_worker(worker_id, endpoint)?;
        restage_worker(t.as_mut(), worker_id, &self.prepared, &self.aggregate);
        drop(t);
        self.pool.set_health(worker_id, WorkerHealth::Live);
        Ok(())
    }

    /// Grow the pool by one worker mid-run; returns its id. Existing
    /// prepared operands have no A-half for the new slot (they were encoded
    /// for the old pool size), so nothing is staged on it.
    pub fn add_worker(&mut self, endpoint: Option<&str>) -> anyhow::Result<usize> {
        let worker_id = self.transport.lock().unwrap().add_worker(endpoint)?;
        self.pool.ensure_len(worker_id + 1);
        Ok(worker_id)
    }

    /// The transport's short name (`"channel"`, `"tcp"`), for reports.
    pub fn transport_name(&self) -> &'static str {
        self.transport.lock().unwrap().name()
    }

    /// Coordinator-lifetime byte totals, summed over every job (never
    /// reset). Per-job accounting lives on each [`JobHandle::counters`].
    pub fn counters(&self) -> &ByteCounters {
        &self.aggregate
    }

    /// Number of jobs currently registered with the router.
    pub fn jobs_in_flight(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    /// Dispatch the payloads — shard `i` of the job is `payloads[i]` — and
    /// return immediately with a [`JobHandle`] that collects the first
    /// `need` successful responses. With one payload per worker (the
    /// classic shape) shard `i` goes to worker `i`; with **fewer** payloads
    /// than workers the shards go to the healthiest workers (live before
    /// suspect before dead, ties by index), which is how a degraded scheme
    /// from [`SchemeConfig::for_live_workers`] runs on a partly-dead pool.
    /// Any number of submitted jobs may overlap; responses are routed to
    /// their owning job by id.
    ///
    /// Payloads are anything convertible into a [`PooledBuf`] —
    /// pool-leased buffers from the erased scheme facade ride through with
    /// zero copies; plain `Vec<u8>`s (tests, ad-hoc callers) are wrapped
    /// without reallocation.
    ///
    /// [`SchemeConfig::for_live_workers`]:
    ///     crate::codes::registry::SchemeConfig::for_live_workers
    pub fn submit<P: Into<PooledBuf>>(
        &mut self,
        payloads: Vec<P>,
        need: usize,
    ) -> anyhow::Result<JobHandle> {
        self.submit_with(payloads.into_iter().map(Into::into).collect(), need, None)
    }

    /// Encode-once serving, step 1: register `a_shares` (worker `i`'s
    /// serialized A-side share half is `a_shares[i]`, from
    /// [`DynScheme::encode_left_bytes`]) as a **prepared operand**, staging
    /// each half on its worker. Returns the operand's id for
    /// [`Coordinator::submit_prepared`]. The staged bytes are credited to
    /// the aggregate [`ByteCounters::staged_upload_total`] — not to any
    /// job's upload — and are re-pushed automatically whenever a worker
    /// link is re-established. The store is bounded
    /// ([`DEFAULT_PREPARED_CAP`]): registering past capacity evicts the
    /// least-recently-used operand master- and worker-side.
    ///
    /// [`DynScheme::encode_left_bytes`]:
    ///     crate::codes::DynScheme::encode_left_bytes
    pub fn prepare<P: Into<PooledBuf>>(&mut self, a_shares: Vec<P>) -> anyhow::Result<u64> {
        anyhow::ensure!(self.open, "coordinator is shut down");
        let n_workers = self.n_workers();
        anyhow::ensure!(
            a_shares.len() == n_workers,
            "need one A-half per worker ({n_workers}), got {}",
            a_shares.len()
        );
        let shares: Vec<PooledBuf> = a_shares.into_iter().map(Into::into).collect();
        let (id, evicted) = self.prepared.insert(shares.clone());
        let mut t = self.transport.lock().unwrap();
        for old in evicted {
            for w in 0..n_workers {
                let _ = t.send(w, ToWorker::Evict { prepared_id: old });
            }
        }
        for (w, half) in shares.into_iter().enumerate() {
            let msg = ToWorker::Stage { prepared_id: id, payload: half };
            let sent = t.send(w, msg)?;
            self.aggregate.add_staged_upload(sent);
        }
        Ok(id)
    }

    /// Drop a prepared operand master- and worker-side. Returns whether the
    /// id was still registered.
    pub fn release_prepared(&mut self, id: u64) -> anyhow::Result<bool> {
        let present = self.prepared.remove(id);
        if present {
            let mut t = self.transport.lock().unwrap();
            for w in 0..t.n_workers() {
                let _ = t.send(w, ToWorker::Evict { prepared_id: id });
            }
        }
        Ok(present)
    }

    /// Encode-once serving, step 2: dispatch a job whose A-side was staged
    /// by [`Coordinator::prepare`] — `b_payloads[i]` is worker `i`'s
    /// serialized B-side half (from [`DynScheme::encode_right_bytes`]), the
    /// only per-job bytes that cross the wire. Workers prepend their staged
    /// A-half, so the compute path (and the decode) is byte-identical to an
    /// unprepared submit of the full shares. Shard `i` is pinned to worker
    /// `i` (its staged half lives there); a dead worker's shard fail-stops,
    /// like any straggler. Unknown/evicted ids error (and count a store
    /// miss); hits touch the operand's LRU slot.
    ///
    /// [`DynScheme::encode_right_bytes`]:
    ///     crate::codes::DynScheme::encode_right_bytes
    pub fn submit_prepared<P: Into<PooledBuf>>(
        &mut self,
        id: u64,
        b_payloads: Vec<P>,
        need: usize,
    ) -> anyhow::Result<JobHandle> {
        anyhow::ensure!(self.open, "coordinator is shut down");
        let staged = self.prepared.get(id);
        anyhow::ensure!(staged.is_some(), "prepared operand {id} is not registered (evicted?)");
        let n_workers = self.n_workers();
        anyhow::ensure!(
            b_payloads.len() == n_workers,
            "need one B-half per worker ({n_workers}), got {} — prepared shards are pinned \
             to their staged workers",
            b_payloads.len()
        );
        self.submit_with(b_payloads.into_iter().map(Into::into).collect(), need, Some(id))
    }

    /// `(hits, misses, evictions)` of the prepared-operand store.
    pub fn prepared_stats(&self) -> (u64, u64, u64) {
        self.prepared.stats()
    }

    /// Number of operands currently staged.
    pub fn prepared_len(&self) -> usize {
        self.prepared.len()
    }

    /// Bound the prepared-operand store (default
    /// [`DEFAULT_PREPARED_CAP`]). Shrinking below the current size takes
    /// effect on the next [`Coordinator::prepare`], which LRU-evicts down
    /// to the new bound master- and worker-side.
    pub fn set_prepared_capacity(&mut self, cap: usize) {
        self.prepared.set_capacity(cap);
    }

    fn submit_with(
        &mut self,
        payloads: Vec<PooledBuf>,
        need: usize,
        prepared: Option<u64>,
    ) -> anyhow::Result<JobHandle> {
        anyhow::ensure!(self.open, "coordinator is shut down");
        let n_workers = self.n_workers();
        let n_shards = payloads.len();
        anyhow::ensure!(
            (1..=n_workers).contains(&n_shards),
            "need between 1 and {n_workers} payloads, one per target worker (got {n_shards})"
        );
        anyhow::ensure!(
            (1..=n_shards).contains(&need),
            "need must be in 1..={n_shards} (got {need})"
        );
        let targets: Vec<usize> = if n_shards == n_workers {
            (0..n_workers).collect()
        } else {
            let mut ranked: Vec<(u8, usize)> = {
                let t = self.transport.lock().unwrap();
                (0..n_workers)
                    .map(|w| {
                        let rank = if t.link_status(w).alive {
                            self.pool.health(w).rank()
                        } else {
                            WorkerHealth::Dead.rank()
                        };
                        (rank, w)
                    })
                    .collect()
            };
            ranked.sort_unstable();
            let mut chosen: Vec<usize> =
                ranked.into_iter().take(n_shards).map(|(_, w)| w).collect();
            chosen.sort_unstable();
            chosen
        };
        let job_id = self.next_job;
        self.next_job += 1;

        let counters = ByteCounters::new();
        let (job_tx, job_rx) = channel::<FromWorker>();
        let submitted = Instant::now();
        // Register before dispatching: a response must never beat the entry.
        self.jobs.lock().unwrap().insert(
            job_id,
            JobEntry {
                tx: Some(job_tx),
                counters: counters.clone(),
                outstanding: n_shards,
                shards: targets
                    .iter()
                    .map(|&t| ShardState {
                        done: false,
                        in_flight: 1,
                        assigned: vec![t],
                        last_dispatch: submitted,
                    })
                    .collect(),
                payloads: payloads.iter().cloned().map(Some).collect(),
                prepared,
            },
        );

        for (shard, payload) in payloads.into_iter().enumerate() {
            let msg = ToWorker::Job { job_id, shard, prepared, payload };
            match self.transport.lock().unwrap().send(targets[shard], msg) {
                Ok(sent) => {
                    // Credit the bytes the transport reports actually
                    // crossing the link — identical across transports.
                    counters.add_upload(sent);
                    self.aggregate.add_upload(sent);
                }
                Err(e) => {
                    self.jobs.lock().unwrap().remove(&job_id);
                    return Err(e);
                }
            }
        }
        Ok(JobHandle {
            job_id,
            need,
            cap: need,
            n_shards,
            failures: 0,
            count_used: true,
            rx: job_rx,
            counters,
            aggregate: self.aggregate.clone(),
            submitted,
            timeout: self.timeout,
            collected: Vec::with_capacity(need),
            done_at: None,
        })
    }

    fn shutdown_impl(&mut self) {
        self.open = false;
        // Monitor first (it holds no lock while asleep and exits within one
        // tick), so nothing re-dispatches into a closing transport.
        self.stop.store(true, Ordering::Release);
        if let Some(monitor) = self.monitor.take() {
            let _ = monitor.join();
        }
        self.transport.lock().unwrap().shutdown();
        if let Some(router) = self.router.take() {
            let _ = router.join();
        }
    }

    /// Graceful shutdown: stop the health monitor, signal the transport
    /// (every worker joins / every connection closes), then join the
    /// router. Queued jobs are still processed and routed before workers
    /// exit.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }
}

/// Dropping the coordinator performs the same shutdown as
/// [`Coordinator::shutdown`], so a panicking test or an early `?` return
/// never leaks the pool/router/monitor threads.
impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo backend: replies with the payload itself.
    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<PooledBuf> {
            Ok(payload.to_vec().into())
        }
    }

    fn payloads(n: usize, byte: u8, len: usize) -> Vec<Vec<u8>> {
        (0..n).map(|_| vec![byte; len]).collect()
    }

    #[test]
    fn collects_first_r() {
        let mut c = Coordinator::new(4, Arc::new(Echo), StragglerModel::None, 1);
        assert_eq!(c.transport_name(), "channel");
        let payloads: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 10]).collect();
        let handle = c.submit(payloads, 3).unwrap();
        let job_counters = handle.counters().clone();
        let (got, _) = handle.wait().unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(job_counters.upload_total(), 40);
        assert_eq!(job_counters.download_used_total(), 30);
        // single job: aggregate equals the job's view
        assert_eq!(c.counters().upload_total(), 40);
        assert_eq!(c.counters().download_used_total(), 30);
        c.shutdown();
    }

    #[test]
    fn overlapping_jobs_route_by_job_id() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 6);
        let h1 = c.submit(payloads(3, 0xA1, 5), 3).unwrap();
        let h2 = c.submit(payloads(3, 0xB2, 9), 3).unwrap();
        let h3 = c.submit(payloads(3, 0xC3, 2), 3).unwrap();
        assert_eq!((h1.job_id(), h2.job_id(), h3.job_id()), (0, 1, 2));
        // collect out of submission order: routing must not care
        for (h, byte, len) in [(h2, 0xB2u8, 9usize), (h3, 0xC3, 2), (h1, 0xA1, 5)] {
            let counters = h.counters().clone();
            let (got, _) = h.wait().unwrap();
            assert_eq!(got.len(), 3);
            for resp in &got {
                assert_eq!(resp.payload, vec![byte; len], "response bytes belong to the job");
            }
            assert_eq!(counters.upload_total(), (3 * len) as u64);
            assert_eq!(counters.download_used_total(), (3 * len) as u64);
        }
        c.shutdown();
    }

    #[test]
    fn tolerates_fail_stop_up_to_n_minus_r() {
        let straggler = StragglerModel::fail_stop([0, 2]);
        let mut c = Coordinator::new(5, Arc::new(Echo), straggler, 2);
        let (got, _) = c.submit(payloads(5, 7, 4), 3).unwrap().wait().unwrap();
        let ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        assert!(!ids.contains(&0) && !ids.contains(&2));
        c.shutdown();
    }

    #[test]
    fn fails_fast_when_too_many_fail() {
        let straggler = StragglerModel::fail_stop([0, 1, 2]);
        let mut c = Coordinator::new(4, Arc::new(Echo), straggler, 3);
        // No short timeout needed: once all four workers have reported
        // (three of them as drops) the threshold is unreachable and the
        // collector fails fast.
        let err = c.submit(payloads(4, 1, 1), 2).unwrap().wait().unwrap_err();
        assert!(err.to_string().contains("1/2"), "{err}");
        c.shutdown();
    }

    #[test]
    fn times_out_on_slow_workers() {
        let straggler = StragglerModel::fixed_slow([0, 1], Duration::from_millis(400));
        let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 11);
        c.timeout = Duration::from_millis(80);
        let err = c.submit(payloads(2, 1, 1), 1).unwrap().wait().unwrap_err();
        assert!(err.to_string().contains("timed out with 0/1"), "{err}");
        c.shutdown(); // joins the still-sleeping workers
    }

    #[test]
    fn slow_workers_not_in_first_r() {
        let straggler = StragglerModel::fixed_slow([0], Duration::from_millis(300));
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 4);
        let (got, wait) = c.submit(payloads(3, 1, 8), 2).unwrap().wait().unwrap();
        let ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        assert!(!ids.contains(&0), "slow worker 0 should not be among first 2");
        assert!(wait < Duration::from_millis(250), "did not wait for the straggler");
        c.shutdown();
    }

    #[test]
    fn multiple_jobs_reuse_pool() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 5);
        for _ in 0..5 {
            let (got, _) = c.submit(payloads(3, 9, 2), 3).unwrap().wait().unwrap();
            assert_eq!(got.len(), 3);
        }
        c.shutdown();
    }

    #[test]
    fn try_wait_polls_to_completion() {
        let straggler = StragglerModel::fixed_slow([0, 1, 2], Duration::from_millis(150));
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 7);
        let mut handle = c.submit(payloads(3, 4, 3), 2).unwrap();
        // workers are still sleeping: pending
        assert!(handle.try_wait().unwrap().is_none());
        let deadline = Instant::now() + Duration::from_secs(5);
        let (got, _) = loop {
            if let Some(done) = handle.try_wait().unwrap() {
                break done;
            }
            assert!(Instant::now() < deadline, "try_wait never completed");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert_eq!(got.len(), 2);
        // the handle is spent now
        assert!(handle.try_wait().is_err());
        c.shutdown();
    }

    #[test]
    fn dropping_handle_keeps_pool_serving() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 8);
        let abandoned = c.submit(payloads(3, 1, 6), 3).unwrap();
        let abandoned_counters = abandoned.counters().clone();
        drop(abandoned);
        // the pool still serves the next job
        let (got, _) = c.submit(payloads(3, 2, 4), 3).unwrap().wait().unwrap();
        assert_eq!(got.len(), 3);
        // the abandoned job's responses were routed/accounted, never used
        let deadline = Instant::now() + Duration::from_secs(5);
        while abandoned_counters.download_arrived_total() < 18 {
            assert!(Instant::now() < deadline, "late responses were not attributed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(abandoned_counters.download_used_total(), 0);
        assert_eq!(abandoned_counters.download_discarded_total(), 18);
        c.shutdown();
    }

    #[test]
    fn drop_joins_pool_and_drains_in_flight_job() {
        // No explicit shutdown: Drop must signal and join workers + router
        // + monitor (this test would hang otherwise). The job queued before
        // the drop is still processed and routed, so its handle collects
        // normally.
        let handle = {
            let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 9);
            c.submit(payloads(2, 3, 2), 2).unwrap()
        };
        let (got, _) = handle.wait().unwrap();
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn handle_errors_cleanly_after_unserved_shutdown() {
        // Workers fail-stop, coordinator dropped: the handle can never be
        // served and reports that instead of hanging.
        let straggler = StragglerModel::fail_stop([0, 1]);
        let handle = {
            let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 12);
            c.submit(payloads(2, 3, 2), 1).unwrap()
        };
        let err = handle.wait().unwrap_err();
        assert!(err.to_string().contains("cannot complete"), "{err}");
    }

    #[test]
    fn job_table_drains_after_all_workers_report() {
        // Worker 1 fail-stops; it still reports the drop, so the entry
        // retires once every shard is resolved — the table stays bounded by
        // the genuinely in-flight jobs.
        let straggler = StragglerModel::fail_stop([1]);
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 10);
        let h = c.submit(payloads(3, 5, 1), 2).unwrap();
        let _ = h.wait().unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.jobs_in_flight() != 0 {
            assert!(Instant::now() < deadline, "job entry never retired");
            std::thread::sleep(Duration::from_millis(5));
        }
        c.shutdown();
    }

    #[test]
    fn partial_submit_targets_healthy_workers() {
        // One payload on a two-worker pool whose worker 0 is down: the
        // shard must be placed on the live worker 1 (and still report as
        // shard 0), with its bytes actually crossing the link.
        let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 21);
        c.disconnect_worker(0).unwrap();
        assert_eq!(c.worker_health(0), WorkerHealth::Dead);
        assert_eq!(c.live_workers(), 1);
        let h = c.submit(vec![vec![7u8; 6]], 1).unwrap();
        let job_counters = h.counters().clone();
        let (got, _) = h.wait().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].worker_id, 0, "reports carry the shard id");
        assert_eq!(
            job_counters.upload_total(),
            6,
            "the payload crossed a live link (a dead-link dispatch would count 0)"
        );
        c.shutdown();
    }

    #[test]
    fn speculative_redispatch_rescues_a_straggling_shard() {
        // Worker 0 drags its shard for 2s; with speculation on, the monitor
        // re-dispatches that shard to worker 1 after the deadline floor and
        // the job completes far below the straggler's delay. The straggler
        // model keys off the *machine*, so the spare copy runs clean.
        let straggler = StragglerModel::fixed_slow([0], Duration::from_secs(2));
        let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 22);
        let mut cfg = ElasticConfig::speculative();
        cfg.tick = Duration::from_millis(2);
        cfg.spec_min_deadline = Duration::from_millis(30);
        c.set_elastic(cfg);
        let h = c.submit(payloads(2, 0xAB, 4), 2).unwrap();
        let job_counters = h.counters().clone();
        let (got, wait) = h.wait().unwrap();
        assert_eq!(got.len(), 2);
        let mut ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1], "both shards collected exactly once");
        assert!(wait < Duration::from_secs(1), "speculation did not beat the straggler: {wait:?}");
        assert_eq!(job_counters.speculative_total(), 1, "exactly one speculative copy");
        assert_eq!(
            job_counters.upload_total(),
            12,
            "the speculative copy's bytes are counted as upload too"
        );
        c.shutdown();
    }

    /// A transport double whose "workers" echo every job TWICE, plus one
    /// response under a bogus shard id: a retransmitting / byzantine peer
    /// distilled. Exercises the master-side duplicate-response and
    /// id-bounds guards end-to-end through submit → router → collect.
    struct DuplicatingTransport {
        n: usize,
        tx: Option<Sender<FromWorker>>,
        rx: Option<Receiver<FromWorker>>,
    }

    impl DuplicatingTransport {
        fn new(n: usize) -> Self {
            let (tx, rx) = channel();
            DuplicatingTransport { n, tx: Some(tx), rx: Some(rx) }
        }
    }

    impl Transport for DuplicatingTransport {
        fn n_workers(&self) -> usize {
            self.n
        }

        fn send(&mut self, _worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
            let ToWorker::Job { job_id, shard, payload, .. } = msg else {
                return Ok(0);
            };
            let tx = self.tx.as_ref().expect("transport is open");
            let echo = |wid: usize| FromWorker {
                job_id,
                worker_id: wid,
                payload: Some(payload.clone()),
                compute: Duration::ZERO,
                injected_delay: Duration::ZERO,
            };
            // every worker answers twice, and worker 0's peer additionally
            // spoofs an out-of-range id
            tx.send(echo(shard)).unwrap();
            tx.send(echo(shard)).unwrap();
            if shard == 0 {
                tx.send(echo(self.n + 7)).unwrap();
            }
            Ok(payload.len())
        }

        fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
            self.rx.take()
        }

        fn shutdown(&mut self) {
            self.tx = None;
        }

        fn name(&self) -> &'static str {
            "mock-duplicating"
        }
    }

    #[test]
    fn duplicate_responses_are_dropped_before_decode() {
        let mut c = Coordinator::with_transport(Box::new(DuplicatingTransport::new(3)));
        let handle = c.submit(payloads(3, 0xEE, 10), 3).unwrap();
        let job_counters = handle.counters().clone();
        let (got, _) = handle.wait().unwrap();
        // exactly one collected response per shard, despite the double
        // echo — a duplicate must never be fed to a decoder
        let mut ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        // duplicates and the spoofed id were counted as arrived, not used.
        // Job view: 3 used + the two duplicates routed before the entry
        // retired (shard 2's duplicate lands after retirement, and the
        // spoofed id is never attributable) = 50 bytes arrived. Safe to
        // assert here: wait() returning implies the router processed
        // through shard 2's first response (message 6 of 7).
        assert_eq!(job_counters.download_used_total(), 30);
        assert_eq!(job_counters.download_arrived_total(), 50);
        // the entry retired exactly once every *distinct* shard reported
        let deadline = Instant::now() + Duration::from_secs(5);
        while c.jobs_in_flight() != 0 {
            assert!(Instant::now() < deadline, "duplicates confused retirement");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Aggregate view: all 7 responses = 70 bytes arrived. Asserted
        // after shutdown (which joins the router), because the 7th message
        // (shard 2's duplicate) may still be in flight when wait() returns.
        let aggregate = c.counters().clone();
        c.shutdown();
        assert_eq!(aggregate.download_arrived_total(), 70);
    }

    #[test]
    fn prepared_jobs_ship_only_b_halves_and_compute_on_the_full_share() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 30);
        let a_halves: Vec<Vec<u8>> = (0..3).map(|w| vec![0xA0 + w as u8; 10]).collect();
        let id = c.prepare(a_halves).unwrap();
        assert_eq!(c.counters().staged_upload_total(), 30, "A-halves credited as staging");
        assert_eq!(c.counters().upload_total(), 0, "staging is not job upload");
        for round in 0..3u8 {
            let b_halves: Vec<Vec<u8>> = (0..3).map(|w| vec![0x10 * round + w as u8; 4]).collect();
            let h = c.submit_prepared(id, b_halves.clone(), 3).unwrap();
            let job_counters = h.counters().clone();
            let (got, _) = h.wait().unwrap();
            assert_eq!(got.len(), 3);
            for resp in &got {
                let w = resp.worker_id;
                let mut expect = vec![0xA0 + w as u8; 10];
                expect.extend_from_slice(&b_halves[w]);
                assert_eq!(resp.payload, expect, "worker {w} computed on staged ++ B-half");
            }
            assert_eq!(job_counters.upload_total(), 12, "only the B-halves crossed");
            assert_eq!(job_counters.staged_upload_total(), 0);
        }
        let (hits, misses, evictions) = c.prepared_stats();
        assert_eq!((hits, misses, evictions), (3, 0, 0));
        assert_eq!(c.counters().staged_upload_total(), 30, "staged exactly once");
        c.shutdown();
    }

    #[test]
    fn unknown_and_released_prepared_ids_are_rejected() {
        let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 31);
        assert!(c.submit_prepared(7, payloads(2, 1, 2), 2).is_err());
        let id = c.prepare(payloads(2, 0xA, 5)).unwrap();
        assert!(c.release_prepared(id).unwrap());
        assert!(!c.release_prepared(id).unwrap(), "second release is a no-op");
        assert!(c.submit_prepared(id, payloads(2, 1, 2), 2).is_err());
        let (hits, misses, _) = c.prepared_stats();
        assert_eq!((hits, misses), (0, 2));
        // Wrong payload count is rejected before dispatch.
        let id = c.prepare(payloads(2, 0xB, 5)).unwrap();
        assert!(c.submit_prepared(id, payloads(1, 1, 2), 1).is_err());
        c.shutdown();
    }

    #[test]
    fn lru_eviction_propagates_to_workers() {
        let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 32);
        c.set_prepared_capacity(1);
        let first = c.prepare(payloads(2, 0xA, 6)).unwrap();
        let second = c.prepare(payloads(2, 0xB, 6)).unwrap();
        assert_eq!(c.prepared_len(), 1);
        // The evicted operand is gone master-side…
        assert!(c.submit_prepared(first, payloads(2, 1, 2), 2).is_err());
        // …and worker-side: even a forged entry submit can't reach it, but
        // the surviving operand still serves.
        let (got, _) = c.submit_prepared(second, payloads(2, 1, 2), 2).unwrap().wait().unwrap();
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|r| r.payload[..6] == [0xB; 6]));
        let (_, _, evictions) = c.prepared_stats();
        assert_eq!(evictions, 1);
        // Both prepares staged: 2 workers × 6 bytes × 2 operands.
        assert_eq!(c.counters().staged_upload_total(), 24);
        c.shutdown();
    }

    #[test]
    fn reconnect_restages_prepared_operands() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 33);
        let id = c.prepare(payloads(3, 0xCC, 8)).unwrap();
        assert_eq!(c.counters().staged_upload_total(), 24);
        c.disconnect_worker(1).unwrap();
        c.reconnect_worker(1, None).unwrap();
        // The revived link was re-staged (one more 8-byte half).
        assert_eq!(c.counters().staged_upload_total(), 32);
        let h = c.submit_prepared(id, payloads(3, 0xD, 4), 3).unwrap();
        let (got, _) = h.wait().unwrap();
        assert_eq!(got.len(), 3, "all shards — including the revived worker's — served");
        assert!(got.iter().all(|r| r.payload.len() == 12));
        c.shutdown();
    }

    #[test]
    fn speculative_copy_of_a_prepared_job_ships_the_full_share() {
        // Worker 0 drags its prepared shard; the speculative copy to worker
        // 1 must carry the re-assembled full share (worker 1's staged half
        // is its own, not shard 0's) and decode-identical bytes come back.
        let straggler = StragglerModel::fixed_slow([0], Duration::from_secs(2));
        let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 34);
        let mut cfg = ElasticConfig::speculative();
        cfg.tick = Duration::from_millis(2);
        cfg.spec_min_deadline = Duration::from_millis(30);
        c.set_elastic(cfg);
        let id = c.prepare(vec![vec![0xA0; 6], vec![0xA1; 6]]).unwrap();
        let h = c.submit_prepared(id, payloads(2, 0xB, 4), 2).unwrap();
        let job_counters = h.counters().clone();
        let (got, wait) = h.wait().unwrap();
        assert_eq!(got.len(), 2);
        assert!(wait < Duration::from_secs(1), "speculation did not beat the straggler");
        let shard0 = got.iter().find(|g| g.worker_id == 0).unwrap();
        assert_eq!(
            shard0.payload[..6],
            [0xA0; 6],
            "the spare computed shard 0 on shard 0's A-half, not its own"
        );
        assert_eq!(job_counters.speculative_total(), 1);
        // Upload: 2 B-halves (4 each) + one full speculative copy (6 + 4).
        assert_eq!(job_counters.upload_total(), 18);
        c.shutdown();
    }

    #[test]
    fn wait_surplus_collects_past_the_threshold() {
        let mut c = Coordinator::new(4, Arc::new(Echo), StragglerModel::None, 40);
        let h = c.submit(payloads(4, 0x5A, 6), 2).unwrap();
        assert_eq!(h.n_shards(), 4);
        let job_counters = h.counters().clone();
        let (got, _) = h.wait_surplus(Duration::from_secs(5)).unwrap();
        assert_eq!(got.len(), 4, "the grace drain collects every response");
        // Used-byte accounting is deferred to the verifying caller: the
        // bytes show as arrived until classified used/rejected.
        assert_eq!(job_counters.download_arrived_total(), 24);
        assert_eq!(job_counters.download_used_total(), 0);
        c.shutdown();
    }

    #[test]
    fn wait_surplus_ends_early_once_every_shard_is_resolved() {
        // Worker 3 fail-stops: its drop report resolves the shard, so the
        // drain must return 3 clean responses well before the grace expires.
        let straggler = StragglerModel::fail_stop([3]);
        let mut c = Coordinator::new(4, Arc::new(Echo), straggler, 41);
        let h = c.submit(payloads(4, 0x5B, 6), 2).unwrap();
        let start = Instant::now();
        let (got, _) = h.wait_surplus(Duration::from_secs(30)).unwrap();
        assert_eq!(got.len(), 3);
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "failure resolution must end the drain, not the 30s grace"
        );
        c.shutdown();
    }

    #[test]
    fn quarantined_worker_is_excluded_from_partial_placement() {
        // Worker 0 would drop the job (fail-stop); quarantining it steers
        // the single-shard submit to worker 1, so the job succeeds. Without
        // the quarantine the rank tie at Live would pick worker 0.
        let straggler = StragglerModel::fail_stop([0]);
        let mut c = Coordinator::new(2, Arc::new(Echo), straggler, 42);
        c.quarantine_worker(0);
        assert_eq!(c.worker_health(0), WorkerHealth::Quarantined);
        let (got, _) = c.submit(vec![vec![9u8; 4]], 1).unwrap().wait().unwrap();
        assert_eq!(got.len(), 1);
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let mut c = Coordinator::new(2, Arc::new(Echo), StragglerModel::None, 13);
        let (got, _) = c.submit(payloads(2, 1, 3), 2).unwrap().wait().unwrap();
        assert_eq!(got.len(), 2);
        c.shutdown_impl(); // internal: a consumed-by-shutdown coordinator can't be called
        let err = c.submit(payloads(2, 1, 3), 2).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }
}
