//! The master node / coordinator: owns the worker pool, dispatches encoded
//! shares, and collects the first `R` responses per job.

use super::straggler::StragglerModel;
use super::transport::{ByteCounters, FromWorker, ToWorker};
use super::worker::{spawn_worker, ShareCompute};
use crate::util::rng::Rng64;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One collected response.
#[derive(Debug)]
pub struct Collected {
    pub worker_id: usize,
    pub payload: Vec<u8>,
    pub compute: Duration,
    pub injected_delay: Duration,
}

/// The coordinator: a persistent pool of `N` worker threads plus the
/// master-side dispatch/collect logic.
pub struct Coordinator {
    n_workers: usize,
    senders: Vec<Sender<ToWorker>>,
    receiver: Receiver<FromWorker>,
    handles: Vec<JoinHandle<()>>,
    counters: ByteCounters,
    next_job: u64,
    /// Max wall time to wait for the recovery threshold per job.
    pub timeout: Duration,
}

impl Coordinator {
    /// Spawn `n_workers` workers applying `compute`, with straggler
    /// injection. `seed` derives the per-worker RNG streams.
    pub fn new(
        n_workers: usize,
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
    ) -> Self {
        let (resp_tx, resp_rx) = channel::<FromWorker>();
        let mut senders = Vec::with_capacity(n_workers);
        let mut handles = Vec::with_capacity(n_workers);
        let mut seeder = Rng64::seeded(seed);
        for wid in 0..n_workers {
            let (tx, rx) = channel::<ToWorker>();
            let handle = spawn_worker(
                wid,
                rx,
                resp_tx.clone(),
                Arc::clone(&compute),
                straggler.clone(),
                seeder.fork(),
            );
            senders.push(tx);
            handles.push(handle);
        }
        Coordinator {
            n_workers,
            senders,
            receiver: resp_rx,
            handles,
            counters: ByteCounters::new(),
            next_job: 0,
            timeout: Duration::from_secs(120),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn counters(&self) -> &ByteCounters {
        &self.counters
    }

    /// Dispatch one payload per worker and collect the first `need`
    /// successful responses (arrival order). Late/extra responses for this
    /// job are drained non-blockingly and counted as discarded download.
    ///
    /// Returns the responses and the dispatch→threshold wall time.
    pub fn submit_and_collect(
        &mut self,
        payloads: Vec<Vec<u8>>,
        need: usize,
    ) -> anyhow::Result<(Vec<Collected>, Duration)> {
        anyhow::ensure!(
            payloads.len() == self.n_workers,
            "need exactly one payload per worker ({} != {})",
            payloads.len(),
            self.n_workers
        );
        anyhow::ensure!(need <= self.n_workers, "need > n_workers");
        let job_id = self.next_job;
        self.next_job += 1;

        let t0 = Instant::now();
        for (tx, payload) in self.senders.iter().zip(payloads) {
            self.counters.add_upload(payload.len());
            tx.send(ToWorker::Job { job_id, payload })
                .map_err(|_| anyhow::anyhow!("worker hung up"))?;
        }

        let mut collected = Vec::with_capacity(need);
        while collected.len() < need {
            let remaining = self
                .timeout
                .checked_sub(t0.elapsed())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "timed out with {}/{need} responses (too many stragglers/failures?)",
                        collected.len()
                    )
                })?;
            match self.receiver.recv_timeout(remaining) {
                Ok(msg) => {
                    if msg.job_id != job_id {
                        // stale response from a previous job
                        if let Some(p) = msg.payload {
                            self.counters.add_download_discarded(p.len());
                        }
                        continue;
                    }
                    let Some(payload) = msg.payload else {
                        continue; // worker-side compute error: treat as straggler
                    };
                    self.counters.add_download_used(payload.len());
                    collected.push(Collected {
                        worker_id: msg.worker_id,
                        payload,
                        compute: msg.compute,
                        injected_delay: msg.injected_delay,
                    });
                }
                Err(RecvTimeoutError::Timeout) => {
                    anyhow::bail!(
                        "timed out with {}/{need} responses (too many stragglers/failures?)",
                        collected.len()
                    );
                }
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all workers disconnected");
                }
            }
        }
        let wait = t0.elapsed();

        // Drain any stragglers that already responded, without blocking.
        while let Ok(msg) = self.receiver.try_recv() {
            if let Some(p) = msg.payload {
                self.counters.add_download_discarded(p.len());
            }
        }
        Ok((collected, wait))
    }

    /// Graceful shutdown: signal and join every worker.
    pub fn shutdown(self) {
        for tx in &self.senders {
            let _ = tx.send(ToWorker::Shutdown);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo backend: replies with the payload itself.
    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<Vec<u8>> {
            Ok(payload.to_vec())
        }
    }

    #[test]
    fn collects_first_r() {
        let mut c = Coordinator::new(4, Arc::new(Echo), StragglerModel::None, 1);
        let payloads: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 10]).collect();
        let (got, _) = c.submit_and_collect(payloads, 3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(c.counters().upload_total(), 40);
        assert_eq!(c.counters().download_used_total(), 30);
        c.shutdown();
    }

    #[test]
    fn tolerates_fail_stop_up_to_n_minus_r() {
        let straggler = StragglerModel::fail_stop([0, 2]);
        let mut c = Coordinator::new(5, Arc::new(Echo), straggler, 2);
        let payloads: Vec<Vec<u8>> = (0..5).map(|_| vec![7u8; 4]).collect();
        let (got, _) = c.submit_and_collect(payloads, 3).unwrap();
        let ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        assert!(!ids.contains(&0) && !ids.contains(&2));
        c.shutdown();
    }

    #[test]
    fn times_out_when_too_many_fail() {
        let straggler = StragglerModel::fail_stop([0, 1, 2]);
        let mut c = Coordinator::new(4, Arc::new(Echo), straggler, 3);
        c.timeout = Duration::from_millis(200);
        let payloads: Vec<Vec<u8>> = (0..4).map(|_| vec![1u8]).collect();
        let err = c.submit_and_collect(payloads, 2).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        c.shutdown();
    }

    #[test]
    fn slow_workers_not_in_first_r() {
        let straggler = StragglerModel::fixed_slow([0], Duration::from_millis(300));
        let mut c = Coordinator::new(3, Arc::new(Echo), straggler, 4);
        let payloads: Vec<Vec<u8>> = (0..3).map(|_| vec![1u8; 8]).collect();
        let (got, wait) = c.submit_and_collect(payloads, 2).unwrap();
        let ids: Vec<usize> = got.iter().map(|g| g.worker_id).collect();
        assert!(!ids.contains(&0), "slow worker 0 should not be among first 2");
        assert!(wait < Duration::from_millis(250), "did not wait for the straggler");
        c.shutdown();
    }

    #[test]
    fn multiple_jobs_reuse_pool() {
        let mut c = Coordinator::new(3, Arc::new(Echo), StragglerModel::None, 5);
        for _ in 0..5 {
            let payloads: Vec<Vec<u8>> = (0..3).map(|_| vec![9u8; 2]).collect();
            let (got, _) = c.submit_and_collect(payloads, 3).unwrap();
            assert_eq!(got.len(), 3);
        }
        c.shutdown();
    }
}
