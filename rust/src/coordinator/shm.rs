//! [`ShmTransport`]: the same-host [`Transport`] — control frames ride a
//! TCP connection per worker (exactly the [`super::wire`] protocol the
//! socket transport speaks), but every large payload travels **out-of-line**
//! through a pair of file-backed ring buffers the master and the daemon
//! both map by path. On a same-host deployment this removes the kernel
//! socket copy from the data plane entirely: the payload is written once
//! into a shared ring slot and read once out of it, and the only thing
//! crossing the socket is a 64-byte doorbell frame.
//!
//! # Ring layout
//!
//! Each worker link owns two single-writer/single-reader rings in a shared
//! directory: `m2w-<id>.ring` (master → worker: job and stage payloads) and
//! `w2m-<id>.ring` (worker → master: response payloads). A ring file is a
//! 32-byte superblock followed by `n_slots` fixed-size slots:
//!
//! ```text
//! superblock   offset  size  field
//!                   0     4  magic      "GRSR"
//!                   4     4  version    currently 1
//!                   8     8  slot_size  payload capacity of one slot
//!                  16     8  n_slots    slot count
//!                  24     8  (reserved, zero)
//! slot k       offset  size  field
//!                   0     8  state      0 = free, 1 = full
//!                   8     8  seq        monotone payload sequence number
//!                  16     8  len        payload bytes in this slot
//!                  24     …  data       `slot_size` bytes of capacity
//! ```
//!
//! All integers are little-endian. Payload `seq` maps to slot `seq %
//! n_slots`; the writer spins (bounded) until the slot is `free`, writes
//! the data, publishes the `[full, seq, len]` header, and only then sends
//! the doorbell — a job-ref / stage-ref / response-ref control frame whose
//! 16-byte payload names `(seq, len)`. The TCP stream's ordering is the
//! fence: the reader never touches a slot before its doorbell arrives, and
//! it validates the slot header against the doorbell before trusting a
//! byte. After a successful read the reader marks the slot `free` again.
//!
//! # Contract parity
//!
//! Everything the coordinator relies on is inherited from the TCP
//! transport verbatim: per-worker FIFO (one ordered control stream), a
//! dead or rogue peer degrades to **fail-stop** (synthetic byte-free
//! reports for everything the link still owed — a truncated slot, a bad
//! ring magic, a seq/len mismatch, or a vanished peer all kill the link,
//! never hang it), the hello/stage-ack identity checks, and byte
//! accounting: [`Transport::send`] returns the payload bytes handed to the
//! link — the *same* serialized lengths the channel and TCP transports
//! count, so per-job [`super::transport::ByteCounters`] are identical
//! across all three transports for the same job stream (asserted in
//! `tests/integration_alloc.rs`).
//!
//! A payload larger than the ring's `slot_size` falls back to the inline
//! classic frame on the control stream — correctness never depends on the
//! ring geometry, only the fast path does. Zero-copy discipline: ring
//! reads lease their buffers from the process-wide
//! [`BytePool`](crate::util::bytepool::BytePool), so the steady state
//! allocates nothing (see `docs/ARCHITECTURE.md`, "Memory discipline").

use super::transport::{fail_report, FromWorker, LinkStatus, ToWorker, Transport};
use super::wire::{self, Frame, FrameKind, MAX_PAYLOAD};
use crate::util::bytepool::{BytePool, PooledBuf};
use std::collections::BTreeSet;
use std::fs::{File, OpenOptions};
use std::io::BufReader;
use std::net::{Shutdown as SockShutdown, TcpStream};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// `b"GRSR"` — the ring-file superblock magic.
pub const RING_MAGIC: [u8; 4] = *b"GRSR";

/// Ring-file layout version.
pub const RING_VERSION: u32 = 1;

/// Superblock length in bytes.
pub const SUPER_LEN: u64 = 32;

/// Per-slot header length in bytes (`state | seq | len`).
pub const SLOT_HEADER_LEN: u64 = 24;

const SLOT_FREE: u64 = 0;
const SLOT_FULL: u64 = 1;

/// Default payload capacity of one ring slot (4 MiB — comfortably above
/// the serialized share sizes the serving experiment ships).
pub const DEFAULT_SLOT_SIZE: u64 = 4 << 20;

/// Default slot count per ring. Eight slots of in-flight payloads per
/// direction is deeper than the coordinator's dispatch pipelining needs.
pub const DEFAULT_N_SLOTS: u64 = 8;

/// How long a writer waits for its target slot to come free before
/// declaring the peer stalled (fail-stop). A healthy reader frees a slot
/// within microseconds of its doorbell.
pub const SLOT_WAIT: Duration = Duration::from_secs(10);

/// One file-backed payload ring: a superblock plus `n_slots` fixed-size
/// slots, single writer and single reader (one per peer, one per
/// direction). See the module docs for the byte layout.
pub struct ShmRing {
    file: File,
    slot_size: u64,
    n_slots: u64,
    path: PathBuf,
}

impl ShmRing {
    /// Create (or truncate) the ring file at `path` with all slots free.
    pub fn create(path: impl Into<PathBuf>, slot_size: u64, n_slots: u64) -> anyhow::Result<ShmRing> {
        let path = path.into();
        anyhow::ensure!(slot_size > 0 && n_slots > 0, "ring needs nonzero slot_size and n_slots");
        anyhow::ensure!(
            slot_size <= MAX_PAYLOAD,
            "ring slot_size {slot_size} exceeds the {MAX_PAYLOAD}-byte payload limit"
        );
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("creating ring file {}: {e}", path.display()))?;
        // set_len zero-fills: every slot starts [free, 0, 0].
        file.set_len(SUPER_LEN + n_slots * (SLOT_HEADER_LEN + slot_size))?;
        let mut sb = [0u8; SUPER_LEN as usize];
        sb[0..4].copy_from_slice(&RING_MAGIC);
        sb[4..8].copy_from_slice(&RING_VERSION.to_le_bytes());
        sb[8..16].copy_from_slice(&slot_size.to_le_bytes());
        sb[16..24].copy_from_slice(&n_slots.to_le_bytes());
        file.write_all_at(&sb, 0)?;
        Ok(ShmRing { file, slot_size, n_slots, path })
    }

    /// Open an existing ring file, validating its superblock and size. Any
    /// mismatch — wrong magic, unknown version, impossible geometry, a
    /// truncated file — is a clean `Err` (the caller fail-stops the link).
    pub fn open(path: impl Into<PathBuf>) -> anyhow::Result<ShmRing> {
        let path = path.into();
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("opening ring file {}: {e}", path.display()))?;
        let mut sb = [0u8; SUPER_LEN as usize];
        file.read_exact_at(&mut sb, 0)
            .map_err(|e| anyhow::anyhow!("ring file {} superblock: {e}", path.display()))?;
        anyhow::ensure!(
            sb[0..4] == RING_MAGIC,
            "ring file {} has bad magic {:02x?} (expected {RING_MAGIC:02x?})",
            path.display(),
            &sb[0..4]
        );
        let version = u32::from_le_bytes(sb[4..8].try_into().unwrap());
        anyhow::ensure!(
            version == RING_VERSION,
            "ring file {} speaks version {version} (expected {RING_VERSION})",
            path.display()
        );
        let slot_size = u64::from_le_bytes(sb[8..16].try_into().unwrap());
        let n_slots = u64::from_le_bytes(sb[16..24].try_into().unwrap());
        anyhow::ensure!(
            slot_size > 0 && slot_size <= MAX_PAYLOAD && n_slots > 0,
            "ring file {} declares impossible geometry (slot_size {slot_size}, n_slots {n_slots})",
            path.display()
        );
        let expect = SUPER_LEN + n_slots * (SLOT_HEADER_LEN + slot_size);
        let actual = file.metadata()?.len();
        anyhow::ensure!(
            actual == expect,
            "ring file {} is {actual} bytes, geometry requires {expect} — truncated or corrupt",
            path.display()
        );
        Ok(ShmRing { file, slot_size, n_slots, path })
    }

    /// Payload capacity of one slot.
    pub fn slot_size(&self) -> u64 {
        self.slot_size
    }

    /// The backing file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn slot_offset(&self, seq: u64) -> u64 {
        SUPER_LEN + (seq % self.n_slots) * (SLOT_HEADER_LEN + self.slot_size)
    }

    /// Write `payload` into the slot for `seq`: wait (bounded) for the slot
    /// to come free, write the data, then publish the `[full, seq, len]`
    /// header. The caller sends the doorbell frame *after* this returns, so
    /// the reader can never observe a half-written slot.
    pub fn write_payload(&self, seq: u64, payload: &[u8], timeout: Duration) -> anyhow::Result<()> {
        anyhow::ensure!(
            payload.len() as u64 <= self.slot_size,
            "payload of {} bytes exceeds the ring's {}-byte slot size",
            payload.len(),
            self.slot_size
        );
        let off = self.slot_offset(seq);
        let deadline = Instant::now() + timeout;
        loop {
            let mut state = [0u8; 8];
            self.file.read_exact_at(&mut state, off)?;
            if u64::from_le_bytes(state) == SLOT_FREE {
                break;
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "ring slot for seq {seq} still occupied after {timeout:?} — peer stalled or dead"
            );
            std::thread::sleep(Duration::from_micros(200));
        }
        self.file.write_all_at(payload, off + SLOT_HEADER_LEN)?;
        let mut hdr = [0u8; SLOT_HEADER_LEN as usize];
        hdr[0..8].copy_from_slice(&SLOT_FULL.to_le_bytes());
        hdr[8..16].copy_from_slice(&seq.to_le_bytes());
        hdr[16..24].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        self.file.write_all_at(&hdr, off)?;
        Ok(())
    }

    /// Read and free the slot a doorbell referenced, validating the slot
    /// header against the doorbell's `(seq, len)` first: a not-full slot, a
    /// sequence mismatch (a reused or truncated slot), or a length mismatch
    /// all err — the caller treats it as a rogue peer. The payload buffer
    /// is leased from the process-wide pool.
    pub fn read_payload(&self, seq: u64, len: u64) -> anyhow::Result<PooledBuf> {
        anyhow::ensure!(
            len <= self.slot_size,
            "doorbell references {len} bytes, beyond the ring's {}-byte slot size",
            self.slot_size
        );
        let off = self.slot_offset(seq);
        let mut hdr = [0u8; SLOT_HEADER_LEN as usize];
        self.file.read_exact_at(&mut hdr, off)?;
        let state = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
        let got_seq = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        let got_len = u64::from_le_bytes(hdr[16..24].try_into().unwrap());
        anyhow::ensure!(
            state == SLOT_FULL,
            "ring slot for seq {seq} is not full (state {state}) — truncated or never-written slot"
        );
        anyhow::ensure!(
            got_seq == seq,
            "ring slot holds seq {got_seq} but the doorbell referenced seq {seq}"
        );
        anyhow::ensure!(
            got_len == len,
            "ring slot holds {got_len} bytes but the doorbell referenced {len}"
        );
        let mut lease = BytePool::global().lease(len as usize);
        lease.resize(len as usize, 0);
        self.file.read_exact_at(&mut lease, off + SLOT_HEADER_LEN)?;
        // Release the slot for the writer's next lap.
        self.file.write_all_at(&SLOT_FREE.to_le_bytes(), off)?;
        Ok(lease.freeze())
    }
}

/// The master-side ring paths for worker `id` under `dir`.
pub fn ring_paths(dir: &Path, worker_id: usize) -> (PathBuf, PathBuf) {
    (
        dir.join(format!("m2w-{worker_id}.ring")),
        dir.join(format!("w2m-{worker_id}.ring")),
    )
}

/// A fresh, unique directory under the system temp dir for a set of ring
/// files — what the serving experiment's `shm` loopback mode and the tests
/// use so concurrent runs never collide.
pub fn unique_ring_dir(tag: &str) -> std::io::Result<PathBuf> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "gr-cdmm-shm-{tag}-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Writer/reader-shared per-link state — same shape and discipline as the
/// TCP transport's: whoever observes the death (reader *or* writer) flips
/// `alive` and drains `pending` into synthetic fail-stop reports under the
/// same lock, so every dispatched copy is reported exactly once.
struct ConnState {
    alive: bool,
    pending: BTreeSet<(u64, u64)>,
    last_heard: Option<Instant>,
    ping_sent: Option<(u64, Instant)>,
    last_rtt: Option<Duration>,
}

impl ConnState {
    fn fresh() -> ConnState {
        ConnState {
            alive: true,
            pending: BTreeSet::new(),
            last_heard: None,
            ping_sent: None,
            last_rtt: None,
        }
    }
}

type SharedState = Arc<Mutex<ConnState>>;

/// One worker slot: the control socket, its reader thread (which owns the
/// worker→master ring), the master→worker ring, and the endpoint to
/// re-dial on reconnect.
struct ShmConn {
    stream: TcpStream,
    state: SharedState,
    reader: Option<JoinHandle<()>>,
    endpoint: String,
    /// Master → worker payload ring (job shares and staged halves).
    m2w: ShmRing,
    /// Next m2w payload sequence number.
    next_seq: u64,
}

fn drain_dead(state: &SharedState) -> BTreeSet<(u64, u64)> {
    let mut st = state.lock().unwrap();
    st.alive = false;
    std::mem::take(&mut st.pending)
}

/// The control-stream reader. Identical to the TCP reader except that a
/// response-ref frame resolves its payload out of the worker→master ring
/// (with full slot validation) before entering the same
/// unsolicited-response gate, and a ring violation is one more way a peer
/// turns rogue.
fn spawn_reader(
    worker_id: usize,
    stream: TcpStream,
    state: SharedState,
    funnel: Sender<FromWorker>,
    peer: String,
    w2m: ShmRing,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("gr-cdmm-shm-reader-{worker_id}"))
        .spawn(move || {
            let mut reader = BufReader::new(stream);
            loop {
                let frame = match wire::read_frame(&mut reader) {
                    Ok(Some(frame)) => frame,
                    Ok(None) => break, // clean close
                    Err(e) => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) link broke: {e}; \
                             treating it as fail-stopped"
                        );
                        break;
                    }
                };
                match frame.kind {
                    FrameKind::RespOk | FrameKind::RespFail | FrameKind::RespRef => {
                        let msg = if frame.kind == FrameKind::RespRef {
                            // Resolve the out-of-line payload. Any ring
                            // violation — bad descriptor, truncated or
                            // mismatched slot — is a rogue peer.
                            let resolved = frame
                                .ref_slot()
                                .and_then(|(seq, len)| w2m.read_payload(seq, len));
                            let payload = match resolved {
                                Ok(p) => p,
                                Err(e) => {
                                    eprintln!(
                                        "gr-cdmm: worker {worker_id} ({peer}) sent a bad \
                                         ring reference ({e}); treating the link as rogue \
                                         (fail-stopped)"
                                    );
                                    break;
                                }
                            };
                            match usize::try_from(frame.worker_id) {
                                Ok(shard) => FromWorker {
                                    job_id: frame.job_id,
                                    worker_id: shard,
                                    payload: Some(payload),
                                    compute: Duration::from_micros(frame.compute_us),
                                    injected_delay: Duration::from_micros(frame.delay_us),
                                },
                                Err(_) => break,
                            }
                        } else {
                            match frame.into_report() {
                                Ok(msg) => msg,
                                Err(e) => {
                                    eprintln!(
                                        "gr-cdmm: worker {worker_id} ({peer}) sent a \
                                         malformed response ({e}); treating it as \
                                         fail-stopped"
                                    );
                                    break;
                                }
                            }
                        };
                        // Same gate as TCP: a response is only valid if
                        // this link actually owes that (job, shard).
                        let key = (msg.job_id, msg.worker_id as u64);
                        {
                            let mut st = state.lock().unwrap();
                            if !st.pending.remove(&key) {
                                drop(st);
                                eprintln!(
                                    "gr-cdmm: worker {worker_id} ({peer}) sent an \
                                     unsolicited response for job {} shard {}; treating \
                                     the link as rogue (fail-stopped)",
                                    msg.job_id, msg.worker_id
                                );
                                break;
                            }
                            st.last_heard = Some(Instant::now());
                        }
                        if funnel.send(msg).is_err() {
                            break; // coordinator gone
                        }
                    }
                    FrameKind::Pong => {
                        let mut st = state.lock().unwrap();
                        st.last_heard = Some(Instant::now());
                        if let Some((nonce, sent)) = st.ping_sent {
                            if nonce == frame.job_id {
                                st.last_rtt = Some(sent.elapsed());
                                st.ping_sent = None;
                            }
                        }
                    }
                    FrameKind::Hello | FrameKind::StageAck => {
                        if frame.worker_id != worker_id as u64 {
                            eprintln!(
                                "gr-cdmm: peer at {peer} answered as worker {} but is \
                                 connected as worker {worker_id}; rejecting the link as \
                                 rogue (fail-stopped)",
                                frame.worker_id
                            );
                            break;
                        }
                        state.lock().unwrap().last_heard = Some(Instant::now());
                    }
                    FrameKind::Goodbye => break, // graceful leave
                    FrameKind::Job
                    | FrameKind::Shutdown
                    | FrameKind::Ping
                    | FrameKind::Stage
                    | FrameKind::Evict
                    | FrameKind::JobRef
                    | FrameKind::StageRef => {
                        eprintln!(
                            "gr-cdmm: worker {worker_id} ({peer}) sent an unexpected \
                             {:?} frame; treating it as fail-stopped",
                            frame.kind
                        );
                        break;
                    }
                }
            }
            for (job_id, shard) in drain_dead(&state) {
                if funnel.send(fail_report(job_id, shard as usize)).is_err() {
                    break;
                }
            }
        })
        .expect("failed to spawn shm reader thread")
}

/// Wrap an accepted control stream into a live worker slot: create both
/// rings fresh (so the peer can never read a previous session's slots),
/// spawn the reader, and send the hello. Ring creation happens *before*
/// the hello goes out — the hello is the daemon's cue to open the rings,
/// and TCP ordering guarantees the files exist by then.
fn open_link(
    worker_id: usize,
    endpoint: String,
    stream: TcpStream,
    dir: &Path,
    slot_size: u64,
    n_slots: u64,
    funnel: &Sender<FromWorker>,
) -> anyhow::Result<ShmConn> {
    stream.set_nodelay(true)?;
    let (m2w_path, w2m_path) = ring_paths(dir, worker_id);
    let m2w = ShmRing::create(m2w_path, slot_size, n_slots)?;
    let w2m = ShmRing::create(w2m_path, slot_size, n_slots)?;
    let state: SharedState = Arc::new(Mutex::new(ConnState::fresh()));
    let reader = spawn_reader(
        worker_id,
        stream.try_clone()?,
        Arc::clone(&state),
        funnel.clone(),
        endpoint.clone(),
        w2m,
    );
    let _ = wire::write_frame(&mut &stream, &Frame::hello(worker_id));
    Ok(ShmConn { stream, state, reader: Some(reader), endpoint, m2w, next_seq: 0 })
}

/// The shared-memory transport. Build with [`ShmTransport::connect`];
/// endpoint `i` in the list is worker `i`, and `dir` is the ring directory
/// both sides must agree on (the daemons' [`super::daemon::DaemonConfig`]
/// `shm_dir`).
pub struct ShmTransport {
    conns: Vec<ShmConn>,
    dir: PathBuf,
    slot_size: u64,
    n_slots: u64,
    funnel: Option<Sender<FromWorker>>,
    rx: Option<Receiver<FromWorker>>,
    shut: bool,
}

impl ShmTransport {
    /// Connect with the default ring geometry ([`DEFAULT_SLOT_SIZE`],
    /// [`DEFAULT_N_SLOTS`]).
    pub fn connect(endpoints: &[String], dir: impl Into<PathBuf>) -> anyhow::Result<ShmTransport> {
        Self::connect_with(endpoints, dir, DEFAULT_SLOT_SIZE, DEFAULT_N_SLOTS)
    }

    /// Connect with explicit ring geometry (tests shrink `slot_size` to
    /// exercise the inline-fallback path).
    pub fn connect_with(
        endpoints: &[String],
        dir: impl Into<PathBuf>,
        slot_size: u64,
        n_slots: u64,
    ) -> anyhow::Result<ShmTransport> {
        anyhow::ensure!(!endpoints.is_empty(), "need at least one worker endpoint");
        let dir: PathBuf = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("creating ring directory {}: {e}", dir.display()))?;
        let mut streams = Vec::with_capacity(endpoints.len());
        for addr in endpoints {
            streams.push(super::tcp::connect_retry(addr)?);
        }
        let (funnel_tx, rx) = channel::<FromWorker>();
        let mut conns = Vec::with_capacity(endpoints.len());
        for (wid, (stream, addr)) in streams.into_iter().zip(endpoints).enumerate() {
            conns.push(open_link(wid, addr.clone(), stream, &dir, slot_size, n_slots, &funnel_tx)?);
        }
        Ok(ShmTransport {
            conns,
            dir,
            slot_size,
            n_slots,
            funnel: Some(funnel_tx),
            rx: Some(rx),
            shut: false,
        })
    }

    fn synthesize_fail(&self, shard: usize, job_id: u64) {
        if let Some(tx) = &self.funnel {
            let _ = tx.send(fail_report(job_id, shard));
        }
    }

    fn kill_link(&mut self, worker_id: usize) {
        let _ = self.conns[worker_id].stream.shutdown(SockShutdown::Both);
        for (job, shard) in drain_dead(&self.conns[worker_id].state) {
            self.synthesize_fail(shard as usize, job);
        }
    }

    /// Ship one payload out-of-line: ring write, then the doorbell frame
    /// built by `doorbell(seq, len)`. Falls back to the inline frame from
    /// `inline()` when the payload exceeds the slot size. `Err` means the
    /// link died (the caller kills it).
    fn send_payload(
        conn: &mut ShmConn,
        payload: &PooledBuf,
        doorbell: impl FnOnce(u64, u64) -> Frame,
        inline: impl FnOnce() -> Frame,
    ) -> anyhow::Result<()> {
        if payload.len() as u64 <= conn.m2w.slot_size() {
            let seq = conn.next_seq;
            conn.m2w.write_payload(seq, payload, SLOT_WAIT)?;
            wire::write_frame(&mut &conn.stream, &doorbell(seq, payload.len() as u64))?;
            conn.next_seq += 1;
        } else {
            // Oversize for the ring geometry: the classic inline frame is
            // always correct, just not zero-copy on the socket.
            wire::write_frame(&mut &conn.stream, &inline())?;
        }
        Ok(())
    }
}

impl Transport for ShmTransport {
    fn n_workers(&self) -> usize {
        self.conns.len()
    }

    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
        anyhow::ensure!(worker_id < self.conns.len(), "worker id {worker_id} out of range");
        match msg {
            ToWorker::Shutdown => {
                if self.conns[worker_id].state.lock().unwrap().alive {
                    let _ =
                        wire::write_frame(&mut &self.conns[worker_id].stream, &Frame::shutdown());
                }
                Ok(0)
            }
            ToWorker::Ping { nonce, .. } => {
                {
                    let mut st = self.conns[worker_id].state.lock().unwrap();
                    if !st.alive {
                        return Ok(0);
                    }
                    st.ping_sent = Some((nonce, Instant::now()));
                }
                if wire::write_frame(&mut &self.conns[worker_id].stream, &Frame::ping(nonce))
                    .is_err()
                {
                    self.kill_link(worker_id);
                }
                Ok(0)
            }
            ToWorker::Evict { prepared_id } => {
                if !self.conns[worker_id].state.lock().unwrap().alive {
                    return Ok(0);
                }
                if wire::write_frame(
                    &mut &self.conns[worker_id].stream,
                    &Frame::evict(prepared_id),
                )
                .is_err()
                {
                    self.kill_link(worker_id);
                }
                Ok(0)
            }
            ToWorker::Stage { prepared_id, payload } => {
                if !self.conns[worker_id].state.lock().unwrap().alive {
                    return Ok(0);
                }
                let len = payload.len();
                let sent = Self::send_payload(
                    &mut self.conns[worker_id],
                    &payload,
                    |seq, n| Frame::stage_ref(prepared_id, seq, n),
                    || Frame::stage(prepared_id, payload.clone()),
                );
                if sent.is_err() {
                    self.kill_link(worker_id);
                    return Ok(0);
                }
                Ok(len)
            }
            ToWorker::Job { job_id, shard, prepared, payload } => {
                {
                    let mut st = self.conns[worker_id].state.lock().unwrap();
                    if !st.alive {
                        drop(st);
                        self.synthesize_fail(shard, job_id);
                        return Ok(0);
                    }
                    st.pending.insert((job_id, shard as u64));
                }
                let len = payload.len();
                let sent = Self::send_payload(
                    &mut self.conns[worker_id],
                    &payload,
                    |seq, n| Frame::job_ref(job_id, shard, prepared, seq, n),
                    || {
                        let mut f = Frame::job(job_id, shard, payload.clone());
                        f.compute_us = prepared.map_or(0, |p| p + 1);
                        f
                    },
                );
                if sent.is_err() {
                    self.kill_link(worker_id);
                    return Ok(0);
                }
                Ok(len)
            }
        }
    }

    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
        self.rx.take()
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        for conn in &self.conns {
            if conn.state.lock().unwrap().alive {
                let _ = wire::write_frame(&mut &conn.stream, &Frame::shutdown());
            }
            let _ = conn.stream.shutdown(SockShutdown::Write);
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        for conn in &mut self.conns {
            let Some(h) = conn.reader.take() else { continue };
            while !h.is_finished() && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            if !h.is_finished() {
                let _ = conn.stream.shutdown(SockShutdown::Both);
            }
            let _ = h.join();
        }
        // Best-effort ring cleanup: the transport created the files, so it
        // removes them. (The directory is the caller's.)
        for wid in 0..self.conns.len() {
            let (m2w, w2m) = ring_paths(&self.dir, wid);
            let _ = std::fs::remove_file(m2w);
            let _ = std::fs::remove_file(w2m);
        }
        self.funnel = None;
    }

    fn name(&self) -> &'static str {
        "shm"
    }

    fn link_status(&self, worker_id: usize) -> LinkStatus {
        match self.conns.get(worker_id) {
            Some(conn) => {
                let st = conn.state.lock().unwrap();
                LinkStatus {
                    alive: st.alive,
                    idle: st.last_heard.map(|t| t.elapsed()),
                    last_rtt: st.last_rtt,
                }
            }
            None => LinkStatus { alive: false, idle: None, last_rtt: None },
        }
    }

    fn ping(&mut self, worker_id: usize, nonce: u64) -> anyhow::Result<()> {
        self.send(worker_id, ToWorker::Ping { nonce, sent: Instant::now() })?;
        Ok(())
    }

    fn disconnect_worker(&mut self, worker_id: usize) -> anyhow::Result<()> {
        anyhow::ensure!(worker_id < self.conns.len(), "worker id {worker_id} out of range");
        self.kill_link(worker_id);
        if let Some(h) = self.conns[worker_id].reader.take() {
            let _ = h.join();
        }
        Ok(())
    }

    fn reconnect_worker(&mut self, worker_id: usize, endpoint: Option<&str>) -> anyhow::Result<()> {
        anyhow::ensure!(!self.shut, "transport is shut down");
        anyhow::ensure!(worker_id < self.conns.len(), "worker id {worker_id} out of range");
        let funnel = self
            .funnel
            .clone()
            .ok_or_else(|| anyhow::anyhow!("transport is shutting down"))?;
        if let Some(ep) = endpoint {
            self.conns[worker_id].endpoint = ep.to_string();
        }
        anyhow::ensure!(
            !self.conns[worker_id].state.lock().unwrap().alive,
            "worker {worker_id} link is still alive"
        );
        if let Some(h) = self.conns[worker_id].reader.take() {
            let _ = h.join();
        }
        let addr = self.conns[worker_id].endpoint.clone();
        let stream = TcpStream::connect(&addr)
            .map_err(|e| anyhow::anyhow!("re-dialing worker {worker_id} at {addr}: {e}"))?;
        // open_link recreates both rings, so the fresh connection starts
        // from seq 0 on zeroed slots — stale payloads can never replay.
        self.conns[worker_id] =
            open_link(worker_id, addr, stream, &self.dir, self.slot_size, self.n_slots, &funnel)?;
        Ok(())
    }

    fn add_worker(&mut self, endpoint: Option<&str>) -> anyhow::Result<usize> {
        anyhow::ensure!(!self.shut, "transport is shut down");
        let addr = endpoint
            .ok_or_else(|| anyhow::anyhow!("shm add_worker needs a host:port endpoint"))?;
        let funnel = self
            .funnel
            .clone()
            .ok_or_else(|| anyhow::anyhow!("transport is shutting down"))?;
        let wid = self.conns.len();
        let stream = super::tcp::connect_retry(addr)?;
        self.conns.push(open_link(
            wid,
            addr.to_string(),
            stream,
            &self.dir,
            self.slot_size,
            self.n_slots,
            &funnel,
        )?);
        Ok(wid)
    }
}

impl Drop for ShmTransport {
    fn drop(&mut self) {
        Transport::shutdown(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::daemon::{DaemonConfig, WorkerDaemon};
    use crate::coordinator::straggler::StragglerModel;
    use crate::coordinator::worker::ShareCompute;
    use std::time::Instant;

    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<PooledBuf> {
            Ok(payload.to_vec().into())
        }
    }

    fn shm_daemon(dir: &Path, conns: usize) -> WorkerDaemon {
        let cfg = DaemonConfig { shm_dir: Some(dir.to_path_buf()), ..DaemonConfig::default() };
        WorkerDaemon::spawn_local_cfg(std::sync::Arc::new(Echo), cfg, conns).unwrap()
    }

    #[test]
    fn ring_roundtrips_and_wraps() {
        let dir = unique_ring_dir("ring-rt").unwrap();
        let path = dir.join("t.ring");
        let ring = ShmRing::create(&path, 64, 4).unwrap();
        // more laps than slots: every seq maps to seq % 4 and frees cleanly
        for seq in 0..13u64 {
            let payload = vec![seq as u8; 1 + (seq as usize % 60)];
            ring.write_payload(seq, &payload, SLOT_WAIT).unwrap();
            let back = ring.read_payload(seq, payload.len() as u64).unwrap();
            assert_eq!(back, payload);
        }
        // a reader on a second handle sees the same geometry
        let other = ShmRing::open(&path).unwrap();
        assert_eq!(other.slot_size(), 64);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ring_rejects_oversize_and_unwritten_slots() {
        let dir = unique_ring_dir("ring-guard").unwrap();
        let ring = ShmRing::create(dir.join("t.ring"), 32, 2).unwrap();
        assert!(ring.write_payload(0, &[0u8; 33], SLOT_WAIT).is_err(), "oversize payload");
        let err = ring.read_payload(0, 8).unwrap_err().to_string();
        assert!(err.contains("not full"), "{err}");
        let err = ring.read_payload(0, 64).unwrap_err().to_string();
        assert!(err.contains("slot size"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ring_validates_doorbell_against_slot_header() {
        let dir = unique_ring_dir("ring-val").unwrap();
        let ring = ShmRing::create(dir.join("t.ring"), 64, 4).unwrap();
        ring.write_payload(1, &[7u8; 16], SLOT_WAIT).unwrap();
        // wrong seq for the same slot (5 % 4 == 1)
        let err = ring.read_payload(5, 16).unwrap_err().to_string();
        assert!(err.contains("seq"), "{err}");
        // wrong length
        let err = ring.read_payload(1, 15).unwrap_err().to_string();
        assert!(err.contains("bytes"), "{err}");
        // the honest doorbell still works afterwards
        assert_eq!(ring.read_payload(1, 16).unwrap(), vec![7u8; 16]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ring_open_rejects_bad_magic_version_and_truncation() {
        let dir = unique_ring_dir("ring-open").unwrap();
        let path = dir.join("t.ring");
        ShmRing::create(&path, 64, 2).unwrap();

        let good = std::fs::read(&path).unwrap();
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        std::fs::write(&path, &bad_magic).unwrap();
        assert!(ShmRing::open(&path).unwrap_err().to_string().contains("magic"));

        let mut bad_version = good.clone();
        bad_version[4] = 0x7F;
        std::fs::write(&path, &bad_version).unwrap();
        assert!(ShmRing::open(&path).unwrap_err().to_string().contains("version"));

        std::fs::write(&path, &good[..good.len() - 1]).unwrap();
        assert!(ShmRing::open(&path).unwrap_err().to_string().contains("truncated"));

        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shm_transport_round_trips_jobs_out_of_line() {
        let dir = unique_ring_dir("rt").unwrap();
        let daemon = shm_daemon(&dir, 1);
        let mut t = ShmTransport::connect(&[daemon.addr()], &dir).unwrap();
        let rx = t.take_receiver().unwrap();
        let payload = vec![0x5Au8; 8192];
        let sent = t
            .send(
                0,
                ToWorker::Job { job_id: 9, shard: 0, prepared: None, payload: payload.clone().into() },
            )
            .unwrap();
        assert_eq!(sent, payload.len(), "send reports the payload bytes, like tcp");
        let msg = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (9, 0));
        assert_eq!(msg.payload.unwrap(), payload);
        t.shutdown();
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn oversize_payloads_fall_back_to_inline_frames() {
        let dir = unique_ring_dir("oversize").unwrap();
        let daemon = shm_daemon(&dir, 1);
        // 64-byte slots: a 200-byte share must travel inline
        let mut t = ShmTransport::connect_with(&[daemon.addr()], &dir, 64, 2).unwrap();
        let rx = t.take_receiver().unwrap();
        let payload = vec![0xA1u8; 200];
        let sent = t
            .send(
                0,
                ToWorker::Job { job_id: 1, shard: 0, prepared: None, payload: payload.clone().into() },
            )
            .unwrap();
        assert_eq!(sent, payload.len());
        let msg = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        // the 200-byte echo also exceeds the slot, so the daemon's reply
        // came back inline too — both fallbacks in one round trip
        assert_eq!(msg.payload.unwrap(), payload);
        t.shutdown();
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn staged_operands_travel_through_the_ring() {
        let dir = unique_ring_dir("stage").unwrap();
        let daemon = shm_daemon(&dir, 1);
        let mut t = ShmTransport::connect(&[daemon.addr()], &dir).unwrap();
        let rx = t.take_receiver().unwrap();
        t.send(0, ToWorker::Stage { prepared_id: 3, payload: vec![0xA, 0xB].into() }).unwrap();
        t.send(
            0,
            ToWorker::Job { job_id: 4, shard: 0, prepared: Some(3), payload: vec![0xC, 0xD].into() },
        )
        .unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(
            msg.payload.unwrap(),
            vec![0xA, 0xB, 0xC, 0xD],
            "daemon computed on staged ++ payload, reassembled from ring slots"
        );
        t.shutdown();
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dead_peer_fail_stops_pending_jobs() {
        let dir = unique_ring_dir("dead").unwrap();
        // daemon serves zero further connections after the first, which we
        // use up and let die immediately by dropping a raw connection
        let daemon = shm_daemon(&dir, 1);
        let mut t = ShmTransport::connect(&[daemon.addr()], &dir).unwrap();
        let rx = t.take_receiver().unwrap();
        // kill the link from our side, then submit: the job must fail-stop
        t.disconnect_worker(0).unwrap();
        t.send(0, ToWorker::Job { job_id: 5, shard: 0, prepared: None, payload: vec![1u8; 8].into() })
            .unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (5, 0));
        assert!(msg.payload.is_none(), "dead link reports byte-free fail-stop");
        t.shutdown();
        let _ = daemon.join();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rogue_ring_reference_kills_the_link() {
        // A daemon-side stand-in: accept the control connection, then send
        // a response-ref naming a slot that was never written. The master's
        // reader must fail-stop the link, not hang or panic.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let dir = unique_ring_dir("rogue").unwrap();
        let dir2 = dir.clone();
        let rogue = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            // consume the hello, echo it honestly
            let hello = wire::read_frame(&mut reader).unwrap().unwrap();
            assert_eq!(hello.kind, FrameKind::Hello);
            wire::write_frame(&mut &stream, &Frame::hello(0)).unwrap();
            // read the job-ref doorbell, then answer with a reference to a
            // never-written slot
            let job = wire::read_frame(&mut reader).unwrap().unwrap();
            assert_eq!(job.kind, FrameKind::JobRef);
            let _ = ShmRing::open(dir2.join("w2m-0.ring")).unwrap();
            wire::write_frame(
                &mut &stream,
                &Frame::resp_ref(job.job_id, 0, Duration::ZERO, Duration::ZERO, 7, 16),
            )
            .unwrap();
            // hold the socket open until the master kills it
            let _ = wire::read_frame(&mut reader);
        });
        let mut t = ShmTransport::connect(&[addr], &dir).unwrap();
        let rx = t.take_receiver().unwrap();
        t.send(0, ToWorker::Job { job_id: 8, shard: 0, prepared: None, payload: vec![2u8; 32].into() })
            .unwrap();
        let msg = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (8, 0));
        assert!(msg.payload.is_none(), "bad ring reference degrades to fail-stop");
        assert!(!t.link_status(0).alive, "the rogue link is dead");
        t.shutdown();
        rogue.join().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ping_pong_and_reconnect_work_over_shm() {
        let dir = unique_ring_dir("elastic").unwrap();
        let daemon = shm_daemon(&dir, 2);
        let mut t = ShmTransport::connect(&[daemon.addr()], &dir).unwrap();
        let _rx = t.take_receiver().unwrap();
        t.ping(0, 77).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while t.link_status(0).last_rtt.is_none() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(t.link_status(0).last_rtt.is_some(), "pong answered over the control stream");
        t.disconnect_worker(0).unwrap();
        assert!(!t.link_status(0).alive);
        t.reconnect_worker(0, None).unwrap();
        assert!(t.link_status(0).alive);
        t.shutdown();
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
