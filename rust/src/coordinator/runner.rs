//! Glue between the coding layer and the coordinator: runs one job (single
//! or batch — the unified [`DmmScheme`] covers both) end-to-end and produces
//! the full [`JobMetrics`] breakdown.
//!
//! There is exactly **one** native worker backend, [`NativeCompute`]: it
//! holds an erased [`DynScheme`] and forwards the serialized share payload
//! to [`DynScheme::compute_bytes`] — deserialize the plane-major share
//! (one block copy for `Zq` planes), multiply plane-by-plane with the base
//! ring's contiguous kernel on `GR_CDMM_THREADS` scoped threads (row-panel
//! parallel, bit-identical to sequential — see [`crate::util::parallel`]),
//! serialize the plane-major response. Malformed payloads surface as job
//! failures (the worker loop reports `Err` as a dropped response), never as
//! a panic unwinding the pool thread.

use super::master::Coordinator;
use super::metrics::JobMetrics;
use super::straggler::StragglerModel;
use super::worker::ShareCompute;
use crate::codes::scheme::{DmmScheme, DynScheme, Erased, Response};
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;
use crate::ring::traits::Ring;
use crate::util::bytepool::{large_allocs, BytePool, PooledBuf};
use crate::util::rng::Rng64;
use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use super::worker::ShareCompute as ShareComputeTrait;

/// Build the coordinator either way the CLI can ask for one: spawn an
/// in-process pool (`endpoints = None`; `backend`/`straggler`/`seed` apply
/// there), or connect to already-running `gr-cdmm worker` daemons
/// (`endpoints = Some(..)` — the daemons own the compute backend and
/// straggler injection in that case, so those arguments are ignored by
/// design). At least `n_workers` endpoints are required; extras join the
/// pool as spare capacity for health-ranked placement and speculative
/// re-dispatch.
pub fn make_coordinator(
    n_workers: usize,
    backend: Arc<dyn ShareCompute>,
    straggler: StragglerModel,
    seed: u64,
    endpoints: Option<&[String]>,
) -> anyhow::Result<Coordinator> {
    match endpoints {
        None => Ok(Coordinator::new(n_workers, backend, straggler, seed)),
        Some(addrs) => {
            anyhow::ensure!(
                addrs.len() >= n_workers,
                "--connect lists {} endpoint(s) but the scheme needs N = {n_workers} workers \
                 (pick a smaller preset with SchemeConfig::for_live_workers, or add daemons)",
                addrs.len()
            );
            Coordinator::connect_tcp(addrs)
        }
    }
}

/// The native worker backend: an erased scheme applied to byte payloads.
pub struct NativeCompute {
    scheme: Arc<dyn DynScheme>,
}

impl NativeCompute {
    /// Wrap an already-erased scheme (e.g. from
    /// [`crate::codes::registry::build`]).
    pub fn new(scheme: Arc<dyn DynScheme>) -> Self {
        NativeCompute { scheme }
    }

    /// Convenience: erase a typed scheme and wrap it.
    pub fn for_scheme<R, S>(scheme: Arc<S>) -> Self
    where
        R: Ring,
        S: DmmScheme<R> + 'static,
    {
        NativeCompute { scheme: Arc::new(Erased::new(scheme)) }
    }
}

impl ShareCompute for NativeCompute {
    fn compute(&self, _worker_id: usize, payload: &[u8]) -> anyhow::Result<PooledBuf> {
        self.scheme.compute_bytes(payload)
    }

    fn backend_name(&self) -> String {
        format!("native:{}", self.scheme.name())
    }
}

/// Snapshot of the global byte pool's counters at job start, for the
/// per-job deltas [`JobMetrics`] reports. Overlapping jobs share the
/// process-wide pool, so a delta attributes *everything* that happened
/// during the job's window — exact for the sequential serving loops that
/// consume these metrics, an upper bound under concurrent submission.
struct PoolProbe {
    hits: u64,
    misses: u64,
    allocs: u64,
}

impl PoolProbe {
    fn start() -> PoolProbe {
        let s = BytePool::global().stats();
        PoolProbe { hits: s.hits, misses: s.misses, allocs: large_allocs() }
    }

    /// `(pool_hits, pool_misses, large_allocs)` since [`PoolProbe::start`].
    fn delta(&self) -> (u64, u64, u64) {
        let s = BytePool::global().stats();
        (
            s.hits.saturating_sub(self.hits),
            s.misses.saturating_sub(self.misses),
            large_allocs().saturating_sub(self.allocs),
        )
    }
}

fn job_metrics(
    encode: std::time::Duration,
    decode: std::time::Duration,
    wait_for_r: std::time::Duration,
    total: std::time::Duration,
    counters: &super::transport::ByteCounters,
    collected: &[super::master::Collected],
) -> JobMetrics {
    JobMetrics {
        encode,
        decode,
        wait_for_r,
        total,
        upload_bytes: counters.upload_total(),
        download_bytes: counters.download_used_total(),
        speculative_dispatches: counters.speculative_total(),
        worker_compute: collected.iter().map(|c| c.compute).collect(),
        worker_delay: collected.iter().map(|c| c.injected_delay).collect(),
        used_workers: collected.iter().map(|c| c.worker_id).collect(),
        // job_id and plan-cache deltas are filled in by the caller
        ..JobMetrics::default()
    }
}

/// Run one job through the erased byte facade: serialize the inputs in
/// `ring`'s canonical format, encode, dispatch, collect the first `R`
/// responses, decode. This is the path `main.rs` and `experiments/` use —
/// scheme selection stays a string, no per-scheme monomorphization.
pub fn run_erased<R: Ring>(
    ring: &R,
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    a: &[Matrix<R::Elem>],
    b: &[Matrix<R::Elem>],
) -> anyhow::Result<(Vec<Matrix<R::Elem>>, JobMetrics)> {
    let t_total = Instant::now();
    let probe = PoolProbe::start();

    // Crossing the byte facade (serialize here, deserialize inside
    // `encode_bytes`) happens OUTSIDE the timed encode window, so the
    // reported `encode` stays comparable to the typed `run_batch` path up to
    // one linear input pass inside the facade (memcpy-level, dwarfed by the
    // polynomial evaluation it precedes).
    let a_bytes: Vec<Vec<u8>> = a.iter().map(|m| m.to_bytes(ring)).collect();
    let b_bytes: Vec<Vec<u8>> = b.iter().map(|m| m.to_bytes(ring)).collect();

    let t0 = Instant::now();
    let payloads = scheme.encode_bytes(&a_bytes, &b_bytes)?;
    let encode = t0.elapsed();

    let need = scheme.recovery_threshold();
    let handle = coord.submit(payloads, need)?;
    let job_id = handle.job_id();
    let counters = handle.counters().clone();
    let (collected, wait_for_r) = handle.wait()?;

    let responses: Vec<(usize, &[u8])> = collected
        .iter()
        .map(|c| (c.worker_id, c.payload.as_slice()))
        .collect();
    let (hits_before, misses_before) = scheme.plan_cache_stats();
    let t0 = Instant::now();
    let out_bytes = scheme.decode_bytes(&responses)?;
    let decode = t0.elapsed();
    let (hits_after, misses_after) = scheme.plan_cache_stats();
    // Re-crossing the facade (output bytes → matrices) is untimed, mirroring
    // the encode side.
    let out: Vec<Matrix<R::Elem>> = out_bytes
        .iter()
        .map(|buf| Matrix::from_bytes(ring, buf))
        .collect::<anyhow::Result<_>>()?;

    let mut metrics =
        job_metrics(encode, decode, wait_for_r, t_total.elapsed(), &counters, &collected);
    metrics.job_id = job_id;
    metrics.plan_cache_hits = hits_after.saturating_sub(hits_before);
    metrics.plan_cache_misses = misses_after.saturating_sub(misses_before);
    (metrics.pool_hits, metrics.pool_misses, metrics.large_allocs) = probe.delta();
    Ok((out, metrics))
}

/// Tuning for the Byzantine-tolerant decode path ([`run_verified_erased`]).
#[derive(Clone, Debug)]
pub struct VerifyOptions {
    /// Freivalds trials per probabilistic product check. Each trial's error
    /// is at most `1/|S|` for the challenge set `S` the scheme draws from
    /// (the extension's canonical exceptional set where available), so over
    /// `Z_{2^64}`-lifted schemes 40 trials push the error below `2^{-40}`
    /// even in the worst `|S| = 2` case.
    pub trials: usize,
    /// How long to keep draining surplus responses after the threshold is
    /// met — the raw material for the re-encode-and-compare check.
    pub grace: Duration,
    /// Seed of the challenge-vector RNG (XORed with the job id, so repeated
    /// jobs draw independent challenges).
    pub seed: u64,
    /// Re-dispatch rounds allowed to replace rejected shares before the job
    /// fails fast.
    pub max_redispatch: usize,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions {
            trials: 40,
            grace: Duration::from_millis(250),
            seed: 0x5eed_f00d,
            max_redispatch: 2,
        }
    }
}

/// Run one job with Byzantine-tolerant verified decode: collect *more* than
/// `R` responses when the pool offers them, cross-check the decode against
/// the surplus shares (re-encode-and-compare at the spare evaluation
/// points), fall back to a Freivalds probabilistic product check when
/// exactly `R` arrived, and on a verification failure isolate the
/// inconsistent share by leave-one-out re-decode, quarantine the culprit
/// worker, re-dispatch its shard to a spare, and retry. The job fails fast
/// — with a named suspect set, never a silently wrong product — only when
/// corruption exceeds the code's slack.
///
/// Assumes the classic one-shard-per-worker dispatch shape (shard `i` on
/// worker `i`), which is how every serve path submits; the quarantine
/// verdicts use the shard index as the worker id.
pub fn run_verified_erased<R: Ring>(
    ring: &R,
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    a: &[Matrix<R::Elem>],
    b: &[Matrix<R::Elem>],
    opts: &VerifyOptions,
) -> anyhow::Result<(Vec<Matrix<R::Elem>>, JobMetrics)> {
    let t_total = Instant::now();
    let probe = PoolProbe::start();
    let a_bytes: Vec<Vec<u8>> = a.iter().map(|m| m.to_bytes(ring)).collect();
    let b_bytes: Vec<Vec<u8>> = b.iter().map(|m| m.to_bytes(ring)).collect();

    let t0 = Instant::now();
    let payloads = scheme.encode_bytes(&a_bytes, &b_bytes)?;
    let encode = t0.elapsed();
    // Retained for re-dispatch after a quarantine.
    let retained = payloads.clone();
    let n_shards = payloads.len();

    let need = scheme.recovery_threshold();
    let handle = coord.submit(payloads, need)?;
    let job_id = handle.job_id();
    let counters = handle.counters().clone();
    let aggregate = coord.counters().clone();
    let (collected, wait_for_r) = handle.wait_surplus(opts.grace)?;

    let mut rng = Rng64::seeded(opts.seed ^ job_id);
    let mut corrupt = 0u64;
    let mut verify_trials = 0u64;
    let mut quarantines = 0u64;
    let mut loo = 0u64;
    let mut redispatches = 0usize;
    let mut suspects: BTreeSet<usize> = BTreeSet::new();

    // Working set: (share index, payload, bytes already credited as used by
    // a re-dispatch job's own counters). `wait_surplus` deferred the
    // original collection's used-accounting to us. Payload clones are
    // reference-count bumps on the pooled buffers, not byte copies.
    let mut responses: Vec<(usize, PooledBuf, bool)> =
        collected.iter().map(|c| (c.worker_id, c.payload.clone(), false)).collect();

    let (hits_before, misses_before) = scheme.plan_cache_stats();
    let (out_bytes, decode) = loop {
        // (0) Well-formedness: a response that does not even parse is
        // rejected outright and its sender quarantined.
        let mut kept = Vec::with_capacity(responses.len());
        for (idx, payload, counted) in responses.drain(..) {
            if scheme.response_is_wellformed(&payload) {
                kept.push((idx, payload, counted));
            } else {
                corrupt += 1;
                quarantines += 1;
                suspects.insert(idx);
                coord.quarantine_worker(idx);
                if !counted {
                    counters.add_download_rejected(payload.len());
                    aggregate.add_download_rejected(payload.len());
                }
            }
        }
        responses = kept;

        // (1) Below threshold: re-dispatch the missing shards to the
        // healthiest (non-quarantined) workers, budget-bounded.
        if responses.len() < need {
            anyhow::ensure!(
                redispatches < opts.max_redispatch,
                "verification failed: {}/{need} trusted responses for job {job_id} after \
                 {redispatches} re-dispatch round(s); suspect workers {suspects:?}",
                responses.len()
            );
            redispatches += 1;
            let present: BTreeSet<usize> = responses.iter().map(|r| r.0).collect();
            let missing: Vec<usize> =
                (0..n_shards).filter(|i| !present.contains(i)).collect();
            let sub: Vec<PooledBuf> = missing.iter().map(|&i| retained[i].clone()).collect();
            let h = coord.submit(sub, missing.len())?;
            let (extra, _) = h.wait()?;
            for c in extra {
                responses.push((missing[c.worker_id], c.payload, true));
            }
            continue;
        }

        let borrowed: Vec<(usize, &[u8])> =
            responses.iter().map(|(i, p, _)| (*i, p.as_slice())).collect();

        if responses.len() > need {
            // (2) Surplus in hand: re-encode-and-compare at the spare
            // evaluation points. Empty flags ⇒ the whole set lies on one
            // codeword ⇒ the decode is trustworthy as-is.
            let consistent = matches!(
                scheme.check_surplus_bytes(&borrowed), Ok(f) if f.is_empty()
            );
            if consistent {
                let t0 = Instant::now();
                let out = scheme.decode_bytes(&borrowed[..need])?;
                break (out, t0.elapsed());
            }
            // Leave-one-out isolation: a response whose removal restores
            // consistency is the culprit — but only a *unique* such
            // response is conclusive.
            let mut culprits: Vec<usize> = Vec::new();
            for skip in 0..borrowed.len() {
                let subset: Vec<(usize, &[u8])> = borrowed
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != skip)
                    .map(|(_, r)| *r)
                    .collect();
                loo += 1;
                let ok = if subset.len() > need {
                    matches!(scheme.check_surplus_bytes(&subset), Ok(f) if f.is_empty())
                } else {
                    verify_trials += opts.trials as u64;
                    match scheme.decode_bytes(&subset[..need]) {
                        Ok(c) => scheme
                            .verify_products_bytes(&a_bytes, &b_bytes, &c, opts.trials, &mut rng)
                            .unwrap_or(false),
                        Err(_) => false,
                    }
                };
                if ok {
                    culprits.push(skip);
                }
            }
            if culprits.len() != 1 {
                let named: Vec<usize> = if culprits.is_empty() {
                    borrowed.iter().map(|(i, _)| *i).collect()
                } else {
                    culprits.iter().map(|&j| borrowed[j].0).collect()
                };
                suspects.extend(named);
                anyhow::bail!(
                    "verification failed: mutually inconsistent responses exceed the code's \
                     slack for job {job_id} ({} candidate culprit(s)); suspect workers \
                     {suspects:?}",
                    culprits.len()
                );
            }
            let pos = culprits[0];
            let (idx, payload, counted) = responses.remove(pos);
            corrupt += 1;
            quarantines += 1;
            suspects.insert(idx);
            coord.quarantine_worker(idx);
            if !counted {
                counters.add_download_rejected(payload.len());
                aggregate.add_download_rejected(payload.len());
            }
            continue;
        }

        // (3) Exactly R responses — no surplus to compare against: the
        // Freivalds probabilistic product check gates the result. With zero
        // slack a rejection cannot be isolated, so fail fast with the
        // contributing set named rather than ever emitting an unverified
        // wrong product.
        let t0 = Instant::now();
        let out = scheme.decode_bytes(&borrowed)?;
        let dt = t0.elapsed();
        verify_trials += opts.trials as u64;
        if scheme.verify_products_bytes(&a_bytes, &b_bytes, &out, opts.trials, &mut rng)? {
            break (out, dt);
        }
        suspects.extend(borrowed.iter().map(|(i, _)| *i));
        anyhow::bail!(
            "verification failed: Freivalds rejected the product of job {job_id} with exactly \
             {need} responses (no surplus to isolate with); suspect workers {suspects:?}"
        );
    };
    let (hits_after, misses_after) = scheme.plan_cache_stats();

    // Classify the surviving responses as used (the re-dispatch jobs
    // already counted theirs).
    for (_, payload, counted) in &responses {
        if !counted {
            counters.add_download_used(payload.len());
            aggregate.add_download_used(payload.len());
        }
    }

    let out: Vec<Matrix<R::Elem>> = out_bytes
        .iter()
        .map(|buf| Matrix::from_bytes(ring, buf))
        .collect::<anyhow::Result<_>>()?;

    let mut metrics =
        job_metrics(encode, decode, wait_for_r, t_total.elapsed(), &counters, &collected);
    metrics.job_id = job_id;
    metrics.plan_cache_hits = hits_after.saturating_sub(hits_before);
    metrics.plan_cache_misses = misses_after.saturating_sub(misses_before);
    metrics.used_workers = responses.iter().map(|(i, _, _)| *i).collect();
    metrics.corrupt_responses_detected = corrupt;
    metrics.verify_trials = verify_trials;
    metrics.quarantines = quarantines;
    metrics.leave_one_out_decodes = loo;
    (metrics.pool_hits, metrics.pool_misses, metrics.large_allocs) = probe.delta();
    Ok((out, metrics))
}

/// Encode-once serving, step 1 (erased): encode `a`'s per-worker A-side
/// share halves via [`DynScheme::encode_left_bytes`] and stage them on the
/// pool as a prepared operand. Returns the id for [`run_prepared_erased`].
/// Errors if the scheme cannot encode its operands independently.
pub fn prepare_erased<R: Ring>(
    ring: &R,
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    a: &[Matrix<R::Elem>],
) -> anyhow::Result<u64> {
    let a_bytes: Vec<Vec<u8>> = a.iter().map(|m| m.to_bytes(ring)).collect();
    let halves = scheme.encode_left_bytes(&a_bytes)?;
    coord.prepare(halves)
}

/// Encode-once serving, step 2 (erased): encode only `b`'s B-side halves
/// ([`DynScheme::encode_right_bytes`] — the A-side was staged by
/// [`prepare_erased`], so zero A-encodes happen here), dispatch them as a
/// prepared job, collect and decode. The decode input is byte-identical to
/// an unprepared [`run_erased`] of the same `(a, b)`, so the outputs are
/// bit-identical; only the encode time and upload volume shrink. The
/// returned metrics carry the prepared-store hit/miss/eviction deltas of
/// this job.
pub fn run_prepared_erased<R: Ring>(
    ring: &R,
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    prepared_id: u64,
    b: &[Matrix<R::Elem>],
) -> anyhow::Result<(Vec<Matrix<R::Elem>>, JobMetrics)> {
    let t_total = Instant::now();
    let probe = PoolProbe::start();
    let b_bytes: Vec<Vec<u8>> = b.iter().map(|m| m.to_bytes(ring)).collect();

    let t0 = Instant::now();
    let payloads = scheme.encode_right_bytes(&b_bytes)?;
    let encode = t0.elapsed();

    let need = scheme.recovery_threshold();
    let (p_hits0, p_misses0, p_evict0) = coord.prepared_stats();
    let handle = coord.submit_prepared(prepared_id, payloads, need)?;
    let (p_hits1, p_misses1, p_evict1) = coord.prepared_stats();
    let job_id = handle.job_id();
    let counters = handle.counters().clone();
    let (collected, wait_for_r) = handle.wait()?;

    let responses: Vec<(usize, &[u8])> = collected
        .iter()
        .map(|c| (c.worker_id, c.payload.as_slice()))
        .collect();
    let (hits_before, misses_before) = scheme.plan_cache_stats();
    let t0 = Instant::now();
    let out_bytes = scheme.decode_bytes(&responses)?;
    let decode = t0.elapsed();
    let (hits_after, misses_after) = scheme.plan_cache_stats();
    let out: Vec<Matrix<R::Elem>> = out_bytes
        .iter()
        .map(|buf| Matrix::from_bytes(ring, buf))
        .collect::<anyhow::Result<_>>()?;

    let mut metrics =
        job_metrics(encode, decode, wait_for_r, t_total.elapsed(), &counters, &collected);
    metrics.job_id = job_id;
    metrics.plan_cache_hits = hits_after.saturating_sub(hits_before);
    metrics.plan_cache_misses = misses_after.saturating_sub(misses_before);
    metrics.prepared_hits = p_hits1.saturating_sub(p_hits0);
    metrics.prepared_misses = p_misses1.saturating_sub(p_misses0);
    metrics.prepared_evictions = p_evict1.saturating_sub(p_evict0);
    (metrics.pool_hits, metrics.pool_misses, metrics.large_allocs) = probe.delta();
    Ok((out, metrics))
}

/// Run one batch job (`C_k = A_k·B_k`) with a typed scheme. The coordinator
/// must have been built with a compatible backend (e.g.
/// [`NativeCompute::for_scheme`]).
pub fn run_batch<R: Ring, S: DmmScheme<R>>(
    scheme: &S,
    coord: &mut Coordinator,
    a: &[Matrix<R::Elem>],
    b: &[Matrix<R::Elem>],
) -> anyhow::Result<(Vec<Matrix<R::Elem>>, JobMetrics)> {
    let ring = scheme.share_ring();
    let t_total = Instant::now();
    let probe = PoolProbe::start();

    let t0 = Instant::now();
    let shares = scheme.encode_batch(a, b)?;
    let payloads: Vec<PooledBuf> = shares
        .iter()
        .map(|s| {
            let mut lease = BytePool::global().lease(s.byte_len(ring));
            s.write_bytes_into(ring, &mut lease);
            lease.freeze()
        })
        .collect();
    let encode = t0.elapsed();

    let need = scheme.recovery_threshold();
    let handle = coord.submit(payloads, need)?;
    let job_id = handle.job_id();
    let counters = handle.counters().clone();
    let (collected, wait_for_r) = handle.wait()?;

    let (hits_before, misses_before) = scheme.plan_cache_stats();
    let t0 = Instant::now();
    let responses: Vec<Response<S::ShareRing>> = collected
        .iter()
        .map(|c| PlaneMatrix::from_bytes(ring, &c.payload).map(|m| (c.worker_id, m)))
        .collect::<anyhow::Result<_>>()?;
    let c = scheme.decode_batch(&responses)?;
    let decode = t0.elapsed();
    let (hits_after, misses_after) = scheme.plan_cache_stats();

    let mut metrics =
        job_metrics(encode, decode, wait_for_r, t_total.elapsed(), &counters, &collected);
    metrics.job_id = job_id;
    metrics.plan_cache_hits = hits_after.saturating_sub(hits_before);
    metrics.plan_cache_misses = misses_after.saturating_sub(misses_before);
    (metrics.pool_hits, metrics.pool_misses, metrics.large_allocs) = probe.delta();
    Ok((c, metrics))
}

/// Run one single-product job (`C = A·B`) with a typed scheme
/// (`batch_size() == 1`).
pub fn run_single<R: Ring, S: DmmScheme<R>>(
    scheme: &S,
    coord: &mut Coordinator,
    a: &Matrix<R::Elem>,
    b: &Matrix<R::Elem>,
) -> anyhow::Result<(Matrix<R::Elem>, JobMetrics)> {
    anyhow::ensure!(
        scheme.batch_size() == 1,
        "{} is a batch scheme; use run_batch",
        scheme.name()
    );
    let (mut out, metrics) = run_batch(
        scheme,
        coord,
        std::slice::from_ref(a),
        std::slice::from_ref(b),
    )?;
    anyhow::ensure!(out.len() == 1, "single-product job returned {} outputs", out.len());
    Ok((out.pop().expect("length checked above"), metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::batch_ep_rmfe::BatchEpRmfe;
    use crate::codes::ep::PlainEp;
    use crate::codes::ep_rmfe_i::EpRmfeI;
    use crate::codes::registry::{self, SchemeConfig};
    use crate::coordinator::straggler::StragglerModel;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    #[test]
    fn single_job_end_to_end() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
        let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 11);
        let mut rng = Rng64::seeded(171);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
        // wire accounting matches the scheme's analytic model
        assert_eq!(m.upload_bytes as usize, scheme.upload_bytes(8, 8, 8));
        assert_eq!(m.download_bytes as usize, scheme.download_bytes(8, 8, 8));
        assert_eq!(m.used_workers.len(), 4);
        coord.shutdown();
    }

    #[test]
    fn single_job_with_stragglers_still_correct() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap());
        let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
        let straggler =
            StragglerModel::fixed_slow([0, 1], std::time::Duration::from_millis(150));
        let mut coord = Coordinator::new(8, backend, straggler, 12);
        let mut rng = Rng64::seeded(172);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
        assert!(!m.used_workers.contains(&0));
        assert!(!m.used_workers.contains(&1));
        coord.shutdown();
    }

    #[test]
    fn batch_job_end_to_end() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(BatchEpRmfe::new(base.clone(), 8, 2, 2, 1, 2).unwrap());
        let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 13);
        let mut rng = Rng64::seeded(173);
        let a: Vec<_> = (0..2).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let b: Vec<_> = (0..2).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let (c, m) = run_batch(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        for k in 0..2 {
            assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]));
        }
        assert_eq!(m.used_workers.len(), scheme.recovery_threshold());
        coord.shutdown();
    }

    #[test]
    fn fail_stop_within_budget_recovers() {
        let base = Zq::z2e(64);
        // R = 4, N = 8: tolerate up to 4 failures.
        let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
        let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
        let straggler = StragglerModel::fail_stop([1, 3, 5, 7]);
        let mut coord = Coordinator::new(8, backend, straggler, 14);
        let mut rng = Rng64::seeded(174);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let (c, _) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
        coord.shutdown();
    }

    #[test]
    fn metrics_carry_job_id_and_plan_cache_delta() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
        let backend = Arc::new(NativeCompute::for_scheme(Arc::clone(&scheme)));
        // exactly R = 4 survivors: the responding subset is {0,1,2,3} every
        // job, so the second decode must hit the plan cache
        let straggler = StragglerModel::fail_stop([4, 5, 6, 7]);
        let mut coord = Coordinator::new(8, backend, straggler, 16);
        let mut rng = Rng64::seeded(176);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c1, m1) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        let (c2, m2) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c1, c2, "warm decode must equal cold decode");
        assert_eq!((m1.job_id, m2.job_id), (0, 1));
        assert_eq!((m1.plan_cache_hits, m1.plan_cache_misses), (0, 1));
        assert_eq!((m2.plan_cache_hits, m2.plan_cache_misses), (1, 0));
        coord.shutdown();
    }

    #[test]
    fn prepared_serving_is_bit_identical_with_split_upload_and_zero_a_encodes() {
        let base = Zq::z2e(64);
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 17);
        let mut rng = Rng64::seeded(177);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let bs: Vec<_> = (0..3).map(|_| Matrix::random(&base, 8, 8, &mut rng)).collect();

        // Unprepared baseline for each B.
        let mut baseline = Vec::new();
        for b in &bs {
            let (c, _) = run_erased(
                &base,
                scheme.as_ref(),
                &mut coord,
                std::slice::from_ref(&a),
                std::slice::from_ref(b),
            )
            .unwrap();
            baseline.push(c);
        }

        // Prepared: encode A once, stream the same Bs.
        let encodes_before = scheme.left_encodes();
        let id = prepare_erased(&base, scheme.as_ref(), &mut coord, std::slice::from_ref(&a))
            .unwrap();
        assert_eq!(scheme.left_encodes(), encodes_before + 1, "prepare encodes A once");
        let (a_bytes, b_bytes) = scheme.split_upload_bytes(8, 8, 8).unwrap();
        assert_eq!(
            coord.counters().staged_upload_total() as usize,
            a_bytes,
            "staging ships exactly the analytic A-side volume"
        );
        for (b, expect) in bs.iter().zip(&baseline) {
            let (c, m) = run_prepared_erased(
                &base,
                scheme.as_ref(),
                &mut coord,
                id,
                std::slice::from_ref(b),
            )
            .unwrap();
            assert_eq!(&c, expect, "prepared decode is bit-identical to unprepared");
            assert_eq!(m.upload_bytes as usize, b_bytes, "per-job upload is the B-half only");
            assert_eq!(m.staged_upload_bytes, 0, "no per-job staging");
            assert_eq!((m.prepared_hits, m.prepared_misses), (1, 0));
        }
        assert_eq!(
            scheme.left_encodes(),
            encodes_before + 1,
            "zero A-encodes in the steady state"
        );
        coord.shutdown();
    }

    #[test]
    fn verified_run_accepts_a_clean_pool_via_surplus_check() {
        let base = Zq::z2e(64);
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let scheme = registry::build("ep", &cfg).unwrap();
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 50);
        let mut rng = Rng64::seeded(180);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c, m) = run_verified_erased(
            &base,
            scheme.as_ref(),
            &mut coord,
            std::slice::from_ref(&a),
            std::slice::from_ref(&b),
            &VerifyOptions::default(),
        )
        .unwrap();
        assert_eq!(c[0], Matrix::matmul(&base, &a, &b));
        assert_eq!(m.corrupt_responses_detected, 0);
        assert_eq!(m.quarantines, 0);
        assert_eq!(m.leave_one_out_decodes, 0);
        // All 8 clean responses arrived within the grace: the surplus check
        // certifies the decode, no Freivalds fallback needed.
        assert_eq!(m.verify_trials, 0);
        coord.shutdown();
    }

    #[test]
    fn verified_run_quarantines_a_silent_wrong_share_worker() {
        use crate::coordinator::pool::WorkerHealth;
        use crate::coordinator::straggler::CorruptionModel;
        use crate::coordinator::transport::ChannelTransport;
        let base = Zq::z2e(64);
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let scheme = registry::build("ep", &cfg).unwrap();
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let transport = ChannelTransport::spawn_faulty(
            8,
            backend,
            StragglerModel::None,
            CorruptionModel::silent_wrong_share([2]),
            51,
        );
        let mut coord = Coordinator::with_transport(Box::new(transport));
        let mut rng = Rng64::seeded(181);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        // Clean reference from an honest in-process run of the same scheme.
        let expect = Matrix::matmul(&base, &a, &b);
        let (c, m) = run_verified_erased(
            &base,
            scheme.as_ref(),
            &mut coord,
            std::slice::from_ref(&a),
            std::slice::from_ref(&b),
            &VerifyOptions::default(),
        )
        .unwrap();
        assert_eq!(c[0], expect, "the verified product is the clean product, bit-identical");
        assert!(m.corrupt_responses_detected >= 1, "the wrong share was detected");
        assert!(m.quarantines >= 1);
        assert_eq!(coord.worker_health(2), WorkerHealth::Quarantined, "culprit quarantined");
        assert!(!m.used_workers.contains(&2), "the corrupt share is not in the trusted set");
        // Rejected bytes live in their own bucket; the identity holds.
        let counters = coord.counters();
        assert!(counters.download_rejected_total() > 0);
        assert_eq!(
            counters.download_arrived_total(),
            counters.download_used_total()
                + counters.download_discarded_total()
                + counters.download_rejected_total()
        );
        coord.shutdown();
    }

    #[test]
    fn verified_run_fails_fast_when_corruption_exceeds_slack() {
        use crate::coordinator::straggler::CorruptionModel;
        use crate::coordinator::transport::ChannelTransport;
        let base = Zq::z2e(64);
        // N = 4 preset: R = 4 = N, zero slack — one corrupt worker is
        // beyond the code's tolerance and must be reported, not decoded.
        let cfg = SchemeConfig::for_workers(4).unwrap();
        let scheme = registry::build("ep", &cfg).unwrap();
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let transport = ChannelTransport::spawn_faulty(
            4,
            backend,
            StragglerModel::None,
            CorruptionModel::silent_wrong_share([1]),
            52,
        );
        let mut coord = Coordinator::with_transport(Box::new(transport));
        let mut rng = Rng64::seeded(182);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let err = run_verified_erased(
            &base,
            scheme.as_ref(),
            &mut coord,
            std::slice::from_ref(&a),
            std::slice::from_ref(&b),
            &VerifyOptions::default(),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("verification failed"), "{msg}");
        assert!(msg.contains("suspect workers"), "{msg}");
        coord.shutdown();
    }

    #[test]
    fn make_coordinator_validates_endpoint_count() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(EpRmfeI::new(base, 8, 2, 1, 2, 2).unwrap());
        let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::for_scheme(scheme));
        let one_endpoint = vec!["127.0.0.1:1".to_string()];
        let err =
            make_coordinator(8, backend, StragglerModel::None, 1, Some(&one_endpoint))
                .unwrap_err();
        assert!(err.to_string().contains("endpoint"), "{err}");
    }

    #[test]
    fn erased_job_through_registry() {
        // The exact path main.rs/experiments take: registry name → erased
        // scheme → NativeCompute pool → run_erased.
        let base = Zq::z2e(64);
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let scheme = registry::build("ep-rmfe-1", &cfg).unwrap();
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 15);
        let mut rng = Rng64::seeded(175);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c, m) = run_erased(
            &base,
            scheme.as_ref(),
            &mut coord,
            std::slice::from_ref(&a),
            std::slice::from_ref(&b),
        )
        .unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0], Matrix::matmul(&base, &a, &b));
        assert_eq!(m.upload_bytes as usize, scheme.upload_bytes(8, 8, 8));
        coord.shutdown();
    }
}
