//! Glue between the coding layer and the coordinator: runs one
//! single-product or batch job end-to-end and produces the full
//! [`JobMetrics`] breakdown.

use super::master::Coordinator;
use super::metrics::JobMetrics;
use super::worker::ShareCompute;
use crate::codes::scheme::{BatchCodedScheme, CodedScheme, Share};
use crate::ring::matrix::Matrix;
use crate::ring::traits::Ring;
use std::sync::Arc;
use std::time::Instant;

pub use super::worker::ShareCompute as ShareComputeTrait;

/// Native worker backend for a single-product scheme: deserialize the share,
/// multiply with the generic ring kernels, serialize the response.
pub struct NativeSingleCompute<R: Ring, S: CodedScheme<R>> {
    scheme: Arc<S>,
    _marker: std::marker::PhantomData<fn() -> R>,
}

impl<R: Ring, S: CodedScheme<R>> NativeSingleCompute<R, S> {
    pub fn new(scheme: Arc<S>) -> Self {
        NativeSingleCompute { scheme, _marker: std::marker::PhantomData }
    }
}

impl<R: Ring, S: CodedScheme<R> + 'static> ShareCompute for NativeSingleCompute<R, S> {
    fn compute(&self, _worker_id: usize, payload: &[u8]) -> anyhow::Result<Vec<u8>> {
        let ring = self.scheme.share_ring();
        let share = Share::from_bytes(ring, payload);
        let resp = self.scheme.worker_compute(&share)?;
        Ok(resp.to_bytes(ring))
    }
}

/// Native worker backend for a batch scheme.
pub struct NativeBatchCompute<R: Ring, S: BatchCodedScheme<R>> {
    scheme: Arc<S>,
    _marker: std::marker::PhantomData<fn() -> R>,
}

impl<R: Ring, S: BatchCodedScheme<R>> NativeBatchCompute<R, S> {
    pub fn new(scheme: Arc<S>) -> Self {
        NativeBatchCompute { scheme, _marker: std::marker::PhantomData }
    }
}

impl<R: Ring, S: BatchCodedScheme<R> + 'static> ShareCompute for NativeBatchCompute<R, S> {
    fn compute(&self, _worker_id: usize, payload: &[u8]) -> anyhow::Result<Vec<u8>> {
        let ring = self.scheme.share_ring();
        let share = Share::from_bytes(ring, payload);
        let resp = self.scheme.worker_compute(&share)?;
        Ok(resp.to_bytes(ring))
    }
}

/// Run one single-product job (`C = A·B`) on the pool. The coordinator must
/// have been built with a backend compatible with `scheme` (e.g.
/// [`NativeSingleCompute::new(scheme.clone())`]).
pub fn run_single<R: Ring, S: CodedScheme<R>>(
    scheme: &S,
    coord: &mut Coordinator,
    a: &Matrix<R::Elem>,
    b: &Matrix<R::Elem>,
) -> anyhow::Result<(Matrix<R::Elem>, JobMetrics)> {
    let ring = scheme.share_ring();
    let t_total = Instant::now();
    let counters = coord.counters().clone();
    counters.reset();

    let t0 = Instant::now();
    let shares = scheme.encode(a, b)?;
    let payloads: Vec<Vec<u8>> = shares.iter().map(|s| s.to_bytes(ring)).collect();
    let encode = t0.elapsed();

    let need = scheme.recovery_threshold();
    let (collected, wait_for_r) = coord.submit_and_collect(payloads, need)?;

    let t0 = Instant::now();
    let responses: Vec<(usize, Matrix<<S::ShareRing as Ring>::Elem>)> = collected
        .iter()
        .map(|c| (c.worker_id, Matrix::from_bytes(ring, &c.payload)))
        .collect();
    let c = scheme.decode(&responses)?;
    let decode = t0.elapsed();

    let metrics = JobMetrics {
        encode,
        decode,
        wait_for_r,
        upload_bytes: counters.upload_total(),
        download_bytes: counters.download_used_total(),
        worker_compute: collected.iter().map(|c| c.compute).collect(),
        worker_delay: collected.iter().map(|c| c.injected_delay).collect(),
        used_workers: collected.iter().map(|c| c.worker_id).collect(),
        total: t_total.elapsed(),
    };
    Ok((c, metrics))
}

/// Run one batch job (`C_k = A_k·B_k`) on the pool.
pub fn run_batch<R: Ring, S: BatchCodedScheme<R>>(
    scheme: &S,
    coord: &mut Coordinator,
    a: &[Matrix<R::Elem>],
    b: &[Matrix<R::Elem>],
) -> anyhow::Result<(Vec<Matrix<R::Elem>>, JobMetrics)> {
    let ring = scheme.share_ring();
    let t_total = Instant::now();
    let counters = coord.counters().clone();
    counters.reset();

    let t0 = Instant::now();
    let shares = scheme.encode_batch(a, b)?;
    let payloads: Vec<Vec<u8>> = shares.iter().map(|s| s.to_bytes(ring)).collect();
    let encode = t0.elapsed();

    let need = scheme.recovery_threshold();
    let (collected, wait_for_r) = coord.submit_and_collect(payloads, need)?;

    let t0 = Instant::now();
    let responses: Vec<(usize, Matrix<<S::ShareRing as Ring>::Elem>)> = collected
        .iter()
        .map(|c| (c.worker_id, Matrix::from_bytes(ring, &c.payload)))
        .collect();
    let c = scheme.decode_batch(&responses)?;
    let decode = t0.elapsed();

    let metrics = JobMetrics {
        encode,
        decode,
        wait_for_r,
        upload_bytes: counters.upload_total(),
        download_bytes: counters.download_used_total(),
        worker_compute: collected.iter().map(|c| c.compute).collect(),
        worker_delay: collected.iter().map(|c| c.injected_delay).collect(),
        used_workers: collected.iter().map(|c| c.worker_id).collect(),
        total: t_total.elapsed(),
    };
    Ok((c, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::batch_ep_rmfe::BatchEpRmfe;
    use crate::codes::ep::PlainEp;
    use crate::codes::ep_rmfe_i::EpRmfeI;
    use crate::coordinator::straggler::StragglerModel;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    #[test]
    fn single_job_end_to_end() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
        let backend = Arc::new(NativeSingleCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 11);
        let mut rng = Rng64::seeded(171);
        let a = Matrix::random(&base, 8, 8, &mut rng);
        let b = Matrix::random(&base, 8, 8, &mut rng);
        let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
        // wire accounting matches the scheme's analytic model
        assert_eq!(m.upload_bytes as usize, CodedScheme::upload_bytes(scheme.as_ref(), 8, 8, 8));
        assert_eq!(
            m.download_bytes as usize,
            CodedScheme::download_bytes(scheme.as_ref(), 8, 8, 8)
        );
        assert_eq!(m.used_workers.len(), 4);
        coord.shutdown();
    }

    #[test]
    fn single_job_with_stragglers_still_correct() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap());
        let backend = Arc::new(NativeSingleCompute::new(Arc::clone(&scheme)));
        let straggler =
            StragglerModel::fixed_slow([0, 1], std::time::Duration::from_millis(150));
        let mut coord = Coordinator::new(8, backend, straggler, 12);
        let mut rng = Rng64::seeded(172);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let (c, m) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
        assert!(!m.used_workers.contains(&0));
        assert!(!m.used_workers.contains(&1));
        coord.shutdown();
    }

    #[test]
    fn batch_job_end_to_end() {
        let base = Zq::z2e(64);
        let scheme = Arc::new(BatchEpRmfe::new(base.clone(), 8, 2, 2, 1, 2).unwrap());
        let backend = Arc::new(NativeBatchCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(8, backend, StragglerModel::None, 13);
        let mut rng = Rng64::seeded(173);
        let a: Vec<_> = (0..2).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let b: Vec<_> = (0..2).map(|_| Matrix::random(&base, 4, 4, &mut rng)).collect();
        let (c, m) = run_batch(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        for k in 0..2 {
            assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]));
        }
        assert_eq!(m.used_workers.len(), scheme.recovery_threshold());
        coord.shutdown();
    }

    #[test]
    fn fail_stop_within_budget_recovers() {
        let base = Zq::z2e(64);
        // R = 4, N = 8: tolerate up to 4 failures.
        let scheme = Arc::new(EpRmfeI::new(base.clone(), 8, 2, 1, 2, 2).unwrap());
        let backend = Arc::new(NativeSingleCompute::new(Arc::clone(&scheme)));
        let straggler = StragglerModel::fail_stop([1, 3, 5, 7]);
        let mut coord = Coordinator::new(8, backend, straggler, 14);
        let mut rng = Rng64::seeded(174);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let (c, _) = run_single(scheme.as_ref(), &mut coord, &a, &b).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
        coord.shutdown();
    }
}
