//! Message types and byte-accounted links between master and workers.
//!
//! Transport is in-process (`std::sync::mpsc`) — the paper's evaluation
//! measures communication *volume*, not bandwidth, and volume is preserved
//! exactly by counting the serialized payload bytes crossing each link.
//! Every payload that would cross a network in a deployment crosses a
//! counted channel here.
//!
//! Counters exist at two scopes since the multi-job coordinator: every
//! in-flight job owns a [`ByteCounters`] (written by the dispatch path, the
//! response router and the job's collector — see
//! [`super::master`]), and the coordinator keeps one **aggregate**
//! instance summing all jobs over its lifetime. Counters are monotone;
//! "discarded" download is derived (`arrived − used`), so late responses
//! counted by the router can never race the collector's used-bytes
//! accounting into a negative.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Master → worker message.
pub enum ToWorker {
    Job {
        job_id: u64,
        /// Serialized [`crate::codes::Share`].
        payload: Vec<u8>,
    },
    Shutdown,
}

/// Worker → master message.
pub struct FromWorker {
    pub job_id: u64,
    pub worker_id: usize,
    /// Serialized response matrix. `None` if the worker failed the job.
    pub payload: Option<Vec<u8>>,
    /// Pure compute time at the worker (excludes injected straggler delay).
    pub compute: Duration,
    /// Injected straggler delay, for reporting.
    pub injected_delay: Duration,
}

/// Shared, monotone byte counters for one scope (one job, or one
/// coordinator lifetime). Cloning shares the underlying atomics.
#[derive(Clone, Default)]
pub struct ByteCounters {
    /// Total bytes master → workers.
    upload: Arc<AtomicU64>,
    /// Total response bytes that reached the master (router-side count,
    /// whether or not the collector still wanted them).
    download_arrived: Arc<AtomicU64>,
    /// Bytes of responses the collector consumed for decoding (the first
    /// `need` successful responses of the job).
    download_used: Arc<AtomicU64>,
}

impl ByteCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_upload(&self, n: usize) {
        self.upload.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_arrived(&self, n: usize) {
        self.download_arrived.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_used(&self, n: usize) {
        self.download_used.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn upload_total(&self) -> u64 {
        self.upload.load(Ordering::Relaxed)
    }

    pub fn download_arrived_total(&self) -> u64 {
        self.download_arrived.load(Ordering::Relaxed)
    }

    pub fn download_used_total(&self) -> u64 {
        self.download_used.load(Ordering::Relaxed)
    }

    /// Bytes that arrived after the job no longer needed them (beyond the
    /// recovery threshold, or after the job's handle was dropped).
    pub fn download_discarded_total(&self) -> u64 {
        self.download_arrived_total().saturating_sub(self.download_used_total())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = ByteCounters::new();
        c.add_upload(100);
        c.add_upload(20);
        c.add_download_arrived(10);
        c.add_download_used(7);
        assert_eq!(c.upload_total(), 120);
        assert_eq!(c.download_arrived_total(), 10);
        assert_eq!(c.download_used_total(), 7);
        assert_eq!(c.download_discarded_total(), 3);
    }

    #[test]
    fn counters_shared_across_clones() {
        let c = ByteCounters::new();
        let c2 = c.clone();
        c2.add_upload(42);
        assert_eq!(c.upload_total(), 42);
    }

    #[test]
    fn discarded_never_underflows() {
        // The collector may count a response as used before the router's
        // arrived increment is observed; discarded saturates at 0.
        let c = ByteCounters::new();
        c.add_download_used(5);
        assert_eq!(c.download_discarded_total(), 0);
    }
}
