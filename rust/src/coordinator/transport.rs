//! The pluggable master ↔ worker transport: message types, the object-safe
//! [`Transport`] trait, byte accounting, and the in-process
//! [`ChannelTransport`].
//!
//! The paper's evaluation measures communication *volume*, and volume is
//! preserved exactly by counting the serialized payload bytes crossing each
//! link — so both transports account the same quantity at the same
//! boundary:
//!
//! * [`ChannelTransport`] — the worker pool as OS threads joined by
//!   `std::sync::mpsc` channels. Payloads cross untouched; "wire" bytes are
//!   the serialized payload lengths. This is the default for experiments
//!   and tests (deterministic, no sockets).
//! * [`super::tcp::TcpTransport`] — real sockets speaking the
//!   length-prefixed [`super::wire`] protocol to `gr-cdmm worker` daemons
//!   ([`super::daemon`]). The counted bytes are the same payload lengths
//!   (framing overhead is excluded by design), so upload/download
//!   accounting is identical across transports for the same job stream.
//!
//! [`Transport::send`] returns the payload bytes actually put on the link;
//! the coordinator credits them to the job's and its own [`ByteCounters`]
//! at that boundary. Download bytes are credited by the response router the
//! moment the transport hands a [`FromWorker`] over (see [`super::master`]).
//!
//! Counters exist at two scopes since the multi-job coordinator: every
//! in-flight job owns a [`ByteCounters`] (written by the dispatch path, the
//! response router and the job's collector), and the coordinator keeps one
//! **aggregate** instance summing all jobs over its lifetime. Counters are
//! monotone; "discarded" download is derived (`arrived − used`), so late
//! responses counted by the router can never race the collector's
//! used-bytes accounting into a negative.
//!
//! # Elastic membership
//!
//! Since the elastic-pool change the trait also models membership churn:
//! workers may be taken down ([`Transport::disconnect_worker`]), revived or
//! re-dialed ([`Transport::reconnect_worker`]), or added while the pool is
//! serving ([`Transport::add_worker`]); [`Transport::ping`] plus
//! [`Transport::link_status`] give the master the liveness/latency signal
//! its health monitor turns into live/suspect/dead verdicts (see
//! [`super::pool`]). All five have conservative default implementations so
//! simple transports (and test mocks) keep compiling: always-alive links
//! and "membership unsupported" errors.

use super::straggler::{CorruptionModel, StragglerModel};
use super::worker::{spawn_worker, worker_rng, ShareCompute};
use crate::util::bytepool::PooledBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Master → worker message.
pub enum ToWorker {
    Job {
        job_id: u64,
        /// Which shard of the job this payload is. Shard identity is fixed
        /// at submit; speculative re-dispatch may hand the *same* shard to
        /// a different worker, so the shard index — not the worker index —
        /// is what response reports carry back.
        shard: usize,
        /// `Some(prepared_id)` on a prepared job: the worker prepends its
        /// staged A-half to `payload` (which then carries only the B-half)
        /// before deserializing the share. `None` for a full-share job.
        prepared: Option<u64>,
        /// Serialized [`crate::codes::Share`] (or, on a prepared job, just
        /// its B-half), a shared [`PooledBuf`] so a speculative re-dispatch
        /// of the same shard never copies the bytes — and the storage
        /// returns to the pool when the last dispatch drops it.
        payload: PooledBuf,
    },
    /// Store a prepared operand's A-side share half under `prepared_id` so
    /// later prepared jobs can reference it. The worker acknowledges
    /// (in-process: stamping its [`WorkerLink`]; socket daemon: a
    /// stage-ack frame).
    Stage { prepared_id: u64, payload: PooledBuf },
    /// Drop a staged operand. Unknown ids are ignored.
    Evict { prepared_id: u64 },
    /// Health-check probe; the in-process worker answers by stamping its
    /// shared [`WorkerLink`] (the socket daemon answers with a pong frame).
    Ping { nonce: u64, sent: Instant },
    Shutdown,
}

/// Worker → master message.
pub struct FromWorker {
    pub job_id: u64,
    /// The **shard index** this report answers (historically equal to the
    /// worker index; under speculative re-dispatch a spare worker reports
    /// the original shard id).
    pub worker_id: usize,
    /// Serialized response matrix. `None` if the worker failed the job.
    pub payload: Option<PooledBuf>,
    /// Pure compute time at the worker (excludes injected straggler delay).
    pub compute: Duration,
    /// Injected straggler delay, for reporting.
    pub injected_delay: Duration,
}

/// The byte-free fail-stop report for one `(job, shard)`: what a worker
/// that drops a job sends, and what a transport synthesizes when a worker's
/// link dies with the job outstanding — either way the master's response
/// router hears exactly one report per dispatched copy of a shard, so job
/// retirement stays deterministic (see [`super::master`]).
pub fn fail_report(job_id: u64, worker_id: usize) -> FromWorker {
    FromWorker {
        job_id,
        worker_id,
        payload: None,
        compute: Duration::ZERO,
        injected_delay: Duration::ZERO,
    }
}

/// One worker link's liveness/latency snapshot, as observed by the
/// transport. The master's health monitor combines this with its own ping
/// bookkeeping to classify the worker live/suspect/dead.
#[derive(Clone, Copy, Debug)]
pub struct LinkStatus {
    /// The link can still carry traffic. A dead link fail-stops every job
    /// sent on it.
    pub alive: bool,
    /// Time since the transport last heard *anything* from the worker
    /// (response, pong, hello). `None` if it has never been heard from.
    pub idle: Option<Duration>,
    /// Most recent ping → pong round-trip time, if any ping was answered.
    pub last_rtt: Option<Duration>,
}

impl LinkStatus {
    /// The conservative default for transports without liveness tracking:
    /// alive, no traffic history.
    pub fn alive_unknown() -> LinkStatus {
        LinkStatus { alive: true, idle: None, last_rtt: None }
    }
}

/// An object-safe master-side link to `N` workers.
///
/// The contract the coordinator relies on:
///
/// * **per-worker FIFO** — messages sent to one worker are processed in
///   order;
/// * **exactly-one report per dispatched (job, shard) copy** — for every
///   `Job` sent, the receiver eventually yields exactly one [`FromWorker`]
///   with that `(job_id, shard)`: a real response, a worker-side failure
///   report, or a transport-synthesized fail-stop report ([`fail_report`])
///   if the link died. A permanently dead worker therefore looks exactly
///   like the fail-stop straggler model, never like a hang;
/// * **byte accounting** — [`Transport::send`] returns the payload bytes
///   actually put on the link (0 for control messages and for jobs
///   dropped because the worker's link is already dead), and response
///   payload bytes arrive uncounted for the router to credit.
pub trait Transport: Send {
    /// Number of worker slots this transport reaches (dead links included —
    /// membership grows via [`Transport::add_worker`], but slots are never
    /// removed, only marked dead).
    fn n_workers(&self) -> usize;

    /// Send one message to `worker_id`. Returns the payload bytes handed to
    /// the link. `Err` means the transport itself is broken (programming
    /// error, e.g. a worker index out of range, or an in-process worker
    /// that vanished without shutdown) — a *remote* worker dying is not an
    /// error but a fail-stop, reported through the receiver instead.
    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize>;

    /// Take the single worker → master message stream. Yields each
    /// [`FromWorker`] exactly once; the channel disconnects when the
    /// transport is shut down and every in-flight report has been
    /// delivered. Returns `None` on the second call.
    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>>;

    /// Signal shutdown to every worker and release the transport's threads
    /// and links. Idempotent; also invoked by `Drop` implementations.
    fn shutdown(&mut self);

    /// Short transport name for logs and reports (`"channel"`, `"tcp"`).
    fn name(&self) -> &'static str;

    /// Liveness/latency snapshot for one worker link. The default claims
    /// every in-range worker alive with no history, which keeps
    /// health-oblivious transports (and mocks) working.
    fn link_status(&self, worker_id: usize) -> LinkStatus {
        if worker_id < self.n_workers() {
            LinkStatus::alive_unknown()
        } else {
            LinkStatus { alive: false, idle: None, last_rtt: None }
        }
    }

    /// Fire one health-check probe at `worker_id`. Answers surface through
    /// [`Transport::link_status`] (a fresher `idle`, a new `last_rtt`), not
    /// through the receiver. The default is a successful no-op.
    fn ping(&mut self, _worker_id: usize, _nonce: u64) -> anyhow::Result<()> {
        Ok(())
    }

    /// Take worker `worker_id`'s link down. Jobs it still owes — and any
    /// sent to it afterwards — fail-stop. The default errors: membership is
    /// fixed on transports that don't override it.
    fn disconnect_worker(&mut self, _worker_id: usize) -> anyhow::Result<()> {
        anyhow::bail!("this transport does not support dynamic membership")
    }

    /// Bring worker `worker_id`'s link back up, optionally at a new
    /// endpoint (TCP re-dials; the in-process transport revives the thread
    /// and accepts no endpoint). The default errors.
    fn reconnect_worker(
        &mut self,
        _worker_id: usize,
        _endpoint: Option<&str>,
    ) -> anyhow::Result<()> {
        anyhow::bail!("this transport does not support dynamic membership")
    }

    /// Grow the pool by one worker (TCP dials `endpoint`; the in-process
    /// transport spawns a thread and accepts no endpoint). Returns the new
    /// worker's id. The default errors.
    fn add_worker(&mut self, _endpoint: Option<&str>) -> anyhow::Result<usize> {
        anyhow::bail!("this transport does not support dynamic membership")
    }
}

/// Shared, monotone counters for one scope (one job, or one coordinator
/// lifetime): byte volume on each link direction plus the number of
/// speculative re-dispatches. Cloning shares the underlying atomics.
#[derive(Clone, Default)]
pub struct ByteCounters {
    /// Total per-job bytes master → workers (share payloads; on prepared
    /// jobs only the B-half ships, so only the B-half is counted here).
    upload: Arc<AtomicU64>,
    /// Bytes of prepared A-halves staged on workers (initial staging and
    /// every re-stage after a reconnect/join). Kept out of `upload` so
    /// per-job upload accounting stays analytic.
    staged_upload: Arc<AtomicU64>,
    /// Total response bytes that reached the master (router-side count,
    /// whether or not the collector still wanted them).
    download_arrived: Arc<AtomicU64>,
    /// Bytes of responses the collector consumed for decoding (the first
    /// `need` successful responses of the job).
    download_used: Arc<AtomicU64>,
    /// Speculative shard re-dispatches (copies beyond the first dispatch of
    /// each shard). Their payload bytes are also in `upload`.
    speculative: Arc<AtomicU64>,
    /// Bytes of responses the verified-decode path rejected as corrupt
    /// (malformed or inconsistent shares). Kept out of the derived
    /// "discarded" bucket so late-but-honest and corrupt bytes are
    /// distinguishable: `arrived == used + discarded + rejected`.
    download_rejected: Arc<AtomicU64>,
}

impl ByteCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_upload(&self, n: usize) {
        self.upload.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_staged_upload(&self, n: usize) {
        self.staged_upload.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_arrived(&self, n: usize) {
        self.download_arrived.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_used(&self, n: usize) {
        self.download_used.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_speculative(&self, n: u64) {
        self.speculative.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_download_rejected(&self, n: usize) {
        self.download_rejected.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn upload_total(&self) -> u64 {
        self.upload.load(Ordering::Relaxed)
    }

    pub fn staged_upload_total(&self) -> u64 {
        self.staged_upload.load(Ordering::Relaxed)
    }

    pub fn download_arrived_total(&self) -> u64 {
        self.download_arrived.load(Ordering::Relaxed)
    }

    pub fn download_used_total(&self) -> u64 {
        self.download_used.load(Ordering::Relaxed)
    }

    pub fn speculative_total(&self) -> u64 {
        self.speculative.load(Ordering::Relaxed)
    }

    pub fn download_rejected_total(&self) -> u64 {
        self.download_rejected.load(Ordering::Relaxed)
    }

    /// Bytes that arrived after the job no longer needed them (beyond the
    /// recovery threshold, or after the job's handle was dropped). Rejected
    /// corrupt bytes have their own bucket and are excluded here, so
    /// `arrived == used + discarded + rejected` holds at every scope.
    pub fn download_discarded_total(&self) -> u64 {
        self.download_arrived_total()
            .saturating_sub(self.download_used_total())
            .saturating_sub(self.download_rejected_total())
    }
}

/// Worker-side shared state for one in-process link: the channel analogue
/// of a TCP connection's health. The master flips `dead` to take the link
/// down (the worker thread then fail-stops every job it dequeues, exactly
/// as a dead socket would); the worker stamps `last_heard`/`last_rtt` so
/// [`Transport::link_status`] mirrors the socket transport's signal.
#[derive(Default)]
pub struct WorkerLink {
    pub dead: AtomicBool,
    pub last_heard: Mutex<Option<Instant>>,
    pub last_rtt: Mutex<Option<Duration>>,
}

impl WorkerLink {
    fn new() -> WorkerLink {
        WorkerLink::default()
    }
}

/// The in-process transport: `N` worker threads running the
/// [`super::worker`] loop, one `mpsc` channel per direction. Behaviorally
/// identical to the pre-trait coordinator — per-worker RNG streams, message
/// order, byte accounting and shutdown semantics are all preserved
/// bit-for-bit — plus the full dynamic-membership surface, mirrored from
/// [`super::tcp::TcpTransport`] so every elastic scenario can be tested
/// without sockets: a disconnected worker's queued and future jobs
/// fail-stop byte-free, a reconnect revives the same worker (same RNG
/// stream, same id), and `add_worker` grows the pool mid-run.
pub struct ChannelTransport {
    compute: Arc<dyn ShareCompute>,
    straggler: StragglerModel,
    corrupt: CorruptionModel,
    seed: u64,
    senders: Vec<Sender<ToWorker>>,
    workers: Vec<JoinHandle<()>>,
    links: Vec<Arc<WorkerLink>>,
    funnel: Option<Sender<FromWorker>>,
    rx: Option<Receiver<FromWorker>>,
    shut: bool,
}

impl ChannelTransport {
    /// Spawn `n_workers` worker threads applying `compute`, with straggler
    /// injection. `seed` derives the per-worker RNG streams (worker `i`
    /// gets [`worker_rng`]`(seed, i)` — the same stream a TCP daemon
    /// serving worker `i` with the same seed would draw).
    pub fn spawn(
        n_workers: usize,
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
    ) -> ChannelTransport {
        Self::spawn_faulty(n_workers, compute, straggler, CorruptionModel::None, seed)
    }

    /// [`ChannelTransport::spawn`] with Byzantine corruption injection:
    /// workers targeted by `corrupt` mutate their response bytes after a
    /// successful compute, drawing from the same per-worker RNG streams the
    /// straggler models use (so a TCP daemon with the same seed and model
    /// corrupts identically).
    pub fn spawn_faulty(
        n_workers: usize,
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        corrupt: CorruptionModel,
        seed: u64,
    ) -> ChannelTransport {
        let (funnel, rx) = channel::<FromWorker>();
        let mut t = ChannelTransport {
            compute,
            straggler,
            corrupt,
            seed,
            senders: Vec::with_capacity(n_workers),
            workers: Vec::with_capacity(n_workers),
            links: Vec::with_capacity(n_workers),
            funnel: Some(funnel),
            rx: Some(rx),
            shut: false,
        };
        for _ in 0..n_workers {
            t.spawn_one();
        }
        t
    }

    /// Spawn the next worker thread (id = current pool size).
    fn spawn_one(&mut self) -> usize {
        let wid = self.senders.len();
        let funnel = self.funnel.as_ref().expect("pool is not shut down").clone();
        let (tx, rx) = channel::<ToWorker>();
        let link = Arc::new(WorkerLink::new());
        let handle = spawn_worker(
            wid,
            rx,
            funnel,
            Arc::clone(&self.compute),
            self.straggler.clone(),
            self.corrupt.clone(),
            worker_rng(self.seed, wid),
            Arc::clone(&link),
        );
        self.senders.push(tx);
        self.workers.push(handle);
        self.links.push(link);
        wid
    }
}

impl Transport for ChannelTransport {
    fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
        let tx = self
            .senders
            .get(worker_id)
            .ok_or_else(|| anyhow::anyhow!("worker id {worker_id} out of range"))?;
        let len = match &msg {
            ToWorker::Job { payload, .. } | ToWorker::Stage { payload, .. } => payload.len(),
            ToWorker::Evict { .. } | ToWorker::Ping { .. } | ToWorker::Shutdown => 0,
        };
        if self.links[worker_id].dead.load(Ordering::Relaxed) {
            match &msg {
                ToWorker::Job { job_id, shard, .. } => {
                    // Dead link = fail-stop worker: the payload never
                    // crosses (0 bytes, exactly like a dead socket) and the
                    // master still hears one byte-free report for this
                    // dispatch.
                    let report = fail_report(*job_id, *shard);
                    if let Some(funnel) = &self.funnel {
                        let _ = funnel.send(report);
                    }
                    return Ok(0);
                }
                // Staging traffic to a dead link is silently lost, exactly
                // like a dead socket; the master re-stages on reconnect.
                ToWorker::Stage { .. } | ToWorker::Evict { .. } => return Ok(0),
                ToWorker::Ping { .. } | ToWorker::Shutdown => {}
            }
        }
        // An in-process worker only hangs up by panicking (or after
        // shutdown): that is a broken transport, not a fail-stop.
        anyhow::ensure!(tx.send(msg).is_ok(), "worker {worker_id} hung up");
        Ok(len)
    }

    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
        self.rx.take()
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        // Queued jobs are still processed and replied to before each worker
        // sees the shutdown message (per-worker FIFO).
        for tx in self.senders.drain(..) {
            let _ = tx.send(ToWorker::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Only now does the router's stream disconnect: every synthesized
        // and worker-sent report has been delivered.
        self.funnel = None;
    }

    fn name(&self) -> &'static str {
        "channel"
    }

    fn link_status(&self, worker_id: usize) -> LinkStatus {
        match self.links.get(worker_id) {
            Some(link) => LinkStatus {
                alive: !link.dead.load(Ordering::Relaxed),
                idle: link.last_heard.lock().unwrap().map(|t| t.elapsed()),
                last_rtt: *link.last_rtt.lock().unwrap(),
            },
            None => LinkStatus { alive: false, idle: None, last_rtt: None },
        }
    }

    fn ping(&mut self, worker_id: usize, nonce: u64) -> anyhow::Result<()> {
        // A dead worker swallows the probe (simulated silence); the link
        // status already reports it dead.
        self.send(worker_id, ToWorker::Ping { nonce, sent: Instant::now() })?;
        Ok(())
    }

    fn disconnect_worker(&mut self, worker_id: usize) -> anyhow::Result<()> {
        let link = self
            .links
            .get(worker_id)
            .ok_or_else(|| anyhow::anyhow!("worker id {worker_id} out of range"))?;
        link.dead.store(true, Ordering::Relaxed);
        Ok(())
    }

    fn reconnect_worker(&mut self, worker_id: usize, endpoint: Option<&str>) -> anyhow::Result<()> {
        anyhow::ensure!(
            endpoint.is_none(),
            "channel transport has no endpoints; reconnect revives the in-process worker"
        );
        anyhow::ensure!(!self.shut, "transport is shut down");
        let link = self
            .links
            .get(worker_id)
            .ok_or_else(|| anyhow::anyhow!("worker id {worker_id} out of range"))?;
        link.dead.store(false, Ordering::Relaxed);
        Ok(())
    }

    fn add_worker(&mut self, endpoint: Option<&str>) -> anyhow::Result<usize> {
        anyhow::ensure!(
            endpoint.is_none(),
            "channel transport has no endpoints; add_worker spawns an in-process worker"
        );
        anyhow::ensure!(!self.shut, "transport is shut down");
        Ok(self.spawn_one())
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        Transport::shutdown(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(job_id: u64, shard: usize, payload: Vec<u8>) -> ToWorker {
        ToWorker::Job { job_id, shard, prepared: None, payload: payload.into() }
    }

    #[test]
    fn counters_accumulate() {
        let c = ByteCounters::new();
        c.add_upload(100);
        c.add_upload(20);
        c.add_download_arrived(10);
        c.add_download_used(7);
        c.add_speculative(2);
        assert_eq!(c.upload_total(), 120);
        assert_eq!(c.download_arrived_total(), 10);
        assert_eq!(c.download_used_total(), 7);
        assert_eq!(c.download_discarded_total(), 3);
        assert_eq!(c.speculative_total(), 2);
    }

    #[test]
    fn counters_shared_across_clones() {
        let c = ByteCounters::new();
        let c2 = c.clone();
        c2.add_upload(42);
        assert_eq!(c.upload_total(), 42);
    }

    #[test]
    fn discarded_never_underflows() {
        // The collector may count a response as used before the router's
        // arrived increment is observed; discarded saturates at 0.
        let c = ByteCounters::new();
        c.add_download_used(5);
        assert_eq!(c.download_discarded_total(), 0);
    }

    #[test]
    fn rejected_bytes_have_their_own_bucket() {
        // arrived == used + discarded + rejected: corrupt responses leave
        // the derived discarded bucket untouched.
        let c = ByteCounters::new();
        c.add_download_arrived(100);
        c.add_download_used(60);
        c.add_download_rejected(30);
        assert_eq!(c.download_rejected_total(), 30);
        assert_eq!(c.download_discarded_total(), 10);
        assert_eq!(
            c.download_arrived_total(),
            c.download_used_total() + c.download_discarded_total() + c.download_rejected_total()
        );
    }

    #[test]
    fn faulty_spawn_corrupts_targeted_workers_only() {
        let corrupt = CorruptionModel::garbage_payload([1]);
        let mut t = ChannelTransport::spawn_faulty(
            2,
            Arc::new(Echo),
            StragglerModel::None,
            corrupt,
            7,
        );
        let rx = t.take_receiver().unwrap();
        let payload = vec![0x42u8; 24];
        t.send(0, job(1, 0, payload.clone())).unwrap();
        t.send(1, job(1, 1, payload.clone())).unwrap();
        let mut by_shard = [None, None];
        for _ in 0..2 {
            let msg = rx.recv().unwrap();
            by_shard[msg.worker_id] = msg.payload;
        }
        assert_eq!(by_shard[0].as_deref(), Some(&payload[..]), "worker 0 is clean");
        let bad = by_shard[1].clone().unwrap();
        assert_eq!(bad.len(), payload.len(), "garbage keeps the length (well-formed-looking)");
        assert_ne!(bad, payload, "worker 1's response is corrupted");
        Transport::shutdown(&mut t);
    }

    /// Echo backend for transport-level tests.
    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<PooledBuf> {
            Ok(payload.to_vec().into())
        }
    }

    #[test]
    fn channel_transport_round_trips_and_reports_sent_bytes() {
        let mut t = ChannelTransport::spawn(2, Arc::new(Echo), StragglerModel::None, 1);
        assert_eq!(t.n_workers(), 2);
        assert_eq!(t.name(), "channel");
        let rx = t.take_receiver().expect("first take yields the receiver");
        assert!(t.take_receiver().is_none(), "receiver can only be taken once");
        let sent = t.send(0, job(9, 0, vec![5u8; 33])).unwrap();
        assert_eq!(sent, 33);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (9, 0));
        assert_eq!(msg.payload.as_ref().map(PooledBuf::len), Some(33));
        assert!(t.send(5, ToWorker::Shutdown).is_err(), "out-of-range worker id");
        Transport::shutdown(&mut t);
        assert!(rx.recv().is_err(), "stream disconnects after shutdown");
    }

    #[test]
    fn channel_transport_fail_stop_workers_report_byte_free() {
        let straggler = StragglerModel::fail_stop([0]);
        let mut t = ChannelTransport::spawn(1, Arc::new(Echo), straggler, 2);
        let rx = t.take_receiver().unwrap();
        let sent = t.send(0, job(4, 0, vec![1u8; 10])).unwrap();
        // the payload crossed the link (and is counted) even though the
        // worker will drop the job
        assert_eq!(sent, 10);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (4, 0));
        assert!(msg.payload.is_none());
        Transport::shutdown(&mut t);
    }

    #[test]
    fn disconnected_worker_fail_stops_byte_free_and_reconnect_revives_it() {
        let mut t = ChannelTransport::spawn(2, Arc::new(Echo), StragglerModel::None, 3);
        let rx = t.take_receiver().unwrap();
        t.disconnect_worker(0).unwrap();
        assert!(!t.link_status(0).alive);
        assert!(t.link_status(1).alive);

        // A job to the dead link: 0 bytes cross, one byte-free report.
        let sent = t.send(0, job(1, 0, vec![7u8; 16])).unwrap();
        assert_eq!(sent, 0);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (1, 0));
        assert!(msg.payload.is_none());

        // Revive and serve again (same worker id, same RNG stream).
        t.reconnect_worker(0, None).unwrap();
        assert!(t.link_status(0).alive);
        let sent = t.send(0, job(2, 0, vec![7u8; 16])).unwrap();
        assert_eq!(sent, 16);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (2, 0));
        assert_eq!(msg.payload.as_ref().map(PooledBuf::len), Some(16));

        // Endpoints are a TCP concept.
        assert!(t.reconnect_worker(0, Some("127.0.0.1:1")).is_err());
        Transport::shutdown(&mut t);
    }

    #[test]
    fn staging_counts_bytes_on_live_links_and_drops_silently_on_dead_ones() {
        let mut t = ChannelTransport::spawn(2, Arc::new(Echo), StragglerModel::None, 6);
        let rx = t.take_receiver().unwrap();
        // Live link: the staged bytes cross and are reported for the
        // staged_upload counter.
        let stage = ToWorker::Stage { prepared_id: 1, payload: vec![0xA; 24].into() };
        assert_eq!(t.send(0, stage).unwrap(), 24);
        // Dead link: staging traffic is silently lost (no synthesized
        // report — only jobs owe one), 0 bytes.
        t.disconnect_worker(1).unwrap();
        let stage = ToWorker::Stage { prepared_id: 1, payload: vec![0xA; 24].into() };
        assert_eq!(t.send(1, stage).unwrap(), 0);
        assert_eq!(t.send(1, ToWorker::Evict { prepared_id: 1 }).unwrap(), 0);
        // Worker 0 serves a prepared job from its staged half.
        let msg = ToWorker::Job {
            job_id: 3,
            shard: 0,
            prepared: Some(1),
            payload: vec![0xB; 8].into(),
        };
        assert_eq!(t.send(0, msg).unwrap(), 8, "only the B-half crosses per job");
        let reply = rx.recv().unwrap();
        assert_eq!(
            reply.payload.as_ref().map(PooledBuf::len),
            Some(32),
            "staged ++ payload computed"
        );
        // Evict on a live link costs nothing and unstages.
        assert_eq!(t.send(0, ToWorker::Evict { prepared_id: 1 }).unwrap(), 0);
        let msg = ToWorker::Job {
            job_id: 4,
            shard: 0,
            prepared: Some(1),
            payload: vec![0xB; 8].into(),
        };
        t.send(0, msg).unwrap();
        assert!(rx.recv().unwrap().payload.is_none(), "evicted id fail-stops");
        Transport::shutdown(&mut t);
    }

    #[test]
    fn add_worker_grows_the_pool_mid_run() {
        let mut t = ChannelTransport::spawn(1, Arc::new(Echo), StragglerModel::None, 4);
        let rx = t.take_receiver().unwrap();
        assert_eq!(t.add_worker(None).unwrap(), 1);
        assert_eq!(t.n_workers(), 2);
        let sent = t.send(1, job(8, 1, vec![9u8; 12])).unwrap();
        assert_eq!(sent, 12);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (8, 1));
        assert!(t.add_worker(Some("127.0.0.1:1")).is_err(), "endpoints are TCP-only");
        Transport::shutdown(&mut t);
    }

    #[test]
    fn ping_surfaces_rtt_and_freshness_through_link_status() {
        let mut t = ChannelTransport::spawn(1, Arc::new(Echo), StragglerModel::None, 5);
        let _rx = t.take_receiver().unwrap();
        assert!(t.link_status(0).idle.is_none(), "never heard from yet");
        t.ping(0, 99).unwrap();
        // The worker thread answers asynchronously; wait for the stamp.
        let deadline = Instant::now() + Duration::from_secs(5);
        while t.link_status(0).last_rtt.is_none() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let status = t.link_status(0);
        assert!(status.alive);
        assert!(status.last_rtt.is_some(), "pong stamps the round-trip time");
        assert!(status.idle.is_some(), "heard from since the ping");
        Transport::shutdown(&mut t);
    }
}
