//! The pluggable master ↔ worker transport: message types, the object-safe
//! [`Transport`] trait, byte accounting, and the in-process
//! [`ChannelTransport`].
//!
//! The paper's evaluation measures communication *volume*, and volume is
//! preserved exactly by counting the serialized payload bytes crossing each
//! link — so both transports account the same quantity at the same
//! boundary:
//!
//! * [`ChannelTransport`] — the worker pool as OS threads joined by
//!   `std::sync::mpsc` channels. Payloads cross untouched; "wire" bytes are
//!   the serialized payload lengths. This is the default for experiments
//!   and tests (deterministic, no sockets).
//! * [`super::tcp::TcpTransport`] — real sockets speaking the
//!   length-prefixed [`super::wire`] protocol to `gr-cdmm worker` daemons
//!   ([`super::daemon`]). The counted bytes are the same payload lengths
//!   (framing overhead is excluded by design), so upload/download
//!   accounting is identical across transports for the same job stream.
//!
//! [`Transport::send`] returns the payload bytes actually put on the link;
//! the coordinator credits them to the job's and its own [`ByteCounters`]
//! at that boundary. Download bytes are credited by the response router the
//! moment the transport hands a [`FromWorker`] over (see [`super::master`]).
//!
//! Counters exist at two scopes since the multi-job coordinator: every
//! in-flight job owns a [`ByteCounters`] (written by the dispatch path, the
//! response router and the job's collector), and the coordinator keeps one
//! **aggregate** instance summing all jobs over its lifetime. Counters are
//! monotone; "discarded" download is derived (`arrived − used`), so late
//! responses counted by the router can never race the collector's
//! used-bytes accounting into a negative.

use super::straggler::StragglerModel;
use super::worker::{spawn_worker, worker_rng, ShareCompute};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Master → worker message.
pub enum ToWorker {
    Job {
        job_id: u64,
        /// Serialized [`crate::codes::Share`].
        payload: Vec<u8>,
    },
    Shutdown,
}

/// Worker → master message.
pub struct FromWorker {
    pub job_id: u64,
    pub worker_id: usize,
    /// Serialized response matrix. `None` if the worker failed the job.
    pub payload: Option<Vec<u8>>,
    /// Pure compute time at the worker (excludes injected straggler delay).
    pub compute: Duration,
    /// Injected straggler delay, for reporting.
    pub injected_delay: Duration,
}

/// The byte-free fail-stop report for one `(job, worker)`: what a worker
/// that drops a job sends, and what a transport synthesizes when a worker's
/// link dies with the job outstanding — either way the master's response
/// router hears from every worker exactly once per job, so job retirement
/// stays deterministic (see [`super::master`]).
pub fn fail_report(job_id: u64, worker_id: usize) -> FromWorker {
    FromWorker {
        job_id,
        worker_id,
        payload: None,
        compute: Duration::ZERO,
        injected_delay: Duration::ZERO,
    }
}

/// An object-safe master-side link to `N` workers.
///
/// The contract the coordinator relies on:
///
/// * **per-worker FIFO** — messages sent to one worker are processed in
///   order;
/// * **exactly-one report per (job, worker)** — for every `Job` sent, the
///   receiver eventually yields exactly one [`FromWorker`] with that
///   `(job_id, worker_id)`: a real response, a worker-side failure report,
///   or a transport-synthesized fail-stop report ([`fail_report`]) if the
///   link died. A permanently dead worker therefore looks exactly like the
///   fail-stop straggler model, never like a hang;
/// * **byte accounting** — [`Transport::send`] returns the payload bytes
///   actually put on the link (0 for control messages and for jobs
///   dropped because the worker's link is already dead), and response
///   payload bytes arrive uncounted for the router to credit.
pub trait Transport: Send {
    /// Number of workers this transport reaches.
    fn n_workers(&self) -> usize;

    /// Send one message to `worker_id`. Returns the payload bytes handed to
    /// the link. `Err` means the transport itself is broken (programming
    /// error, e.g. a worker index out of range, or an in-process worker
    /// that vanished without shutdown) — a *remote* worker dying is not an
    /// error but a fail-stop, reported through the receiver instead.
    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize>;

    /// Take the single worker → master message stream. Yields each
    /// [`FromWorker`] exactly once; the channel disconnects when the
    /// transport is shut down and every in-flight report has been
    /// delivered. Returns `None` on the second call.
    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>>;

    /// Signal shutdown to every worker and release the transport's threads
    /// and links. Idempotent; also invoked by `Drop` implementations.
    fn shutdown(&mut self);

    /// Short transport name for logs and reports (`"channel"`, `"tcp"`).
    fn name(&self) -> &'static str;
}

/// Shared, monotone byte counters for one scope (one job, or one
/// coordinator lifetime). Cloning shares the underlying atomics.
#[derive(Clone, Default)]
pub struct ByteCounters {
    /// Total bytes master → workers.
    upload: Arc<AtomicU64>,
    /// Total response bytes that reached the master (router-side count,
    /// whether or not the collector still wanted them).
    download_arrived: Arc<AtomicU64>,
    /// Bytes of responses the collector consumed for decoding (the first
    /// `need` successful responses of the job).
    download_used: Arc<AtomicU64>,
}

impl ByteCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_upload(&self, n: usize) {
        self.upload.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_arrived(&self, n: usize) {
        self.download_arrived.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_used(&self, n: usize) {
        self.download_used.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn upload_total(&self) -> u64 {
        self.upload.load(Ordering::Relaxed)
    }

    pub fn download_arrived_total(&self) -> u64 {
        self.download_arrived.load(Ordering::Relaxed)
    }

    pub fn download_used_total(&self) -> u64 {
        self.download_used.load(Ordering::Relaxed)
    }

    /// Bytes that arrived after the job no longer needed them (beyond the
    /// recovery threshold, or after the job's handle was dropped).
    pub fn download_discarded_total(&self) -> u64 {
        self.download_arrived_total().saturating_sub(self.download_used_total())
    }
}

/// The in-process transport: `N` worker threads running the
/// [`super::worker`] loop, one `mpsc` channel per direction. Behaviorally
/// identical to the pre-trait coordinator — per-worker RNG streams, message
/// order, byte accounting and shutdown semantics are all preserved
/// bit-for-bit.
pub struct ChannelTransport {
    senders: Vec<Sender<ToWorker>>,
    workers: Vec<JoinHandle<()>>,
    rx: Option<Receiver<FromWorker>>,
    shut: bool,
}

impl ChannelTransport {
    /// Spawn `n_workers` worker threads applying `compute`, with straggler
    /// injection. `seed` derives the per-worker RNG streams (worker `i`
    /// gets [`worker_rng`]`(seed, i)` — the same stream a TCP daemon
    /// serving worker `i` with the same seed would draw).
    pub fn spawn(
        n_workers: usize,
        compute: Arc<dyn ShareCompute>,
        straggler: StragglerModel,
        seed: u64,
    ) -> ChannelTransport {
        let (resp_tx, resp_rx) = channel::<FromWorker>();
        let mut senders = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let (tx, rx) = channel::<ToWorker>();
            let handle = spawn_worker(
                wid,
                rx,
                resp_tx.clone(),
                Arc::clone(&compute),
                straggler.clone(),
                worker_rng(seed, wid),
            );
            senders.push(tx);
            workers.push(handle);
        }
        // Workers hold the only response senders: the receiver disconnects
        // exactly when the last worker exits.
        drop(resp_tx);
        ChannelTransport { senders, workers, rx: Some(resp_rx), shut: false }
    }
}

impl Transport for ChannelTransport {
    fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn send(&mut self, worker_id: usize, msg: ToWorker) -> anyhow::Result<usize> {
        let len = match &msg {
            ToWorker::Job { payload, .. } => payload.len(),
            ToWorker::Shutdown => 0,
        };
        let tx = self
            .senders
            .get(worker_id)
            .ok_or_else(|| anyhow::anyhow!("worker id {worker_id} out of range"))?;
        // An in-process worker only hangs up by panicking (or after
        // shutdown): that is a broken transport, not a fail-stop.
        anyhow::ensure!(tx.send(msg).is_ok(), "worker {worker_id} hung up");
        Ok(len)
    }

    fn take_receiver(&mut self) -> Option<Receiver<FromWorker>> {
        self.rx.take()
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        // Queued jobs are still processed and replied to before each worker
        // sees the shutdown message (per-worker FIFO).
        for tx in self.senders.drain(..) {
            let _ = tx.send(ToWorker::Shutdown);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    fn name(&self) -> &'static str {
        "channel"
    }
}

impl Drop for ChannelTransport {
    fn drop(&mut self) {
        Transport::shutdown(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = ByteCounters::new();
        c.add_upload(100);
        c.add_upload(20);
        c.add_download_arrived(10);
        c.add_download_used(7);
        assert_eq!(c.upload_total(), 120);
        assert_eq!(c.download_arrived_total(), 10);
        assert_eq!(c.download_used_total(), 7);
        assert_eq!(c.download_discarded_total(), 3);
    }

    #[test]
    fn counters_shared_across_clones() {
        let c = ByteCounters::new();
        let c2 = c.clone();
        c2.add_upload(42);
        assert_eq!(c.upload_total(), 42);
    }

    #[test]
    fn discarded_never_underflows() {
        // The collector may count a response as used before the router's
        // arrived increment is observed; discarded saturates at 0.
        let c = ByteCounters::new();
        c.add_download_used(5);
        assert_eq!(c.download_discarded_total(), 0);
    }

    /// Echo backend for transport-level tests.
    struct Echo;
    impl ShareCompute for Echo {
        fn compute(&self, _w: usize, payload: &[u8]) -> anyhow::Result<Vec<u8>> {
            Ok(payload.to_vec())
        }
    }

    #[test]
    fn channel_transport_round_trips_and_reports_sent_bytes() {
        let mut t = ChannelTransport::spawn(2, Arc::new(Echo), StragglerModel::None, 1);
        assert_eq!(t.n_workers(), 2);
        assert_eq!(t.name(), "channel");
        let rx = t.take_receiver().expect("first take yields the receiver");
        assert!(t.take_receiver().is_none(), "receiver can only be taken once");
        let sent = t.send(0, ToWorker::Job { job_id: 9, payload: vec![5u8; 33] }).unwrap();
        assert_eq!(sent, 33);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (9, 0));
        assert_eq!(msg.payload.as_ref().map(Vec::len), Some(33));
        assert!(t.send(5, ToWorker::Shutdown).is_err(), "out-of-range worker id");
        Transport::shutdown(&mut t);
        assert!(rx.recv().is_err(), "stream disconnects after shutdown");
    }

    #[test]
    fn channel_transport_fail_stop_workers_report_byte_free() {
        let straggler = StragglerModel::fail_stop([0]);
        let mut t = ChannelTransport::spawn(1, Arc::new(Echo), straggler, 2);
        let rx = t.take_receiver().unwrap();
        let sent = t.send(0, ToWorker::Job { job_id: 4, payload: vec![1u8; 10] }).unwrap();
        // the payload crossed the link (and is counted) even though the
        // worker will drop the job
        assert_eq!(sent, 10);
        let msg = rx.recv().unwrap();
        assert_eq!((msg.job_id, msg.worker_id), (4, 0));
        assert!(msg.payload.is_none());
        Transport::shutdown(&mut t);
    }
}
