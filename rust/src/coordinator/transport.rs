//! Message types and byte-accounted links between master and workers.
//!
//! Transport is in-process (`std::sync::mpsc`) — the paper's evaluation
//! measures communication *volume*, not bandwidth, and volume is preserved
//! exactly by counting the serialized payload bytes crossing each link.
//! Every payload that would cross a network in a deployment crosses a
//! counted channel here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Master → worker message.
pub enum ToWorker {
    Job {
        job_id: u64,
        /// Serialized [`crate::codes::Share`].
        payload: Vec<u8>,
    },
    Shutdown,
}

/// Worker → master message.
pub struct FromWorker {
    pub job_id: u64,
    pub worker_id: usize,
    /// Serialized response matrix. `None` if the worker failed the job.
    pub payload: Option<Vec<u8>>,
    /// Pure compute time at the worker (excludes injected straggler delay).
    pub compute: Duration,
    /// Injected straggler delay, for reporting.
    pub injected_delay: Duration,
}

/// Shared byte counters for one coordinator (all links).
#[derive(Clone, Default)]
pub struct ByteCounters {
    /// Total bytes master → workers.
    pub upload: Arc<AtomicU64>,
    /// Total bytes workers → master *that the master consumed for decoding*.
    pub download_used: Arc<AtomicU64>,
    /// Bytes from responses that arrived after the recovery threshold was met.
    pub download_discarded: Arc<AtomicU64>,
}

impl ByteCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_upload(&self, n: usize) {
        self.upload.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_used(&self, n: usize) {
        self.download_used.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_download_discarded(&self, n: usize) {
        self.download_discarded.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn upload_total(&self) -> u64 {
        self.upload.load(Ordering::Relaxed)
    }

    pub fn download_used_total(&self) -> u64 {
        self.download_used.load(Ordering::Relaxed)
    }

    pub fn download_discarded_total(&self) -> u64 {
        self.download_discarded.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.upload.store(0, Ordering::Relaxed);
        self.download_used.store(0, Ordering::Relaxed);
        self.download_discarded.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_reset() {
        let c = ByteCounters::new();
        c.add_upload(100);
        c.add_upload(20);
        c.add_download_used(7);
        c.add_download_discarded(3);
        assert_eq!(c.upload_total(), 120);
        assert_eq!(c.download_used_total(), 7);
        assert_eq!(c.download_discarded_total(), 3);
        c.reset();
        assert_eq!(c.upload_total(), 0);
    }

    #[test]
    fn counters_shared_across_clones() {
        let c = ByteCounters::new();
        let c2 = c.clone();
        c2.add_upload(42);
        assert_eq!(c.upload_total(), 42);
    }
}
