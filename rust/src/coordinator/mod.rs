//! The L3 distributed runtime: a master node and a pool of `N` worker
//! nodes behind a pluggable [`Transport`] — a **pipelined serving layer**
//! with any number of jobs in flight, over in-process channels or real TCP
//! sockets.
//!
//! The paper's system model (§I, §V.A): a master encodes, uploads one share
//! per worker, workers compute their small product, and the master decodes
//! from the first `R` responses — stragglers beyond the fastest `R` are
//! simply never waited for. This module reproduces that model faithfully
//! and extends it to the serving setting the paper motivates: requests
//! overlap, so worker queues never idle between jobs, and the master/worker
//! boundary is a real wire when workers are separate OS processes.
//!
//! * [`transport`] — message types, the object-safe [`Transport`] trait
//!   (per-worker FIFO sends + one worker→master stream + exactly-one
//!   report per dispatched shard copy), exact per-link byte accounting, and the
//!   in-process [`ChannelTransport`] (the paper reports communication
//!   *volume*; we count serialized payload bytes on the link, which matches
//!   the schemes' analytic `upload_bytes`/`download_bytes` — asserted in
//!   tests, and asserted *equal across transports* in
//!   `tests/integration_transport.rs`);
//! * [`wire`] — the length-prefixed, versioned binary framing TCP peers
//!   speak (magic/version/kind header, job + worker ids, compute/delay
//!   micros, validated payload length);
//! * [`tcp`] — [`TcpTransport`]: one socket per worker to a `gr-cdmm
//!   worker` daemon; disconnects and malformed peers degrade to fail-stop
//!   (synthetic byte-free reports), never hangs or panics;
//! * [`shm`] — [`ShmTransport`]: the same-host zero-copy variant — control
//!   frames ride TCP but payloads travel out-of-line through file-backed
//!   ring buffers both processes share by path, preserving the full
//!   fail-stop / duplicate-guard / byte-accounting contract (per-job
//!   counters are identical across channel, tcp and shm);
//! * [`daemon`] — the worker daemon behind `gr-cdmm worker --listen ADDR`:
//!   the same worker loop, served over a socket, straggler injection
//!   included ([`WorkerDaemon`] runs one on a thread for tests/benches);
//! * [`straggler`] — delay/failure injection models (fixed slow set,
//!   exponential tails, fail-stop) and Byzantine corruption models
//!   ([`CorruptionModel`]: bit-flip, garbage payload, stale replay, silent
//!   wrong share) with deterministic per-worker draws on both transports;
//! * [`worker`] — the worker job handler ([`worker::process_job`]: receive
//!   share → compute (native ring kernels or the AOT XLA backend from
//!   [`crate::runtime`]) → reply), shared verbatim by pool threads and
//!   daemons;
//! * [`master`] — the multi-job coordinator: [`Coordinator::submit`]
//!   dispatches a job without blocking and returns a [`JobHandle`]; a
//!   response-router thread routes every worker reply to its owning job by
//!   `job_id`, dropping duplicate or impersonated responses; a monitor
//!   thread pings workers, tracks membership, and (when enabled)
//!   speculatively re-dispatches overdue shards to healthy spares;
//!   [`Coordinator::prepare`] + [`Coordinator::submit_prepared`] are the
//!   encode-once serving path: a fixed A-operand's share halves are staged
//!   on the workers once and every subsequent job ships only its B-halves;
//! * [`prepared`] — the master-side [`PreparedStore`]: the bounded
//!   (LRU-evicting) registry of staged operands, re-pushed automatically
//!   whenever a worker link is re-established, with hit/miss/eviction
//!   stats mirroring the decode-plan cache's;
//! * [`pool`] — elastic-membership state: per-worker
//!   [`WorkerHealth`](pool::WorkerHealth) (live / suspect / dead), latency
//!   EWMAs feeding the speculation deadline, ping bookkeeping, and the
//!   [`ElasticConfig`](pool::ElasticConfig) knobs that govern health-check
//!   cadence and re-dispatch policy;
//! * [`metrics`] — the timing/volume breakdown the evaluation section plots
//!   (encode / upload / worker compute / download / decode), plus the
//!   decode-plan cache hit/miss counters;
//! * [`runner`] — glue that runs a [`DmmScheme`](crate::codes::DmmScheme)
//!   job (typed, single or batch) or an erased
//!   [`DynScheme`](crate::codes::DynScheme) job end-to-end on a pool, the
//!   single native worker backend ([`NativeCompute`](runner::NativeCompute)),
//!   and [`runner::make_coordinator`] — in-process pool or `--connect`
//!   endpoints from one call.
//!
//! # The `JobHandle` lifecycle
//!
//! ```text
//! submit(payloads, need) ──► JobHandle           (dispatch; deadline starts)
//!        │                      │
//!        │   router thread ───► │  responses routed by job_id, bytes
//!        │                      │  attributed to the job's counters
//!        │                      ▼
//!        │            wait() / try_wait() ──► (Vec<Collected>, wait_for_R)
//!        │                      │
//!        └── drop (any time) ───┴─► job retired; late responses counted
//!                                   as discarded against this job
//! ```
//!
//! 1. **Submit.** [`Coordinator::submit`] registers the job in the shared
//!    job table *before* dispatching, so no response can beat the entry,
//!    and returns immediately. Any number of jobs may be in flight; submit
//!    order and collection order are independent.
//! 2. **Route.** The router thread owns the transport's single
//!    worker→master stream and forwards each [`transport::FromWorker`] to
//!    the owning job's private channel. A straggler answering an old job
//!    while newer jobs collect is attributed to *its* job — never discarded
//!    as "stale", and never misread by another job's collector. A worker is
//!    heard at most once per job: duplicates are dropped before they can
//!    reach a decoder.
//! 3. **Collect.** [`JobHandle::wait`] blocks (with a per-job timeout,
//!    default [`Coordinator::timeout`] at submit time) until the first
//!    `need` successful responses arrived; [`JobHandle::try_wait`] is the
//!    polling variant for multiplexed serving loops. Worker-side failures
//!    are invisible to collection (like silence on a network) but let the
//!    collector fail fast once the threshold is provably unreachable. A
//!    worker whose *connection* dies looks exactly the same — the transport
//!    synthesizes the byte-free failure report.
//! 4. **Retire.** Once every shard is resolved (success, exhausted
//!    failure, fail-stop report, or transport-synthesized disconnect
//!    report — with speculation on, the *first* copy to succeed resolves
//!    the shard and later copies are dropped as duplicates), the router
//!    retires the table entry — the table is bounded by the number of
//!    genuinely in-flight jobs. Dropping the handle early just stops
//!    forwarding; accounting continues.
//!
//! [`Coordinator`] implements `Drop` (shut the transport down + join the
//! router), so early `?` returns and panicking tests never leak the
//! pool/router threads; [`Coordinator::shutdown`] remains the explicit
//! happy path.

pub mod transport;
pub mod wire;
pub mod tcp;
pub mod shm;
pub mod daemon;
pub mod straggler;
pub mod worker;
pub mod master;
pub mod metrics;
pub mod pool;
pub mod prepared;
pub mod runner;

pub use daemon::{DaemonConfig, WorkerDaemon};
pub use master::{Coordinator, JobHandle};
pub use prepared::{PreparedStore, DEFAULT_PREPARED_CAP};
pub use metrics::JobMetrics;
pub use pool::{ElasticConfig, WorkerHealth, WorkerSnapshot};
pub use straggler::{CorruptionModel, StragglerModel};
pub use runner::{
    run_batch, run_erased, run_single, run_verified_erased, NativeCompute, VerifyOptions,
};
pub use shm::ShmTransport;
pub use tcp::TcpTransport;
pub use transport::{ByteCounters, ChannelTransport, Transport};
pub use worker::ShareCompute;
