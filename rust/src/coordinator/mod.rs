//! The L3 distributed runtime: a master node and a pool of worker nodes on
//! OS threads, joined by byte-accounted channels.
//!
//! The paper's system model (§I, §V.A): a master encodes, uploads one share
//! per worker, workers compute their small product, and the master decodes
//! from the first `R` responses — stragglers beyond the fastest `R` are
//! simply never waited for. This module reproduces that model faithfully:
//!
//! * [`transport`] — message types and exact per-link byte accounting (the
//!   paper reports communication *volume*; we count serialized bytes on the
//!   wire, which matches the schemes' analytic `upload_bytes`/`download_bytes`
//!   — asserted in tests);
//! * [`straggler`] — delay/failure injection models (fixed slow set,
//!   exponential tails, fail-stop);
//! * [`worker`] — the worker loop: receive share → compute (native ring
//!   kernels or the AOT XLA backend from [`crate::runtime`]) → reply;
//! * [`master`] — the coordinator: dispatch, first-`R` collection, timeout
//!   handling;
//! * [`metrics`] — the timing/volume breakdown the evaluation section plots
//!   (encode / upload / worker compute / download / decode);
//! * [`runner`] — glue that runs a [`DmmScheme`](crate::codes::DmmScheme)
//!   job (typed, single or batch) or an erased
//!   [`DynScheme`](crate::codes::DynScheme) job end-to-end on a pool, plus
//!   the single native worker backend
//!   ([`NativeCompute`](runner::NativeCompute)).

pub mod transport;
pub mod straggler;
pub mod worker;
pub mod master;
pub mod metrics;
pub mod runner;

pub use master::Coordinator;
pub use metrics::JobMetrics;
pub use straggler::StragglerModel;
pub use runner::{run_batch, run_erased, run_single, NativeCompute};
pub use worker::ShareCompute;
