//! The L3 distributed runtime: a master node and a pool of worker nodes on
//! OS threads, joined by byte-accounted channels — now a **pipelined
//! serving layer** with any number of jobs in flight.
//!
//! The paper's system model (§I, §V.A): a master encodes, uploads one share
//! per worker, workers compute their small product, and the master decodes
//! from the first `R` responses — stragglers beyond the fastest `R` are
//! simply never waited for. This module reproduces that model faithfully
//! and extends it to the serving setting the paper motivates: requests
//! overlap, so worker queues never idle between jobs.
//!
//! * [`transport`] — message types and exact per-link byte accounting (the
//!   paper reports communication *volume*; we count serialized bytes on the
//!   wire, which matches the schemes' analytic `upload_bytes`/`download_bytes`
//!   — asserted in tests). Counters exist per job and aggregated per
//!   coordinator;
//! * [`straggler`] — delay/failure injection models (fixed slow set,
//!   exponential tails, fail-stop);
//! * [`worker`] — the worker loop: receive share → compute (native ring
//!   kernels or the AOT XLA backend from [`crate::runtime`]) → reply;
//! * [`master`] — the multi-job coordinator: [`Coordinator::submit`]
//!   dispatches a job without blocking and returns a [`JobHandle`]; a
//!   response-router thread routes every worker reply to its owning job by
//!   `job_id`;
//! * [`metrics`] — the timing/volume breakdown the evaluation section plots
//!   (encode / upload / worker compute / download / decode), plus the
//!   decode-plan cache hit/miss counters;
//! * [`runner`] — glue that runs a [`DmmScheme`](crate::codes::DmmScheme)
//!   job (typed, single or batch) or an erased
//!   [`DynScheme`](crate::codes::DynScheme) job end-to-end on a pool, plus
//!   the single native worker backend
//!   ([`NativeCompute`](runner::NativeCompute)).
//!
//! # The `JobHandle` lifecycle
//!
//! ```text
//! submit(payloads, need) ──► JobHandle           (dispatch; deadline starts)
//!        │                      │
//!        │   router thread ───► │  responses routed by job_id, bytes
//!        │                      │  attributed to the job's counters
//!        │                      ▼
//!        │            wait() / try_wait() ──► (Vec<Collected>, wait_for_R)
//!        │                      │
//!        └── drop (any time) ───┴─► job retired; late responses counted
//!                                   as discarded against this job
//! ```
//!
//! 1. **Submit.** [`Coordinator::submit`] registers the job in the shared
//!    job table *before* dispatching, so no response can beat the entry,
//!    and returns immediately. Any number of jobs may be in flight; submit
//!    order and collection order are independent.
//! 2. **Route.** The router thread owns the single worker→master channel
//!    and forwards each [`transport::FromWorker`] to the owning job's
//!    private channel. A straggler answering an old job while newer jobs
//!    collect is attributed to *its* job — never discarded as "stale", and
//!    never misread by another job's collector.
//! 3. **Collect.** [`JobHandle::wait`] blocks (with a per-job timeout,
//!    default [`Coordinator::timeout`] at submit time) until the first
//!    `need` successful responses arrived; [`JobHandle::try_wait`] is the
//!    polling variant for multiplexed serving loops. Worker-side failures
//!    are invisible to collection (like silence on a network) but let the
//!    collector fail fast once the threshold is provably unreachable.
//! 4. **Retire.** Once every worker has been heard from (success, failure
//!    or fail-stop report), the router retires the table entry — the table
//!    is bounded by the number of genuinely in-flight jobs. Dropping the
//!    handle early just stops forwarding; accounting continues.
//!
//! [`Coordinator`] implements `Drop` (signal shutdown + join workers and
//! router), so early `?` returns and panicking tests never leak the pool;
//! [`Coordinator::shutdown`] remains the explicit happy path.

pub mod transport;
pub mod straggler;
pub mod worker;
pub mod master;
pub mod metrics;
pub mod runner;

pub use master::{Coordinator, JobHandle};
pub use metrics::JobMetrics;
pub use straggler::StragglerModel;
pub use runner::{run_batch, run_erased, run_single, NativeCompute};
pub use worker::ShareCompute;
