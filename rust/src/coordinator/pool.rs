//! Master-side worker-pool health model: the live/suspect/dead membership
//! state machine, per-worker latency estimation (EWMA mean/deviation plus a
//! log-bucket histogram), and the [`ElasticConfig`] knobs that drive the
//! coordinator's health monitor and speculative re-dispatch (see
//! [`super::master`]).
//!
//! # Membership state machine
//!
//! ```text
//!            pong / response heard
//!          ┌─────────────────────────┐
//!          ▼                         │
//!        LIVE ──ping unanswered──▶ SUSPECT
//!          │ ▲   for suspect_after   │
//!          │ │                       │
//!          │ └─probation: 3 clean pings
//!          │ ┌──────────────────────┐
//!          ├─│verification failure──▶ QUARANTINED
//!          │ └──────────────────────┘
//!          └──link down──▶ DEAD ◀────(suspect link down)
//!                            │
//!                            └──reconnect succeeds──▶ LIVE
//! ```
//!
//! * **Live** — the link is up and traffic (a response, pong or hello) has
//!   been heard recently enough. Only live workers are eligible as
//!   speculative spares and are preferred by shard placement.
//! * **Suspect** — the link is up but a health-check ping has gone
//!   unanswered for longer than [`ElasticConfig::suspect_after`]. A
//!   suspect worker keeps its in-flight work (it may just be slow) but
//!   receives no new speculative copies.
//! * **Quarantined** — verified decode caught the worker returning a
//!   corrupt share ([`PoolState::quarantine`]). Excluded from placement and
//!   speculation like a dead worker, but the link stays up and the monitor
//!   keeps pinging it; after [`PROBATION_CLEAN_PINGS`] consecutively
//!   answered pings it is released back to live (the fault may have been
//!   transient bit-rot). The verdict is *sticky*: neither fresh traffic nor
//!   a reconnect clears it early.
//! * **Dead** — the transport reports the link down. Everything it owed
//!   has already fail-stopped; with
//!   [`ElasticConfig::auto_reconnect`] the monitor periodically re-dials
//!   it back to live.
//!
//! # Re-dispatch deadline
//!
//! Each worker's observed response latencies feed an exponentially
//! weighted moving average of the mean and absolute deviation. A shard
//! dispatched to worker `w` is overdue — and eligible for a speculative
//! copy on a live spare — once it has been outstanding longer than
//!
//! ```text
//! deadline(w) = max(spec_min_deadline, mean(w) + spec_factor · dev(w))
//! ```
//!
//! with the pool-wide mean standing in for a worker with no samples yet,
//! and `spec_min_deadline` alone when the whole pool is cold.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// EWMA smoothing factor for latency mean and deviation.
const EWMA_ALPHA: f64 = 0.25;

/// Number of log₂ microsecond buckets in [`LatencyHistogram`] (the top
/// bucket saturates: ≥ 2¹⁵ µs ≈ 33 ms per bucket-16 sample).
const HISTOGRAM_BUCKETS: usize = 16;

/// Consecutively answered health-check pings a quarantined worker must
/// accumulate before probation releases it back to live.
pub const PROBATION_CLEAN_PINGS: u32 = 3;

/// One worker's membership state as tracked by the master's health monitor.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WorkerHealth {
    /// Link up, heard from recently. Eligible for new work and as a
    /// speculative spare.
    #[default]
    Live,
    /// Link up but a health check has gone unanswered past the configured
    /// window; gets no new speculative copies until it answers again.
    Suspect,
    /// Caught returning a corrupt share by verified decode. Excluded from
    /// placement until a clean ping streak releases it (probation). Sticky:
    /// fresh traffic does not clear it.
    Quarantined,
    /// Link down; every job it owed has fail-stopped.
    Dead,
}

impl WorkerHealth {
    /// Placement preference: lower ranks first.
    pub fn rank(self) -> u8 {
        match self {
            WorkerHealth::Live => 0,
            WorkerHealth::Suspect => 1,
            WorkerHealth::Quarantined => 2,
            WorkerHealth::Dead => 3,
        }
    }
}

/// Exponentially weighted estimate of one worker's response latency: mean
/// and mean absolute deviation, in microseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyEwma {
    mean_us: f64,
    dev_us: f64,
    samples: u64,
}

impl LatencyEwma {
    pub fn observe(&mut self, latency: Duration) {
        let x = latency.as_micros() as f64;
        if self.samples == 0 {
            // First sample: seed the deviation at half the mean so a
            // single observation doesn't produce a zero-slack deadline.
            self.mean_us = x;
            self.dev_us = x / 2.0;
        } else {
            let diff = (x - self.mean_us).abs();
            self.mean_us += EWMA_ALPHA * (x - self.mean_us);
            self.dev_us += EWMA_ALPHA * (diff - self.dev_us);
        }
        self.samples += 1;
    }

    pub fn samples(&self) -> u64 {
        self.samples
    }

    pub fn mean(&self) -> Duration {
        Duration::from_micros(self.mean_us as u64)
    }

    /// `mean + factor · dev`, the raw (un-floored) re-dispatch deadline.
    pub fn deadline(&self, factor: f64) -> Duration {
        Duration::from_micros((self.mean_us + factor * self.dev_us).max(0.0) as u64)
    }
}

/// Log₂-bucketed latency histogram: bucket `i` counts responses with
/// latency in `[2^i, 2^(i+1))` microseconds (bucket 0 additionally holds
/// sub-microsecond samples; the last bucket saturates upward). Cheap enough
/// to keep per worker, detailed enough to show a bimodal straggler.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; HISTOGRAM_BUCKETS] }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, latency: Duration) {
        let us = latency.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(HISTOGRAM_BUCKETS - 1);
        self.buckets[bucket] += 1;
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }
}

/// Tuning for the coordinator's health monitor and speculative re-dispatch.
/// The default reproduces the pre-elastic coordinator exactly on the job
/// path (no speculation, no auto-reconnect) while keeping passive health
/// tracking on.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Health-monitor loop cadence.
    pub tick: Duration,
    /// How often to ping an idle worker; `None` disables health-check
    /// pings entirely (liveness then comes only from link state).
    pub ping_interval: Option<Duration>,
    /// An unanswered ping older than this marks the worker suspect.
    pub suspect_after: Duration,
    /// Enable speculative re-dispatch of overdue shards to live spares.
    pub speculate: bool,
    /// Floor on the re-dispatch deadline — no shard is ever declared
    /// overdue before this much time has passed.
    pub spec_min_deadline: Duration,
    /// Deadline slack: `deadline = max(floor, mean + spec_factor · dev)`.
    pub spec_factor: f64,
    /// Maximum simultaneous in-flight copies of one shard (1 = primary
    /// only, 2 = primary + one spare, …).
    pub max_copies: usize,
    /// Maximum total dispatch attempts per shard over its lifetime.
    pub max_attempts: usize,
    /// Re-dial dead links in the background (TCP; the channel transport
    /// revives the worker thread).
    pub auto_reconnect: bool,
    /// Minimum delay between background reconnect attempts per worker.
    pub reconnect_interval: Duration,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            tick: Duration::from_millis(25),
            ping_interval: Some(Duration::from_millis(500)),
            suspect_after: Duration::from_secs(1),
            speculate: false,
            spec_min_deadline: Duration::from_millis(50),
            spec_factor: 4.0,
            max_copies: 2,
            max_attempts: 4,
            auto_reconnect: false,
            reconnect_interval: Duration::from_millis(500),
        }
    }
}

impl ElasticConfig {
    /// The full elastic mode: speculation plus background reconnect, with
    /// the default cadences. What `--speculate` turns on.
    pub fn speculative() -> Self {
        ElasticConfig { speculate: true, auto_reconnect: true, ..ElasticConfig::default() }
    }
}

/// What the health monitor should do about one worker after a
/// [`PoolState::health_check`] pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PingAction {
    /// Nothing to send this tick.
    None,
    /// Fire a ping with this nonce.
    Send(u64),
}

#[derive(Default)]
struct WorkerStats {
    health: WorkerHealth,
    latency: LatencyEwma,
    histogram: LatencyHistogram,
    /// When the monitor's outstanding ping (if any) was sent.
    ping_sent: Option<Instant>,
    /// Consecutively answered pings while quarantined (probation counter;
    /// reset on every quarantine and on every unanswered ping).
    clean_pings: u32,
}

/// A read-only snapshot of one worker's health and latency estimate.
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub health: WorkerHealth,
    pub mean_latency: Duration,
    pub samples: u64,
    pub histogram: Vec<u64>,
}

/// Shared pool-health state: written by the coordinator's router (latency
/// observations) and health monitor (verdicts), read by shard placement and
/// speculation. Cloning shares the underlying state.
#[derive(Clone)]
pub struct PoolState {
    inner: Arc<Mutex<PoolInner>>,
}

struct PoolInner {
    workers: Vec<WorkerStats>,
    next_nonce: u64,
}

impl PoolState {
    pub fn new(n_workers: usize) -> PoolState {
        let mut workers = Vec::with_capacity(n_workers);
        workers.resize_with(n_workers, WorkerStats::default);
        PoolState { inner: Arc::new(Mutex::new(PoolInner { workers, next_nonce: 1 })) }
    }

    /// Grow to at least `n` workers (new entries start live). Membership
    /// only ever grows; a removed worker is just dead forever.
    pub fn ensure_len(&self, n: usize) {
        let mut inner = self.inner.lock().unwrap();
        if inner.workers.len() < n {
            inner.workers.resize_with(n, WorkerStats::default);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn health(&self, worker: usize) -> WorkerHealth {
        let inner = self.inner.lock().unwrap();
        inner.workers.get(worker).map_or(WorkerHealth::Dead, |w| w.health)
    }

    pub fn set_health(&self, worker: usize, health: WorkerHealth) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(w) = inner.workers.get_mut(worker) {
            w.health = health;
            if health == WorkerHealth::Live {
                w.ping_sent = None;
            }
            w.clean_pings = 0;
        }
    }

    /// Quarantine `worker`: verified decode caught it returning a corrupt
    /// share. Excluded from placement and speculation until probation (a
    /// streak of [`PROBATION_CLEAN_PINGS`] answered pings) releases it.
    pub fn quarantine(&self, worker: usize) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(w) = inner.workers.get_mut(worker) {
            w.health = WorkerHealth::Quarantined;
            w.clean_pings = 0;
        }
    }

    /// Record a successful response latency for `worker`. Hearing a real
    /// response also clears any suspect verdict.
    pub fn observe_latency(&self, worker: usize, latency: Duration) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(w) = inner.workers.get_mut(worker) {
            w.latency.observe(latency);
            w.histogram.record(latency);
            w.ping_sent = None;
            if w.health == WorkerHealth::Suspect {
                w.health = WorkerHealth::Live;
            }
        }
    }

    /// The lowest-index live worker not in `exclude`, if any — the spare a
    /// speculative copy goes to.
    pub fn live_spare(&self, exclude: &[usize]) -> Option<usize> {
        let inner = self.inner.lock().unwrap();
        (0..inner.workers.len())
            .find(|w| inner.workers[*w].health == WorkerHealth::Live && !exclude.contains(w))
    }

    /// The re-dispatch deadline for a shard whose primary is `worker`:
    /// `max(floor, mean + factor·dev)`, falling back to the pool-wide mean
    /// for an unsampled worker and to the floor alone for a cold pool.
    pub fn deadline(&self, worker: Option<usize>, cfg: &ElasticConfig) -> Duration {
        let inner = self.inner.lock().unwrap();
        let per_worker = worker
            .and_then(|w| inner.workers.get(w))
            .filter(|w| w.latency.samples() > 0)
            .map(|w| w.latency.deadline(cfg.spec_factor));
        let estimate = per_worker.or_else(|| {
            let sampled: Vec<&LatencyEwma> = inner
                .workers
                .iter()
                .filter(|w| w.latency.samples() > 0)
                .map(|w| &w.latency)
                .collect();
            if sampled.is_empty() {
                None
            } else {
                let sum: Duration = sampled.iter().map(|l| l.deadline(cfg.spec_factor)).sum();
                Some(sum / sampled.len() as u32)
            }
        });
        estimate.unwrap_or(Duration::ZERO).max(cfg.spec_min_deadline)
    }

    /// One health-check pass for `worker`, given the transport's view of
    /// the link (`alive`, `idle` = time since last heard). Updates the
    /// live/suspect verdict and says whether to fire a ping now.
    pub fn health_check(
        &self,
        worker: usize,
        alive: bool,
        idle: Option<Duration>,
        cfg: &ElasticConfig,
    ) -> PingAction {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let nonce = inner.next_nonce;
        let Some(w) = inner.workers.get_mut(worker) else {
            return PingAction::None;
        };
        if !alive {
            // A quarantine verdict is sticky: the worker's link dying and
            // coming back must not launder it through Dead → Live.
            if w.health != WorkerHealth::Quarantined {
                w.health = WorkerHealth::Dead;
            }
            w.ping_sent = None;
            w.clean_pings = 0;
            return PingAction::None;
        }
        if w.health == WorkerHealth::Dead {
            // The link is back up (a reconnect landed).
            w.health = WorkerHealth::Live;
            w.ping_sent = None;
        }
        let Some(ping_interval) = cfg.ping_interval else {
            return PingAction::None;
        };
        match w.ping_sent {
            Some(sent) => {
                // Answered if the link has been heard from since the ping
                // left (any traffic counts, not just the pong).
                if idle.is_some_and(|d| d < sent.elapsed()) {
                    w.ping_sent = None;
                    match w.health {
                        WorkerHealth::Suspect => w.health = WorkerHealth::Live,
                        WorkerHealth::Quarantined => {
                            // Probation: a clean ping streak earns release.
                            w.clean_pings += 1;
                            if w.clean_pings >= PROBATION_CLEAN_PINGS {
                                w.health = WorkerHealth::Live;
                                w.clean_pings = 0;
                            }
                        }
                        _ => {}
                    }
                    PingAction::None
                } else {
                    if sent.elapsed() > cfg.suspect_after
                        && w.health != WorkerHealth::Quarantined
                    {
                        w.health = WorkerHealth::Suspect;
                    }
                    if sent.elapsed() > cfg.suspect_after {
                        // An unanswered ping breaks a probation streak.
                        w.clean_pings = 0;
                    }
                    PingAction::None
                }
            }
            None => {
                let due = idle.is_none_or(|d| d >= ping_interval);
                if due {
                    w.ping_sent = Some(Instant::now());
                    inner.next_nonce += 1;
                    PingAction::Send(nonce)
                } else {
                    PingAction::None
                }
            }
        }
    }

    /// Read-only snapshot of every worker, for reporting and tests.
    pub fn snapshot(&self) -> Vec<WorkerSnapshot> {
        let inner = self.inner.lock().unwrap();
        inner
            .workers
            .iter()
            .map(|w| WorkerSnapshot {
                health: w.health,
                mean_latency: w.latency.mean(),
                samples: w.latency.samples(),
                histogram: w.histogram.buckets().to_vec(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn ewma_tracks_mean_and_spreads_deadline_by_deviation() {
        let mut l = LatencyEwma::default();
        for _ in 0..50 {
            l.observe(ms(10));
        }
        let mean = l.mean();
        assert!(mean >= ms(9) && mean <= ms(11), "converges to 10ms, got {mean:?}");
        // Steady stream → deviation decays → deadline approaches the mean.
        let tight = l.deadline(4.0);
        assert!(tight < ms(25), "steady worker gets a tight deadline, got {tight:?}");

        // A jittery worker earns more slack.
        let mut jittery = LatencyEwma::default();
        for i in 0..50 {
            jittery.observe(if i % 2 == 0 { ms(5) } else { ms(40) });
        }
        assert!(jittery.deadline(4.0) > tight);
    }

    #[test]
    fn histogram_buckets_by_log2_microseconds() {
        let mut h = LatencyHistogram::default();
        h.record(Duration::from_micros(1)); // bucket 0
        h.record(Duration::from_micros(3)); // bucket 1
        h.record(Duration::from_micros(1024)); // bucket 10
        h.record(Duration::from_secs(3600)); // saturates into the top bucket
        assert_eq!(h.total(), 4);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[1], 1);
        assert_eq!(h.buckets()[10], 1);
        assert_eq!(h.buckets()[HISTOGRAM_BUCKETS - 1], 1);
    }

    #[test]
    fn live_spare_skips_unhealthy_and_excluded_workers() {
        let pool = PoolState::new(4);
        pool.set_health(0, WorkerHealth::Dead);
        pool.set_health(1, WorkerHealth::Suspect);
        assert_eq!(pool.live_spare(&[]), Some(2));
        assert_eq!(pool.live_spare(&[2]), Some(3));
        assert_eq!(pool.live_spare(&[2, 3]), None, "suspect workers are not spares");
    }

    #[test]
    fn deadline_falls_back_from_worker_to_pool_to_floor() {
        let cfg =
            ElasticConfig { spec_min_deadline: ms(50), spec_factor: 2.0, ..Default::default() };
        let pool = PoolState::new(2);
        // Cold pool: the floor.
        assert_eq!(pool.deadline(Some(0), &cfg), ms(50));
        // Worker 1 sampled at ~200ms; worker 0 falls back to the pool mean.
        for _ in 0..20 {
            pool.observe_latency(1, ms(200));
        }
        assert!(pool.deadline(Some(1), &cfg) >= ms(200));
        assert!(pool.deadline(Some(0), &cfg) >= ms(200), "unsampled worker uses the pool mean");
        // A fast sampled worker still never goes below the floor.
        for _ in 0..50 {
            pool.observe_latency(0, Duration::from_micros(100));
        }
        assert_eq!(pool.deadline(Some(0), &cfg), ms(50));
    }

    #[test]
    fn health_check_walks_live_suspect_dead_and_back() {
        let cfg = ElasticConfig {
            ping_interval: Some(Duration::ZERO),
            suspect_after: Duration::ZERO,
            ..Default::default()
        };
        let pool = PoolState::new(1);
        assert_eq!(pool.health(0), WorkerHealth::Live);

        // Never heard from → ping immediately.
        let action = pool.health_check(0, true, None, &cfg);
        assert!(matches!(action, PingAction::Send(_)));
        // Ping outstanding, no traffic since, past the (zero) window →
        // suspect.
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(pool.health_check(0, true, None, &cfg), PingAction::None);
        assert_eq!(pool.health(0), WorkerHealth::Suspect);

        // Fresh traffic (idle < time since ping) clears the suspicion.
        assert_eq!(pool.health_check(0, true, Some(Duration::ZERO), &cfg), PingAction::None);
        assert_eq!(pool.health(0), WorkerHealth::Live);

        // Link down → dead; link back up → live.
        pool.health_check(0, false, None, &cfg);
        assert_eq!(pool.health(0), WorkerHealth::Dead);
        pool.health_check(0, true, Some(Duration::ZERO), &cfg);
        assert_eq!(pool.health(0), WorkerHealth::Live);

        // A real observed response also revives a suspect.
        pool.set_health(0, WorkerHealth::Suspect);
        pool.observe_latency(0, ms(5));
        assert_eq!(pool.health(0), WorkerHealth::Live);
    }

    #[test]
    fn quarantine_excludes_from_spares_and_is_sticky() {
        let cfg = ElasticConfig {
            ping_interval: Some(Duration::ZERO),
            suspect_after: Duration::ZERO,
            ..Default::default()
        };
        let pool = PoolState::new(2);
        pool.quarantine(0);
        assert_eq!(pool.health(0), WorkerHealth::Quarantined);
        assert_eq!(pool.live_spare(&[]), Some(1), "quarantined worker is never a spare");
        assert!(WorkerHealth::Quarantined.rank() > WorkerHealth::Suspect.rank());
        assert!(WorkerHealth::Quarantined.rank() < WorkerHealth::Dead.rank());

        // Fresh traffic does not clear the verdict (unlike Suspect).
        pool.observe_latency(0, ms(3));
        assert_eq!(pool.health(0), WorkerHealth::Quarantined);

        // Neither does the link bouncing: down stays quarantined (no
        // laundering through Dead → Live on reconnect), back up too.
        pool.health_check(0, false, None, &cfg);
        assert_eq!(pool.health(0), WorkerHealth::Quarantined);
        pool.health_check(0, true, Some(Duration::ZERO), &cfg);
        assert_eq!(pool.health(0), WorkerHealth::Quarantined);

        // The reconnect pass above fired a ping (zero interval); leaving it
        // unanswered past the window never downgrades the worker to the
        // better-ranked Suspect either.
        std::thread::sleep(Duration::from_millis(2));
        pool.health_check(0, true, None, &cfg);
        assert_eq!(pool.health(0), WorkerHealth::Quarantined);
    }

    #[test]
    fn probation_releases_after_a_clean_ping_streak() {
        let cfg = ElasticConfig {
            ping_interval: Some(Duration::ZERO),
            suspect_after: Duration::from_secs(3600),
            ..Default::default()
        };
        let pool = PoolState::new(1);
        pool.quarantine(0);
        for round in 0..PROBATION_CLEAN_PINGS {
            assert_eq!(
                pool.health(0),
                WorkerHealth::Quarantined,
                "still quarantined before clean ping {round}"
            );
            // Monitor fires a ping…
            assert!(matches!(pool.health_check(0, true, None, &cfg), PingAction::Send(_)));
            std::thread::sleep(Duration::from_millis(2));
            // …and the worker answers it (idle < time since the ping left).
            pool.health_check(0, true, Some(Duration::ZERO), &cfg);
        }
        assert_eq!(pool.health(0), WorkerHealth::Live, "probation served");
        assert_eq!(pool.live_spare(&[]), Some(0));
    }

    #[test]
    fn pings_respect_the_interval_and_nonces_are_unique() {
        let cfg = ElasticConfig {
            ping_interval: Some(Duration::from_secs(3600)),
            ..Default::default()
        };
        let pool = PoolState::new(1);
        // Heard from recently → no ping.
        assert_eq!(pool.health_check(0, true, Some(Duration::ZERO), &cfg), PingAction::None);
        // Idle past the interval → ping, with a fresh nonce each time.
        let PingAction::Send(n1) = pool.health_check(0, true, Some(Duration::from_secs(7200)), &cfg)
        else {
            panic!("expected a ping")
        };
        pool.set_health(0, WorkerHealth::Live); // clears ping_sent
        let PingAction::Send(n2) = pool.health_check(0, true, Some(Duration::from_secs(7200)), &cfg)
        else {
            panic!("expected a ping")
        };
        assert_ne!(n1, n2);

        // Pings disabled → never.
        let off = ElasticConfig { ping_interval: None, ..Default::default() };
        assert_eq!(pool.health_check(0, true, None, &off), PingAction::None);
    }
}
