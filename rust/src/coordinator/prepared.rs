//! Master-side registry of **prepared operands**: left (A-side) share
//! halves encoded once and staged on every worker, so each subsequent job
//! of a fixed-weight serving stream ships only its right (B-side) halves.
//!
//! The store is the staging state's single source of truth:
//!
//! * an entry holds the per-worker serialized A-halves (`shares[w]` goes to
//!   worker `w`), shared via ref-counted [`PooledBuf`]s so re-staging
//!   after a reconnect never copies the bytes (and evicting an operand
//!   returns its buffers to the global byte pool);
//! * capacity is bounded with least-recently-used eviction, exactly like
//!   [`crate::codes::plan_cache::PlanCache`] — a long-running server cannot
//!   leak staged uploads. [`PreparedStore::insert`] reports which ids were
//!   evicted so the coordinator can send the matching evict frames;
//! * hit/miss/eviction counts are shared atomics (clone-visible), surfaced
//!   through [`super::metrics::JobMetrics`] the same way plan-cache stats
//!   are.
//!
//! Workers hold a *copy* of each staged half, keyed by the same id; the
//! master re-pushes every live entry when a worker (re)joins, so worker
//! state is always a function of this store — a prepared job can only ever
//! name an id the store currently holds.

use crate::util::bytepool::PooledBuf;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default bound on simultaneously staged operands, mirroring
/// [`crate::codes::plan_cache::DEFAULT_PLAN_CACHE_CAP`]'s role for
/// interpolation plans.
pub const DEFAULT_PREPARED_CAP: usize = 64;

/// One staged operand: the per-worker serialized A-halves, in worker order.
#[derive(Clone)]
pub struct PreparedOperand {
    /// `shares[w]` is the A-half staged on worker `w`.
    pub shares: Vec<PooledBuf>,
    /// LRU clock value of the most recent touch.
    last_used: u64,
}

impl PreparedOperand {
    /// Total bytes this operand stages across the pool (the analytic
    /// A-side upload volume of one staging pass).
    pub fn staged_bytes(&self) -> usize {
        self.shares.iter().map(|s| s.len()).sum()
    }
}

struct Inner {
    map: HashMap<u64, PreparedOperand>,
    /// Monotone LRU clock; bumped on every insert and touch.
    tick: u64,
    /// Next id to assign; never reused, so a stale id on a worker can
    /// never alias a newer operand.
    next_id: u64,
    /// Capacity bound; shrinking it takes effect lazily on the next
    /// insert (which then evicts down to the new bound).
    cap: usize,
}

/// Bounded, thread-safe store of prepared operands with LRU eviction and
/// shared hit/miss/eviction statistics. Cloning shares the store.
#[derive(Clone)]
pub struct PreparedStore {
    inner: Arc<Mutex<Inner>>,
    hits: Arc<AtomicU64>,
    misses: Arc<AtomicU64>,
    evictions: Arc<AtomicU64>,
}

impl PreparedStore {
    pub fn new(cap: usize) -> PreparedStore {
        assert!(cap > 0, "prepared store capacity must be positive");
        PreparedStore {
            inner: Arc::new(Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
                next_id: 0,
                cap,
            })),
            hits: Arc::new(AtomicU64::new(0)),
            misses: Arc::new(AtomicU64::new(0)),
            evictions: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Register a new operand. Returns its id plus the ids evicted to make
    /// room (normally at most one per insert; more after the capacity was
    /// shrunk), so the caller can evict them from the workers too.
    pub fn insert(&self, shares: Vec<PooledBuf>) -> (u64, Vec<u64>) {
        let mut inner = self.inner.lock().unwrap();
        let mut evicted = Vec::new();
        while inner.map.len() >= inner.cap {
            let lru = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&id, _)| id)
                .expect("non-empty map at capacity");
            inner.map.remove(&lru);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            evicted.push(lru);
        }
        inner.tick += 1;
        let tick = inner.tick;
        let id = inner.next_id;
        inner.next_id += 1;
        inner.map.insert(id, PreparedOperand { shares, last_used: tick });
        (id, evicted)
    }

    /// Look an operand up by id, touching its LRU slot. A hit clones the
    /// buffers by reference count (never the bytes); a miss — an id never issued, explicitly
    /// released, or since evicted — is counted and returns `None`.
    pub fn get(&self, id: u64) -> Option<Vec<PooledBuf>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&id) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.shares.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Look an operand up without touching the LRU clock or the hit/miss
    /// stats — for internal machinery (speculative re-dispatch assembling a
    /// full payload) that must not skew the serving-visible counters.
    pub fn peek(&self, id: u64) -> Option<Vec<PooledBuf>> {
        self.inner.lock().unwrap().map.get(&id).map(|e| e.shares.clone())
    }

    /// Explicitly release an operand. Returns whether it was present. Not
    /// counted as an eviction (those are capacity pressure only).
    pub fn remove(&self, id: u64) -> bool {
        self.inner.lock().unwrap().map.remove(&id).is_some()
    }

    /// Every live entry, for re-staging a (re)joined worker. Ordered by id
    /// so re-stages are deterministic across transports.
    pub fn entries(&self) -> Vec<(u64, Vec<PooledBuf>)> {
        let inner = self.inner.lock().unwrap();
        let mut all: Vec<(u64, Vec<PooledBuf>)> =
            inner.map.iter().map(|(&id, e)| (id, e.shares.clone())).collect();
        all.sort_unstable_by_key(|(id, _)| *id);
        all
    }

    /// `(hits, misses, evictions)` since construction.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
        )
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().cap
    }

    /// Change the capacity bound. Shrinking below the current size takes
    /// effect on the next insert, which evicts down to the new bound.
    pub fn set_capacity(&self, cap: usize) {
        assert!(cap > 0, "prepared store capacity must be positive");
        self.inner.lock().unwrap().cap = cap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn operand(bytes: &[usize]) -> Vec<PooledBuf> {
        bytes.iter().map(|&n| vec![0u8; n].into()).collect()
    }

    #[test]
    fn insert_get_remove_roundtrip_with_stats() {
        let store = PreparedStore::new(4);
        assert!(store.is_empty());
        let (id, evicted) = store.insert(operand(&[3, 5]));
        assert_eq!((id, evicted.len(), store.len()), (0, 0, 1));
        let shares = store.get(id).expect("present");
        assert_eq!(shares.iter().map(|s| s.len()).sum::<usize>(), 8);
        assert!(store.get(99).is_none());
        assert_eq!(store.stats(), (1, 1, 0));
        assert!(store.remove(id));
        assert!(!store.remove(id), "second release is a no-op");
        assert!(store.get(id).is_none(), "released id misses");
        assert_eq!(store.stats(), (1, 2, 0), "explicit release is not an eviction");
    }

    #[test]
    fn lru_eviction_at_capacity_reports_the_victim() {
        let store = PreparedStore::new(2);
        let (a, _) = store.insert(operand(&[1]));
        let (b, _) = store.insert(operand(&[1]));
        // Touch a so b is the LRU victim.
        store.get(a).unwrap();
        let (c, evicted) = store.insert(operand(&[1]));
        assert_eq!(evicted, vec![b], "least-recently-used entry evicted");
        assert_eq!(store.len(), 2);
        assert!(store.get(a).is_some() && store.get(c).is_some());
        assert!(store.get(b).is_none(), "evicted id misses");
        let (_, _, evictions) = store.stats();
        assert_eq!(evictions, 1);
    }

    #[test]
    fn ids_are_never_reused_and_entries_are_ordered() {
        let store = PreparedStore::new(2);
        let (a, _) = store.insert(operand(&[1]));
        store.remove(a);
        let (b, _) = store.insert(operand(&[2]));
        assert!(b > a, "released ids are not recycled");
        let (c, _) = store.insert(operand(&[3]));
        let ids: Vec<u64> = store.entries().iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![b, c]);
    }

    #[test]
    fn shrinking_capacity_evicts_on_the_next_insert() {
        let store = PreparedStore::new(4);
        let (a, _) = store.insert(operand(&[1]));
        let (b, _) = store.insert(operand(&[1]));
        let (c, _) = store.insert(operand(&[1]));
        store.set_capacity(2);
        assert_eq!(store.len(), 3, "shrink is lazy");
        // Touch c and a so b is the coldest.
        store.get(c).unwrap();
        store.get(a).unwrap();
        let (d, mut evicted) = store.insert(operand(&[1]));
        evicted.sort_unstable();
        assert_eq!(evicted, vec![b, c], "evicts down to the new bound, coldest first");
        assert_eq!(store.len(), 2);
        assert!(store.peek(a).is_some() && store.peek(d).is_some());
    }

    #[test]
    fn staged_bytes_sums_all_workers() {
        let op = PreparedOperand { shares: operand(&[4, 6, 2]), last_used: 0 };
        assert_eq!(op.staged_bytes(), 12);
    }

    #[test]
    fn clones_share_state() {
        let store = PreparedStore::new(4);
        let view = store.clone();
        let (id, _) = store.insert(operand(&[7]));
        assert!(view.get(id).is_some());
        assert_eq!(store.stats().0, 1, "hit visible through every clone");
    }
}
