//! Straggler and corruption injection — the two failure phenomena coded
//! computation must defeat (§I: "the effect caused by some computing nodes
//! which run unintentionally slower than others" — plus the Byzantine
//! sibling: nodes that answer *wrongly*).
//!
//! Delay models:
//! * [`StragglerModel::None`] — ideal cluster;
//! * [`StragglerModel::FixedSlow`] — a designated set of persistently slow
//!   nodes (e.g. co-scheduled tenants);
//! * [`StragglerModel::Exponential`] — i.i.d. exponential delay tails on
//!   every node (the standard model in the coded-computation literature);
//! * [`StragglerModel::FailStop`] — nodes that never answer; the scheme
//!   tolerates up to `N − R` of them.
//!
//! Corruption models ([`CorruptionModel`], drawn from the same deterministic
//! per-worker RNG streams so channel and TCP transports inject identical
//! faults):
//! * [`CorruptionModel::BitFlip`] — one random bit of the response flips
//!   (may hit the header → malformed, or the data → wrong-but-well-formed);
//! * [`CorruptionModel::GarbagePayload`] — the whole response is replaced
//!   with random bytes (almost surely malformed);
//! * [`CorruptionModel::StaleReplay`] — the worker replays its previous
//!   *clean* response instead of the current one (well-formed, usually the
//!   wrong polynomial evaluation; the first job passes through clean);
//! * [`CorruptionModel::SilentWrongShare`] — one payload byte past the
//!   serialization header is perturbed: the response stays perfectly
//!   well-formed and only *verified* decode can catch it.

use crate::util::rng::Rng64;
use std::collections::BTreeSet;
use std::time::Duration;

/// Per-worker delay model, sampled per job.
#[derive(Clone, Debug, Default)]
pub enum StragglerModel {
    /// No injected delay.
    #[default]
    None,
    /// Workers in `slow` sleep `delay` before answering.
    FixedSlow { slow: BTreeSet<usize>, delay: Duration },
    /// Every worker sleeps an `Exp(mean)` time.
    Exponential { mean: Duration },
    /// Workers in `failed` never answer.
    FailStop { failed: BTreeSet<usize> },
}

impl StragglerModel {
    pub fn fixed_slow(slow: impl IntoIterator<Item = usize>, delay: Duration) -> Self {
        StragglerModel::FixedSlow { slow: slow.into_iter().collect(), delay }
    }

    pub fn fail_stop(failed: impl IntoIterator<Item = usize>) -> Self {
        StragglerModel::FailStop { failed: failed.into_iter().collect() }
    }

    /// Sample the injected delay for `worker` on one job. `None` means the
    /// worker drops the job entirely.
    pub fn sample(&self, worker: usize, rng: &mut Rng64) -> Option<Duration> {
        match self {
            StragglerModel::None => Some(Duration::ZERO),
            StragglerModel::FixedSlow { slow, delay } => {
                if slow.contains(&worker) {
                    Some(*delay)
                } else {
                    Some(Duration::ZERO)
                }
            }
            StragglerModel::Exponential { mean } => {
                Some(Duration::from_secs_f64(rng.exp(mean.as_secs_f64())))
            }
            StragglerModel::FailStop { failed } => {
                if failed.contains(&worker) {
                    None
                } else {
                    Some(Duration::ZERO)
                }
            }
        }
    }
}

/// Per-worker response-corruption model, applied after a successful compute.
///
/// Mirrors [`StragglerModel`]'s determinism contract: corruption draws come
/// from the worker's own [`Rng64`] stream (`worker_rng(seed, id)`), and a
/// model only consumes draws for the workers it targets, so straggler draws
/// for untargeted workers are byte-identical with and without corruption.
/// An **empty** target set means "every worker".
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum CorruptionModel {
    /// No corruption (the default).
    #[default]
    None,
    /// Flip one uniformly random bit of the response payload.
    BitFlip { corrupt: BTreeSet<usize> },
    /// Replace the whole response payload with uniform random bytes.
    GarbagePayload { corrupt: BTreeSet<usize> },
    /// Replay the previous clean response verbatim (first job: no-op).
    StaleReplay { corrupt: BTreeSet<usize> },
    /// Perturb one payload byte past the 16-byte serialization header, so
    /// the response deserializes cleanly but decodes to a wrong product.
    SilentWrongShare { corrupt: BTreeSet<usize> },
}

impl CorruptionModel {
    pub fn bit_flip(corrupt: impl IntoIterator<Item = usize>) -> Self {
        CorruptionModel::BitFlip { corrupt: corrupt.into_iter().collect() }
    }

    pub fn garbage_payload(corrupt: impl IntoIterator<Item = usize>) -> Self {
        CorruptionModel::GarbagePayload { corrupt: corrupt.into_iter().collect() }
    }

    pub fn stale_replay(corrupt: impl IntoIterator<Item = usize>) -> Self {
        CorruptionModel::StaleReplay { corrupt: corrupt.into_iter().collect() }
    }

    pub fn silent_wrong_share(corrupt: impl IntoIterator<Item = usize>) -> Self {
        CorruptionModel::SilentWrongShare { corrupt: corrupt.into_iter().collect() }
    }

    /// `true` for [`CorruptionModel::None`].
    pub fn is_none(&self) -> bool {
        matches!(self, CorruptionModel::None)
    }

    /// Does this model corrupt `worker`'s responses? An empty target set
    /// targets every worker.
    pub fn targets(&self, worker: usize) -> bool {
        match self {
            CorruptionModel::None => false,
            CorruptionModel::BitFlip { corrupt }
            | CorruptionModel::GarbagePayload { corrupt }
            | CorruptionModel::StaleReplay { corrupt }
            | CorruptionModel::SilentWrongShare { corrupt } => {
                corrupt.is_empty() || corrupt.contains(&worker)
            }
        }
    }

    /// Short CLI/report label (`none`, `bit-flip`, …).
    pub fn label(&self) -> &'static str {
        match self {
            CorruptionModel::None => "none",
            CorruptionModel::BitFlip { .. } => "bit-flip",
            CorruptionModel::GarbagePayload { .. } => "garbage-payload",
            CorruptionModel::StaleReplay { .. } => "stale-replay",
            CorruptionModel::SilentWrongShare { .. } => "silent-wrong-share",
        }
    }

    /// Parse a `--corrupt` spec: `none` or `MODEL[:id,id,...]` where MODEL
    /// is `bit-flip | garbage-payload | stale-replay | silent-wrong-share`.
    /// Without the id list the model targets every worker.
    pub fn parse(spec: &str) -> anyhow::Result<CorruptionModel> {
        let (model, ids) = match spec.split_once(':') {
            Some((m, rest)) => (m, rest),
            None => (spec, ""),
        };
        let corrupt: BTreeSet<usize> = ids
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad worker id `{s}` in --corrupt `{spec}`"))
            })
            .collect::<anyhow::Result<_>>()?;
        match model.trim() {
            "none" => Ok(CorruptionModel::None),
            "bit-flip" => Ok(CorruptionModel::BitFlip { corrupt }),
            "garbage-payload" => Ok(CorruptionModel::GarbagePayload { corrupt }),
            "stale-replay" => Ok(CorruptionModel::StaleReplay { corrupt }),
            "silent-wrong-share" => Ok(CorruptionModel::SilentWrongShare { corrupt }),
            other => anyhow::bail!(
                "unknown corruption model `{other}` \
                 (none | bit-flip | garbage-payload | stale-replay | silent-wrong-share)"
            ),
        }
    }

    /// Corrupt `payload` in place for `worker`'s current job. `prev` is the
    /// worker's previous *clean* response (for [`CorruptionModel::StaleReplay`]).
    /// Returns `true` iff the payload was modified. Only targeted workers
    /// consume RNG draws, keeping untargeted straggler streams untouched.
    pub fn apply(
        &self,
        worker: usize,
        rng: &mut Rng64,
        payload: &mut Vec<u8>,
        prev: Option<&[u8]>,
    ) -> bool {
        if !self.targets(worker) {
            return false;
        }
        match self {
            CorruptionModel::None => false,
            CorruptionModel::BitFlip { .. } => {
                if payload.is_empty() {
                    return false;
                }
                let bit = rng.below(payload.len() as u64 * 8) as usize;
                payload[bit / 8] ^= 1 << (bit % 8);
                true
            }
            CorruptionModel::GarbagePayload { .. } => {
                for chunk in payload.chunks_mut(8) {
                    let bytes = rng.next_u64().to_le_bytes();
                    chunk.copy_from_slice(&bytes[..chunk.len()]);
                }
                true
            }
            CorruptionModel::StaleReplay { .. } => match prev {
                Some(prev) => {
                    payload.clear();
                    payload.extend_from_slice(prev);
                    true
                }
                None => false,
            },
            CorruptionModel::SilentWrongShare { .. } => {
                // Skip the 16-byte PlaneMatrix header so the response still
                // deserializes; add a nonzero delta to one data byte.
                if payload.len() <= 16 {
                    return false;
                }
                let off = 16 + rng.below((payload.len() - 16) as u64) as usize;
                let delta = (rng.below(255) + 1) as u8;
                payload[off] = payload[off].wrapping_add(delta);
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_zero() {
        let mut rng = Rng64::seeded(1);
        assert_eq!(StragglerModel::None.sample(0, &mut rng), Some(Duration::ZERO));
    }

    #[test]
    fn fixed_slow_targets_only_listed() {
        let m = StragglerModel::fixed_slow([1, 3], Duration::from_millis(50));
        let mut rng = Rng64::seeded(2);
        assert_eq!(m.sample(0, &mut rng), Some(Duration::ZERO));
        assert_eq!(m.sample(1, &mut rng), Some(Duration::from_millis(50)));
        assert_eq!(m.sample(2, &mut rng), Some(Duration::ZERO));
        assert_eq!(m.sample(3, &mut rng), Some(Duration::from_millis(50)));
    }

    #[test]
    fn fail_stop_drops() {
        let m = StragglerModel::fail_stop([2]);
        let mut rng = Rng64::seeded(3);
        assert_eq!(m.sample(2, &mut rng), None);
        assert!(m.sample(0, &mut rng).is_some());
    }

    #[test]
    fn exponential_positive_and_varies() {
        let m = StragglerModel::Exponential { mean: Duration::from_millis(10) };
        let mut rng = Rng64::seeded(4);
        let a = m.sample(0, &mut rng).unwrap();
        let b = m.sample(0, &mut rng).unwrap();
        assert!(a != b, "two samples should differ");
    }

    #[test]
    fn corruption_parse_roundtrips_labels() {
        for spec in ["none", "bit-flip", "garbage-payload", "stale-replay", "silent-wrong-share"]
        {
            let m = CorruptionModel::parse(spec).unwrap();
            assert_eq!(m.label(), spec);
        }
        let m = CorruptionModel::parse("silent-wrong-share:1,3").unwrap();
        assert_eq!(m, CorruptionModel::silent_wrong_share([1, 3]));
        assert!(m.targets(1) && m.targets(3) && !m.targets(0));
        assert!(CorruptionModel::parse("bogus").is_err());
        assert!(CorruptionModel::parse("bit-flip:x").is_err());
    }

    #[test]
    fn empty_target_set_targets_everyone() {
        let m = CorruptionModel::bit_flip([]);
        assert!(m.targets(0) && m.targets(17));
        assert!(!CorruptionModel::None.targets(0));
    }

    #[test]
    fn untargeted_workers_draw_nothing_and_stay_clean() {
        let m = CorruptionModel::garbage_payload([2]);
        let mut rng = Rng64::seeded(9);
        let before = rng.next_u64();
        let mut rng = Rng64::seeded(9);
        let mut payload = vec![1u8, 2, 3, 4];
        let orig = payload.clone();
        assert!(!m.apply(0, &mut rng, &mut payload, None));
        assert_eq!(payload, orig, "untargeted worker's payload untouched");
        assert_eq!(rng.next_u64(), before, "no RNG draws consumed");
    }

    #[test]
    fn bit_flip_changes_exactly_one_bit() {
        let m = CorruptionModel::bit_flip([0]);
        let mut rng = Rng64::seeded(10);
        let mut payload = vec![0u8; 64];
        assert!(m.apply(0, &mut rng, &mut payload, None));
        let ones: u32 = payload.iter().map(|b| b.count_ones()).sum();
        assert_eq!(ones, 1, "exactly one bit flipped");
    }

    #[test]
    fn stale_replay_replays_prev_and_passes_first_job_clean() {
        let m = CorruptionModel::stale_replay([0]);
        let mut rng = Rng64::seeded(11);
        let mut payload = vec![5u8; 8];
        assert!(!m.apply(0, &mut rng, &mut payload, None), "first job has no prev");
        assert_eq!(payload, vec![5u8; 8]);
        let prev = vec![7u8; 8];
        assert!(m.apply(0, &mut rng, &mut payload, Some(&prev)));
        assert_eq!(payload, prev, "replayed the previous clean response");
    }

    #[test]
    fn silent_wrong_share_keeps_the_header_intact() {
        let m = CorruptionModel::silent_wrong_share([0]);
        let mut rng = Rng64::seeded(12);
        let mut payload: Vec<u8> = (0..48).map(|i| i as u8).collect();
        let orig = payload.clone();
        assert!(m.apply(0, &mut rng, &mut payload, None));
        assert_eq!(&payload[..16], &orig[..16], "header bytes untouched");
        let diffs = payload.iter().zip(&orig).filter(|(a, b)| a != b).count();
        assert_eq!(diffs, 1, "exactly one data byte perturbed");
        // too-short payloads are left alone rather than malformed
        let mut tiny = vec![0u8; 16];
        assert!(!m.apply(0, &mut rng, &mut tiny, None));
    }

    #[test]
    fn corruption_draws_are_deterministic_per_seed() {
        let m = CorruptionModel::bit_flip([0]);
        let run = || {
            let mut rng = Rng64::seeded(13);
            let mut payload = vec![0u8; 32];
            m.apply(0, &mut rng, &mut payload, None);
            payload
        };
        assert_eq!(run(), run(), "same seed, same corrupt draw");
    }
}
