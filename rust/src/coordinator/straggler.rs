//! Straggler injection — the phenomenon coded computation exists to defeat
//! (§I: "the effect caused by some computing nodes which run unintentionally
//! slower than others").
//!
//! Models:
//! * [`StragglerModel::None`] — ideal cluster;
//! * [`StragglerModel::FixedSlow`] — a designated set of persistently slow
//!   nodes (e.g. co-scheduled tenants);
//! * [`StragglerModel::Exponential`] — i.i.d. exponential delay tails on
//!   every node (the standard model in the coded-computation literature);
//! * [`StragglerModel::FailStop`] — nodes that never answer; the scheme
//!   tolerates up to `N − R` of them.

use crate::util::rng::Rng64;
use std::collections::BTreeSet;
use std::time::Duration;

/// Per-worker delay model, sampled per job.
#[derive(Clone, Debug, Default)]
pub enum StragglerModel {
    /// No injected delay.
    #[default]
    None,
    /// Workers in `slow` sleep `delay` before answering.
    FixedSlow { slow: BTreeSet<usize>, delay: Duration },
    /// Every worker sleeps an `Exp(mean)` time.
    Exponential { mean: Duration },
    /// Workers in `failed` never answer.
    FailStop { failed: BTreeSet<usize> },
}

impl StragglerModel {
    pub fn fixed_slow(slow: impl IntoIterator<Item = usize>, delay: Duration) -> Self {
        StragglerModel::FixedSlow { slow: slow.into_iter().collect(), delay }
    }

    pub fn fail_stop(failed: impl IntoIterator<Item = usize>) -> Self {
        StragglerModel::FailStop { failed: failed.into_iter().collect() }
    }

    /// Sample the injected delay for `worker` on one job. `None` means the
    /// worker drops the job entirely.
    pub fn sample(&self, worker: usize, rng: &mut Rng64) -> Option<Duration> {
        match self {
            StragglerModel::None => Some(Duration::ZERO),
            StragglerModel::FixedSlow { slow, delay } => {
                if slow.contains(&worker) {
                    Some(*delay)
                } else {
                    Some(Duration::ZERO)
                }
            }
            StragglerModel::Exponential { mean } => {
                Some(Duration::from_secs_f64(rng.exp(mean.as_secs_f64())))
            }
            StragglerModel::FailStop { failed } => {
                if failed.contains(&worker) {
                    None
                } else {
                    Some(Duration::ZERO)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_zero() {
        let mut rng = Rng64::seeded(1);
        assert_eq!(StragglerModel::None.sample(0, &mut rng), Some(Duration::ZERO));
    }

    #[test]
    fn fixed_slow_targets_only_listed() {
        let m = StragglerModel::fixed_slow([1, 3], Duration::from_millis(50));
        let mut rng = Rng64::seeded(2);
        assert_eq!(m.sample(0, &mut rng), Some(Duration::ZERO));
        assert_eq!(m.sample(1, &mut rng), Some(Duration::from_millis(50)));
        assert_eq!(m.sample(2, &mut rng), Some(Duration::ZERO));
        assert_eq!(m.sample(3, &mut rng), Some(Duration::from_millis(50)));
    }

    #[test]
    fn fail_stop_drops() {
        let m = StragglerModel::fail_stop([2]);
        let mut rng = Rng64::seeded(3);
        assert_eq!(m.sample(2, &mut rng), None);
        assert!(m.sample(0, &mut rng).is_some());
    }

    #[test]
    fn exponential_positive_and_varies() {
        let m = StragglerModel::Exponential { mean: Duration::from_millis(10) };
        let mut rng = Rng64::seeded(4);
        let a = m.sample(0, &mut rng).unwrap();
        let b = m.sample(0, &mut rng).unwrap();
        assert!(a != b, "two samples should differ");
    }
}
