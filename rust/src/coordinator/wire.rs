//! The `gr-cdmm` wire protocol: length-prefixed binary frames with a
//! versioned header, spoken between a coordinator ([`super::tcp`]) and a
//! worker daemon ([`super::daemon`]).
//!
//! Every message in either direction is one frame:
//!
//! ```text
//! offset  size  field        notes
//!      0     4  magic        0x4D43_5247 ("GRCM" as little-endian bytes)
//!      4     2  version      protocol version, currently 4
//!      6     2  kind         1=job  2=shutdown  3=response-ok
//!                            4=response-failed  5=ping  6=pong  7=hello
//!                            8=goodbye  9=stage  10=stage-ack  11=evict
//!                            12=job-ref  13=stage-ref  14=response-ref
//!      8     8  job_id       coordinator-assigned job id (ping/pong reuse
//!                            this field as the health-check nonce;
//!                            stage/stage-ack/evict reuse it as the
//!                            prepared-operand id)
//!     16     8  worker_id    shard index on job/response frames; the
//!                            daemon's assigned machine id on hello, pong,
//!                            goodbye and stage-ack frames
//!     24     8  compute_us   worker compute time in microseconds
//!                            (responses); on job frames, `prepared_id + 1`
//!                            of the staged operand to prepend, 0 for an
//!                            unprepared job
//!     32     8  delay_us     injected straggler delay in microseconds
//!     40     8  payload_len  must be ≤ [`MAX_PAYLOAD`]
//!     48     …  payload      serialized share / response bytes
//! ```
//!
//! All integers are little-endian. Job frames carry a serialized
//! [`crate::codes::Share`]; response-ok frames carry a serialized
//! [`crate::ring::plane::PlaneMatrix`]; every other kind carries no payload
//! (a response-failed frame is the byte-free fail-stop report that keeps
//! the master's job retirement deterministic — see [`super::master`]).
//!
//! Version 2 adds the four payload-free control kinds that make the pool
//! elastic: the master opens every connection with a **hello** frame
//! assigning the daemon its machine id (the daemon echoes it back, and the
//! master rejects an echo whose claimed id mismatches the slot); **ping**
//! frames carry a nonce in `job_id` which the daemon echoes in a **pong**
//! so the master can maintain a per-worker latency/liveness estimate; a
//! **goodbye** frame is a graceful leave — the daemon writes one after
//! reading a shutdown frame, and a master can write one to release a
//! connection without shutting the daemon down.
//!
//! Version 3 adds prepared-operand staging (kinds 9–11): a **stage** frame
//! carries a prepared operand's per-worker A-side share half (payload)
//! under a `prepared_id` (in the `job_id` field); the daemon stores it
//! per-connection and answers with a **stage-ack** echoing the id and its
//! machine id. A job frame whose `compute_us` field is non-zero names a
//! staged operand (`prepared_id + 1`): the daemon prepends the staged bytes
//! to the job payload — reassembling the full serialized share, since a
//! share serializes as `a` then `b` — before computing; a prepared job
//! naming an id this connection has never staged fail-stops
//! (response-failed frame). An **evict** frame (payload-free) drops the
//! staged entry.
//!
//! Version 4 is the zero-copy revision. On the write side, frames go out
//! **scatter-gather**: the 48-byte header is assembled on the stack and the
//! payload is borrowed — [`write_frame_parts`] hands both to
//! `write_vectored` so nothing is ever joined into a temporary buffer. On
//! the read side, [`read_frame`] leases the payload buffer from the
//! process-wide [`BytePool`] (pre-sized from the already-validated header
//! length), so a steady stream of frames recycles the same storage. And
//! three **reference kinds** (12–14) let the shared-memory transport
//! ([`super::shm`]) move payloads out-of-line: a job-ref / stage-ref /
//! response-ref frame mirrors its classic counterpart but carries only a
//! 16-byte `(slot seq, payload len)` descriptor naming a slot in the
//! peer-shared ring file — the control frame is the doorbell, the ring is
//! the data plane. TCP peers never send reference kinds.
//!
//! [`read_frame`] validates everything before allocating: bad magic, an
//! unknown version or kind, an oversized declared `payload_len`, and
//! truncation (mid-header or mid-payload) are all clean `Err`s; only EOF
//! exactly on a frame boundary is a clean end-of-stream (`Ok(None)`). The
//! receiving side treats any `Err` as a broken peer — fail-stop, never a
//! panic or a hang. The payload-length guard doubles as the pool guard:
//! [`MAX_PAYLOAD`] equals the pool's largest size class, so any frame that
//! passes validation can be leased.

use super::transport::FromWorker;
use crate::util::bytepool::{BytePool, PooledBuf, MAX_BUCKET};
use std::io::{ErrorKind, IoSlice, Read, Write};
use std::time::Duration;

/// `b"GRCM"` read as a little-endian `u32`.
pub const MAGIC: u32 = u32::from_le_bytes(*b"GRCM");

/// Current protocol version. Version 2 added the ping/pong/hello/goodbye
/// control frames (kinds 5–8); version 3 added prepared-operand staging
/// (stage/stage-ack/evict, kinds 9–11) and the `prepared_id + 1` tag in a
/// job frame's `compute_us` field; version 4 adds the out-of-line payload
/// reference kinds (job-ref/stage-ref/response-ref, kinds 12–14) used by
/// the shared-memory transport.
pub const VERSION: u16 = 4;

/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 48;

/// Upper bound on a frame's declared payload length (1 GiB). A header
/// declaring more is rejected before any allocation — a malformed or
/// malicious peer cannot make the receiver reserve unbounded memory.
pub const MAX_PAYLOAD: u64 = 1 << 30;

// The oversize guard doubles as the pool guard: every validated payload
// length fits the pool's largest size class, so read_frame can always
// lease.
const _: () = assert!(MAX_PAYLOAD as usize == MAX_BUCKET);

/// Byte length of a reference-kind payload: `slot seq (u64 LE) | payload
/// len (u64 LE)`.
pub const REF_PAYLOAD_LEN: usize = 16;

/// Frame discriminator (the header's `kind` field).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Master → worker: compute this job's share product.
    Job,
    /// Master → worker: no more jobs on this connection.
    Shutdown,
    /// Worker → master: successful response, payload attached.
    RespOk,
    /// Worker → master: the job was dropped (fail-stop draw or compute
    /// error); no payload.
    RespFail,
    /// Master → worker: health check. `job_id` carries the nonce.
    Ping,
    /// Worker → master: health-check reply echoing the ping's nonce.
    Pong,
    /// Master → worker: membership handshake assigning the daemon its
    /// machine id; the daemon echoes the id back to confirm.
    Hello,
    /// Either direction: graceful leave — the peer is closing this
    /// connection on purpose, not crashing.
    Goodbye,
    /// Master → worker: store this prepared operand's A-side share half.
    /// `job_id` carries the prepared id; the payload is the staged bytes.
    Stage,
    /// Worker → master: confirm a stage. Echoes the prepared id in `job_id`
    /// and the daemon's machine id in `worker_id`.
    StageAck,
    /// Master → worker: drop a staged operand. `job_id` carries the
    /// prepared id; no payload.
    Evict,
    /// Master → worker (shm only): a job whose share payload sits
    /// out-of-line in the master→worker ring. Payload is the 16-byte
    /// `(slot seq, len)` descriptor; all other fields as in `Job`.
    JobRef,
    /// Master → worker (shm only): a stage whose staged bytes sit
    /// out-of-line in the master→worker ring.
    StageRef,
    /// Worker → master (shm only): a successful response whose payload sits
    /// out-of-line in the worker→master ring.
    RespRef,
}

impl FrameKind {
    fn to_u16(self) -> u16 {
        match self {
            FrameKind::Job => 1,
            FrameKind::Shutdown => 2,
            FrameKind::RespOk => 3,
            FrameKind::RespFail => 4,
            FrameKind::Ping => 5,
            FrameKind::Pong => 6,
            FrameKind::Hello => 7,
            FrameKind::Goodbye => 8,
            FrameKind::Stage => 9,
            FrameKind::StageAck => 10,
            FrameKind::Evict => 11,
            FrameKind::JobRef => 12,
            FrameKind::StageRef => 13,
            FrameKind::RespRef => 14,
        }
    }

    fn from_u16(x: u16) -> Option<FrameKind> {
        match x {
            1 => Some(FrameKind::Job),
            2 => Some(FrameKind::Shutdown),
            3 => Some(FrameKind::RespOk),
            4 => Some(FrameKind::RespFail),
            5 => Some(FrameKind::Ping),
            6 => Some(FrameKind::Pong),
            7 => Some(FrameKind::Hello),
            8 => Some(FrameKind::Goodbye),
            9 => Some(FrameKind::Stage),
            10 => Some(FrameKind::StageAck),
            11 => Some(FrameKind::Evict),
            12 => Some(FrameKind::JobRef),
            13 => Some(FrameKind::StageRef),
            14 => Some(FrameKind::RespRef),
            _ => None,
        }
    }
}

/// One decoded wire frame. The payload is a [`PooledBuf`]: cloning a frame
/// (or constructing one from an already-shared payload) never copies the
/// bytes, and a payload read off the wire returns its storage to the pool
/// when the last reference drops.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub job_id: u64,
    pub worker_id: u64,
    pub compute_us: u64,
    pub delay_us: u64,
    pub payload: PooledBuf,
}

fn saturating_micros(d: Duration) -> u64 {
    u64::try_from(d.as_micros()).unwrap_or(u64::MAX)
}

impl Frame {
    /// A master → worker job frame.
    pub fn job(job_id: u64, worker_id: usize, payload: impl Into<PooledBuf>) -> Frame {
        Frame {
            kind: FrameKind::Job,
            job_id,
            worker_id: worker_id as u64,
            compute_us: 0,
            delay_us: 0,
            payload: payload.into(),
        }
    }

    /// A master → worker shutdown frame.
    pub fn shutdown() -> Frame {
        Frame {
            kind: FrameKind::Shutdown,
            job_id: 0,
            worker_id: 0,
            compute_us: 0,
            delay_us: 0,
            payload: PooledBuf::default(),
        }
    }

    /// A payload-free control frame of the given kind.
    fn control(kind: FrameKind, job_id: u64, worker_id: u64) -> Frame {
        Frame { kind, job_id, worker_id, compute_us: 0, delay_us: 0, payload: PooledBuf::default() }
    }

    /// A master → worker health-check ping. The nonce rides in `job_id`.
    pub fn ping(nonce: u64) -> Frame {
        Frame::control(FrameKind::Ping, nonce, 0)
    }

    /// A worker → master pong echoing the ping's nonce, stamped with the
    /// daemon's machine id (0 if the master never said hello).
    pub fn pong(nonce: u64, worker_id: usize) -> Frame {
        Frame::control(FrameKind::Pong, nonce, worker_id as u64)
    }

    /// A hello frame carrying a machine id: the master sends one to assign
    /// the id, the daemon echoes it back to confirm.
    pub fn hello(worker_id: usize) -> Frame {
        Frame::control(FrameKind::Hello, 0, worker_id as u64)
    }

    /// A graceful-leave frame.
    pub fn goodbye(worker_id: usize) -> Frame {
        Frame::control(FrameKind::Goodbye, 0, worker_id as u64)
    }

    /// A master → worker stage frame: store `payload` (a prepared operand's
    /// A-side share half) under `prepared_id`.
    pub fn stage(prepared_id: u64, payload: impl Into<PooledBuf>) -> Frame {
        Frame {
            kind: FrameKind::Stage,
            job_id: prepared_id,
            worker_id: 0,
            compute_us: 0,
            delay_us: 0,
            payload: payload.into(),
        }
    }

    /// A worker → master stage-ack echoing `prepared_id`, stamped with the
    /// daemon's machine id.
    pub fn stage_ack(prepared_id: u64, worker_id: usize) -> Frame {
        Frame::control(FrameKind::StageAck, prepared_id, worker_id as u64)
    }

    /// A master → worker evict frame dropping `prepared_id`.
    pub fn evict(prepared_id: u64) -> Frame {
        Frame::control(FrameKind::Evict, prepared_id, 0)
    }

    /// The staged-operand tag of a job frame: `Some(prepared_id)` when the
    /// worker must prepend its staged A-half to this payload, `None` for a
    /// full-share job. (Job frames repurpose the otherwise-unused
    /// `compute_us` field as `prepared_id + 1`, 0 meaning unprepared.)
    pub fn job_prepared_id(&self) -> Option<u64> {
        ((self.kind == FrameKind::Job || self.kind == FrameKind::JobRef) && self.compute_us != 0)
            .then(|| self.compute_us - 1)
    }

    /// The 16-byte `(slot seq, payload len)` descriptor of a reference
    /// frame.
    fn ref_descriptor(seq: u64, len: u64) -> PooledBuf {
        let mut p = Vec::with_capacity(REF_PAYLOAD_LEN);
        p.extend_from_slice(&seq.to_le_bytes());
        p.extend_from_slice(&len.to_le_bytes());
        PooledBuf::from_vec(p)
    }

    /// Parse a reference frame's `(slot seq, payload len)` descriptor,
    /// rejecting malformed sizes and oversize declared lengths (same
    /// [`MAX_PAYLOAD`] guard as inline frames).
    pub fn ref_slot(&self) -> anyhow::Result<(u64, u64)> {
        anyhow::ensure!(
            matches!(self.kind, FrameKind::JobRef | FrameKind::StageRef | FrameKind::RespRef),
            "frame kind {:?} carries no slot reference",
            self.kind
        );
        anyhow::ensure!(
            self.payload.len() == REF_PAYLOAD_LEN,
            "reference frame payload is {} bytes (expected {REF_PAYLOAD_LEN})",
            self.payload.len()
        );
        let seq = le_u64(&self.payload[0..8]);
        let len = le_u64(&self.payload[8..16]);
        anyhow::ensure!(
            len <= MAX_PAYLOAD,
            "referenced payload length {len} exceeds the {MAX_PAYLOAD}-byte frame limit"
        );
        Ok((seq, len))
    }

    /// A master → worker job frame whose payload sits in ring slot `seq`.
    pub fn job_ref(job_id: u64, shard: usize, prepared: Option<u64>, seq: u64, len: u64) -> Frame {
        Frame {
            kind: FrameKind::JobRef,
            job_id,
            worker_id: shard as u64,
            compute_us: prepared.map_or(0, |p| p + 1),
            delay_us: 0,
            payload: Frame::ref_descriptor(seq, len),
        }
    }

    /// A master → worker stage frame whose staged bytes sit in ring slot
    /// `seq`.
    pub fn stage_ref(prepared_id: u64, seq: u64, len: u64) -> Frame {
        Frame {
            kind: FrameKind::StageRef,
            job_id: prepared_id,
            worker_id: 0,
            compute_us: 0,
            delay_us: 0,
            payload: Frame::ref_descriptor(seq, len),
        }
    }

    /// A worker → master response frame whose payload sits in ring slot
    /// `seq`.
    pub fn resp_ref(
        job_id: u64,
        worker_id: usize,
        compute: Duration,
        injected_delay: Duration,
        seq: u64,
        len: u64,
    ) -> Frame {
        Frame {
            kind: FrameKind::RespRef,
            job_id,
            worker_id: worker_id as u64,
            compute_us: saturating_micros(compute),
            delay_us: saturating_micros(injected_delay),
            payload: Frame::ref_descriptor(seq, len),
        }
    }

    /// Package a worker's job report as a response frame (durations are
    /// rounded to microseconds on the wire).
    pub fn from_report(msg: FromWorker) -> Frame {
        let FromWorker { job_id, worker_id, payload, compute, injected_delay } = msg;
        let (kind, payload) = match payload {
            Some(p) => (FrameKind::RespOk, p),
            None => (FrameKind::RespFail, PooledBuf::default()),
        };
        Frame {
            kind,
            job_id,
            worker_id: worker_id as u64,
            compute_us: saturating_micros(compute),
            delay_us: saturating_micros(injected_delay),
            payload,
        }
    }

    /// Reconstruct a worker's job report from a response frame. Errs on
    /// non-response kinds and on a response-failed frame that smuggles
    /// payload bytes.
    pub fn into_report(self) -> anyhow::Result<FromWorker> {
        let payload = match self.kind {
            FrameKind::RespOk => Some(self.payload),
            FrameKind::RespFail => {
                anyhow::ensure!(
                    self.payload.is_empty(),
                    "response-failed frame carries {} payload bytes",
                    self.payload.len()
                );
                None
            }
            other => anyhow::bail!("frame kind {other:?} is not a worker response"),
        };
        Ok(FromWorker {
            job_id: self.job_id,
            worker_id: usize::try_from(self.worker_id)?,
            payload,
            compute: Duration::from_micros(self.compute_us),
            injected_delay: Duration::from_micros(self.delay_us),
        })
    }
}

/// Serialize one frame from borrowed parts — **scatter-gather**: the
/// 48-byte header is assembled on the stack and handed to `write_vectored`
/// alongside the *borrowed* payload, so header and payload still go out as
/// one syscall on a `TCP_NODELAY` socket (one segment per frame) but
/// nothing is ever joined into a heap buffer. This is the per-message hot
/// path of the dispatch and response loops: zero allocations, zero payload
/// copies.
#[allow(clippy::too_many_arguments)]
fn write_frame_parts<W: Write>(
    w: &mut W,
    kind: FrameKind,
    job_id: u64,
    worker_id: u64,
    compute_us: u64,
    delay_us: u64,
    payload: &[u8],
) -> std::io::Result<()> {
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    header[4..6].copy_from_slice(&VERSION.to_le_bytes());
    header[6..8].copy_from_slice(&kind.to_u16().to_le_bytes());
    header[8..16].copy_from_slice(&job_id.to_le_bytes());
    header[16..24].copy_from_slice(&worker_id.to_le_bytes());
    header[24..32].copy_from_slice(&compute_us.to_le_bytes());
    header[32..40].copy_from_slice(&delay_us.to_le_bytes());
    header[40..48].copy_from_slice(&(payload.len() as u64).to_le_bytes());
    // Vectored writes may be partial; resume at the right offset across
    // both segments (and retry on EINTR) until the whole frame is out.
    let total = HEADER_LEN + payload.len();
    let mut off = 0usize;
    while off < total {
        let res = if off < HEADER_LEN {
            let bufs = [IoSlice::new(&header[off..]), IoSlice::new(payload)];
            w.write_vectored(&bufs)
        } else {
            w.write(&payload[off - HEADER_LEN..])
        };
        match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "failed to write whole frame",
                ))
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    w.flush()
}

/// Serialize one frame (single buffered write; see [`write_job_frame`] for
/// the copy-free job dispatch path).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    write_frame_parts(
        w,
        frame.kind,
        frame.job_id,
        frame.worker_id,
        frame.compute_us,
        frame.delay_us,
        &frame.payload,
    )
}

/// Write a job frame for `shard` of `job_id` straight from a borrowed
/// payload. Speculative re-dispatch keeps one `Arc<Vec<u8>>` per in-flight
/// shard and may send the same bytes to several workers; this path avoids
/// cloning the payload into an owned [`Frame`] per send. `prepared` names a
/// staged operand the daemon must prepend (see [`Frame::job_prepared_id`]).
pub fn write_job_frame<W: Write>(
    w: &mut W,
    job_id: u64,
    shard: usize,
    prepared: Option<u64>,
    payload: &[u8],
) -> std::io::Result<()> {
    let tag = prepared.map_or(0, |p| p + 1);
    write_frame_parts(w, FrameKind::Job, job_id, shard as u64, tag, 0, payload)
}

/// Read exactly `buf.len()` bytes, reporting how many were read before EOF.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(filled)
}

fn le_u16(buf: &[u8]) -> u16 {
    u16::from_le_bytes(buf.try_into().expect("2-byte slice"))
}

fn le_u32(buf: &[u8]) -> u32 {
    u32::from_le_bytes(buf.try_into().expect("4-byte slice"))
}

fn le_u64(buf: &[u8]) -> u64 {
    u64::from_le_bytes(buf.try_into().expect("8-byte slice"))
}

/// Read and validate one frame. `Ok(None)` means the peer closed the stream
/// cleanly on a frame boundary; every malformed case — truncated header or
/// payload, bad magic, unknown version or kind, oversized declared payload
/// length — is an `Err`.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    let got = read_full(r, &mut header)?;
    if got == 0 {
        return Ok(None);
    }
    anyhow::ensure!(got == HEADER_LEN, "truncated frame header ({got}/{HEADER_LEN} bytes)");

    let magic = le_u32(&header[0..4]);
    anyhow::ensure!(magic == MAGIC, "bad frame magic {magic:#010x} (expected {MAGIC:#010x})");
    let version = le_u16(&header[4..6]);
    anyhow::ensure!(version == VERSION, "unsupported protocol version {version} (speak {VERSION})");
    let kind = le_u16(&header[6..8]);
    let kind = FrameKind::from_u16(kind)
        .ok_or_else(|| anyhow::anyhow!("unknown frame kind {kind}"))?;
    let payload_len = le_u64(&header[40..48]);
    anyhow::ensure!(
        payload_len <= MAX_PAYLOAD,
        "declared payload length {payload_len} exceeds the {MAX_PAYLOAD}-byte frame limit"
    );

    // The length is validated (≤ MAX_PAYLOAD = the pool's largest class),
    // so the payload buffer is pool-leased rather than freshly allocated —
    // a steady frame stream recycles the same storage.
    let mut payload = BytePool::global().lease(payload_len as usize);
    payload.resize(payload_len as usize, 0);
    let got = read_full(r, &mut payload)?;
    anyhow::ensure!(
        got == payload_len as usize,
        "truncated frame payload ({got}/{payload_len} bytes)"
    );
    Ok(Some(Frame {
        kind,
        job_id: le_u64(&header[8..16]),
        worker_id: le_u64(&header[16..24]),
        compute_us: le_u64(&header[24..32]),
        delay_us: le_u64(&header[32..40]),
        payload: payload.freeze(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng64;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        let mut cur = Cursor::new(buf);
        let back = read_frame(&mut cur).unwrap().expect("one frame in");
        // stream is exactly one frame long
        assert!(read_frame(&mut cur).unwrap().is_none());
        back
    }

    #[test]
    fn every_kind_roundtrips() {
        let frames = [
            Frame::job(7, 3, vec![1, 2, 3, 4, 5]),
            Frame::shutdown(),
            Frame {
                kind: FrameKind::RespOk,
                job_id: u64::MAX,
                worker_id: 31,
                compute_us: 1234,
                delay_us: 99,
                payload: vec![0xAB; 1000].into(),
            },
            Frame {
                kind: FrameKind::RespFail,
                job_id: 0,
                worker_id: 0,
                compute_us: 0,
                delay_us: 0,
                payload: PooledBuf::default(),
            },
        ];
        for frame in frames {
            assert_eq!(roundtrip(&frame), frame);
        }
    }

    #[test]
    fn control_kinds_roundtrip_and_carry_no_payload() {
        let frames = [
            Frame::ping(0xDEAD_BEEF),
            Frame::pong(0xDEAD_BEEF, 13),
            Frame::hello(7),
            Frame::goodbye(7),
        ];
        for frame in frames {
            assert!(frame.payload.is_empty());
            assert_eq!(roundtrip(&frame), frame);
            // control frames are not worker reports
            assert!(frame.clone().into_report().is_err());
        }
        assert_eq!(Frame::ping(42).job_id, 42, "nonce rides in job_id");
        assert_eq!(Frame::pong(42, 3).job_id, 42);
        assert_eq!(Frame::hello(5).worker_id, 5);
    }

    #[test]
    fn job_frame_from_borrowed_parts_matches_owned_encoding() {
        let payload = vec![3u8; 129];
        let mut owned = Vec::new();
        write_frame(&mut owned, &Frame::job(77, 4, payload.clone())).unwrap();
        let mut borrowed = Vec::new();
        write_job_frame(&mut borrowed, 77, 4, None, &payload).unwrap();
        assert_eq!(owned, borrowed);
    }

    #[test]
    fn stage_frames_roundtrip_and_carry_the_prepared_id() {
        let stage = Frame::stage(5, vec![1, 2, 3]);
        assert_eq!(roundtrip(&stage), stage);
        assert_eq!(stage.job_id, 5, "prepared id rides in job_id");
        let ack = Frame::stage_ack(5, 3);
        assert_eq!(roundtrip(&ack), ack);
        assert_eq!((ack.job_id, ack.worker_id), (5, 3));
        assert!(ack.payload.is_empty());
        let evict = Frame::evict(5);
        assert_eq!(roundtrip(&evict), evict);
        assert!(evict.payload.is_empty());
        // staging frames are not worker reports
        assert!(stage.into_report().is_err());
        assert!(ack.into_report().is_err());
    }

    #[test]
    fn prepared_job_tag_roundtrips_through_compute_us() {
        let payload = vec![8u8; 16];
        let mut buf = Vec::new();
        write_job_frame(&mut buf, 42, 1, Some(0), &payload).unwrap();
        let frame = read_frame(&mut Cursor::new(buf)).unwrap().unwrap();
        assert_eq!(frame.job_prepared_id(), Some(0), "id 0 is distinguishable from unprepared");
        let mut buf = Vec::new();
        write_job_frame(&mut buf, 42, 1, Some(9), &payload).unwrap();
        let frame = read_frame(&mut Cursor::new(buf)).unwrap().unwrap();
        assert_eq!(frame.job_prepared_id(), Some(9));
        assert_eq!(Frame::job(42, 1, vec![]).job_prepared_id(), None);
        // only job frames carry the tag
        assert_eq!(Frame::stage(7, vec![]).job_prepared_id(), None);
    }

    #[test]
    fn random_payloads_roundtrip() {
        let mut rng = Rng64::seeded(41);
        for _ in 0..50 {
            let len = rng.below_usize(4096);
            let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let frame = Frame::job(rng.next_u64(), rng.below_usize(64), payload);
            assert_eq!(roundtrip(&frame), frame);
        }
    }

    #[test]
    fn every_truncation_point_is_a_clean_error() {
        let frame = Frame::job(11, 2, vec![9u8; 64]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        for cut in 0..buf.len() {
            let mut cur = Cursor::new(&buf[..cut]);
            let res = read_frame(&mut cur);
            if cut == 0 {
                assert!(matches!(res, Ok(None)), "empty stream is a clean EOF");
            } else {
                let err = res.unwrap_err().to_string();
                assert!(err.contains("truncated"), "cut at {cut}: {err}");
            }
        }
    }

    #[test]
    fn v3_kinds_reject_truncation_at_every_offset() {
        // The staging frames are the newest wire surface; hold them to the
        // same standard as job frames — a cut anywhere (mid-header or
        // mid-payload) is a clean error, never a panic, hang, or misparse.
        let frames = [
            Frame::stage(3, vec![0x5A; 33]),
            Frame::stage_ack(3, 6),
            Frame::evict(3),
        ];
        for frame in frames {
            let mut buf = Vec::new();
            write_frame(&mut buf, &frame).unwrap();
            for cut in 0..buf.len() {
                let mut cur = Cursor::new(&buf[..cut]);
                let res = read_frame(&mut cur);
                if cut == 0 {
                    assert!(matches!(res, Ok(None)), "empty stream is a clean EOF");
                } else {
                    let err = res.unwrap_err().to_string();
                    assert!(
                        err.contains("truncated"),
                        "{:?} cut at {cut}: {err}",
                        frame.kind
                    );
                }
            }
            // and the untruncated stream still parses back exactly
            assert_eq!(roundtrip(&frame), frame);
        }
    }

    #[test]
    fn v3_kinds_reject_bad_kind_and_oversize() {
        for frame in [Frame::stage(9, vec![1, 2, 3]), Frame::stage_ack(9, 1), Frame::evict(9)] {
            let mut good = Vec::new();
            write_frame(&mut good, &frame).unwrap();

            // kind 15 is one past response-ref — the first unassigned
            // discriminator
            let mut bad_kind = good.clone();
            bad_kind[6..8].copy_from_slice(&15u16.to_le_bytes());
            let err = read_frame(&mut Cursor::new(bad_kind)).unwrap_err().to_string();
            assert!(err.contains("kind"), "{err}");

            // forged oversize payload_len must be rejected before allocation
            let mut oversize = good.clone();
            oversize[40..48].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
            let err = read_frame(&mut Cursor::new(oversize)).unwrap_err().to_string();
            assert!(err.contains("exceeds"), "{err}");
        }
    }

    #[test]
    fn reference_kinds_roundtrip_and_parse_their_slot() {
        let job = Frame::job_ref(21, 3, Some(4), 17, 4096);
        assert_eq!(roundtrip(&job), job);
        assert_eq!(job.ref_slot().unwrap(), (17, 4096));
        assert_eq!(job.job_prepared_id(), Some(4), "prepared tag rides job-refs too");
        assert_eq!(Frame::job_ref(21, 3, None, 17, 4096).job_prepared_id(), None);

        let stage = Frame::stage_ref(9, 2, 128);
        assert_eq!(roundtrip(&stage), stage);
        assert_eq!(stage.ref_slot().unwrap(), (2, 128));

        let resp = Frame::resp_ref(
            21,
            3,
            Duration::from_micros(55),
            Duration::from_micros(7),
            18,
            512,
        );
        assert_eq!(roundtrip(&resp), resp);
        assert_eq!(resp.ref_slot().unwrap(), (18, 512));
        assert_eq!(resp.compute_us, 55);

        // non-reference kinds carry no slot; malformed descriptors and
        // oversize declared lengths are clean errors
        assert!(Frame::job(1, 0, vec![0u8; REF_PAYLOAD_LEN]).ref_slot().is_err());
        let mut short = Frame::stage_ref(1, 0, 0);
        short.payload = vec![0u8; 8].into();
        assert!(short.ref_slot().is_err());
        let oversize = Frame::stage_ref(1, 0, MAX_PAYLOAD + 1);
        assert!(oversize.ref_slot().unwrap_err().to_string().contains("exceeds"));
    }

    #[test]
    fn bad_magic_version_kind_rejected() {
        let frame = Frame::job(1, 0, vec![7u8; 8]);
        let mut good = Vec::new();
        write_frame(&mut good, &frame).unwrap();

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(bad_magic)).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        let mut bad_version = good.clone();
        bad_version[4] = 0xEE;
        let err = read_frame(&mut Cursor::new(bad_version)).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        let mut bad_kind = good.clone();
        bad_kind[6] = 0x7F;
        let err = read_frame(&mut Cursor::new(bad_kind)).unwrap_err().to_string();
        assert!(err.contains("kind"), "{err}");
    }

    #[test]
    fn oversized_declared_payload_rejected_before_allocation() {
        let frame = Frame::job(1, 0, Vec::new());
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        // forge payload_len = 2^40 without materializing any payload
        buf[40..48].copy_from_slice(&(1u64 << 40).to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn report_conversion_roundtrips_and_validates() {
        let ok = FromWorker {
            job_id: 5,
            worker_id: 2,
            payload: Some(vec![1u8, 2, 3].into()),
            compute: Duration::from_micros(777),
            injected_delay: Duration::from_micros(12),
        };
        let back = Frame::from_report(ok).into_report().unwrap();
        assert_eq!(back.job_id, 5);
        assert_eq!(back.worker_id, 2);
        assert_eq!(back.payload.as_deref(), Some(&[1u8, 2, 3][..]));
        assert_eq!(back.compute, Duration::from_micros(777));

        let fail = FromWorker {
            job_id: 6,
            worker_id: 1,
            payload: None,
            compute: Duration::ZERO,
            injected_delay: Duration::ZERO,
        };
        let back = Frame::from_report(fail).into_report().unwrap();
        assert!(back.payload.is_none());

        // a response-failed frame smuggling bytes is a protocol error
        let mut forged = Frame::shutdown();
        forged.kind = FrameKind::RespFail;
        forged.payload = vec![1u8].into();
        assert!(forged.into_report().is_err());
        // a job frame is not a report
        assert!(Frame::job(0, 0, Vec::new()).into_report().is_err());
    }
}
