//! The §V.C extension experiment: "in a configuration with 32 worker nodes
//! (requiring operations over GR(2^64, 5)), setting n = 3 and using a
//! (3,5)-RMFE enables a more efficient packing strategy".
//!
//! We run EP (plain, m=5) vs EP_RMFE-I (n=3, via the ∞-point (3,5)-RMFE) at
//! N = 32 and report the same master/worker metrics as Figures 2–5 — the
//! expected shape is a ~3× reduction in encode time, upload volume and
//! worker compute. Both schemes come from the erased registry.

use crate::codes::registry::{self, SchemeConfig};
use crate::coordinator::runner::{run_erased, NativeCompute};
use crate::coordinator::{Coordinator, StragglerModel};
use crate::ring::matrix::Matrix;
use crate::ring::zq::Zq;
use crate::util::bench::markdown_table;
use crate::util::json::Json;
use crate::util::rng::Rng64;
use std::sync::Arc;

pub struct Rmfe35Record {
    pub scheme: String,
    pub size: usize,
    pub encode_s: f64,
    pub decode_s: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub worker_compute_s: f64,
}

impl Rmfe35Record {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme.as_str())
            .set("size", self.size)
            .set("encode_s", self.encode_s)
            .set("decode_s", self.decode_s)
            .set("upload_bytes", self.upload_bytes)
            .set("download_bytes", self.download_bytes)
            .set("worker_compute_s", self.worker_compute_s)
    }
}

pub fn run(sizes: &[usize], seed: u64) -> anyhow::Result<Vec<Rmfe35Record>> {
    let base = Zq::z2e(64);
    // N = 32 over GR(2^64, 5), u = v = 2, w = 1; EP_RMFE-I packs n = 3 via
    // the ∞-point (3,5)-RMFE.
    let cfg = SchemeConfig { n_workers: 32, m: 5, u: 2, w: 1, v: 2, n_split: 3 };
    let mut rng = Rng64::seeded(seed);
    let mut out = Vec::new();
    for &size in sizes {
        anyhow::ensure!(size % 12 == 0, "size must be divisible by 12 (u·v·n=3 splits)");
        let a = Matrix::random(&base, size, size, &mut rng);
        let b = Matrix::random(&base, size, size, &mut rng);

        for (label, reg_name, seed_xor) in
            [("EP (m=5)", "ep", 0u64), ("EP_RMFE-I (n=3, (3,5)-RMFE)", "ep-rmfe-1", 3)]
        {
            let scheme = registry::build(reg_name, &cfg)?;
            let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
            let mut coord =
                Coordinator::new(cfg.n_workers, backend, StragglerModel::None, seed ^ seed_xor);
            let (c, m) = run_erased(
                &base,
                scheme.as_ref(),
                &mut coord,
                std::slice::from_ref(&a),
                std::slice::from_ref(&b),
            )?;
            debug_assert_eq!(c[0], Matrix::matmul(&base, &a, &b));
            coord.shutdown();
            out.push(Rmfe35Record {
                scheme: label.into(),
                size,
                encode_s: m.encode.as_secs_f64(),
                decode_s: m.decode.as_secs_f64(),
                upload_bytes: m.upload_bytes,
                download_bytes: m.download_bytes,
                worker_compute_s: m.mean_worker_compute().as_secs_f64(),
            });
        }
    }
    Ok(out)
}

pub fn render(records: &[Rmfe35Record]) -> String {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.scheme.clone(),
                r.size.to_string(),
                format!("{:.4}", r.encode_s),
                format!("{:.4}", r.decode_s),
                format!("{:.2}", r.upload_bytes as f64 / 1e6),
                format!("{:.2}", r.download_bytes as f64 / 1e6),
                format!("{:.4}", r.worker_compute_s),
            ]
        })
        .collect();
    markdown_table(
        &[
            "scheme",
            "size",
            "encode (s)",
            "decode (s)",
            "upload (MB)",
            "download (MB)",
            "worker (s)",
        ],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmfe35_runs_and_packs_3x() {
        let recs = run(&[24], 99).unwrap();
        assert_eq!(recs.len(), 2);
        // upload ratio ≈ 1/3 (n = 3 packing), within header slack
        let ratio = recs[1].upload_bytes as f64 / recs[0].upload_bytes as f64;
        assert!(
            (ratio - 1.0 / 3.0).abs() < 0.05,
            "upload ratio {ratio} (expect ≈ 1/3)"
        );
    }
}
