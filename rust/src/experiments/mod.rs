//! The evaluation harness: regenerates every table and figure of the
//! paper's evaluation section (see DESIGN.md §3 for the index).
//!
//! * [`figs`] — Figures 2–5: master/worker computation time and
//!   communication volume for EP (plain), EP_RMFE-I and EP_RMFE-II at 8 and
//!   16 workers over `Z_{2^64}`;
//! * [`table1`] — Table 1: GCSA vs Batch-EP_RMFE (analytic rows for all κ +
//!   a measured CSA-vs-Batch-EP_RMFE run at the `uvw = 1, κ = n` point);
//! * [`rmfe35`] — the §V.C extension: 32 workers, `GR(2^64, 5)`, `(3,5)`-RMFE;
//! * [`serving`] — serving throughput: the pipelined multi-job coordinator
//!   vs the sequential submit+wait baseline (jobs/s, decode-plan cache
//!   hits) — the steady-state workload §I motivates.
//!
//! Every entry point prints a markdown table (the "rows/series the paper
//! reports") and can emit JSON for plotting.

pub mod figs;
pub mod table1;
pub mod rmfe35;
pub mod serving;

/// Default scaled-down sizes (CI-speed); `--full` switches to the paper's
/// 2000–8000.
pub const DEFAULT_SIZES: &[usize] = &[128, 256, 384, 512];
pub const PAPER_SIZES: &[usize] = &[2000, 4000, 6000, 8000];
