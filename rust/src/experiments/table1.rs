//! Table 1 (§III-B): comparison of batch-coded matrix multiplication over a
//! Galois ring — GCSA codes [4] vs the paper's Batch-EP_RMFE.
//!
//! The paper's Table 1 is an *analytic complexity table*; we reproduce it two
//! ways:
//!
//! 1. **Analytic rows** — the closed forms, instantiated with concrete
//!    parameters `(N, n, κ, u, v, w, t, r, s)` so "who wins by what factor"
//!    is visible as numbers, for every divisor κ of n;
//! 2. **Measured point** — at `uvw = 1, κ = n` GCSA degenerates to CSA codes
//!    (implemented in `codes::csa`), which we run head-to-head against
//!    Batch-EP_RMFE on the coordinator, reporting measured thresholds,
//!    wire bytes and encode/decode times. Both schemes come from the erased
//!    registry (the `csa` entry embeds `Z_{2^64}` inputs into the extension
//!    itself, exactly as GCSA prescribes) and run through
//!    [`run_erased`].

use crate::codes::registry::{self, SchemeConfig};
use crate::coordinator::runner::{run_erased, NativeCompute};
use crate::coordinator::{Coordinator, StragglerModel};
use crate::ring::matrix::Matrix;
use crate::ring::zq::Zq;
use crate::util::bench::markdown_table;
use crate::util::json::Json;
use crate::util::rng::Rng64;
use std::sync::Arc;

/// Analytic Table-1 row for given parameters. Complexities are reported as
/// operation/element *counts* in the base ring GR (the paper's unit),
/// dropping the log² factors common to both columns.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub kappa: usize,
    pub gcsa_r: usize,
    pub ours_r: usize,
    pub gcsa_upload: f64,
    pub ours_upload: f64,
    pub gcsa_download: f64,
    pub ours_download: f64,
    pub gcsa_worker: f64,
    pub ours_worker: f64,
}

impl Table1Row {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("kappa", self.kappa)
            .set("gcsa_r", self.gcsa_r)
            .set("ours_r", self.ours_r)
            .set("gcsa_upload", self.gcsa_upload)
            .set("ours_upload", self.ours_upload)
            .set("gcsa_download", self.gcsa_download)
            .set("ours_download", self.ours_download)
            .set("gcsa_worker", self.gcsa_worker)
            .set("ours_worker", self.ours_worker)
    }
}

/// Instantiate the Table-1 formulas (amortized per matrix multiplication).
#[allow(clippy::too_many_arguments)]
pub fn analytic_rows(
    n_workers: usize,
    n_batch: usize,
    u: usize,
    v: usize,
    w: usize,
    t: usize,
    r: usize,
    s: usize,
) -> Vec<Table1Row> {
    let nf = n_batch as f64;
    let (tf, rf, sf) = (t as f64, r as f64, s as f64);
    let nn = n_workers as f64;
    let upload_unit = (tf * rf * v as f64 + sf * rf * u as f64) / (u * v * w) as f64;
    let worker_unit = tf * rf * sf / (u * v * w) as f64;
    let mut rows = Vec::new();
    for kappa in 1..=n_batch {
        if n_batch % kappa != 0 {
            continue;
        }
        let gcsa_r = u * v * w * (n_batch + kappa - 1) + w - 1;
        let ours_r = u * v * w + w - 1;
        rows.push(Table1Row {
            kappa,
            gcsa_r,
            ours_r,
            gcsa_upload: upload_unit * (nf / kappa as f64) * nn,
            ours_upload: upload_unit * nn,
            gcsa_download: (tf * sf / (u * v) as f64) * gcsa_r as f64,
            ours_download: (tf * sf / (u * v) as f64) * ours_r as f64,
            gcsa_worker: worker_unit * (nf / kappa as f64),
            ours_worker: worker_unit,
        });
    }
    rows
}

pub fn render_analytic(rows: &[Table1Row]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kappa.to_string(),
                format!("{} / {}", r.gcsa_r, r.ours_r),
                format!("{:.3e} / {:.3e}", r.gcsa_upload, r.ours_upload),
                format!("{:.3e} / {:.3e}", r.gcsa_download, r.ours_download),
                format!("{:.3e} / {:.3e}", r.gcsa_worker, r.ours_worker),
            ]
        })
        .collect();
    markdown_table(
        &[
            "κ",
            "R (GCSA / ours)",
            "upload GR-elems (GCSA / ours)",
            "download GR-elems (GCSA / ours)",
            "worker ops (GCSA / ours)",
        ],
        &body,
    )
}

/// Measured head-to-head at the runnable point: CSA (`uvw=1, κ=n`, `R=2n−1`)
/// vs Batch-EP_RMFE (`u=v=w=1`, `R=1`) on the same batch over `Z_{2^64}`.
pub struct MeasuredPoint {
    pub scheme: String,
    pub recovery_threshold: usize,
    pub encode_s: f64,
    pub decode_s: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub worker_compute_s: f64,
}

impl MeasuredPoint {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme.as_str())
            .set("recovery_threshold", self.recovery_threshold)
            .set("encode_s", self.encode_s)
            .set("decode_s", self.decode_s)
            .set("upload_bytes", self.upload_bytes)
            .set("download_bytes", self.download_bytes)
            .set("worker_compute_s", self.worker_compute_s)
    }
}

pub fn measured_point(
    n_batch: usize,
    size: usize,
    seed: u64,
) -> anyhow::Result<Vec<MeasuredPoint>> {
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    let a: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, size, size, &mut rng)).collect();
    let b: Vec<_> = (0..n_batch).map(|_| Matrix::random(&base, size, size, &mut rng)).collect();
    let mut out = Vec::new();

    // Registry configs for the two runnable points. Batch-EP_RMFE uses
    // u=v=w=1 (pure batching; R = 1) with m = max(2n−1, ⌈log₂ N⌉); the CSA
    // entry sizes its own extension for n + N exceptional points.
    let runs = [
        (
            "batch-ep-rmfe",
            SchemeConfig {
                n_workers: 4,
                m: (2 * n_batch - 1).max(2),
                u: 1,
                w: 1,
                v: 1,
                n_split: n_batch,
            },
            seed,
        ),
        (
            "csa",
            SchemeConfig {
                n_workers: 2 * n_batch + 1,
                m: 0, // unused: csa derives m from n_split + n_workers
                u: 1,
                w: 1,
                v: 1,
                n_split: n_batch,
            },
            seed ^ 1,
        ),
    ];

    for (name, cfg, run_seed) in runs {
        let scheme = registry::build(name, &cfg)?;
        let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
        let mut coord = Coordinator::new(cfg.n_workers, backend, StragglerModel::None, run_seed);
        let (c, m) = run_erased(&base, scheme.as_ref(), &mut coord, &a, &b)?;
        for k in 0..n_batch {
            debug_assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]));
        }
        coord.shutdown();
        out.push(MeasuredPoint {
            scheme: scheme.name(),
            recovery_threshold: scheme.recovery_threshold(),
            encode_s: m.encode.as_secs_f64(),
            decode_s: m.decode.as_secs_f64(),
            upload_bytes: m.upload_bytes,
            download_bytes: m.download_bytes,
            worker_compute_s: m.mean_worker_compute().as_secs_f64(),
        });
    }
    Ok(out)
}

pub fn render_measured(points: &[MeasuredPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.scheme.clone(),
                p.recovery_threshold.to_string(),
                format!("{:.4}", p.encode_s),
                format!("{:.4}", p.decode_s),
                format!("{:.2}", p.upload_bytes as f64 / 1e6),
                format!("{:.2}", p.download_bytes as f64 / 1e6),
                format!("{:.4}", p.worker_compute_s),
            ]
        })
        .collect();
    markdown_table(
        &["scheme", "R", "encode (s)", "decode (s)", "upload (MB)", "download (MB)", "worker (s)"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_matches_paper_formulas() {
        // N=8, n=4, u=v=2, w=1, square 1000.
        let rows = analytic_rows(8, 4, 2, 2, 1, 1000, 1000, 1000);
        // κ divisors of 4: 1, 2, 4.
        assert_eq!(rows.len(), 3);
        let k1 = &rows[0];
        assert_eq!(k1.kappa, 1);
        assert_eq!(k1.gcsa_r, 2 * 2 * 1 * (4 + 1 - 1) + 1 - 1); // 16
        assert_eq!(k1.ours_r, 4);
        // at κ=n the comm is equal but GCSA's R is ~2n× ours:
        let kn = rows.last().unwrap();
        assert_eq!(kn.kappa, 4);
        assert!((kn.gcsa_upload - kn.ours_upload).abs() < 1e-9);
        assert_eq!(kn.gcsa_r, 2 * 2 * (4 + 4 - 1)); // uvw(n+κ−1)+w−1 = 28
    }

    #[test]
    fn measured_point_runs() {
        let pts = measured_point(2, 8, 77).unwrap();
        assert_eq!(pts.len(), 2);
        // Batch-EP_RMFE threshold (uvw=1 ⇒ R=1) < CSA's 2n−1 = 3.
        assert!(pts[0].recovery_threshold < pts[1].recovery_threshold);
        let table = render_measured(&pts);
        assert!(table.contains("CSA/GCSA"));
    }

    #[test]
    fn render_analytic_table() {
        let rows = analytic_rows(16, 2, 2, 2, 2, 64, 64, 64);
        let t = render_analytic(&rows);
        assert!(t.contains("κ"));
    }
}
