//! Figures 2–5 (§V.B, §V.C): master/worker computation time and
//! communication volume for the three single-DMM schemes over `Z_{2^64}`.
//!
//! Configurations (exactly §V.A, via
//! [`SchemeConfig::for_workers`]):
//! * 8 workers — `GR(2^64, 3)`, `u = v = 2, w = 1` ⇒ `R = 4`, both RMFE
//!   variants at `n = 2`;
//! * 16 workers — `GR(2^64, 4)`, `u = v = w = 2` ⇒ `R = 9`, `n = 2`.
//!
//! One sweep produces both the master view (Figs 2/3: encode+decode time,
//! upload/download volume) and the worker view (Figs 4/5: per-worker compute
//! time and per-worker communication) — the paper plots the same runs from
//! two angles, and so do we.
//!
//! Every scheme is built through the erased registry and driven with
//! [`run_erased`] — one code path, no per-scheme monomorphized plumbing.

use crate::codes::registry::{self, SchemeConfig};
use crate::coordinator::runner::{run_erased, NativeCompute};
use crate::coordinator::{Coordinator, JobMetrics, StragglerModel};
use crate::ring::matrix::Matrix;
use crate::ring::zq::Zq;
use crate::util::bench::markdown_table;
use crate::util::json::Json;
use crate::util::rng::Rng64;
use std::sync::Arc;

/// One measured point of the sweep.
#[derive(Clone, Debug)]
pub struct FigRecord {
    pub scheme: String,
    pub n_workers: usize,
    pub size: usize,
    /// Mean metrics across reps.
    pub encode_s: f64,
    pub decode_s: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub worker_compute_s: f64,
    pub per_worker_down: u64,
    pub per_worker_up: u64,
}

impl FigRecord {
    fn from_metrics(
        scheme: &str,
        n_workers: usize,
        size: usize,
        runs: &[JobMetrics],
    ) -> FigRecord {
        let n = runs.len() as f64;
        let m0 = &runs[0];
        FigRecord {
            scheme: scheme.to_string(),
            n_workers,
            size,
            encode_s: runs.iter().map(|m| m.encode.as_secs_f64()).sum::<f64>() / n,
            decode_s: runs.iter().map(|m| m.decode.as_secs_f64()).sum::<f64>() / n,
            upload_bytes: m0.upload_bytes,
            download_bytes: m0.download_bytes,
            worker_compute_s: runs
                .iter()
                .map(|m| m.mean_worker_compute().as_secs_f64())
                .sum::<f64>()
                / n,
            per_worker_down: m0.per_worker_download(n_workers),
            per_worker_up: m0.per_worker_upload(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme.as_str())
            .set("n_workers", self.n_workers)
            .set("size", self.size)
            .set("encode_s", self.encode_s)
            .set("decode_s", self.decode_s)
            .set("upload_bytes", self.upload_bytes)
            .set("download_bytes", self.download_bytes)
            .set("worker_compute_s", self.worker_compute_s)
            .set("per_worker_down_bytes", self.per_worker_down)
            .set("per_worker_up_bytes", self.per_worker_up)
    }
}

/// The three single-DMM schemes of Figures 2–5: display label, registry
/// name, per-scheme seed perturbation.
const FIG_SCHEMES: &[(&str, &str, u64)] =
    &[("EP", "ep", 0), ("EP_RMFE-I", "ep-rmfe-1", 1), ("EP_RMFE-II", "ep-rmfe-2", 2)];

/// Run the sweep: for each size and scheme, run `reps` jobs and average.
pub fn sweep(
    cfg: &SchemeConfig,
    sizes: &[usize],
    reps: usize,
    seed: u64,
) -> anyhow::Result<Vec<FigRecord>> {
    let base = Zq::z2e(64);
    let mut records = Vec::new();
    let mut rng = Rng64::seeded(seed);

    for &size in sizes {
        anyhow::ensure!(
            size % (cfg.u.max(cfg.v) * cfg.n_split * cfg.w.max(1)) == 0,
            "size {size} must be divisible by the partition/split parameters"
        );
        let a = Matrix::random(&base, size, size, &mut rng);
        let b = Matrix::random(&base, size, size, &mut rng);

        for &(label, reg_name, seed_xor) in FIG_SCHEMES {
            let scheme = registry::build(reg_name, cfg)?;
            let backend = Arc::new(NativeCompute::new(Arc::clone(&scheme)));
            let mut coord =
                Coordinator::new(cfg.n_workers, backend, StragglerModel::None, seed ^ seed_xor);
            let mut runs = Vec::new();
            for _ in 0..reps {
                let (c, m) = run_erased(
                    &base,
                    scheme.as_ref(),
                    &mut coord,
                    std::slice::from_ref(&a),
                    std::slice::from_ref(&b),
                )?;
                debug_assert_eq!(c[0], Matrix::matmul(&base, &a, &b));
                runs.push(m);
            }
            coord.shutdown();
            records.push(FigRecord::from_metrics(label, cfg.n_workers, size, &runs));
        }
    }
    Ok(records)
}

/// Master view (Figures 2 & 3): encode/decode time + upload/download volume.
pub fn render_master_view(records: &[FigRecord]) -> String {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.scheme.clone(),
                r.size.to_string(),
                format!("{:.4}", r.encode_s),
                format!("{:.4}", r.decode_s),
                format!("{:.2}", r.upload_bytes as f64 / 1e6),
                format!("{:.2}", r.download_bytes as f64 / 1e6),
            ]
        })
        .collect();
    markdown_table(
        &["scheme", "size", "encode (s)", "decode (s)", "upload (MB)", "download (MB)"],
        &rows,
    )
}

/// Worker view (Figures 4 & 5): per-worker compute time + communication.
pub fn render_worker_view(records: &[FigRecord]) -> String {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.scheme.clone(),
                r.size.to_string(),
                format!("{:.4}", r.worker_compute_s),
                format!("{:.3}", r.per_worker_down as f64 / 1e6),
                format!("{:.3}", r.per_worker_up as f64 / 1e6),
            ]
        })
        .collect();
    markdown_table(
        &["scheme", "size", "worker compute (s)", "worker recv (MB)", "worker send (MB)"],
        &rows,
    )
}

pub fn records_to_json(records: &[FigRecord]) -> Json {
    Json::Arr(records.iter().map(|r| r.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_smallest_size_8_workers() {
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let recs = sweep(&cfg, &[16], 1, 7).unwrap();
        assert_eq!(recs.len(), 3);
        // the paper's headline ratios at n=2:
        let ep = &recs[0];
        let r1 = &recs[1];
        let r2 = &recs[2];
        assert_eq!(ep.scheme, "EP");
        // EP_RMFE-I halves upload; EP_RMFE-II halves download (±headers).
        let up_ratio = r1.upload_bytes as f64 / ep.upload_bytes as f64;
        assert!((up_ratio - 0.5).abs() < 0.05, "upload ratio {up_ratio}");
        let down_ratio = r2.download_bytes as f64 / ep.download_bytes as f64;
        assert!((down_ratio - 0.5).abs() < 0.05, "download ratio {down_ratio}");
        // EP_RMFE-I download matches EP.
        assert_eq!(r1.download_bytes, ep.download_bytes);
    }

    #[test]
    fn render_views() {
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let recs = sweep(&cfg, &[16], 1, 8).unwrap();
        let master = render_master_view(&recs);
        assert!(master.contains("encode (s)"));
        let worker = render_worker_view(&recs);
        assert!(worker.contains("worker compute (s)"));
    }

    #[test]
    fn config_16_is_paper_params() {
        let cfg = SchemeConfig::for_workers(16).unwrap();
        assert_eq!((cfg.m, cfg.u, cfg.w, cfg.v, cfg.n_split), (4, 2, 2, 2, 2));
    }

    #[test]
    fn unknown_worker_count_rejected() {
        assert!(SchemeConfig::for_workers(12).is_err());
    }
}
