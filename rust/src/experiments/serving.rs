//! Serving-throughput experiment: the pipelined multi-job coordinator vs
//! the sequential submit-then-wait baseline, on the same scheme, worker
//! pool shape and straggler model.
//!
//! This is the workload §I motivates coded computation with: a *stream* of
//! multiplication requests served by an `R`-of-`N` pool. Sequentially, the
//! master's encode/decode and the workers' compute strictly alternate —
//! worker queues idle while the master interpolates. Pipelined, up to
//! `inflight` jobs overlap: the master encodes job `k+1` and decodes job
//! `k−1` while the workers chew job `k`, and the decode-plan cache
//! ([`crate::codes::plan_cache`]) turns the recurring fast-`R` subset's
//! interpolation setup into a lookup.
//!
//! Each pass uses a **fresh scheme instance** (cold plan cache) and a
//! **fresh pool with the same seed** (identical straggler draws), so the
//! comparison isolates pipelining itself; the reported cache counters are
//! the pipelined pass's own. Every decoded product is verified against a
//! locally computed `A_k·B_k`, which also certifies warm-cache decodes
//! bit-identical to cold ones (the first decode of each subset is cold).
//!
//! The pool behind each pass is transport-selectable ([`ServeTransport`]):
//! the in-process channel pool, freshly spawned loopback TCP daemons
//! (identical straggler draws — the only delta vs in-process is the wire,
//! which is how the `serving_throughput` bench prices the transport), or
//! externally started `gr-cdmm worker` daemons via `--connect`.
//!
//! With [`ServeConfig::prepared`] on, the stream reuses one fixed `A` (the
//! fixed-weight serving shape §I motivates) and a **third pass** exercises
//! the encode-once path: the A-halves are staged on every worker via
//! [`Coordinator::prepare`], then each job encodes and ships only its
//! B-halves through [`Coordinator::submit_prepared`]. The run *asserts*
//! the encode-once proof obligations — exactly one A-side encode for the
//! whole stream (scheme counter), per-job upload equal to the summed
//! B-halves alone, staged bytes equal to the summed A-halves — and the
//! usual per-job verification certifies the decodes bit-identical to the
//! local reference products.
//!
//! With [`ServeConfig::verify_products`] on (`serve --verify-products`),
//! the throughput comparison is replaced by a single **Byzantine-tolerant
//! pass**: every job decodes through
//! [`run_verified_erased`](crate::coordinator::run_verified_erased) —
//! surplus responses are cross-checked against the decoded product,
//! exact-threshold decodes are Freivalds-checked, corrupt shares are
//! isolated by leave-one-out re-decode and their workers quarantined — so
//! a pool poisoned by [`ServeConfig::corrupt`] (`--corrupt`, injected at
//! the workers on both local transports) still serves bit-identical
//! products or fails fast naming the suspects, never emitting an
//! unverified wrong product. The pass also closes the download byte
//! ledger: `arrived == used + discarded + rejected` is asserted in-run.

use crate::codes::registry::{self, SchemeConfig};
use crate::codes::DynScheme;
use crate::coordinator::pool::ElasticConfig;
use crate::coordinator::runner::make_coordinator;
use crate::coordinator::{
    run_verified_erased, ChannelTransport, Coordinator, CorruptionModel, DaemonConfig, JobHandle,
    NativeCompute, ShareCompute, StragglerModel, VerifyOptions, WorkerDaemon,
};
use crate::ring::matrix::Matrix;
use crate::ring::zq::Zq;
use crate::util::bench::markdown_table;
use crate::util::json::Json;
use crate::util::rng::Rng64;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Which master ↔ worker transport a serving run uses.
#[derive(Clone, Debug, Default)]
pub enum ServeTransport {
    /// The in-process worker pool over mpsc channels (the default).
    #[default]
    InProcess,
    /// Spawn one real TCP worker daemon per worker on an ephemeral loopback
    /// port — fresh daemons per pass, same straggler model and seed, so the
    /// draws match [`ServeTransport::InProcess`] exactly and the only delta
    /// is the wire. Self-contained: no external processes needed.
    TcpLoopback,
    /// Like [`ServeTransport::TcpLoopback`] but over the shared-memory
    /// transport ([`crate::coordinator::shm`]): loopback daemons sharing a
    /// fresh ring directory with the coordinator, payloads out-of-line,
    /// control frames on TCP. Same straggler draws, same byte accounting.
    ShmLoopback,
    /// Connect to externally started `gr-cdmm worker` daemons (one
    /// endpoint per worker). The daemons own compute and straggler
    /// injection; both passes reconnect to the same daemons.
    Connect(Vec<String>),
}

impl ServeTransport {
    /// Short label for reports (`channel`, `tcp-loopback`, `shm`, `tcp`).
    pub fn label(&self) -> &'static str {
        match self {
            ServeTransport::InProcess => "channel",
            ServeTransport::TcpLoopback => "tcp-loopback",
            ServeTransport::ShmLoopback => "shm",
            ServeTransport::Connect(_) => "tcp",
        }
    }
}

/// One serving run's shape.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Registry scheme name (`ep`, `ep-rmfe-1`, `ep-rmfe-2`,
    /// `batch-ep-rmfe`, `csa`).
    pub scheme: String,
    pub n_workers: usize,
    /// Square input size per job (divisible by the partition/split params).
    pub size: usize,
    /// Number of jobs in the request stream.
    pub jobs: usize,
    /// Max jobs in flight in the pipelined pass (≥ 1).
    pub inflight: usize,
    pub straggler: StragglerModel,
    /// Byzantine corruption injected at the workers (`--corrupt`): the
    /// channel pool and freshly spawned loopback daemons corrupt with the
    /// same deterministic per-worker draws. [`ServeTransport::Connect`]
    /// daemons own their injection (`gr-cdmm worker --corrupt`), so a
    /// non-none model is rejected in that mode.
    pub corrupt: CorruptionModel,
    pub seed: u64,
    /// Verify every decoded product against a local `A·B` (also certifies
    /// warm-cache decodes identical to cold ones).
    pub verify: bool,
    /// Byzantine-tolerant serving (`--verify-products`): skip the plain
    /// throughput passes and run the stream through the verified decoder
    /// instead — surplus cross-checks, Freivalds product checks,
    /// leave-one-out isolation, quarantine + re-dispatch.
    pub verify_products: bool,
    /// Master ↔ worker transport (see [`ServeTransport`]).
    pub transport: ServeTransport,
    /// Enable speculative re-dispatch + background reconnect
    /// ([`ElasticConfig::speculative`]) on every pass's coordinator.
    pub speculate: bool,
    /// Elastic scheme selection: in [`ServeTransport::Connect`] mode, if
    /// fewer endpoints than `n_workers` are listed, downgrade to the
    /// largest preset the live pool can run
    /// ([`SchemeConfig::for_live_workers`]) instead of failing.
    pub elastic: bool,
    /// Fixed-weight serving: reuse one `A` across the whole stream and add
    /// a third, encode-once pass (stage A via [`Coordinator::prepare`],
    /// then `submit_prepared` B-only jobs). Requires a scheme with
    /// independent operand encodes (`ep`, `ep-rmfe-1`, `ep-rmfe-2`,
    /// `batch-ep-rmfe`); schemes without them (`csa`) fail with a clear
    /// error.
    pub prepared: bool,
}

/// Measured serving results.
#[derive(Clone, Debug)]
pub struct ServeRecord {
    pub scheme: String,
    /// Transport label (`channel`, `tcp-loopback`, `shm`, `tcp`).
    pub transport: String,
    pub n_workers: usize,
    pub size: usize,
    pub jobs: usize,
    pub inflight: usize,
    pub seq_elapsed_s: f64,
    pub seq_jobs_per_s: f64,
    pub pipe_elapsed_s: f64,
    pub pipe_jobs_per_s: f64,
    /// `pipe_jobs_per_s / seq_jobs_per_s`.
    pub speedup: f64,
    /// Decode-plan cache counters of the pipelined pass (cold at its start).
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// Speculative shard re-dispatches of the pipelined pass (0 unless
    /// [`ServeConfig::speculate`] is on).
    pub speculative_dispatches: u64,
    /// Whether the encode-once pass ran (all fields below are 0 when not).
    pub prepared: bool,
    /// Steady-state elapsed time of the prepared pass (staging excluded —
    /// it is the one-time cost `staged_upload_bytes` prices).
    pub prep_elapsed_s: f64,
    pub prep_jobs_per_s: f64,
    /// `prep_jobs_per_s / pipe_jobs_per_s` — the fixed-weight serving gain
    /// on top of pipelining.
    pub prep_speedup: f64,
    /// One-time A-half staging volume (bytes, all workers) of the prepared
    /// pass — equals the summed serialized A-halves by construction.
    pub staged_upload_bytes: u64,
    /// Total per-job upload of the prepared pass: the B-halves alone.
    pub prep_upload_bytes: u64,
    /// Total per-job upload of the pipelined pass (full A++B shares), for
    /// the ratio the encode-once path is about.
    pub pipe_upload_bytes: u64,
    /// Prepared-operand store counters of the prepared pass (hits must be
    /// one per job; misses/evictions 0 for a single staged operand).
    pub prepared_hits: u64,
    pub prepared_misses: u64,
    pub prepared_evictions: u64,
    /// A-side encodes performed *after* staging (must be 0: encode-once).
    pub steady_a_encodes: u64,
    /// Whether the Byzantine verified pass ran (`--verify-products`). When
    /// true the plain throughput passes were skipped and their fields are 0.
    pub verify_products: bool,
    /// Elapsed time / throughput of the verified pass (0 when it didn't run).
    pub vrfy_elapsed_s: f64,
    pub vrfy_jobs_per_s: f64,
    /// Responses the verified pass rejected as corrupt (malformed payloads
    /// plus shares flagged by surplus / leave-one-out consistency).
    pub corrupt_responses_detected: u64,
    /// Quarantine markings the verified pass issued.
    pub quarantines: u64,
    /// Freivalds product-check trials run across the stream.
    pub verify_trials: u64,
    /// Leave-one-out re-decodes run to isolate inconsistent shares.
    pub leave_one_out_decodes: u64,
    /// Bytes of rejected-corrupt responses (the dedicated
    /// [`ByteCounters`](crate::coordinator::ByteCounters) bucket).
    pub download_rejected_bytes: u64,
    /// Byte-pool buffer reuses during the pipelined pass (see
    /// [`crate::util::bytepool`]): with the pool warm from the sequential
    /// pass, every payload-sized buffer should be a hit.
    pub pool_hits: u64,
    /// Byte-pool misses (fresh heap allocations) during the pipelined pass.
    pub pool_misses: u64,
    /// Hot-path heap allocations ≥ 64 KiB during the pipelined pass — the
    /// zero-alloc counter-proof; 0 in the pooled steady state.
    pub large_allocs: u64,
    /// Deliberate in-memory payload copies during the pipelined pass
    /// ([`crate::util::bytepool::copied_bytes`] delta); only the prepared
    /// A++B reassembly charges this probe, so a plain pipelined pass shows
    /// 0.
    pub copied_bytes: u64,
    /// `true` iff every decoded product of both passes matched the local
    /// reference (trivially `true` when verification was disabled).
    pub verified: bool,
}

/// One request's pre-generated inputs (serialized for the byte facade) and
/// reference products.
struct Request {
    a_bytes: Vec<Vec<u8>>,
    b_bytes: Vec<Vec<u8>>,
    expected: Vec<Matrix<u64>>,
}

fn make_requests(cfg: &ServeConfig, batch: usize) -> Vec<Request> {
    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(cfg.seed ^ 0x5e21);
    // Fixed-weight serving reuses one A across the stream so all three
    // passes multiply the same operands and the comparison stays fair.
    let fixed_a: Option<Vec<Matrix<u64>>> = cfg.prepared.then(|| {
        (0..batch).map(|_| Matrix::random(&base, cfg.size, cfg.size, &mut rng)).collect()
    });
    (0..cfg.jobs)
        .map(|_| {
            let a: Vec<Matrix<u64>> = match &fixed_a {
                Some(a) => a.clone(),
                None => (0..batch)
                    .map(|_| Matrix::random(&base, cfg.size, cfg.size, &mut rng))
                    .collect(),
            };
            let b: Vec<Matrix<u64>> =
                (0..batch).map(|_| Matrix::random(&base, cfg.size, cfg.size, &mut rng)).collect();
            let expected = if cfg.verify {
                a.iter().zip(&b).map(|(ak, bk)| Matrix::matmul(&base, ak, bk)).collect()
            } else {
                Vec::new()
            };
            Request {
                a_bytes: a.iter().map(|m| m.to_bytes(&base)).collect(),
                b_bytes: b.iter().map(|m| m.to_bytes(&base)).collect(),
                expected,
            }
        })
        .collect()
}

/// Decode one collected job and verify it against the request's reference.
/// Returns `false` on any mismatch.
fn finish_job(
    scheme: &dyn DynScheme,
    req: &Request,
    handle: JobHandle,
) -> anyhow::Result<bool> {
    let (collected, _) = handle.wait()?;
    let responses: Vec<(usize, &[u8])> =
        collected.iter().map(|c| (c.worker_id, c.payload.as_slice())).collect();
    let out = scheme.decode_bytes(&responses)?;
    if req.expected.is_empty() {
        return Ok(true);
    }
    let base = Zq::z2e(64);
    anyhow::ensure!(out.len() == req.expected.len(), "decode returned a wrong batch size");
    for (buf, want) in out.iter().zip(&req.expected) {
        if &Matrix::from_bytes(&base, buf)? != want {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Run the request stream strictly sequentially: submit, wait, decode, next.
fn run_sequential(
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    requests: &[Request],
) -> anyhow::Result<(f64, bool)> {
    let need = scheme.recovery_threshold();
    let mut ok = true;
    let t0 = Instant::now();
    for req in requests {
        let payloads = scheme.encode_bytes(&req.a_bytes, &req.b_bytes)?;
        let handle = coord.submit(payloads, need)?;
        ok &= finish_job(scheme, req, handle)?;
    }
    Ok((t0.elapsed().as_secs_f64(), ok))
}

/// Run the request stream with up to `inflight` jobs overlapping: the
/// master encodes/submits ahead while older jobs are still at the workers,
/// and decodes the oldest one whenever the window is full.
fn run_pipelined(
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    requests: &[Request],
    inflight: usize,
) -> anyhow::Result<(f64, bool)> {
    let need = scheme.recovery_threshold();
    let mut window: VecDeque<(usize, JobHandle)> = VecDeque::with_capacity(inflight);
    let mut ok = true;
    let t0 = Instant::now();
    for (idx, req) in requests.iter().enumerate() {
        if window.len() == inflight {
            let (oldest, handle) = window.pop_front().expect("window is non-empty");
            ok &= finish_job(scheme, &requests[oldest], handle)?;
        }
        let payloads = scheme.encode_bytes(&req.a_bytes, &req.b_bytes)?;
        window.push_back((idx, coord.submit(payloads, need)?));
    }
    while let Some((idx, handle)) = window.pop_front() {
        ok &= finish_job(scheme, &requests[idx], handle)?;
    }
    Ok((t0.elapsed().as_secs_f64(), ok))
}

/// Run the stream through the encode-once path: encode the fixed `A`'s
/// share halves once, stage them on every worker, then pipeline
/// `submit_prepared` jobs that encode and ship only their B-halves.
/// Returns the steady-state elapsed time (staging excluded), the
/// verification flag, and the analytic `(staged A-half, summed B-half)`
/// byte volumes actually handed to the transport — the run asserts the
/// coordinator's counters match them exactly.
fn run_prepared(
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    requests: &[Request],
    inflight: usize,
) -> anyhow::Result<(f64, bool, u64, u64)> {
    let need = scheme.recovery_threshold();
    let a_halves = scheme.encode_left_bytes(&requests[0].a_bytes)?;
    let staged_bytes: u64 = a_halves.iter().map(|h| h.len() as u64).sum();
    let prep_id = coord.prepare(a_halves)?;
    let mut b_bytes = 0u64;
    let mut window: VecDeque<(usize, JobHandle)> = VecDeque::with_capacity(inflight);
    let mut ok = true;
    let t0 = Instant::now();
    for (idx, req) in requests.iter().enumerate() {
        if window.len() == inflight {
            let (oldest, handle) = window.pop_front().expect("window is non-empty");
            ok &= finish_job(scheme, &requests[oldest], handle)?;
        }
        let payloads = scheme.encode_right_bytes(&req.b_bytes)?;
        b_bytes += payloads.iter().map(|p| p.len() as u64).sum::<u64>();
        window.push_back((idx, coord.submit_prepared(prep_id, payloads, need)?));
    }
    while let Some((idx, handle)) = window.pop_front() {
        ok &= finish_job(scheme, &requests[idx], handle)?;
    }
    Ok((t0.elapsed().as_secs_f64(), ok, staged_bytes, b_bytes))
}

/// Verified-pass tallies summed over the stream's per-job metrics.
#[derive(Clone, Copy, Debug, Default)]
struct VerifiedStats {
    corrupt: u64,
    quarantines: u64,
    trials: u64,
    loo: u64,
}

/// Run the stream sequentially through the Byzantine-tolerant verified
/// decoder: every job drains surplus responses past the threshold,
/// cross-checks them against the decoded product, Freivalds-checks
/// exact-threshold decodes, and quarantines + re-dispatches around corrupt
/// workers. Returns the elapsed time, the reference-match flag, and the
/// summed detection tallies.
fn run_verified(
    scheme: &dyn DynScheme,
    coord: &mut Coordinator,
    requests: &[Request],
    seed: u64,
) -> anyhow::Result<(f64, bool, VerifiedStats)> {
    let base = Zq::z2e(64);
    let opts = VerifyOptions { seed, ..VerifyOptions::default() };
    let mut stats = VerifiedStats::default();
    let mut ok = true;
    let t0 = Instant::now();
    for req in requests {
        let a: Vec<Matrix<u64>> = req
            .a_bytes
            .iter()
            .map(|buf| Matrix::from_bytes(&base, buf))
            .collect::<anyhow::Result<_>>()?;
        let b: Vec<Matrix<u64>> = req
            .b_bytes
            .iter()
            .map(|buf| Matrix::from_bytes(&base, buf))
            .collect::<anyhow::Result<_>>()?;
        let (out, metrics) = run_verified_erased(&base, scheme, coord, &a, &b, &opts)?;
        stats.corrupt += metrics.corrupt_responses_detected;
        stats.quarantines += metrics.quarantines;
        stats.trials += metrics.verify_trials;
        stats.loo += metrics.leave_one_out_decodes;
        if !req.expected.is_empty() {
            ok &= out == req.expected;
        }
    }
    Ok((t0.elapsed().as_secs_f64(), ok, stats))
}

/// Build one pass's pool for the configured transport: the in-process
/// coordinator, or a TCP coordinator against freshly spawned loopback
/// daemons (joined after the pass), or a TCP coordinator against external
/// endpoints. The scheme instance passed in is the *master's* (its plan
/// cache is the one reported); loopback daemons share it as their compute
/// backend, exactly like the in-process pool does.
fn make_pool(
    cfg: &ServeConfig,
    scheme: &Arc<dyn DynScheme>,
) -> anyhow::Result<(Coordinator, Vec<WorkerDaemon>)> {
    let backend: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(Arc::clone(scheme)));
    // The scheme's own N (which elastic selection may have downgraded below
    // `cfg.n_workers`) is the pool size a pass actually needs.
    let n_workers = scheme.n_workers();
    let (mut coord, daemons) = match &cfg.transport {
        ServeTransport::TcpLoopback => {
            let daemons: Vec<WorkerDaemon> = (0..n_workers)
                .map(|_| {
                    WorkerDaemon::spawn_local_cfg(
                        Arc::clone(&backend),
                        DaemonConfig {
                            straggler: cfg.straggler.clone(),
                            corrupt: cfg.corrupt.clone(),
                            seed: cfg.seed,
                            ..DaemonConfig::default()
                        },
                        1,
                    )
                })
                .collect::<anyhow::Result<_>>()?;
            let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
            (Coordinator::connect_tcp(&addrs)?, daemons)
        }
        ServeTransport::ShmLoopback => {
            // A fresh ring directory per pool; the transport removes the
            // ring files at shutdown (the tiny directory itself is left to
            // the OS temp cleaner).
            let dir = crate::coordinator::shm::unique_ring_dir("serve")?;
            let daemons: Vec<WorkerDaemon> = (0..n_workers)
                .map(|_| {
                    WorkerDaemon::spawn_local_cfg(
                        Arc::clone(&backend),
                        DaemonConfig {
                            straggler: cfg.straggler.clone(),
                            corrupt: cfg.corrupt.clone(),
                            seed: cfg.seed,
                            shm_dir: Some(dir.clone()),
                        },
                        1,
                    )
                })
                .collect::<anyhow::Result<_>>()?;
            let addrs: Vec<String> = daemons.iter().map(WorkerDaemon::addr).collect();
            (Coordinator::connect_shm(&addrs, &dir)?, daemons)
        }
        // In-process and --connect are exactly the runner's two pool
        // flavors; the endpoint-count validation lives there. A corrupting
        // channel pool needs the faulty spawn path directly.
        ServeTransport::InProcess if !cfg.corrupt.is_none() => {
            let transport = ChannelTransport::spawn_faulty(
                n_workers,
                backend,
                cfg.straggler.clone(),
                cfg.corrupt.clone(),
                cfg.seed,
            );
            (Coordinator::with_transport(Box::new(transport)), Vec::new())
        }
        ServeTransport::InProcess => {
            let coord =
                make_coordinator(n_workers, backend, cfg.straggler.clone(), cfg.seed, None)?;
            (coord, Vec::new())
        }
        ServeTransport::Connect(addrs) => {
            anyhow::ensure!(
                cfg.corrupt.is_none(),
                "--corrupt needs a pool this process spawns; --connect daemons inject \
                 their own corruption (gr-cdmm worker --corrupt)"
            );
            let coord = make_coordinator(
                n_workers,
                backend,
                cfg.straggler.clone(),
                cfg.seed,
                Some(addrs.as_slice()),
            )?;
            (coord, Vec::new())
        }
    };
    if cfg.speculate {
        coord.set_elastic(ElasticConfig::speculative());
    }
    Ok((coord, daemons))
}

/// Run the full comparison (sequential pass, then pipelined pass on fresh
/// state) and return the measured record.
pub fn run(cfg: &ServeConfig) -> anyhow::Result<ServeRecord> {
    anyhow::ensure!(cfg.jobs >= 1 && cfg.inflight >= 1, "jobs and inflight must be >= 1");
    // Elastic scheme selection: a --connect pool smaller than the requested
    // preset downgrades to the largest preset its live daemons can serve.
    let reg_cfg = match (&cfg.transport, cfg.elastic) {
        (ServeTransport::Connect(addrs), true) if addrs.len() < cfg.n_workers => {
            SchemeConfig::for_live_workers(addrs.len())?
        }
        _ => SchemeConfig::for_workers(cfg.n_workers)?,
    };
    anyhow::ensure!(
        cfg.size % (reg_cfg.u.max(reg_cfg.v) * reg_cfg.n_split * reg_cfg.w.max(1)) == 0,
        "size {} must be divisible by the partition/split parameters",
        cfg.size
    );

    // Probe instance only for the batch size; each pass gets a cold scheme.
    let batch = registry::build(&cfg.scheme, &reg_cfg)?.batch_size();
    let requests = make_requests(cfg, batch);

    // Byzantine-tolerant serving: one verified pass replaces the throughput
    // comparison. Every decode is cross-checked before release, so a
    // corrupt pool serves bit-identical products (culprits quarantined) or
    // fails fast naming the suspects — never an unverified wrong product.
    if cfg.verify_products {
        anyhow::ensure!(
            !cfg.prepared,
            "--verify-products and --prepared are mutually exclusive \
             (the verified pass re-dispatches full shares)"
        );
        let scheme = registry::build(&cfg.scheme, &reg_cfg)?;
        let (mut coord, daemons) = make_pool(cfg, &scheme)?;
        let (vrfy_elapsed_s, ok, stats) =
            run_verified(scheme.as_ref(), &mut coord, &requests, cfg.seed)?;
        let counters = coord.counters().clone();
        coord.shutdown();
        for daemon in daemons {
            daemon.join()?;
        }
        // The rejected bucket closes the byte ledger: every arrived
        // response ends up classified used, discarded, or rejected.
        anyhow::ensure!(
            counters.download_arrived_total()
                == counters.download_used_total()
                    + counters.download_discarded_total()
                    + counters.download_rejected_total(),
            "download byte ledger must balance: arrived {} != used {} + discarded {} + rejected {}",
            counters.download_arrived_total(),
            counters.download_used_total(),
            counters.download_discarded_total(),
            counters.download_rejected_total(),
        );
        if !cfg.corrupt.is_none() {
            anyhow::ensure!(
                stats.corrupt >= 1 && stats.quarantines >= 1,
                "corruption was injected but the verified pass detected {} corrupt \
                 response(s) and issued {} quarantine(s)",
                stats.corrupt,
                stats.quarantines
            );
        }
        let (plan_cache_hits, plan_cache_misses) = scheme.plan_cache_stats();
        let vrfy_jobs_per_s = cfg.jobs as f64 / vrfy_elapsed_s.max(1e-12);
        return Ok(ServeRecord {
            scheme: cfg.scheme.clone(),
            transport: cfg.transport.label().to_string(),
            n_workers: cfg.n_workers,
            size: cfg.size,
            jobs: cfg.jobs,
            inflight: cfg.inflight,
            seq_elapsed_s: 0.0,
            seq_jobs_per_s: 0.0,
            pipe_elapsed_s: 0.0,
            pipe_jobs_per_s: 0.0,
            speedup: 0.0,
            plan_cache_hits,
            plan_cache_misses,
            speculative_dispatches: 0,
            prepared: false,
            prep_elapsed_s: 0.0,
            prep_jobs_per_s: 0.0,
            prep_speedup: 0.0,
            staged_upload_bytes: 0,
            prep_upload_bytes: 0,
            pipe_upload_bytes: 0,
            prepared_hits: 0,
            prepared_misses: 0,
            prepared_evictions: 0,
            steady_a_encodes: 0,
            verify_products: true,
            vrfy_elapsed_s,
            vrfy_jobs_per_s,
            corrupt_responses_detected: stats.corrupt,
            quarantines: stats.quarantines,
            verify_trials: stats.trials,
            leave_one_out_decodes: stats.loo,
            download_rejected_bytes: counters.download_rejected_total(),
            pool_hits: 0,
            pool_misses: 0,
            large_allocs: 0,
            copied_bytes: 0,
            verified: ok,
        });
    }

    let seq_scheme = registry::build(&cfg.scheme, &reg_cfg)?;
    let (mut seq_coord, seq_daemons) = make_pool(cfg, &seq_scheme)?;
    let (seq_elapsed_s, seq_ok) = run_sequential(seq_scheme.as_ref(), &mut seq_coord, &requests)?;
    seq_coord.shutdown();
    for daemon in seq_daemons {
        daemon.join()?;
    }

    let pipe_scheme = registry::build(&cfg.scheme, &reg_cfg)?;
    let (mut pipe_coord, pipe_daemons) = make_pool(cfg, &pipe_scheme)?;
    // Memory-discipline probes around the steady-state (pipelined) pass:
    // the sequential pass above doubles as pool warm-up, so the deltas
    // here are what a long-running server would see per batch of jobs.
    let pool_before = crate::util::bytepool::BytePool::global().stats();
    let large_before = crate::util::bytepool::large_allocs();
    let copied_before = crate::util::bytepool::copied_bytes();
    let (pipe_elapsed_s, pipe_ok) =
        run_pipelined(pipe_scheme.as_ref(), &mut pipe_coord, &requests, cfg.inflight)?;
    let pool_after = crate::util::bytepool::BytePool::global().stats();
    let pool_hits = pool_after.hits.saturating_sub(pool_before.hits);
    let pool_misses = pool_after.misses.saturating_sub(pool_before.misses);
    let large_allocs = crate::util::bytepool::large_allocs().saturating_sub(large_before);
    let copied_bytes = crate::util::bytepool::copied_bytes().saturating_sub(copied_before);
    let speculative_dispatches = pipe_coord.counters().speculative_total();
    let pipe_upload_bytes = pipe_coord.counters().upload_total();
    pipe_coord.shutdown();
    for daemon in pipe_daemons {
        daemon.join()?;
    }

    // Third pass (encode-once): stage the fixed A, stream B-only jobs, and
    // hold the proof obligations — one A-encode total, per-job upload equal
    // to the B-halves alone, staged volume equal to the A-halves.
    let mut prep_elapsed_s = 0.0;
    let mut prep_ok = true;
    let mut staged_upload_bytes = 0;
    let mut prep_upload_bytes = 0;
    let mut prepared_counts = (0, 0, 0);
    let mut steady_a_encodes = 0;
    if cfg.prepared {
        let prep_scheme = registry::build(&cfg.scheme, &reg_cfg)?;
        let (mut prep_coord, prep_daemons) = make_pool(cfg, &prep_scheme)?;
        let encodes_before = prep_scheme.left_encodes();
        let (elapsed, ok, staged_analytic, b_analytic) =
            run_prepared(prep_scheme.as_ref(), &mut prep_coord, &requests, cfg.inflight)?;
        let encode_delta = prep_scheme.left_encodes() - encodes_before;
        anyhow::ensure!(
            encode_delta == 1,
            "encode-once violated: {encode_delta} A-side encodes for {} jobs",
            cfg.jobs
        );
        steady_a_encodes = encode_delta - 1;
        staged_upload_bytes = prep_coord.counters().staged_upload_total();
        prep_upload_bytes = prep_coord.counters().upload_total();
        prepared_counts = prep_coord.prepared_stats();
        if !cfg.speculate {
            anyhow::ensure!(
                prep_upload_bytes == b_analytic,
                "prepared per-job upload must be the B-halves alone \
                 (counted {prep_upload_bytes}, analytic {b_analytic})"
            );
            anyhow::ensure!(
                staged_upload_bytes == staged_analytic,
                "staged volume must be the A-halves alone \
                 (counted {staged_upload_bytes}, analytic {staged_analytic})"
            );
        }
        anyhow::ensure!(
            prepared_counts.0 == cfg.jobs as u64 && prepared_counts.1 == 0,
            "every prepared job must hit the staged operand (stats {prepared_counts:?})"
        );
        prep_elapsed_s = elapsed;
        prep_ok = ok;
        prep_coord.shutdown();
        for daemon in prep_daemons {
            daemon.join()?;
        }
    }

    let (plan_cache_hits, plan_cache_misses) = pipe_scheme.plan_cache_stats();
    let seq_jobs_per_s = cfg.jobs as f64 / seq_elapsed_s.max(1e-12);
    let pipe_jobs_per_s = cfg.jobs as f64 / pipe_elapsed_s.max(1e-12);
    let prep_jobs_per_s =
        if cfg.prepared { cfg.jobs as f64 / prep_elapsed_s.max(1e-12) } else { 0.0 };
    Ok(ServeRecord {
        scheme: cfg.scheme.clone(),
        transport: cfg.transport.label().to_string(),
        n_workers: cfg.n_workers,
        size: cfg.size,
        jobs: cfg.jobs,
        inflight: cfg.inflight,
        seq_elapsed_s,
        seq_jobs_per_s,
        pipe_elapsed_s,
        pipe_jobs_per_s,
        speedup: pipe_jobs_per_s / seq_jobs_per_s.max(1e-12),
        plan_cache_hits,
        plan_cache_misses,
        speculative_dispatches,
        prepared: cfg.prepared,
        prep_elapsed_s,
        prep_jobs_per_s,
        prep_speedup: if cfg.prepared { prep_jobs_per_s / pipe_jobs_per_s.max(1e-12) } else { 0.0 },
        staged_upload_bytes,
        prep_upload_bytes,
        pipe_upload_bytes,
        prepared_hits: prepared_counts.0,
        prepared_misses: prepared_counts.1,
        prepared_evictions: prepared_counts.2,
        steady_a_encodes,
        verify_products: false,
        vrfy_elapsed_s: 0.0,
        vrfy_jobs_per_s: 0.0,
        corrupt_responses_detected: 0,
        quarantines: 0,
        verify_trials: 0,
        leave_one_out_decodes: 0,
        download_rejected_bytes: 0,
        pool_hits,
        pool_misses,
        large_allocs,
        copied_bytes,
        verified: seq_ok && pipe_ok && prep_ok,
    })
}

/// Markdown summary of one or more serving records.
pub fn render(records: &[ServeRecord]) -> String {
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.scheme.clone(),
                r.transport.clone(),
                r.size.to_string(),
                r.jobs.to_string(),
                r.inflight.to_string(),
                format!("{:.2}", r.seq_jobs_per_s),
                format!("{:.2}", r.pipe_jobs_per_s),
                format!("{:.2}x", r.speedup),
                if r.prepared { format!("{:.2}", r.prep_jobs_per_s) } else { "-".to_string() },
                if r.prepared && r.jobs > 0 {
                    // Per-job upload, full share vs B-half only — the byte
                    // saving the encode-once path is about.
                    format!(
                        "{}→{}",
                        r.pipe_upload_bytes / r.jobs as u64,
                        r.prep_upload_bytes / r.jobs as u64
                    )
                } else {
                    "-".to_string()
                },
                format!("{}/{}", r.plan_cache_hits, r.plan_cache_hits + r.plan_cache_misses),
                if r.verify_products {
                    format!("{:.2}", r.vrfy_jobs_per_s)
                } else {
                    "-".to_string()
                },
                if r.verify_products {
                    // Corrupt responses detected / quarantines issued by the
                    // Byzantine-tolerant pass.
                    format!("{}/{}", r.corrupt_responses_detected, r.quarantines)
                } else {
                    "-".to_string()
                },
                if r.verify_products {
                    "-".to_string()
                } else {
                    // Pool hit ratio over the steady-state pass: hits out of
                    // total leases. 100% hits + 0 large allocs is the
                    // zero-alloc proof surfaced to the operator.
                    format!("{}/{}", r.pool_hits, r.pool_hits + r.pool_misses)
                },
                if r.verify_products { "-".to_string() } else { r.large_allocs.to_string() },
                if r.verify_products || r.jobs == 0 {
                    "-".to_string()
                } else {
                    (r.copied_bytes / r.jobs as u64).to_string()
                },
                r.verified.to_string(),
            ]
        })
        .collect();
    markdown_table(
        &[
            "scheme",
            "transport",
            "size",
            "jobs",
            "inflight",
            "seq jobs/s",
            "pipelined jobs/s",
            "speedup",
            "prepared jobs/s",
            "upload/job",
            "plan-cache hits",
            "verified jobs/s",
            "corrupt/quar",
            "pool hits",
            "large allocs",
            "copied/job",
            "verified",
        ],
        &rows,
    )
}

impl ServeRecord {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scheme", self.scheme.as_str())
            .set("transport", self.transport.as_str())
            .set("n_workers", self.n_workers)
            .set("size", self.size)
            .set("jobs", self.jobs)
            .set("inflight", self.inflight)
            .set("seq_elapsed_s", self.seq_elapsed_s)
            .set("seq_jobs_per_s", self.seq_jobs_per_s)
            .set("pipe_elapsed_s", self.pipe_elapsed_s)
            .set("pipe_jobs_per_s", self.pipe_jobs_per_s)
            .set("speedup", self.speedup)
            .set("plan_cache_hits", self.plan_cache_hits)
            .set("plan_cache_misses", self.plan_cache_misses)
            .set("speculative_dispatches", self.speculative_dispatches)
            .set("prepared", self.prepared)
            .set("prep_elapsed_s", self.prep_elapsed_s)
            .set("prep_jobs_per_s", self.prep_jobs_per_s)
            .set("prep_speedup", self.prep_speedup)
            .set("staged_upload_bytes", self.staged_upload_bytes)
            .set("prep_upload_bytes", self.prep_upload_bytes)
            .set("pipe_upload_bytes", self.pipe_upload_bytes)
            .set("prepared_hits", self.prepared_hits)
            .set("prepared_misses", self.prepared_misses)
            .set("prepared_evictions", self.prepared_evictions)
            .set("steady_a_encodes", self.steady_a_encodes)
            .set("verify_products", self.verify_products)
            .set("vrfy_elapsed_s", self.vrfy_elapsed_s)
            .set("vrfy_jobs_per_s", self.vrfy_jobs_per_s)
            .set("corrupt_responses_detected", self.corrupt_responses_detected)
            .set("quarantines", self.quarantines)
            .set("verify_trials", self.verify_trials)
            .set("leave_one_out_decodes", self.leave_one_out_decodes)
            .set("download_rejected_bytes", self.download_rejected_bytes)
            .set("pool_hits", self.pool_hits)
            .set("pool_misses", self.pool_misses)
            .set("large_allocs", self.large_allocs)
            .set("copied_bytes", self.copied_bytes)
            .set("verified", self.verified)
    }
}

pub fn records_to_json(records: &[ServeRecord]) -> Json {
    Json::Arr(records.iter().map(|r| r.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn small_cfg(scheme: &str) -> ServeConfig {
        ServeConfig {
            scheme: scheme.to_string(),
            n_workers: 8,
            size: 16,
            jobs: 6,
            inflight: 3,
            straggler: StragglerModel::fixed_slow([0, 1], Duration::from_millis(10)),
            corrupt: CorruptionModel::None,
            seed: 77,
            verify: true,
            transport: ServeTransport::InProcess,
            speculate: false,
            elastic: false,
            prepared: false,
            verify_products: false,
        }
    }

    #[test]
    fn serving_run_verifies_all_jobs() {
        let rec = run(&small_cfg("ep-rmfe-1")).unwrap();
        assert!(rec.verified, "every pipelined job must decode correctly");
        assert_eq!(rec.jobs, 6);
        assert!(rec.seq_jobs_per_s > 0.0 && rec.pipe_jobs_per_s > 0.0);
        // 6 decodes over at most C(6,4)=15 subsets: hits are possible but
        // not guaranteed; the counters must at least add up.
        assert_eq!(rec.plan_cache_hits + rec.plan_cache_misses, 6);
    }

    #[test]
    fn serving_handles_batch_schemes() {
        let rec = run(&small_cfg("csa")).unwrap();
        assert!(rec.verified);
    }

    #[test]
    fn serving_over_tcp_loopback_verifies() {
        // Same shape as the channel run, but every pass drives freshly
        // spawned loopback daemons over real sockets; verification inside
        // `run` certifies decode correctness end-to-end over the wire.
        let mut cfg = small_cfg("ep-rmfe-1");
        cfg.transport = ServeTransport::TcpLoopback;
        let rec = run(&cfg).unwrap();
        assert!(rec.verified, "every TCP-served job must decode correctly");
        assert_eq!(rec.transport, "tcp-loopback");
        assert_eq!(rec.plan_cache_hits + rec.plan_cache_misses, 6);
    }

    #[test]
    fn serving_over_shm_loopback_verifies() {
        // Loopback daemons with the shared-memory data plane: control
        // frames ride TCP, payloads ride per-worker file-backed rings.
        // Decode verification inside `run` certifies the ring path is
        // bit-identical to the inline one.
        let mut cfg = small_cfg("ep-rmfe-1");
        cfg.transport = ServeTransport::ShmLoopback;
        let rec = run(&cfg).unwrap();
        assert!(rec.verified, "every shm-served job must decode correctly");
        assert_eq!(rec.transport, "shm");
        assert_eq!(rec.plan_cache_hits + rec.plan_cache_misses, 6);
    }

    #[test]
    fn prepared_serving_ships_b_only_and_verifies() {
        let mut cfg = small_cfg("ep-rmfe-1");
        cfg.prepared = true;
        let rec = run(&cfg).unwrap();
        // `run` itself asserts the encode-once obligations (one A-encode,
        // B-only upload, all hits); here we check the surfaced record.
        assert!(rec.verified, "all three passes must decode correctly");
        assert!(rec.prepared);
        assert_eq!((rec.prepared_hits, rec.prepared_misses, rec.prepared_evictions), (6, 0, 0));
        assert_eq!(rec.steady_a_encodes, 0, "zero A-side encodes in steady state");
        assert!(rec.staged_upload_bytes > 0, "the A-halves were staged once");
        assert!(
            rec.prep_upload_bytes < rec.pipe_upload_bytes,
            "B-only jobs ({}) must upload less than full-share jobs ({})",
            rec.prep_upload_bytes,
            rec.pipe_upload_bytes
        );
        assert!(rec.prep_jobs_per_s > 0.0);
    }

    #[test]
    fn prepared_serving_over_tcp_matches_channel_accounting() {
        // Same prepared stream over both transports: the wire must not
        // change the staged or per-job byte volumes (both are payload
        // bytes), and TCP-served prepared decodes must verify too.
        let mut cfg = small_cfg("ep-rmfe-1");
        cfg.prepared = true;
        let chan = run(&cfg).unwrap();
        cfg.transport = ServeTransport::TcpLoopback;
        let tcp = run(&cfg).unwrap();
        assert!(tcp.verified, "prepared jobs over TCP must decode correctly");
        assert_eq!(
            (tcp.staged_upload_bytes, tcp.prep_upload_bytes),
            (chan.staged_upload_bytes, chan.prep_upload_bytes),
            "byte accounting must be transport-independent"
        );
    }

    #[test]
    fn prepared_serving_rejects_schemes_without_split_encode() {
        let mut cfg = small_cfg("csa");
        cfg.prepared = true;
        let err = run(&cfg).unwrap_err();
        assert!(err.to_string().contains("left operand"), "{err}");
    }

    #[test]
    fn verified_serving_accepts_a_clean_pool() {
        // No corruption: the surplus cross-check certifies every decode
        // without ever falling back to Freivalds or leave-one-out, and the
        // byte ledger balances with an empty rejected bucket (asserted
        // inside `run`).
        let mut cfg = small_cfg("ep");
        cfg.verify_products = true;
        let rec = run(&cfg).unwrap();
        assert!(rec.verified, "every verified job must match the local reference");
        assert!(rec.verify_products);
        assert!(rec.vrfy_jobs_per_s > 0.0);
        assert_eq!(rec.corrupt_responses_detected, 0);
        assert_eq!(rec.quarantines, 0);
        assert_eq!(rec.leave_one_out_decodes, 0);
        assert_eq!(rec.download_rejected_bytes, 0);
    }

    #[test]
    fn verified_serving_detects_and_quarantines_a_corrupt_worker() {
        // One silently-wrong worker: plain decode would return a wrong
        // product without any error. The verified pass must still serve the
        // bit-identical reference product for every job, reject the corrupt
        // shares into the dedicated byte bucket, and quarantine the culprit
        // (`run` additionally asserts detection >= 1 whenever corruption
        // was injected).
        let mut cfg = small_cfg("ep");
        cfg.straggler = StragglerModel::None;
        cfg.corrupt = CorruptionModel::silent_wrong_share([2]);
        cfg.verify_products = true;
        let rec = run(&cfg).unwrap();
        assert!(rec.verified, "products must be bit-identical to the clean reference");
        assert!(rec.corrupt_responses_detected >= 1, "{rec:?}");
        assert!(rec.quarantines >= 1, "{rec:?}");
        assert!(rec.download_rejected_bytes > 0, "rejected bytes must be bucketed");
    }

    #[test]
    fn verified_serving_over_tcp_loopback_quarantines() {
        // Same Byzantine stream over real sockets: the loopback daemons
        // inject the corruption (DaemonConfig::corrupt), detection happens
        // at the master, end to end over the wire.
        let mut cfg = small_cfg("ep");
        cfg.jobs = 3;
        cfg.straggler = StragglerModel::None;
        cfg.corrupt = CorruptionModel::silent_wrong_share([2]);
        cfg.verify_products = true;
        cfg.transport = ServeTransport::TcpLoopback;
        let rec = run(&cfg).unwrap();
        assert!(rec.verified, "products must be bit-identical to the clean reference");
        assert!(rec.corrupt_responses_detected >= 1, "{rec:?}");
        assert!(rec.quarantines >= 1, "{rec:?}");
    }

    #[test]
    fn connect_mode_rejects_local_corruption() {
        // --connect daemons own their corruption injection; a local model
        // would silently not apply, so it is rejected up front.
        let mut cfg = small_cfg("ep-rmfe-1");
        cfg.transport = ServeTransport::Connect(vec!["127.0.0.1:1".to_string(); 8]);
        cfg.corrupt = CorruptionModel::silent_wrong_share([0]);
        let err = run(&cfg).unwrap_err();
        assert!(err.to_string().contains("--connect"), "{err}");
    }

    #[test]
    fn connect_mode_validates_endpoint_count() {
        let mut cfg = small_cfg("ep-rmfe-1");
        cfg.transport = ServeTransport::Connect(vec!["127.0.0.1:1".to_string()]);
        let err = run(&cfg).unwrap_err();
        assert!(err.to_string().contains("endpoint"), "{err}");
    }

    #[test]
    fn render_and_json_contain_throughput() {
        let rec = run(&small_cfg("ep")).unwrap();
        let md = render(std::slice::from_ref(&rec));
        assert!(md.contains("pipelined jobs/s"));
        assert!(md.contains("verified jobs/s"));
        assert!(md.contains("corrupt/quar"));
        let js = records_to_json(&[rec]).render();
        assert!(js.contains("pipe_jobs_per_s"));
        assert!(js.contains("plan_cache_hits"));
        assert!(js.contains("vrfy_jobs_per_s"));
        assert!(js.contains("corrupt_responses_detected"));
        assert!(js.contains("download_rejected_bytes"));
    }
}
