//! `gr-cdmm` — the leader binary: run coded distributed matrix
//! multiplications, regenerate the paper's experiments, inspect the runtime.
//!
//! Scheme selection goes through the erased registry
//! ([`gr_cdmm::codes::registry`]): one code path serves every scheme, and
//! the worker pool runs the single native backend
//! ([`gr_cdmm::coordinator::NativeCompute`]) on byte payloads.
//!
//! ```text
//! gr-cdmm info
//! gr-cdmm run  --scheme ep|ep-rmfe-1|ep-rmfe-2 --workers 8 --size 256
//!              [--straggler none|slow|exp|fail] [--backend native|xla] [--seed k]
//!              [--connect HOST:PORT,HOST:PORT,...]
//! gr-cdmm serve --scheme ep-rmfe-1 --workers 8 --size 128 --jobs 16 --inflight 4
//!              [--straggler none|slow|exp|fail] [--no-verify] [--seed k] [--out results]
//!              [--transport channel|tcp-loopback|shm] [--connect HOST:PORT,...]
//!              [--speculate] [--elastic] [--prepared]
//!              [--corrupt MODEL[:ids]] [--verify-products]
//! gr-cdmm worker --listen HOST:PORT --scheme ep-rmfe-1 --workers 8
//!              [--straggler none|slow|exp|fail] [--corrupt MODEL[:ids]]
//!              [--seed k] [--once | --conns K]
//! gr-cdmm experiments --exp fig2|fig3|fig4|fig5|table1|rmfe35|all
//!              [--sizes 128,256,...] [--full] [--reps k] [--out results]
//! ```
//!
//! `worker` turns this binary into a remote worker daemon: it serves the
//! same receive → compute → reply loop the in-process pool runs, over a
//! TCP socket speaking the versioned `coordinator::wire` protocol. Start
//! one daemon per worker (ports of your choice), then point `serve` or
//! `run` at them with `--connect` — master and daemons must agree on
//! `--scheme` and `--workers`.

use gr_cdmm::codes::registry::{self, SchemeConfig};
use gr_cdmm::coordinator::daemon::{self, DaemonConfig};
use gr_cdmm::coordinator::runner::{make_coordinator, run_erased, NativeCompute};
use gr_cdmm::coordinator::{CorruptionModel, JobMetrics, ShareCompute, StragglerModel};
use gr_cdmm::experiments::serving::ServeTransport;
use gr_cdmm::experiments::{figs, rmfe35, serving, table1, DEFAULT_SIZES, PAPER_SIZES};
use gr_cdmm::ring::extension::Extension;
use gr_cdmm::ring::matrix::Matrix;
use gr_cdmm::ring::traits::Ring;
use gr_cdmm::ring::zq::Zq;
use gr_cdmm::runtime::gr_backend::XlaShareCompute;
use gr_cdmm::runtime::XlaRuntime;
use gr_cdmm::util::cli::Args;
use gr_cdmm::util::json::Json;
use gr_cdmm::util::rng::Rng64;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "info" => cmd_info(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "experiments" => cmd_experiments(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "gr-cdmm — coded distributed (batch) matrix multiplication over Galois rings via RMFE

USAGE:
  gr-cdmm info
  gr-cdmm run  --scheme ep|ep-rmfe-1|ep-rmfe-2 --workers 4|8|16|32 --size 256
               [--straggler none|slow|exp|fail] [--backend native|xla] [--seed K]
               [--connect HOST:PORT,HOST:PORT,...]
  gr-cdmm serve --scheme NAME --workers 4|8|16|32 --size 128 --jobs 16 --inflight 4
               [--straggler none|slow|exp|fail] [--no-verify] [--seed K] [--out DIR]
               [--transport channel|tcp-loopback|shm] [--connect HOST:PORT,...]
               [--speculate] [--elastic] [--prepared]
               [--corrupt MODEL[:ids]] [--verify-products]
  gr-cdmm worker --listen HOST:PORT --scheme NAME --workers 4|8|16|32
               [--straggler none|slow|exp|fail] [--corrupt MODEL[:ids]]
               [--seed K] [--once | --conns K]
  gr-cdmm experiments --exp fig2|fig3|fig4|fig5|table1|rmfe35|all
               [--sizes 128,256] [--full] [--reps K] [--out DIR]

Multi-process quickstart: start one `worker` daemon per worker (ports of
your choice), then `serve --connect addr1,addr2,...` — the scheme name and
worker count must match on both sides. `--speculate` turns on health-check
pings and speculative re-dispatch of overdue shards; `--elastic` lets a
short `--connect` list downgrade to the largest scheme preset its live
daemons can serve instead of erroring. `--prepared` fixes one A across the
stream and adds an encode-once pass: A's share halves are staged on the
workers once and every job ships only its B-halves (the run asserts zero
steady-state A-encodes and B-only per-job upload). `--transport shm`
spawns loopback daemons whose control frames ride TCP while payloads move
out-of-line through per-worker file-backed shared-memory rings (same-host
only; oversize payloads fall back to inline frames automatically).

Byzantine faults: `--corrupt MODEL[:ids]` injects corrupt responses at the
listed workers (models: bit-flip | garbage-payload | stale-replay |
silent-wrong-share; omitting the id list targets every worker) — on `serve` for the local
transports, on `worker` for external daemons. `serve --verify-products`
decodes every job through the verified path: surplus responses are
cross-checked against the decoded product, exact-threshold decodes are
Freivalds-checked, corrupt shares are isolated by leave-one-out re-decode
and their workers quarantined — wrong products are never emitted
unverified."
    );
}

fn cmd_info(_args: &Args) -> anyhow::Result<()> {
    println!("rings:");
    for m in [3usize, 4, 5] {
        let ext = Extension::new(Zq::z2e(64), m);
        println!(
            "  {}  modulus={:?}  exceptional points={}",
            ext.name(),
            ext.modulus(),
            ext.residue_size()
        );
    }
    println!("schemes (registry, Z_2^64 inputs):");
    for (name, about) in registry::SCHEME_NAMES {
        println!("  {name:<14} {about}");
    }
    match XlaRuntime::open_default() {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!("artifacts:");
            for s in rt.specs() {
                println!("  {}  m={} shapes={}x{}x{}", s.name, s.m, s.t, s.r, s.s);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn parse_straggler(args: &Args, n_workers: usize) -> StragglerModel {
    match args.get_or("straggler", "none") {
        "slow" => StragglerModel::fixed_slow([0, 1], Duration::from_millis(200)),
        "exp" => StragglerModel::Exponential { mean: Duration::from_millis(50) },
        "fail" => StragglerModel::fail_stop([n_workers - 1]),
        _ => StragglerModel::None,
    }
}

/// `--corrupt MODEL[:id,id,...]` → corruption model (None when absent).
fn parse_corrupt(args: &Args) -> anyhow::Result<CorruptionModel> {
    match args.get("corrupt") {
        Some(spec) => CorruptionModel::parse(spec),
        None => Ok(CorruptionModel::None),
    }
}

/// `--connect a,b,c` → endpoint list (None when the flag is absent).
fn parse_connect(args: &Args) -> Option<Vec<String>> {
    args.get("connect").map(|list| {
        list.split(',')
            .map(str::trim)
            .filter(|addr| !addr.is_empty())
            .map(str::to_string)
            .collect()
    })
}

fn report(name: &str, m: &JobMetrics, ok: bool) {
    println!("scheme:            {name}");
    println!("verified:          {ok}");
    println!("encode:            {:?}", m.encode);
    println!("decode:            {:?}", m.decode);
    println!("wait for R:        {:?}", m.wait_for_r);
    println!("upload:            {:.3} MB", m.upload_bytes as f64 / 1e6);
    println!("download:          {:.3} MB", m.download_bytes as f64 / 1e6);
    println!("mean worker time:  {:?}", m.mean_worker_compute());
    println!("used workers:      {:?}", m.used_workers);
    println!("total:             {:?}", m.total);
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let n_workers = args.get_usize("workers", 8);
    let size = args.get_usize("size", 256);
    let seed = args.get_u64("seed", 42);
    let scheme_name = args.get_or("scheme", "ep-rmfe-1").to_string();
    let backend_kind = args.get_or("backend", "native");
    let cfg = SchemeConfig::for_workers(n_workers)?;
    let straggler = parse_straggler(args, n_workers);

    let base = Zq::z2e(64);
    let mut rng = Rng64::seeded(seed);
    let a = Matrix::random(&base, size, size, &mut rng);
    let b = Matrix::random(&base, size, size, &mut rng);
    let expected = Matrix::matmul(&base, &a, &b);

    let scheme = registry::build(&scheme_name, &cfg)?;
    anyhow::ensure!(
        scheme.batch_size() == 1,
        "`run` multiplies one pair; {scheme_name} is a batch scheme — see `experiments --exp table1`"
    );
    let backend: Arc<dyn ShareCompute> = if backend_kind == "xla" {
        anyhow::ensure!(
            scheme_name == "ep",
            "--backend xla supports only the plain `ep` scheme (the AOT artifacts bake its share shapes)"
        );
        let ext = Extension::new(base.clone(), cfg.m);
        let (t, r, s) = (size / cfg.u, size / cfg.w, size / cfg.v);
        Arc::new(XlaShareCompute::for_shapes(
            std::env::var("GR_CDMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
            ext,
            t,
            r,
            s,
        )?)
    } else {
        Arc::new(NativeCompute::new(Arc::clone(&scheme)))
    };
    let connect = parse_connect(args);
    let mut coord = make_coordinator(n_workers, backend, straggler, seed, connect.as_deref())?;
    let (c, m) = run_erased(
        &base,
        scheme.as_ref(),
        &mut coord,
        std::slice::from_ref(&a),
        std::slice::from_ref(&b),
    )?;
    report(&scheme.name(), &m, c.len() == 1 && c[0] == expected);
    coord.shutdown();
    Ok(())
}

/// Serving throughput mode: drive `--jobs` requests through the pipelined
/// coordinator with `--inflight` jobs overlapping, against the sequential
/// submit+wait baseline on identical state.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let transport = match (parse_connect(args), args.get("transport")) {
        (Some(_), Some(_)) => anyhow::bail!(
            "--connect and --transport are mutually exclusive (--connect already \
             selects the external-daemon TCP transport)"
        ),
        (Some(addrs), None) => ServeTransport::Connect(addrs),
        (None, Some("tcp-loopback")) => ServeTransport::TcpLoopback,
        (None, Some("shm")) => ServeTransport::ShmLoopback,
        (None, Some("channel")) | (None, None) => ServeTransport::InProcess,
        (None, Some(other)) => {
            anyhow::bail!("unknown --transport `{other}` (channel | tcp-loopback | shm | --connect)")
        }
    };
    let cfg = serving::ServeConfig {
        scheme: args.get_or("scheme", "ep-rmfe-1").to_string(),
        n_workers: args.get_usize("workers", 8),
        size: args.get_usize("size", 128),
        jobs: args.get_usize("jobs", 16),
        inflight: args.get_usize("inflight", 4),
        straggler: parse_straggler(args, args.get_usize("workers", 8)),
        corrupt: parse_corrupt(args)?,
        seed: args.get_u64("seed", 42),
        verify: !args.flag("no-verify"),
        verify_products: args.flag("verify-products"),
        transport,
        speculate: args.flag("speculate"),
        elastic: args.flag("elastic"),
        prepared: args.flag("prepared"),
    };
    let rec = serving::run(&cfg)?;
    println!(
        "# serving throughput — {} jobs, {} in flight, {} transport\n",
        rec.jobs, rec.inflight, rec.transport
    );
    println!("{}", serving::render(std::slice::from_ref(&rec)));
    if rec.verify_products {
        println!(
            "verified (Byzantine-tolerant) {:.2} jobs/s; {} corrupt response(s) \
             detected, {} quarantine(s), {} Freivalds trial(s), {} leave-one-out \
             re-decode(s), {} B rejected; verified: {}",
            rec.vrfy_jobs_per_s,
            rec.corrupt_responses_detected,
            rec.quarantines,
            rec.verify_trials,
            rec.leave_one_out_decodes,
            rec.download_rejected_bytes,
            rec.verified
        );
    } else {
        println!(
            "pipelined {:.2} jobs/s vs sequential {:.2} jobs/s ({:.2}x); \
             decode-plan cache {} hits / {} misses; verified: {}",
            rec.pipe_jobs_per_s,
            rec.seq_jobs_per_s,
            rec.speedup,
            rec.plan_cache_hits,
            rec.plan_cache_misses,
            rec.verified
        );
    }
    if rec.prepared {
        println!(
            "prepared (encode-once) {:.2} jobs/s ({:.2}x over pipelined); \
             per-job upload {} B → {} B (B-halves only), A-halves staged once ({} B); \
             store {} hits / {} misses / {} evictions; steady-state A-encodes: {}",
            rec.prep_jobs_per_s,
            rec.prep_speedup,
            rec.pipe_upload_bytes / rec.jobs.max(1) as u64,
            rec.prep_upload_bytes / rec.jobs.max(1) as u64,
            rec.staged_upload_bytes,
            rec.prepared_hits,
            rec.prepared_misses,
            rec.prepared_evictions,
            rec.steady_a_encodes
        );
    }
    if let Some(dir) = args.get("out") {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/serving_throughput.json");
        std::fs::write(&path, rec.to_json().render())?;
        println!("(written to {path})");
    }
    anyhow::ensure!(rec.verified, "decoded outputs diverged from the local reference");
    Ok(())
}

/// Worker daemon mode: serve the worker loop over a TCP socket. The scheme
/// (and the worker count it is parameterized for) must match what the
/// coordinator will use — exactly like a deployed executor fleet agreeing
/// on a binary + config.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("--listen HOST:PORT is required"))?;
    let n_workers = args.get_usize("workers", 8);
    let scheme_name = args.get_or("scheme", "ep-rmfe-1");
    let cfg = SchemeConfig::for_workers(n_workers)?;
    let scheme = registry::build(scheme_name, &cfg)?;
    let compute: Arc<dyn ShareCompute> = Arc::new(NativeCompute::new(scheme));
    let straggler = parse_straggler(args, n_workers);
    let corrupt = parse_corrupt(args)?;
    let seed = args.get_u64("seed", 42);
    let max_conns = if args.flag("once") {
        Some(1)
    } else if let Some(conns) = args.get("conns") {
        let parsed = conns
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--conns expects a connection count, got `{conns}`"))?;
        anyhow::ensure!(parsed >= 1, "--conns must be >= 1");
        Some(parsed)
    } else {
        None
    };
    daemon::run(
        listen,
        compute,
        DaemonConfig { straggler, corrupt, seed, ..DaemonConfig::default() },
        max_conns,
    )
}

fn write_out(
    out_dir: Option<&str>,
    name: &str,
    md: &str,
    json: Option<Json>,
) -> anyhow::Result<()> {
    println!("\n## {name}\n\n{md}");
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/{name}.md"), md)?;
        if let Some(j) = json {
            std::fs::write(format!("{dir}/{name}.json"), j.render())?;
        }
        println!("(written to {dir}/{name}.md)");
    }
    Ok(())
}

fn cmd_experiments(args: &Args) -> anyhow::Result<()> {
    let exp = args.get_or("exp", "all").to_string();
    let full = args.flag("full");
    let sizes = if full {
        args.get_usize_list("sizes", PAPER_SIZES)
    } else {
        args.get_usize_list("sizes", DEFAULT_SIZES)
    };
    let reps = args.get_usize("reps", 1);
    let seed = args.get_u64("seed", 42);
    let out_dir = args.get("out");

    let want = |name: &str| exp == name || exp == "all";

    if want("fig2") || want("fig4") {
        let cfg = SchemeConfig::for_workers(8)?;
        let recs = figs::sweep(&cfg, &sizes, reps, seed)?;
        if want("fig2") {
            write_out(
                out_dir,
                "fig2_master_8workers",
                &figs::render_master_view(&recs),
                Some(figs::records_to_json(&recs)),
            )?;
        }
        if want("fig4") {
            write_out(out_dir, "fig4_worker_8workers", &figs::render_worker_view(&recs), None)?;
        }
    }
    if want("fig3") || want("fig5") {
        let cfg = SchemeConfig::for_workers(16)?;
        let sizes16: Vec<usize> = sizes.iter().map(|&s| s.next_multiple_of(8)).collect();
        let recs = figs::sweep(&cfg, &sizes16, reps, seed ^ 1)?;
        if want("fig3") {
            write_out(
                out_dir,
                "fig3_master_16workers",
                &figs::render_master_view(&recs),
                Some(figs::records_to_json(&recs)),
            )?;
        }
        if want("fig5") {
            write_out(out_dir, "fig5_worker_16workers", &figs::render_worker_view(&recs), None)?;
        }
    }
    if want("table1") {
        let rows = table1::analytic_rows(16, 4, 2, 2, 2, 1000, 1000, 1000);
        write_out(out_dir, "table1_analytic", &table1::render_analytic(&rows), None)?;
        let pts = table1::measured_point(2, *sizes.first().unwrap_or(&128), seed)?;
        write_out(out_dir, "table1_measured", &table1::render_measured(&pts), None)?;
    }
    if want("rmfe35") {
        let sizes35: Vec<usize> = sizes.iter().map(|&s| s.next_multiple_of(12)).collect();
        let recs = rmfe35::run(&sizes35, seed)?;
        write_out(out_dir, "rmfe35_32workers", &rmfe35::render(&recs), None)?;
    }
    Ok(())
}
