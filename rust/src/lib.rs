//! # gr-cdmm — Coded Distributed (Batch) Matrix Multiplication over Galois Rings via RMFE
//!
//! A production-grade implementation of
//! *"Coded Distributed (Batch) Matrix Multiplication over Galois Ring via RMFE"*
//! (Kuang, Li, Li, Xing — 2024).
//!
//! The crate is organised bottom-up:
//!
//! * [`ring`] — the algebraic substrate: `Z_{p^e}`, Galois rings `GR(p^e, d)`,
//!   tower extensions `GR(p^e, d·m)`, exceptional sets, fast multipoint
//!   evaluation / interpolation, and dense matrices over any ring — the AoS
//!   [`ring::matrix::Matrix`] for user-facing inputs and the plane-major
//!   [`ring::plane::PlaneMatrix`] that every share, wire payload and worker
//!   product uses.
//! * [`rmfe`] — Reverse Multiplication-Friendly Embeddings: the interpolation
//!   construction `(n, m)`-RMFE with `m ≥ 2n−1` (Definition II.2), the
//!   point-at-infinity extension (`n ≤ p^d + 1`) and concatenation (Lemma II.5).
//! * [`codes`] — the coding schemes: Entangled Polynomial (EP) codes,
//!   Polynomial codes, MatDot codes, CSA batch codes (the runnable GCSA
//!   baseline point), and the paper's contributions: `Batch-EP_RMFE`
//!   (Theorem III.2), `EP_RMFE-I` (Corollary IV.1) and `EP_RMFE-II`
//!   (Corollary IV.2). One trait ([`codes::DmmScheme`], single product =
//!   `batch_size() == 1`) covers all of them; [`codes::DynScheme`] is the
//!   object-safe byte-payload facade and [`codes::registry`] builds schemes
//!   by name.
//! * [`coordinator`] — the L3 distributed runtime: master node, pipelined
//!   multi-job serving, straggler injection, metrics — over a pluggable,
//!   byte-accounted `Transport`: the in-process worker pool on OS threads
//!   (mpsc channels), or remote `gr-cdmm worker` daemons speaking a
//!   length-prefixed versioned wire protocol over TCP.
//! * [`runtime`] — the PJRT bridge: loads AOT-compiled `artifacts/*.hlo.txt`
//!   (lowered once from JAX/Pallas by `python/compile/aot.py`) and executes
//!   worker-node coefficient-plane matmuls through XLA. Python is never on the
//!   request path. Gated behind the non-default `pjrt` cargo feature; the
//!   default build ships an offline stub (see the [`runtime`] module docs).
//! * [`experiments`] — the harness that regenerates every table and figure of
//!   the paper's evaluation section (Table 1, Figures 2–5).
//!
//! ## Quickstart
//!
//! One coded multiplication, encode → worker products → decode, using the
//! paper's Fig. 2 configuration (8 workers over `GR(2^64, 3)`, `u = v = 2`,
//! `w = 1`, split `n = 2`, recovery threshold `R = 4`). This example runs as
//! a doctest on every `cargo test`:
//!
//! ```
//! use gr_cdmm::ring::zq::Zq;
//! use gr_cdmm::ring::matrix::Matrix;
//! use gr_cdmm::codes::scheme::DmmScheme;
//! use gr_cdmm::codes::ep_rmfe_i::EpRmfeI;
//! use gr_cdmm::util::rng::Rng64;
//!
//! let ring = Zq::z2e(64);                      // Z_{2^64}
//! let mut rng = Rng64::seeded(7);
//! let a = Matrix::random(&ring, 64, 64, &mut rng);
//! let b = Matrix::random(&ring, 64, 64, &mut rng);
//! // 8 workers over GR(2^64, 3), u=2, w=1, v=2, n=2 — the paper's Fig. 2 config.
//! let scheme = EpRmfeI::new(ring.clone(), 8, 2, 1, 2, 2).unwrap();
//! assert_eq!(scheme.recovery_threshold(), 4);
//! let shares = scheme.encode(&a, &b).unwrap();
//! let responses: Vec<_> = shares.iter().enumerate()
//!     .map(|(i, s)| (i, scheme.worker_compute(s).unwrap()))
//!     .collect();
//! // Any R = 4 of the 8 responses decode the product.
//! let c = scheme.decode(&responses[..scheme.recovery_threshold()]).unwrap();
//! assert_eq!(c, Matrix::matmul(&ring, &a, &b));
//! ```
//!
//! For the threaded end-to-end path (worker pool, straggler injection, byte
//! accounting) see `examples/quickstart.rs`.

// Ring element types are `Vec`-backed aliases (`GfqElem`, `GrElem`,
// `ExtElem<R>`): `&GfqElem` parameters are the canonical `&Elem` API of the
// `Ring` trait, not slices-in-disguise, so `clippy::ptr_arg` does not apply.
#![allow(clippy::ptr_arg)]

pub mod util;
pub mod ring;
pub mod rmfe;
pub mod codes;
pub mod coordinator;
pub mod runtime;
pub mod experiments;
