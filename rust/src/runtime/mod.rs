//! The PJRT runtime bridge: load AOT-compiled HLO artifacts (lowered once
//! from JAX/Pallas by `python/compile/aot.py`) and execute them from the
//! rust request path via the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text** — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; `from_text_file`
//! reassigns ids (see `/opt/xla-example/README.md`).
//!
//! * [`XlaRuntime`] — one PJRT client per process; compiles artifacts once.
//! * [`HloArtifact`] — a loaded executable with its manifest entry.
//! * [`gr_backend`] — a [`ShareCompute`](crate::coordinator::worker::ShareCompute)
//!   backend that runs worker share products through the artifact instead of
//!   the native ring kernels.

pub mod gr_backend;

use std::path::{Path, PathBuf};

/// Manifest entry describing one artifact (parsed from
/// `artifacts/manifest.json`, written by `aot.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Extension degree (1 = plain u64 matmul).
    pub m: usize,
    pub t: usize,
    pub r: usize,
    pub s: usize,
    /// Little-endian modulus coefficients (length m+1).
    pub modulus: Vec<u64>,
}

/// Minimal JSON value extraction for the manifest (flat, known schema; we
/// ship no JSON parser dependency). Robust to whitespace/ordering produced
/// by `json.dump(indent=2)`.
fn parse_manifest(text: &str) -> anyhow::Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    // Split on the artifact object boundaries: each entry contains "name".
    for chunk in text.split('{').skip(2) {
        // skip root + artifacts array opener
        if !chunk.contains("\"name\"") {
            continue;
        }
        let get_str = |key: &str| -> Option<String> {
            let pat = format!("\"{key}\":");
            let at = chunk.find(&pat)? + pat.len();
            let rest = chunk[at..].trim_start();
            let rest = rest.strip_prefix('"')?;
            Some(rest[..rest.find('"')?].to_string())
        };
        let get_num = |key: &str| -> Option<u64> {
            let pat = format!("\"{key}\":");
            let at = chunk.find(&pat)? + pat.len();
            let rest = chunk[at..].trim_start();
            let end = rest.find(|c: char| !c.is_ascii_digit())?;
            rest[..end].parse().ok()
        };
        let get_arr = |key: &str| -> Option<Vec<u64>> {
            let pat = format!("\"{key}\":");
            let at = chunk.find(&pat)? + pat.len();
            let rest = chunk[at..].trim_start().strip_prefix('[')?;
            let inner = &rest[..rest.find(']')?];
            Some(
                inner
                    .split(',')
                    .filter_map(|x| x.trim().parse().ok())
                    .collect(),
            )
        };
        specs.push(ArtifactSpec {
            name: get_str("name").ok_or_else(|| anyhow::anyhow!("manifest: missing name"))?,
            file: get_str("file").ok_or_else(|| anyhow::anyhow!("manifest: missing file"))?,
            m: get_num("m").ok_or_else(|| anyhow::anyhow!("manifest: missing m"))? as usize,
            t: get_num("t").unwrap_or(0) as usize,
            r: get_num("r").unwrap_or(0) as usize,
            s: get_num("s").unwrap_or(0) as usize,
            modulus: get_arr("modulus").unwrap_or_default(),
        });
    }
    anyhow::ensure!(!specs.is_empty(), "manifest contains no artifacts");
    Ok(specs)
}

/// A loaded, compiled HLO artifact.
pub struct HloArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl HloArtifact {
    /// Execute with u64 input buffers (row-major, shapes from the spec).
    /// The lowered fn returns a 1-tuple (aot.py lowers with
    /// `return_tuple=True`).
    pub fn run_u64(&self, inputs: &[(Vec<u64>, Vec<i64>)]) -> anyhow::Result<Vec<u64>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data.as_slice());
                lit.reshape(dims).map_err(|e| anyhow::anyhow!("reshape: {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let out = out.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        out.to_vec::<u64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }
}

/// The process-wide PJRT client + artifact loader.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
}

impl XlaRuntime {
    /// Open the CPU PJRT client over an artifact directory (reads
    /// `manifest.json`). `GR_CDMM_ARTIFACTS` overrides the default
    /// `artifacts/`.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!(
                "read manifest in {}: {e} (run `make artifacts`)",
                dir.display()
            )
        })?;
        let specs = parse_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(XlaRuntime { client, dir, specs })
    }

    /// Default artifact directory: `$GR_CDMM_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> anyhow::Result<Self> {
        let dir = std::env::var("GR_CDMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find the manifest entry for a GR worker task with the given extension
    /// degree and share shapes.
    pub fn find_spec(&self, m: usize, t: usize, r: usize, s: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|a| a.m == m && a.t == t && a.r == r && a.s == s)
    }

    /// Load + compile one artifact by manifest name.
    pub fn load(&self, name: &str) -> anyhow::Result<HloArtifact> {
        let spec = self
            .specs
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(HloArtifact { spec, exe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parser_handles_aot_output() {
        let text = r#"{
  "artifacts": [
    {
      "name": "matmul_u64_16x16x16",
      "file": "matmul_u64_16x16x16.hlo.txt",
      "m": 1,
      "t": 16,
      "r": 16,
      "s": 16,
      "modulus": [0, 1],
      "dtype": "uint64"
    },
    {
      "name": "worker_gr_m3_16x32x16",
      "file": "worker_gr_m3_16x32x16.hlo.txt",
      "m": 3,
      "t": 16,
      "r": 32,
      "s": 16,
      "modulus": [1, 1, 0, 1],
      "dtype": "uint64"
    }
  ]
}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "matmul_u64_16x16x16");
        assert_eq!(specs[0].m, 1);
        assert_eq!(specs[1].modulus, vec![1, 1, 0, 1]);
        assert_eq!(specs[1].r, 32);
    }

    #[test]
    fn manifest_parser_rejects_empty() {
        assert!(parse_manifest("{\"artifacts\": []}").is_err());
    }
}
