//! The PJRT runtime bridge: load AOT-compiled HLO artifacts (lowered once
//! from JAX/Pallas by `python/compile/aot.py`) and execute them from the
//! rust request path via the `xla` crate's PJRT CPU client.
//!
//! # The `pjrt` feature
//!
//! Everything that touches XLA is gated behind the **non-default `pjrt`
//! cargo feature**:
//!
//! * **default build (no `pjrt`)** — std-only and offline-safe. The types
//!   in this module keep their full API ([`XlaRuntime`], [`HloArtifact`],
//!   [`gr_backend::XlaShareCompute`]), but [`XlaRuntime::open`] returns an
//!   error explaining that PJRT support was not compiled in. Everything that
//!   consumes the runtime (the `gr-cdmm info` command, the
//!   `matmul_kernels` bench, the `integration_runtime` tests) already
//!   treats an unavailable runtime as "skip", so the default build is fully
//!   usable with the native ring kernels.
//! * **`--features pjrt`** — compiles the real bridge. This additionally
//!   requires an `xla` dependency (built against a vendored `xla_extension`
//!   checkout, e.g. `/opt/xla-example`) to be added to `rust/Cargo.toml`;
//!   see the commented block there. The dependency is not declared by
//!   default because the checkout does not exist in offline environments.
//!
//! The manifest parsing ([`ArtifactSpec`], the `artifacts/manifest.json`
//! loader) is **not** gated: it is pure std and is unit-tested in every
//! build.
//!
//! # The `artifacts/manifest.json` contract
//!
//! `python/compile/aot.py` (run via `make artifacts`) lowers each worker
//! task once and writes, next to the `*.hlo.txt` files, a manifest:
//!
//! ```json
//! {
//!   "artifacts": [
//!     {
//!       "name": "worker_gr_m3_128x256x128",
//!       "file": "worker_gr_m3_128x256x128.hlo.txt",
//!       "m": 3,
//!       "t": 128, "r": 256, "s": 128,
//!       "modulus": [1, 1, 0, 1],
//!       "dtype": "uint64"
//!     }
//!   ]
//! }
//! ```
//!
//! * `m` — extension degree of the share ring `GR(2^64, m)`; `m = 1` marks
//!   a plain `u64` matmul artifact.
//! * `t`, `r`, `s` — the share shapes: worker inputs are `(m, t, r)` and
//!   `(m, r, s)` plane-major u64 tensors (or `(t, r)`/`(r, s)` for `m = 1`),
//!   the output is `(m, t, s)`.
//! * `modulus` — little-endian coefficients (length `m + 1`) of the tower's
//!   defining polynomial, baked into the lowered kernel. The rust side
//!   validates at load time that this equals the deterministic modulus
//!   chosen by [`crate::ring::irreducible::find_irreducible`] — the
//!   cross-language contract asserted in `tests/integration_runtime.rs` and
//!   `python/tests/test_gr.py`.
//!
//! The default artifact directory is `./artifacts`, overridable with the
//! `GR_CDMM_ARTIFACTS` environment variable.
//!
//! # Why HLO *text* interchange
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto` bytes:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which
//! `xla_extension` 0.5.1 rejects (`proto.id() <= INT_MAX`); parsing the
//! text form reassigns ids and round-trips cleanly (see
//! `/opt/xla-example/README.md`). Python runs once at build time
//! (`make artifacts`) and is never on the request path.
//!
//! * [`XlaRuntime`] — one PJRT client per process; compiles artifacts once.
//! * [`HloArtifact`] — a loaded executable with its manifest entry.
//! * [`gr_backend`] — a [`ShareCompute`](crate::coordinator::worker::ShareCompute)
//!   backend that runs worker share products through the artifact instead of
//!   the native ring kernels.

pub mod gr_backend;

use std::path::{Path, PathBuf};

/// Manifest entry describing one artifact (parsed from
/// `artifacts/manifest.json`, written by `aot.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Extension degree (1 = plain u64 matmul).
    pub m: usize,
    pub t: usize,
    pub r: usize,
    pub s: usize,
    /// Little-endian modulus coefficients (length m+1).
    pub modulus: Vec<u64>,
}

/// Minimal JSON value extraction for the manifest (flat, known schema; we
/// ship no JSON parser dependency). Robust to whitespace/ordering produced
/// by `json.dump(indent=2)`.
// Without `pjrt` only the unit tests call this (the stub runtime fails
// before reaching the manifest), hence the cfg'd allow.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn parse_manifest(text: &str) -> anyhow::Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    // Split on the artifact object boundaries: each entry contains "name".
    for chunk in text.split('{').skip(2) {
        // skip root + artifacts array opener
        if !chunk.contains("\"name\"") {
            continue;
        }
        let get_str = |key: &str| -> Option<String> {
            let pat = format!("\"{key}\":");
            let at = chunk.find(&pat)? + pat.len();
            let rest = chunk[at..].trim_start();
            let rest = rest.strip_prefix('"')?;
            Some(rest[..rest.find('"')?].to_string())
        };
        let get_num = |key: &str| -> Option<u64> {
            let pat = format!("\"{key}\":");
            let at = chunk.find(&pat)? + pat.len();
            let rest = chunk[at..].trim_start();
            let end = rest.find(|c: char| !c.is_ascii_digit())?;
            rest[..end].parse().ok()
        };
        let get_arr = |key: &str| -> Option<Vec<u64>> {
            let pat = format!("\"{key}\":");
            let at = chunk.find(&pat)? + pat.len();
            let rest = chunk[at..].trim_start().strip_prefix('[')?;
            let inner = &rest[..rest.find(']')?];
            Some(
                inner
                    .split(',')
                    .filter_map(|x| x.trim().parse().ok())
                    .collect(),
            )
        };
        specs.push(ArtifactSpec {
            name: get_str("name").ok_or_else(|| anyhow::anyhow!("manifest: missing name"))?,
            file: get_str("file").ok_or_else(|| anyhow::anyhow!("manifest: missing file"))?,
            m: get_num("m").ok_or_else(|| anyhow::anyhow!("manifest: missing m"))? as usize,
            t: get_num("t").unwrap_or(0) as usize,
            r: get_num("r").unwrap_or(0) as usize,
            s: get_num("s").unwrap_or(0) as usize,
            modulus: get_arr("modulus").unwrap_or_default(),
        });
    }
    anyhow::ensure!(!specs.is_empty(), "manifest contains no artifacts");
    Ok(specs)
}

/// A loaded, compiled HLO artifact.
#[cfg(feature = "pjrt")]
pub struct HloArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl HloArtifact {
    /// Execute with u64 input buffers (row-major, shapes from the spec).
    /// The lowered fn returns a 1-tuple (aot.py lowers with
    /// `return_tuple=True`).
    pub fn run_u64(&self, inputs: &[(Vec<u64>, Vec<i64>)]) -> anyhow::Result<Vec<u64>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data.as_slice());
                lit.reshape(dims).map_err(|e| anyhow::anyhow!("reshape: {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
        let out = out.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        out.to_vec::<u64>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }
}

/// The process-wide PJRT client + artifact loader.
///
/// Without the `pjrt` feature this is an offline stub: [`XlaRuntime::open`]
/// always errors (so no instance can ever exist) and every consumer — the
/// CLI `info` command, the benches, the integration tests,
/// [`gr_backend::XlaShareCompute`] — takes its graceful "runtime
/// unavailable" path.
pub struct XlaRuntime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
}

// Feature-independent surface over the shared fields.
impl XlaRuntime {
    /// Default artifact directory: `$GR_CDMM_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> anyhow::Result<Self> {
        let dir = std::env::var("GR_CDMM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Find the manifest entry for a GR worker task with the given extension
    /// degree and share shapes.
    pub fn find_spec(&self, m: usize, t: usize, r: usize, s: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|a| a.m == m && a.t == t && a.r == r && a.s == s)
    }
}

#[cfg(feature = "pjrt")]
impl XlaRuntime {
    /// Open the CPU PJRT client over an artifact directory (reads
    /// `manifest.json`). `GR_CDMM_ARTIFACTS` overrides the default
    /// `artifacts/`.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!(
                "read manifest in {}: {e} (run `make artifacts`)",
                dir.display()
            )
        })?;
        let specs = parse_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(XlaRuntime { client, dir, specs })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by manifest name.
    pub fn load(&self, name: &str) -> anyhow::Result<HloArtifact> {
        let spec = self
            .specs
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(HloArtifact { spec, exe })
    }
}

/// A loaded, compiled HLO artifact — **offline stub** (built without the
/// `pjrt` feature). Carries the manifest entry only; [`HloArtifact::run_u64`]
/// always errors.
#[cfg(not(feature = "pjrt"))]
pub struct HloArtifact {
    pub spec: ArtifactSpec,
}

#[cfg(not(feature = "pjrt"))]
impl HloArtifact {
    /// Stub: always errors — rebuild with `--features pjrt` (and an `xla`
    /// dependency) for real execution.
    pub fn run_u64(&self, _inputs: &[(Vec<u64>, Vec<i64>)]) -> anyhow::Result<Vec<u64>> {
        anyhow::bail!(
            "artifact {}: gr_cdmm was built without the `pjrt` feature; \
             XLA execution is unavailable (use the native backend, or rebuild \
             with --features pjrt and an `xla` dependency)",
            self.spec.name
        )
    }
}

// Offline stub surface: `open` always errors, so no instance can exist and
// `platform`/`load` are only here so callers typecheck identically.
#[cfg(not(feature = "pjrt"))]
impl XlaRuntime {
    /// Stub: always errors — PJRT support was not compiled in.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        anyhow::bail!(
            "cannot open artifact directory {}: gr_cdmm was built without the \
             `pjrt` feature (std-only offline build); rebuild with \
             --features pjrt and an `xla` dependency in rust/Cargo.toml to \
             enable the PJRT bridge",
            dir.as_ref().display()
        )
    }

    pub fn platform(&self) -> String {
        "stub (built without the pjrt feature)".to_string()
    }

    /// Stub: always errors (unreachable in practice — [`XlaRuntime::open`]
    /// already fails, so no stub runtime can be constructed).
    pub fn load(&self, name: &str) -> anyhow::Result<HloArtifact> {
        anyhow::bail!(
            "cannot load artifact {name} from {}: gr_cdmm was built without \
             the `pjrt` feature",
            self.dir.display()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parser_handles_aot_output() {
        let text = r#"{
  "artifacts": [
    {
      "name": "matmul_u64_16x16x16",
      "file": "matmul_u64_16x16x16.hlo.txt",
      "m": 1,
      "t": 16,
      "r": 16,
      "s": 16,
      "modulus": [0, 1],
      "dtype": "uint64"
    },
    {
      "name": "worker_gr_m3_16x32x16",
      "file": "worker_gr_m3_16x32x16.hlo.txt",
      "m": 3,
      "t": 16,
      "r": 32,
      "s": 16,
      "modulus": [1, 1, 0, 1],
      "dtype": "uint64"
    }
  ]
}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "matmul_u64_16x16x16");
        assert_eq!(specs[0].m, 1);
        assert_eq!(specs[1].modulus, vec![1, 1, 0, 1]);
        assert_eq!(specs[1].r, 32);
    }

    #[test]
    fn manifest_parser_rejects_empty() {
        assert!(parse_manifest("{\"artifacts\": []}").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = XlaRuntime::open("artifacts").err().expect("stub open must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = XlaRuntime::open_default().err().expect("stub open must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
