//! Worker compute backend that executes share products through the AOT XLA
//! artifact instead of the native ring kernels.
//!
//! Requires the non-default `pjrt` cargo feature for real execution; in the
//! default offline build [`XlaShareCompute::for_shapes`] fails cleanly with
//! a "built without the `pjrt` feature" error (see [`crate::runtime`] docs),
//! and the plane-layout conversion helpers below remain fully functional and
//! tested.
//!
//! The `xla` crate's PJRT client is `Rc`-based (not `Send`), so the
//! executable cannot be shared across worker threads. Each worker thread
//! lazily opens its *own* client + compiled artifact through a thread-local
//! cache — which also happens to model the deployment reality (every worker
//! node is a separate process with its own PJRT runtime).
//!
//! Share wire format ↔ artifact format: the share payload is **already**
//! plane-major — [`crate::ring::plane::PlaneMatrix`] over `Zq` serializes as
//! contiguous `u64` planes, exactly the `(m, rows, cols)` inputs of
//! `python/compile/kernels/gr_matmul.py` — so the backend just strips the
//! 16-byte header and hands the flat buffer to PJRT (no layout conversion
//! on the wire path; [`ext_matrix_to_planes`] remains for AoS callers).
//! The artifact's baked modulus must equal the rust tower's modulus —
//! validated at construction.

use super::{HloArtifact, XlaRuntime};
use crate::codes::scheme::Share;
use crate::coordinator::worker::ShareCompute;
use crate::ring::extension::Extension;
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;
use crate::ring::zq::Zq;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

type ExtElem = Vec<u64>;

thread_local! {
    /// (artifact dir, artifact name) → compiled executable, per thread.
    static ARTIFACT_CACHE: RefCell<HashMap<(String, String), Rc<HloArtifact>>> =
        RefCell::new(HashMap::new());
}

/// Convert a `GR(2^64, m)` matrix into plane-major u64 data
/// (`planes[k][i][j] = M[i,j][k]`).
pub fn ext_matrix_to_planes(m: usize, mat: &Matrix<ExtElem>) -> Vec<u64> {
    let (rows, cols) = (mat.rows, mat.cols);
    let mut out = vec![0u64; m * rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            let e = mat.at(i, j);
            for k in 0..m {
                out[k * rows * cols + i * cols + j] = e[k];
            }
        }
    }
    out
}

/// Inverse of [`ext_matrix_to_planes`].
pub fn planes_to_ext_matrix(m: usize, rows: usize, cols: usize, data: &[u64]) -> Matrix<ExtElem> {
    assert_eq!(data.len(), m * rows * cols);
    Matrix::from_fn(rows, cols, |i, j| {
        (0..m).map(|k| data[k * rows * cols + i * cols + j]).collect::<Vec<u64>>()
    })
}

/// XLA-backed [`ShareCompute`] for shares over `Extension<Zq>` (i.e.
/// `GR(2^64, m)`).
pub struct XlaShareCompute {
    dir: PathBuf,
    artifact_name: String,
    ext: Extension<Zq>,
    m: usize,
    /// Expected share shapes (from the artifact spec): A is t×r, B is r×s.
    t: usize,
    r: usize,
    s: usize,
}

impl XlaShareCompute {
    /// Bind to the artifact matching `(m, t, r, s)` in `dir`'s manifest and
    /// validate that its baked modulus equals `ext`'s defining polynomial.
    pub fn for_shapes(
        dir: impl Into<PathBuf>,
        ext: Extension<Zq>,
        t: usize,
        r: usize,
        s: usize,
    ) -> anyhow::Result<Self> {
        let dir: PathBuf = dir.into();
        let m = ext.m();
        let runtime = XlaRuntime::open(&dir)?;
        let spec = runtime.find_spec(m, t, r, s).ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact for m={m}, shapes {t}x{r}x{s} in {} — regenerate with \
                 `python -m compile.aot` for this configuration",
                dir.display()
            )
        })?;
        anyhow::ensure!(
            spec.modulus.len() == m + 1 && spec.modulus[..] == ext_modulus_u64(&ext)[..],
            "artifact modulus {:?} != rust tower modulus {:?} — cross-language \
             contract violated",
            spec.modulus,
            ext_modulus_u64(&ext)
        );
        Ok(XlaShareCompute {
            artifact_name: spec.name.clone(),
            dir,
            ext,
            m,
            t,
            r,
            s,
        })
    }

    fn with_artifact<T>(
        &self,
        f: impl FnOnce(&HloArtifact) -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        ARTIFACT_CACHE.with(|cache| {
            let key = (
                self.dir.display().to_string(),
                self.artifact_name.clone(),
            );
            let mut cache = cache.borrow_mut();
            if !cache.contains_key(&key) {
                let runtime = XlaRuntime::open(&self.dir)?;
                let artifact = runtime.load(&self.artifact_name)?;
                cache.insert(key.clone(), Rc::new(artifact));
            }
            f(cache.get(&key).unwrap())
        })
    }
}

/// The tower modulus of `Extension<Zq>` as plain u64 coefficients.
fn ext_modulus_u64(ext: &Extension<Zq>) -> Vec<u64> {
    ext.modulus().to_vec()
}

impl ShareCompute for XlaShareCompute {
    fn compute(
        &self,
        _worker_id: usize,
        payload: &[u8],
    ) -> anyhow::Result<crate::util::bytepool::PooledBuf> {
        let share: Share<Extension<Zq>> = Share::from_bytes(&self.ext, payload)?;
        anyhow::ensure!(
            share.a.rows == self.t && share.a.cols == self.r && share.b.cols == self.s,
            "share shapes ({}, {})·({}, {}) do not match artifact {}x{}x{}",
            share.a.rows,
            share.a.cols,
            share.b.rows,
            share.b.cols,
            self.t,
            self.r,
            self.s
        );
        let m = self.m;
        // The plane-major share data is byte-identical to the artifact's
        // expected (m, rows, cols) u64 layout — no conversion needed.
        let out = self.with_artifact(|artifact| {
            artifact.run_u64(&[
                (share.a.data.clone(), vec![m as i64, self.t as i64, self.r as i64]),
                (share.b.data.clone(), vec![m as i64, self.r as i64, self.s as i64]),
            ])
        })?;
        anyhow::ensure!(
            out.len() == m * self.t * self.s,
            "artifact returned {} u64s, expected {}",
            out.len(),
            m * self.t * self.s
        );
        let c = PlaneMatrix::<Zq> { rows: self.t, cols: self.s, planes: m, data: out };
        let mut lease = crate::util::bytepool::BytePool::global().lease(c.byte_len(&self.ext));
        c.write_bytes_into(&self.ext, &mut lease);
        Ok(lease.freeze())
    }

    fn backend_name(&self) -> String {
        format!("xla:{}", self.artifact_name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng64;

    #[test]
    fn plane_conversion_roundtrip() {
        let ext = Extension::new(Zq::z2e(64), 3);
        let mut rng = Rng64::seeded(191);
        let mat = Matrix::random(&ext, 4, 5, &mut rng);
        let planes = ext_matrix_to_planes(3, &mat);
        assert_eq!(planes.len(), 3 * 4 * 5);
        let back = planes_to_ext_matrix(3, 4, 5, &planes);
        assert_eq!(back, mat);
    }

    #[test]
    fn plane_layout_is_plane_major() {
        let ext = Extension::new(Zq::z2e(64), 2);
        let mut mat = Matrix::zeros(&ext, 1, 2);
        mat.set(0, 0, vec![10, 11]);
        mat.set(0, 1, vec![20, 21]);
        // plane 0 = [10, 20], plane 1 = [11, 21]
        assert_eq!(ext_matrix_to_planes(2, &mat), vec![10, 20, 11, 21]);
    }
}
