//! Size-bucketed, ref-counted **payload buffer pool** — the allocator
//! behind the zero-copy hot path.
//!
//! Steady-state serving must perform *zero per-job large allocations*: every
//! byte buffer that exists per job (encoded shares, wire-frame payloads read
//! off a socket, worker responses, decoded outputs) is leased from a
//! process-wide pool and returned on last drop, so after a short warmup the
//! allocator is out of the loop entirely. The proof follows the pattern of
//! [`crate::ring::plane::scalar_table_builds`] (PR 4's zero-rebuild probe):
//! a process-wide [`large_allocs`] counter is bumped on every pool **miss**
//! whose backing allocation is ≥ [`LARGE_ALLOC_THRESHOLD`], and the
//! steady-state integration probe asserts its delta is zero across a warmed
//! serving stream (`tests/integration_alloc.rs`).
//!
//! **Size classes.** Buffers live in power-of-two buckets from
//! [`MIN_BUCKET`] (4 KiB) to [`MAX_BUCKET`] (1 GiB, matching the wire-level
//! `MAX_PAYLOAD` guard). A lease for `len` bytes draws from the bucket of
//! `len.next_power_of_two()`; the buffer's *capacity* is the bucket size, so
//! any later lease of a similar length reuses it regardless of exact shape —
//! this is what makes mixed-shape streams hit after one warm pass per
//! bucket.
//!
//! **Lifecycle.** [`BytePool::lease`] hands out a [`BufLease`]: an owned,
//! writable `Vec<u8>` view the serializers fill (`PlaneMatrix::
//! write_bytes_into` and friends append into it). [`BufLease::freeze`] seals
//! it into a [`PooledBuf`]: a cheaply clonable, `Arc`-backed immutable byte
//! buffer. Cloning a `PooledBuf` never copies — N speculative sends of one
//! payload cost one buffer — and when the last clone drops, the storage
//! returns to its bucket (bounded by the retention cap; surplus buffers are
//! simply freed).
//!
//! **Knobs.** `GR_CDMM_POOL_CAP` sets the per-bucket retention cap
//! (default [`DEFAULT_POOL_CAP`]). `GR_CDMM_POOL_CAP=0` is the escape
//! hatch: pooling is disabled, every lease is a fresh allocation (and is
//! counted — the bench's pooled-vs-unpooled columns price exactly this).
//! [`BytePool::set_cap`] adjusts the same knob at runtime for in-process
//! A/B comparisons.
//!
//! **Copy probe.** Alongside the allocation probe, [`copied_bytes`] counts
//! deliberate in-memory payload duplications (today: only the prepared-path
//! A+B reassembly, which must produce a contiguous share for the kernel).
//! The steady non-prepared hot path performs none; the integration probe
//! asserts that too.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Pool misses allocating at least this many bytes count toward
/// [`large_allocs`]. 64 KiB: well above control-plane noise (frames,
/// strings), well below any real share payload.
pub const LARGE_ALLOC_THRESHOLD: usize = 64 * 1024;

/// Smallest size class. Leases below this draw from the 4 KiB bucket.
pub const MIN_BUCKET: usize = 4096;

/// Largest size class — one bucket per power of two up to 1 GiB, matching
/// the wire protocol's `MAX_PAYLOAD` guard; a frame that passes header
/// validation always fits a bucket, and anything larger was already
/// rejected by the oversize error path.
pub const MAX_BUCKET: usize = 1 << 30;

/// Default per-bucket retention cap (buffers kept idle per size class).
pub const DEFAULT_POOL_CAP: usize = 32;

/// Number of power-of-two size classes: 2^12 (4 KiB) ..= 2^30 (1 GiB).
const N_BUCKETS: usize = 19;

static LARGE_ALLOCS: AtomicU64 = AtomicU64::new(0);
static COPIED_BYTES: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of hot-path allocations ≥ [`LARGE_ALLOC_THRESHOLD`]
/// (pool misses and unpooled fallbacks at instrumented sites). Steady-state
/// serving must not move this — the zero-alloc analogue of
/// [`crate::ring::plane::scalar_table_builds`].
///
/// Scope note (kept honest): the probe instruments the **byte-buffer** hot
/// path — payload leases, frame reads, response and decode buffers — not
/// every allocation in the process. The complementary strong assertion at
/// small sizes is the pool hit-rate itself: 100% hits means *no* payload
/// buffer of any size was freshly allocated, large or not.
pub fn large_allocs() -> u64 {
    LARGE_ALLOCS.load(Ordering::Relaxed)
}

fn note_alloc(len: usize) {
    if len >= LARGE_ALLOC_THRESHOLD {
        LARGE_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
}

/// Process-wide count of bytes deliberately duplicated in memory on the
/// payload path (see module docs). Zero per job on the steady non-prepared
/// path; prepared jobs pay exactly one A+B reassembly per compute.
pub fn copied_bytes() -> u64 {
    COPIED_BYTES.load(Ordering::Relaxed)
}

/// Record an in-memory payload duplication of `len` bytes.
pub fn note_copy(len: usize) {
    COPIED_BYTES.fetch_add(len as u64, Ordering::Relaxed);
}

/// Bucket index for a lease of `len` bytes (`len` ≤ [`MAX_BUCKET`]).
fn bucket_index(len: usize) -> usize {
    let class = len.max(MIN_BUCKET).next_power_of_two();
    (class.trailing_zeros() - MIN_BUCKET.trailing_zeros()) as usize
}

/// The backing capacity a lease of `len` bytes receives.
pub fn bucket_size(len: usize) -> usize {
    len.max(MIN_BUCKET).next_power_of_two()
}

/// Point-in-time pool counters (monotone except `outstanding`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Leases served from a bucket (no allocation).
    pub hits: u64,
    /// Leases that had to allocate (bucket empty, pooling disabled, or
    /// oversize).
    pub misses: u64,
    /// Pooled buffers currently leased out (live `BufLease`s +
    /// `PooledBuf`s).
    pub outstanding: u64,
}

struct PoolInner {
    buckets: [Mutex<Vec<Vec<u8>>>; N_BUCKETS],
    cap: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
    outstanding: AtomicU64,
}

/// Handle to a buffer pool; cloning shares the pool. See module docs.
#[derive(Clone)]
pub struct BytePool {
    inner: Arc<PoolInner>,
}

impl BytePool {
    /// New pool with the given per-bucket retention cap (`0` disables
    /// pooling: every lease allocates, nothing is retained).
    pub fn new(cap: usize) -> BytePool {
        BytePool {
            inner: Arc::new(PoolInner {
                buckets: std::array::from_fn(|_| Mutex::new(Vec::new())),
                cap: AtomicUsize::new(cap),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                outstanding: AtomicU64::new(0),
            }),
        }
    }

    /// The process-wide pool every hot-path site leases from. Capacity from
    /// `GR_CDMM_POOL_CAP` at first use (default [`DEFAULT_POOL_CAP`]);
    /// adjustable later via [`BytePool::set_cap`].
    pub fn global() -> &'static BytePool {
        static GLOBAL: OnceLock<BytePool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let cap = std::env::var("GR_CDMM_POOL_CAP")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(DEFAULT_POOL_CAP);
            BytePool::new(cap)
        })
    }

    /// Current per-bucket retention cap (`0` = pooling disabled).
    pub fn cap(&self) -> usize {
        self.inner.cap.load(Ordering::Relaxed)
    }

    /// Adjust the retention cap at runtime. Setting `0` disables pooling
    /// for subsequent leases (already-pooled idle buffers are kept until
    /// their bucket is next touched; outstanding buffers still return).
    pub fn set_cap(&self, cap: usize) {
        self.inner.cap.store(cap, Ordering::Relaxed);
    }

    /// Lease a writable buffer with capacity ≥ `len` (cleared, length 0).
    ///
    /// `len` ≤ [`MAX_BUCKET`] draws from the matching size class; larger
    /// requests — which the wire layer already rejects — fall back to an
    /// unpooled allocation and count as a miss.
    pub fn lease(&self, len: usize) -> BufLease {
        if self.cap() == 0 || len > MAX_BUCKET {
            self.inner.misses.fetch_add(1, Ordering::Relaxed);
            note_alloc(len);
            return BufLease { vec: Some(Vec::with_capacity(len)), pool: None };
        }
        let idx = bucket_index(len);
        let recycled = self.inner.buckets[idx].lock().unwrap().pop();
        let vec = match recycled {
            Some(mut v) => {
                v.clear();
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                let size = bucket_size(len);
                self.inner.misses.fetch_add(1, Ordering::Relaxed);
                note_alloc(size);
                Vec::with_capacity(size)
            }
        };
        self.inner.outstanding.fetch_add(1, Ordering::Relaxed);
        BufLease { vec: Some(vec), pool: Some(self.clone()) }
    }

    /// Counters snapshot.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            outstanding: self.inner.outstanding.load(Ordering::Relaxed),
        }
    }

    /// Return a leased buffer's storage to its bucket (or free it if the
    /// bucket is at cap / pooling is disabled).
    fn give_back(&self, vec: Vec<u8>) {
        self.inner.outstanding.fetch_sub(1, Ordering::Relaxed);
        let cap = self.cap();
        if cap == 0 || vec.capacity() < MIN_BUCKET {
            return; // dropped
        }
        // Floor the capacity to the largest class it fully covers, so a hit
        // drawn from bucket i always has capacity ≥ that bucket's size (the
        // allocator may round capacities up, never down).
        let capped = vec.capacity().min(MAX_BUCKET);
        let class = 1usize << (usize::BITS - 1 - capped.leading_zeros());
        let mut bucket = self.inner.buckets[bucket_index(class)].lock().unwrap();
        if bucket.len() < cap {
            bucket.push(vec);
        }
    }
}

impl fmt::Debug for BytePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.stats();
        f.debug_struct("BytePool")
            .field("cap", &self.cap())
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .field("outstanding", &s.outstanding)
            .finish()
    }
}

/// An exclusively held, writable pool lease. Deref's to `Vec<u8>` so the
/// serializers can append in place; [`BufLease::freeze`] seals it into a
/// shareable [`PooledBuf`]. Dropping an unfrozen lease returns the storage.
pub struct BufLease {
    vec: Option<Vec<u8>>,
    pool: Option<BytePool>,
}

impl BufLease {
    /// Seal the lease into an immutable, cheaply clonable buffer.
    pub fn freeze(mut self) -> PooledBuf {
        let vec = self.vec.take().expect("lease not yet frozen");
        let pool = self.pool.take();
        PooledBuf { inner: Arc::new(PooledInner { vec, pool }) }
    }
}

impl Deref for BufLease {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        self.vec.as_ref().expect("lease not yet frozen")
    }
}

impl DerefMut for BufLease {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        self.vec.as_mut().expect("lease not yet frozen")
    }
}

impl Drop for BufLease {
    fn drop(&mut self) {
        if let (Some(vec), Some(pool)) = (self.vec.take(), self.pool.take()) {
            pool.give_back(vec);
        }
    }
}

struct PooledInner {
    vec: Vec<u8>,
    pool: Option<BytePool>,
}

impl Drop for PooledInner {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.give_back(std::mem::take(&mut self.vec));
        }
    }
}

/// Immutable, `Arc`-backed byte buffer whose storage returns to its pool on
/// last drop. Cloning shares the bytes (never copies) — the unit of payload
/// ownership everywhere downstream of encode: `Frame`s, `ToWorker` sends,
/// staged operands, collected responses, decode outputs.
#[derive(Clone)]
pub struct PooledBuf {
    inner: Arc<PooledInner>,
}

impl PooledBuf {
    /// Wrap an existing `Vec` without pooling (its storage is freed on last
    /// drop, not recycled). The bridge for cold-path and test callers.
    pub fn from_vec(vec: Vec<u8>) -> PooledBuf {
        PooledBuf { inner: Arc::new(PooledInner { vec, pool: None }) }
    }

    pub fn len(&self) -> usize {
        self.inner.vec.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.vec.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.inner.vec
    }

    /// Copy out to an owned `Vec` (a deliberate copy; cold paths only).
    pub fn to_vec(&self) -> Vec<u8> {
        self.inner.vec.clone()
    }
}

impl Deref for PooledBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.inner.vec
    }
}

impl AsRef<[u8]> for PooledBuf {
    fn as_ref(&self) -> &[u8] {
        &self.inner.vec
    }
}

impl From<Vec<u8>> for PooledBuf {
    fn from(vec: Vec<u8>) -> PooledBuf {
        PooledBuf::from_vec(vec)
    }
}

impl From<&[u8]> for PooledBuf {
    fn from(bytes: &[u8]) -> PooledBuf {
        PooledBuf::from_vec(bytes.to_vec())
    }
}

impl Default for PooledBuf {
    fn default() -> PooledBuf {
        PooledBuf::from_vec(Vec::new())
    }
}

impl fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.inner.vec, f)
    }
}

impl PartialEq for PooledBuf {
    fn eq(&self, other: &PooledBuf) -> bool {
        self.inner.vec == other.inner.vec
    }
}

impl Eq for PooledBuf {}

impl PartialEq<Vec<u8>> for PooledBuf {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.inner.vec == *other
    }
}

impl PartialEq<PooledBuf> for Vec<u8> {
    fn eq(&self, other: &PooledBuf) -> bool {
        *self == other.inner.vec
    }
}

impl PartialEq<[u8]> for PooledBuf {
    fn eq(&self, other: &[u8]) -> bool {
        self.inner.vec == other
    }
}

impl PartialEq<&[u8]> for PooledBuf {
    fn eq(&self, other: &&[u8]) -> bool {
        self.inner.vec == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_size_classes() {
        assert_eq!(bucket_size(0), MIN_BUCKET);
        assert_eq!(bucket_size(1), MIN_BUCKET);
        assert_eq!(bucket_size(4096), 4096);
        assert_eq!(bucket_size(4097), 8192);
        assert_eq!(bucket_size(1 << 20), 1 << 20);
        assert_eq!(bucket_index(MIN_BUCKET), 0);
        assert_eq!(bucket_index(MAX_BUCKET), N_BUCKETS - 1);
    }

    #[test]
    fn lease_freeze_drop_recycles_storage() {
        let pool = BytePool::new(8);
        let mut lease = pool.lease(100);
        lease.extend_from_slice(&[1, 2, 3]);
        let buf = lease.freeze();
        assert_eq!(&buf[..], &[1, 2, 3]);
        assert_eq!(pool.stats(), PoolStats { hits: 0, misses: 1, outstanding: 1 });
        let clone = buf.clone();
        drop(buf);
        assert_eq!(
            pool.stats().outstanding,
            1,
            "storage held while any clone lives"
        );
        drop(clone);
        assert_eq!(pool.stats().outstanding, 0);
        // Second lease of a similar size reuses the same storage: a hit.
        let lease2 = pool.lease(200);
        assert_eq!(pool.stats(), PoolStats { hits: 1, misses: 1, outstanding: 1 });
        assert!(lease2.is_empty(), "recycled buffer comes back cleared");
        assert!(lease2.capacity() >= 200);
    }

    #[test]
    fn dropping_an_unfrozen_lease_returns_storage() {
        let pool = BytePool::new(8);
        drop(pool.lease(50));
        assert_eq!(pool.stats().outstanding, 0);
        pool.lease(50);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn cap_zero_disables_pooling() {
        let pool = BytePool::new(0);
        let a = pool.lease(64).freeze();
        drop(a);
        let _b = pool.lease(64);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (0, 2), "every lease is a miss");
        assert_eq!(s.outstanding, 0, "unpooled leases are not tracked");
    }

    #[test]
    fn retention_cap_bounds_idle_buffers() {
        let pool = BytePool::new(2);
        let bufs: Vec<PooledBuf> = (0..4).map(|_| pool.lease(10).freeze()).collect();
        drop(bufs);
        // Only 2 retained; next 4 leases: 2 hits then 2 more misses.
        let _l: Vec<BufLease> = (0..4).map(|_| pool.lease(10)).collect();
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (2, 6));
    }

    #[test]
    fn large_alloc_probe_counts_only_big_misses() {
        let pool = BytePool::new(4);
        let before = large_allocs();
        drop(pool.lease(1024)); // 4 KiB class: below threshold
        assert_eq!(large_allocs(), before, "small miss not counted");
        let big = pool.lease(LARGE_ALLOC_THRESHOLD).freeze();
        assert_eq!(large_allocs(), before + 1, "large miss counted");
        drop(big);
        drop(pool.lease(LARGE_ALLOC_THRESHOLD));
        assert_eq!(large_allocs(), before + 1, "pool hit is not an allocation");
    }

    #[test]
    fn copy_probe_accumulates() {
        let before = copied_bytes();
        note_copy(10);
        note_copy(5);
        assert_eq!(copied_bytes(), before + 15);
    }

    #[test]
    fn from_vec_is_unpooled_and_compares_by_bytes() {
        let buf = PooledBuf::from_vec(vec![9, 9]);
        let other: PooledBuf = vec![9u8, 9].into();
        assert_eq!(buf, other);
        assert_eq!(buf, vec![9u8, 9]);
        assert_eq!(vec![9u8, 9], buf);
        assert_eq!(buf, [9u8, 9][..]);
        assert_eq!(buf.to_vec(), vec![9, 9]);
        assert_eq!(format!("{:?}", buf), "[9, 9]");
    }

    #[test]
    fn top_bucket_math() {
        // Oversize leases (> MAX_BUCKET) take the unpooled branch; the
        // largest pooled class is exactly MAX_BUCKET.
        assert_eq!(bucket_index(MAX_BUCKET - 1), N_BUCKETS - 1);
        assert_eq!(bucket_index(MAX_BUCKET), N_BUCKETS - 1);
    }

    #[test]
    fn set_cap_runtime_toggle() {
        let pool = BytePool::new(4);
        drop(pool.lease(10).freeze());
        pool.set_cap(0);
        drop(pool.lease(10)); // unpooled: miss even though a buffer idles
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (0, 2));
        pool.set_cap(4);
        drop(pool.lease(10));
        assert_eq!(pool.stats().hits, 1, "re-enabled pool serves the idle buffer");
    }

    #[test]
    fn global_pool_is_shared() {
        let a = BytePool::global();
        let b = BytePool::global();
        assert!(Arc::ptr_eq(&a.inner, &b.inner));
    }
}
