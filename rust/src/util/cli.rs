//! Minimal command-line argument parser (in-repo `clap` stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order plus `--key`→value options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    args.options.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usizes, e.g. `--sizes 256,512,1024`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--workers", "8", "--size=256", "--full"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get_usize("size", 0), 256);
        assert!(a.flag("full"));
        assert!(!a.flag("absent"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("x", 7), 7);
        assert_eq!(a.get_or("y", "z"), "z");
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--sizes", "256,512, 1024"]);
        assert_eq!(a.get_usize_list("sizes", &[1]), vec![256, 512, 1024]);
        assert_eq!(a.get_usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--quiet", "--verbose"]);
        assert!(a.flag("quiet") && a.flag("verbose"));
    }
}
