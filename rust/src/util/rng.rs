//! Deterministic 64-bit PRNG (SplitMix64 core + xoshiro256** stream).
//!
//! Used everywhere randomness is needed: random matrices, property tests,
//! straggler delay sampling. Deterministic seeding keeps every experiment and
//! test reproducible bit-for-bit.

/// SplitMix64 — used to seed the main generator and as a cheap stand-alone
/// generator for small cases.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator. Passes BigCrush; more than adequate for synthetic
/// workload generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng64 { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, bound)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponentially distributed f64 with the given mean.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Fork a child generator (for per-thread deterministic streams).
    pub fn fork(&mut self) -> Rng64 {
        Rng64::seeded(self.next_u64())
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (uniformly, order random).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::seeded(42);
        let mut b = Rng64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng64::seeded(1);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Rng64::seeded(2);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng64::seeded(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::seeded(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng64::seeded(5);
        let picks = r.choose_k(20, 8);
        assert_eq!(picks.len(), 8);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn exp_positive_mean_roughly_right() {
        let mut r = Rng64::seeded(6);
        let n = 20_000;
        let mean = 3.5;
        let total: f64 = (0..n).map(|_| r.exp(mean)).sum();
        let est = total / n as f64;
        assert!((est - mean).abs() < 0.2, "estimated mean {est}");
    }
}
