//! Minimal statistical micro-benchmark harness (in-repo `criterion` stand-in).
//!
//! Methodology mirrors the paper's (§V.A: "10 times per configuration,
//! averaged"): warmup, `reps` timed runs, report min / median / mean / max.
//! Used by every `rust/benches/*.rs` target and the experiments harness.
//!
//! Besides the human-readable stdout, every bench target persists a
//! machine-readable `BENCH_<name>.json` via [`write_bench_json`] (into the
//! invoking directory — the repo root under `make bench` / `make
//! bench-json` — or `$GR_CDMM_BENCH_OUT`), the input for perf-trajectory
//! tooling.

use crate::util::json::Json;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub reps: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl Sample {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("reps", self.reps)
            .set("min_s", self.min.as_secs_f64())
            .set("median_s", self.median.as_secs_f64())
            .set("mean_s", self.mean.as_secs_f64())
            .set("max_s", self.max.as_secs_f64())
    }
}

/// Write `BENCH_<name>.json` into `$GR_CDMM_BENCH_OUT` (default: the current
/// directory — the repo root when invoked via `make bench`/`make
/// bench-json`, since cargo bench binaries keep the invoking cwd). Returns
/// the written path.
pub fn write_bench_json(name: &str, json: &Json) -> std::io::Result<PathBuf> {
    let dir = std::env::var("GR_CDMM_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = PathBuf::from(dir).join(format!("BENCH_{name}.json"));
    std::fs::write(&path, json.render())?;
    Ok(path)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Benchmark runner: `warmup` untimed runs followed by `reps` timed runs.
pub struct Bencher {
    pub warmup: usize,
    pub reps: usize,
    quiet: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, reps: 5, quiet: false }
    }
}

impl Bencher {
    pub fn new(warmup: usize, reps: usize) -> Self {
        Bencher { warmup, reps, quiet: false }
    }

    /// Honour `GR_CDMM_BENCH_REPS` / `GR_CDMM_BENCH_WARMUP` env overrides so CI
    /// can dial effort up or down without editing bench sources.
    pub fn from_env() -> Self {
        let reps = std::env::var("GR_CDMM_BENCH_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(5);
        let warmup = std::env::var("GR_CDMM_BENCH_WARMUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1);
        Bencher { warmup, reps, quiet: false }
    }

    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Run `f` and collect timing statistics.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Sample {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.reps);
        for _ in 0..self.reps.max(1) {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let total: Duration = times.iter().sum();
        let sample = Sample {
            name: name.to_string(),
            reps: times.len(),
            min: times[0],
            median: times[times.len() / 2],
            mean: total / times.len() as u32,
            max: *times.last().unwrap(),
        };
        if !self.quiet {
            println!(
                "{:<48} reps={:<3} min={:>12?} median={:>12?} mean={:>12?} max={:>12?}",
                sample.name, sample.reps, sample.min, sample.median, sample.mean, sample.max
            );
        }
        sample
    }

    /// Time a single invocation of `f`, returning both duration and result.
    pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (Duration, T) {
        let t0 = Instant::now();
        let out = f();
        (t0.elapsed(), out)
    }
}

/// Format a throughput line: items (e.g. ring ops or bytes) per second.
pub fn throughput(items: f64, d: Duration) -> f64 {
    items / d.as_secs_f64().max(1e-12)
}

/// Render a markdown table from rows of (label, column values).
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&header.join(" | "));
    out.push_str(" |\n|");
    for _ in header {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher::new(0, 3).quiet();
        let mut count = 0u64;
        let s = b.bench("noop", || {
            count += 1;
        });
        assert_eq!(s.reps, 3);
        assert_eq!(count, 3);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn time_once_returns_value() {
        let (d, v) = Bencher::time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() < 1_000_000_000);
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn sample_to_json_has_all_stats() {
        let b = Bencher::new(0, 2).quiet();
        let s = b.bench("noop2", || {});
        let j = s.to_json().render();
        for key in ["name", "reps", "min_s", "median_s", "mean_s", "max_s"] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
