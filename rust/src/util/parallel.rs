//! Dependency-free scoped-thread data parallelism for the compute hot path.
//!
//! The offline build ships no `rayon`; this module is the minimal in-repo
//! replacement built on [`std::thread::scope`]. Three entry points cover
//! every parallel kernel in the crate:
//!
//! * [`par_map`] — order-preserving indexed map over a slice, chunked into
//!   one contiguous range per thread (the encode fan-out over `N` workers
//!   and the decode weight accumulation over output blocks);
//! * [`split_ranges`] / [`effective_threads`] — the partitioning policy the
//!   row-panel matmul kernels in [`crate::ring::plane`] share;
//! * [`configured_threads`] / [`with_threads`] — the thread-count source.
//!
//! **Thread count.** `GR_CDMM_THREADS` overrides, default =
//! [`std::thread::available_parallelism`]; `GR_CDMM_THREADS=1` takes the
//! exact sequential code path everywhere (no scope, no spawn — kernels
//! branch to their pre-threading loop). [`with_threads`] installs a
//! thread-local override for the duration of a closure, which is what the
//! bit-identity property tests use to pin the count without touching the
//! (process-global, racy) environment. The override is per-thread: threads
//! spawned *inside* the closure read the environment again, so nesting
//! stays bounded by the configured count per parallel region.
//!
//! **Determinism.** Parallel results are bit-identical to sequential by
//! construction: every kernel partitions its *output* into disjoint chunks
//! and runs the unchanged sequential loop per chunk, so each output element
//! sees exactly the same ring-operation sequence at every thread count
//! (property-tested across `GR_CDMM_THREADS ∈ {1, 2, 8}` and all ring
//! towers in `property_tests.rs`).

use std::cell::Cell;
use std::ops::Range;

/// Minimum number of base-ring multiply-adds before a kernel bothers to
/// spawn: below this, scope/spawn overhead (~tens of µs) dominates. The
/// Table-1 shapes (≥ 256², m ∈ {3,4,5}) sit orders of magnitude above it.
pub const MIN_PAR_OPS: usize = 1 << 15;

thread_local! {
    static THREAD_OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The machine's available parallelism (1 if unknown).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The worker-thread count parallel kernels use: the [`with_threads`]
/// override if one is active on this thread, else `GR_CDMM_THREADS`, else
/// [`available_threads`]. Always ≥ 1.
pub fn configured_threads() -> usize {
    if let Some(n) = THREAD_OVERRIDE.with(|c| c.get()) {
        return n.max(1);
    }
    std::env::var("GR_CDMM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(available_threads)
}

/// Run `f` with [`configured_threads`] pinned to `n` on the current thread
/// (restored afterwards, panic-safe). Used by tests to compare thread
/// counts deterministically without mutating the process environment.
pub fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = THREAD_OVERRIDE.with(|c| c.replace(Some(n.max(1))));
    let _restore = Restore(prev);
    f()
}

/// Partition `0..n` into at most `parts` contiguous ranges of near-equal
/// length (the first `n % parts` ranges get one extra element). Returns
/// fewer ranges when `n < parts`; never returns an empty range.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// The partitioning policy of the row-panel kernels: how many threads to
/// actually use for `units` splittable work units totalling roughly `ops`
/// base-ring multiply-adds. Returns 1 (→ exact sequential path) when the
/// request is sequential, the work can't be split, or it is too small to
/// amortize spawning.
pub fn effective_threads(threads: usize, units: usize, ops: usize) -> usize {
    if threads <= 1 || units < 2 || ops < MIN_PAR_OPS {
        1
    } else {
        threads.min(units)
    }
}

/// Order-preserving indexed map over a slice on up to `threads` scoped
/// threads (one contiguous chunk each). `threads <= 1` (or fewer than two
/// items) runs the plain sequential iterator — the exact same closure calls
/// in the exact same order, so results are identical at every count.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if threads <= 1 || n < 2 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let ranges = split_ranges(n, threads);
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| {
                s.spawn(move || {
                    items[r.clone()]
                        .iter()
                        .enumerate()
                        .map(|(off, x)| f(r.start + off, x))
                        .collect::<Vec<U>>()
                })
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        for h in handles {
            out.extend(h.join().expect("parallel worker thread panicked"));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_cover_exactly() {
        for (n, parts) in [(0usize, 3usize), (1, 4), (7, 3), (8, 8), (9, 2), (100, 7)] {
            let rs = split_ranges(n, parts);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} parts={parts}");
            let mut pos = 0;
            for r in &rs {
                assert_eq!(r.start, pos);
                assert!(!r.is_empty());
                pos = r.end;
            }
            assert!(rs.len() <= parts.max(1));
        }
    }

    #[test]
    fn par_map_preserves_order_and_indices() {
        let items: Vec<usize> = (0..37).collect();
        let expect: Vec<usize> = items.iter().map(|&x| x * x).collect();
        for t in [1usize, 2, 5, 16, 64] {
            let got = par_map(&items, t, |i, &x| {
                assert_eq!(i, x);
                x * x
            });
            assert_eq!(got, expect, "threads={t}");
        }
    }

    #[test]
    fn with_threads_overrides_and_restores() {
        let outer = configured_threads();
        let inner = with_threads(3, configured_threads);
        assert_eq!(inner, 3);
        assert_eq!(configured_threads(), outer);
        // nested overrides restore in LIFO order
        with_threads(5, || {
            assert_eq!(configured_threads(), 5);
            with_threads(2, || assert_eq!(configured_threads(), 2));
            assert_eq!(configured_threads(), 5);
        });
    }

    #[test]
    fn effective_threads_policy() {
        assert_eq!(effective_threads(1, 100, usize::MAX), 1);
        assert_eq!(effective_threads(8, 1, usize::MAX), 1);
        assert_eq!(effective_threads(8, 100, 10), 1, "tiny work stays sequential");
        assert_eq!(effective_threads(8, 100, MIN_PAR_OPS), 8);
        assert_eq!(effective_threads(8, 3, MIN_PAR_OPS), 3, "clamped to units");
    }
}
