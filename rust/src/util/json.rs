//! Tiny JSON *emitter* (no parser needed) for experiment output files.
//! In-repo replacement for `serde_json` (unavailable offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Only what the experiments harness emits.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig2")
            .set("sizes", Json::Arr(vec![Json::Int(256), Json::Int(512)]))
            .set("ok", true);
        let s = j.render();
        assert_eq!(s, r#"{"name":"fig2","ok":true,"sizes":[256,512]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
