//! Small self-contained utilities: PRNG, micro-bench harness, CLI parsing,
//! JSON emission, scoped-thread parallelism, pooled payload buffers. The
//! offline build environment ships no `rand`/`criterion`/`clap`/`serde`/
//! `rayon` — these are deliberately minimal in-repo replacements.

pub mod rng;
pub mod bench;
pub mod bytepool;
pub mod cli;
pub mod json;
pub mod parallel;
