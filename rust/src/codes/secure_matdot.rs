//! **Secure MatDot codes over a Galois ring** — the paper's stated future
//! work (§I: "Our CDMM based on Entangled polynomial codes over Galois ring
//! GR(p^e, d) can be extended to secure and private computation and we left
//! it as a future work"). This module implements the T-private inner-product
//! case (secure MatDot, [2]/[6]-style) over any Galois ring, reusing the
//! exceptional-set machinery. Shares, masks and responses are plane-major
//! ([`PlaneMatrix`]) like every other scheme.
//!
//! Construction. Partition `A` into `w` column blocks and `B` into `w` row
//! blocks (`C = Σ_k A_k B_k`). With `T` uniformly random mask matrices
//! `R_z, S_z` (same block shapes):
//!
//! ```text
//! f(x) = Σ_{j<w} A_j x^j        + Σ_{z<T} R_z x^{w+z}
//! g(x) = Σ_{k<w} B_k x^{w−1−k}  + Σ_{z<T} S_z x^{w+z}
//! ```
//!
//! `C` is the coefficient of `x^{w−1}` in `f·g`: the genuine terms land
//! there exactly for `j = k`, every mask-involving product lands at exponent
//! `≥ w`. Recovery threshold `R = deg(fg) + 1 = 2(w + T) − 1`.
//!
//! **T-privacy over the ring.** Any `T` workers' shares of `A` are
//! `f(α_i) = (known) + Σ_z R_z α_i^{w+z}`; the map from masks to those share
//! deviations is `diag(α_i^w)·V` where `V` is the Vandermonde on the `α_i`.
//! Over a Galois ring this is invertible iff the `α_i` are *units* with
//! unit pairwise differences — so the evaluation points are drawn from the
//! exceptional set **excluding 0** (lifts of nonzero residues). Uniform
//! masks then make any `T` shares uniform, i.e. perfect T-privacy; the
//! tests verify the invertibility of that mask matrix for random subsets
//! (the simulatability witness) and the correctness/threshold claims.

use super::encode_plan::{LagrangeDecodePlan, PowerTables};
use super::plan_cache::{PlanCache, DEFAULT_PLAN_CACHE_CAP};
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::matrix::Matrix;
use crate::ring::plane::{PlaneMatrix, PlaneRing};
use crate::ring::traits::Ring;
use crate::util::parallel;
use crate::util::rng::Rng64;
use std::sync::Mutex;

/// T-private MatDot code over a ring `E` with ≥ N+1 exceptional points.
pub struct SecureMatDot<E: PlaneRing> {
    ring: E,
    w: usize,
    t_priv: usize,
    n_workers: usize,
    /// Unit evaluation points (exceptional set minus 0).
    points: Vec<E::Elem>,
    /// The encode plan: per-point power tables `α^0 .. α^{w+T−1}` (data and
    /// mask slots), built once at construction.
    encode_plan: PowerTables<E>,
    /// Mask source (per-job fresh masks; Mutex for Send+Sync worker pools).
    rng: Mutex<Rng64>,
    /// Lagrange weight tables per sorted responding subset. Caching is
    /// sound despite the per-job masks: the plan depends only on the
    /// evaluation points, never on mask material.
    plan_cache: PlanCache<LagrangeDecodePlan<E>>,
}

impl<E: PlaneRing> SecureMatDot<E> {
    pub fn new(
        ring: E,
        n_workers: usize,
        w: usize,
        t_priv: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(w >= 1 && t_priv >= 1);
        let r = 2 * (w + t_priv) - 1;
        anyhow::ensure!(
            r <= n_workers,
            "recovery threshold R = {r} exceeds worker count N = {n_workers}"
        );
        // N unit points: take N+1 exceptional points and drop the lift of 0
        // (index 0 in the canonical enumeration) — every remaining point is
        // ≢ 0 (mod p), i.e. a unit, and differences stay units.
        let mut pts = ring.exceptional_points(n_workers + 1)?;
        pts.remove(0);
        debug_assert!(pts.iter().all(|p| ring.is_unit(p)));
        let encode_plan = PowerTables::build(&ring, &pts, w + t_priv - 1);
        Ok(SecureMatDot {
            ring,
            w,
            t_priv,
            n_workers,
            points: pts,
            encode_plan,
            rng: Mutex::new(Rng64::seeded(seed)),
            plan_cache: PlanCache::new(DEFAULT_PLAN_CACHE_CAP),
        })
    }

    pub fn privacy(&self) -> usize {
        self.t_priv
    }

    pub fn points(&self) -> &[E::Elem] {
        &self.points
    }

    /// The mask-to-share matrix `M[i][z] = α_i^{w+z}` for a worker subset —
    /// invertibility of this matrix for every T-subset is the perfect-privacy
    /// witness (simulatability of any T shares under uniform masks).
    pub fn mask_matrix(&self, workers: &[usize]) -> Matrix<E::Elem> {
        let ring = &self.ring;
        Matrix::from_fn(workers.len(), self.t_priv, |i, z| {
            ring.pow_u128(&self.points[workers[i]], (self.w + z) as u128)
        })
    }
}

impl<E: PlaneRing> DmmScheme<E> for SecureMatDot<E> {
    type ShareRing = E;

    fn name(&self) -> String {
        format!(
            "SecureMatDot(w={},T={}) over {}",
            self.w,
            self.t_priv,
            self.ring.name()
        )
    }
    fn share_ring(&self) -> &E {
        &self.ring
    }
    fn input_ring(&self) -> &E {
        &self.ring
    }
    fn n_workers(&self) -> usize {
        self.n_workers
    }
    fn recovery_threshold(&self) -> usize {
        2 * (self.w + self.t_priv) - 1
    }

    fn encode_batch(
        &self,
        a: &[Matrix<E::Elem>],
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<Share<E>>> {
        anyhow::ensure!(a.len() == 1 && b.len() == 1, "SecureMatDot is a single-product scheme");
        let ring = &self.ring;
        let (w, t_priv) = (self.w, self.t_priv);
        let (a, b) = (&a[0], &b[0]);
        anyhow::ensure!(a.cols == b.rows, "inner dimensions must agree");
        anyhow::ensure!(a.cols % w == 0, "w = {w} must divide r = {}", a.cols);
        let ap = PlaneMatrix::from_aos(ring, a);
        let bp = PlaneMatrix::from_aos(ring, b);
        let a_blocks = ap.partition_grid(1, w);
        let b_blocks = bp.partition_grid(w, 1);
        // fresh uniform masks per job (a uniform extension element is m
        // uniform base coefficients — identical distribution plane-major)
        let (r_masks, s_masks) = {
            let mut rng = self.rng.lock().unwrap();
            let r: Vec<_> = (0..t_priv)
                .map(|_| PlaneMatrix::random(ring, a_blocks[0].rows, a_blocks[0].cols, &mut rng))
                .collect();
            let s: Vec<_> = (0..t_priv)
                .map(|_| PlaneMatrix::random(ring, b_blocks[0].rows, b_blocks[0].cols, &mut rng))
                .collect();
            (r, s)
        };
        // Per-worker shares are independent: plan-driven (the power tables
        // up to w+T−1 were built at construction) and fanned out over
        // scoped threads; total-work gate keeps tiny encodes sequential.
        let base = ring.plane_base();
        let m = ring.plane_count();
        let per_share_ops =
            ((w + t_priv) * a_blocks[0].data.len() + (w + t_priv) * b_blocks[0].data.len()) * m;
        let threads = parallel::effective_threads(
            parallel::configured_threads(),
            self.points.len(),
            per_share_ops * self.points.len(),
        );
        Ok(parallel::par_map(&self.points, threads, |i, _alpha| {
            let powers = self.encode_plan.point(i);
            let mut fa = PlaneMatrix::zeros(ring, a_blocks[0].rows, a_blocks[0].cols);
            for (j, blk) in a_blocks.iter().enumerate() {
                fa.axpy_with_table(base, &powers[j], blk);
            }
            for (z, blk) in r_masks.iter().enumerate() {
                fa.axpy_with_table(base, &powers[w + z], blk); // x^{w+z} mask slot
            }
            let mut gb = PlaneMatrix::zeros(ring, b_blocks[0].rows, b_blocks[0].cols);
            for (k, blk) in b_blocks.iter().enumerate() {
                gb.axpy_with_table(base, &powers[w - 1 - k], blk);
            }
            for (z, blk) in s_masks.iter().enumerate() {
                gb.axpy_with_table(base, &powers[w + z], blk); // x^{w+z} mask slot
            }
            Share { a: fa, b: gb }
        }))
    }

    fn decode_batch(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<Matrix<E::Elem>>> {
        let ring = &self.ring;
        let need = self.recovery_threshold();
        anyhow::ensure!(responses.len() >= need, "{} responses < R = {need}", responses.len());
        let used = &responses[..need];
        let (rows, cols) = (used[0].1.rows, used[0].1.cols);
        let m = ring.plane_count();
        let mut seen = vec![false; self.n_workers];
        for (idx, y) in used {
            anyhow::ensure!(*idx < self.n_workers, "worker index {idx} out of range");
            anyhow::ensure!(!seen[*idx], "duplicate response from worker {idx}");
            seen[*idx] = true;
            anyhow::ensure!(
                y.rows == rows && y.cols == cols && y.planes == m,
                "response from worker {idx} has shape {}x{} ({} planes), expected {rows}x{cols} ({m})",
                y.rows,
                y.cols,
                y.planes
            );
        }
        // Lagrange weight tables per sorted subset, cached (see
        // `codes::plan_cache`); rank in the sorted key indexes that
        // worker's table. C = coefficient of x^{w−1} of the interpolated
        // product polynomial, so the plan holds exactly that one exponent.
        let mut sorted: Vec<usize> = used.iter().map(|(i, _)| *i).collect();
        sorted.sort_unstable();
        let plan = self.plan_cache.get_or_compute(&sorted, || {
            let pts: Vec<E::Elem> =
                sorted.iter().map(|&i| self.points[i].clone()).collect();
            LagrangeDecodePlan::build(ring, &pts, &[self.w - 1])
        });
        let base = ring.plane_base();
        let mut c = PlaneMatrix::zeros(ring, rows, cols);
        for (idx, y) in used {
            let j = sorted.binary_search(idx).expect("idx is in its own sorted subset");
            c.axpy_with_table(base, plan.table(j, 0), y);
        }
        Ok(vec![c.to_aos(ring)])
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        let eb = self.ring.elem_bytes();
        self.n_workers * ((16 + t * (r / self.w) * eb) + (16 + (r / self.w) * s * eb))
    }

    fn download_bytes(&self, t: usize, _r: usize, s: usize) -> usize {
        self.recovery_threshold() * (16 + t * s * self.ring.elem_bytes())
    }

    fn plan_cache_stats(&self) -> (u64, u64) {
        self.plan_cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::zq::Zq;

    fn ring(m: usize) -> Extension<Zq> {
        Extension::new(Zq::z2e(64), m)
    }

    fn roundtrip(w: usize, t_priv: usize, m: usize, seed: u64) {
        let ring = ring(m);
        let n_workers = 2 * (w + t_priv) - 1 + 2; // two spare workers
        let code = SecureMatDot::new(ring.clone(), n_workers, w, t_priv, seed).unwrap();
        let mut rng = Rng64::seeded(seed + 1);
        let a = Matrix::random(&ring, 3, 2 * w, &mut rng);
        let b = Matrix::random(&ring, 2 * w, 3, &mut rng);
        let shares = code.encode(&a, &b).unwrap();
        let rt = code.recovery_threshold();
        // use the LAST rt workers
        let responses: Vec<_> = (n_workers - rt..n_workers)
            .map(|i| (i, code.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert_eq!(code.decode(&responses).unwrap(), Matrix::matmul(&ring, &a, &b));
    }

    #[test]
    fn correct_for_various_w_and_t() {
        roundtrip(2, 1, 4, 501);
        roundtrip(3, 1, 4, 502);
        roundtrip(2, 2, 4, 503);
        roundtrip(1, 1, 3, 504);
    }

    #[test]
    fn threshold_is_2_w_plus_t_minus_1() {
        let code = SecureMatDot::new(ring(4), 9, 2, 2, 505).unwrap();
        assert_eq!(code.recovery_threshold(), 7);
    }

    #[test]
    fn evaluation_points_are_units() {
        let code = SecureMatDot::new(ring(4), 9, 2, 2, 506).unwrap();
        let r = ring(4);
        for p in code.points() {
            assert!(r.is_unit(p), "privacy requires unit evaluation points");
        }
    }

    #[test]
    fn mask_matrix_invertible_for_random_subsets() {
        // The perfect-privacy witness: diag(α^w)·Vandermonde on any T-subset
        // must be invertible over the ring.
        let r = ring(4);
        let code = SecureMatDot::new(r.clone(), 9, 2, 2, 507).unwrap();
        let mut rng = Rng64::seeded(508);
        for _ in 0..10 {
            let subset = rng.choose_k(9, 2);
            let m = code.mask_matrix(&subset);
            assert!(
                m.invert(&r).is_some(),
                "mask matrix must be invertible (subset {subset:?})"
            );
        }
    }

    #[test]
    fn masks_are_fresh_per_job() {
        // Same inputs, two encodes → different shares (masks resampled),
        // same decoded product.
        let r = ring(3);
        let code = SecureMatDot::new(r.clone(), 5, 1, 1, 509).unwrap();
        let mut rng = Rng64::seeded(510);
        let a = Matrix::random(&r, 2, 2, &mut rng);
        let b = Matrix::random(&r, 2, 2, &mut rng);
        let s1 = code.encode(&a, &b).unwrap();
        let s2 = code.encode(&a, &b).unwrap();
        assert_ne!(s1[0], s2[0], "fresh masks must change the shares");
        for shares in [&s1, &s2] {
            let responses: Vec<_> = (0..code.recovery_threshold())
                .map(|i| (i, code.worker_compute(&shares[i]).unwrap()))
                .collect();
            assert_eq!(code.decode(&responses).unwrap(), Matrix::matmul(&r, &a, &b));
        }
    }

    #[test]
    fn single_share_is_mask_randomized() {
        // With T = 1, a single worker's A-share equals (known) + R·α^w with R
        // uniform ⇒ the share itself is uniform. Sanity check: two different
        // INPUT matrices can produce the same share under suitable masks —
        // equivalently, share minus input-part is α^w·R, and α^w is a unit,
        // so the map R ↦ share-deviation is a bijection.
        let r = ring(3);
        let code = SecureMatDot::new(r.clone(), 5, 2, 1, 511).unwrap();
        let alpha_w = r.pow_u128(&code.points()[0], 2);
        assert!(r.is_unit(&alpha_w));
    }

    #[test]
    fn plan_cache_reused_across_jobs_on_same_subset() {
        let r = ring(3);
        let code = SecureMatDot::new(r.clone(), 5, 1, 1, 513).unwrap();
        let mut rng = Rng64::seeded(514);
        // same worker subset {0,1,2} every job, shuffled arrival order
        for order in [[0usize, 1, 2], [2, 0, 1], [1, 2, 0]] {
            let a = Matrix::random(&r, 2, 2, &mut rng);
            let b = Matrix::random(&r, 2, 2, &mut rng);
            let shares = code.encode(&a, &b).unwrap();
            let responses: Vec<_> = order
                .iter()
                .map(|&i| (i, code.worker_compute(&shares[i]).unwrap()))
                .collect();
            assert_eq!(code.decode(&responses).unwrap(), Matrix::matmul(&r, &a, &b));
        }
        // one cold plan, two warm reuses — masks change per job, the plan doesn't
        assert_eq!(code.plan_cache_stats(), (2, 1));
    }

    #[test]
    fn rejects_undersized_pool() {
        assert!(SecureMatDot::new(ring(3), 4, 2, 1, 512).is_err()); // R=5 > 4
    }
}
