//! Subset-keyed decode-plan cache — the steady-state serving optimisation.
//!
//! Every scheme's decode begins with an interpolation setup that is a *pure
//! function of the responding worker subset*: the Lagrange basis coefficients
//! in [`super::ep`] / [`super::secure_matdot`], the Cauchy–Vandermonde
//! inverse in [`super::csa`]. Under serving load the same fast-`R` subset
//! recurs job after job (the stragglers are the stragglers), so that
//! `O(R²)`–`O(R³)` scalar setup is recomputed for an input it has already
//! seen. [`PlanCache`] memoises it behind a bounded LRU keyed by the
//! **sorted** worker subset — sorting makes the key canonical under arrival
//! order, and because ring arithmetic is exact the plan computed on the
//! sorted subset is bit-identical to the one the arrival-order decode would
//! have produced (the decoders index plans by each worker's rank in the
//! sorted key; see the property tests).
//!
//! Hit/miss counters are cumulative over the cache lifetime and surfaced
//! per-job through [`DmmScheme::plan_cache_stats`](super::DmmScheme::plan_cache_stats)
//! into [`JobMetrics`](crate::coordinator::JobMetrics).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default capacity: comfortably above the `C(N−|slow|, R)` subsets a small
/// pool cycles through, small enough that plans (a few KB each) stay cheap.
pub const DEFAULT_PLAN_CACHE_CAP: usize = 64;

struct CacheEntry<V> {
    plan: Arc<V>,
    last_used: u64,
}

struct Inner<V> {
    map: HashMap<Vec<usize>, CacheEntry<V>>,
    /// Monotone access clock for LRU eviction.
    tick: u64,
}

/// A bounded LRU cache from sorted worker subsets to decode plans.
///
/// Plans are returned as `Arc<V>` so a hit never clones the plan; the
/// compute closure runs under the cache lock (decodes are master-side and
/// effectively serial per scheme, and a plan is far cheaper than the decode
/// it precedes).
pub struct PlanCache<V> {
    cap: usize,
    inner: Mutex<Inner<V>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<V> PlanCache<V> {
    /// A cache holding at most `cap ≥ 1` plans.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "plan cache capacity must be at least 1");
        PlanCache {
            cap,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up the plan for `key` (a **sorted** worker subset), computing and
    /// inserting it on a miss. The computation may fail; failures are not
    /// cached.
    pub fn try_get_or_compute(
        &self,
        key: &[usize],
        compute: impl FnOnce() -> anyhow::Result<V>,
    ) -> anyhow::Result<Arc<V>> {
        debug_assert!(key.windows(2).all(|w| w[0] < w[1]), "key must be sorted and duplicate-free");
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(entry) = inner.map.get_mut(key) {
            entry.last_used = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(&entry.plan));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let plan = Arc::new(compute()?);
        if inner.map.len() >= self.cap {
            let evict = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("cap >= 1 and map is at capacity");
            inner.map.remove(&evict);
        }
        inner
            .map
            .insert(key.to_vec(), CacheEntry { plan: Arc::clone(&plan), last_used: tick });
        Ok(plan)
    }

    /// Infallible variant of [`PlanCache::try_get_or_compute`].
    pub fn get_or_compute(&self, key: &[usize], compute: impl FnOnce() -> V) -> Arc<V> {
        match self.try_get_or_compute(key, || Ok(compute())) {
            Ok(plan) => plan,
            Err(_) => unreachable!("infallible compute"),
        }
    }

    /// Cumulative `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Number of plans currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss_counting() {
        let c: PlanCache<u64> = PlanCache::new(4);
        assert_eq!(*c.get_or_compute(&[0, 2, 5], || 10), 10);
        assert_eq!(c.stats(), (0, 1));
        // same subset: hit, no recompute
        assert_eq!(*c.get_or_compute(&[0, 2, 5], || unreachable!()), 10);
        assert_eq!(c.stats(), (1, 1));
        // different subset: miss
        assert_eq!(*c.get_or_compute(&[1, 2, 5], || 20), 20);
        assert_eq!(c.stats(), (1, 2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c: PlanCache<usize> = PlanCache::new(2);
        c.get_or_compute(&[0], || 0);
        c.get_or_compute(&[1], || 1);
        // touch [0] so [1] becomes the LRU victim
        c.get_or_compute(&[0], || unreachable!());
        c.get_or_compute(&[2], || 2); // evicts [1]
        assert_eq!(c.len(), 2);
        assert_eq!(*c.get_or_compute(&[0], || 99), 0); // still cached
        let (hits_before, _) = c.stats();
        c.get_or_compute(&[1], || 1); // recomputed: was evicted
        let (hits_after, _) = c.stats();
        assert_eq!(hits_before, hits_after, "[1] must have been a miss");
    }

    #[test]
    fn capacity_is_bounded() {
        let c: PlanCache<usize> = PlanCache::new(3);
        for i in 0..10 {
            c.get_or_compute(&[i], || i);
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.capacity(), 3);
        assert!(!c.is_empty());
    }

    #[test]
    fn failed_compute_not_cached() {
        let c: PlanCache<usize> = PlanCache::new(2);
        assert!(c.try_get_or_compute(&[7], || anyhow::bail!("nope")).is_err());
        assert_eq!(c.len(), 0);
        // the failure counted as a miss, and the retry recomputes
        assert_eq!(*c.try_get_or_compute(&[7], || Ok(7)).unwrap(), 7);
        assert_eq!(c.stats(), (0, 2));
    }
}
