//! **EP_RMFE-II** (Section IV, Corollary IV.2) — single-product CDMM with
//! Polynomial-style batch preprocessing.
//!
//! The variant implemented here is exactly the one the paper benchmarks in
//! §V ("Since we only tested small Galois rings with m = 3 or m = 4, we did
//! not split matrix A in EP_RMFE-II and applied only φ1"):
//!
//! * `B` is split into `n` *column* blocks `B_1 … B_n` (`r × s/n`) and packed
//!   elementwise: `ℬ = φ(B_1, …, B_n)` over `GR_m` (plane-major via
//!   [`crate::rmfe::pack_to_planes`]);
//! * `A` is kept whole and constant-embedded into `GR_m` (plane 0 = `A`);
//! * EP codes over `GR_m` compute `𝒞 = 𝒜·ℬ` (`t × s/n`);
//! * since `ψ(const_a · φ(x)) = a ⋆ x` (the embedded factor scales every
//!   slot), unpacking `𝒞` elementwise yields `(A·B_1, …, A·B_n)`, which are
//!   stitched side-by-side into `C`.
//!
//! Effect (Remark IV.3 / Figures 2–5): download volume and decoding time
//! drop by `1/n` (the response matrix is `t × s/n` but carries all `n`
//! column stripes), upload sits between plain EP (for the `A` part) and
//! EP_RMFE-I. The general two-level (φ1 + φ2) construction of Corollary IV.2
//! additionally splits `A` and packs with a second RMFE over `GR_{√m}`; it
//! kicks in only when `m` has a square structure (`m ≥ (2n−1)²`) — far
//! beyond the `m ∈ {3,4,5}` of every experimental configuration, so the
//! φ1-only path is the faithful reproduction.
//!
//! Restriction: the constant-embedding trick requires the finite-point RMFE
//! (`n ≤ p^d`) — with the ∞ variant, `ψ`'s last slot reads the coefficient
//! of `t^{2n−2}`, which a degree-`(n−1)` product `const·φ(x)` never reaches.

use super::ep::EpCode;
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;
use crate::ring::traits::Ring;
use crate::rmfe::poly_rmfe::PolyRmfe;
use crate::rmfe::{pack_to_planes, unpack_from_planes, RmfeScheme};

/// Single-DMM scheme: Polynomial-split of `B` → φ-pack → EP → ψ-unpack.
#[derive(Clone)]
pub struct EpRmfeII<R: ExtensibleRing> {
    rmfe: PolyRmfe<R>,
    ep: EpCode<Extension<R>>,
    n_split: usize,
}

impl<R: ExtensibleRing> EpRmfeII<R> {
    /// `n_workers` workers, EP partition `(u, w, v)` of the *packed* shapes
    /// (`u | t`, `w | r`, `v | s/n`), split factor `n_split`.
    pub fn new(
        base: R,
        n_workers: usize,
        u: usize,
        w: usize,
        v: usize,
        n_split: usize,
    ) -> anyhow::Result<Self> {
        let cap_ext = Extension::with_capacity(base.clone(), n_workers);
        let m = cap_ext.m().max(2 * n_split - 1);
        let ext = if m == cap_ext.m() { cap_ext } else { Extension::new(base, m) };
        Self::with_ext(ext, n_workers, u, w, v, n_split)
    }

    /// Fixed extension degree.
    pub fn with_m(
        base: R,
        m: usize,
        n_workers: usize,
        u: usize,
        w: usize,
        v: usize,
        n_split: usize,
    ) -> anyhow::Result<Self> {
        Self::with_ext(Extension::new(base, m), n_workers, u, w, v, n_split)
    }

    fn with_ext(
        ext: Extension<R>,
        n_workers: usize,
        u: usize,
        w: usize,
        v: usize,
        n_split: usize,
    ) -> anyhow::Result<Self> {
        let rmfe = PolyRmfe::with_ext(ext.clone(), n_split)?;
        anyhow::ensure!(
            !rmfe.uses_infinity(),
            "EP_RMFE-II's constant-embedding needs the finite-point RMFE \
             (n ≤ p^d); n = {n_split} requires the ∞ point over {}",
            rmfe.base().name()
        );
        let ep = EpCode::new(ext, n_workers, u, w, v)?;
        Ok(EpRmfeII { rmfe, ep, n_split })
    }

    pub fn n_split(&self) -> usize {
        self.n_split
    }
    pub fn m(&self) -> usize {
        self.rmfe.m()
    }
    pub fn ep(&self) -> &EpCode<Extension<R>> {
        &self.ep
    }
}

impl<R: ExtensibleRing> DmmScheme<R> for EpRmfeII<R> {
    type ShareRing = Extension<R>;

    fn name(&self) -> String {
        let p = self.ep.partition();
        format!(
            "EP_RMFE-II(n={},m={},u={},w={},v={}) over {}",
            self.n_split,
            self.m(),
            p.u,
            p.w,
            p.v,
            self.rmfe.base().name()
        )
    }
    fn share_ring(&self) -> &Extension<R> {
        self.rmfe.ext()
    }
    fn input_ring(&self) -> &R {
        self.rmfe.base()
    }
    fn n_workers(&self) -> usize {
        self.ep.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        self.ep.recovery_threshold()
    }

    fn encode_batch(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<Share<Extension<R>>>> {
        anyhow::ensure!(a.len() == 1 && b.len() == 1, "EP_RMFE-II is a single-product scheme");
        let (a, b) = (&a[0], &b[0]);
        let n = self.n_split;
        let ext = self.rmfe.ext();
        anyhow::ensure!(a.cols == b.rows, "inner dimensions must agree");
        anyhow::ensure!(b.cols % n == 0, "split n = {n} must divide s = {}", b.cols);
        // 𝒜 = constant-embedded A (plane 0); ℬ = φ(B_1 … B_n) columnwise.
        let packed_a = PlaneMatrix::from_base_matrix(ext, a);
        let b_parts = b.partition_grid(1, n);
        let packed_b = pack_to_planes(&self.rmfe, &b_parts);
        self.ep.encode_planes(&packed_a, &packed_b)
    }

    fn encode_left_batch(
        &self,
        a: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(a.len() == 1, "EP_RMFE-II is a single-product scheme");
        let packed_a = PlaneMatrix::from_base_matrix(self.rmfe.ext(), &a[0]);
        self.ep.encode_planes_left(&packed_a)
    }

    fn encode_right_batch(
        &self,
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(b.len() == 1, "EP_RMFE-II is a single-product scheme");
        let b = &b[0];
        let n = self.n_split;
        anyhow::ensure!(b.cols % n == 0, "split n = {n} must divide s = {}", b.cols);
        let b_parts = b.partition_grid(1, n);
        let packed_b = pack_to_planes(&self.rmfe, &b_parts);
        self.ep.encode_planes_right(&packed_b)
    }

    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        // A is kept whole (full t×r); only B is split into n column stripes.
        Some((
            self.n_workers() * self.ep.a_share_bytes(t, r),
            self.n_workers() * self.ep.b_share_bytes(r, s / self.n_split),
        ))
    }

    fn left_encodes(&self) -> u64 {
        self.ep.left_encode_count()
    }

    fn decode_batch(
        &self,
        responses: &[Response<Extension<R>>],
    ) -> anyhow::Result<Vec<Matrix<R::Elem>>> {
        anyhow::ensure!(!responses.is_empty(), "no responses");
        let p = self.ep.partition();
        let (bh, bw) = (responses[0].1.rows, responses[0].1.cols);
        let packed_c = self.ep.decode_planes(responses, bh * p.u, bw * p.v)?;
        // ψ unpacks each entry into the n column stripes A·B_j.
        let stripes = unpack_from_planes(&self.rmfe, &packed_c);
        Ok(vec![Matrix::stitch_grid(&stripes, 1, self.n_split)])
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.ep.upload_bytes(t, r, s / self.n_split)
    }
    fn download_bytes(&self, t: usize, _r: usize, s: usize) -> usize {
        self.recovery_threshold() * self.ep.response_bytes(t, s / self.n_split)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.ep.plan_cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::ep::PlainEp;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn roundtrip(scheme: &EpRmfeII<Zq>, t: usize, r: usize, s: usize, seed: u64) {
        let base = scheme.input_ring().clone();
        let mut rng = Rng64::seeded(seed);
        let a = Matrix::random(&base, t, r, &mut rng);
        let b = Matrix::random(&base, r, s, &mut rng);
        let shares = scheme.encode(&a, &b).unwrap();
        let rt = scheme.recovery_threshold();
        let responses: Vec<_> = (scheme.n_workers() - rt..scheme.n_workers())
            .map(|i| (i, scheme.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert_eq!(scheme.decode(&responses).unwrap(), Matrix::matmul(&base, &a, &b));
    }

    #[test]
    fn paper_8_worker_config() {
        // N=8, GR(2^64,3), u=v=2, w=1, n=2 (§V.A): v must divide s/2.
        let s = EpRmfeII::new(Zq::z2e(64), 8, 2, 1, 2, 2).unwrap();
        assert_eq!(s.m(), 3);
        assert_eq!(s.recovery_threshold(), 4);
        roundtrip(&s, 4, 4, 8, 161);
    }

    #[test]
    fn paper_16_worker_config() {
        let s = EpRmfeII::new(Zq::z2e(64), 16, 2, 2, 2, 2).unwrap();
        assert_eq!(s.m(), 4);
        assert_eq!(s.recovery_threshold(), 9);
        roundtrip(&s, 4, 4, 8, 162);
    }

    #[test]
    fn download_is_half_of_plain_ep_at_n2() {
        // Remark IV.3 / Fig 3d: EP_RMFE-II halves download at n=2.
        let base = Zq::z2e(64);
        let rmfe2 = EpRmfeII::with_m(base.clone(), 3, 8, 2, 1, 2, 2).unwrap();
        let plain = PlainEp::with_m(base, 3, 8, 2, 1, 2).unwrap();
        let (t, r, s) = (64usize, 64, 64);
        let down_rmfe = rmfe2.download_bytes(t, r, s);
        let down_plain = plain.download_bytes(t, r, s);
        let ratio = down_rmfe as f64 / down_plain as f64;
        assert!((ratio - 0.5).abs() < 0.01, "ratio {ratio}");
        // upload strictly between EP_RMFE-I (half) and plain EP (full):
        let up_rmfe2 = rmfe2.upload_bytes(t, r, s);
        let up_plain = plain.upload_bytes(t, r, s);
        assert!(up_rmfe2 < up_plain && up_rmfe2 > up_plain / 2, "upload in between");
    }

    #[test]
    fn split_encode_matches_joint() {
        let s = EpRmfeII::new(Zq::z2e(64), 8, 2, 1, 2, 2).unwrap();
        let base = s.input_ring().clone();
        let mut rng = Rng64::seeded(164);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 8, &mut rng);
        let joint = s.encode(&a, &b).unwrap();
        let left = s.encode_left(&a).unwrap();
        let right = s.encode_right(&b).unwrap();
        for (i, sh) in joint.iter().enumerate() {
            assert_eq!(left[i], sh.a, "worker {i} a-half");
            assert_eq!(right[i], sh.b, "worker {i} b-half");
        }
        let (sa, sb) = s.split_upload_bytes(4, 4, 8).unwrap();
        assert_eq!(sa + sb, s.upload_bytes(4, 4, 8));
        assert_eq!(s.left_encodes(), 2);
    }

    #[test]
    fn rejects_infinity_rmfe() {
        // n=3 over Z_2^e needs the ∞ point — invalid for EP_RMFE-II.
        assert!(EpRmfeII::new(Zq::z2e(64), 32, 2, 1, 2, 3).is_err());
    }

    #[test]
    fn galois_field_base_n4() {
        // over GF(2^2): 4 finite points allow n=4 without ∞.
        use crate::ring::galois::GaloisRing;
        let base = GaloisRing::new(2, 1, 2);
        let s = EpRmfeII::new(base.clone(), 16, 2, 1, 1, 4).unwrap();
        let mut rng = Rng64::seeded(163);
        let a = Matrix::random(&base, 2, 2, &mut rng);
        let b = Matrix::random(&base, 2, 8, &mut rng);
        let shares = s.encode(&a, &b).unwrap();
        let rt = s.recovery_threshold();
        let responses: Vec<_> = (0..rt)
            .map(|i| (i, s.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert_eq!(s.decode(&responses).unwrap(), Matrix::matmul(&base, &a, &b));
    }
}
