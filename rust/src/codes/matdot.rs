//! MatDot codes ([2]; Remark III.3) — the `u = v = 1` point of the EP
//! family: `A` split into `w` column-blocks, `B` into `w` row-blocks,
//! `C = Σ_k A_k B_k`, `R = 2w − 1`. Optimal recovery threshold for
//! inner-product partitions; every response is a full `t × s` matrix (the
//! download-heavy end of the trade-off).
//!
//! The batch preprocessing of EP_RMFE-I (Corollary IV.1) is exactly the
//! MatDot partition applied *before* packing.

use super::ep::EpCode;
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneRing;
use crate::ring::traits::Ring;

/// MatDot code over a ring with ≥ N exceptional points.
#[derive(Clone)]
pub struct MatDotCode<E: PlaneRing> {
    inner: EpCode<E>,
}

impl<E: PlaneRing> MatDotCode<E> {
    pub fn new(ring: E, n_workers: usize, w: usize) -> anyhow::Result<Self> {
        Ok(MatDotCode { inner: EpCode::new(ring, n_workers, 1, w, 1)? })
    }

    pub fn inner(&self) -> &EpCode<E> {
        &self.inner
    }
}

impl<E: PlaneRing> DmmScheme<E> for MatDotCode<E> {
    type ShareRing = E;

    fn name(&self) -> String {
        let p = self.inner.partition();
        format!("MatDot(w={}) over {}", p.w, self.share_ring().name())
    }
    fn share_ring(&self) -> &E {
        self.inner.share_ring()
    }
    fn input_ring(&self) -> &E {
        self.inner.input_ring()
    }
    fn n_workers(&self) -> usize {
        self.inner.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        // 1·1·w + w − 1 = 2w − 1
        self.inner.recovery_threshold()
    }
    fn encode_batch(
        &self,
        a: &[Matrix<E::Elem>],
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<Share<E>>> {
        self.inner.encode_batch(a, b)
    }
    fn encode_left_batch(
        &self,
        a: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<crate::ring::plane::PlaneMatrix<E::Base>>> {
        self.inner.encode_left_batch(a)
    }
    fn encode_right_batch(
        &self,
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<crate::ring::plane::PlaneMatrix<E::Base>>> {
        self.inner.encode_right_batch(b)
    }
    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        self.inner.split_upload_bytes(t, r, s)
    }
    fn left_encodes(&self) -> u64 {
        self.inner.left_encode_count()
    }
    fn decode_batch(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<Matrix<E::Elem>>> {
        self.inner.decode_batch(responses)
    }
    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.inner.upload_bytes(t, r, s)
    }
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.inner.download_bytes(t, r, s)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.inner.plan_cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    #[test]
    fn recovery_threshold_is_2w_minus_1() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let md = MatDotCode::new(ring, 8, 4).unwrap();
        assert_eq!(md.recovery_threshold(), 7);
    }

    #[test]
    fn roundtrip() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let md = MatDotCode::new(ring.clone(), 8, 3).unwrap();
        let mut rng = Rng64::seeded(121);
        let a = Matrix::random(&ring, 3, 6, &mut rng);
        let b = Matrix::random(&ring, 6, 3, &mut rng);
        let shares = md.encode(&a, &b).unwrap();
        let rt = md.recovery_threshold();
        let responses: Vec<_> = (0..rt)
            .map(|i| (i, md.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert_eq!(md.decode(&responses).unwrap(), Matrix::matmul(&ring, &a, &b));
    }

    #[test]
    fn split_encode_matches_joint() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let md = MatDotCode::new(ring.clone(), 8, 3).unwrap();
        let mut rng = Rng64::seeded(123);
        let a = Matrix::random(&ring, 3, 6, &mut rng);
        let b = Matrix::random(&ring, 6, 3, &mut rng);
        let joint = md.encode(&a, &b).unwrap();
        let left = md.encode_left(&a).unwrap();
        let right = md.encode_right(&b).unwrap();
        for (i, s) in joint.iter().enumerate() {
            assert_eq!(left[i], s.a, "worker {i} a-half");
            assert_eq!(right[i], s.b, "worker {i} b-half");
        }
        let (sa, sb) = md.split_upload_bytes(3, 6, 3).unwrap();
        assert_eq!(sa + sb, md.upload_bytes(3, 6, 3));
        assert_eq!(md.left_encodes(), 2);
    }

    #[test]
    fn responses_are_full_size() {
        // u = v = 1: every response is t × s.
        let ring = Extension::new(Zq::z2e(64), 3);
        let md = MatDotCode::new(ring.clone(), 5, 2).unwrap();
        let mut rng = Rng64::seeded(122);
        let a = Matrix::random(&ring, 3, 4, &mut rng);
        let b = Matrix::random(&ring, 4, 3, &mut rng);
        let shares = md.encode(&a, &b).unwrap();
        let resp = md.worker_compute(&shares[0]).unwrap();
        assert_eq!((resp.rows, resp.cols), (3, 3));
        // but shares carry only r/w of the inner dimension
        assert_eq!(shares[0].a.cols, 2);
    }
}
