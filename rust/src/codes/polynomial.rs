//! Polynomial codes ([1]; Remark III.3) — the `w = 1` point of the EP
//! family: `A` split into `u` row-blocks, `B` into `v` column-blocks,
//! `R = uv`. Optimal download among one-shot partitions (each response is a
//! distinct product block combination), at the cost of the full `r`-length
//! inner dimension at every worker.
//!
//! Provided as a named scheme because the paper calls it out explicitly
//! ("When using Polynomial codes, w = 1"): construction, docs and tests are
//! its own, arithmetic is shared with [`super::ep::EpCode`].

use super::ep::EpCode;
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneRing;
use crate::ring::traits::Ring;

/// Polynomial code over a ring with ≥ N exceptional points.
#[derive(Clone)]
pub struct PolynomialCode<E: PlaneRing> {
    inner: EpCode<E>,
}

impl<E: PlaneRing> PolynomialCode<E> {
    pub fn new(ring: E, n_workers: usize, u: usize, v: usize) -> anyhow::Result<Self> {
        Ok(PolynomialCode { inner: EpCode::new(ring, n_workers, u, 1, v)? })
    }

    pub fn inner(&self) -> &EpCode<E> {
        &self.inner
    }
}

impl<E: PlaneRing> DmmScheme<E> for PolynomialCode<E> {
    type ShareRing = E;

    fn name(&self) -> String {
        let p = self.inner.partition();
        format!("Polynomial(u={},v={}) over {}", p.u, p.v, self.share_ring().name())
    }
    fn share_ring(&self) -> &E {
        self.inner.share_ring()
    }
    fn input_ring(&self) -> &E {
        self.inner.input_ring()
    }
    fn n_workers(&self) -> usize {
        self.inner.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        // uv·1 + 1 − 1 = uv
        self.inner.recovery_threshold()
    }
    fn encode_batch(
        &self,
        a: &[Matrix<E::Elem>],
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<Share<E>>> {
        self.inner.encode_batch(a, b)
    }
    fn decode_batch(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<Matrix<E::Elem>>> {
        self.inner.decode_batch(responses)
    }
    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.inner.upload_bytes(t, r, s)
    }
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.inner.download_bytes(t, r, s)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.inner.plan_cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    #[test]
    fn recovery_threshold_is_uv() {
        let ring = Extension::new(Zq::z2e(64), 4);
        let pc = PolynomialCode::new(ring, 9, 3, 3).unwrap();
        assert_eq!(pc.recovery_threshold(), 9);
    }

    #[test]
    fn roundtrip() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let pc = PolynomialCode::new(ring.clone(), 8, 2, 2).unwrap();
        let mut rng = Rng64::seeded(111);
        let a = Matrix::random(&ring, 4, 3, &mut rng);
        let b = Matrix::random(&ring, 3, 4, &mut rng);
        let shares = pc.encode(&a, &b).unwrap();
        let rt = pc.recovery_threshold();
        let responses: Vec<_> = (8 - rt..8)
            .map(|i| (i, pc.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert_eq!(pc.decode(&responses).unwrap(), Matrix::matmul(&ring, &a, &b));
    }

    #[test]
    fn workers_see_full_inner_dimension() {
        // w = 1: shares keep the whole r dimension.
        let ring = Extension::new(Zq::z2e(64), 3);
        let pc = PolynomialCode::new(ring.clone(), 8, 2, 2).unwrap();
        let mut rng = Rng64::seeded(112);
        let a = Matrix::random(&ring, 4, 6, &mut rng);
        let b = Matrix::random(&ring, 6, 4, &mut rng);
        let shares = pc.encode(&a, &b).unwrap();
        assert_eq!(shares[0].a.cols, 6);
        assert_eq!(shares[0].b.rows, 6);
        assert_eq!(shares[0].a.rows, 2); // t/u
        assert_eq!(shares[0].b.cols, 2); // s/v
    }
}
