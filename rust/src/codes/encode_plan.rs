//! Precomputed encode/decode plans — the scalar-mul tables the hot loops
//! borrow instead of rebuilding.
//!
//! Every encode/decode inner loop in [`crate::codes`] is a sequence of
//! `acc += s·X` plane axpys whose scalars are **fixed at scheme
//! construction** (powers of the evaluation points, CSA's `ν_l(α_i)` /
//! `(f_l − α_i)^{-1}` factors) or fixed per responding subset (Lagrange
//! weights, the Cauchy–Vandermonde inverse). Before this module each such
//! axpy recomputed and heap-allocated the `m × m`
//! [`PlaneRing::scalar_mul_table`](crate::ring::plane::PlaneRing::scalar_mul_table)
//! on every call; now:
//!
//! * [`PowerTables`] — built once per scheme: for every evaluation point,
//!   the [`ScalarTable`]s of its powers `α^0 .. α^max_exp` (the sparse
//!   Horner encode fan-out and the secure-MatDot mask slots);
//! * [`LagrangeDecodePlan`] — built once per responding subset and cached
//!   in the subset-keyed [`super::plan_cache::PlanCache`]: the tables of
//!   the Lagrange-basis coefficients the EP/secure-MatDot decoders take as
//!   interpolation weights (warm decodes do zero table work).
//!
//! Plan-driven results are **bit-identical** to the on-the-spot path: the
//! plans compute each scalar with the exact operation sequence the naive
//! loops used (the same `acc ← acc·α` power recurrence, the same
//! `basis[j].get(k)` weight lookup) and
//! [`PlaneMatrix::axpy_with_table`](crate::ring::plane::PlaneMatrix::axpy_with_table)
//! replays the same slice axpys. Steady-state table builds are counted by
//! [`crate::ring::plane::scalar_table_builds`] and asserted zero in
//! `integration_codes.rs` and the `encode_decode` bench.

use crate::ring::eval::lagrange_basis_coeffs;
use crate::ring::plane::{PlaneRing, ScalarTable};
use crate::ring::traits::Ring;

/// Per-evaluation-point power tables: `point(i)[k]` is the
/// [`ScalarTable`] of `points[i]^k`, for `k = 0..=max_exp`.
pub struct PowerTables<E: PlaneRing> {
    tables: Vec<Vec<ScalarTable<E::Base>>>,
}

impl<E: PlaneRing> PowerTables<E> {
    /// Build tables for `points[i]^k`, `k = 0..=max_exp`, with the same
    /// `acc ← acc·α` recurrence the naive Horner evaluators used — so
    /// plan-driven evaluation reproduces their scalars bit for bit.
    pub fn build(ring: &E, points: &[E::Elem], max_exp: usize) -> Self {
        let tables = points
            .iter()
            .map(|alpha| {
                let mut per_point = Vec::with_capacity(max_exp + 1);
                let mut acc = ring.one();
                for _ in 0..=max_exp {
                    per_point.push(ScalarTable::build(ring, &acc));
                    acc = ring.mul(&acc, alpha);
                }
                per_point
            })
            .collect();
        PowerTables { tables }
    }

    /// The tables of point `i`: index `k` holds `points[i]^k`.
    pub fn point(&self, i: usize) -> &[ScalarTable<E::Base>] {
        &self.tables[i]
    }

    /// Number of points.
    pub fn n_points(&self) -> usize {
        self.tables.len()
    }

    /// Tables per point (`max_exp + 1`).
    pub fn powers_per_point(&self) -> usize {
        self.tables.first().map_or(0, Vec::len)
    }
}

/// A cached decode plan for Lagrange-interpolating decoders (EP family,
/// secure MatDot): for each response rank `j` in the **sorted** responding
/// subset and each wanted coefficient exponent, the [`ScalarTable`] of
/// `basis[j][exp]` — the weight the decoder multiplies response `j` by.
pub struct LagrangeDecodePlan<E: PlaneRing> {
    /// `tables[j][ci]`: rank `j`, index `ci` into the `exps` the plan was
    /// built with.
    tables: Vec<Vec<ScalarTable<E::Base>>>,
}

impl<E: PlaneRing> LagrangeDecodePlan<E> {
    /// Build the plan for the points of a sorted subset and the wanted
    /// coefficient exponents. Missing coefficients (`exp ≥ basis degree`)
    /// get the zero table, matching the naive `get(k).unwrap_or(zero)`.
    pub fn build(ring: &E, pts: &[E::Elem], exps: &[usize]) -> Self {
        let basis = lagrange_basis_coeffs(ring, pts);
        let tables = basis
            .iter()
            .map(|bj| {
                exps.iter()
                    .map(|&k| {
                        let w = bj.get(k).cloned().unwrap_or_else(|| ring.zero());
                        ScalarTable::build(ring, &w)
                    })
                    .collect()
            })
            .collect();
        LagrangeDecodePlan { tables }
    }

    /// Weight table for sorted-subset rank `j`, wanted-exponent index `ci`.
    pub fn table(&self, j: usize, ci: usize) -> &ScalarTable<E::Base> {
        &self.tables[j][ci]
    }

    /// Number of ranks (the subset size the plan was built for).
    pub fn n_ranks(&self) -> usize {
        self.tables.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::plane::PlaneMatrix;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn ext3() -> Extension<Zq> {
        Extension::new(Zq::z2e(64), 3)
    }

    #[test]
    fn power_tables_reproduce_naive_powers() {
        let ext = ext3();
        let pts = ext.exceptional_points(8).unwrap();
        let plan = PowerTables::build(&ext, &pts, 5);
        assert_eq!(plan.n_points(), 8);
        assert_eq!(plan.powers_per_point(), 6);
        let mut rng = Rng64::seeded(730);
        let x = PlaneMatrix::random(&ext, 2, 3, &mut rng);
        for (i, alpha) in pts.iter().enumerate() {
            // the naive power recurrence of the old eval_sparse
            let mut acc = ext.one();
            for k in 0..=5usize {
                let mut via_plan = PlaneMatrix::zeros(&ext, 2, 3);
                via_plan.axpy_with_table(ext.base(), &plan.point(i)[k], &x);
                let mut naive = PlaneMatrix::zeros(&ext, 2, 3);
                naive.axpy(&ext, &acc, &x);
                assert_eq!(via_plan, naive, "point {i} power {k}");
                acc = ext.mul(&acc, alpha);
            }
        }
    }

    #[test]
    fn lagrange_plan_matches_naive_weights() {
        let ext = ext3();
        let pts = ext.exceptional_points(5).unwrap();
        let exps = [0usize, 2, 4, 7]; // 7 is beyond the basis degree → zero
        let plan = LagrangeDecodePlan::build(&ext, &pts, &exps);
        assert_eq!(plan.n_ranks(), 5);
        let basis = lagrange_basis_coeffs(&ext, &pts);
        let mut rng = Rng64::seeded(731);
        let y = PlaneMatrix::random(&ext, 2, 2, &mut rng);
        for j in 0..5 {
            for (ci, &k) in exps.iter().enumerate() {
                let w = basis[j].get(k).cloned().unwrap_or_else(|| ext.zero());
                let mut naive = PlaneMatrix::zeros(&ext, 2, 2);
                naive.axpy(&ext, &w, &y);
                let mut planned = PlaneMatrix::zeros(&ext, 2, 2);
                planned.axpy_with_table(ext.base(), plan.table(j, ci), &y);
                assert_eq!(planned, naive, "rank {j} exp {k}");
                if k == 7 {
                    assert!(plan.table(j, ci).is_zero_scalar());
                }
            }
        }
    }
}
