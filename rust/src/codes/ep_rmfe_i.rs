//! **EP_RMFE-I** (Section IV, Corollary IV.1) — single-product CDMM with
//! MatDot-style batch preprocessing.
//!
//! `A` is split into `n` column blocks and `B` into `n` row blocks, so that
//! `A·B = Σ_i A_i B_i` — a "manufactured" batch of `n` products of
//! `(t × r/n)·(r/n × s)` matrices, computed with one Batch-EP_RMFE call and
//! summed.
//!
//! Compared to the plain EP baseline (Lemma III.1) this saves a factor `m`
//! in *encoding time, upload volume and per-worker compute* (Remark IV.3)
//! while download/decoding match plain EP — the profile visible in
//! Figures 2–5 as "EP_RMFE-I": half the encode time and upload at `n = 2`.

use super::batch_ep_rmfe::BatchEpRmfe;
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;

/// Single-DMM scheme: MatDot-split → Batch-EP_RMFE → sum.
#[derive(Clone)]
pub struct EpRmfeI<R: ExtensibleRing> {
    batch: BatchEpRmfe<R>,
    n_split: usize,
}

impl<R: ExtensibleRing> EpRmfeI<R> {
    /// `n_workers` workers, EP partition `(u, w, v)` (of the *split* shapes:
    /// `u | t`, `w | r/n`, `v | s`), split factor `n_split`.
    pub fn new(
        base: R,
        n_workers: usize,
        u: usize,
        w: usize,
        v: usize,
        n_split: usize,
    ) -> anyhow::Result<Self> {
        let batch = BatchEpRmfe::new(base, n_workers, n_split, u, w, v)?;
        Ok(EpRmfeI { batch, n_split })
    }

    /// Fixed extension degree `m` (paper: m=3 for N=8, m=4 for N=16).
    pub fn with_m(
        base: R,
        m: usize,
        n_workers: usize,
        u: usize,
        w: usize,
        v: usize,
        n_split: usize,
    ) -> anyhow::Result<Self> {
        let batch = BatchEpRmfe::with_m(base, m, n_workers, n_split, u, w, v)?;
        Ok(EpRmfeI { batch, n_split })
    }

    pub fn n_split(&self) -> usize {
        self.n_split
    }
    pub fn m(&self) -> usize {
        self.batch.m()
    }
    pub fn batch(&self) -> &BatchEpRmfe<R> {
        &self.batch
    }
}

impl<R: ExtensibleRing> DmmScheme<R> for EpRmfeI<R> {
    type ShareRing = Extension<R>;

    fn name(&self) -> String {
        format!("EP_RMFE-I(n={}) [{}]", self.n_split, self.batch.name())
    }
    fn share_ring(&self) -> &Extension<R> {
        self.batch.share_ring()
    }
    fn input_ring(&self) -> &R {
        self.batch.input_ring()
    }
    fn n_workers(&self) -> usize {
        self.batch.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        self.batch.recovery_threshold()
    }

    fn encode_batch(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<Share<Extension<R>>>> {
        anyhow::ensure!(a.len() == 1 && b.len() == 1, "EP_RMFE-I is a single-product scheme");
        let (a, b) = (&a[0], &b[0]);
        let n = self.n_split;
        anyhow::ensure!(a.cols == b.rows, "inner dimensions must agree");
        anyhow::ensure!(a.cols % n == 0, "split n = {n} must divide r = {}", a.cols);
        let a_parts = a.partition_grid(1, n); // A = (A_1 … A_n)
        let b_parts = b.partition_grid(n, 1); // B = (B_1; …; B_n)
        self.batch.encode_batch(&a_parts, &b_parts)
    }

    fn encode_left_batch(
        &self,
        a: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(a.len() == 1, "EP_RMFE-I is a single-product scheme");
        let a = &a[0];
        let n = self.n_split;
        anyhow::ensure!(a.cols % n == 0, "split n = {n} must divide r = {}", a.cols);
        let a_parts = a.partition_grid(1, n);
        self.batch.encode_left_batch(&a_parts)
    }

    fn encode_right_batch(
        &self,
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(b.len() == 1, "EP_RMFE-I is a single-product scheme");
        let b = &b[0];
        let n = self.n_split;
        anyhow::ensure!(b.rows % n == 0, "split n = {n} must divide r = {}", b.rows);
        let b_parts = b.partition_grid(n, 1);
        self.batch.encode_right_batch(&b_parts)
    }

    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        self.batch.split_upload_bytes(t, r / self.n_split, s)
    }

    fn left_encodes(&self) -> u64 {
        self.batch.left_encodes()
    }

    fn decode_batch(
        &self,
        responses: &[Response<Extension<R>>],
    ) -> anyhow::Result<Vec<Matrix<R::Elem>>> {
        let parts = self.batch.decode_batch(responses)?;
        let ring = self.input_ring();
        let mut acc = parts[0].clone();
        for p in &parts[1..] {
            acc.add_assign(ring, p);
        }
        Ok(vec![acc])
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.batch.upload_bytes(t, r / self.n_split, s)
    }
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.batch.download_bytes(t, r / self.n_split, s)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.batch.plan_cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::ep::PlainEp;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn roundtrip(scheme: &EpRmfeI<Zq>, t: usize, r: usize, s: usize, seed: u64) {
        let base = scheme.input_ring().clone();
        let mut rng = Rng64::seeded(seed);
        let a = Matrix::random(&base, t, r, &mut rng);
        let b = Matrix::random(&base, r, s, &mut rng);
        let shares = scheme.encode(&a, &b).unwrap();
        let rt = scheme.recovery_threshold();
        let responses: Vec<_> = (scheme.n_workers() - rt..scheme.n_workers())
            .map(|i| (i, scheme.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert_eq!(scheme.decode(&responses).unwrap(), Matrix::matmul(&base, &a, &b));
    }

    #[test]
    fn paper_8_worker_config() {
        // N=8, GR(2^64,3), u=v=2, w=1, n=2 (§V.A): R=4.
        let s = EpRmfeI::new(Zq::z2e(64), 8, 2, 1, 2, 2).unwrap();
        assert_eq!(s.m(), 3);
        assert_eq!(s.recovery_threshold(), 4);
        roundtrip(&s, 4, 4, 4, 151);
    }

    #[test]
    fn paper_16_worker_config() {
        // N=16, GR(2^64,4), u=v=w=2, n=2: R=9.
        let s = EpRmfeI::new(Zq::z2e(64), 16, 2, 2, 2, 2).unwrap();
        assert_eq!(s.m(), 4);
        assert_eq!(s.recovery_threshold(), 9);
        roundtrip(&s, 4, 8, 4, 152);
    }

    #[test]
    fn n3_split_32_workers() {
        // §V.C extension: N=32, m=5, (3,5)-RMFE, n=3.
        let s = EpRmfeI::new(Zq::z2e(64), 32, 2, 1, 2, 3).unwrap();
        assert_eq!(s.m(), 5);
        roundtrip(&s, 2, 6, 2, 153);
    }

    #[test]
    fn upload_is_half_of_plain_ep_at_n2() {
        // Remark IV.3 / Fig 2b: EP_RMFE-I halves upload at n=2.
        let base = Zq::z2e(64);
        let rmfe1 = EpRmfeI::with_m(base.clone(), 3, 8, 2, 1, 2, 2).unwrap();
        let plain = PlainEp::with_m(base, 3, 8, 2, 1, 2).unwrap();
        let (t, r, s) = (64usize, 64, 64);
        let up_rmfe = rmfe1.upload_bytes(t, r, s);
        let up_plain = plain.upload_bytes(t, r, s);
        // ratio ≈ 1/2 up to the 16-byte headers
        let ratio = up_rmfe as f64 / up_plain as f64;
        assert!((ratio - 0.5).abs() < 0.01, "ratio {ratio}");
        // download unchanged
        assert_eq!(rmfe1.download_bytes(t, r, s), plain.download_bytes(t, r, s));
    }

    #[test]
    fn split_encode_matches_joint() {
        let s = EpRmfeI::new(Zq::z2e(64), 8, 2, 1, 2, 2).unwrap();
        let base = s.input_ring().clone();
        let mut rng = Rng64::seeded(155);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let joint = s.encode(&a, &b).unwrap();
        let left = s.encode_left(&a).unwrap();
        let right = s.encode_right(&b).unwrap();
        for (i, sh) in joint.iter().enumerate() {
            assert_eq!(left[i], sh.a, "worker {i} a-half");
            assert_eq!(right[i], sh.b, "worker {i} b-half");
        }
        let (sa, sb) = s.split_upload_bytes(4, 4, 4).unwrap();
        assert_eq!(sa + sb, s.upload_bytes(4, 4, 4));
        assert_eq!(s.left_encodes(), 2);
    }

    #[test]
    fn rejects_bad_split() {
        let s = EpRmfeI::new(Zq::z2e(64), 8, 2, 1, 2, 2).unwrap();
        let base = Zq::z2e(64);
        let mut rng = Rng64::seeded(154);
        let a = Matrix::random(&base, 4, 5, &mut rng); // r=5 not divisible by 2
        let b = Matrix::random(&base, 5, 4, &mut rng);
        assert!(s.encode(&a, &b).is_err());
    }
}
