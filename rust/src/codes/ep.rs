//! Entangled Polynomial codes over a Galois ring ([20]; Lemma III.1).
//!
//! The master partitions `A` into a `u × w` grid and `B` into `w × v`, forms
//!
//! ```text
//! f(x) = Σ_{i,j} A_{ij} x^{i·w + j}                 (0-based; deg = uw−1)
//! g(x) = Σ_{k,ℓ} B_{kℓ} x^{(w−1−k) + ℓ·uw}          (deg = (v−1)uw + w−1)
//! ```
//!
//! and sends `(f(α_i), g(α_i))` to worker `i`, where `α_1, …, α_N` are
//! exceptional points of the ring. Worker `i` returns `h(α_i) =
//! f(α_i)·g(α_i)`. From any `R = uvw + w − 1` responses the master
//! interpolates `h` (degree `R−1`) and reads the product blocks `C_{iℓ}` off
//! the coefficients of `x^{i·w + (w−1) + ℓ·uw}`.
//!
//! Implementation notes:
//! * all share-ring matrices are **plane-major** ([`PlaneMatrix`]): encoding
//!   evaluates the (sparse) matrix polynomials with the per-point power
//!   tables precomputed once at construction ([`PowerTables`] — the encode
//!   plan) and plane-level table axpys (`m²` base-ring slice axpys per
//!   term), fanning the `N` worker shares out over scoped threads
//!   ([`crate::util::parallel`]) — zero per-element heap traffic and zero
//!   steady-state `scalar_mul_table` builds;
//! * decoding computes a [`LagrangeDecodePlan`] on the responding subset
//!   once (`O(R²)` scalar ops + `uv·R` weight tables) and then takes `uv`
//!   weighted sums of the plane-major response matrices (parallel over the
//!   `uv` output blocks) — the interpolation never materializes `h` as a
//!   polynomial; the plan is memoised per sorted subset in a [`PlanCache`],
//!   so a recurring fast-`R` subset pays the setup once per cache lifetime
//!   and warm decodes do zero table work;
//! * [`PlainEp`] is the Lemma III.1 baseline for inputs in a *small* ring:
//!   every input element is constant-embedded into the extension
//!   `GR(p^e, d·m)` with `p^{dm} ≥ N` (plane 0 = input, higher planes zero),
//!   paying the `O(m)` blowup in every metric — the overhead RMFE amortizes
//!   away.

use super::encode_plan::{LagrangeDecodePlan, PowerTables};
use super::plan_cache::{PlanCache, DEFAULT_PLAN_CACHE_CAP};
use super::scheme::{freivalds_check, DmmScheme, Partition, Response, Share};
use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::matrix::Matrix;
use crate::ring::plane::{PlaneMatrix, PlaneRing, ScalarTable};
use crate::ring::traits::Ring;
use crate::util::parallel;
use crate::util::rng::Rng64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// EP code operating directly over a ring `E` with at least `N` exceptional
/// points (typically an extension ring).
#[derive(Clone)]
pub struct EpCode<E: PlaneRing> {
    pub(super) ring: E,
    part: Partition,
    n_workers: usize,
    points: Vec<E::Elem>,
    /// The encode plan: per-point power tables for every exponent the
    /// sparse `f`/`g` layouts use, built once at construction; `Arc` so
    /// clones share it.
    encode_plan: Arc<PowerTables<E>>,
    /// Decode plans (Lagrange weight tables) per sorted responding subset;
    /// `Arc` so clones of the code share one warm cache.
    plan_cache: Arc<PlanCache<LagrangeDecodePlan<E>>>,
    /// A-side encode probe: bumped by every joint encode and every
    /// left-only encode; `Arc` so clones share it (the serving bench
    /// asserts the count stays flat across prepared steady-state jobs).
    left_encodes: Arc<AtomicU64>,
    /// The verify plan: per-point power tables for *every* exponent of `h`
    /// (degree `R−1`, strictly more than the encode plan's sparse layouts
    /// cover), used to re-encode an interpolated `h` at spare evaluation
    /// points for surplus consistency checking. Built lazily on the first
    /// verified decode; `Arc` so clones share it.
    verify_plan: Arc<OnceLock<PowerTables<E>>>,
}

impl<E: PlaneRing> EpCode<E> {
    pub fn new(ring: E, n_workers: usize, u: usize, w: usize, v: usize) -> anyhow::Result<Self> {
        let part = Partition::new(u, w, v);
        let r = part.recovery_threshold();
        anyhow::ensure!(
            r <= n_workers,
            "recovery threshold R = {r} exceeds worker count N = {n_workers}"
        );
        let points = ring.exceptional_points(n_workers)?;
        let max_exp = Self::a_exponents_of(part)
            .into_iter()
            .chain(Self::b_exponents_of(part))
            .max()
            .expect("u, w, v >= 1");
        let encode_plan = Arc::new(PowerTables::build(&ring, &points, max_exp));
        Ok(EpCode {
            ring,
            part,
            n_workers,
            points,
            encode_plan,
            plan_cache: Arc::new(PlanCache::new(DEFAULT_PLAN_CACHE_CAP)),
            left_encodes: Arc::new(AtomicU64::new(0)),
            verify_plan: Arc::new(OnceLock::new()),
        })
    }

    /// Cumulative A-side encodes (joint or left-only) since construction.
    pub fn left_encode_count(&self) -> u64 {
        self.left_encodes.load(Ordering::Relaxed)
    }

    pub fn partition(&self) -> Partition {
        self.part
    }

    pub fn points(&self) -> &[E::Elem] {
        &self.points
    }

    /// The decode-plan cache (Lagrange weight tables keyed by sorted
    /// subset).
    pub fn plan_cache(&self) -> &PlanCache<LagrangeDecodePlan<E>> {
        &self.plan_cache
    }

    /// The sparse exponent layout of `f` for `A`-blocks: block `(i, j)` (row
    /// `i` of `u`, col `j` of `w`) sits at exponent `i·w + j`.
    fn a_exponents_of(part: Partition) -> Vec<usize> {
        let Partition { u, w, .. } = part;
        (0..u).flat_map(|i| (0..w).map(move |j| i * w + j)).collect()
    }

    fn a_exponents(&self) -> Vec<usize> {
        Self::a_exponents_of(self.part)
    }

    /// Exponents of `g` for `B`-blocks: block `(k, ℓ)` at `(w−1−k) + ℓ·uw`.
    fn b_exponents_of(part: Partition) -> Vec<usize> {
        let Partition { u, w, v } = part;
        (0..w)
            .flat_map(|k| (0..v).map(move |l| (w - 1 - k) + l * u * w))
            .collect()
    }

    fn b_exponents(&self) -> Vec<usize> {
        Self::b_exponents_of(self.part)
    }

    /// Exponents of `h = f·g` that carry the product blocks `C_{iℓ}`.
    fn c_exponents(&self) -> Vec<usize> {
        let Partition { u, w, v } = self.part;
        (0..u)
            .flat_map(|i| (0..v).map(move |l| i * w + (w - 1) + l * u * w))
            .collect()
    }

    /// Evaluate a sparse matrix polynomial `Σ blocks[b] x^{exps[b]}` with
    /// the precomputed power tables of one point — plane-level Horner via
    /// [`PlaneMatrix::axpy_with_table`], zero table builds.
    fn eval_sparse_tables(
        ring: &E,
        blocks: &[PlaneMatrix<E::Base>],
        exps: &[usize],
        tables: &[ScalarTable<E::Base>],
    ) -> PlaneMatrix<E::Base> {
        let base = ring.plane_base();
        let mut out = PlaneMatrix::zeros(ring, blocks[0].rows, blocks[0].cols);
        for (blk, &e) in blocks.iter().zip(exps) {
            out.axpy_with_table(base, &tables[e], blk);
        }
        out
    }

    /// Encode plane-major share-ring matrices directly (the entry point the
    /// RMFE schemes use after packing into the extension).
    pub fn encode_planes(
        &self,
        a: &PlaneMatrix<E::Base>,
        b: &PlaneMatrix<E::Base>,
    ) -> anyhow::Result<Vec<Share<E>>> {
        let Partition { u, w, v } = self.part;
        anyhow::ensure!(a.cols == b.rows, "inner dimensions must agree");
        let m = self.ring.plane_count();
        anyhow::ensure!(
            a.planes == m && b.planes == m,
            "share matrices must have {m} planes"
        );
        self.part.check_shapes(a.rows, a.cols, b.cols)?;
        self.left_encodes.fetch_add(1, Ordering::Relaxed);
        let a_blocks = a.partition_grid(u, w);
        let b_blocks = b.partition_grid(w, v);
        let a_exps = self.a_exponents();
        let b_exps = self.b_exponents();
        let ring = &self.ring;
        let plan = &self.encode_plan;
        // One share per worker, fanned out over scoped threads (the shares
        // are independent); plan-driven, so no table builds in here. Gate on
        // total work so tiny encodes stay sequential (spawn overhead floor).
        let per_share_ops = (a_blocks[0].data.len() * a_blocks.len()
            + b_blocks[0].data.len() * b_blocks.len())
            * m;
        let threads = parallel::effective_threads(
            parallel::configured_threads(),
            self.points.len(),
            per_share_ops * self.points.len(),
        );
        Ok(parallel::par_map(&self.points, threads, |i, _alpha| {
            let tables = plan.point(i);
            Share {
                a: Self::eval_sparse_tables(ring, &a_blocks, &a_exps, tables),
                b: Self::eval_sparse_tables(ring, &b_blocks, &b_exps, tables),
            }
        }))
    }

    /// Encode only the A-side halves — one `f(α_i)` per worker,
    /// bit-identical to the [`Share::a`] halves [`EpCode::encode_planes`]
    /// produces for the same `a` (the evaluation of `f` never reads `B`).
    pub fn encode_planes_left(
        &self,
        a: &PlaneMatrix<E::Base>,
    ) -> anyhow::Result<Vec<PlaneMatrix<E::Base>>> {
        let Partition { u, w, .. } = self.part;
        let m = self.ring.plane_count();
        anyhow::ensure!(a.planes == m, "share matrix must have {m} planes");
        anyhow::ensure!(a.rows % u == 0, "u = {u} must divide t = {}", a.rows);
        anyhow::ensure!(a.cols % w == 0, "w = {w} must divide r = {}", a.cols);
        self.left_encodes.fetch_add(1, Ordering::Relaxed);
        let a_blocks = a.partition_grid(u, w);
        let a_exps = self.a_exponents();
        let ring = &self.ring;
        let plan = &self.encode_plan;
        let per_share_ops = a_blocks[0].data.len() * a_blocks.len() * m;
        let threads = parallel::effective_threads(
            parallel::configured_threads(),
            self.points.len(),
            per_share_ops * self.points.len(),
        );
        Ok(parallel::par_map(&self.points, threads, |i, _alpha| {
            Self::eval_sparse_tables(ring, &a_blocks, &a_exps, plan.point(i))
        }))
    }

    /// Encode only the B-side halves — one `g(α_i)` per worker,
    /// bit-identical to the [`Share::b`] halves of the joint encode.
    pub fn encode_planes_right(
        &self,
        b: &PlaneMatrix<E::Base>,
    ) -> anyhow::Result<Vec<PlaneMatrix<E::Base>>> {
        let Partition { w, v, .. } = self.part;
        let m = self.ring.plane_count();
        anyhow::ensure!(b.planes == m, "share matrix must have {m} planes");
        anyhow::ensure!(b.rows % w == 0, "w = {w} must divide r = {}", b.rows);
        anyhow::ensure!(b.cols % v == 0, "v = {v} must divide s = {}", b.cols);
        let b_blocks = b.partition_grid(w, v);
        let b_exps = self.b_exponents();
        let ring = &self.ring;
        let plan = &self.encode_plan;
        let per_share_ops = b_blocks[0].data.len() * b_blocks.len() * m;
        let threads = parallel::effective_threads(
            parallel::configured_threads(),
            self.points.len(),
            per_share_ops * self.points.len(),
        );
        Ok(parallel::par_map(&self.points, threads, |i, _alpha| {
            Self::eval_sparse_tables(ring, &b_blocks, &b_exps, plan.point(i))
        }))
    }

    /// Decode a plane-major share-ring product from any `R` responses.
    pub fn decode_planes(
        &self,
        responses: &[Response<E>],
        t: usize,
        s: usize,
    ) -> anyhow::Result<PlaneMatrix<E::Base>> {
        let ring = &self.ring;
        let r_needed = self.part.recovery_threshold();
        anyhow::ensure!(
            responses.len() >= r_needed,
            "{} responses < recovery threshold {r_needed}",
            responses.len()
        );
        let used = &responses[..r_needed];
        let Partition { u, v, .. } = self.part;
        let (bh, bw) = (t / u, s / self.part.v);
        let m = ring.plane_count();
        let mut seen = vec![false; self.n_workers];
        for (idx, y) in used {
            anyhow::ensure!(*idx < self.n_workers, "worker index {idx} out of range");
            anyhow::ensure!(!seen[*idx], "duplicate response from worker {idx}");
            seen[*idx] = true;
            anyhow::ensure!(
                y.rows == bh && y.cols == bw && y.planes == m,
                "response from worker {idx} has shape {}x{} ({} planes), expected {bh}x{bw} ({m})",
                y.rows,
                y.cols,
                y.planes
            );
        }
        // Lagrange basis on the responding subset: L_j has R coefficients;
        // coefficient k of h equals Σ_j L_j[k] · Y_j. The basis (and the
        // weight tables derived from it) is a pure function of the subset,
        // so the whole decode plan is cached keyed by the sorted worker
        // ids; rank of a worker in the sorted key indexes its tables,
        // whatever the arrival order.
        let mut sorted: Vec<usize> = used.iter().map(|(i, _)| *i).collect();
        sorted.sort_unstable();
        let c_exps = self.c_exponents();
        let plan = self.plan_cache.get_or_compute(&sorted, || {
            let pts: Vec<E::Elem> = sorted.iter().map(|&i| self.points[i].clone()).collect();
            LagrangeDecodePlan::build(ring, &pts, &c_exps)
        });
        // The uv output blocks are independent weighted sums — parallel
        // over blocks, table-driven (warm decodes build zero tables). Gate
        // on total work so tiny decodes stay sequential.
        let base = ring.plane_base();
        let per_block_ops = r_needed * bh * bw * m * m;
        let threads = parallel::effective_threads(
            parallel::configured_threads(),
            c_exps.len(),
            per_block_ops * c_exps.len(),
        );
        let c_blocks: Vec<PlaneMatrix<E::Base>> = parallel::par_map(&c_exps, threads, |ci, _k| {
            let mut acc = PlaneMatrix::zeros(ring, bh, bw);
            for (idx, y) in used {
                let j = sorted.binary_search(idx).expect("idx is in its own sorted subset");
                acc.axpy_with_table(base, plan.table(j, ci), y);
            }
            acc
        });
        Ok(PlaneMatrix::stitch_grid(&c_blocks, u, v))
    }

    /// Consistency-check surplus responses by **re-encode-and-compare**:
    /// interpolate *all* `R` coefficients of `h` from the first `R`
    /// responses (not just the `uv` product coefficients decode reads),
    /// evaluate `h` at each surplus worker's evaluation point with the
    /// lazily-built verify plan, and flag every surplus response that
    /// disagrees with its re-encoding. Empty flags mean the whole response
    /// set lies on one degree-`R−1` codeword — the overdetermined-decode
    /// consistency guarantee. One interpolation plus a cheap sparse-style
    /// evaluation per surplus share, instead of the default's full decode
    /// per surplus response.
    pub fn check_surplus_planes(
        &self,
        responses: &[Response<E>],
    ) -> anyhow::Result<Vec<usize>> {
        let ring = &self.ring;
        let r_needed = self.part.recovery_threshold();
        anyhow::ensure!(
            responses.len() > r_needed,
            "no surplus to check: {} responses for threshold {r_needed}",
            responses.len()
        );
        let used = &responses[..r_needed];
        let (bh, bw, m) = (used[0].1.rows, used[0].1.cols, ring.plane_count());
        let mut seen = vec![false; self.n_workers];
        for (idx, y) in responses {
            anyhow::ensure!(*idx < self.n_workers, "worker index {idx} out of range");
            anyhow::ensure!(!seen[*idx], "duplicate response from worker {idx}");
            seen[*idx] = true;
            anyhow::ensure!(
                y.rows == bh && y.cols == bw && y.planes == m,
                "response from worker {idx} has shape {}x{} ({} planes), expected {bh}x{bw} ({m})",
                y.rows,
                y.cols,
                y.planes
            );
        }
        // Interpolate every coefficient of h on the first R responses. The
        // exponent set (0..R) differs from the decode plan's c_exponents,
        // so this plan is built fresh rather than borrowed from the cache.
        let pts: Vec<E::Elem> = used.iter().map(|(i, _)| self.points[*i].clone()).collect();
        let all_exps: Vec<usize> = (0..r_needed).collect();
        let plan = LagrangeDecodePlan::build(ring, &pts, &all_exps);
        let base = ring.plane_base();
        let coeffs: Vec<PlaneMatrix<E::Base>> = (0..r_needed)
            .map(|k| {
                let mut acc = PlaneMatrix::zeros(ring, bh, bw);
                for (j, (_, y)) in used.iter().enumerate() {
                    acc.axpy_with_table(base, plan.table(j, k), y);
                }
                acc
            })
            .collect();
        // Re-encode h at each surplus point and compare bit-for-bit.
        let tables = self
            .verify_plan
            .get_or_init(|| PowerTables::build(ring, &self.points, r_needed - 1));
        let mut flagged = Vec::new();
        for (idx, y) in &responses[r_needed..] {
            let expected =
                Self::eval_sparse_tables(ring, &coeffs, &all_exps, tables.point(*idx));
            if expected != *y {
                flagged.push(*idx);
            }
        }
        Ok(flagged)
    }

    /// Per-worker byte size of the A-side share half (`f(α_i)`, serialized).
    pub fn a_share_bytes(&self, t: usize, r: usize) -> usize {
        let Partition { u, w, .. } = self.part;
        16 + (t / u) * (r / w) * self.ring.elem_bytes()
    }

    /// Per-worker byte size of the B-side share half (`g(α_i)`, serialized).
    pub fn b_share_bytes(&self, r: usize, s: usize) -> usize {
        let Partition { w, v, .. } = self.part;
        16 + (r / w) * (s / v) * self.ring.elem_bytes()
    }

    /// Per-worker share byte size for `A: t×r`, `B: r×s`.
    pub fn share_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.a_share_bytes(t, r) + self.b_share_bytes(r, s)
    }

    /// Per-worker response byte size.
    pub fn response_bytes(&self, t: usize, s: usize) -> usize {
        let Partition { u, v, .. } = self.part;
        16 + (t / u) * (s / v) * self.ring.elem_bytes()
    }
}

impl<E: PlaneRing> DmmScheme<E> for EpCode<E> {
    type ShareRing = E;

    fn name(&self) -> String {
        format!(
            "EP(u={},w={},v={}) over {}",
            self.part.u,
            self.part.w,
            self.part.v,
            self.ring.name()
        )
    }
    fn share_ring(&self) -> &E {
        &self.ring
    }
    fn input_ring(&self) -> &E {
        &self.ring
    }
    fn n_workers(&self) -> usize {
        self.n_workers
    }
    fn recovery_threshold(&self) -> usize {
        self.part.recovery_threshold()
    }

    fn encode_batch(
        &self,
        a: &[Matrix<E::Elem>],
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<Share<E>>> {
        anyhow::ensure!(a.len() == 1 && b.len() == 1, "EP is a single-product scheme");
        let ap = PlaneMatrix::from_aos(&self.ring, &a[0]);
        let bp = PlaneMatrix::from_aos(&self.ring, &b[0]);
        self.encode_planes(&ap, &bp)
    }

    fn encode_left_batch(
        &self,
        a: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<E::Base>>> {
        anyhow::ensure!(a.len() == 1, "EP is a single-product scheme");
        self.encode_planes_left(&PlaneMatrix::from_aos(&self.ring, &a[0]))
    }

    fn encode_right_batch(
        &self,
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<E::Base>>> {
        anyhow::ensure!(b.len() == 1, "EP is a single-product scheme");
        self.encode_planes_right(&PlaneMatrix::from_aos(&self.ring, &b[0]))
    }

    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        Some((
            self.n_workers * self.a_share_bytes(t, r),
            self.n_workers * self.b_share_bytes(r, s),
        ))
    }

    fn left_encodes(&self) -> u64 {
        self.left_encode_count()
    }

    fn decode_batch(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<Matrix<E::Elem>>> {
        anyhow::ensure!(!responses.is_empty(), "no responses");
        let Partition { u, v, .. } = self.part;
        let (bh, bw) = (responses[0].1.rows, responses[0].1.cols);
        let c = self.decode_planes(responses, bh * u, bw * v)?;
        Ok(vec![c.to_aos(&self.ring)])
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.n_workers * self.share_bytes(t, r, s)
    }

    fn download_bytes(&self, t: usize, _r: usize, s: usize) -> usize {
        self.recovery_threshold() * self.response_bytes(t, s)
    }

    fn plan_cache_stats(&self) -> (u64, u64) {
        self.plan_cache.stats()
    }

    fn check_surplus(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<usize>> {
        self.check_surplus_planes(responses)
    }
}

/// The **plain CDMM baseline** of Lemma III.1 ("EP" in Figures 2–5): inputs
/// in a small ring `R` are constant-embedded into `GR_m = Extension<R>` with
/// `p^{dm} ≥ N`, and EP codes run over `GR_m`. Every uploaded/downloaded
/// element costs `m` base elements and every worker multiplication costs
/// `O(m²)` base ops — the overhead the RMFE schemes amortize.
///
/// The embedding itself is plane-native: plane 0 of the encoded input *is*
/// the user matrix, higher planes are zero, and decoding reads plane 0 back
/// — no AoS round trip anywhere.
#[derive(Clone)]
pub struct PlainEp<R: ExtensibleRing> {
    base: R,
    ep: EpCode<Extension<R>>,
}

impl<R: ExtensibleRing> PlainEp<R> {
    /// `m` is chosen minimal with `p^{dm} ≥ N` (the paper's
    /// `m = ⌈(log_p N)/d⌉`).
    pub fn new(base: R, n_workers: usize, u: usize, w: usize, v: usize) -> anyhow::Result<Self> {
        let ext = Extension::with_capacity(base.clone(), n_workers);
        let ep = EpCode::new(ext, n_workers, u, w, v)?;
        Ok(PlainEp { base, ep })
    }

    /// Override the extension degree (e.g. to match another scheme's ring
    /// for an apples-to-apples comparison).
    pub fn with_m(
        base: R,
        m: usize,
        n_workers: usize,
        u: usize,
        w: usize,
        v: usize,
    ) -> anyhow::Result<Self> {
        let ext = Extension::new(base.clone(), m);
        let ep = EpCode::new(ext, n_workers, u, w, v)?;
        Ok(PlainEp { base, ep })
    }

    pub fn ep(&self) -> &EpCode<Extension<R>> {
        &self.ep
    }

    pub fn m(&self) -> usize {
        self.ep.ring.m()
    }
}

impl<R: ExtensibleRing> DmmScheme<R> for PlainEp<R> {
    type ShareRing = Extension<R>;

    fn name(&self) -> String {
        format!("PlainEP(m={}) [{}]", self.m(), self.ep.name())
    }
    fn share_ring(&self) -> &Extension<R> {
        &self.ep.ring
    }
    fn input_ring(&self) -> &R {
        &self.base
    }
    fn n_workers(&self) -> usize {
        self.ep.n_workers
    }
    fn recovery_threshold(&self) -> usize {
        self.ep.part.recovery_threshold()
    }

    fn encode_batch(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<Share<Extension<R>>>> {
        anyhow::ensure!(a.len() == 1 && b.len() == 1, "PlainEP is a single-product scheme");
        let ext = &self.ep.ring;
        let ae = PlaneMatrix::from_base_matrix(ext, &a[0]);
        let be = PlaneMatrix::from_base_matrix(ext, &b[0]);
        self.ep.encode_planes(&ae, &be)
    }

    fn encode_left_batch(
        &self,
        a: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(a.len() == 1, "PlainEP is a single-product scheme");
        let ae = PlaneMatrix::from_base_matrix(&self.ep.ring, &a[0]);
        self.ep.encode_planes_left(&ae)
    }

    fn encode_right_batch(
        &self,
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(b.len() == 1, "PlainEP is a single-product scheme");
        let be = PlaneMatrix::from_base_matrix(&self.ep.ring, &b[0]);
        self.ep.encode_planes_right(&be)
    }

    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        Some((
            self.ep.n_workers * self.ep.a_share_bytes(t, r),
            self.ep.n_workers * self.ep.b_share_bytes(r, s),
        ))
    }

    fn left_encodes(&self) -> u64 {
        self.ep.left_encode_count()
    }

    fn decode_batch(
        &self,
        responses: &[Response<Extension<R>>],
    ) -> anyhow::Result<Vec<Matrix<R::Elem>>> {
        anyhow::ensure!(!responses.is_empty(), "no responses");
        let Partition { u, v, .. } = self.ep.part;
        let (bh, bw) = (responses[0].1.rows, responses[0].1.cols);
        let ce = self.ep.decode_planes(responses, bh * u, bw * v)?;
        // Constant-embedded inputs have constant products: read plane 0.
        Ok(vec![ce.base_plane_matrix()])
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.ep.n_workers * self.ep.share_bytes(t, r, s)
    }

    fn download_bytes(&self, t: usize, _r: usize, s: usize) -> usize {
        self.recovery_threshold() * self.ep.response_bytes(t, s)
    }

    fn plan_cache_stats(&self) -> (u64, u64) {
        self.ep.plan_cache.stats()
    }

    fn check_surplus(
        &self,
        responses: &[Response<Extension<R>>],
    ) -> anyhow::Result<Vec<usize>> {
        self.ep.check_surplus_planes(responses)
    }

    fn verify_products(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
        c: &[Matrix<R::Elem>],
        trials: usize,
        rng: &mut Rng64,
    ) -> anyhow::Result<bool> {
        anyhow::ensure!(
            a.len() == b.len() && b.len() == c.len(),
            "batch slots disagree: {} a, {} b, {} c",
            a.len(),
            b.len(),
            c.len()
        );
        // Lift the check into the extension: its exceptional set has p^{dm}
        // points versus the base ring's p^d, shrinking the per-trial error
        // accordingly (constant embedding is a ring homomorphism, so
        // a·b = c in the base ⟺ in the extension).
        let ext = &self.ep.ring;
        for ((ak, bk), ck) in a.iter().zip(b).zip(c) {
            let ae = PlaneMatrix::from_base_matrix(ext, ak).to_aos(ext);
            let be = PlaneMatrix::from_base_matrix(ext, bk).to_aos(ext);
            let ce = PlaneMatrix::from_base_matrix(ext, ck).to_aos(ext);
            if !freivalds_check(ext, &ae, &be, &ce, trials, rng)? {
                return Ok(false);
            }
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn ext_ring(m: usize) -> Extension<Zq> {
        Extension::new(Zq::z2e(64), m)
    }

    /// Run an EP code end-to-end over the extension ring and check the
    /// product, using the *last* R workers (not the first) to exercise
    /// subset-independence.
    fn roundtrip(ep: &EpCode<Extension<Zq>>, t: usize, r: usize, s: usize, seed: u64) {
        let ring = ep.share_ring().clone();
        let mut rng = Rng64::seeded(seed);
        let a = Matrix::random(&ring, t, r, &mut rng);
        let b = Matrix::random(&ring, r, s, &mut rng);
        let shares = ep
            .encode_planes(
                &PlaneMatrix::from_aos(&ring, &a),
                &PlaneMatrix::from_aos(&ring, &b),
            )
            .unwrap();
        assert_eq!(shares.len(), ep.n_workers());
        let rt = ep.recovery_threshold();
        let responses: Vec<_> = (ep.n_workers() - rt..ep.n_workers())
            .map(|i| (i, ep.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = ep.decode_planes(&responses, t, s).unwrap();
        assert_eq!(c.to_aos(&ring), Matrix::matmul(&ring, &a, &b));
    }

    #[test]
    fn ep_paper_8_worker_config() {
        // u=v=2, w=1, N=8 over GR(2^64,3): R=4 (§V.A).
        let ep = EpCode::new(ext_ring(3), 8, 2, 1, 2).unwrap();
        assert_eq!(ep.recovery_threshold(), 4);
        roundtrip(&ep, 4, 2, 4, 101);
    }

    #[test]
    fn ep_paper_16_worker_config() {
        // u=v=w=2, N=16 over GR(2^64,4): R=9 (§V.A).
        let ep = EpCode::new(ext_ring(4), 16, 2, 2, 2).unwrap();
        assert_eq!(ep.recovery_threshold(), 9);
        roundtrip(&ep, 4, 4, 4, 102);
    }

    #[test]
    fn ep_rectangular_shapes() {
        // u=3, w=2, v=2 ⇒ R = 13; N = 14 workers over GR(2^64, 4).
        let ep = EpCode::new(ext_ring(4), 14, 3, 2, 2).unwrap();
        assert_eq!(ep.recovery_threshold(), 13);
        roundtrip(&ep, 6, 4, 2, 108);
    }

    #[test]
    fn ep_rejects_r_above_n() {
        assert!(EpCode::new(ext_ring(4), 12, 3, 2, 2).is_err()); // R=13 > N=12
    }

    #[test]
    fn ep_various_partitions() {
        let shapes =
            [(1, 1, 1, 1), (2, 1, 1, 3), (1, 3, 1, 8), (2, 2, 1, 6), (1, 1, 4, 4), (2, 2, 2, 11)];
        for (u, w, v, n) in shapes {
            let ep = EpCode::new(ext_ring(4), n, u, w, v).unwrap();
            roundtrip(&ep, u * 2, w * 3, v * 2, 200 + (u * 100 + w * 10 + v) as u64);
        }
    }

    #[test]
    fn ep_exponent_layout_no_collisions() {
        let ep = EpCode::new(ext_ring(4), 16, 2, 2, 2).unwrap();
        // a and b exponent sets must each be collision-free
        let mut ae = ep.a_exponents();
        ae.sort_unstable();
        ae.dedup();
        assert_eq!(ae.len(), 4);
        let mut be = ep.b_exponents();
        be.sort_unstable();
        be.dedup();
        assert_eq!(be.len(), 4);
        // c exponents must be within h's degree bound
        let rt = ep.recovery_threshold();
        for &k in &ep.c_exponents() {
            assert!(k < rt, "c exponent {k} >= R {rt}");
        }
    }

    #[test]
    fn ep_decode_uses_any_subset() {
        let ep = EpCode::new(ext_ring(3), 8, 2, 1, 2).unwrap();
        let ring = ep.share_ring().clone();
        let mut rng = Rng64::seeded(103);
        let a = Matrix::random(&ring, 2, 2, &mut rng);
        let b = Matrix::random(&ring, 2, 2, &mut rng);
        let expected = PlaneMatrix::from_aos(&ring, &Matrix::matmul(&ring, &a, &b));
        let shares = ep.encode(&a, &b).unwrap();
        let all: Vec<_> = shares
            .iter()
            .enumerate()
            .map(|(i, s)| (i, ep.worker_compute(s).unwrap()))
            .collect();
        // every contiguous window of R workers decodes correctly
        for start in 0..=(8 - 4) {
            let c = ep.decode_planes(&all[start..start + 4], 2, 2).unwrap();
            assert_eq!(c, expected, "window at {start}");
        }
        // a scattered subset too
        let scattered: Vec<_> = [0usize, 2, 5, 7].iter().map(|&i| all[i].clone()).collect();
        assert_eq!(ep.decode_planes(&scattered, 2, 2).unwrap(), expected);
    }

    #[test]
    fn decode_plan_cache_hits_on_recurring_subset_any_arrival_order() {
        let ep = EpCode::new(ext_ring(3), 8, 2, 1, 2).unwrap();
        let ring = ep.share_ring().clone();
        let mut rng = Rng64::seeded(109);
        let a = Matrix::random(&ring, 2, 2, &mut rng);
        let b = Matrix::random(&ring, 2, 2, &mut rng);
        let expected = PlaneMatrix::from_aos(&ring, &Matrix::matmul(&ring, &a, &b));
        let shares = ep.encode(&a, &b).unwrap();
        let all: Vec<_> = shares
            .iter()
            .enumerate()
            .map(|(i, s)| (i, ep.worker_compute(s).unwrap()))
            .collect();
        // same subset {1,3,4,6} in two arrival orders: one plan, two hits
        let first: Vec<_> = [1usize, 3, 4, 6].iter().map(|&i| all[i].clone()).collect();
        let second: Vec<_> = [6usize, 1, 4, 3].iter().map(|&i| all[i].clone()).collect();
        assert_eq!(ep.decode_planes(&first, 2, 2).unwrap(), expected);
        assert_eq!(ep.plan_cache_stats(), (0, 1));
        assert_eq!(ep.decode_planes(&second, 2, 2).unwrap(), expected);
        assert_eq!(ep.decode_planes(&first, 2, 2).unwrap(), expected);
        assert_eq!(ep.plan_cache_stats(), (2, 1));
        // a different subset is a fresh plan
        let other: Vec<_> = [0usize, 2, 5, 7].iter().map(|&i| all[i].clone()).collect();
        assert_eq!(ep.decode_planes(&other, 2, 2).unwrap(), expected);
        assert_eq!(ep.plan_cache_stats(), (2, 2));
        assert_eq!(ep.plan_cache().len(), 2);
    }

    #[test]
    fn ep_insufficient_responses_fails() {
        let ep = EpCode::new(ext_ring(3), 8, 2, 1, 2).unwrap();
        let ring = ep.share_ring().clone();
        let mut rng = Rng64::seeded(104);
        let a = Matrix::random(&ring, 2, 2, &mut rng);
        let b = Matrix::random(&ring, 2, 2, &mut rng);
        let shares = ep.encode(&a, &b).unwrap();
        let responses: Vec<_> = (0..3)
            .map(|i| (i, ep.worker_compute(&shares[i]).unwrap()))
            .collect();
        assert!(ep.decode_planes(&responses, 2, 2).is_err());
    }

    #[test]
    fn plain_ep_over_z2e64() {
        // Inputs in Z_2^64, N=8 ⇒ m=3 extension chosen automatically.
        let base = Zq::z2e(64);
        let plain = PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap();
        assert_eq!(plain.m(), 3);
        let mut rng = Rng64::seeded(105);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let shares = plain.encode(&a, &b).unwrap();
        let responses: Vec<_> = shares
            .iter()
            .enumerate()
            .take(plain.recovery_threshold())
            .map(|(i, s)| (i, plain.worker_compute(s).unwrap()))
            .collect();
        let c = plain.decode(&responses).unwrap();
        assert_eq!(c, Matrix::matmul(&base, &a, &b));
    }

    #[test]
    fn plain_ep_comm_accounting_matches_wire() {
        let base = Zq::z2e(64);
        let plain = PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap();
        let (t, r, s) = (4usize, 4, 4);
        let mut rng = Rng64::seeded(106);
        let a = Matrix::random(&base, t, r, &mut rng);
        let b = Matrix::random(&base, r, s, &mut rng);
        let shares = plain.encode(&a, &b).unwrap();
        let ring = plain.share_ring();
        let wire: usize = shares.iter().map(|s| s.byte_len(ring)).sum();
        assert_eq!(wire, plain.upload_bytes(t, r, s));
        let resp = plain.worker_compute(&shares[0]).unwrap();
        assert_eq!(
            resp.byte_len(ring) * plain.recovery_threshold(),
            plain.download_bytes(t, r, s)
        );
    }

    #[test]
    fn split_encode_matches_joint_halves_bytes_and_counter() {
        let ep = EpCode::new(ext_ring(3), 8, 2, 1, 2).unwrap();
        let ring = ep.share_ring().clone();
        let mut rng = Rng64::seeded(111);
        let a = Matrix::random(&ring, 4, 2, &mut rng);
        let b = Matrix::random(&ring, 2, 4, &mut rng);
        let ap = PlaneMatrix::from_aos(&ring, &a);
        let bp = PlaneMatrix::from_aos(&ring, &b);
        assert_eq!(ep.left_encode_count(), 0);
        let joint = ep.encode_planes(&ap, &bp).unwrap();
        assert_eq!(ep.left_encode_count(), 1, "joint encode counts as an A-encode");
        let left = ep.encode_planes_left(&ap).unwrap();
        let right = ep.encode_planes_right(&bp).unwrap();
        assert_eq!(ep.left_encode_count(), 2, "right-only encode must not count");
        for (i, s) in joint.iter().enumerate() {
            assert_eq!(left[i], s.a, "worker {i} a-half");
            assert_eq!(right[i], s.b, "worker {i} b-half");
        }
        // staged A-bytes ++ per-job B-bytes reassemble the full share
        // payload byte for byte — the property worker-side staging relies
        // on.
        let mut stitched = left[0].to_bytes(&ring);
        stitched.extend_from_slice(&right[0].to_bytes(&ring));
        assert_eq!(stitched, joint[0].to_bytes(&ring));
        // analytic split accounting matches both the wire and the joint sum
        let (sa, sb) = DmmScheme::split_upload_bytes(&ep, 4, 2, 4).unwrap();
        assert_eq!(sa + sb, ep.upload_bytes(4, 2, 4));
        assert_eq!(sa, 8 * left[0].to_bytes(&ring).len());
        assert_eq!(sb, 8 * right[0].to_bytes(&ring).len());
    }

    #[test]
    fn plain_ep_split_encode_matches_joint() {
        let base = Zq::z2e(64);
        let plain = PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap();
        let mut rng = Rng64::seeded(112);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let joint = plain.encode(&a, &b).unwrap();
        let left = plain.encode_left(&a).unwrap();
        let right = plain.encode_right(&b).unwrap();
        for (i, s) in joint.iter().enumerate() {
            assert_eq!(left[i], s.a, "worker {i} a-half");
            assert_eq!(right[i], s.b, "worker {i} b-half");
        }
        let (sa, sb) = DmmScheme::split_upload_bytes(&plain, 4, 4, 4).unwrap();
        assert_eq!(sa + sb, plain.upload_bytes(4, 4, 4));
        assert_eq!(DmmScheme::left_encodes(&plain), 2);
    }

    #[test]
    fn surplus_check_accepts_clean_responses_and_flags_corrupt_ones() {
        let ep = EpCode::new(ext_ring(3), 8, 2, 1, 2).unwrap();
        let ring = ep.share_ring().clone();
        let mut rng = Rng64::seeded(113);
        let a = Matrix::random(&ring, 2, 2, &mut rng);
        let b = Matrix::random(&ring, 2, 2, &mut rng);
        let shares = ep.encode(&a, &b).unwrap();
        let all: Vec<_> = shares
            .iter()
            .enumerate()
            .map(|(i, s)| (i, ep.worker_compute(s).unwrap()))
            .collect();
        // Clean run: all 8 responses (4 surplus) lie on one codeword.
        assert_eq!(ep.check_surplus_planes(&all).unwrap(), Vec::<usize>::new());

        // A corrupted *surplus* response is flagged by worker id, and the
        // honest surplus responses are not.
        let mut tampered = all.clone();
        tampered[6].1.data[0] = tampered[6].1.data[0].wrapping_add(1);
        assert_eq!(ep.check_surplus_planes(&tampered).unwrap(), vec![6]);

        // A corrupted response inside the first R poisons the
        // interpolation: the check cannot name the culprit but must not
        // come back clean (leave-one-out isolation takes over from here).
        let mut poisoned = all.clone();
        poisoned[1].1.data[0] = poisoned[1].1.data[0].wrapping_add(1);
        assert!(!ep.check_surplus_planes(&poisoned).unwrap().is_empty());

        // No surplus at all is a usage error, not a silent pass.
        assert!(ep.check_surplus_planes(&all[..4]).is_err());

        // The trait hook routes to the same specialization.
        assert_eq!(ep.check_surplus(&tampered).unwrap(), vec![6]);
    }

    #[test]
    fn plain_ep_freivalds_accepts_the_product_and_rejects_a_forgery() {
        let base = Zq::z2e(64);
        let plain = PlainEp::new(base.clone(), 8, 2, 1, 2).unwrap();
        let mut rng = Rng64::seeded(114);
        let a = Matrix::random(&base, 4, 4, &mut rng);
        let b = Matrix::random(&base, 4, 4, &mut rng);
        let c = Matrix::matmul(&base, &a, &b);
        let mut check_rng = Rng64::seeded(42);
        assert!(plain
            .verify_products(
                std::slice::from_ref(&a),
                std::slice::from_ref(&b),
                std::slice::from_ref(&c),
                10,
                &mut check_rng
            )
            .unwrap());
        let mut wrong = c.clone();
        wrong.data[0] = base.add(&wrong.data[0], &base.one());
        assert!(!plain
            .verify_products(
                std::slice::from_ref(&a),
                std::slice::from_ref(&b),
                std::slice::from_ref(&wrong),
                40,
                &mut check_rng
            )
            .unwrap());
    }

    #[test]
    fn share_serialization_roundtrip() {
        let ring = ext_ring(3);
        let mut rng = Rng64::seeded(107);
        let share: Share<Extension<Zq>> = Share {
            a: PlaneMatrix::random(&ring, 2, 3, &mut rng),
            b: PlaneMatrix::random(&ring, 3, 2, &mut rng),
        };
        let bytes = share.to_bytes(&ring);
        assert_eq!(bytes.len(), share.byte_len(&ring));
        assert_eq!(Share::from_bytes(&ring, &bytes).unwrap(), share);
        // truncated and oversized payloads are clean errors
        assert!(Share::<Extension<Zq>>::from_bytes(&ring, &bytes[..bytes.len() - 3]).is_err());
        let mut big = bytes;
        big.extend_from_slice(&[0, 0, 0]);
        assert!(Share::<Extension<Zq>>::from_bytes(&ring, &big).is_err());
    }
}
