//! CSA (Cross-Subspace Alignment) batch codes ([4]) — the runnable baseline
//! for Table 1. This is the `uvw = 1, κ = n` point of the GCSA family, with
//! recovery threshold `R = 2n − 1` (`= uvw(n + κ − 1) + w − 1` at that
//! point); the remaining (analytic) GCSA rows of Table 1 are produced by
//! `experiments::table1`.
//!
//! Construction. Pick `n + N` exceptional points: poles `f_1, …, f_n` and
//! evaluation points `α_1, …, α_N`. With `Δ(α) = Π_l (f_l − α)`:
//!
//! ```text
//! Ã_i = Σ_l ν_l(α_i)·A_l          where ν_l(α) = Δ(α)/(f_l − α) = Π_{k≠l}(f_k − α)
//! B̃_i = Σ_l (f_l − α_i)^{-1}·B_l
//! ```
//!
//! Worker `i` returns `Z_i = Ã_i·B̃_i`. Partial fractions give
//!
//! ```text
//! Z_i = Σ_l c_l·A_l B_l / (f_l − α_i)  +  P(α_i),   c_l = ν_l(f_l) = Π_{k≠l}(f_k − f_l)
//! ```
//!
//! with `deg P ≤ n − 2`: the diagonal terms contribute the Cauchy part (and
//! a polynomial of degree `n−2`), the cross terms (`l ≠ l'`) only
//! polynomials of degree `n−2` — the "cross-subspace alignment". That is
//! `2n − 1` unknown matrices; the master inverts the Cauchy–Vandermonde
//! system on any `R = 2n − 1` responding workers (all pivots are units by
//! exceptionality) and recovers `A_l B_l = c_l^{-1} X_l`.
//!
//! All matrix traffic (shares, responses, encode/decode accumulators) is
//! plane-major ([`PlaneMatrix`]); only the `R × R` scalar Cauchy–Vandermonde
//! system stays in the AoS [`Matrix`] (it is `O(R²)` scalars, never on the
//! wire). Its inverse is a pure function of the responding worker subset and
//! is memoised in a sorted-subset-keyed [`PlanCache`] — recurring fast-`R`
//! subsets skip the `O(R³)` Gauss–Jordan entirely.

use super::plan_cache::{PlanCache, DEFAULT_PLAN_CACHE_CAP};
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::matrix::Matrix;
use crate::ring::plane::{PlaneMatrix, PlaneRing, ScalarTable};
use crate::ring::traits::Ring;
use crate::util::parallel;
use std::sync::Arc;

/// The CSA encode plan: the scalar-mul tables of every encode coefficient,
/// which are fixed at construction — `ν_l(α_i)` for the `A`-side and
/// `(f_l − α_i)^{-1}` for the `B`-side, per (worker, batch slot).
struct CsaEncodePlan<B: Ring> {
    /// `nu[i][l]`: table of `ν_l(α_i) = Π_{k≠l}(f_k − α_i)`.
    nu: Vec<Vec<ScalarTable<B>>>,
    /// `binv[i][l]`: table of `(f_l − α_i)^{-1}`.
    binv: Vec<Vec<ScalarTable<B>>>,
}

/// The cached CSA decode plan for one sorted responding subset: the weight
/// tables of the first `n` rows of the Cauchy–Vandermonde inverse (the
/// rows that carry `c_l·A_lB_l`; the remaining `n−1` unknowns are the
/// cross-term polynomial and are never materialized).
struct CsaDecodePlan<B: Ring> {
    /// `tables[l][col]`: table of `inv[l][col]`, `l < n`, `col < R`.
    tables: Vec<Vec<ScalarTable<B>>>,
}

/// CSA batch code over a ring `E` with at least `n + N` exceptional points.
#[derive(Clone)]
pub struct CsaCode<E: PlaneRing> {
    ring: E,
    n_batch: usize,
    n_workers: usize,
    /// Poles `f_1..f_n`.
    poles: Vec<E::Elem>,
    /// Evaluation points `α_1..α_N`.
    alphas: Vec<E::Elem>,
    /// Encode tables (fixed at construction); `Arc` so clones share them.
    encode_plan: Arc<CsaEncodePlan<E::Base>>,
    /// `c_l^{-1}` scale tables for the decode post-scale (also fixed).
    c_inv_tables: Arc<Vec<ScalarTable<E::Base>>>,
    /// Decode plan (weight tables of the Cauchy–Vandermonde inverse) per
    /// sorted responding subset; `Arc` so clones share a warm cache.
    plan_cache: Arc<PlanCache<CsaDecodePlan<E::Base>>>,
}

impl<E: PlaneRing> CsaCode<E> {
    pub fn new(ring: E, n_workers: usize, n_batch: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(n_batch >= 1);
        let r = 2 * n_batch - 1;
        anyhow::ensure!(
            r <= n_workers,
            "recovery threshold R = {r} exceeds worker count N = {n_workers}"
        );
        let pts = ring.exceptional_points(n_batch + n_workers)?;
        let poles = pts[..n_batch].to_vec();
        let alphas = pts[n_batch..].to_vec();
        let mut c = Vec::with_capacity(n_batch);
        for l in 0..n_batch {
            let mut prod = ring.one();
            for k in 0..n_batch {
                if k != l {
                    prod = ring.mul(&prod, &ring.sub(&poles[k], &poles[l]));
                }
            }
            c.push(prod);
        }
        // Encode plan: every encode scalar is a pure function of the fixed
        // poles and evaluation points — build all tables once, here.
        let mut nu_tables = Vec::with_capacity(alphas.len());
        let mut binv_tables = Vec::with_capacity(alphas.len());
        for alpha in &alphas {
            let diffs: Vec<E::Elem> = poles.iter().map(|f| ring.sub(f, alpha)).collect();
            let mut nu_row = Vec::with_capacity(n_batch);
            let mut bi_row = Vec::with_capacity(n_batch);
            for l in 0..n_batch {
                let mut nu = ring.one();
                for (k, d) in diffs.iter().enumerate() {
                    if k != l {
                        nu = ring.mul(&nu, d);
                    }
                }
                nu_row.push(ScalarTable::build(&ring, &nu));
                let inv = ring.inv(&diffs[l]).expect("poles and alphas are exceptional");
                bi_row.push(ScalarTable::build(&ring, &inv));
            }
            nu_tables.push(nu_row);
            binv_tables.push(bi_row);
        }
        let c_inv_tables = c
            .iter()
            .map(|cl| {
                let cinv = ring.inv(cl).expect("c_l is a unit");
                ScalarTable::build(&ring, &cinv)
            })
            .collect();
        Ok(CsaCode {
            ring,
            n_batch,
            n_workers,
            poles,
            alphas,
            encode_plan: Arc::new(CsaEncodePlan { nu: nu_tables, binv: binv_tables }),
            c_inv_tables: Arc::new(c_inv_tables),
            plan_cache: Arc::new(PlanCache::new(DEFAULT_PLAN_CACHE_CAP)),
        })
    }

    /// Number of decode plans currently cached (plans are keyed by sorted
    /// responding subset; cumulative hit/miss counters are on
    /// [`DmmScheme::plan_cache_stats`]).
    pub fn plan_cache_len(&self) -> usize {
        self.plan_cache.len()
    }

    /// Recovery threshold `R = 2n − 1` — the single source of truth for the
    /// `κ = n` GCSA point (used by the trait impl and the decoder).
    fn threshold(&self) -> usize {
        2 * self.n_batch - 1
    }

    /// Row of the decode system for evaluation point `α`:
    /// `[(f_1−α)^{-1}, …, (f_n−α)^{-1}, 1, α, …, α^{n−2}]`.
    fn system_row(&self, alpha: &E::Elem) -> Vec<E::Elem> {
        let ring = &self.ring;
        let n = self.n_batch;
        let mut row = Vec::with_capacity(2 * n - 1);
        for f in &self.poles {
            let d = ring.sub(f, alpha);
            row.push(ring.inv(&d).expect("poles and alphas are exceptional"));
        }
        let mut pow = ring.one();
        for _ in 0..n.saturating_sub(1) {
            row.push(pow.clone());
            pow = ring.mul(&pow, alpha);
        }
        row
    }

    /// Encode a batch already in plane-major share-ring form (the entry
    /// point the registry's embedded-input adapter uses).
    pub fn encode_planes_batch(
        &self,
        a: &[PlaneMatrix<E::Base>],
        b: &[PlaneMatrix<E::Base>],
    ) -> anyhow::Result<Vec<Share<E>>> {
        let ring = &self.ring;
        let n = self.n_batch;
        anyhow::ensure!(a.len() == n && b.len() == n, "batch size must be n = {n}");
        let (t, r) = (a[0].rows, a[0].cols);
        let s = b[0].cols;
        for (ak, bk) in a.iter().zip(b) {
            anyhow::ensure!(
                ak.rows == t && ak.cols == r && bk.rows == r && bk.cols == s,
                "all batch members must share shapes"
            );
        }
        // Per-worker shares are independent: plan-driven (the ν_l(α_i) and
        // (f_l − α_i)^{-1} tables were built at construction) and fanned
        // out over scoped threads; total-work gate keeps tiny encodes
        // sequential.
        let base = ring.plane_base();
        let plan = &self.encode_plan;
        let m = ring.plane_count();
        let per_share_ops = n * (t * r + r * s) * m * m;
        let threads = parallel::effective_threads(
            parallel::configured_threads(),
            self.alphas.len(),
            per_share_ops * self.alphas.len(),
        );
        Ok(parallel::par_map(&self.alphas, threads, |i, _alpha| {
            let mut sa = PlaneMatrix::zeros(ring, t, r);
            let mut sb = PlaneMatrix::zeros(ring, r, s);
            for l in 0..n {
                sa.axpy_with_table(base, &plan.nu[i][l], &a[l]);
                sb.axpy_with_table(base, &plan.binv[i][l], &b[l]);
            }
            Share { a: sa, b: sb }
        }))
    }

    /// Decode to plane-major share-ring products.
    pub fn decode_planes_batch(
        &self,
        responses: &[Response<E>],
    ) -> anyhow::Result<Vec<PlaneMatrix<E::Base>>> {
        let ring = &self.ring;
        let n = self.n_batch;
        let rt = self.threshold();
        anyhow::ensure!(responses.len() >= rt, "{} responses < R = {rt}", responses.len());
        let used = &responses[..rt];
        let (zr, zc) = (used[0].1.rows, used[0].1.cols);
        let m = ring.plane_count();
        let mut seen = vec![false; self.n_workers];
        for (idx, z) in used {
            anyhow::ensure!(*idx < self.n_workers, "worker index {idx} out of range");
            anyhow::ensure!(!seen[*idx], "duplicate response from worker {idx}");
            seen[*idx] = true;
            anyhow::ensure!(
                z.rows == zr && z.cols == zc && z.planes == m,
                "response from worker {idx} has shape {}x{} ({} planes), expected {zr}x{zc} ({m})",
                z.rows,
                z.cols,
                z.planes
            );
        }
        // Cauchy–Vandermonde system on the responding alphas (scalar-sized).
        // The inverse is a pure function of the subset: cache its weight
        // tables with rows in sorted-worker order, and read the column for
        // each response by its rank in the sorted key (row-permuting the
        // system permutes the columns of its unique inverse — same entries,
        // exactly).
        let mut sorted: Vec<usize> = used.iter().map(|(i, _)| *i).collect();
        sorted.sort_unstable();
        let plan = self.plan_cache.try_get_or_compute(&sorted, || {
            let mut sys = Matrix::zeros(ring, rt, rt);
            for (row_i, &widx) in sorted.iter().enumerate() {
                let row = self.system_row(&self.alphas[widx]);
                for (col, v) in row.into_iter().enumerate() {
                    sys.set(row_i, col, v);
                }
            }
            let inv = sys
                .invert(ring)
                .ok_or_else(|| anyhow::anyhow!("Cauchy–Vandermonde system not invertible"))?;
            let tables = (0..n)
                .map(|l| (0..rt).map(|col| ScalarTable::build(ring, inv.at(l, col))).collect())
                .collect();
            Ok(CsaDecodePlan { tables })
        })?;
        // unknown_l = Σ_i inv[l][rank_i] · Z_i ; A_lB_l = c_l^{-1} · unknown_l
        // — the n batch slots are independent weighted sums, table-driven
        // and parallel over slots (warm decodes build zero tables);
        // total-work gate keeps tiny decodes sequential.
        let base = ring.plane_base();
        let slots: Vec<usize> = (0..n).collect();
        let per_slot_ops = (rt + 1) * zr * zc * m * m;
        let threads =
            parallel::effective_threads(parallel::configured_threads(), n, per_slot_ops * n);
        Ok(parallel::par_map(&slots, threads, |_pos, &l| {
            let mut acc = PlaneMatrix::zeros(ring, zr, zc);
            for (widx, z) in used {
                let col = sorted.binary_search(widx).expect("idx is in its own sorted subset");
                acc.axpy_with_table(base, &plan.tables[l][col], z);
            }
            acc.scale_with_table(base, &self.c_inv_tables[l]);
            acc
        }))
    }

    /// Consistency-check surplus responses by solving the **full**
    /// Cauchy–Vandermonde system on the first `R` responses — all `2n−1`
    /// unknowns, including the cross-term polynomial coefficients the
    /// normal decode never materializes — and predicting each surplus
    /// worker's response as `Z(α) = row(α) · unknowns`. A flagged response
    /// disagrees with the codeword the first `R` responses determine;
    /// empty flags mean the whole set is consistent. Uncached: the decode
    /// plan cache only keeps the first `n` inverse rows.
    pub fn check_surplus_planes(
        &self,
        responses: &[Response<E>],
    ) -> anyhow::Result<Vec<usize>> {
        let ring = &self.ring;
        let rt = self.threshold();
        anyhow::ensure!(
            responses.len() > rt,
            "no surplus to check: {} responses for threshold {rt}",
            responses.len()
        );
        let used = &responses[..rt];
        let (zr, zc) = (used[0].1.rows, used[0].1.cols);
        let m = ring.plane_count();
        let mut seen = vec![false; self.n_workers];
        for (idx, z) in responses {
            anyhow::ensure!(*idx < self.n_workers, "worker index {idx} out of range");
            anyhow::ensure!(!seen[*idx], "duplicate response from worker {idx}");
            seen[*idx] = true;
            anyhow::ensure!(
                z.rows == zr && z.cols == zc && z.planes == m,
                "response from worker {idx} has shape {}x{} ({} planes), expected {zr}x{zc} ({m})",
                z.rows,
                z.cols,
                z.planes
            );
        }
        let mut sys = Matrix::zeros(ring, rt, rt);
        for (row_i, (widx, _)) in used.iter().enumerate() {
            let row = self.system_row(&self.alphas[*widx]);
            for (col, v) in row.into_iter().enumerate() {
                sys.set(row_i, col, v);
            }
        }
        let inv = sys
            .invert(ring)
            .ok_or_else(|| anyhow::anyhow!("Cauchy–Vandermonde system not invertible"))?;
        let base = ring.plane_base();
        let unknowns: Vec<PlaneMatrix<E::Base>> = (0..rt)
            .map(|k| {
                let mut acc = PlaneMatrix::zeros(ring, zr, zc);
                for (col, (_, z)) in used.iter().enumerate() {
                    let tbl = ScalarTable::build(ring, inv.at(k, col));
                    acc.axpy_with_table(base, &tbl, z);
                }
                acc
            })
            .collect();
        let mut flagged = Vec::new();
        for (idx, z) in &responses[rt..] {
            let row = self.system_row(&self.alphas[*idx]);
            let mut expected = PlaneMatrix::zeros(ring, zr, zc);
            for (k, coeff) in row.iter().enumerate() {
                let tbl = ScalarTable::build(ring, coeff);
                expected.axpy_with_table(base, &tbl, &unknowns[k]);
            }
            if expected != *z {
                flagged.push(*idx);
            }
        }
        Ok(flagged)
    }
}

impl<E: PlaneRing> DmmScheme<E> for CsaCode<E> {
    type ShareRing = E;

    fn name(&self) -> String {
        format!("CSA(n={}) over {}", self.n_batch, self.ring.name())
    }
    fn share_ring(&self) -> &E {
        &self.ring
    }
    fn input_ring(&self) -> &E {
        &self.ring
    }
    fn n_workers(&self) -> usize {
        self.n_workers
    }
    fn recovery_threshold(&self) -> usize {
        self.threshold()
    }
    fn batch_size(&self) -> usize {
        self.n_batch
    }

    fn encode_batch(
        &self,
        a: &[Matrix<E::Elem>],
        b: &[Matrix<E::Elem>],
    ) -> anyhow::Result<Vec<Share<E>>> {
        let pa: Vec<PlaneMatrix<E::Base>> =
            a.iter().map(|mk| PlaneMatrix::from_aos(&self.ring, mk)).collect();
        let pb: Vec<PlaneMatrix<E::Base>> =
            b.iter().map(|mk| PlaneMatrix::from_aos(&self.ring, mk)).collect();
        self.encode_planes_batch(&pa, &pb)
    }

    fn decode_batch(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<Matrix<E::Elem>>> {
        let out = self.decode_planes_batch(responses)?;
        Ok(out.iter().map(|c| c.to_aos(&self.ring)).collect())
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        let eb = self.ring.elem_bytes();
        self.n_workers * ((16 + t * r * eb) + (16 + r * s * eb))
    }

    fn download_bytes(&self, t: usize, _r: usize, s: usize) -> usize {
        self.recovery_threshold() * (16 + t * s * self.ring.elem_bytes())
    }

    fn plan_cache_stats(&self) -> (u64, u64) {
        self.plan_cache.stats()
    }

    fn check_surplus(&self, responses: &[Response<E>]) -> anyhow::Result<Vec<usize>> {
        self.check_surplus_planes(responses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::extension::Extension;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn roundtrip(n_batch: usize, n_workers: usize, m: usize, seed: u64, offset: usize) {
        let ring = Extension::new(Zq::z2e(64), m);
        let csa = CsaCode::new(ring.clone(), n_workers, n_batch).unwrap();
        let mut rng = Rng64::seeded(seed);
        let a: Vec<_> = (0..n_batch).map(|_| Matrix::random(&ring, 3, 2, &mut rng)).collect();
        let b: Vec<_> = (0..n_batch).map(|_| Matrix::random(&ring, 2, 3, &mut rng)).collect();
        let shares = csa.encode_batch(&a, &b).unwrap();
        let rt = csa.recovery_threshold();
        let responses: Vec<_> = (offset..offset + rt)
            .map(|i| (i, csa.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = csa.decode_batch(&responses).unwrap();
        for l in 0..n_batch {
            assert_eq!(c[l], Matrix::matmul(&ring, &a[l], &b[l]), "slot {l}");
        }
    }

    #[test]
    fn csa_n2() {
        roundtrip(2, 5, 3, 141, 0);
    }

    #[test]
    fn csa_n3_last_workers() {
        roundtrip(3, 8, 4, 142, 3); // uses workers 3..8
    }

    #[test]
    fn csa_n4() {
        roundtrip(4, 9, 4, 143, 1);
    }

    #[test]
    fn csa_threshold_grows_with_batch() {
        // Table 1: CSA/GCSA threshold scales with n; Batch-EP_RMFE's doesn't.
        let ring = Extension::new(Zq::z2e(64), 4);
        for n in 1..=4usize {
            let csa = CsaCode::new(ring.clone(), 9, n).unwrap();
            assert_eq!(csa.recovery_threshold(), 2 * n - 1);
        }
    }

    #[test]
    fn csa_duplicate_response_rejected() {
        let ring = Extension::new(Zq::z2e(64), 3);
        let csa = CsaCode::new(ring.clone(), 5, 2).unwrap();
        let mut rng = Rng64::seeded(145);
        let a: Vec<_> = (0..2).map(|_| Matrix::random(&ring, 2, 2, &mut rng)).collect();
        let b: Vec<_> = (0..2).map(|_| Matrix::random(&ring, 2, 2, &mut rng)).collect();
        let shares = csa.encode_batch(&a, &b).unwrap();
        let z0 = csa.worker_compute(&shares[0]).unwrap();
        let z1 = csa.worker_compute(&shares[1]).unwrap();
        let dup = vec![(0usize, z0.clone()), (1, z1), (0, z0)];
        assert!(csa.decode_batch(&dup).is_err());
    }

    #[test]
    fn csa_plan_cache_hits_on_recurring_subset() {
        let ring = Extension::new(Zq::z2e(64), 4);
        let csa = CsaCode::new(ring.clone(), 8, 3).unwrap(); // R = 5
        let mut rng = Rng64::seeded(146);
        let a: Vec<_> = (0..3).map(|_| Matrix::random(&ring, 2, 2, &mut rng)).collect();
        let b: Vec<_> = (0..3).map(|_| Matrix::random(&ring, 2, 2, &mut rng)).collect();
        let shares = csa.encode_batch(&a, &b).unwrap();
        let all: Vec<_> = shares
            .iter()
            .enumerate()
            .map(|(i, s)| (i, csa.worker_compute(s).unwrap()))
            .collect();
        // subset {0,2,3,5,7} in two arrival orders → one plan, one hit
        let first: Vec<_> = [0usize, 2, 3, 5, 7].iter().map(|&i| all[i].clone()).collect();
        let second: Vec<_> = [7usize, 3, 0, 5, 2].iter().map(|&i| all[i].clone()).collect();
        let c1 = csa.decode_batch(&first).unwrap();
        let c2 = csa.decode_batch(&second).unwrap();
        assert_eq!(csa.plan_cache_stats(), (1, 1));
        for l in 0..3 {
            assert_eq!(c1[l], Matrix::matmul(&ring, &a[l], &b[l]), "slot {l}");
            assert_eq!(c1[l], c2[l], "arrival order must not change the decode");
        }
    }

    #[test]
    fn csa_needs_enough_points() {
        // n + N must fit in the exceptional set: 3 + 6 = 9 > 8 = 2^3.
        let ring = Extension::new(Zq::z2e(64), 3);
        assert!(CsaCode::new(ring, 6, 3).is_err());
    }

    #[test]
    fn csa_surplus_check_accepts_clean_and_flags_corrupt() {
        let ring = Extension::new(Zq::z2e(64), 4);
        let csa = CsaCode::new(ring.clone(), 8, 3).unwrap(); // R = 5, slack 3
        let mut rng = Rng64::seeded(147);
        let a: Vec<_> = (0..3).map(|_| Matrix::random(&ring, 2, 2, &mut rng)).collect();
        let b: Vec<_> = (0..3).map(|_| Matrix::random(&ring, 2, 2, &mut rng)).collect();
        let shares = csa.encode_batch(&a, &b).unwrap();
        let all: Vec<_> = shares
            .iter()
            .enumerate()
            .map(|(i, s)| (i, csa.worker_compute(s).unwrap()))
            .collect();

        // All eight clean responses lie on one codeword.
        assert_eq!(csa.check_surplus_planes(&all).unwrap(), Vec::<usize>::new());

        // A tampered surplus response is flagged by worker id.
        let mut tampered = all.clone();
        tampered[6].1.data[0] = tampered[6].1.data[0].wrapping_add(1);
        assert_eq!(csa.check_surplus_planes(&tampered).unwrap(), vec![6]);
        // Same answer through the trait hook.
        assert_eq!(csa.check_surplus(&tampered).unwrap(), vec![6]);

        // A corrupt response inside the first R poisons the reference:
        // the check reports inconsistency (non-empty) without naming it.
        let mut poisoned = all.clone();
        poisoned[1].1.data[0] = poisoned[1].1.data[0].wrapping_add(1);
        assert!(!csa.check_surplus_planes(&poisoned).unwrap().is_empty());

        // No surplus at all is an error, not a vacuous pass.
        assert!(csa.check_surplus_planes(&all[..5]).is_err());
    }

    #[test]
    fn csa_single_instance_degenerates() {
        // n = 1: R = 1, share = (ν·A, (f−α)^{-1}B) recovers A·B from one node.
        let ring = Extension::new(Zq::z2e(64), 3);
        let csa = CsaCode::new(ring.clone(), 4, 1).unwrap();
        let mut rng = Rng64::seeded(144);
        let a = vec![Matrix::random(&ring, 2, 2, &mut rng)];
        let b = vec![Matrix::random(&ring, 2, 2, &mut rng)];
        let shares = csa.encode_batch(&a, &b).unwrap();
        let resp = vec![(2usize, csa.worker_compute(&shares[2]).unwrap())];
        let c = csa.decode_batch(&resp).unwrap();
        assert_eq!(c[0], Matrix::matmul(&ring, &a[0], &b[0]));
    }
}
