//! The scheme registry: build any of the paper's schemes as an erased
//! [`DynScheme`] (byte payloads in, byte payloads out) from a name and a
//! [`SchemeConfig`] — the single entry point `main.rs` and `experiments/`
//! use instead of per-scheme monomorphized plumbing.
//!
//! Registry schemes take their inputs over the paper's experimental ring
//! `Z_{2^64}`; the input matrices cross the facade in [`Matrix`]'s canonical
//! byte format and all share traffic is plane-major (see
//! [`super::scheme::DynScheme`] for the contract). Code that needs another
//! input ring (odd characteristic, Galois-field bases) uses the typed
//! constructors directly and erases with [`super::scheme::erase`].

use super::batch_ep_rmfe::BatchEpRmfe;
use super::csa::CsaCode;
use super::ep::PlainEp;
use super::ep_rmfe_i::EpRmfeI;
use super::ep_rmfe_ii::EpRmfeII;
use super::scheme::{freivalds_check, DmmScheme, DynScheme, Erased, Response, Share};
use crate::ring::extension::Extension;
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;
use crate::ring::zq::Zq;
use crate::util::rng::Rng64;
use std::sync::Arc;

/// Parameters shared by every registry scheme: worker count `N`, extension
/// degree `m`, EP partition `(u, w, v)`, and the batch size / split factor
/// `n_split` (ignored by `ep`; `csa` derives its own extension from
/// `n_split + n_workers` and ignores `m`/`u`/`w`/`v`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchemeConfig {
    pub n_workers: usize,
    pub m: usize,
    pub u: usize,
    pub w: usize,
    pub v: usize,
    pub n_split: usize,
}

impl SchemeConfig {
    /// The §V.A configuration for a worker count (8, 16 or 32), plus a
    /// minimal N = 4 preset (not from the paper) for multi-process demos
    /// and the CI loopback e2e: same `m = 3` tower and `(u, w, v) =
    /// (2, 1, 2)` partition as N = 8, but with `R = 4 = N` — every worker
    /// must answer, so there is no straggler slack.
    pub fn for_workers(n_workers: usize) -> anyhow::Result<SchemeConfig> {
        match n_workers {
            4 => Ok(SchemeConfig { n_workers: 4, m: 3, u: 2, w: 1, v: 2, n_split: 2 }),
            8 => Ok(SchemeConfig { n_workers: 8, m: 3, u: 2, w: 1, v: 2, n_split: 2 }),
            16 => Ok(SchemeConfig { n_workers: 16, m: 4, u: 2, w: 2, v: 2, n_split: 2 }),
            32 => Ok(SchemeConfig { n_workers: 32, m: 5, u: 2, w: 2, v: 2, n_split: 3 }),
            _ => anyhow::bail!("no configuration for N = {n_workers} (use 4, 8, 16 or 32)"),
        }
    }

    /// The largest preset that fits a pool of `live` reachable workers —
    /// how an elastic deployment picks a viable `(N, R)` when fewer daemons
    /// than the requested preset are up: a job submitted with `N` shares on
    /// `live ≥ N` healthy workers still completes. Fails below the smallest
    /// preset (a 3-worker pool cannot run any configuration).
    pub fn for_live_workers(live: usize) -> anyhow::Result<SchemeConfig> {
        let n_workers = [32usize, 16, 8, 4].into_iter().find(|&n| n <= live);
        match n_workers {
            Some(n) => SchemeConfig::for_workers(n),
            None => anyhow::bail!(
                "only {live} live workers, but the smallest configuration needs 4"
            ),
        }
    }
}

/// `(name, description)` of every registry scheme.
pub const SCHEME_NAMES: &[(&str, &str)] = &[
    ("ep", "plain EP baseline (Lemma III.1): constant embedding into GR(p^e, d·m)"),
    ("ep-rmfe-1", "EP_RMFE-I (Corollary IV.1): MatDot split + RMFE batch packing"),
    ("ep-rmfe-2", "EP_RMFE-II (Corollary IV.2): column split of B, phi1-only"),
    ("batch-ep-rmfe", "Batch-EP_RMFE (Theorem III.2): n-batch CDBMM, R independent of n"),
    ("csa", "CSA batch baseline (runnable GCSA point uvw=1, kappa=n; R = 2n-1)"),
];

/// Build a registry scheme over `Z_{2^64}` inputs.
pub fn build(name: &str, cfg: &SchemeConfig) -> anyhow::Result<Arc<dyn DynScheme>> {
    let base = Zq::z2e(64);
    let SchemeConfig { n_workers, m, u, w, v, n_split } = *cfg;
    match name {
        "ep" => Ok(Arc::new(Erased::new(Arc::new(PlainEp::with_m(
            base, m, n_workers, u, w, v,
        )?)))),
        "ep-rmfe-1" => Ok(Arc::new(Erased::new(Arc::new(EpRmfeI::with_m(
            base, m, n_workers, u, w, v, n_split,
        )?)))),
        "ep-rmfe-2" => Ok(Arc::new(Erased::new(Arc::new(EpRmfeII::with_m(
            base, m, n_workers, u, w, v, n_split,
        )?)))),
        "batch-ep-rmfe" => Ok(Arc::new(Erased::new(Arc::new(BatchEpRmfe::with_m(
            base, m, n_workers, n_split, u, w, v,
        )?)))),
        "csa" => Ok(Arc::new(Erased::new(Arc::new(CsaZq::new(n_workers, n_split)?)))),
        other => anyhow::bail!(
            "unknown scheme `{other}` (available: ep | ep-rmfe-1 | ep-rmfe-2 | \
             batch-ep-rmfe | csa)"
        ),
    }
}

/// CSA with `Z_{2^64}` inputs: the registry adapter that constant-embeds the
/// batch into the extension (exactly what GCSA prescribes for small-ring
/// inputs — plane 0 = input, higher planes zero) and reads plane 0 back out,
/// so CSA speaks the same input-ring byte contract as every other registry
/// scheme. The extension degree is chosen for `n + N` exceptional points.
pub struct CsaZq {
    base: Zq,
    inner: CsaCode<Extension<Zq>>,
}

impl CsaZq {
    pub fn new(n_workers: usize, n_batch: usize) -> anyhow::Result<CsaZq> {
        let base = Zq::z2e(64);
        let ext = Extension::with_capacity(base.clone(), n_batch + n_workers);
        Ok(CsaZq { base, inner: CsaCode::new(ext, n_workers, n_batch)? })
    }

    pub fn inner(&self) -> &CsaCode<Extension<Zq>> {
        &self.inner
    }
}

impl DmmScheme<Zq> for CsaZq {
    type ShareRing = Extension<Zq>;

    fn name(&self) -> String {
        format!("CSA/GCSA (uvw=1, κ=n) [{}]", self.inner.name())
    }
    fn share_ring(&self) -> &Extension<Zq> {
        self.inner.share_ring()
    }
    fn input_ring(&self) -> &Zq {
        &self.base
    }
    fn n_workers(&self) -> usize {
        self.inner.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        self.inner.recovery_threshold()
    }
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }

    fn encode_batch(
        &self,
        a: &[Matrix<u64>],
        b: &[Matrix<u64>],
    ) -> anyhow::Result<Vec<Share<Extension<Zq>>>> {
        let ext = self.inner.share_ring();
        let pa: Vec<PlaneMatrix<Zq>> =
            a.iter().map(|mk| PlaneMatrix::from_base_matrix(ext, mk)).collect();
        let pb: Vec<PlaneMatrix<Zq>> =
            b.iter().map(|mk| PlaneMatrix::from_base_matrix(ext, mk)).collect();
        self.inner.encode_planes_batch(&pa, &pb)
    }

    fn decode_batch(
        &self,
        responses: &[Response<Extension<Zq>>],
    ) -> anyhow::Result<Vec<Matrix<u64>>> {
        // Constant-embedded inputs have constant products: read plane 0.
        let out = self.inner.decode_planes_batch(responses)?;
        Ok(out.iter().map(|c| c.base_plane_matrix()).collect())
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.inner.upload_bytes(t, r, s)
    }
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.inner.download_bytes(t, r, s)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.inner.plan_cache_stats()
    }

    fn check_surplus(
        &self,
        responses: &[Response<Extension<Zq>>],
    ) -> anyhow::Result<Vec<usize>> {
        self.inner.check_surplus_planes(responses)
    }

    fn verify_products(
        &self,
        a: &[Matrix<u64>],
        b: &[Matrix<u64>],
        c: &[Matrix<u64>],
        trials: usize,
        rng: &mut Rng64,
    ) -> anyhow::Result<bool> {
        // Over Z_{2^64} the exceptional set has only 2 points (error 1/2 per
        // trial); constant-embed into the extension — a ring homomorphism —
        // where the canonical set has 2^m points, so each trial's error is
        // 2^{-m}.
        let ext = self.inner.share_ring();
        let lift = |ms: &[Matrix<u64>]| -> Vec<Matrix<_>> {
            ms.iter().map(|mk| PlaneMatrix::from_base_matrix(ext, mk).to_aos(ext)).collect()
        };
        let (la, lb, lc) = (lift(a), lift(b), lift(c));
        anyhow::ensure!(
            la.len() == lb.len() && lb.len() == lc.len(),
            "verify_products: slot-count mismatch"
        );
        for k in 0..la.len() {
            if !freivalds_check(ext, &la[k], &lb[k], &lc[k], trials, rng)? {
                return Ok(false);
            }
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng64;

    /// Drive a registry scheme end-to-end purely through the byte facade.
    fn byte_roundtrip(name: &str, cfg: &SchemeConfig, size: usize, seed: u64) {
        let base = Zq::z2e(64);
        let scheme = build(name, cfg).unwrap();
        let n = scheme.batch_size();
        let mut rng = Rng64::seeded(seed);
        let a: Vec<_> = (0..n).map(|_| Matrix::random(&base, size, size, &mut rng)).collect();
        let b: Vec<_> = (0..n).map(|_| Matrix::random(&base, size, size, &mut rng)).collect();
        let a_bytes: Vec<Vec<u8>> = a.iter().map(|m| m.to_bytes(&base)).collect();
        let b_bytes: Vec<Vec<u8>> = b.iter().map(|m| m.to_bytes(&base)).collect();
        let payloads = scheme.encode_bytes(&a_bytes, &b_bytes).unwrap();
        assert_eq!(payloads.len(), scheme.n_workers());
        let rt = scheme.recovery_threshold();
        let responses: Vec<(usize, crate::util::bytepool::PooledBuf)> =
            (scheme.n_workers() - rt..scheme.n_workers())
                .map(|i| (i, scheme.compute_bytes(&payloads[i]).unwrap()))
                .collect();
        let borrowed: Vec<(usize, &[u8])> =
            responses.iter().map(|(i, p)| (*i, p.as_slice())).collect();
        let out = scheme.decode_bytes(&borrowed).unwrap();
        assert_eq!(out.len(), n);
        for (k, buf) in out.iter().enumerate() {
            let c = Matrix::from_bytes(&base, buf).unwrap();
            assert_eq!(c, Matrix::matmul(&base, &a[k], &b[k]), "{name} slot {k}");
        }
    }

    #[test]
    fn all_registry_schemes_roundtrip_through_bytes() {
        let cfg = SchemeConfig::for_workers(8).unwrap();
        for (name, _) in SCHEME_NAMES {
            byte_roundtrip(name, &cfg, 8, 600);
        }
    }

    #[test]
    fn demo_config_n4_roundtrips_every_scheme() {
        // The minimal multi-process/CI preset: R = N = 4 for the EP family,
        // so every worker's response participates in the decode.
        let cfg = SchemeConfig::for_workers(4).unwrap();
        for (name, _) in SCHEME_NAMES {
            byte_roundtrip(name, &cfg, 8, 610);
        }
    }

    #[test]
    fn verified_decode_accepts_every_clean_run_for_all_schemes() {
        // Registry-wide property: with every worker answering honestly, the
        // whole verification stack — wellformedness, surplus consistency,
        // Freivalds — accepts, and a single flipped byte in a surplus
        // response is caught.
        let base = Zq::z2e(64);
        let cfg = SchemeConfig::for_workers(8).unwrap();
        for (seed, (name, _)) in SCHEME_NAMES.iter().enumerate() {
            let scheme = build(name, &cfg).unwrap();
            let n = scheme.batch_size();
            let mut rng = Rng64::seeded(620 + seed as u64);
            let a: Vec<_> = (0..n).map(|_| Matrix::random(&base, 6, 6, &mut rng)).collect();
            let b: Vec<_> = (0..n).map(|_| Matrix::random(&base, 6, 6, &mut rng)).collect();
            let a_bytes: Vec<Vec<u8>> = a.iter().map(|m| m.to_bytes(&base)).collect();
            let b_bytes: Vec<Vec<u8>> = b.iter().map(|m| m.to_bytes(&base)).collect();
            let payloads = scheme.encode_bytes(&a_bytes, &b_bytes).unwrap();
            let responses: Vec<(usize, crate::util::bytepool::PooledBuf)> = payloads
                .iter()
                .enumerate()
                .map(|(i, p)| (i, scheme.compute_bytes(p).unwrap()))
                .collect();
            for (i, p) in &responses {
                assert!(scheme.response_is_wellformed(p), "{name} worker {i}");
            }
            let borrowed: Vec<(usize, &[u8])> =
                responses.iter().map(|(i, p)| (*i, p.as_slice())).collect();
            assert_eq!(
                scheme.check_surplus_bytes(&borrowed).unwrap(),
                Vec::<usize>::new(),
                "{name}: clean surplus must be consistent"
            );
            let rt = scheme.recovery_threshold();
            let c_bytes = scheme.decode_bytes(&borrowed[..rt]).unwrap();
            let mut vrng = Rng64::seeded(9000 + seed as u64);
            assert!(
                scheme
                    .verify_products_bytes(&a_bytes, &b_bytes, &c_bytes, 16, &mut vrng)
                    .unwrap(),
                "{name}: Freivalds must accept the true product"
            );
            // One flipped byte in the last (surplus) response gets flagged.
            let last = responses.len() - 1;
            let mut corrupted = responses[last].1.to_vec();
            corrupted[corrupted.len() / 2] ^= 0x01;
            let mut tb: Vec<(usize, &[u8])> =
                responses[..last].iter().map(|(i, p)| (*i, p.as_slice())).collect();
            tb.push((last, corrupted.as_slice()));
            let flagged = scheme.check_surplus_bytes(&tb).unwrap();
            assert!(
                flagged.contains(&last),
                "{name}: tampered surplus worker {last} not in {flagged:?}"
            );
        }
    }

    #[test]
    fn unknown_scheme_rejected() {
        let cfg = SchemeConfig::for_workers(8).unwrap();
        assert!(build("nope", &cfg).is_err());
    }

    #[test]
    fn malformed_payloads_are_clean_errors() {
        let cfg = SchemeConfig::for_workers(8).unwrap();
        let scheme = build("ep-rmfe-1", &cfg).unwrap();
        assert!(scheme.compute_bytes(&[1, 2, 3]).is_err());
        assert!(scheme.compute_bytes(&[]).is_err());
        assert!(scheme.encode_bytes(&[vec![0u8; 7]], &[vec![0u8; 7]]).is_err());
        assert!(scheme.decode_bytes(&[(0, &[9u8, 9][..])]).is_err());
    }

    #[test]
    fn paper_configs_exist_for_8_16_32() {
        for n in [8usize, 16, 32] {
            let cfg = SchemeConfig::for_workers(n).unwrap();
            assert_eq!(cfg.n_workers, n);
        }
        assert!(SchemeConfig::for_workers(12).is_err());
    }

    #[test]
    fn live_worker_fallback_picks_largest_viable_preset() {
        assert_eq!(SchemeConfig::for_live_workers(4).unwrap().n_workers, 4);
        assert_eq!(SchemeConfig::for_live_workers(7).unwrap().n_workers, 4);
        assert_eq!(SchemeConfig::for_live_workers(8).unwrap().n_workers, 8);
        assert_eq!(SchemeConfig::for_live_workers(31).unwrap().n_workers, 16);
        assert_eq!(SchemeConfig::for_live_workers(100).unwrap().n_workers, 32);
        let err = SchemeConfig::for_live_workers(3).unwrap_err();
        assert!(err.to_string().contains("smallest configuration needs 4"), "{err}");
    }
}
