//! The common interface of all coded DMM / DBMM schemes — one trait
//! ([`DmmScheme`], single product = `batch_size() == 1`), the plane-major
//! [`Share`] wire type, the exact communication accounting the evaluation
//! section reports, and the object-safe erased facade ([`DynScheme`]) the
//! CLI/experiments registry and the worker pool run against.
//!
//! A scheme is parameterized by the *input ring* `R` (where the user's
//! matrices live, e.g. `Z_{2^64}`) and internally works over a *share ring*
//! (usually an extension `GR(p^e, d·m)` with enough exceptional points for
//! the worker count). Workers only ever see share-ring matrices, and those
//! are stored and serialized **plane-major** ([`PlaneMatrix`]) end-to-end:
//! encode produces planes, the wire carries one contiguous block per share,
//! the worker multiplies plane-by-plane, decode interpolates over planes.

use crate::ring::matrix::Matrix;
use crate::ring::plane::{PlaneMatrix, PlaneRing};
use crate::ring::traits::Ring;
use crate::util::bytepool::{BytePool, PooledBuf};
use crate::util::rng::Rng64;
use std::marker::PhantomData;
use std::sync::Arc;

/// `m · x` for a row-major matrix and a column vector.
pub fn mat_vec<R: Ring>(ring: &R, m: &Matrix<R::Elem>, x: &[R::Elem]) -> Vec<R::Elem> {
    assert_eq!(m.cols, x.len(), "matrix-vector dimensions must agree");
    (0..m.rows).map(|i| ring.dot(&m.data[i * m.cols..(i + 1) * m.cols], x)).collect()
}

/// Freivalds' probabilistic product check over a Galois ring: does
/// `a · b == c`, with one-sided error?
///
/// Each trial draws a challenge vector `x` coordinate-wise from the ring's
/// canonical *exceptional set* (pairwise differences are units) and tests
/// `a·(b·x) == c·x`. Over a ring with zero divisors a uniformly random
/// challenge is unsound — a nonzero error matrix `d = a·b − c` can satisfy
/// `d·x = 0` for huge swaths of non-unit `x` — but exceptional-set
/// challenges restore the field argument: if `d·x = d·x'` for two set
/// members `x_j ≠ x_j'` in a coordinate where `d` is nonzero, then
/// `d_j·(x_j − x_j') = 0` with `x_j − x_j'` a unit, forcing `d_j = 0`. So a
/// nonzero row of `d` survives a trial with probability at most `1/|S|`,
/// i.e. at most `p^{-D}` using the full exceptional set of `GR(p^e, D)`.
/// Over `Z_{2^64}` the set has only 2 points (error ½ per trial) — hence
/// `trials` is configurable (40 trials ⇒ error ≤ 2⁻⁴⁰), and schemes whose
/// share ring is a genuine extension override
/// [`DmmScheme::verify_products`] to run the check there for `p^{-d·m}`
/// per trial.
///
/// Cost per trial: two matrix-vector products and one vector-vector
/// comparison — `O(tr + rs)` ring ops versus `O(trs)` for recomputing the
/// product.
pub fn freivalds_check<R: Ring>(
    ring: &R,
    a: &Matrix<R::Elem>,
    b: &Matrix<R::Elem>,
    c: &Matrix<R::Elem>,
    trials: usize,
    rng: &mut Rng64,
) -> anyhow::Result<bool> {
    anyhow::ensure!(a.cols == b.rows, "inner dimensions disagree");
    anyhow::ensure!(
        (c.rows, c.cols) == (a.rows, b.cols),
        "product shape disagrees: {}x{} vs {}x{}",
        c.rows,
        c.cols,
        a.rows,
        b.cols
    );
    let n_points = ring.residue_size().min(64).max(2) as usize;
    let points = ring.exceptional_points(n_points)?;
    for _ in 0..trials {
        let x: Vec<R::Elem> = (0..b.cols)
            .map(|_| points[rng.below(points.len() as u64) as usize].clone())
            .collect();
        let bx = mat_vec(ring, b, &x);
        let abx = mat_vec(ring, a, &bx);
        let cx = mat_vec(ring, c, &x);
        if abx != cx {
            return Ok(false);
        }
    }
    Ok(true)
}

/// The pair of encoded matrices sent to one worker: the evaluations
/// `f(α_i)`, `g(α_i)` of the master's encoding polynomials, stored as
/// plane-major flat buffers over the share ring's base.
pub struct Share<E: PlaneRing> {
    pub a: PlaneMatrix<E::Base>,
    pub b: PlaneMatrix<E::Base>,
}

impl<E: PlaneRing> Clone for Share<E> {
    fn clone(&self) -> Self {
        Share { a: self.a.clone(), b: self.b.clone() }
    }
}

impl<E: PlaneRing> PartialEq for Share<E> {
    fn eq(&self, other: &Self) -> bool {
        self.a == other.a && self.b == other.b
    }
}

impl<E: PlaneRing> std::fmt::Debug for Share<E> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Share").field("a", &self.a).field("b", &self.b).finish()
    }
}

impl<E: PlaneRing> Share<E> {
    /// Exact wire size of this share under the share ring's encoding.
    pub fn byte_len(&self, ring: &E) -> usize {
        self.a.byte_len(ring) + self.b.byte_len(ring)
    }

    /// Serialize both matrices as one contiguous block (`a` then `b`).
    pub fn to_bytes(&self, ring: &E) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len(ring));
        self.write_bytes_into(ring, &mut out);
        out
    }

    /// Append the serialized share (`a` then `b`) to a borrowed buffer —
    /// the pool-leased zero-copy path ([`PlaneMatrix::write_bytes_into`]).
    pub fn write_bytes_into(&self, ring: &E, out: &mut Vec<u8>) {
        out.reserve(self.byte_len(ring));
        self.a.write_bytes_into(ring, out);
        self.b.write_bytes_into(ring, out);
    }

    /// Deserialize; truncated, oversized or shape-inconsistent payloads
    /// yield an `Err` (workers report such jobs as clean failures instead of
    /// unwinding).
    pub fn from_bytes(ring: &E, buf: &[u8]) -> anyhow::Result<Self> {
        let mut pos = 0;
        let a = PlaneMatrix::read_from(ring, buf, &mut pos)?;
        let b = PlaneMatrix::read_from(ring, buf, &mut pos)?;
        anyhow::ensure!(
            pos == buf.len(),
            "share payload has {} trailing bytes",
            buf.len() - pos
        );
        anyhow::ensure!(
            a.cols == b.rows,
            "share inner dimensions disagree: a is {}x{}, b is {}x{}",
            a.rows,
            a.cols,
            b.rows,
            b.cols
        );
        Ok(Share { a, b })
    }
}

/// A worker's response, tagged with its worker index.
pub type Response<E> = (usize, PlaneMatrix<<E as PlaneRing>::Base>);

/// Coded distributed (batch) matrix multiplication: `C_k = A_k·B_k` for a
/// batch of [`DmmScheme::batch_size`] pairs, decodable from any
/// [`DmmScheme::recovery_threshold`] of [`DmmScheme::n_workers`] responses.
///
/// Single-product schemes are the `batch_size() == 1` point and additionally
/// get the [`DmmScheme::encode`] / [`DmmScheme::decode`] conveniences.
pub trait DmmScheme<R: Ring>: Send + Sync {
    /// The ring shares and responses live in.
    type ShareRing: PlaneRing;

    fn name(&self) -> String;
    fn share_ring(&self) -> &Self::ShareRing;
    fn input_ring(&self) -> &R;

    /// Total number of worker nodes `N`.
    fn n_workers(&self) -> usize;

    /// Recovery threshold `R ≤ N`.
    fn recovery_threshold(&self) -> usize;

    /// Number of matrix pairs multiplied per invocation (1 = single DMM).
    fn batch_size(&self) -> usize {
        1
    }

    /// Master-side encoding: one plane-major share per worker.
    fn encode_batch(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<Share<Self::ShareRing>>>;

    /// Encode only the **left** operand batch: the [`Share::a`] half of each
    /// worker's share, bit-identical to what [`DmmScheme::encode_batch`]
    /// would have produced for the same `a` (the encoding of `A` is a fixed
    /// linear map per worker, independent of `B`). This is the
    /// encode-once half of prepared-operand serving: stage these halves on
    /// the workers, then ship only [`DmmScheme::encode_right_batch`] per job.
    ///
    /// Default: unsupported — schemes whose encodes entangle the two
    /// operands keep working through the joint path.
    fn encode_left_batch(
        &self,
        a: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<<Self::ShareRing as PlaneRing>::Base>>> {
        let _ = a;
        anyhow::bail!("{} cannot encode its left operand independently", self.name())
    }

    /// Encode only the **right** operand batch: the [`Share::b`] half of
    /// each worker's share. See [`DmmScheme::encode_left_batch`].
    fn encode_right_batch(
        &self,
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<<Self::ShareRing as PlaneRing>::Base>>> {
        let _ = b;
        anyhow::bail!("{} cannot encode its right operand independently", self.name())
    }

    /// Split of [`DmmScheme::upload_bytes`] into `(a_side, b_side)` totals
    /// across all `N` workers — the analytic accounting for the prepared
    /// path, where the `a_side` is staged once and only the `b_side` ships
    /// per job. `None` when the scheme has no independent split; when
    /// `Some`, the two halves sum exactly to `upload_bytes(t, r, s)`.
    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        let _ = (t, r, s);
        None
    }

    /// Cumulative count of A-side encodes performed by this scheme instance
    /// (joint encodes count too — they encode `A`). The prepared-operand
    /// serving bench asserts this stays flat across steady-state jobs, in
    /// the style of the `scalar_table_builds()` probe. Schemes without the
    /// split path report 0.
    fn left_encodes(&self) -> u64 {
        0
    }

    /// The worker-node computation: a share-ring matrix product on flat
    /// plane-major storage — the base ring's contiguous ikj kernel plane by
    /// plane plus one modulus reduction, no per-element heap traffic. Runs
    /// on `GR_CDMM_THREADS` scoped threads (row-panel split, bit-identical
    /// to sequential; see [`crate::util::parallel`]).
    fn worker_compute(
        &self,
        share: &Share<Self::ShareRing>,
    ) -> anyhow::Result<PlaneMatrix<<Self::ShareRing as PlaneRing>::Base>> {
        Ok(PlaneMatrix::matmul(self.share_ring(), &share.a, &share.b))
    }

    /// Master-side decoding from at least `recovery_threshold()` responses
    /// (any subset of workers; extra responses are ignored).
    fn decode_batch(
        &self,
        responses: &[Response<Self::ShareRing>],
    ) -> anyhow::Result<Vec<Matrix<R::Elem>>>;

    /// Exact total upload volume in bytes (master → all N workers) for the
    /// given input shapes — computed from the share shapes, matching what
    /// the byte-accounted transport measures on the wire.
    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize;

    /// Exact download volume in bytes (first `recovery_threshold()` workers
    /// → master).
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize;

    /// Cumulative decode-plan cache counters `(hits, misses)` — see
    /// [`super::plan_cache::PlanCache`]. Schemes whose decode has no
    /// subset-keyed setup to cache report `(0, 0)`; the runner surfaces the
    /// per-job delta in [`crate::coordinator::JobMetrics`].
    fn plan_cache_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Consistency-check a **surplus** of responses (more than
    /// [`DmmScheme::recovery_threshold`]): the code's redundancy makes a
    /// `>R`-point decode overdetermined, so honest responses must agree
    /// with the decode of any R-subset. Returns the worker indices of
    /// responses found *inconsistent* with the rest — empty means every
    /// response fits one consistent codeword and the decode can be trusted
    /// (a corrupt response anywhere in the set would break agreement for
    /// some subset, so non-empty flags mean "run leave-one-out isolation",
    /// not "exactly these are guilty").
    ///
    /// Default: decode the first `R` responses as a reference, then
    /// re-decode with each surplus response substituted in and compare —
    /// pure decode-oracle cross-checking that works for every scheme.
    /// Evaluation-code schemes override it with re-encode-and-compare at
    /// the spare evaluation points, which is one interpolation plus a cheap
    /// evaluation per surplus share instead of a full decode each.
    fn check_surplus(
        &self,
        responses: &[Response<Self::ShareRing>],
    ) -> anyhow::Result<Vec<usize>> {
        let need = self.recovery_threshold();
        anyhow::ensure!(
            responses.len() > need,
            "{} has no surplus to check: {} responses for threshold {need}",
            self.name(),
            responses.len()
        );
        let reference = self.decode_batch(&responses[..need])?;
        let mut flagged = Vec::new();
        for surplus in &responses[need..] {
            let mut subset: Vec<Response<Self::ShareRing>> = responses[..need - 1].to_vec();
            subset.push(surplus.clone());
            match self.decode_batch(&subset) {
                Ok(alt) if alt == reference => {}
                _ => flagged.push(surplus.0),
            }
        }
        Ok(flagged)
    }

    /// Probabilistic product verification for a decoded batch: does
    /// `a[k] · b[k] == c[k]` for every slot, with one-sided error? The
    /// cheap fallback when *exactly* `R` responses arrived and there is no
    /// surplus to cross-check against.
    ///
    /// Default: [`freivalds_check`] over the input ring, whose exceptional
    /// set bounds the per-trial error (see there for the soundness
    /// argument and why `trials` matters over small residue fields).
    /// Schemes with an extension share ring override this to lift the
    /// check there, shrinking the error to `p^{-d·m}` per trial.
    fn verify_products(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
        c: &[Matrix<R::Elem>],
        trials: usize,
        rng: &mut Rng64,
    ) -> anyhow::Result<bool> {
        anyhow::ensure!(
            a.len() == b.len() && b.len() == c.len(),
            "batch slots disagree: {} a, {} b, {} c",
            a.len(),
            b.len(),
            c.len()
        );
        let ring = self.input_ring();
        for ((ak, bk), ck) in a.iter().zip(b).zip(c) {
            if !freivalds_check(ring, ak, bk, ck, trials, rng)? {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Single-product encode (`batch_size() == 1` schemes only).
    fn encode(
        &self,
        a: &Matrix<R::Elem>,
        b: &Matrix<R::Elem>,
    ) -> anyhow::Result<Vec<Share<Self::ShareRing>>> {
        anyhow::ensure!(
            self.batch_size() == 1,
            "{} is a batch scheme (n = {}); use encode_batch",
            self.name(),
            self.batch_size()
        );
        self.encode_batch(std::slice::from_ref(a), std::slice::from_ref(b))
    }

    /// Single-product decode (`batch_size() == 1` schemes only).
    fn decode(
        &self,
        responses: &[Response<Self::ShareRing>],
    ) -> anyhow::Result<Matrix<R::Elem>> {
        anyhow::ensure!(
            self.batch_size() == 1,
            "{} is a batch scheme (n = {}); use decode_batch",
            self.name(),
            self.batch_size()
        );
        let mut out = self.decode_batch(responses)?;
        anyhow::ensure!(out.len() == 1, "single-product decode returned {} matrices", out.len());
        Ok(out.pop().expect("length checked above"))
    }

    /// Single-product left encode (`batch_size() == 1` schemes only).
    fn encode_left(
        &self,
        a: &Matrix<R::Elem>,
    ) -> anyhow::Result<Vec<PlaneMatrix<<Self::ShareRing as PlaneRing>::Base>>> {
        anyhow::ensure!(
            self.batch_size() == 1,
            "{} is a batch scheme (n = {}); use encode_left_batch",
            self.name(),
            self.batch_size()
        );
        self.encode_left_batch(std::slice::from_ref(a))
    }

    /// Single-product right encode (`batch_size() == 1` schemes only).
    fn encode_right(
        &self,
        b: &Matrix<R::Elem>,
    ) -> anyhow::Result<Vec<PlaneMatrix<<Self::ShareRing as PlaneRing>::Base>>> {
        anyhow::ensure!(
            self.batch_size() == 1,
            "{} is a batch scheme (n = {}); use encode_right_batch",
            self.name(),
            self.batch_size()
        );
        self.encode_right_batch(std::slice::from_ref(b))
    }
}

/// Object-safe erased scheme facade: **byte payloads in, byte payloads out**.
///
/// The contract (used by the CLI registry, the experiments harness and the
/// native worker backend):
///
/// * input/output matrices cross the facade serialized in the *input ring*'s
///   canonical [`Matrix`] format (`rows | cols | elements`, little-endian);
/// * share payloads and worker responses cross it in the *share ring*'s
///   plane-major [`PlaneMatrix`]/[`Share`] format — the exact bytes the
///   coordinator puts on the wire;
/// * every deserialization is validated; malformed payloads return `Err`;
/// * every payload the facade *produces* (encoded shares, worker responses,
///   decoded outputs) is written into a pool-leased [`PooledBuf`] — bytes
///   bit-identical to the old `Vec` path, but steady-state serving
///   allocates nothing per job (see [`crate::util::bytepool`]).
pub trait DynScheme: Send + Sync {
    fn name(&self) -> String;
    fn n_workers(&self) -> usize;
    fn recovery_threshold(&self) -> usize;
    fn batch_size(&self) -> usize;

    /// Encode a batch of serialized input matrices into one share payload
    /// per worker.
    fn encode_bytes(&self, a: &[Vec<u8>], b: &[Vec<u8>]) -> anyhow::Result<Vec<PooledBuf>>;

    /// Encode only the left operand batch into one serialized
    /// [`PlaneMatrix`] per worker — the leading bytes of that worker's full
    /// share payload. Concatenating a worker's left half with its
    /// [`DynScheme::encode_right_bytes`] half reproduces the
    /// [`DynScheme::encode_bytes`] payload byte for byte (a [`Share`]
    /// serializes as `a` then `b`), which is what lets staged workers
    /// reassemble shares without any scheme knowledge. Default:
    /// unsupported.
    fn encode_left_bytes(&self, a: &[Vec<u8>]) -> anyhow::Result<Vec<PooledBuf>> {
        let _ = a;
        anyhow::bail!("{} cannot encode its left operand independently", self.name())
    }

    /// Encode only the right operand batch into one serialized
    /// [`PlaneMatrix`] per worker — the trailing bytes of that worker's
    /// full share payload. See [`DynScheme::encode_left_bytes`].
    fn encode_right_bytes(&self, b: &[Vec<u8>]) -> anyhow::Result<Vec<PooledBuf>> {
        let _ = b;
        anyhow::bail!("{} cannot encode its right operand independently", self.name())
    }

    /// `(a_side, b_side)` split of [`DynScheme::upload_bytes`], or `None`
    /// when the scheme has no independent operand encode.
    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        let _ = (t, r, s);
        None
    }

    /// Cumulative A-side encode count (see [`DmmScheme::left_encodes`]).
    fn left_encodes(&self) -> u64 {
        0
    }

    /// Worker computation on a serialized share payload.
    fn compute_bytes(&self, payload: &[u8]) -> anyhow::Result<PooledBuf>;

    /// Decode serialized `(worker_id, response)` payloads into serialized
    /// output matrices (one per batch slot).
    fn decode_bytes(&self, responses: &[(usize, &[u8])]) -> anyhow::Result<Vec<PooledBuf>>;

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize;
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize;

    /// Cumulative decode-plan cache counters `(hits, misses)`; `(0, 0)` for
    /// schemes without a cache.
    fn plan_cache_stats(&self) -> (u64, u64);

    /// Is `payload` a structurally valid response (a share-ring
    /// [`PlaneMatrix`] that deserializes cleanly)? The verified-decode
    /// path's first filter: garbage payloads are rejected here before any
    /// algebraic checking. The permissive default accepts everything.
    fn response_is_wellformed(&self, payload: &[u8]) -> bool {
        let _ = payload;
        true
    }

    /// Byte-facade of [`DmmScheme::check_surplus`]: consistency-check
    /// `(worker_id, response)` payloads when more than the recovery
    /// threshold arrived, returning the worker ids of inconsistent
    /// responses (empty = all consistent). Default: unsupported.
    fn check_surplus_bytes(&self, responses: &[(usize, &[u8])]) -> anyhow::Result<Vec<usize>> {
        let _ = responses;
        anyhow::bail!("{} does not support surplus consistency checking", self.name())
    }

    /// Byte-facade of [`DmmScheme::verify_products`]: Freivalds-check
    /// serialized input matrices `a`, `b` against decoded products `c`
    /// (one per batch slot, as returned by [`DynScheme::decode_bytes`]),
    /// `trials` challenge rounds each. Default: unsupported.
    fn verify_products_bytes(
        &self,
        a: &[Vec<u8>],
        b: &[Vec<u8>],
        c: &[PooledBuf],
        trials: usize,
        rng: &mut Rng64,
    ) -> anyhow::Result<bool> {
        let _ = (a, b, c, trials, rng);
        anyhow::bail!("{} does not support product verification", self.name())
    }
}

/// Adapter implementing [`DynScheme`] for any typed [`DmmScheme`].
pub struct Erased<R: Ring, S: DmmScheme<R>> {
    scheme: Arc<S>,
    _input: PhantomData<fn() -> R>,
}

impl<R: Ring, S: DmmScheme<R>> Erased<R, S> {
    pub fn new(scheme: Arc<S>) -> Self {
        Erased { scheme, _input: PhantomData }
    }

    /// The wrapped typed scheme.
    pub fn inner(&self) -> &S {
        &self.scheme
    }
}

impl<R: Ring, S: DmmScheme<R>> DynScheme for Erased<R, S> {
    fn name(&self) -> String {
        self.scheme.name()
    }
    fn n_workers(&self) -> usize {
        self.scheme.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        self.scheme.recovery_threshold()
    }
    fn batch_size(&self) -> usize {
        self.scheme.batch_size()
    }

    fn encode_bytes(&self, a: &[Vec<u8>], b: &[Vec<u8>]) -> anyhow::Result<Vec<PooledBuf>> {
        let ring = self.scheme.input_ring();
        let am: Vec<Matrix<R::Elem>> = a
            .iter()
            .map(|buf| Matrix::from_bytes(ring, buf))
            .collect::<anyhow::Result<_>>()?;
        let bm: Vec<Matrix<R::Elem>> = b
            .iter()
            .map(|buf| Matrix::from_bytes(ring, buf))
            .collect::<anyhow::Result<_>>()?;
        let shares = self.scheme.encode_batch(&am, &bm)?;
        let sr = self.scheme.share_ring();
        let pool = BytePool::global();
        Ok(shares
            .iter()
            .map(|s| {
                let mut lease = pool.lease(s.byte_len(sr));
                s.write_bytes_into(sr, &mut lease);
                lease.freeze()
            })
            .collect())
    }

    fn encode_left_bytes(&self, a: &[Vec<u8>]) -> anyhow::Result<Vec<PooledBuf>> {
        let ring = self.scheme.input_ring();
        let am: Vec<Matrix<R::Elem>> = a
            .iter()
            .map(|buf| Matrix::from_bytes(ring, buf))
            .collect::<anyhow::Result<_>>()?;
        let halves = self.scheme.encode_left_batch(&am)?;
        let sr = self.scheme.share_ring();
        let pool = BytePool::global();
        Ok(halves
            .iter()
            .map(|p| {
                let mut lease = pool.lease(p.byte_len(sr));
                p.write_bytes_into(sr, &mut lease);
                lease.freeze()
            })
            .collect())
    }

    fn encode_right_bytes(&self, b: &[Vec<u8>]) -> anyhow::Result<Vec<PooledBuf>> {
        let ring = self.scheme.input_ring();
        let bm: Vec<Matrix<R::Elem>> = b
            .iter()
            .map(|buf| Matrix::from_bytes(ring, buf))
            .collect::<anyhow::Result<_>>()?;
        let halves = self.scheme.encode_right_batch(&bm)?;
        let sr = self.scheme.share_ring();
        let pool = BytePool::global();
        Ok(halves
            .iter()
            .map(|p| {
                let mut lease = pool.lease(p.byte_len(sr));
                p.write_bytes_into(sr, &mut lease);
                lease.freeze()
            })
            .collect())
    }

    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        self.scheme.split_upload_bytes(t, r, s)
    }

    fn left_encodes(&self) -> u64 {
        self.scheme.left_encodes()
    }

    fn compute_bytes(&self, payload: &[u8]) -> anyhow::Result<PooledBuf> {
        let sr = self.scheme.share_ring();
        let share = Share::from_bytes(sr, payload)?;
        let resp = self.scheme.worker_compute(&share)?;
        let mut lease = BytePool::global().lease(resp.byte_len(sr));
        resp.write_bytes_into(sr, &mut lease);
        Ok(lease.freeze())
    }

    fn decode_bytes(&self, responses: &[(usize, &[u8])]) -> anyhow::Result<Vec<PooledBuf>> {
        let sr = self.scheme.share_ring();
        let typed: Vec<Response<S::ShareRing>> = responses
            .iter()
            .map(|(w, p)| PlaneMatrix::from_bytes(sr, p).map(|m| (*w, m)))
            .collect::<anyhow::Result<_>>()?;
        let out = self.scheme.decode_batch(&typed)?;
        let ir = self.scheme.input_ring();
        let pool = BytePool::global();
        Ok(out
            .iter()
            .map(|m| {
                let mut lease = pool.lease(m.byte_len(ir));
                m.write_bytes_into(ir, &mut lease);
                lease.freeze()
            })
            .collect())
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.scheme.upload_bytes(t, r, s)
    }
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.scheme.download_bytes(t, r, s)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.scheme.plan_cache_stats()
    }

    fn response_is_wellformed(&self, payload: &[u8]) -> bool {
        PlaneMatrix::from_bytes(self.scheme.share_ring(), payload).is_ok()
    }

    fn check_surplus_bytes(&self, responses: &[(usize, &[u8])]) -> anyhow::Result<Vec<usize>> {
        let sr = self.scheme.share_ring();
        let typed: Vec<Response<S::ShareRing>> = responses
            .iter()
            .map(|(w, p)| PlaneMatrix::from_bytes(sr, p).map(|m| (*w, m)))
            .collect::<anyhow::Result<_>>()?;
        self.scheme.check_surplus(&typed)
    }

    fn verify_products_bytes(
        &self,
        a: &[Vec<u8>],
        b: &[Vec<u8>],
        c: &[Vec<u8>],
        trials: usize,
        rng: &mut Rng64,
    ) -> anyhow::Result<bool> {
        let ir = self.scheme.input_ring();
        let parse = |bufs: &[Vec<u8>]| -> anyhow::Result<Vec<Matrix<R::Elem>>> {
            bufs.iter().map(|buf| Matrix::from_bytes(ir, buf)).collect()
        };
        let (am, bm, cm) = (parse(a)?, parse(b)?, parse(c)?);
        self.scheme.verify_products(&am, &bm, &cm, trials, rng)
    }
}

/// Erase a typed scheme into the byte-payload facade.
pub fn erase<R, S>(scheme: Arc<S>) -> Arc<dyn DynScheme>
where
    R: Ring,
    S: DmmScheme<R> + 'static,
{
    Arc::new(Erased::new(scheme))
}

/// Partition parameters `(u, w, v)` of EP-style codes with their divisibility
/// checks, shared by several schemes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    pub u: usize,
    pub w: usize,
    pub v: usize,
}

impl Partition {
    pub fn new(u: usize, w: usize, v: usize) -> Self {
        assert!(u >= 1 && w >= 1 && v >= 1);
        Partition { u, w, v }
    }

    /// EP recovery threshold `R = uvw + w − 1`.
    pub fn recovery_threshold(&self) -> usize {
        self.u * self.v * self.w + self.w - 1
    }

    /// Validate against input shapes `A: t×r`, `B: r×s`.
    pub fn check_shapes(&self, t: usize, r: usize, s: usize) -> anyhow::Result<()> {
        anyhow::ensure!(t % self.u == 0, "u = {} must divide t = {t}", self.u);
        anyhow::ensure!(r % self.w == 0, "w = {} must divide r = {r}", self.w);
        anyhow::ensure!(s % self.v == 0, "v = {} must divide s = {s}", self.v);
        Ok(())
    }
}
