//! The common interfaces of all coded DMM / DBMM schemes, plus the exact
//! communication accounting the evaluation section reports.
//!
//! A scheme is parameterized by the *input ring* `R` (where the user's
//! matrices live, e.g. `Z_{2^64}`) and internally works over a *share ring*
//! (usually an extension `GR(p^e, d·m)` with enough exceptional points for
//! the worker count). Workers only ever see share-ring matrices.

use crate::ring::matrix::Matrix;
use crate::ring::traits::Ring;

/// The pair of encoded matrices sent to one worker: the evaluations
/// `f(α_i)`, `g(α_i)` of the master's encoding polynomials.
#[derive(Clone, Debug, PartialEq)]
pub struct Share<E> {
    pub a: Matrix<E>,
    pub b: Matrix<E>,
}

impl<E: Clone + PartialEq> Share<E> {
    /// Exact wire size of this share under the share ring's encoding.
    pub fn byte_len<R: Ring<Elem = E>>(&self, ring: &R) -> usize {
        self.a.byte_len(ring) + self.b.byte_len(ring)
    }

    pub fn to_bytes<R: Ring<Elem = E>>(&self, ring: &R) -> Vec<u8> {
        let mut out = self.a.to_bytes(ring);
        out.extend(self.b.to_bytes(ring));
        out
    }

    pub fn from_bytes<R: Ring<Elem = E>>(ring: &R, buf: &[u8]) -> Self {
        let a = Matrix::from_bytes(ring, buf);
        let b = Matrix::from_bytes(ring, &buf[a.byte_len(ring)..]);
        Share { a, b }
    }
}

/// A worker's response, tagged with its worker index.
pub type Response<E> = (usize, Matrix<E>);

/// Single coded distributed matrix multiplication: `C = A·B` from any
/// `recovery_threshold()` of `n_workers()` responses.
pub trait CodedScheme<R: Ring>: Send + Sync {
    /// The ring shares and responses live in.
    type ShareRing: Ring;

    fn name(&self) -> String;
    fn share_ring(&self) -> &Self::ShareRing;
    fn input_ring(&self) -> &R;

    /// Total number of worker nodes `N`.
    fn n_workers(&self) -> usize;

    /// Recovery threshold `R ≤ N`.
    fn recovery_threshold(&self) -> usize;

    /// Master-side encoding: one share per worker.
    fn encode(
        &self,
        a: &Matrix<R::Elem>,
        b: &Matrix<R::Elem>,
    ) -> anyhow::Result<Vec<Share<<Self::ShareRing as Ring>::Elem>>>;

    /// The worker-node computation (a small share-ring matrix product).
    fn worker_compute(
        &self,
        share: &Share<<Self::ShareRing as Ring>::Elem>,
    ) -> anyhow::Result<Matrix<<Self::ShareRing as Ring>::Elem>> {
        Ok(Matrix::matmul(self.share_ring(), &share.a, &share.b))
    }

    /// Master-side decoding from at least `recovery_threshold()` responses
    /// (any subset of workers; extra responses are ignored).
    fn decode(
        &self,
        responses: &[Response<<Self::ShareRing as Ring>::Elem>],
    ) -> anyhow::Result<Matrix<R::Elem>>;

    /// Exact total upload volume in bytes (master → all N workers) for the
    /// given input shapes — computed from the share shapes, matching what the
    /// byte-accounted transport measures on the wire.
    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize;

    /// Exact download volume in bytes (first `recovery_threshold()` workers →
    /// master).
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize;
}

/// Batch coded distributed matrix multiplication: `C_k = A_k·B_k` for a batch
/// of `batch_size()` pairs.
pub trait BatchCodedScheme<R: Ring>: Send + Sync {
    type ShareRing: Ring;

    fn name(&self) -> String;
    fn share_ring(&self) -> &Self::ShareRing;
    fn input_ring(&self) -> &R;
    fn n_workers(&self) -> usize;
    fn recovery_threshold(&self) -> usize;

    /// Number of matrix pairs multiplied per invocation.
    fn batch_size(&self) -> usize;

    fn encode_batch(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<Share<<Self::ShareRing as Ring>::Elem>>>;

    fn worker_compute(
        &self,
        share: &Share<<Self::ShareRing as Ring>::Elem>,
    ) -> anyhow::Result<Matrix<<Self::ShareRing as Ring>::Elem>> {
        Ok(Matrix::matmul(self.share_ring(), &share.a, &share.b))
    }

    fn decode_batch(
        &self,
        responses: &[Response<<Self::ShareRing as Ring>::Elem>],
    ) -> anyhow::Result<Vec<Matrix<R::Elem>>>;

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize;
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize;
}

/// Partition parameters `(u, w, v)` of EP-style codes with their divisibility
/// checks, shared by several schemes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    pub u: usize,
    pub w: usize,
    pub v: usize,
}

impl Partition {
    pub fn new(u: usize, w: usize, v: usize) -> Self {
        assert!(u >= 1 && w >= 1 && v >= 1);
        Partition { u, w, v }
    }

    /// EP recovery threshold `R = uvw + w − 1`.
    pub fn recovery_threshold(&self) -> usize {
        self.u * self.v * self.w + self.w - 1
    }

    /// Validate against input shapes `A: t×r`, `B: r×s`.
    pub fn check_shapes(&self, t: usize, r: usize, s: usize) -> anyhow::Result<()> {
        anyhow::ensure!(t % self.u == 0, "u = {} must divide t = {t}", self.u);
        anyhow::ensure!(r % self.w == 0, "w = {} must divide r = {r}", self.w);
        anyhow::ensure!(s % self.v == 0, "v = {} must divide s = {s}", self.v);
        Ok(())
    }
}
