//! The coding schemes of the paper and its baselines.
//!
//! | Module | Scheme | Paper reference |
//! |---|---|---|
//! | [`ep`] | Entangled Polynomial codes over any Galois ring (+ the *plain* embedded baseline of Lemma III.1) | [20], Lemma III.1 |
//! | [`polynomial`] | Polynomial codes (`w = 1`) | [1], Remark III.3 |
//! | [`matdot`] | MatDot codes (`u = v = 1`) | [2], Remark III.3 |
//! | [`csa`] | CSA batch codes — the runnable GCSA point (`uvw = 1, κ = n`, `R = 2n−1`) | [4], Table 1 baseline |
//! | [`batch_ep_rmfe`] | **Batch-EP_RMFE** — the paper's CDBMM | Theorem III.2 |
//! | [`ep_rmfe_i`] | **EP_RMFE-I** — single DMM, MatDot-style batch preprocessing | Corollary IV.1 |
//! | [`ep_rmfe_ii`] | **EP_RMFE-II** — single DMM, Polynomial-style batch preprocessing (incl. the φ1-only variant benchmarked in §V) | Corollary IV.2 |
//! | [`secure_matdot`] | T-private MatDot over a Galois ring — the paper's stated future work (§I) | extension |
//!
//! All schemes implement the one [`scheme::DmmScheme`] trait (single product
//! = `batch_size() == 1`), store every share/response in plane-major
//! [`crate::ring::plane::PlaneMatrix`] form, and can be erased into the
//! object-safe byte-payload facade [`scheme::DynScheme`]; [`registry`] builds
//! them by name over `Z_{2^64}` for the CLI and the experiments harness.
//!
//! Decoding is subset-aware: the interpolation setup (Lagrange basis /
//! Cauchy–Vandermonde inverse) is a pure function of the responding worker
//! subset, and every decoder memoises it in a sorted-subset-keyed
//! [`plan_cache::PlanCache`] — in steady-state serving the same fast-`R`
//! subset recurs and the setup becomes a lookup (hits/misses surfaced via
//! [`scheme::DmmScheme::plan_cache_stats`]).
//!
//! Encoding and decoding are **plan-driven** ([`encode_plan`]): the
//! scalar-mul tables the plane axpys need are precomputed once per scheme
//! (encode: per-point power tables) or once per responding subset (decode:
//! weight tables, cached alongside the interpolation setup), so the
//! steady-state hot loops build zero tables; the per-worker encode fan-out
//! and the per-block decode accumulation run on scoped threads
//! ([`crate::util::parallel`], `GR_CDMM_THREADS`), bit-identical to
//! sequential.

pub mod scheme;
pub mod plan_cache;
pub mod encode_plan;
pub mod ep;
pub mod polynomial;
pub mod matdot;
pub mod csa;
pub mod batch_ep_rmfe;
pub mod ep_rmfe_i;
pub mod ep_rmfe_ii;
pub mod secure_matdot;
pub mod registry;

pub use scheme::{erase, DmmScheme, DynScheme, Erased, Response, Share};
