//! **Batch-EP_RMFE** — the paper's coded distributed *batch* matrix
//! multiplication (Section III, Theorem III.2).
//!
//! Given batches `{A_k}` (`t×r`) and `{B_k}` (`r×s`) over `GR(p^e, d)`:
//!
//! 1. pack elementwise with the RMFE map `φ` into `𝒜, ℬ` over
//!    `GR_m = GR(p^e, d·m)` (`m ≥ max(2n−1, ⌈log_{p^d} N⌉)`) — written
//!    directly into plane-major storage ([`crate::rmfe::pack_to_planes`]);
//! 2. run EP codes over `GR_m` (partition `u, w, v`; `R = uvw + w − 1`,
//!    *independent of n* — the headline improvement over GCSA, whose
//!    threshold scales with the batch);
//! 3. unpack `𝒞 = 𝒜ℬ` elementwise with `ψ` — by `GR`-linearity of `ψ` and
//!    the RMFE product property, slot `k` of `ψ(𝒞[i,ℓ])` is
//!    `Σ_j A_k[i,j]·B_k[j,ℓ] = C_k[i,ℓ]` (the derivation in §III-A).
//!
//! Cost: one extension-ring CDMM serves `n` products — upload, download and
//! worker compute are amortized by `n` exactly as Theorem III.2 states.

use super::ep::EpCode;
use super::scheme::{DmmScheme, Response, Share};
use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;
use crate::ring::traits::Ring;
use crate::rmfe::poly_rmfe::PolyRmfe;
use crate::rmfe::{pack_to_planes, unpack_from_planes, RmfeScheme};

/// The paper's CDBMM scheme.
#[derive(Clone)]
pub struct BatchEpRmfe<R: ExtensibleRing> {
    rmfe: PolyRmfe<R>,
    ep: EpCode<Extension<R>>,
}

impl<R: ExtensibleRing> BatchEpRmfe<R> {
    /// Build for `N` workers, batch size `n`, EP partition `(u, w, v)`.
    ///
    /// The extension degree is `m = max(⌈log_{p^d} N⌉, 2n−1)`: large enough
    /// both for `N` exceptional points and for the RMFE product property.
    pub fn new(
        base: R,
        n_workers: usize,
        n_batch: usize,
        u: usize,
        w: usize,
        v: usize,
    ) -> anyhow::Result<Self> {
        // capacity for N points …
        let cap_ext = Extension::with_capacity(base.clone(), n_workers);
        let m = cap_ext.m().max(2 * n_batch - 1);
        let ext = if m == cap_ext.m() { cap_ext } else { Extension::new(base, m) };
        let rmfe = PolyRmfe::with_ext(ext.clone(), n_batch)?;
        let ep = EpCode::new(ext, n_workers, u, w, v)?;
        Ok(BatchEpRmfe { rmfe, ep })
    }

    /// Build over an explicit extension degree `m` (the paper fixes `m` by
    /// the worker count: 3 for N=8, 4 for N=16, 5 for N=32).
    pub fn with_m(
        base: R,
        m: usize,
        n_workers: usize,
        n_batch: usize,
        u: usize,
        w: usize,
        v: usize,
    ) -> anyhow::Result<Self> {
        let ext = Extension::new(base, m);
        let rmfe = PolyRmfe::with_ext(ext.clone(), n_batch)?;
        let ep = EpCode::new(ext, n_workers, u, w, v)?;
        Ok(BatchEpRmfe { rmfe, ep })
    }

    pub fn rmfe(&self) -> &PolyRmfe<R> {
        &self.rmfe
    }
    pub fn ep(&self) -> &EpCode<Extension<R>> {
        &self.ep
    }
    pub fn m(&self) -> usize {
        self.rmfe.m()
    }
}

impl<R: ExtensibleRing> DmmScheme<R> for BatchEpRmfe<R> {
    type ShareRing = Extension<R>;

    fn name(&self) -> String {
        let p = self.ep.partition();
        format!(
            "Batch-EP_RMFE(n={},m={},u={},w={},v={}) over {}",
            self.rmfe.n(),
            self.m(),
            p.u,
            p.w,
            p.v,
            self.rmfe.base().name()
        )
    }
    fn share_ring(&self) -> &Extension<R> {
        self.rmfe.ext()
    }
    fn input_ring(&self) -> &R {
        self.rmfe.base()
    }
    fn n_workers(&self) -> usize {
        self.ep.n_workers()
    }
    fn recovery_threshold(&self) -> usize {
        self.ep.recovery_threshold()
    }
    fn batch_size(&self) -> usize {
        self.rmfe.n()
    }

    fn encode_batch(
        &self,
        a: &[Matrix<R::Elem>],
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<Share<Extension<R>>>> {
        anyhow::ensure!(
            a.len() == self.batch_size() && b.len() == self.batch_size(),
            "batch size must be exactly n = {}",
            self.batch_size()
        );
        let packed_a = pack_to_planes(&self.rmfe, a);
        let packed_b = pack_to_planes(&self.rmfe, b);
        self.ep.encode_planes(&packed_a, &packed_b)
    }

    fn encode_left_batch(
        &self,
        a: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(
            a.len() == self.batch_size(),
            "batch size must be exactly n = {}",
            self.batch_size()
        );
        let packed_a = pack_to_planes(&self.rmfe, a);
        self.ep.encode_planes_left(&packed_a)
    }

    fn encode_right_batch(
        &self,
        b: &[Matrix<R::Elem>],
    ) -> anyhow::Result<Vec<PlaneMatrix<R>>> {
        anyhow::ensure!(
            b.len() == self.batch_size(),
            "batch size must be exactly n = {}",
            self.batch_size()
        );
        let packed_b = pack_to_planes(&self.rmfe, b);
        self.ep.encode_planes_right(&packed_b)
    }

    fn split_upload_bytes(&self, t: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        Some((
            self.n_workers() * self.ep.a_share_bytes(t, r),
            self.n_workers() * self.ep.b_share_bytes(r, s),
        ))
    }

    fn left_encodes(&self) -> u64 {
        self.ep.left_encode_count()
    }

    fn decode_batch(
        &self,
        responses: &[Response<Extension<R>>],
    ) -> anyhow::Result<Vec<Matrix<R::Elem>>> {
        anyhow::ensure!(!responses.is_empty(), "no responses");
        let p = self.ep.partition();
        let (bh, bw) = (responses[0].1.rows, responses[0].1.cols);
        let packed_c = self.ep.decode_planes(responses, bh * p.u, bw * p.v)?;
        Ok(unpack_from_planes(&self.rmfe, &packed_c))
    }

    fn upload_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.ep.upload_bytes(t, r, s)
    }
    fn download_bytes(&self, t: usize, r: usize, s: usize) -> usize {
        self.ep.download_bytes(t, r, s)
    }
    fn plan_cache_stats(&self) -> (u64, u64) {
        self.ep.plan_cache_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::galois::GaloisRing;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn roundtrip<R: ExtensibleRing>(
        scheme: &BatchEpRmfe<R>,
        t: usize,
        r: usize,
        s: usize,
        seed: u64,
    ) {
        let base = scheme.input_ring().clone();
        let n = scheme.batch_size();
        let mut rng = Rng64::seeded(seed);
        let a: Vec<_> = (0..n).map(|_| Matrix::random(&base, t, r, &mut rng)).collect();
        let b: Vec<_> = (0..n).map(|_| Matrix::random(&base, r, s, &mut rng)).collect();
        let shares = scheme.encode_batch(&a, &b).unwrap();
        assert_eq!(shares.len(), scheme.n_workers());
        let rt = scheme.recovery_threshold();
        // use the *last* R workers to exercise subset independence
        let responses: Vec<_> = (scheme.n_workers() - rt..scheme.n_workers())
            .map(|i| (i, scheme.worker_compute(&shares[i]).unwrap()))
            .collect();
        let c = scheme.decode_batch(&responses).unwrap();
        assert_eq!(c.len(), n);
        for k in 0..n {
            assert_eq!(c[k], Matrix::matmul(&base, &a[k], &b[k]), "slot {k}");
        }
    }

    #[test]
    fn batch2_8_workers_z2e64() {
        // n=2 over Z_2^64, N=8, u=v=2, w=1 (Fig. 2 config as a batch).
        let s = BatchEpRmfe::new(Zq::z2e(64), 8, 2, 2, 1, 2).unwrap();
        assert_eq!(s.m(), 3);
        assert_eq!(s.recovery_threshold(), 4);
        roundtrip(&s, 4, 2, 4, 131);
    }

    #[test]
    fn batch2_16_workers_z2e64() {
        let s = BatchEpRmfe::new(Zq::z2e(64), 16, 2, 2, 2, 2).unwrap();
        assert_eq!(s.m(), 4);
        assert_eq!(s.recovery_threshold(), 9);
        roundtrip(&s, 4, 4, 4, 132);
    }

    #[test]
    fn batch3_32_workers_z2e64_infinity_rmfe() {
        // §V.C: N=32 ⇒ m=5, n=3 via the (3,5)-RMFE with the ∞ point.
        let s = BatchEpRmfe::new(Zq::z2e(64), 32, 3, 2, 1, 2).unwrap();
        assert_eq!(s.m(), 5);
        assert!(s.rmfe().uses_infinity());
        roundtrip(&s, 2, 2, 2, 133);
    }

    #[test]
    fn batch_over_small_galois_field() {
        // GR(p, d) = GF(4): the "small Galois field" case — CDMM over GF(4)
        // with N=16 workers (needs m=2: 4^2 = 16).
        let base = GaloisRing::new(2, 1, 2);
        let s = BatchEpRmfe::new(base, 16, 2, 2, 2, 2).unwrap();
        roundtrip(&s, 2, 2, 2, 134);
    }

    #[test]
    fn batch_over_galois_ring_base() {
        // GR(2^16, 2) base, n=4 batch (residue field GF(4) ⇒ 4 finite pts
        // + m = max(cap, 7)).
        let base = GaloisRing::new(2, 16, 2);
        let s = BatchEpRmfe::new(base, 8, 4, 2, 1, 2).unwrap();
        roundtrip(&s, 2, 2, 2, 135);
    }

    #[test]
    fn recovery_threshold_independent_of_batch() {
        // The Table-1 headline: R does not grow with n.
        let r2 = BatchEpRmfe::new(Zq::z2e(64), 8, 2, 2, 1, 2).unwrap().recovery_threshold();
        let r3 = BatchEpRmfe::new(Zq::z2e(64), 32, 3, 2, 1, 2).unwrap().recovery_threshold();
        assert_eq!(r2, 4);
        assert_eq!(r3, 4);
    }

    #[test]
    fn split_encode_matches_joint_batch() {
        let s = BatchEpRmfe::new(Zq::z2e(64), 8, 2, 2, 1, 2).unwrap();
        let base = s.input_ring().clone();
        let mut rng = Rng64::seeded(137);
        let a: Vec<_> = (0..2).map(|_| Matrix::random(&base, 4, 2, &mut rng)).collect();
        let b: Vec<_> = (0..2).map(|_| Matrix::random(&base, 2, 4, &mut rng)).collect();
        let joint = s.encode_batch(&a, &b).unwrap();
        let left = s.encode_left_batch(&a).unwrap();
        let right = s.encode_right_batch(&b).unwrap();
        for (i, sh) in joint.iter().enumerate() {
            assert_eq!(left[i], sh.a, "worker {i} a-half");
            assert_eq!(right[i], sh.b, "worker {i} b-half");
        }
        let (sa, sb) = s.split_upload_bytes(4, 2, 4).unwrap();
        assert_eq!(sa + sb, s.upload_bytes(4, 2, 4));
        assert_eq!(s.left_encodes(), 2);
        // wrong batch sizes are rejected on both halves
        assert!(s.encode_left_batch(&a[..1]).is_err());
        assert!(s.encode_right_batch(&b[..1]).is_err());
    }

    #[test]
    fn wrong_batch_size_rejected() {
        let s = BatchEpRmfe::new(Zq::z2e(64), 8, 2, 2, 1, 2).unwrap();
        let base = Zq::z2e(64);
        let mut rng = Rng64::seeded(136);
        let a: Vec<_> = (0..3).map(|_| Matrix::random(&base, 2, 2, &mut rng)).collect();
        let b: Vec<_> = (0..3).map(|_| Matrix::random(&base, 2, 2, &mut rng)).collect();
        assert!(s.encode_batch(&a, &b).is_err());
        // and the single-product conveniences refuse a batch scheme
        assert!(s.encode(&a[0], &b[0]).is_err());
    }

    #[test]
    fn amortized_upload_is_1_over_n_of_plain() {
        // n=2: the packed upload equals what plain EP pays for ONE product,
        // but serves TWO products ⇒ amortized halving (Theorem III.2).
        use super::super::ep::PlainEp;
        let base = Zq::z2e(64);
        let batch = BatchEpRmfe::new(base.clone(), 8, 2, 2, 1, 2).unwrap();
        let plain = PlainEp::new(base, 8, 2, 1, 2).unwrap();
        let (t, r, s) = (8usize, 8, 8);
        assert_eq!(
            batch.upload_bytes(t, r, s),
            plain.upload_bytes(t, r, s),
            "same wire cost ..."
        );
        assert_eq!(batch.batch_size(), 2, "... but serving n=2 products");
    }
}
