//! Concatenated RMFE (Lemma II.5): from an `(n1, m1)`-RMFE `(φ1, ψ1)` over
//! `GR(p^e, d·m2)` and an `(n2, m2)`-RMFE `(φ2, ψ2)` over `GR(p^e, d)`,
//! build the `(n1·n2, m1·m2)`-RMFE
//!
//! ```text
//! φ = φ1 ∘ (φ2 × … × φ2)      ψ = (ψ2 × … × ψ2) ∘ ψ1
//! ```
//!
//! This lifts the `n ≤ p^d + 1` cap of a single interpolation hop: over
//! `Z_{2^e}` (where `p^d = 2`) a `(2,3) ∘ (2,3)` concatenation gives a
//! `(4, 9)`-RMFE, `(3,5) ∘ (3,5)` gives `(9, 25)`, etc. — the asymptotic
//! families of Lemma II.3 are exactly iterated concatenations.
//!
//! The composed extension is represented as the tower-of-towers
//! `Extension<Extension<R>>`; all coding schemes are generic over [`Ring`],
//! so they run over it unchanged.

use super::poly_rmfe::PolyRmfe;
use super::RmfeScheme;
use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::traits::Ring;

/// Two-level concatenated RMFE. `R` must itself be extensible and its
/// extension must be extensible again (true for `R = Zq`, the paper's
/// experimental base).
#[derive(Clone)]
pub struct ConcatRmfe<R>
where
    R: ExtensibleRing,
    Extension<R>: ExtensibleRing,
{
    /// Inner hop: `(n2, m2)` over the base.
    inner: PolyRmfe<R>,
    /// Outer hop: `(n1, m1)` over the inner extension.
    outer: PolyRmfe<Extension<R>>,
}

impl<R> ConcatRmfe<R>
where
    R: ExtensibleRing,
    Extension<R>: ExtensibleRing,
{
    /// Build the `(n1·n2, (2n1−1)(2n2−1))`-RMFE by concatenating two optimal
    /// interpolation hops.
    pub fn new(base: R, n2: usize, n1: usize) -> anyhow::Result<Self> {
        let inner = PolyRmfe::new(base, n2)?;
        let outer = PolyRmfe::new(inner.ext().clone(), n1)?;
        Ok(ConcatRmfe { inner, outer })
    }

    pub fn inner(&self) -> &PolyRmfe<R> {
        &self.inner
    }
    pub fn outer(&self) -> &PolyRmfe<Extension<R>> {
        &self.outer
    }
}

impl<R> RmfeScheme<R, Extension<Extension<R>>> for ConcatRmfe<R>
where
    R: ExtensibleRing,
    Extension<R>: ExtensibleRing,
{
    fn n(&self) -> usize {
        self.inner.n() * self.outer.n()
    }
    fn m(&self) -> usize {
        self.inner.m() * self.outer.m()
    }
    fn base(&self) -> &R {
        self.inner.base()
    }
    fn ext(&self) -> &Extension<Extension<R>> {
        self.outer.ext()
    }

    fn phi(&self, xs: &[R::Elem]) -> <Extension<Extension<R>> as Ring>::Elem {
        let n2 = self.inner.n();
        assert_eq!(xs.len(), self.n(), "phi takes n1·n2 slots");
        let mids: Vec<_> = xs.chunks(n2).map(|chunk| self.inner.phi(chunk)).collect();
        self.outer.phi(&mids)
    }

    fn psi(&self, alpha: &<Extension<Extension<R>> as Ring>::Elem) -> Vec<R::Elem> {
        let mids = self.outer.psi(alpha);
        let mut out = Vec::with_capacity(self.n());
        for mid in &mids {
            out.extend(self.inner.psi(mid));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn check<Rm, Rr, E>(rmfe: &Rm, seed: u64, iters: usize)
    where
        Rr: Ring,
        E: Ring,
        Rm: RmfeScheme<Rr, E>,
    {
        let base = rmfe.base().clone();
        let ext = rmfe.ext().clone();
        let n = rmfe.n();
        let mut rng = Rng64::seeded(seed);
        for _ in 0..iters {
            let xs: Vec<_> = (0..n).map(|_| base.random(&mut rng)).collect();
            let ys: Vec<_> = (0..n).map(|_| base.random(&mut rng)).collect();
            let prod = ext.mul(&rmfe.phi(&xs), &rmfe.phi(&ys));
            let got = rmfe.psi(&prod);
            let expect: Vec<_> = xs.iter().zip(&ys).map(|(x, y)| base.mul(x, y)).collect();
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn concat_4_9_over_z2e64() {
        // (2,3) ∘ (2,3) = (4,9) over Z_2^64 — beyond the p^d+1 = 3 cap of a
        // single hop.
        let rmfe = ConcatRmfe::new(Zq::z2e(64), 2, 2).unwrap();
        assert_eq!(rmfe.n(), 4);
        assert_eq!(rmfe.m(), 9);
        check(&rmfe, 81, 25);
    }

    #[test]
    fn concat_6_15_over_z2e64() {
        // (2,3) inner, (3,5) outer (outer hop can use ∞ over the extension).
        let rmfe = ConcatRmfe::new(Zq::z2e(64), 2, 3).unwrap();
        assert_eq!(rmfe.n(), 6);
        assert_eq!(rmfe.m(), 15);
        check(&rmfe, 82, 15);
    }

    #[test]
    fn concat_9_25_over_z2e32() {
        let rmfe = ConcatRmfe::new(Zq::z2e(32), 3, 3).unwrap();
        assert_eq!(rmfe.n(), 9);
        assert_eq!(rmfe.m(), 25);
        check(&rmfe, 83, 10);
    }

    #[test]
    fn concat_odd_characteristic() {
        let rmfe = ConcatRmfe::new(Zq::new(3, 2), 3, 4).unwrap();
        assert_eq!(rmfe.n(), 12);
        check(&rmfe, 84, 10);
    }

    #[test]
    fn psi_inverts_phi() {
        let rmfe = ConcatRmfe::new(Zq::z2e(64), 2, 2).unwrap();
        let base = rmfe.base().clone();
        let mut rng = Rng64::seeded(85);
        for _ in 0..10 {
            let xs: Vec<_> = (0..4).map(|_| base.random(&mut rng)).collect();
            assert_eq!(rmfe.psi(&rmfe.phi(&xs)), xs);
        }
    }
}
