//! The interpolation `(n, m)`-RMFE over a Galois ring (the construction
//! behind Lemma II.3, specialised to a single extension hop).
//!
//! Fix `n` points: either `n` elements `a_1,…,a_n` of the base ring's
//! exceptional set, or `n−1` such elements plus the *point at infinity*.
//! Let `GR_m = R[y]/(h)` be the degree-`m` tower with generator `γ = y`,
//! `m ≥ 2n−1`.
//!
//! * `φ(x) = f_x(γ)` where `f_x ∈ R[t]` is the unique polynomial of degree
//!   `< n` with `f_x(a_i) = x_i` (for the ∞ variant: degree `≤ n−1` with the
//!   coefficient of `t^{n−1}` equal to `x_n`). Because `deg f_x < n ≤ m`,
//!   the coefficients of `f_x` *are* the `γ`-coordinates of `φ(x)`.
//! * `ψ(α)`: write `α = g(γ)` with `deg g < m` (coordinates of `α`), output
//!   `(g(a_1), …, g(a_n))` (∞ variant: last slot is the coefficient of
//!   `t^{2n−2}`).
//!
//! Correctness: `φ(x)·φ(y) = (f_x f_y)(γ)` and `deg(f_x f_y) ≤ 2n−2 < m`, so
//! the product's `γ`-coordinates are exactly the coefficients of `f_x f_y`;
//! evaluating at `a_i` gives `x_i y_i` and the coefficient of `t^{2n−2}` is
//! the product of leading coefficients, i.e. `x_n y_n` for the ∞ variant.
//!
//! The rate `m/n → 2` matches the constant-rate guarantee of Lemma II.3; the
//! ∞ point gives e.g. the `(3,5)`-RMFE over `Z_{2^e}` mentioned in §V.C
//! (`p^d = 2` has only two finite points).

use super::RmfeScheme;
use crate::ring::eval::lagrange_basis_coeffs;
use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::poly;
use crate::ring::traits::Ring;

/// Interpolation-based RMFE. Construct via [`PolyRmfe::new`] or
/// [`PolyRmfe::with_ext`].
#[derive(Clone)]
pub struct PolyRmfe<R: ExtensibleRing> {
    base: R,
    ext: Extension<R>,
    n: usize,
    m: usize,
    /// Finite evaluation points (n, or n−1 when `use_infinity`).
    points: Vec<R::Elem>,
    use_infinity: bool,
    /// φ table: `phi_basis[i]` = coefficients (length < n, padded to n) of the
    /// i-th Lagrange basis polynomial — φ(x) = Σ_i x_i · phi_basis[i].
    /// For the ∞ slot the basis is `M(t) = Π (t − a_j)` itself.
    phi_basis: Vec<Vec<R::Elem>>,
    /// ψ table: `psi_pows[i][k] = a_i^k` for k < m — ψ_i(α) = Σ_k c_k a_i^k.
    psi_pows: Vec<Vec<R::Elem>>,
}

impl<R: ExtensibleRing> PolyRmfe<R> {
    /// `(n, m)`-RMFE with `m = 2n−1` (the optimal rate for one hop) over a
    /// fresh tower `Extension::new(base, m)`.
    ///
    /// Uses finite points only when `n ≤ p^d`; switches to `n−1` finite
    /// points + ∞ when `n = p^d + 1`. Errors for larger `n` (use
    /// [`super::concat::ConcatRmfe`]).
    pub fn new(base: R, n: usize) -> anyhow::Result<Self> {
        Self::with_m(base, n, 2 * n - 1)
    }

    /// `(n, m)`-RMFE with explicit `m ≥ 2n−1` (the paper's §V setup uses
    /// `(2, 3)` over `GR(2^64, 3)` but `(2, 4)` over `GR(2^64, 4)` — `m` is
    /// dictated by the worker count, padding the RMFE).
    pub fn with_m(base: R, n: usize, m: usize) -> anyhow::Result<Self> {
        let ext = Extension::new(base.clone(), m);
        Self::with_ext(ext, n)
    }

    /// `(n, m)`-RMFE into an existing tower (shared with the coding layer).
    pub fn with_ext(ext: Extension<R>, n: usize) -> anyhow::Result<Self> {
        let base = ext.base().clone();
        let m = ext.m();
        anyhow::ensure!(n >= 1, "n must be >= 1");
        anyhow::ensure!(
            m >= 2 * n - 1,
            "(n={n}, m={m}): RMFE needs m >= 2n-1 so products of degree-(n-1) \
             interpolants are faithfully represented"
        );
        let pd = base.residue_size();
        let use_infinity = (n as u128) > pd;
        anyhow::ensure!(
            (n as u128) <= pd + 1,
            "n = {n} exceeds p^d + 1 = {} for base {} — use ConcatRmfe (Lemma II.5)",
            pd + 1,
            base.name()
        );
        let n_finite = if use_infinity { n - 1 } else { n };
        let points = base.exceptional_points(n_finite)?;

        // φ basis: Lagrange basis over the finite points …
        let mut phi_basis = if n_finite > 0 {
            lagrange_basis_coeffs(&base, &points)
        } else {
            vec![]
        };
        // … plus M(t) = Π (t − a_j) for the ∞ slot (monic of degree n−1:
        // adds x_∞ to the leading coefficient without disturbing f(a_i)).
        if use_infinity {
            phi_basis.push(poly::from_roots(&base, &points));
        }

        // ψ powers: a_i^k for k < m.
        let mut psi_pows = Vec::with_capacity(n_finite);
        for a in &points {
            let mut row = Vec::with_capacity(m);
            let mut acc = base.one();
            for _ in 0..m {
                row.push(acc.clone());
                acc = base.mul(&acc, a);
            }
            psi_pows.push(row);
        }

        Ok(PolyRmfe { base, ext, n, m, points, use_infinity, phi_basis, psi_pows })
    }

    /// The finite evaluation points.
    pub fn points(&self) -> &[R::Elem] {
        &self.points
    }

    pub fn uses_infinity(&self) -> bool {
        self.use_infinity
    }
}

impl<R: ExtensibleRing> RmfeScheme<R, Extension<R>> for PolyRmfe<R> {
    fn n(&self) -> usize {
        self.n
    }
    fn m(&self) -> usize {
        self.m
    }
    fn base(&self) -> &R {
        &self.base
    }
    fn ext(&self) -> &Extension<R> {
        &self.ext
    }

    fn phi(&self, xs: &[R::Elem]) -> <Extension<R> as Ring>::Elem {
        assert_eq!(xs.len(), self.n, "phi takes exactly n slots");
        let mut coeffs = vec![self.base.zero(); self.m];
        for (x, basis) in xs.iter().zip(&self.phi_basis) {
            if self.base.is_zero(x) {
                continue;
            }
            for (k, c) in basis.iter().enumerate() {
                self.base.mul_add_assign(&mut coeffs[k], c, x);
            }
        }
        coeffs
    }

    fn psi(&self, alpha: &<Extension<R> as Ring>::Elem) -> Vec<R::Elem> {
        let c = self.ext.coeffs(alpha);
        let mut out = Vec::with_capacity(self.n);
        for row in &self.psi_pows {
            out.push(self.base.dot(c, row));
        }
        if self.use_infinity {
            // coefficient of t^{2n−2} (products of two degree-(n−1) leading
            // coefficients land exactly there)
            out.push(c[2 * self.n - 2].clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::galois::GaloisRing;
    use crate::ring::zq::Zq;
    use crate::util::rng::Rng64;

    fn check_rmfe_property<R: ExtensibleRing>(rmfe: &PolyRmfe<R>, seeds: u64, iters: usize) {
        let base = rmfe.base().clone();
        let ext = rmfe.ext().clone();
        let n = rmfe.n();
        let mut rng = Rng64::seeded(seeds);
        for _ in 0..iters {
            let xs: Vec<_> = (0..n).map(|_| base.random(&mut rng)).collect();
            let ys: Vec<_> = (0..n).map(|_| base.random(&mut rng)).collect();
            let prod = ext.mul(&rmfe.phi(&xs), &rmfe.phi(&ys));
            let got = rmfe.psi(&prod);
            let expect: Vec<_> = xs.iter().zip(&ys).map(|(x, y)| base.mul(x, y)).collect();
            assert_eq!(got, expect, "x⋆y = ψ(φ(x)φ(y)) violated");
        }
    }

    #[test]
    fn rmfe_2_3_over_z2e64() {
        // The paper's 8-worker configuration: (2,3)-RMFE over Z_2^64.
        let rmfe = PolyRmfe::new(Zq::z2e(64), 2).unwrap();
        assert_eq!(rmfe.m(), 3);
        assert!(!rmfe.uses_infinity());
        check_rmfe_property(&rmfe, 61, 50);
    }

    #[test]
    fn rmfe_2_4_over_z2e64() {
        // The paper's 16-worker configuration: (2,4)-RMFE (padded m).
        let rmfe = PolyRmfe::with_m(Zq::z2e(64), 2, 4).unwrap();
        assert_eq!(rmfe.m(), 4);
        check_rmfe_property(&rmfe, 62, 50);
    }

    #[test]
    fn rmfe_3_5_over_z2e64_infinity() {
        // §V.C: (3,5)-RMFE over Z_2^64 — needs the point at infinity
        // (Z_2 has only two finite exceptional points).
        let rmfe = PolyRmfe::new(Zq::z2e(64), 3).unwrap();
        assert_eq!(rmfe.m(), 5);
        assert!(rmfe.uses_infinity());
        check_rmfe_property(&rmfe, 63, 50);
    }

    #[test]
    fn rmfe_over_galois_ring_base() {
        // (4, 7)-RMFE over GR(2^16, 2): p^d = 4 finite points exactly.
        let base = GaloisRing::new(2, 16, 2);
        let rmfe = PolyRmfe::new(base, 4).unwrap();
        assert!(!rmfe.uses_infinity());
        check_rmfe_property(&rmfe, 64, 30);
    }

    #[test]
    fn rmfe_over_galois_ring_base_infinity() {
        // (5, 9)-RMFE over GR(2^16, 2): 4 finite + ∞.
        let base = GaloisRing::new(2, 16, 2);
        let rmfe = PolyRmfe::new(base, 5).unwrap();
        assert!(rmfe.uses_infinity());
        check_rmfe_property(&rmfe, 65, 30);
    }

    #[test]
    fn rmfe_over_small_field() {
        // GR(p, d) = GF(p^d): the "small Galois field" case of the paper.
        let base = GaloisRing::new(2, 1, 2); // GF(4)
        let rmfe = PolyRmfe::new(base, 4).unwrap();
        check_rmfe_property(&rmfe, 66, 30);
    }

    #[test]
    fn rmfe_odd_characteristic() {
        let rmfe = PolyRmfe::new(Zq::new(3, 4), 3).unwrap(); // 3 finite points in Z_81
        check_rmfe_property(&rmfe, 67, 30);
    }

    #[test]
    fn phi_is_linear() {
        let base = Zq::z2e(64);
        let rmfe = PolyRmfe::new(base.clone(), 2).unwrap();
        let ext = rmfe.ext().clone();
        let mut rng = Rng64::seeded(68);
        for _ in 0..20 {
            let xs: Vec<_> = (0..2).map(|_| base.random(&mut rng)).collect();
            let ys: Vec<_> = (0..2).map(|_| base.random(&mut rng)).collect();
            let s = base.random(&mut rng);
            let lhs = rmfe.phi(
                &xs.iter()
                    .zip(&ys)
                    .map(|(x, y)| base.add(x, &base.mul(&s, y)))
                    .collect::<Vec<_>>(),
            );
            let rhs = ext.add(
                &rmfe.phi(&xs),
                &ext.mul(&ext.from_base(&s), &rmfe.phi(&ys)),
            );
            assert_eq!(lhs, rhs);
        }
    }

    #[test]
    fn psi_inverts_phi_finite_points() {
        // ψ∘φ = id holds for the finite-point variant (evaluating the
        // interpolant recovers the slots). NOTE: it intentionally does *not*
        // hold for the ∞ variant — ψ's last slot reads the coefficient of
        // t^{2n−2}, which is only meaningful on *products* (the only thing
        // Definition II.2 requires).
        let rmfe = PolyRmfe::new(Zq::z2e(64), 2).unwrap();
        let base = rmfe.base().clone();
        let mut rng = Rng64::seeded(69);
        for _ in 0..20 {
            let xs: Vec<_> = (0..2).map(|_| base.random(&mut rng)).collect();
            assert_eq!(rmfe.psi(&rmfe.phi(&xs)), xs);
        }
        // ∞ variant: the product property (checked in rmfe_3_5_…) is the
        // contract; ψ∘φ = id is not.
        let rmfe3 = PolyRmfe::new(Zq::z2e(64), 3).unwrap();
        let one = vec![base.one(), base.one(), base.one()];
        let packed = rmfe3.phi(&one);
        let ext = rmfe3.ext().clone();
        let prod = ext.mul(&packed, &rmfe3.phi(&one));
        assert_eq!(rmfe3.psi(&prod), one, "1⋆1 = 1 via the product path");
    }

    #[test]
    fn rejects_undersized_m() {
        assert!(PolyRmfe::with_m(Zq::z2e(64), 2, 2).is_err());
        assert!(PolyRmfe::with_m(Zq::z2e(64), 3, 4).is_err());
    }

    #[test]
    fn rejects_oversized_n() {
        // Z_2^e supports at most n = 3 (2 finite + ∞).
        assert!(PolyRmfe::new(Zq::z2e(64), 4).is_err());
    }

    #[test]
    fn matrix_pack_unpack_roundtrip() {
        use crate::ring::matrix::Matrix;
        let rmfe = PolyRmfe::new(Zq::z2e(64), 2).unwrap();
        let base = rmfe.base().clone();
        let mut rng = Rng64::seeded(70);
        let mats: Vec<_> = (0..2).map(|_| Matrix::random(&base, 3, 4, &mut rng)).collect();
        let packed = rmfe.pack_matrices(&mats);
        let un = rmfe.unpack_matrix(&packed);
        assert_eq!(un, mats);
    }

    #[test]
    fn matrix_product_hadamard_property() {
        // The core of Section III-A: ψ applied entrywise to 𝒜·ℬ recovers
        // the batch of products A_k · B_k.
        use crate::ring::matrix::Matrix;
        let rmfe = PolyRmfe::new(Zq::z2e(64), 2).unwrap();
        let base = rmfe.base().clone();
        let ext = rmfe.ext().clone();
        let mut rng = Rng64::seeded(71);
        let as_: Vec<_> = (0..2).map(|_| Matrix::random(&base, 3, 5, &mut rng)).collect();
        let bs: Vec<_> = (0..2).map(|_| Matrix::random(&base, 5, 2, &mut rng)).collect();
        let pa = rmfe.pack_matrices(&as_);
        let pb = rmfe.pack_matrices(&bs);
        let pc = Matrix::matmul(&ext, &pa, &pb);
        let cs = rmfe.unpack_matrix(&pc);
        for k in 0..2 {
            assert_eq!(cs[k], Matrix::matmul(&base, &as_[k], &bs[k]), "slot {k}");
        }
    }
}
