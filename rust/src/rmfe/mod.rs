//! Reverse Multiplication-Friendly Embeddings (Definition II.2).
//!
//! An `(n, m)`-RMFE over `GR(p^e, d)` is a pair of `GR(p^e, d)`-linear maps
//!
//! ```text
//! φ : GR(p^e, d)^n → GR(p^e, d·m)      ψ : GR(p^e, d·m) → GR(p^e, d)^n
//! ```
//!
//! with `x ⋆ y = ψ(φ(x)·φ(y))` for all vectors `x, y` (coordinatewise
//! product). This is the tool that amortizes the `O(m)` extension-ring
//! overhead across a batch of `n` multiplications (Section III-A).
//!
//! * [`poly_rmfe`] — the interpolation construction: `m ≥ 2n−1`, supporting
//!   `n ≤ p^d` finite evaluation points plus optionally the point at infinity
//!   (`n ≤ p^d + 1`), e.g. the `(3,5)`-RMFE over `Z_{2^e}` used in §V.C.
//! * [`concat`] — concatenation (Lemma II.5): `(n1 n2, m1 m2)`-RMFE from an
//!   `(n1, m1)`-RMFE over the extension and an `(n2, m2)`-RMFE over the base,
//!   for batch sizes beyond `p^d + 1`.

pub mod poly_rmfe;
pub mod concat;

use crate::ring::extension::Extension;
use crate::ring::galois::ExtensibleRing;
use crate::ring::matrix::Matrix;
use crate::ring::plane::PlaneMatrix;
use crate::ring::traits::Ring;

pub use poly_rmfe::PolyRmfe;
pub use concat::ConcatRmfe;

/// Common interface of RMFE constructions: base ring `R`, extension ring `E`
/// (with `[E : R] = m`), and the pair of linear maps.
pub trait RmfeScheme<R: Ring, E: Ring>: Send + Sync {
    /// Number of packed slots `n`.
    fn n(&self) -> usize;
    /// Extension degree `m` (so `E = GR(p^e, d·m)`).
    fn m(&self) -> usize;
    fn base(&self) -> &R;
    fn ext(&self) -> &E;

    /// The packing map `φ` (base-linear). `xs.len()` must equal `n`.
    fn phi(&self, xs: &[R::Elem]) -> E::Elem;

    /// The unpacking map `ψ` (base-linear). Returns `n` base elements.
    fn psi(&self, alpha: &E::Elem) -> Vec<R::Elem>;

    /// Pack a batch of `n` equal-shaped matrices elementwise:
    /// `out[i,j] = φ(mats[0][i,j], …, mats[n−1][i,j])` (Section III-A,
    /// the construction of `𝒜` and `ℬ` from `{A_k}`, `{B_k}`).
    fn pack_matrices(&self, mats: &[Matrix<R::Elem>]) -> Matrix<E::Elem> {
        assert_eq!(mats.len(), self.n(), "need exactly n matrices");
        let rows = mats[0].rows;
        let cols = mats[0].cols;
        for m in mats {
            assert_eq!((m.rows, m.cols), (rows, cols), "matrices must be equal-shaped");
        }
        let mut slot = vec![self.base().zero(); self.n()];
        Matrix::from_fn(rows, cols, |i, j| {
            for (k, mk) in mats.iter().enumerate() {
                slot[k] = mk.at(i, j).clone();
            }
            self.phi(&slot)
        })
    }

    /// Unpack a matrix of extension elements into `n` base matrices
    /// (elementwise `ψ`).
    fn unpack_matrix(&self, packed: &Matrix<E::Elem>) -> Vec<Matrix<R::Elem>> {
        let rows = packed.rows;
        let cols = packed.cols;
        let mut outs: Vec<Matrix<R::Elem>> = (0..self.n())
            .map(|_| Matrix::zeros(self.base(), rows, cols))
            .collect();
        for i in 0..rows {
            for j in 0..cols {
                let vals = self.psi(packed.at(i, j));
                for (k, v) in vals.into_iter().enumerate() {
                    outs[k].set(i, j, v);
                }
            }
        }
        outs
    }
}

/// Pack a batch of `n` equal-shaped base matrices elementwise with `φ`,
/// writing straight into plane-major storage over the extension —
/// `out[k·rows·cols + idx]` is coefficient `k` of `φ(mats[0][idx], …,
/// mats[n−1][idx])`. This is the Section III-A construction of `𝒜`/`ℬ`
/// without ever materializing an AoS extension matrix.
pub fn pack_to_planes<R, S>(rmfe: &S, mats: &[Matrix<R::Elem>]) -> PlaneMatrix<R>
where
    R: ExtensibleRing,
    S: RmfeScheme<R, Extension<R>> + ?Sized,
{
    assert_eq!(mats.len(), rmfe.n(), "need exactly n matrices");
    let m = rmfe.m();
    let rows = mats[0].rows;
    let cols = mats[0].cols;
    for mk in mats {
        assert_eq!((mk.rows, mk.cols), (rows, cols), "matrices must be equal-shaped");
    }
    let base = rmfe.base();
    let pp = rows * cols;
    let mut data = vec![base.zero(); m * pp];
    let mut slot = vec![base.zero(); rmfe.n()];
    for idx in 0..pp {
        for (k, mk) in mats.iter().enumerate() {
            slot[k] = mk.data[idx].clone();
        }
        let packed = rmfe.phi(&slot);
        for (k, c) in packed.into_iter().enumerate() {
            data[k * pp + idx] = c;
        }
    }
    PlaneMatrix { rows, cols, planes: m, data }
}

/// Inverse of [`pack_to_planes`]: unpack a plane-major extension matrix into
/// `n` base matrices with elementwise `ψ` (gathering each element's `m`
/// coefficients from the planes).
pub fn unpack_from_planes<R, S>(rmfe: &S, packed: &PlaneMatrix<R>) -> Vec<Matrix<R::Elem>>
where
    R: ExtensibleRing,
    S: RmfeScheme<R, Extension<R>> + ?Sized,
{
    let m = rmfe.m();
    assert_eq!(packed.planes, m, "plane count must equal the RMFE's m");
    let (rows, cols) = (packed.rows, packed.cols);
    let pp = rows * cols;
    let base = rmfe.base();
    let mut outs: Vec<Vec<R::Elem>> = (0..rmfe.n()).map(|_| Vec::with_capacity(pp)).collect();
    let mut coeffs: Vec<R::Elem> = vec![base.zero(); m];
    for idx in 0..pp {
        for (k, c) in coeffs.iter_mut().enumerate() {
            *c = packed.data[k * pp + idx].clone();
        }
        let vals = rmfe.psi(&coeffs);
        for (k, v) in vals.into_iter().enumerate() {
            outs[k].push(v);
        }
    }
    outs.into_iter().map(|d| Matrix::from_vec(rows, cols, d)).collect()
}
