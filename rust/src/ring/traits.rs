//! The [`Ring`] trait: the interface every Galois ring in this crate exposes.
//!
//! A *Galois ring* `GR(p^e, D)` is a finite local ring of characteristic `p^e`
//! whose residue field is `GF(p^D)`. Three implementations exist:
//!
//! * [`crate::ring::zq::Zq`] — `GR(p^e, 1) = Z_{p^e}` (fast scalar path,
//!   including wrap-around `Z_{2^64}`),
//! * [`crate::ring::galois::GaloisRing`] — `GR(p^e, d) = Z_{p^e}[x]/(f)`,
//! * [`crate::ring::extension::Extension`] — a tower `R[y]/(h)` over another
//!   Galois ring `R`, i.e. `GR(p^e, d·m)` *presented as a degree-m extension
//!   of* `GR(p^e, d)`. RMFE (and hence all the paper's schemes) need this
//!   presentation.
//!
//! Inversion is provided generically: for a unit `a`, `a mod p` is invertible
//! in the residue field `GF(p^D)`, so `a^(p^D − 2)` computed *in the ring*
//! lifts the residue inverse; Newton–Hensel iteration `x ← x(2 − ax)` then
//! doubles the p-adic precision until `p^e`. This costs `O(log(p^D) + log e)`
//! ring multiplications and requires no per-ring code.

use crate::util::rng::Rng64;

/// A finite Galois ring `GR(p^e, D)`.
///
/// Ring structs are lightweight *contexts* (moduli, precomputed tables);
/// elements are plain data manipulated through the context. This keeps
/// elements compact (`u64`, `Vec<u64>`, …) and lets one context serve
/// millions of elements.
pub trait Ring: Clone + Send + Sync + 'static {
    /// Element representation.
    type Elem: Clone + PartialEq + Send + Sync + std::fmt::Debug + 'static;

    /// The characteristic prime `p`.
    fn p(&self) -> u64;

    /// The exponent `e` (characteristic is `p^e`).
    fn e(&self) -> u32;

    /// Total extension degree `D` over `Z_{p^e}` (so the residue field is
    /// `GF(p^D)`). `Zq` has `D = 1`; a tower `Extension` multiplies degrees.
    fn degree(&self) -> usize;

    /// Size of the residue field, `p^D`, as `u128`.
    ///
    /// Panics if `p^D` overflows `u128` (never the case for practical
    /// parameters: exceptional sets only need `p^D ≥ N` ≈ dozens).
    fn residue_size(&self) -> u128 {
        let p = self.p() as u128;
        let mut acc: u128 = 1;
        for _ in 0..self.degree() {
            acc = acc.checked_mul(p).expect("residue field size overflows u128");
        }
        acc
    }

    fn zero(&self) -> Self::Elem;
    fn one(&self) -> Self::Elem;
    fn add(&self, a: &Self::Elem, b: &Self::Elem) -> Self::Elem;
    fn sub(&self, a: &Self::Elem, b: &Self::Elem) -> Self::Elem;
    fn neg(&self, a: &Self::Elem) -> Self::Elem;
    fn mul(&self, a: &Self::Elem, b: &Self::Elem) -> Self::Elem;

    fn is_zero(&self, a: &Self::Elem) -> bool;

    /// Unit test. In a Galois ring `a` is a unit ⟺ `a ≢ 0 (mod p)` (the
    /// residue field is a field, so nonzero residue ⟺ invertible residue).
    fn is_unit(&self, a: &Self::Elem) -> bool;

    /// In-place add: `a += b`. Override for performance.
    #[inline]
    fn add_assign(&self, a: &mut Self::Elem, b: &Self::Elem) {
        *a = self.add(a, b);
    }

    /// In-place fused multiply-add: `acc += a·b`. Override for performance —
    /// this is the matmul inner loop.
    #[inline]
    fn mul_add_assign(&self, acc: &mut Self::Elem, a: &Self::Elem, b: &Self::Elem) {
        let t = self.mul(a, b);
        self.add_assign(acc, &t);
    }

    /// `a^n` by square-and-multiply.
    fn pow_u128(&self, a: &Self::Elem, mut n: u128) -> Self::Elem {
        let mut base = a.clone();
        let mut acc = self.one();
        while n > 0 {
            if n & 1 == 1 {
                acc = self.mul(&acc, &base);
            }
            n >>= 1;
            if n > 0 {
                base = self.mul(&base, &base);
            }
        }
        acc
    }

    /// Multiplicative inverse of a unit; `None` for non-units.
    ///
    /// Generic algorithm (see module docs): Fermat in the residue field,
    /// lifted by Newton–Hensel. Override only as a performance optimisation.
    fn inv(&self, a: &Self::Elem) -> Option<Self::Elem> {
        if !self.is_unit(a) {
            return None;
        }
        // x0 ≡ (a mod p)^{-1} (mod p): Fermat little theorem in GF(p^D),
        // computed in the ring (the computation commutes with reduction mod p).
        let rs = self.residue_size();
        let mut x = self.pow_u128(a, rs - 2);
        // Newton–Hensel: x_{k+1} = x_k (2 − a x_k); precision doubles each step.
        let two = self.add(&self.one(), &self.one());
        let mut prec: u64 = 1;
        while prec < self.e() as u64 {
            let ax = self.mul(a, &x);
            let corr = self.sub(&two, &ax);
            x = self.mul(&x, &corr);
            prec *= 2;
        }
        debug_assert!(self.mul(a, &x) == self.one(), "inverse failed");
        Some(x)
    }

    /// First `n` points of the canonical *exceptional set*: a set of elements
    /// whose pairwise differences are all units (Section II-B). We use digit
    /// lifts of distinct residue-field elements, so up to `p^D` points exist.
    ///
    /// Returns an error if `n > p^D`.
    fn exceptional_points(&self, n: usize) -> anyhow::Result<Vec<Self::Elem>>;

    /// Serialized size of one element in bytes (used for exact communication
    /// accounting; the paper counts "elements of GR", we count bytes).
    fn elem_bytes(&self) -> usize;

    /// Append the canonical byte serialization of `a` to `out`.
    fn write_elem(&self, a: &Self::Elem, out: &mut Vec<u8>);

    /// Read one element back; advances `pos`.
    fn read_elem(&self, buf: &[u8], pos: &mut usize) -> Self::Elem;

    /// Append the canonical serialization of a whole slice. Default is the
    /// per-element loop; rings with a fixed-width machine representation
    /// override with a single block copy (`Zq`: one little-endian `u64`
    /// block — the plane-major wire hot path).
    fn write_slice(&self, xs: &[Self::Elem], out: &mut Vec<u8>) {
        for x in xs {
            self.write_elem(x, out);
        }
    }

    /// Read `count` elements back, advancing `pos`. Same caller contract as
    /// [`Ring::read_elem`]: the caller must have validated that
    /// `count · elem_bytes()` bytes are available (the deserializers in
    /// [`crate::ring::matrix`] / [`crate::ring::plane`] check lengths
    /// against the header before reading).
    fn read_slice(&self, buf: &[u8], pos: &mut usize, count: usize) -> Vec<Self::Elem> {
        (0..count).map(|_| self.read_elem(buf, pos)).collect()
    }

    /// Uniformly random element.
    fn random(&self, rng: &mut Rng64) -> Self::Elem;

    /// Human-readable ring name, e.g. `GR(2^64, 3)`.
    fn name(&self) -> String;

    /// Sum of a slice.
    fn sum(&self, xs: &[Self::Elem]) -> Self::Elem {
        let mut acc = self.zero();
        for x in xs {
            self.add_assign(&mut acc, x);
        }
        acc
    }

    /// Dot product of two equal-length slices.
    fn dot(&self, xs: &[Self::Elem], ys: &[Self::Elem]) -> Self::Elem {
        debug_assert_eq!(xs.len(), ys.len());
        let mut acc = self.zero();
        for (x, y) in xs.iter().zip(ys) {
            self.mul_add_assign(&mut acc, x, y);
        }
        acc
    }

    /// Slice kernel hook: `acc[j] += s·x[j]` — the innermost encode/decode
    /// op ([`crate::ring::plane`] table axpys and modulus reductions bottom
    /// out here). Default is the per-element scalar loop; rings with a
    /// machine-word representation override it to dispatch into the
    /// runtime-selected SIMD kernel table ([`crate::ring::arch`] — `Zq`
    /// today). Every override must be bit-identical to this default.
    fn slice_axpy_assign(&self, acc: &mut [Self::Elem], s: &Self::Elem, x: &[Self::Elem]) {
        debug_assert_eq!(acc.len(), x.len());
        for (a, b) in acc.iter_mut().zip(x) {
            self.mul_add_assign(a, s, b);
        }
    }

    /// Slice kernel hook: `xs[j] = xs[j]·s` in place (the scalar-matrix
    /// scale). Same override contract as [`Ring::slice_axpy_assign`].
    fn slice_scale_assign(&self, xs: &mut [Self::Elem], s: &Self::Elem) {
        for x in xs.iter_mut() {
            *x = self.mul(x, s);
        }
    }

    /// Slice kernel hook: `c += a·b` over row-major slices (`a: ar×ac`,
    /// `b: ac×bc`, `c: ar×bc`) — the dense matmul step every worker share
    /// product bottoms out in. Cache-friendly ikj order with 64-row
    /// k-panels of `b` (§Perf iteration 2: +10–15% at 512³ over plain ikj).
    ///
    /// The `a_ik` zero-skip is hoisted out of the dense path (PR 7
    /// satellite): each panel row of `a` is probed once, and the zero-free
    /// (dense) case runs with no branch in the `k` loop at all. Skipping a
    /// zero `a_ik` is bitwise a no-op (`acc + 0·b` returns `acc`'s exact
    /// representation in every ring here), so both paths are bit-identical
    /// to the original always-branching loop — property-tested against the
    /// verbatim old loop in `property_tests.rs`.
    ///
    /// `Zq` overrides this to dispatch into [`crate::ring::arch`].
    fn slice_mat_mul_acc(
        &self,
        c: &mut [Self::Elem],
        a: &[Self::Elem],
        b: &[Self::Elem],
        ar: usize,
        ac: usize,
        bc: usize,
    ) {
        debug_assert_eq!(a.len(), ar * ac);
        debug_assert_eq!(b.len(), ac * bc);
        debug_assert_eq!(c.len(), ar * bc);
        const KB: usize = 64;
        let mut k0 = 0;
        while k0 < ac {
            let kend = (k0 + KB).min(ac);
            for i in 0..ar {
                let arow = &a[i * ac + k0..i * ac + kend];
                let crow = &mut c[i * bc..(i + 1) * bc];
                if arow.iter().any(|aik| self.is_zero(aik)) {
                    // sparse panel row: keep the per-a_ik skip
                    for (k, aik) in arow.iter().enumerate() {
                        if self.is_zero(aik) {
                            continue;
                        }
                        let brow = &b[(k0 + k) * bc..(k0 + k + 1) * bc];
                        for (cj, bj) in crow.iter_mut().zip(brow) {
                            self.mul_add_assign(cj, aik, bj);
                        }
                    }
                } else {
                    // dense panel row: branch-free sweep
                    for (k, aik) in arow.iter().enumerate() {
                        let brow = &b[(k0 + k) * bc..(k0 + k + 1) * bc];
                        for (cj, bj) in crow.iter_mut().zip(brow) {
                            self.mul_add_assign(cj, aik, bj);
                        }
                    }
                }
            }
            k0 = kend;
        }
    }

    /// Matrix product hook. The default delegates to
    /// [`Ring::slice_mat_mul_acc`] on the flat element storage (so scalar
    /// rings inherit the dispatched slice kernel); structured rings
    /// override it (e.g. `Extension` decomposes into `m²` *base-ring*
    /// matmuls plus a modulus reduction — the §Perf optimization that
    /// removed per-element `Vec` traffic from the worker hot path).
    fn mat_mul(
        &self,
        a: &crate::ring::matrix::Matrix<Self::Elem>,
        b: &crate::ring::matrix::Matrix<Self::Elem>,
    ) -> crate::ring::matrix::Matrix<Self::Elem>
    where
        Self::Elem: PartialEq,
    {
        assert_eq!(a.cols, b.rows, "inner dimensions must agree");
        let mut c = crate::ring::matrix::Matrix::zeros(self, a.rows, b.cols);
        self.slice_mat_mul_acc(&mut c.data, &a.data, &b.data, a.rows, a.cols, b.cols);
        c
    }

    /// Matrix scale-accumulate hook: `acc += s · x`. Default delegates to
    /// the [`Ring::slice_axpy_assign`] slice kernel (dispatched for `Zq`);
    /// `Extension` overrides with a plane decomposition (encode/decode hot
    /// path — Horner steps and interpolation weights are exactly this op).
    fn mat_axpy(
        &self,
        acc: &mut crate::ring::matrix::Matrix<Self::Elem>,
        s: &Self::Elem,
        x: &crate::ring::matrix::Matrix<Self::Elem>,
    ) where
        Self::Elem: PartialEq,
    {
        assert_eq!((acc.rows, acc.cols), (x.rows, x.cols));
        if self.is_zero(s) {
            return;
        }
        self.slice_axpy_assign(&mut acc.data, s, &x.data);
    }
}

/// Check that a slice of points is pairwise-difference-invertible (an
/// exceptional sequence). Used in debug assertions and tests.
pub fn is_exceptional_sequence<R: Ring>(ring: &R, pts: &[R::Elem]) -> bool {
    for i in 0..pts.len() {
        for j in (i + 1)..pts.len() {
            let d = ring.sub(&pts[i], &pts[j]);
            if !ring.is_unit(&d) {
                return false;
            }
        }
    }
    true
}
