//! Plane-major storage for extension-ring matrices — the wire/worker format.
//!
//! An extension-ring matrix over `GR_m = R[y]/(h)` is algebraically a stack
//! of `m` *coefficient planes*, each a plain matrix over the base ring `R`.
//! The AoS representation ([`Matrix`]`<Vec<R::Elem>>`) pays one heap
//! allocation per element and scatters each plane across memory;
//! [`PlaneMatrix`] stores the same data as one flat plane-major `Vec`
//! (`data[k·rows·cols + i·cols + j]` = coefficient `k` of entry `(i, j)`),
//! so that:
//!
//! * [`PlaneMatrix::plane`] is a zero-copy slice view of one base-ring plane;
//! * the worker share product runs plane-by-plane through the base ring's
//!   contiguous ikj kernel (monomorphized `u64` loops for `Zq`) plus one
//!   modulus reduction — no per-element `Vec` traffic;
//! * encode/decode Horner steps and interpolation weights are `m²`
//!   scalar-times-slice axpys via a precomputed scalar multiplication table;
//! * serialization is a single contiguous block, already in the layout the
//!   AOT XLA artifacts consume (`(m, rows, cols)` u64 planes for
//!   `GR(2^64, m)` — see [`crate::runtime::gr_backend`]).
//!
//! [`PlaneRing`] is the small capability trait that lets any ring act as a
//! plane decomposition: scalar rings ([`Zq`], [`GaloisRing`]) are their own
//! single plane, a tower [`Extension`] exposes its `m` coefficient planes
//! over its base. Every scheme in [`crate::codes`] stores shares and
//! responses as `PlaneMatrix` over `ShareRing::Base`.

use super::extension::Extension;
use super::galois::{ExtensibleRing, GaloisRing, GrElem};
use super::matrix::Matrix;
use super::traits::Ring;
use super::zq::Zq;
use crate::util::rng::Rng64;

/// A ring whose elements decompose into `plane_count()` coefficients over a
/// base ring — the capability [`PlaneMatrix`] kernels are generic over.
///
/// Scalar rings are their own (single) plane; [`Extension`] towers expose
/// their `m` coefficient planes. The monic modulus enters only through
/// [`PlaneRing::modulus_low`], which the matmul kernel uses for the final
/// plane-level reduction (`y^k ≡ −Σ_i h_i·y^{k−m+i}`).
pub trait PlaneRing: Ring {
    /// The base ring one plane lives over (`Self` for scalar rings).
    type Base: Ring;

    /// The base-ring context.
    fn plane_base(&self) -> &Self::Base;

    /// Number of coefficient planes `m` (`1` for scalar rings).
    fn plane_count(&self) -> usize;

    /// Low `m` coefficients of the monic degree-`m` modulus (empty when
    /// `plane_count() == 1` — a scalar ring has nothing to reduce by).
    fn modulus_low(&self) -> &[<Self::Base as Ring>::Elem];

    /// Coefficient `k` of an element (`0 ≤ k < plane_count()`).
    fn coeff(&self, a: &Self::Elem, k: usize) -> <Self::Base as Ring>::Elem;

    /// Rebuild an element from its coefficients (length `plane_count()`).
    fn elem_from_coeffs(&self, coeffs: &[<Self::Base as Ring>::Elem]) -> Self::Elem;

    /// Row-major `m × m` multiplication table of the scalar `s`: column `j`
    /// holds the coefficients of `s·y^j mod h`, so multiplying an element by
    /// `s` maps its coefficient vector `x` to `table·x`. This is what turns a
    /// scalar-times-matrix axpy into `m²` base-ring slice axpys with the
    /// modulus reduction folded in (and into the single entry `[s]` for
    /// scalar rings).
    fn scalar_mul_table(&self, s: &Self::Elem) -> Vec<<Self::Base as Ring>::Elem> {
        let m = self.plane_count();
        let base = self.plane_base();
        let mut cur: Vec<<Self::Base as Ring>::Elem> = (0..m).map(|k| self.coeff(s, k)).collect();
        let mut table = vec![base.zero(); m * m];
        for j in 0..m {
            for (k, c) in cur.iter().enumerate() {
                table[k * m + j] = c.clone();
            }
            if j + 1 < m {
                // cur ← cur·y mod h: shift up one degree, fold the overflow
                // coefficient back with the monic modulus.
                let top = cur[m - 1].clone();
                for k in (1..m).rev() {
                    cur[k] = cur[k - 1].clone();
                }
                cur[0] = base.zero();
                if !base.is_zero(&top) {
                    for (i, h) in self.modulus_low().iter().enumerate() {
                        if !base.is_zero(h) {
                            let d = base.mul(&top, h);
                            cur[i] = base.sub(&cur[i], &d);
                        }
                    }
                }
            }
        }
        table
    }
}

impl PlaneRing for Zq {
    type Base = Zq;
    fn plane_base(&self) -> &Zq {
        self
    }
    fn plane_count(&self) -> usize {
        1
    }
    fn modulus_low(&self) -> &[u64] {
        &[]
    }
    fn coeff(&self, a: &u64, k: usize) -> u64 {
        debug_assert_eq!(k, 0);
        *a
    }
    fn elem_from_coeffs(&self, coeffs: &[u64]) -> u64 {
        coeffs[0]
    }
}

impl PlaneRing for GaloisRing {
    type Base = GaloisRing;
    fn plane_base(&self) -> &GaloisRing {
        self
    }
    fn plane_count(&self) -> usize {
        1
    }
    fn modulus_low(&self) -> &[GrElem] {
        &[]
    }
    fn coeff(&self, a: &GrElem, k: usize) -> GrElem {
        debug_assert_eq!(k, 0);
        a.clone()
    }
    fn elem_from_coeffs(&self, coeffs: &[GrElem]) -> GrElem {
        coeffs[0].clone()
    }
}

impl<R: ExtensibleRing> PlaneRing for Extension<R> {
    type Base = R;
    fn plane_base(&self) -> &R {
        self.base()
    }
    fn plane_count(&self) -> usize {
        self.m()
    }
    fn modulus_low(&self) -> &[R::Elem] {
        &self.modulus()[..self.m()]
    }
    fn coeff(&self, a: &Self::Elem, k: usize) -> R::Elem {
        a[k].clone()
    }
    fn elem_from_coeffs(&self, coeffs: &[R::Elem]) -> Self::Elem {
        self.from_coeffs(coeffs)
    }
}

/// `acc += s·x` over base-ring slices — the innermost encode/decode op.
#[inline]
pub fn slice_axpy<B: Ring>(base: &B, acc: &mut [B::Elem], s: &B::Elem, x: &[B::Elem]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        base.mul_add_assign(a, s, b);
    }
}

/// `c += a·b` over base-ring slices (`a: ar×ac`, `b: ac×bc`, `c: ar×bc`,
/// all row-major). The cache-friendly ikj order with 64-row k-panels of `b`
/// — identical structure to [`Ring::mat_mul`]'s default, monomorphizing to
/// straight-line `u64` code for [`Zq`].
pub fn slice_matmul_acc<B: Ring>(
    base: &B,
    c: &mut [B::Elem],
    a: &[B::Elem],
    b: &[B::Elem],
    ar: usize,
    ac: usize,
    bc: usize,
) {
    debug_assert_eq!(a.len(), ar * ac);
    debug_assert_eq!(b.len(), ac * bc);
    debug_assert_eq!(c.len(), ar * bc);
    const KB: usize = 64;
    let mut k0 = 0;
    while k0 < ac {
        let kend = (k0 + KB).min(ac);
        for i in 0..ar {
            let crow = &mut c[i * bc..(i + 1) * bc];
            for k in k0..kend {
                let aik = &a[i * ac + k];
                if base.is_zero(aik) {
                    continue;
                }
                let brow = &b[k * bc..(k + 1) * bc];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    base.mul_add_assign(cj, aik, bj);
                }
            }
        }
        k0 = kend;
    }
}

/// An extension-ring matrix stored as `planes` contiguous base-ring
/// coefficient planes (plane-major): `data[k·rows·cols + i·cols + j]` is
/// coefficient `k` of entry `(i, j)`.
///
/// This is the storage for everything on the encode → wire → worker → decode
/// path; [`Matrix`] remains the element-generic AoS type for user-facing
/// inputs/outputs and scalar-sized internal systems.
pub struct PlaneMatrix<B: Ring> {
    pub rows: usize,
    pub cols: usize,
    /// Number of coefficient planes (`= plane_count()` of the plane ring).
    pub planes: usize,
    /// Flat plane-major storage, length `planes·rows·cols`.
    pub data: Vec<B::Elem>,
}

impl<B: Ring> Clone for PlaneMatrix<B> {
    fn clone(&self) -> Self {
        PlaneMatrix {
            rows: self.rows,
            cols: self.cols,
            planes: self.planes,
            data: self.data.clone(),
        }
    }
}

impl<B: Ring> PartialEq for PlaneMatrix<B> {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.planes == other.planes
            && self.data == other.data
    }
}

impl<B: Ring> std::fmt::Debug for PlaneMatrix<B> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlaneMatrix")
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("planes", &self.planes)
            .field("data", &self.data)
            .finish()
    }
}

impl<B: Ring> PlaneMatrix<B> {
    /// Elements per plane.
    #[inline]
    pub fn plane_len(&self) -> usize {
        self.rows * self.cols
    }

    /// Zero-copy view of coefficient plane `k`.
    #[inline]
    pub fn plane(&self, k: usize) -> &[B::Elem] {
        let pp = self.plane_len();
        &self.data[k * pp..(k + 1) * pp]
    }

    /// Mutable view of coefficient plane `k`.
    #[inline]
    pub fn plane_mut(&mut self, k: usize) -> &mut [B::Elem] {
        let pp = self.plane_len();
        &mut self.data[k * pp..(k + 1) * pp]
    }

    /// All-zero matrix with `ext.plane_count()` planes.
    pub fn zeros<E: PlaneRing<Base = B>>(ext: &E, rows: usize, cols: usize) -> Self {
        let m = ext.plane_count();
        PlaneMatrix {
            rows,
            cols,
            planes: m,
            data: vec![ext.plane_base().zero(); m * rows * cols],
        }
    }

    /// Uniformly random matrix (same distribution as AoS
    /// [`Matrix::random`] over the plane ring: independent uniform planes).
    pub fn random<E: PlaneRing<Base = B>>(
        ext: &E,
        rows: usize,
        cols: usize,
        rng: &mut Rng64,
    ) -> Self {
        let m = ext.plane_count();
        let base = ext.plane_base();
        PlaneMatrix {
            rows,
            cols,
            planes: m,
            data: (0..m * rows * cols).map(|_| base.random(rng)).collect(),
        }
    }

    /// Convert from the AoS representation (one allocation per element).
    pub fn from_aos<E: PlaneRing<Base = B>>(ext: &E, mat: &Matrix<E::Elem>) -> Self {
        let m = ext.plane_count();
        let pp = mat.rows * mat.cols;
        let base = ext.plane_base();
        let mut data = vec![base.zero(); m * pp];
        for (idx, e) in mat.data.iter().enumerate() {
            for k in 0..m {
                data[k * pp + idx] = ext.coeff(e, k);
            }
        }
        PlaneMatrix { rows: mat.rows, cols: mat.cols, planes: m, data }
    }

    /// Convert back to the AoS representation (boundary use only).
    pub fn to_aos<E: PlaneRing<Base = B>>(&self, ext: &E) -> Matrix<E::Elem> {
        let m = self.planes;
        let pp = self.plane_len();
        let mut out = Vec::with_capacity(pp);
        let mut coeffs: Vec<B::Elem> = Vec::with_capacity(m);
        for idx in 0..pp {
            coeffs.clear();
            for k in 0..m {
                coeffs.push(self.data[k * pp + idx].clone());
            }
            out.push(ext.elem_from_coeffs(&coeffs));
        }
        Matrix::from_vec(self.rows, self.cols, out)
    }

    /// Constant embedding of a base-ring matrix: plane 0 is `mat`, higher
    /// planes are zero (the `PlainEp` / GCSA input embedding).
    pub fn from_base_matrix<E: PlaneRing<Base = B>>(ext: &E, mat: &Matrix<B::Elem>) -> Self {
        let m = ext.plane_count();
        let pp = mat.rows * mat.cols;
        let mut data = vec![ext.plane_base().zero(); m * pp];
        data[..pp].clone_from_slice(&mat.data);
        PlaneMatrix { rows: mat.rows, cols: mat.cols, planes: m, data }
    }

    /// Plane 0 as a base-ring matrix (inverse of
    /// [`PlaneMatrix::from_base_matrix`] for constant-valued matrices).
    pub fn base_plane_matrix(&self) -> Matrix<B::Elem> {
        Matrix::from_vec(self.rows, self.cols, self.plane(0).to_vec())
    }

    /// `self += other`, elementwise across all planes.
    pub fn add_assign(&mut self, base: &B, other: &Self) {
        assert_eq!(
            (self.rows, self.cols, self.planes),
            (other.rows, other.cols, other.planes),
            "plane matrix shapes must agree"
        );
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            base.add_assign(a, b);
        }
    }

    /// `self += s·x` for an extension-ring scalar `s` — the encode/decode
    /// workhorse (Horner steps, Lagrange weights): `m²` base-ring slice
    /// axpys through the precomputed [`PlaneRing::scalar_mul_table`].
    pub fn axpy<E: PlaneRing<Base = B>>(&mut self, ext: &E, s: &E::Elem, x: &Self) {
        assert_eq!(
            (self.rows, self.cols, self.planes),
            (x.rows, x.cols, x.planes),
            "plane matrix shapes must agree"
        );
        if ext.is_zero(s) {
            return;
        }
        let m = ext.plane_count();
        debug_assert_eq!(self.planes, m);
        let base = ext.plane_base();
        let pp = self.plane_len();
        let table = ext.scalar_mul_table(s);
        for k in 0..m {
            let dst = &mut self.data[k * pp..(k + 1) * pp];
            for j in 0..m {
                let c = &table[k * m + j];
                if base.is_zero(c) {
                    continue;
                }
                slice_axpy(base, dst, c, &x.data[j * pp..(j + 1) * pp]);
            }
        }
    }

    /// `self = s·self` for an extension-ring scalar `s`.
    pub fn scale_assign<E: PlaneRing<Base = B>>(&mut self, ext: &E, s: &E::Elem) {
        let m = ext.plane_count();
        debug_assert_eq!(self.planes, m);
        let base = ext.plane_base();
        let pp = self.plane_len();
        let table = ext.scalar_mul_table(s);
        let mut out = vec![base.zero(); m * pp];
        for k in 0..m {
            let dst = &mut out[k * pp..(k + 1) * pp];
            for j in 0..m {
                let c = &table[k * m + j];
                if base.is_zero(c) {
                    continue;
                }
                slice_axpy(base, dst, c, &self.data[j * pp..(j + 1) * pp]);
            }
        }
        self.data = out;
    }

    /// Extension-ring matrix product on plane-major storage — the worker
    /// hot path. Schoolbook on planes: `m²` contiguous base-ring matmuls
    /// into `2m−1` accumulation planes, then one plane-level reduction by
    /// the monic modulus. Equivalent to the AoS [`Ring::mat_mul`] of
    /// [`Extension`] but with zero per-element allocation or plane
    /// extraction (asserted equivalent in tests and `property_tests.rs`).
    pub fn matmul<E: PlaneRing<Base = B>>(ext: &E, a: &Self, b: &Self) -> Self {
        assert_eq!(a.cols, b.rows, "inner dimensions must agree");
        let m = ext.plane_count();
        assert_eq!(a.planes, m, "lhs plane count mismatch");
        assert_eq!(b.planes, m, "rhs plane count mismatch");
        let base = ext.plane_base();
        let pp = a.rows * b.cols;
        let conv_planes = 2 * m - 1;
        let mut conv: Vec<B::Elem> = vec![base.zero(); conv_planes * pp];
        for i in 0..m {
            for j in 0..m {
                let k = i + j;
                slice_matmul_acc(
                    base,
                    &mut conv[k * pp..(k + 1) * pp],
                    a.plane(i),
                    b.plane(j),
                    a.rows,
                    a.cols,
                    b.cols,
                );
            }
        }
        // Reduce planes m..2m−1 by the monic modulus:
        // y^k ≡ −Σ_i h_i·y^{k−m+i}.
        let h = ext.modulus_low();
        for k in (m..conv_planes).rev() {
            let (lo, hi) = conv.split_at_mut(k * pp);
            let top = &hi[..pp];
            for (i, hc) in h.iter().enumerate() {
                if base.is_zero(hc) {
                    continue;
                }
                let neg = base.neg(hc);
                let dst = &mut lo[(k - m + i) * pp..(k - m + i + 1) * pp];
                slice_axpy(base, dst, &neg, top);
            }
        }
        conv.truncate(m * pp);
        PlaneMatrix { rows: a.rows, cols: b.cols, planes: m, data: conv }
    }

    /// Partition into a `gr × gc` grid of equal blocks, each plane-major
    /// (dims must divide). Row-major block order, like
    /// [`Matrix::partition_grid`].
    pub fn partition_grid(&self, gr: usize, gc: usize) -> Vec<Self> {
        assert!(self.rows % gr == 0, "rows {} not divisible by {gr}", self.rows);
        assert!(self.cols % gc == 0, "cols {} not divisible by {gc}", self.cols);
        let bh = self.rows / gr;
        let bw = self.cols / gc;
        let pp = self.plane_len();
        let mut out = Vec::with_capacity(gr * gc);
        for a in 0..gr {
            for b in 0..gc {
                let mut data = Vec::with_capacity(self.planes * bh * bw);
                for k in 0..self.planes {
                    for i in 0..bh {
                        let start = k * pp + (a * bh + i) * self.cols + b * bw;
                        data.extend_from_slice(&self.data[start..start + bw]);
                    }
                }
                out.push(PlaneMatrix { rows: bh, cols: bw, planes: self.planes, data });
            }
        }
        out
    }

    /// Inverse of [`PlaneMatrix::partition_grid`].
    pub fn stitch_grid(blocks: &[Self], gr: usize, gc: usize) -> Self {
        assert_eq!(blocks.len(), gr * gc);
        let bh = blocks[0].rows;
        let bw = blocks[0].cols;
        let m = blocks[0].planes;
        let bpp = bh * bw;
        let (rows, cols) = (gr * bh, gc * bw);
        let mut data = Vec::with_capacity(m * rows * cols);
        for k in 0..m {
            for a in 0..gr {
                for i in 0..bh {
                    for b in 0..gc {
                        let blk = &blocks[a * gc + b];
                        assert_eq!((blk.rows, blk.cols, blk.planes), (bh, bw, m));
                        let start = k * bpp + i * bw;
                        data.extend_from_slice(&blk.data[start..start + bw]);
                    }
                }
            }
        }
        PlaneMatrix { rows, cols, planes: m, data }
    }

    /// Serialized byte size: 16-byte header + contiguous planes.
    pub fn byte_len<E: PlaneRing<Base = B>>(&self, ext: &E) -> usize {
        16 + self.data.len() * ext.plane_base().elem_bytes()
    }

    /// Serialize as one contiguous block:
    /// `rows (u64 LE) | cols (u64 LE) | plane 0 | … | plane m−1`.
    /// The plane count is carried by the ring context, not the wire.
    pub fn to_bytes<E: PlaneRing<Base = B>>(&self, ext: &E) -> Vec<u8> {
        let base = ext.plane_base();
        let mut out = Vec::with_capacity(self.byte_len(ext));
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        for x in &self.data {
            base.write_elem(x, &mut out);
        }
        out
    }

    /// Read one matrix from `buf` starting at `*pos`, advancing `*pos`.
    /// Every length is validated before any allocation or read — truncated
    /// or corrupt payloads yield an `Err`, never a panic (workers report
    /// such jobs as clean failures instead of unwinding their thread).
    pub fn read_from<E: PlaneRing<Base = B>>(
        ext: &E,
        buf: &[u8],
        pos: &mut usize,
    ) -> anyhow::Result<Self> {
        let base = ext.plane_base();
        let m = ext.plane_count();
        let avail = buf.len().saturating_sub(*pos);
        anyhow::ensure!(avail >= 16, "matrix header truncated: {avail} of 16 bytes");
        let mut b8 = [0u8; 8];
        b8.copy_from_slice(&buf[*pos..*pos + 8]);
        let rows = u64::from_le_bytes(b8) as usize;
        b8.copy_from_slice(&buf[*pos + 8..*pos + 16]);
        let cols = u64::from_le_bytes(b8) as usize;
        *pos += 16;
        let count = rows
            .checked_mul(cols)
            .and_then(|x| x.checked_mul(m))
            .ok_or_else(|| anyhow::anyhow!("matrix shape {rows}x{cols}x{m} overflows"))?;
        let need = count
            .checked_mul(base.elem_bytes())
            .ok_or_else(|| anyhow::anyhow!("matrix payload size overflows"))?;
        anyhow::ensure!(
            buf.len() - *pos >= need,
            "matrix payload truncated: need {need} bytes for {rows}x{cols} ({m} planes), have {}",
            buf.len() - *pos
        );
        let data: Vec<B::Elem> = (0..count).map(|_| base.read_elem(buf, pos)).collect();
        Ok(PlaneMatrix { rows, cols, planes: m, data })
    }

    /// Deserialize, requiring the buffer to be consumed exactly.
    pub fn from_bytes<E: PlaneRing<Base = B>>(ext: &E, buf: &[u8]) -> anyhow::Result<Self> {
        let mut pos = 0;
        let mat = Self::read_from(ext, buf, &mut pos)?;
        anyhow::ensure!(
            pos == buf.len(),
            "matrix payload has {} trailing bytes",
            buf.len() - pos
        );
        Ok(mat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ext3() -> Extension<Zq> {
        Extension::new(Zq::z2e(64), 3)
    }

    #[test]
    fn aos_roundtrip_and_plane_layout() {
        let ext = Extension::new(Zq::z2e(64), 2);
        let mut mat = Matrix::zeros(&ext, 1, 2);
        mat.set(0, 0, vec![10, 11]);
        mat.set(0, 1, vec![20, 21]);
        let pm = PlaneMatrix::from_aos(&ext, &mat);
        // plane 0 = [10, 20], plane 1 = [11, 21] — plane-major.
        assert_eq!(pm.data, vec![10, 20, 11, 21]);
        assert_eq!(pm.plane(0), &[10, 20]);
        assert_eq!(pm.plane(1), &[11, 21]);
        assert_eq!(pm.to_aos(&ext), mat);
    }

    #[test]
    fn matmul_matches_aos_extension_matmul() {
        for m in [1usize, 2, 3, 4, 5] {
            let ext = Extension::new(Zq::z2e(64), m);
            let mut rng = Rng64::seeded(700 + m as u64);
            let a = Matrix::random(&ext, 4, 3, &mut rng);
            let b = Matrix::random(&ext, 3, 5, &mut rng);
            let pa = PlaneMatrix::from_aos(&ext, &a);
            let pb = PlaneMatrix::from_aos(&ext, &b);
            let pc = PlaneMatrix::matmul(&ext, &pa, &pb);
            let c = Matrix::matmul(&ext, &a, &b);
            assert_eq!(pc, PlaneMatrix::from_aos(&ext, &c), "m={m}");
            assert_eq!(pc.to_aos(&ext), c, "m={m}");
        }
    }

    #[test]
    fn matmul_scalar_ring_single_plane() {
        let zq = Zq::z2e(64);
        let mut rng = Rng64::seeded(710);
        let a = Matrix::random(&zq, 5, 4, &mut rng);
        let b = Matrix::random(&zq, 4, 6, &mut rng);
        let pa = PlaneMatrix::from_aos(&zq, &a);
        let pb = PlaneMatrix::from_aos(&zq, &b);
        let pc = PlaneMatrix::matmul(&zq, &pa, &pb);
        assert_eq!(pc.data, Matrix::matmul(&zq, &a, &b).data);
    }

    #[test]
    fn axpy_and_scale_match_aos() {
        let ext = ext3();
        let mut rng = Rng64::seeded(711);
        let a = Matrix::random(&ext, 3, 4, &mut rng);
        let x = Matrix::random(&ext, 3, 4, &mut rng);
        let s = ext.random(&mut rng);
        // axpy
        let mut pa = PlaneMatrix::from_aos(&ext, &a);
        pa.axpy(&ext, &s, &PlaneMatrix::from_aos(&ext, &x));
        let mut aos = a.clone();
        aos.axpy(&ext, &s, &x);
        assert_eq!(pa, PlaneMatrix::from_aos(&ext, &aos));
        // scale
        let mut ps = PlaneMatrix::from_aos(&ext, &x);
        ps.scale_assign(&ext, &s);
        let mut xs = x.clone();
        xs.scale_assign(&ext, &s);
        assert_eq!(ps, PlaneMatrix::from_aos(&ext, &xs));
    }

    #[test]
    fn scalar_mul_table_reproduces_ring_mul() {
        let ext = ext3();
        let mut rng = Rng64::seeded(712);
        for _ in 0..20 {
            let s = ext.random(&mut rng);
            let x = ext.random(&mut rng);
            let table = ext.scalar_mul_table(&s);
            let m = ext.m();
            let base = ext.base();
            let mut got = vec![0u64; m];
            for k in 0..m {
                for j in 0..m {
                    base.mul_add_assign(&mut got[k], &table[k * m + j], &x[j]);
                }
            }
            assert_eq!(got, ext.mul(&s, &x));
        }
    }

    #[test]
    fn partition_stitch_roundtrip() {
        let ext = ext3();
        let mut rng = Rng64::seeded(713);
        let a = PlaneMatrix::random(&ext, 6, 8, &mut rng);
        for (gr, gc) in [(1, 1), (2, 2), (3, 4), (6, 8), (2, 4)] {
            let blocks = a.partition_grid(gr, gc);
            assert_eq!(blocks.len(), gr * gc);
            assert_eq!(PlaneMatrix::stitch_grid(&blocks, gr, gc), a, "grid {gr}x{gc}");
        }
    }

    #[test]
    fn partition_matches_aos_partition() {
        let ext = ext3();
        let mut rng = Rng64::seeded(714);
        let a = Matrix::random(&ext, 4, 6, &mut rng);
        let pa = PlaneMatrix::from_aos(&ext, &a);
        let blocks = a.partition_grid(2, 3);
        let pblocks = pa.partition_grid(2, 3);
        for (b, pb) in blocks.iter().zip(&pblocks) {
            assert_eq!(PlaneMatrix::from_aos(&ext, b), *pb);
        }
    }

    #[test]
    fn serialization_roundtrip_and_length() {
        let ext = ext3();
        let mut rng = Rng64::seeded(715);
        let a = PlaneMatrix::random(&ext, 3, 2, &mut rng);
        let bytes = a.to_bytes(&ext);
        assert_eq!(bytes.len(), a.byte_len(&ext));
        assert_eq!(bytes.len(), 16 + 3 * 2 * 3 * 8);
        assert_eq!(PlaneMatrix::from_bytes(&ext, &bytes).unwrap(), a);
    }

    #[test]
    fn deserialization_rejects_truncated_and_oversized() {
        let ext = ext3();
        let mut rng = Rng64::seeded(716);
        let a = PlaneMatrix::random(&ext, 3, 2, &mut rng);
        let bytes = a.to_bytes(&ext);
        // truncated header
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &bytes[..8]).is_err());
        // truncated payload
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &bytes[..bytes.len() - 1]).is_err());
        // oversized payload
        let mut big = bytes.clone();
        big.push(0);
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &big).is_err());
        // header lying about the shape
        let mut lie = bytes;
        lie[0] = 200; // rows = 200 with the same payload
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &lie).is_err());
        // empty buffer
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &[]).is_err());
    }

    #[test]
    fn const_embedding_roundtrip() {
        let ext = ext3();
        let zq = Zq::z2e(64);
        let mut rng = Rng64::seeded(717);
        let a = Matrix::random(&zq, 3, 3, &mut rng);
        let pa = PlaneMatrix::from_base_matrix(&ext, &a);
        assert_eq!(pa.planes, 3);
        assert_eq!(pa.base_plane_matrix(), a);
        assert!(pa.plane(1).iter().all(|&x| x == 0));
        // agrees with the AoS constant embedding
        let aos = a.map(|x| ext.from_base(x));
        assert_eq!(pa, PlaneMatrix::from_aos(&ext, &aos));
    }

    #[test]
    fn matmul_over_galois_base_tower() {
        // Extension<GaloisRing>: planes hold GrElem (Vec<u64>) — the generic
        // path still matches the AoS kernel.
        let base = GaloisRing::new(2, 16, 2);
        let ext = Extension::new(base, 2);
        let mut rng = Rng64::seeded(718);
        let a = Matrix::random(&ext, 3, 3, &mut rng);
        let b = Matrix::random(&ext, 3, 3, &mut rng);
        let pc = PlaneMatrix::matmul(
            &ext,
            &PlaneMatrix::from_aos(&ext, &a),
            &PlaneMatrix::from_aos(&ext, &b),
        );
        assert_eq!(pc.to_aos(&ext), Matrix::matmul(&ext, &a, &b));
    }
}
