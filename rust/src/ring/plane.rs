//! Plane-major storage for extension-ring matrices — the wire/worker format.
//!
//! An extension-ring matrix over `GR_m = R[y]/(h)` is algebraically a stack
//! of `m` *coefficient planes*, each a plain matrix over the base ring `R`.
//! The AoS representation ([`Matrix`]`<Vec<R::Elem>>`) pays one heap
//! allocation per element and scatters each plane across memory;
//! [`PlaneMatrix`] stores the same data as one flat plane-major `Vec`
//! (`data[k·rows·cols + i·cols + j]` = coefficient `k` of entry `(i, j)`),
//! so that:
//!
//! * [`PlaneMatrix::plane`] is a zero-copy slice view of one base-ring plane;
//! * the worker share product runs plane-by-plane through the base ring's
//!   contiguous ikj kernel (monomorphized `u64` loops for `Zq`) plus one
//!   modulus reduction — no per-element `Vec` traffic;
//! * encode/decode Horner steps and interpolation weights are `m²`
//!   scalar-times-slice axpys via a precomputed scalar multiplication table
//!   — borrowed as a [`ScalarTable`] by the table-driven
//!   [`PlaneMatrix::axpy_with_table`] / [`PlaneMatrix::scale_with_table`],
//!   so the encode/decode *plans* in [`crate::codes::encode_plan`] build
//!   each table exactly once (builds are counted per thread by
//!   [`scalar_table_builds`] and asserted zero in steady state);
//! * the matmul and slice kernels parallelize over **disjoint output row
//!   panels** on scoped threads ([`crate::util::parallel`]) — bit-identical
//!   to sequential at every thread count because each output element runs
//!   the unchanged per-row loop, and `GR_CDMM_THREADS=1` branches to the
//!   exact pre-threading code path;
//! * serialization is a single contiguous block, already in the layout the
//!   AOT XLA artifacts consume (`(m, rows, cols)` u64 planes for
//!   `GR(2^64, m)` — see [`crate::runtime::gr_backend`]); `Zq` planes move
//!   as one little-endian block copy ([`Ring::write_slice`]), not a
//!   per-element loop.
//!
//! [`PlaneRing`] is the small capability trait that lets any ring act as a
//! plane decomposition: scalar rings ([`Zq`], [`GaloisRing`]) are their own
//! single plane, a tower [`Extension`] exposes its `m` coefficient planes
//! over its base. Every scheme in [`crate::codes`] stores shares and
//! responses as `PlaneMatrix` over `ShareRing::Base`.

use super::extension::Extension;
use super::galois::{ExtensibleRing, GaloisRing, GrElem};
use super::matrix::Matrix;
use super::traits::Ring;
use super::zq::Zq;
use crate::util::parallel::{self, split_ranges};
use crate::util::rng::Rng64;
use std::cell::Cell;

thread_local! {
    static SCALAR_TABLE_BUILDS: Cell<u64> = const { Cell::new(0) };
}

/// Cumulative count of [`PlaneRing::scalar_mul_table`] constructions **on
/// the current thread** — the probe behind the "zero table builds in the
/// steady-state encode/decode loop" acceptance criterion. Plans built at
/// scheme construction or on a decode-plan cache miss increment it; warm
/// table-driven encode/decode must not (asserted in `integration_codes.rs`
/// and the `encode_decode` bench). Per-thread so concurrently running tests
/// don't race the probe.
pub fn scalar_table_builds() -> u64 {
    SCALAR_TABLE_BUILDS.with(|c| c.get())
}

/// A ring whose elements decompose into `plane_count()` coefficients over a
/// base ring — the capability [`PlaneMatrix`] kernels are generic over.
///
/// Scalar rings are their own (single) plane; [`Extension`] towers expose
/// their `m` coefficient planes. The monic modulus enters only through
/// [`PlaneRing::modulus_low`], which the matmul kernel uses for the final
/// plane-level reduction (`y^k ≡ −Σ_i h_i·y^{k−m+i}`).
pub trait PlaneRing: Ring {
    /// The base ring one plane lives over (`Self` for scalar rings).
    type Base: Ring;

    /// The base-ring context.
    fn plane_base(&self) -> &Self::Base;

    /// Number of coefficient planes `m` (`1` for scalar rings).
    fn plane_count(&self) -> usize;

    /// Low `m` coefficients of the monic degree-`m` modulus (empty when
    /// `plane_count() == 1` — a scalar ring has nothing to reduce by).
    fn modulus_low(&self) -> &[<Self::Base as Ring>::Elem];

    /// Coefficient `k` of an element (`0 ≤ k < plane_count()`).
    fn coeff(&self, a: &Self::Elem, k: usize) -> <Self::Base as Ring>::Elem;

    /// Rebuild an element from its coefficients (length `plane_count()`).
    fn elem_from_coeffs(&self, coeffs: &[<Self::Base as Ring>::Elem]) -> Self::Elem;

    /// Row-major `m × m` multiplication table of the scalar `s`: column `j`
    /// holds the coefficients of `s·y^j mod h`, so multiplying an element by
    /// `s` maps its coefficient vector `x` to `table·x`. This is what turns a
    /// scalar-times-matrix axpy into `m²` base-ring slice axpys with the
    /// modulus reduction folded in (and into the single entry `[s]` for
    /// scalar rings).
    fn scalar_mul_table(&self, s: &Self::Elem) -> Vec<<Self::Base as Ring>::Elem> {
        SCALAR_TABLE_BUILDS.with(|c| c.set(c.get() + 1));
        let m = self.plane_count();
        let base = self.plane_base();
        let mut cur: Vec<<Self::Base as Ring>::Elem> = (0..m).map(|k| self.coeff(s, k)).collect();
        let mut table = vec![base.zero(); m * m];
        for j in 0..m {
            for (k, c) in cur.iter().enumerate() {
                table[k * m + j] = c.clone();
            }
            if j + 1 < m {
                // cur ← cur·y mod h: shift up one degree, fold the overflow
                // coefficient back with the monic modulus.
                let top = cur[m - 1].clone();
                for k in (1..m).rev() {
                    cur[k] = cur[k - 1].clone();
                }
                cur[0] = base.zero();
                if !base.is_zero(&top) {
                    for (i, h) in self.modulus_low().iter().enumerate() {
                        if !base.is_zero(h) {
                            let d = base.mul(&top, h);
                            cur[i] = base.sub(&cur[i], &d);
                        }
                    }
                }
            }
        }
        table
    }
}

impl PlaneRing for Zq {
    type Base = Zq;
    fn plane_base(&self) -> &Zq {
        self
    }
    fn plane_count(&self) -> usize {
        1
    }
    fn modulus_low(&self) -> &[u64] {
        &[]
    }
    fn coeff(&self, a: &u64, k: usize) -> u64 {
        debug_assert_eq!(k, 0);
        *a
    }
    fn elem_from_coeffs(&self, coeffs: &[u64]) -> u64 {
        coeffs[0]
    }
}

impl PlaneRing for GaloisRing {
    type Base = GaloisRing;
    fn plane_base(&self) -> &GaloisRing {
        self
    }
    fn plane_count(&self) -> usize {
        1
    }
    fn modulus_low(&self) -> &[GrElem] {
        &[]
    }
    fn coeff(&self, a: &GrElem, k: usize) -> GrElem {
        debug_assert_eq!(k, 0);
        a.clone()
    }
    fn elem_from_coeffs(&self, coeffs: &[GrElem]) -> GrElem {
        coeffs[0].clone()
    }
}

impl<R: ExtensibleRing> PlaneRing for Extension<R> {
    type Base = R;
    fn plane_base(&self) -> &R {
        self.base()
    }
    fn plane_count(&self) -> usize {
        self.m()
    }
    fn modulus_low(&self) -> &[R::Elem] {
        &self.modulus()[..self.m()]
    }
    fn coeff(&self, a: &Self::Elem, k: usize) -> R::Elem {
        a[k].clone()
    }
    fn elem_from_coeffs(&self, coeffs: &[R::Elem]) -> Self::Elem {
        self.from_coeffs(coeffs)
    }
}

/// `acc += s·x` over base-ring slices — the innermost encode/decode op.
/// Delegates to the [`Ring::slice_axpy_assign`] hook, so rings with a
/// machine-word representation ([`Zq`]) run the runtime-dispatched SIMD
/// kernel from [`crate::ring::arch`].
#[inline]
pub fn slice_axpy<B: Ring>(base: &B, acc: &mut [B::Elem], s: &B::Elem, x: &[B::Elem]) {
    debug_assert_eq!(acc.len(), x.len());
    base.slice_axpy_assign(acc, s, x);
}

/// `c += a·b` over base-ring slices (`a: ar×ac`, `b: ac×bc`, `c: ar×bc`,
/// all row-major). Delegates to the [`Ring::slice_mat_mul_acc`] hook: the
/// cache-friendly ikj order with 64-row k-panels of `b` by default,
/// dispatched into the [`crate::ring::arch`] SIMD kernel table for [`Zq`].
pub fn slice_matmul_acc<B: Ring>(
    base: &B,
    c: &mut [B::Elem],
    a: &[B::Elem],
    b: &[B::Elem],
    ar: usize,
    ac: usize,
    bc: usize,
) {
    debug_assert_eq!(a.len(), ar * ac);
    debug_assert_eq!(b.len(), ac * bc);
    debug_assert_eq!(c.len(), ar * bc);
    base.slice_mat_mul_acc(c, a, b, ar, ac, bc);
}

/// [`slice_matmul_acc`] over up to `threads` scoped threads: `c` is split
/// into disjoint contiguous row panels (rows are contiguous in row-major
/// `c`), each panel accumulated by the unchanged sequential kernel on the
/// matching rows of `a` — so every output element sees the exact sequential
/// operation sequence at any thread count. `threads <= 1`, a single row, or
/// sub-[`parallel::MIN_PAR_OPS`] work runs the sequential kernel directly.
#[allow(clippy::too_many_arguments)] // the 7 kernel dims + the thread count
pub fn slice_matmul_acc_threads<B: Ring>(
    base: &B,
    c: &mut [B::Elem],
    a: &[B::Elem],
    b: &[B::Elem],
    ar: usize,
    ac: usize,
    bc: usize,
    threads: usize,
) {
    let t = parallel::effective_threads(threads, ar, ar * ac * bc);
    if t <= 1 {
        slice_matmul_acc(base, c, a, b, ar, ac, bc);
        return;
    }
    debug_assert_eq!(c.len(), ar * bc);
    let ranges = split_ranges(ar, t);
    std::thread::scope(|s| {
        let mut rest = c;
        let mut handles = Vec::with_capacity(ranges.len());
        for r in &ranges {
            let rows = r.end - r.start;
            let (panel, tail) = rest.split_at_mut(rows * bc);
            rest = tail;
            let a_panel = &a[r.start * ac..r.end * ac];
            handles.push(s.spawn(move || slice_matmul_acc(base, panel, a_panel, b, rows, ac, bc)));
        }
        for h in handles {
            h.join().expect("matmul worker thread panicked");
        }
    });
}

/// A precomputed `m × m` scalar multiplication table
/// ([`PlaneRing::scalar_mul_table`]) bundled with its dimension and a
/// zero-scalar flag — the borrowed currency of the table-driven
/// [`PlaneMatrix::axpy_with_table`] / [`PlaneMatrix::scale_with_table`].
/// Building one costs `O(m²)` base-ring ops (counted by
/// [`scalar_table_builds`]); the encode/decode plans in
/// [`crate::codes::encode_plan`] build each table once per scheme (or once
/// per responding subset) so the steady-state hot loops never rebuild one.
#[derive(Clone)]
pub struct ScalarTable<B: Ring> {
    m: usize,
    /// Row-major `m × m`; column `j` holds the coefficients of `s·y^j mod h`.
    table: Vec<B::Elem>,
    /// Whether the scalar was zero (an axpy with it is a no-op).
    zero: bool,
}

impl<B: Ring> ScalarTable<B> {
    /// Build the table of `s` over the plane ring `ext`.
    pub fn build<E: PlaneRing<Base = B>>(ext: &E, s: &E::Elem) -> Self {
        ScalarTable {
            m: ext.plane_count(),
            table: ext.scalar_mul_table(s),
            zero: ext.is_zero(s),
        }
    }

    /// The plane count `m` the table was built for.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Whether the scalar was zero (axpy no-ops; scale zeroes the target).
    pub fn is_zero_scalar(&self) -> bool {
        self.zero
    }

    /// Table entry `(k, j)`: the coefficient-`k` contribution of input
    /// plane `j`.
    #[inline]
    pub fn coeff(&self, k: usize, j: usize) -> &B::Elem {
        &self.table[k * self.m + j]
    }
}

/// An extension-ring matrix stored as `planes` contiguous base-ring
/// coefficient planes (plane-major): `data[k·rows·cols + i·cols + j]` is
/// coefficient `k` of entry `(i, j)`.
///
/// This is the storage for everything on the encode → wire → worker → decode
/// path; [`Matrix`] remains the element-generic AoS type for user-facing
/// inputs/outputs and scalar-sized internal systems.
pub struct PlaneMatrix<B: Ring> {
    pub rows: usize,
    pub cols: usize,
    /// Number of coefficient planes (`= plane_count()` of the plane ring).
    pub planes: usize,
    /// Flat plane-major storage, length `planes·rows·cols`.
    pub data: Vec<B::Elem>,
}

impl<B: Ring> Clone for PlaneMatrix<B> {
    fn clone(&self) -> Self {
        PlaneMatrix {
            rows: self.rows,
            cols: self.cols,
            planes: self.planes,
            data: self.data.clone(),
        }
    }
}

impl<B: Ring> PartialEq for PlaneMatrix<B> {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.planes == other.planes
            && self.data == other.data
    }
}

impl<B: Ring> std::fmt::Debug for PlaneMatrix<B> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlaneMatrix")
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("planes", &self.planes)
            .field("data", &self.data)
            .finish()
    }
}

impl<B: Ring> PlaneMatrix<B> {
    /// Elements per plane.
    #[inline]
    pub fn plane_len(&self) -> usize {
        self.rows * self.cols
    }

    /// Zero-copy view of coefficient plane `k`.
    #[inline]
    pub fn plane(&self, k: usize) -> &[B::Elem] {
        let pp = self.plane_len();
        &self.data[k * pp..(k + 1) * pp]
    }

    /// Mutable view of coefficient plane `k`.
    #[inline]
    pub fn plane_mut(&mut self, k: usize) -> &mut [B::Elem] {
        let pp = self.plane_len();
        &mut self.data[k * pp..(k + 1) * pp]
    }

    /// All-zero matrix with `ext.plane_count()` planes.
    pub fn zeros<E: PlaneRing<Base = B>>(ext: &E, rows: usize, cols: usize) -> Self {
        let m = ext.plane_count();
        PlaneMatrix {
            rows,
            cols,
            planes: m,
            data: vec![ext.plane_base().zero(); m * rows * cols],
        }
    }

    /// Uniformly random matrix (same distribution as AoS
    /// [`Matrix::random`] over the plane ring: independent uniform planes).
    pub fn random<E: PlaneRing<Base = B>>(
        ext: &E,
        rows: usize,
        cols: usize,
        rng: &mut Rng64,
    ) -> Self {
        let m = ext.plane_count();
        let base = ext.plane_base();
        PlaneMatrix {
            rows,
            cols,
            planes: m,
            data: (0..m * rows * cols).map(|_| base.random(rng)).collect(),
        }
    }

    /// Convert from the AoS representation (one allocation per element).
    pub fn from_aos<E: PlaneRing<Base = B>>(ext: &E, mat: &Matrix<E::Elem>) -> Self {
        let m = ext.plane_count();
        let pp = mat.rows * mat.cols;
        let base = ext.plane_base();
        let mut data = vec![base.zero(); m * pp];
        for (idx, e) in mat.data.iter().enumerate() {
            for k in 0..m {
                data[k * pp + idx] = ext.coeff(e, k);
            }
        }
        PlaneMatrix { rows: mat.rows, cols: mat.cols, planes: m, data }
    }

    /// Convert back to the AoS representation (boundary use only).
    pub fn to_aos<E: PlaneRing<Base = B>>(&self, ext: &E) -> Matrix<E::Elem> {
        let m = self.planes;
        let pp = self.plane_len();
        let mut out = Vec::with_capacity(pp);
        let mut coeffs: Vec<B::Elem> = Vec::with_capacity(m);
        for idx in 0..pp {
            coeffs.clear();
            for k in 0..m {
                coeffs.push(self.data[k * pp + idx].clone());
            }
            out.push(ext.elem_from_coeffs(&coeffs));
        }
        Matrix::from_vec(self.rows, self.cols, out)
    }

    /// Constant embedding of a base-ring matrix: plane 0 is `mat`, higher
    /// planes are zero (the `PlainEp` / GCSA input embedding).
    pub fn from_base_matrix<E: PlaneRing<Base = B>>(ext: &E, mat: &Matrix<B::Elem>) -> Self {
        let m = ext.plane_count();
        let pp = mat.rows * mat.cols;
        let mut data = vec![ext.plane_base().zero(); m * pp];
        data[..pp].clone_from_slice(&mat.data);
        PlaneMatrix { rows: mat.rows, cols: mat.cols, planes: m, data }
    }

    /// Plane 0 as a base-ring matrix (inverse of
    /// [`PlaneMatrix::from_base_matrix`] for constant-valued matrices).
    pub fn base_plane_matrix(&self) -> Matrix<B::Elem> {
        Matrix::from_vec(self.rows, self.cols, self.plane(0).to_vec())
    }

    /// `self += other`, elementwise across all planes.
    pub fn add_assign(&mut self, base: &B, other: &Self) {
        assert_eq!(
            (self.rows, self.cols, self.planes),
            (other.rows, other.cols, other.planes),
            "plane matrix shapes must agree"
        );
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            base.add_assign(a, b);
        }
    }

    /// `self += s·x` for an extension-ring scalar `s` — the encode/decode
    /// workhorse (Horner steps, Lagrange weights): `m²` base-ring slice
    /// axpys through the scalar multiplication table of `s`. Builds the
    /// table on the spot; steady-state loops use the precomputed-plan
    /// variant [`PlaneMatrix::axpy_with_table`] instead (identical result).
    pub fn axpy<E: PlaneRing<Base = B>>(&mut self, ext: &E, s: &E::Elem, x: &Self) {
        if ext.is_zero(s) {
            assert_eq!(
                (self.rows, self.cols, self.planes),
                (x.rows, x.cols, x.planes),
                "plane matrix shapes must agree"
            );
            return;
        }
        let table = ScalarTable::build(ext, s);
        self.axpy_with_table(ext.plane_base(), &table, x);
    }

    /// `self += s·x` driven by a precomputed, borrowed [`ScalarTable`] of
    /// `s` — the steady-state encode/decode op. Bit-identical to
    /// [`PlaneMatrix::axpy`] by construction: same table, same slice-axpy
    /// order, same zero-coefficient skips.
    pub fn axpy_with_table(&mut self, base: &B, t: &ScalarTable<B>, x: &Self) {
        assert_eq!(
            (self.rows, self.cols, self.planes),
            (x.rows, x.cols, x.planes),
            "plane matrix shapes must agree"
        );
        if t.zero {
            return;
        }
        let m = t.m;
        debug_assert_eq!(self.planes, m, "table plane count mismatch");
        let pp = self.plane_len();
        for k in 0..m {
            let dst = &mut self.data[k * pp..(k + 1) * pp];
            for j in 0..m {
                let c = t.coeff(k, j);
                if base.is_zero(c) {
                    continue;
                }
                slice_axpy(base, dst, c, &x.data[j * pp..(j + 1) * pp]);
            }
        }
    }

    /// `self = s·self` for an extension-ring scalar `s`. Builds the table on
    /// the spot and updates in place via [`PlaneMatrix::scale_with_table`] —
    /// no `m·rows·cols` scratch buffer.
    pub fn scale_assign<E: PlaneRing<Base = B>>(&mut self, ext: &E, s: &E::Elem) {
        let table = ScalarTable::build(ext, s);
        self.scale_with_table(ext.plane_base(), &table);
    }

    /// `self = s·self` in place, driven by a borrowed [`ScalarTable`] of
    /// `s`: streams the planes in fixed-size column chunks with an
    /// `O(m·CHUNK)` scratch instead of allocating a fresh `m·rows·cols`
    /// buffer per call. Each chunk snapshots the `m` input plane segments,
    /// then rebuilds every output plane segment as a zero-initialized
    /// ascending-`j` sequence of [`slice_axpy`]s with zero coefficients
    /// skipped — per output element that is the exact multiply-accumulate
    /// sequence of the elementwise update (and of the old out-of-place
    /// path), so results are bit-identical while the inner loops run
    /// through the dispatched slice kernels over contiguous runs.
    pub fn scale_with_table(&mut self, base: &B, t: &ScalarTable<B>) {
        let m = t.m;
        debug_assert_eq!(self.planes, m, "table plane count mismatch");
        let pp = self.plane_len();
        // 1024 × u64 = 8 KiB per plane segment: comfortably in L1 even for
        // wide towers, long enough to amortize the dispatch call.
        const CHUNK: usize = 1024;
        let seg_cap = CHUNK.min(pp.max(1));
        let mut scratch: Vec<B::Elem> = vec![base.zero(); m * seg_cap];
        let mut i0 = 0;
        while i0 < pp {
            let seg = (pp - i0).min(seg_cap);
            for j in 0..m {
                scratch[j * seg_cap..j * seg_cap + seg]
                    .clone_from_slice(&self.data[j * pp + i0..j * pp + i0 + seg]);
            }
            for k in 0..m {
                let dst = &mut self.data[k * pp + i0..k * pp + i0 + seg];
                dst.fill(base.zero());
                for j in 0..m {
                    let c = t.coeff(k, j);
                    if base.is_zero(c) {
                        continue;
                    }
                    slice_axpy(base, dst, c, &scratch[j * seg_cap..j * seg_cap + seg]);
                }
            }
            i0 += seg;
        }
    }

    /// Extension-ring matrix product on plane-major storage — the worker
    /// hot path. Schoolbook on planes: `m²` contiguous base-ring matmuls
    /// into `2m−1` accumulation planes, then one plane-level reduction by
    /// the monic modulus. Equivalent to the AoS [`Ring::mat_mul`] of
    /// [`Extension`] but with zero per-element allocation or plane
    /// extraction (asserted equivalent in tests and `property_tests.rs`).
    ///
    /// Runs on [`parallel::configured_threads`] scoped threads (row-panel
    /// split — see [`PlaneMatrix::matmul_threads`]); `GR_CDMM_THREADS=1`
    /// takes the exact sequential code path.
    pub fn matmul<E: PlaneRing<Base = B>>(ext: &E, a: &Self, b: &Self) -> Self {
        Self::matmul_threads(ext, a, b, parallel::configured_threads())
    }

    /// [`PlaneMatrix::matmul`] with an explicit thread count. Each thread
    /// computes a disjoint panel of output rows end to end (its own `2m−1`
    /// convolution planes + reduction, restricted to those rows) with the
    /// unchanged sequential kernels, so every output element sees the exact
    /// sequential operation sequence — results are bit-identical at every
    /// thread count (property-tested). `threads <= 1`, a single row, or
    /// sub-[`parallel::MIN_PAR_OPS`] work runs the sequential kernel
    /// directly — the exact pre-threading code path.
    pub fn matmul_threads<E: PlaneRing<Base = B>>(
        ext: &E,
        a: &Self,
        b: &Self,
        threads: usize,
    ) -> Self {
        assert_eq!(a.cols, b.rows, "inner dimensions must agree");
        let m = ext.plane_count();
        assert_eq!(a.planes, m, "lhs plane count mismatch");
        assert_eq!(b.planes, m, "rhs plane count mismatch");
        let ops = a.rows * a.cols * b.cols * m * m;
        let t = parallel::effective_threads(threads, a.rows, ops);
        if t <= 1 {
            return Self::matmul_seq(ext, a, b);
        }
        let base = ext.plane_base();
        let bc = b.cols;
        let pp = a.rows * bc;
        let ranges = split_ranges(a.rows, t);
        let panels: Vec<Vec<B::Elem>> = std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|r| {
                    let (r0, r1) = (r.start, r.end);
                    s.spawn(move || Self::matmul_rows(ext, a, b, r0, r1))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("matmul worker thread panicked"))
                .collect()
        });
        // Stitch the row panels back into plane-major output (cheap: one
        // linear pass over the m·rows·cols result the matmul just paid
        // O(rows·cols·inner·m²) to produce).
        let mut data = vec![base.zero(); m * pp];
        for (r, panel) in ranges.iter().zip(&panels) {
            let cpp = (r.end - r.start) * bc;
            for k in 0..m {
                data[k * pp + r.start * bc..k * pp + r.end * bc]
                    .clone_from_slice(&panel[k * cpp..(k + 1) * cpp]);
            }
        }
        PlaneMatrix { rows: a.rows, cols: bc, planes: m, data }
    }

    /// The sequential kernel (the exact pre-threading code path).
    fn matmul_seq<E: PlaneRing<Base = B>>(ext: &E, a: &Self, b: &Self) -> Self {
        let m = ext.plane_count();
        let base = ext.plane_base();
        let pp = a.rows * b.cols;
        let conv_planes = 2 * m - 1;
        let mut conv: Vec<B::Elem> = vec![base.zero(); conv_planes * pp];
        for i in 0..m {
            for j in 0..m {
                let k = i + j;
                slice_matmul_acc(
                    base,
                    &mut conv[k * pp..(k + 1) * pp],
                    a.plane(i),
                    b.plane(j),
                    a.rows,
                    a.cols,
                    b.cols,
                );
            }
        }
        // Reduce planes m..2m−1 by the monic modulus:
        // y^k ≡ −Σ_i h_i·y^{k−m+i}.
        let h = ext.modulus_low();
        for k in (m..conv_planes).rev() {
            let (lo, hi) = conv.split_at_mut(k * pp);
            let top = &hi[..pp];
            for (i, hc) in h.iter().enumerate() {
                if base.is_zero(hc) {
                    continue;
                }
                let neg = base.neg(hc);
                let dst = &mut lo[(k - m + i) * pp..(k - m + i + 1) * pp];
                slice_axpy(base, dst, &neg, top);
            }
        }
        conv.truncate(m * pp);
        PlaneMatrix { rows: a.rows, cols: b.cols, planes: m, data: conv }
    }

    /// One thread's share of [`PlaneMatrix::matmul_threads`]: output rows
    /// `r0..r1` across all `m` planes — the same schoolbook-on-planes +
    /// reduction as [`PlaneMatrix::matmul_seq`], restricted to a row panel
    /// of `a` (row panels of the output depend only on the matching row
    /// panel of `a` and all of `b`). Returns the panel's `m` planes,
    /// plane-major over `(r1−r0) × b.cols`.
    fn matmul_rows<E: PlaneRing<Base = B>>(
        ext: &E,
        a: &Self,
        b: &Self,
        r0: usize,
        r1: usize,
    ) -> Vec<B::Elem> {
        let m = ext.plane_count();
        let base = ext.plane_base();
        let crows = r1 - r0;
        let bc = b.cols;
        let cpp = crows * bc;
        let a_pp = a.plane_len();
        let conv_planes = 2 * m - 1;
        let mut conv: Vec<B::Elem> = vec![base.zero(); conv_planes * cpp];
        for i in 0..m {
            let a_panel = &a.data[i * a_pp + r0 * a.cols..i * a_pp + r1 * a.cols];
            for j in 0..m {
                let k = i + j;
                slice_matmul_acc(
                    base,
                    &mut conv[k * cpp..(k + 1) * cpp],
                    a_panel,
                    b.plane(j),
                    crows,
                    a.cols,
                    bc,
                );
            }
        }
        let h = ext.modulus_low();
        for k in (m..conv_planes).rev() {
            let (lo, hi) = conv.split_at_mut(k * cpp);
            let top = &hi[..cpp];
            for (i, hc) in h.iter().enumerate() {
                if base.is_zero(hc) {
                    continue;
                }
                let neg = base.neg(hc);
                let dst = &mut lo[(k - m + i) * cpp..(k - m + i + 1) * cpp];
                slice_axpy(base, dst, &neg, top);
            }
        }
        conv.truncate(m * cpp);
        conv
    }

    /// Partition into a `gr × gc` grid of equal blocks, each plane-major
    /// (dims must divide). Row-major block order, like
    /// [`Matrix::partition_grid`].
    pub fn partition_grid(&self, gr: usize, gc: usize) -> Vec<Self> {
        assert!(self.rows % gr == 0, "rows {} not divisible by {gr}", self.rows);
        assert!(self.cols % gc == 0, "cols {} not divisible by {gc}", self.cols);
        let bh = self.rows / gr;
        let bw = self.cols / gc;
        let pp = self.plane_len();
        let mut out = Vec::with_capacity(gr * gc);
        for a in 0..gr {
            for b in 0..gc {
                let mut data = Vec::with_capacity(self.planes * bh * bw);
                for k in 0..self.planes {
                    for i in 0..bh {
                        let start = k * pp + (a * bh + i) * self.cols + b * bw;
                        data.extend_from_slice(&self.data[start..start + bw]);
                    }
                }
                out.push(PlaneMatrix { rows: bh, cols: bw, planes: self.planes, data });
            }
        }
        out
    }

    /// Inverse of [`PlaneMatrix::partition_grid`].
    pub fn stitch_grid(blocks: &[Self], gr: usize, gc: usize) -> Self {
        assert_eq!(blocks.len(), gr * gc);
        let bh = blocks[0].rows;
        let bw = blocks[0].cols;
        let m = blocks[0].planes;
        let bpp = bh * bw;
        let (rows, cols) = (gr * bh, gc * bw);
        let mut data = Vec::with_capacity(m * rows * cols);
        for k in 0..m {
            for a in 0..gr {
                for i in 0..bh {
                    for b in 0..gc {
                        let blk = &blocks[a * gc + b];
                        assert_eq!((blk.rows, blk.cols, blk.planes), (bh, bw, m));
                        let start = k * bpp + i * bw;
                        data.extend_from_slice(&blk.data[start..start + bw]);
                    }
                }
            }
        }
        PlaneMatrix { rows, cols, planes: m, data }
    }

    /// Serialized byte size: 16-byte header + contiguous planes.
    pub fn byte_len<E: PlaneRing<Base = B>>(&self, ext: &E) -> usize {
        16 + self.data.len() * ext.plane_base().elem_bytes()
    }

    /// Serialize as one contiguous block:
    /// `rows (u64 LE) | cols (u64 LE) | plane 0 | … | plane m−1`.
    /// The plane count is carried by the ring context, not the wire.
    /// The element payload moves through [`Ring::write_slice`] — a single
    /// block copy for `Zq` planes, per-element for structured bases.
    pub fn to_bytes<E: PlaneRing<Base = B>>(&self, ext: &E) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.byte_len(ext));
        self.write_bytes_into(ext, &mut out);
        out
    }

    /// Append the serialized form to a **borrowed** buffer — the zero-copy
    /// hot path's entry point: the caller leases `out` from the
    /// [`crate::util::bytepool::BytePool`] (sized via [`Self::byte_len`])
    /// and this writes in place, so serialization never allocates.
    pub fn write_bytes_into<E: PlaneRing<Base = B>>(&self, ext: &E, out: &mut Vec<u8>) {
        let base = ext.plane_base();
        out.reserve(self.byte_len(ext));
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        base.write_slice(&self.data, out);
    }

    /// Read one matrix from `buf` starting at `*pos`, advancing `*pos`.
    /// Every length is validated before any allocation or read — truncated
    /// or corrupt payloads yield an `Err`, never a panic (workers report
    /// such jobs as clean failures instead of unwinding their thread).
    pub fn read_from<E: PlaneRing<Base = B>>(
        ext: &E,
        buf: &[u8],
        pos: &mut usize,
    ) -> anyhow::Result<Self> {
        let base = ext.plane_base();
        let m = ext.plane_count();
        let avail = buf.len().saturating_sub(*pos);
        anyhow::ensure!(avail >= 16, "matrix header truncated: {avail} of 16 bytes");
        let mut b8 = [0u8; 8];
        b8.copy_from_slice(&buf[*pos..*pos + 8]);
        let rows = u64::from_le_bytes(b8) as usize;
        b8.copy_from_slice(&buf[*pos + 8..*pos + 16]);
        let cols = u64::from_le_bytes(b8) as usize;
        *pos += 16;
        let count = rows
            .checked_mul(cols)
            .and_then(|x| x.checked_mul(m))
            .ok_or_else(|| anyhow::anyhow!("matrix shape {rows}x{cols}x{m} overflows"))?;
        let need = count
            .checked_mul(base.elem_bytes())
            .ok_or_else(|| anyhow::anyhow!("matrix payload size overflows"))?;
        anyhow::ensure!(
            buf.len() - *pos >= need,
            "matrix payload truncated: need {need} bytes for {rows}x{cols} ({m} planes), have {}",
            buf.len() - *pos
        );
        // Length validated above; the bulk read (one block copy for `Zq`)
        // cannot run past the buffer.
        let data: Vec<B::Elem> = base.read_slice(buf, pos, count);
        Ok(PlaneMatrix { rows, cols, planes: m, data })
    }

    /// Deserialize, requiring the buffer to be consumed exactly.
    pub fn from_bytes<E: PlaneRing<Base = B>>(ext: &E, buf: &[u8]) -> anyhow::Result<Self> {
        let mut pos = 0;
        let mat = Self::read_from(ext, buf, &mut pos)?;
        anyhow::ensure!(
            pos == buf.len(),
            "matrix payload has {} trailing bytes",
            buf.len() - pos
        );
        Ok(mat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ext3() -> Extension<Zq> {
        Extension::new(Zq::z2e(64), 3)
    }

    #[test]
    fn aos_roundtrip_and_plane_layout() {
        let ext = Extension::new(Zq::z2e(64), 2);
        let mut mat = Matrix::zeros(&ext, 1, 2);
        mat.set(0, 0, vec![10, 11]);
        mat.set(0, 1, vec![20, 21]);
        let pm = PlaneMatrix::from_aos(&ext, &mat);
        // plane 0 = [10, 20], plane 1 = [11, 21] — plane-major.
        assert_eq!(pm.data, vec![10, 20, 11, 21]);
        assert_eq!(pm.plane(0), &[10, 20]);
        assert_eq!(pm.plane(1), &[11, 21]);
        assert_eq!(pm.to_aos(&ext), mat);
    }

    #[test]
    fn matmul_matches_aos_extension_matmul() {
        for m in [1usize, 2, 3, 4, 5] {
            let ext = Extension::new(Zq::z2e(64), m);
            let mut rng = Rng64::seeded(700 + m as u64);
            let a = Matrix::random(&ext, 4, 3, &mut rng);
            let b = Matrix::random(&ext, 3, 5, &mut rng);
            let pa = PlaneMatrix::from_aos(&ext, &a);
            let pb = PlaneMatrix::from_aos(&ext, &b);
            let pc = PlaneMatrix::matmul(&ext, &pa, &pb);
            let c = Matrix::matmul(&ext, &a, &b);
            assert_eq!(pc, PlaneMatrix::from_aos(&ext, &c), "m={m}");
            assert_eq!(pc.to_aos(&ext), c, "m={m}");
        }
    }

    #[test]
    fn matmul_scalar_ring_single_plane() {
        let zq = Zq::z2e(64);
        let mut rng = Rng64::seeded(710);
        let a = Matrix::random(&zq, 5, 4, &mut rng);
        let b = Matrix::random(&zq, 4, 6, &mut rng);
        let pa = PlaneMatrix::from_aos(&zq, &a);
        let pb = PlaneMatrix::from_aos(&zq, &b);
        let pc = PlaneMatrix::matmul(&zq, &pa, &pb);
        assert_eq!(pc.data, Matrix::matmul(&zq, &a, &b).data);
    }

    #[test]
    fn axpy_and_scale_match_aos() {
        let ext = ext3();
        let mut rng = Rng64::seeded(711);
        let a = Matrix::random(&ext, 3, 4, &mut rng);
        let x = Matrix::random(&ext, 3, 4, &mut rng);
        let s = ext.random(&mut rng);
        // axpy
        let mut pa = PlaneMatrix::from_aos(&ext, &a);
        pa.axpy(&ext, &s, &PlaneMatrix::from_aos(&ext, &x));
        let mut aos = a.clone();
        aos.axpy(&ext, &s, &x);
        assert_eq!(pa, PlaneMatrix::from_aos(&ext, &aos));
        // scale
        let mut ps = PlaneMatrix::from_aos(&ext, &x);
        ps.scale_assign(&ext, &s);
        let mut xs = x.clone();
        xs.scale_assign(&ext, &s);
        assert_eq!(ps, PlaneMatrix::from_aos(&ext, &xs));
    }

    #[test]
    fn scalar_mul_table_reproduces_ring_mul() {
        let ext = ext3();
        let mut rng = Rng64::seeded(712);
        for _ in 0..20 {
            let s = ext.random(&mut rng);
            let x = ext.random(&mut rng);
            let table = ext.scalar_mul_table(&s);
            let m = ext.m();
            let base = ext.base();
            let mut got = vec![0u64; m];
            for k in 0..m {
                for j in 0..m {
                    base.mul_add_assign(&mut got[k], &table[k * m + j], &x[j]);
                }
            }
            assert_eq!(got, ext.mul(&s, &x));
        }
    }

    #[test]
    fn partition_stitch_roundtrip() {
        let ext = ext3();
        let mut rng = Rng64::seeded(713);
        let a = PlaneMatrix::random(&ext, 6, 8, &mut rng);
        for (gr, gc) in [(1, 1), (2, 2), (3, 4), (6, 8), (2, 4)] {
            let blocks = a.partition_grid(gr, gc);
            assert_eq!(blocks.len(), gr * gc);
            assert_eq!(PlaneMatrix::stitch_grid(&blocks, gr, gc), a, "grid {gr}x{gc}");
        }
    }

    #[test]
    fn partition_matches_aos_partition() {
        let ext = ext3();
        let mut rng = Rng64::seeded(714);
        let a = Matrix::random(&ext, 4, 6, &mut rng);
        let pa = PlaneMatrix::from_aos(&ext, &a);
        let blocks = a.partition_grid(2, 3);
        let pblocks = pa.partition_grid(2, 3);
        for (b, pb) in blocks.iter().zip(&pblocks) {
            assert_eq!(PlaneMatrix::from_aos(&ext, b), *pb);
        }
    }

    #[test]
    fn serialization_roundtrip_and_length() {
        let ext = ext3();
        let mut rng = Rng64::seeded(715);
        let a = PlaneMatrix::random(&ext, 3, 2, &mut rng);
        let bytes = a.to_bytes(&ext);
        assert_eq!(bytes.len(), a.byte_len(&ext));
        assert_eq!(bytes.len(), 16 + 3 * 2 * 3 * 8);
        assert_eq!(PlaneMatrix::from_bytes(&ext, &bytes).unwrap(), a);
    }

    #[test]
    fn deserialization_rejects_truncated_and_oversized() {
        let ext = ext3();
        let mut rng = Rng64::seeded(716);
        let a = PlaneMatrix::random(&ext, 3, 2, &mut rng);
        let bytes = a.to_bytes(&ext);
        // truncated header
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &bytes[..8]).is_err());
        // truncated payload
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &bytes[..bytes.len() - 1]).is_err());
        // oversized payload
        let mut big = bytes.clone();
        big.push(0);
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &big).is_err());
        // header lying about the shape
        let mut lie = bytes;
        lie[0] = 200; // rows = 200 with the same payload
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &lie).is_err());
        // empty buffer
        assert!(PlaneMatrix::<Zq>::from_bytes(&ext, &[]).is_err());
    }

    #[test]
    fn const_embedding_roundtrip() {
        let ext = ext3();
        let zq = Zq::z2e(64);
        let mut rng = Rng64::seeded(717);
        let a = Matrix::random(&zq, 3, 3, &mut rng);
        let pa = PlaneMatrix::from_base_matrix(&ext, &a);
        assert_eq!(pa.planes, 3);
        assert_eq!(pa.base_plane_matrix(), a);
        assert!(pa.plane(1).iter().all(|&x| x == 0));
        // agrees with the AoS constant embedding
        let aos = a.map(|x| ext.from_base(x));
        assert_eq!(pa, PlaneMatrix::from_aos(&ext, &aos));
    }

    #[test]
    fn table_driven_axpy_and_scale_match_build_on_the_spot() {
        let ext = ext3();
        let base = ext.base().clone();
        let mut rng = Rng64::seeded(720);
        for case in 0..10 {
            let acc0 = PlaneMatrix::random(&ext, 3, 4, &mut rng);
            let x = PlaneMatrix::random(&ext, 3, 4, &mut rng);
            let s = if case == 0 { ext.zero() } else { ext.random(&mut rng) };
            let t = ScalarTable::build(&ext, &s);
            assert_eq!(t.m(), 3);
            assert_eq!(t.is_zero_scalar(), case == 0);
            let mut a1 = acc0.clone();
            a1.axpy(&ext, &s, &x);
            let mut a2 = acc0.clone();
            a2.axpy_with_table(&base, &t, &x);
            assert_eq!(a1, a2, "case {case} axpy");
            let mut s1 = x.clone();
            s1.scale_assign(&ext, &s);
            let mut s2 = x.clone();
            s2.scale_with_table(&base, &t);
            assert_eq!(s1, s2, "case {case} scale");
            // scale agrees with elementwise ring multiplication
            let expect = x.to_aos(&ext).map(|e| ext.mul(&s, e));
            assert_eq!(s2.to_aos(&ext), expect, "case {case} scale semantics");
        }
    }

    #[test]
    fn scale_with_zero_scalar_zeroes_in_place() {
        let ext = ext3();
        let mut rng = Rng64::seeded(721);
        let mut x = PlaneMatrix::random(&ext, 2, 3, &mut rng);
        let t = ScalarTable::build(&ext, &ext.zero());
        x.scale_with_table(ext.base(), &t);
        assert!(x.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn matmul_threads_bit_identical_to_sequential() {
        // sizes above MIN_PAR_OPS so the parallel path actually engages
        let ext = ext3();
        let mut rng = Rng64::seeded(722);
        let a = PlaneMatrix::random(&ext, 24, 20, &mut rng);
        let b = PlaneMatrix::random(&ext, 20, 24, &mut rng);
        let seq = PlaneMatrix::matmul_threads(&ext, &a, &b, 1);
        for t in [2usize, 3, 8, 64] {
            assert_eq!(PlaneMatrix::matmul_threads(&ext, &a, &b, t), seq, "threads={t}");
        }
        // env-driven entry point with a pinned override agrees too
        let via_override =
            crate::util::parallel::with_threads(4, || PlaneMatrix::matmul(&ext, &a, &b));
        assert_eq!(via_override, seq);
    }

    #[test]
    fn slice_matmul_threads_bit_identical_to_sequential() {
        let zq = Zq::z2e(64);
        let mut rng = Rng64::seeded(723);
        let (ar, ac, bc) = (70usize, 33, 41);
        let a: Vec<u64> = (0..ar * ac).map(|_| zq.random(&mut rng)).collect();
        let b: Vec<u64> = (0..ac * bc).map(|_| zq.random(&mut rng)).collect();
        let mut seq = vec![0u64; ar * bc];
        slice_matmul_acc(&zq, &mut seq, &a, &b, ar, ac, bc);
        for t in [2usize, 3, 8, 64] {
            let mut par = vec![0u64; ar * bc];
            slice_matmul_acc_threads(&zq, &mut par, &a, &b, ar, ac, bc, t);
            assert_eq!(par, seq, "threads={t}");
        }
    }

    #[test]
    fn scalar_table_build_counter_counts_this_thread() {
        let ext = ext3();
        let mut rng = Rng64::seeded(724);
        let s = ext.random(&mut rng);
        let before = scalar_table_builds();
        let t = ScalarTable::build(&ext, &s);
        assert_eq!(scalar_table_builds(), before + 1);
        // table-driven ops build nothing further
        let x = PlaneMatrix::random(&ext, 2, 2, &mut rng);
        let mut acc = PlaneMatrix::zeros(&ext, 2, 2);
        acc.axpy_with_table(ext.base(), &t, &x);
        let mut y = x.clone();
        y.scale_with_table(ext.base(), &t);
        assert_eq!(scalar_table_builds(), before + 1);
        // on-the-spot axpy builds exactly one
        acc.axpy(&ext, &s, &x);
        assert_eq!(scalar_table_builds(), before + 2);
    }

    #[test]
    fn matmul_over_galois_base_tower() {
        // Extension<GaloisRing>: planes hold GrElem (Vec<u64>) — the generic
        // path still matches the AoS kernel.
        let base = GaloisRing::new(2, 16, 2);
        let ext = Extension::new(base, 2);
        let mut rng = Rng64::seeded(718);
        let a = Matrix::random(&ext, 3, 3, &mut rng);
        let b = Matrix::random(&ext, 3, 3, &mut rng);
        let pc = PlaneMatrix::matmul(
            &ext,
            &PlaneMatrix::from_aos(&ext, &a),
            &PlaneMatrix::from_aos(&ext, &b),
        );
        assert_eq!(pc.to_aos(&ext), Matrix::matmul(&ext, &a, &b));
    }
}
