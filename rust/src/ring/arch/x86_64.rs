//! AVX2 mask-mode kernels for `x86_64`, via `core::arch` intrinsics.
//!
//! Only reachable through the dispatch table, which selects this module
//! after `is_x86_feature_detected!("avx2")` succeeded at process start —
//! the public wrappers' `unsafe` blocks rely on that gate.
//!
//! AVX2 has no 64-bit low multiply (`_mm256_mullo_epi64` is AVX-512DQ), so
//! the private `mul64_lo` helper synthesizes it from three 32×32→64 partial
//! products:
//! `lo(a)·lo(b) + ((lo(a)·hi(b) + hi(a)·lo(b)) << 32)` — exactly the
//! wrapping 64-bit product, so results are bit-identical to the scalar
//! `wrapping_mul` path. Four lanes per vector, unrolled ×2 per iteration
//! (one cache line), scalar tail for non-multiple-of-4 lengths.
//!
//! Odd-modulus (Montgomery) kernels are *not* vectorized here: the inner
//! step needs a widening 64×64→128 multiply, which AVX2 cannot express
//! (AVX-512IFMA territory); the dispatch table routes `mod`-mode calls to
//! the scalar Montgomery kernels in [`super::generic`] instead.

use core::arch::x86_64::{
    __m256i, _mm256_add_epi64, _mm256_and_si256, _mm256_loadu_si256, _mm256_mul_epu32,
    _mm256_set1_epi64x, _mm256_slli_epi64, _mm256_srli_epi64, _mm256_storeu_si256,
};

/// Lane-wise wrapping 64-bit product of `a` and `b` (see module docs).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mul64_lo(a: __m256i, b: __m256i) -> __m256i {
    let a_hi = _mm256_srli_epi64::<32>(a);
    let b_hi = _mm256_srli_epi64::<32>(b);
    let lolo = _mm256_mul_epu32(a, b);
    let cross = _mm256_add_epi64(_mm256_mul_epu32(a, b_hi), _mm256_mul_epu32(a_hi, b));
    _mm256_add_epi64(lolo, _mm256_slli_epi64::<32>(cross))
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_mask_avx2(acc: &mut [u64], s: u64, x: &[u64], mask: u64) {
    debug_assert_eq!(acc.len(), x.len());
    let n = acc.len();
    let vs = _mm256_set1_epi64x(s as i64);
    let vm = _mm256_set1_epi64x(mask as i64);
    let mut j = 0;
    while j + 8 <= n {
        let ap0 = acc.as_mut_ptr().add(j).cast::<__m256i>();
        let ap1 = acc.as_mut_ptr().add(j + 4).cast::<__m256i>();
        let x0 = _mm256_loadu_si256(x.as_ptr().add(j).cast::<__m256i>());
        let x1 = _mm256_loadu_si256(x.as_ptr().add(j + 4).cast::<__m256i>());
        let s0 = _mm256_add_epi64(_mm256_loadu_si256(ap0.cast_const()), mul64_lo(x0, vs));
        let s1 = _mm256_add_epi64(_mm256_loadu_si256(ap1.cast_const()), mul64_lo(x1, vs));
        _mm256_storeu_si256(ap0, _mm256_and_si256(s0, vm));
        _mm256_storeu_si256(ap1, _mm256_and_si256(s1, vm));
        j += 8;
    }
    while j + 4 <= n {
        let ap = acc.as_mut_ptr().add(j).cast::<__m256i>();
        let xv = _mm256_loadu_si256(x.as_ptr().add(j).cast::<__m256i>());
        let sum = _mm256_add_epi64(_mm256_loadu_si256(ap.cast_const()), mul64_lo(xv, vs));
        _mm256_storeu_si256(ap, _mm256_and_si256(sum, vm));
        j += 4;
    }
    while j < n {
        acc[j] = acc[j].wrapping_add(s.wrapping_mul(x[j])) & mask;
        j += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn scale_mask_avx2(xs: &mut [u64], s: u64, mask: u64) {
    let n = xs.len();
    let vs = _mm256_set1_epi64x(s as i64);
    let vm = _mm256_set1_epi64x(mask as i64);
    let mut j = 0;
    while j + 4 <= n {
        let p = xs.as_mut_ptr().add(j).cast::<__m256i>();
        let v = _mm256_loadu_si256(p.cast_const());
        _mm256_storeu_si256(p, _mm256_and_si256(mul64_lo(v, vs), vm));
        j += 4;
    }
    while j < n {
        xs[j] = xs[j].wrapping_mul(s) & mask;
        j += 1;
    }
}

#[target_feature(enable = "avx2")]
unsafe fn matmul_mask_avx2(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    mask: u64,
) {
    // Same ikj / 64-row k-panel structure and accumulation order as the
    // scalar kernels; only the row update is vectorized.
    const KB: usize = 64;
    let mut k0 = 0;
    while k0 < ac {
        let kend = (k0 + KB).min(ac);
        for i in 0..ar {
            let crow = &mut c[i * bc..(i + 1) * bc];
            for k in k0..kend {
                let aik = a[i * ac + k];
                if aik == 0 {
                    continue;
                }
                axpy_mask_avx2(crow, aik, &b[k * bc..(k + 1) * bc], mask);
            }
        }
        k0 = kend;
    }
}

/// AVX2 `acc[j] = (acc[j] + s·x[j]) mod 2^e`.
pub fn axpy_mask(acc: &mut [u64], s: u64, x: &[u64], mask: u64) {
    // SAFETY: this function is only installed in the dispatch table when
    // `is_x86_feature_detected!("avx2")` returned true (see `arch::mod`).
    unsafe { axpy_mask_avx2(acc, s, x, mask) }
}

/// AVX2 `xs[j] = (xs[j]·s) mod 2^e`.
pub fn scale_mask(xs: &mut [u64], s: u64, mask: u64) {
    // SAFETY: AVX2 presence gated by the dispatch table (see `axpy_mask`).
    unsafe { scale_mask_avx2(xs, s, mask) }
}

/// AVX2 `c += a·b mod 2^e`.
pub fn matmul_mask(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    mask: u64,
) {
    // SAFETY: AVX2 presence gated by the dispatch table (see `axpy_mask`).
    unsafe { matmul_mask_avx2(c, a, b, ar, ac, bc, mask) }
}
