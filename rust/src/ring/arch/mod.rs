//! Runtime arch-dispatch for the `Zq` base-ring slice kernels.
//!
//! Every hot loop in the crate bottoms out in three slice primitives over
//! the base ring (see [`crate::ring::plane`]):
//!
//! * **axpy** — `acc[j] += s·x[j]` (the encode/decode table op),
//! * **scale** — `xs[j] = s·xs[j]` (in-place scalar multiply),
//! * **matmul-acc** — `c += a·b` on row-major slices (the worker share
//!   product, `m²` calls per extension-ring matmul).
//!
//! For `Zq` those primitives monomorphize to straight-line `u64` loops; this
//! module provides *several implementations of each* and picks one at
//! runtime, so the same build adapts to the machine it lands on:
//!
//! * [`Backend::Reference`] — the exact scalar loops the crate shipped with,
//!   kept verbatim in [`reference`] as the bit-identity oracle;
//! * [`Backend::Generic`] — branch-free, chunk-unrolled,
//!   autovectorizer-friendly loops ([`generic`]), plus Montgomery
//!   multiplication for odd moduli (the per-element `u128 %` disappears —
//!   see [`crate::ring::zq::Montgomery`]);
//! * [`Backend::Native`] — per-ISA kernels: AVX2 via `core::arch`
//!   intrinsics on `x86_64` (the `x86_64` module, gated at runtime on
//!   `is_x86_feature_detected!("avx2")`), the NEON-baseline path on
//!   `aarch64` (the `aarch64` module; both are `cfg`-gated, so only the
//!   host's own module exists in a given build). Hosts without native
//!   support fall back to [`Backend::Generic`].
//!
//! **Selection.** The default backend is resolved once per process
//! ([`default_backend`]): `GR_CDMM_SIMD=reference|generic|native` overrides,
//! otherwise auto-detection picks `native` where available and `generic`
//! elsewhere. [`with_backend`] installs a thread-local override for the
//! duration of a closure — the equivalence tests and the per-kernel bench
//! use it to force each backend in-process without touching the (global,
//! racy) environment. Like [`crate::util::parallel::with_threads`], the
//! override is per-thread: scoped threads spawned inside the closure read
//! the process default again. That is sound because **every backend is
//! bit-identical by construction** (each produces canonical residues, and
//! modular addition of canonical residues is order-independent), so kernels
//! may mix backends across row panels without changing a single output bit
//! — property-tested in `tests/integration_arch.rs`.
//!
//! Dispatch is a table of plain `fn` pointers ([`ZqKernels`]) rather than
//! per-call feature detection: [`crate::ring::zq::Zq`] overrides the
//! [`crate::ring::traits::Ring`] slice hooks to look the table up once per
//! slice call, so the per-element loops stay monomorphic and inlinable
//! inside each kernel.

use crate::ring::zq::Montgomery;
use std::cell::Cell;
use std::sync::OnceLock;

pub mod generic;
pub mod reference;

#[cfg(target_arch = "aarch64")]
pub mod aarch64;
#[cfg(target_arch = "x86_64")]
pub mod x86_64;

/// Which kernel family to run. See the module docs for what each means.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    /// The original scalar loops, verbatim — the bit-identity oracle.
    Reference,
    /// Branch-free autovectorizer-friendly loops + Montgomery for odd `q`.
    Generic,
    /// Per-ISA intrinsics (AVX2 / NEON); falls back to `Generic` when the
    /// host has no supported native path.
    Native,
}

impl Backend {
    /// All three backends, in escalation order.
    pub const ALL: [Backend; 3] = [Backend::Reference, Backend::Generic, Backend::Native];

    /// The `GR_CDMM_SIMD` spelling of this backend.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Reference => "reference",
            Backend::Generic => "generic",
            Backend::Native => "native",
        }
    }
}

/// Parse a `GR_CDMM_SIMD` value. `None` for anything unrecognized
/// (including `auto`/empty, which mean "detect").
pub fn parse_backend(s: &str) -> Option<Backend> {
    match s.trim().to_ascii_lowercase().as_str() {
        "reference" | "ref" => Some(Backend::Reference),
        "generic" => Some(Backend::Generic),
        "native" | "simd" => Some(Backend::Native),
        _ => None,
    }
}

/// Whether this host has a native (per-ISA) kernel path: AVX2 on `x86_64`
/// (runtime-detected), always on `aarch64` (NEON is part of the baseline
/// target). `GR_CDMM_SIMD=native` degrades to [`Backend::Generic`] when
/// this is false; native-specific tests and bench rows skip.
#[cfg(target_arch = "x86_64")]
pub fn native_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// See the `x86_64` variant: NEON is baseline on `aarch64`.
#[cfg(target_arch = "aarch64")]
pub fn native_available() -> bool {
    true
}

/// See the `x86_64` variant: no native path on other architectures.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn native_available() -> bool {
    false
}

/// The backends that run *distinct code* on this host: always
/// `[Reference, Generic]`, plus `Native` when [`native_available`]. The
/// equivalence tests and the per-kernel bench iterate exactly this set.
pub fn available_backends() -> Vec<Backend> {
    let mut v = vec![Backend::Reference, Backend::Generic];
    if native_available() {
        v.push(Backend::Native);
    }
    v
}

/// `c += a·b mod 2^e` over row-major slices: `(c, a, b, ar, ac, bc, mask)`
/// with `a: ar×ac`, `b: ac×bc`, `c: ar×bc` accumulated in place.
pub type MaskMatmulFn = fn(&mut [u64], &[u64], &[u64], usize, usize, usize, u64);

/// `c += a·b mod q` over row-major slices: `(c, a, b, ar, ac, bc, mont)`,
/// canonical residues throughout.
pub type ModMatmulFn = fn(&mut [u64], &[u64], &[u64], usize, usize, usize, &Montgomery);

/// The per-`Zq`-representation kernel table one backend provides. `mask`
/// kernels serve `p = 2` moduli (wrap-around `u64` + mask, exact mod `2^e`);
/// `mod` kernels serve odd `p^e` through the ring's precomputed
/// [`Montgomery`] constants. All slices are row-major.
pub struct ZqKernels {
    /// Human-readable kernel-family name (shown by the bench).
    pub name: &'static str,
    /// `acc[j] = (acc[j] + s·x[j]) mod 2^e`.
    pub axpy_mask: fn(acc: &mut [u64], s: u64, x: &[u64], mask: u64),
    /// `xs[j] = (xs[j]·s) mod 2^e`.
    pub scale_mask: fn(xs: &mut [u64], s: u64, mask: u64),
    /// `c += a·b mod 2^e`.
    pub matmul_mask: MaskMatmulFn,
    /// `acc[j] = (acc[j] + s·x[j]) mod q`, canonical residues.
    pub axpy_mod: fn(acc: &mut [u64], s: u64, x: &[u64], m: &Montgomery),
    /// `xs[j] = (xs[j]·s) mod q`, canonical residues.
    pub scale_mod: fn(xs: &mut [u64], s: u64, m: &Montgomery),
    /// `c += a·b mod q`, canonical residues.
    pub matmul_mod: ModMatmulFn,
}

static REFERENCE_KERNELS: ZqKernels = ZqKernels {
    name: "reference",
    axpy_mask: reference::axpy_mask,
    scale_mask: reference::scale_mask,
    matmul_mask: reference::matmul_mask,
    axpy_mod: reference::axpy_mod,
    scale_mod: reference::scale_mod,
    matmul_mod: reference::matmul_mod,
};

static GENERIC_KERNELS: ZqKernels = ZqKernels {
    name: "generic",
    axpy_mask: generic::axpy_mask,
    scale_mask: generic::scale_mask,
    matmul_mask: generic::matmul_mask,
    axpy_mod: generic::axpy_mod,
    scale_mod: generic::scale_mod,
    matmul_mod: generic::matmul_mod,
};

// Native mask-mode kernels are hand-vectorized per ISA. The odd-q path
// stays on the generic Montgomery kernels under Native too: a widening
// 64×64→128 vector multiply does not exist below AVX-512IFMA, so the
// scalar Montgomery loop is already the best encoding (documented in
// ARCHITECTURE.md → "SIMD kernel dispatch").
#[cfg(target_arch = "x86_64")]
static NATIVE_KERNELS: ZqKernels = ZqKernels {
    name: "native-avx2",
    axpy_mask: x86_64::axpy_mask,
    scale_mask: x86_64::scale_mask,
    matmul_mask: x86_64::matmul_mask,
    axpy_mod: generic::axpy_mod,
    scale_mod: generic::scale_mod,
    matmul_mod: generic::matmul_mod,
};

#[cfg(target_arch = "aarch64")]
static NATIVE_KERNELS: ZqKernels = ZqKernels {
    name: "native-neon",
    axpy_mask: aarch64::axpy_mask,
    scale_mask: aarch64::scale_mask,
    matmul_mask: aarch64::matmul_mask,
    axpy_mod: generic::axpy_mod,
    scale_mod: generic::scale_mod,
    matmul_mod: generic::matmul_mod,
};

fn native_kernels() -> &'static ZqKernels {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if native_available() {
        return &NATIVE_KERNELS;
    }
    &GENERIC_KERNELS
}

static DEFAULT_BACKEND: OnceLock<Backend> = OnceLock::new();

/// The process-wide backend, resolved once on first use: `GR_CDMM_SIMD` if
/// set and recognized (`native` degrades to `generic` with a warning when
/// unsupported), else `native` where [`native_available`], else `generic`.
pub fn default_backend() -> Backend {
    *DEFAULT_BACKEND.get_or_init(|| {
        let auto = if native_available() { Backend::Native } else { Backend::Generic };
        let Ok(v) = std::env::var("GR_CDMM_SIMD") else {
            return auto;
        };
        match parse_backend(&v) {
            Some(Backend::Native) if !native_available() => {
                eprintln!(
                    "[gr-cdmm] GR_CDMM_SIMD=native: no native SIMD path on this host, \
                     using generic"
                );
                Backend::Generic
            }
            Some(b) => b,
            None => {
                let t = v.trim();
                if !(t.is_empty() || t.eq_ignore_ascii_case("auto")) {
                    eprintln!(
                        "[gr-cdmm] unrecognized GR_CDMM_SIMD={t:?} \
                         (expected reference|generic|native|auto), using {}",
                        auto.name()
                    );
                }
                auto
            }
        }
    })
}

thread_local! {
    static BACKEND_OVERRIDE: Cell<Option<Backend>> = const { Cell::new(None) };
}

/// Run `f` with the backend pinned to `b` **on the current thread**
/// (restored afterwards, panic-safe) — the in-process counterpart of
/// setting `GR_CDMM_SIMD`. Threads spawned inside `f` (e.g. the row-panel
/// matmul threads) use the process default; mixing backends is safe
/// because all backends are bit-identical (see module docs).
pub fn with_backend<T>(b: Backend, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<Backend>);
    impl Drop for Restore {
        fn drop(&mut self) {
            BACKEND_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = BACKEND_OVERRIDE.with(|c| c.replace(Some(b)));
    let _restore = Restore(prev);
    f()
}

/// The backend the current thread's kernels run: the [`with_backend`]
/// override if active, else [`default_backend`].
pub fn active_backend() -> Backend {
    BACKEND_OVERRIDE.with(|c| c.get()).unwrap_or_else(default_backend)
}

/// The kernel table of a specific backend. `Native` resolves to the
/// generic table when [`native_available`] is false, so a table fetched
/// here is always safe to call on this host.
pub fn kernels_for(b: Backend) -> &'static ZqKernels {
    match b {
        Backend::Reference => &REFERENCE_KERNELS,
        Backend::Generic => &GENERIC_KERNELS,
        Backend::Native => native_kernels(),
    }
}

/// The kernel table for [`active_backend`] — what the `Zq` slice hooks call.
#[inline]
pub fn active_kernels() -> &'static ZqKernels {
    kernels_for(active_backend())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_backend_spellings() {
        assert_eq!(parse_backend("reference"), Some(Backend::Reference));
        assert_eq!(parse_backend("REF"), Some(Backend::Reference));
        assert_eq!(parse_backend(" generic "), Some(Backend::Generic));
        assert_eq!(parse_backend("native"), Some(Backend::Native));
        assert_eq!(parse_backend("simd"), Some(Backend::Native));
        assert_eq!(parse_backend("auto"), None);
        assert_eq!(parse_backend(""), None);
        assert_eq!(parse_backend("avx512"), None);
    }

    #[test]
    fn with_backend_overrides_and_restores() {
        let outer = active_backend();
        let inner = with_backend(Backend::Reference, active_backend);
        assert_eq!(inner, Backend::Reference);
        assert_eq!(active_backend(), outer);
        with_backend(Backend::Generic, || {
            assert_eq!(active_backend(), Backend::Generic);
            with_backend(Backend::Reference, || {
                assert_eq!(active_backend(), Backend::Reference);
            });
            assert_eq!(active_backend(), Backend::Generic);
        });
        assert_eq!(active_backend(), outer);
    }

    #[test]
    fn kernels_for_native_always_callable() {
        // Whatever the host, the Native table must resolve to something
        // runnable (the AVX2 table only when detection succeeded).
        let k = kernels_for(Backend::Native);
        let mut acc = vec![1u64, 2, 3];
        (k.axpy_mask)(&mut acc, 3, &[10, 20, 30], u64::MAX);
        assert_eq!(acc, vec![31, 62, 93]);
    }

    #[test]
    fn available_backends_distinct_and_ordered() {
        let av = available_backends();
        assert!(av.starts_with(&[Backend::Reference, Backend::Generic]));
        assert_eq!(av.len(), 2 + usize::from(native_available()));
    }
}
