//! The `aarch64` native path.
//!
//! NEON is part of the baseline `aarch64` target, so the compiler already
//! has full SIMD codegen freedom for every function in this crate — there
//! is no runtime feature to detect and no `target_feature` gate to cross
//! (`native_available()` is constantly true here). NEON also has no 64-bit
//! lane multiply: the optimal encoding of the mask-mode kernels is the
//! `umull`/`umlal` 32×32→64 partial-product sequence, which LLVM emits
//! from the branch-free chunk-unrolled loops in [`super::generic`] as-is.
//! Hand-written `vmull_u32` intrinsics reproduce the same instruction
//! sequence with more unsafe surface, so this module delegates and exists
//! as the anchor point for future explicit NEON work (e.g. SVE once
//! runtime detection lands in std).
//!
//! The delegation is still a distinct dispatch entry (`native-neon`) so
//! `GR_CDMM_SIMD=native` is meaningful — and testable — on aarch64 hosts.

/// NEON-baseline `acc[j] = (acc[j] + s·x[j]) mod 2^e`.
pub fn axpy_mask(acc: &mut [u64], s: u64, x: &[u64], mask: u64) {
    super::generic::axpy_mask(acc, s, x, mask)
}

/// NEON-baseline `xs[j] = (xs[j]·s) mod 2^e`.
pub fn scale_mask(xs: &mut [u64], s: u64, mask: u64) {
    super::generic::scale_mask(xs, s, mask)
}

/// NEON-baseline `c += a·b mod 2^e`.
pub fn matmul_mask(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    mask: u64,
) {
    super::generic::matmul_mask(c, a, b, ar, ac, bc, mask)
}
