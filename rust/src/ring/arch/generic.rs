//! Branch-free, chunk-unrolled kernels for any target — the autovectorizer
//! path, and the floor every `native` backend must beat.
//!
//! **Mask mode** (`q = 2^e`): the math is wrap-around `u64` multiply/add
//! plus a mask — exact, order-independent, and fully vectorizable. The
//! loops below differ from [`super::reference`] only in shape: fixed-width
//! chunks (`chunks_exact`) tell LLVM the trip count is a multiple of the
//! unroll factor, so it emits clean SIMD bodies without scalar prologue
//! guesswork, and the matmul keeps the `a_ik` zero-skip *outside* the inner
//! column loop (one branch per row sweep, never per element).
//!
//! **Mod mode** (odd `q = p^e`): Montgomery multiplication
//! ([`crate::ring::zq::Montgomery`]) replaces the per-element `u128 %`.
//! The scalar operand is converted to Montgomery form **once per slice
//! call** (`s·R mod q`), after which each element costs three 64×64→128
//! multiplies and no division: `mont_mul(s·R, x) = s·x mod q`, already
//! canonical. Canonical outputs are what make this bit-identical to the
//! reference `%` path — both produce the unique representative in `[0, q)`.
//!
//! Bit-identity across backends is asserted in `tests/integration_arch.rs`.

use crate::ring::zq::Montgomery;

/// Unroll width for the mask-mode element loops: 8 × u64 = one cache line,
/// two AVX2 vectors, four NEON vectors — a multiple of every lane width in
/// play.
const LANES: usize = 8;

/// `acc[j] = (acc[j] + s·x[j]) mod 2^e`, branch-free and chunk-unrolled.
pub fn axpy_mask(acc: &mut [u64], s: u64, x: &[u64], mask: u64) {
    debug_assert_eq!(acc.len(), x.len());
    let split = acc.len() - acc.len() % LANES;
    let (a_main, a_tail) = acc.split_at_mut(split);
    let (x_main, x_tail) = x.split_at(split);
    for (ac, xc) in a_main.chunks_exact_mut(LANES).zip(x_main.chunks_exact(LANES)) {
        for (a, b) in ac.iter_mut().zip(xc) {
            *a = a.wrapping_add(s.wrapping_mul(*b)) & mask;
        }
    }
    for (a, b) in a_tail.iter_mut().zip(x_tail) {
        *a = a.wrapping_add(s.wrapping_mul(*b)) & mask;
    }
}

/// `xs[j] = (xs[j]·s) mod 2^e`, branch-free and chunk-unrolled.
pub fn scale_mask(xs: &mut [u64], s: u64, mask: u64) {
    let split = xs.len() - xs.len() % LANES;
    let (main, tail) = xs.split_at_mut(split);
    for chunk in main.chunks_exact_mut(LANES) {
        for x in chunk.iter_mut() {
            *x = x.wrapping_mul(s) & mask;
        }
    }
    for x in tail.iter_mut() {
        *x = x.wrapping_mul(s) & mask;
    }
}

/// `c += a·b mod 2^e`: same ikj / 64-row k-panel structure as the
/// reference kernel (same memory access pattern, same accumulation order),
/// with the inner row update running through the unrolled [`axpy_mask`].
/// Skipping `a_ik = 0` rows is kept — adding a zero product is bitwise a
/// no-op, so the skip cannot change results, and encode matrices are often
/// sparse in a plane.
pub fn matmul_mask(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    mask: u64,
) {
    const KB: usize = 64;
    let mut k0 = 0;
    while k0 < ac {
        let kend = (k0 + KB).min(ac);
        for i in 0..ar {
            let crow = &mut c[i * bc..(i + 1) * bc];
            for k in k0..kend {
                let aik = a[i * ac + k];
                if aik == 0 {
                    continue;
                }
                axpy_mask(crow, aik, &b[k * bc..(k + 1) * bc], mask);
            }
        }
        k0 = kend;
    }
}

/// `acc[j] = (acc[j] + s·x[j]) mod q` via Montgomery: `s` enters Montgomery
/// form once, then each element is one `mont_mul` + one conditional-subtract
/// add — no division anywhere. Outputs are canonical residues, bit-identical
/// to the reference `%` loop.
pub fn axpy_mod(acc: &mut [u64], s: u64, x: &[u64], m: &Montgomery) {
    debug_assert_eq!(acc.len(), x.len());
    let sm = m.to_mont(s);
    for (a, b) in acc.iter_mut().zip(x) {
        *a = m.add(*a, m.mul(sm, *b));
    }
}

/// `xs[j] = (xs[j]·s) mod q` via Montgomery (see [`axpy_mod`]).
pub fn scale_mod(xs: &mut [u64], s: u64, m: &Montgomery) {
    let sm = m.to_mont(s);
    for x in xs.iter_mut() {
        *x = m.mul(sm, *x);
    }
}

/// `c += a·b mod q` via Montgomery: each `a_ik` is converted to Montgomery
/// form once per row sweep (amortized over `bc` columns), the inner loop is
/// division-free. Same panel structure and accumulation order as the
/// reference kernel.
pub fn matmul_mod(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    m: &Montgomery,
) {
    const KB: usize = 64;
    let mut k0 = 0;
    while k0 < ac {
        let kend = (k0 + KB).min(ac);
        for i in 0..ar {
            let crow = &mut c[i * bc..(i + 1) * bc];
            for k in k0..kend {
                let aik = a[i * ac + k];
                if aik == 0 {
                    continue;
                }
                let am = m.to_mont(aik);
                let brow = &b[k * bc..(k + 1) * bc];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj = m.add(*cj, m.mul(am, *bj));
                }
            }
        }
        k0 = kend;
    }
}
