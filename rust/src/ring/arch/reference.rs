//! The scalar oracle kernels — the exact loops the crate shipped with
//! before the dispatch layer existed, kept **verbatim** (including the
//! per-`a_ik` `is_zero` skip and the `u128 %` reduction for odd `q`).
//!
//! Everything the optimized backends produce is asserted bit-identical to
//! these in `tests/integration_arch.rs` and the property tests; do not
//! "improve" them — their value is being the unchanged baseline. Forced via
//! `GR_CDMM_SIMD=reference`.

use crate::ring::zq::Montgomery;

/// `acc[j] = (acc[j] + s·x[j]) mod 2^e` — the original `Zq::mul_add_assign`
/// mask-mode loop.
pub fn axpy_mask(acc: &mut [u64], s: u64, x: &[u64], mask: u64) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a = a.wrapping_add(s.wrapping_mul(*b)) & mask;
    }
}

/// `xs[j] = (xs[j]·s) mod 2^e` — the original `Matrix::scale_assign` order
/// (`x·s`; multiplication is commutative, kept for bit-layout fidelity).
pub fn scale_mask(xs: &mut [u64], s: u64, mask: u64) {
    for x in xs.iter_mut() {
        *x = x.wrapping_mul(s) & mask;
    }
}

/// `c += a·b mod 2^e` — the original `slice_matmul_acc` body: ikj order,
/// 64-row k-panels of `b`, per-`a_ik` zero skip.
pub fn matmul_mask(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    mask: u64,
) {
    const KB: usize = 64;
    let mut k0 = 0;
    while k0 < ac {
        let kend = (k0 + KB).min(ac);
        for i in 0..ar {
            let crow = &mut c[i * bc..(i + 1) * bc];
            for k in k0..kend {
                let aik = a[i * ac + k];
                if aik == 0 {
                    continue;
                }
                let brow = &b[k * bc..(k + 1) * bc];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj = cj.wrapping_add(aik.wrapping_mul(*bj)) & mask;
                }
            }
        }
        k0 = kend;
    }
}

/// `acc[j] = (acc[j] + s·x[j]) mod q` — the original odd-modulus
/// `Zq::mul_add_assign` loop: `u128` product, `%` reduction, conditional
/// subtract. Only reads `m.q`; the Montgomery constants are for the
/// optimized backends.
pub fn axpy_mod(acc: &mut [u64], s: u64, x: &[u64], m: &Montgomery) {
    debug_assert_eq!(acc.len(), x.len());
    let q = m.q;
    for (a, b) in acc.iter_mut().zip(x) {
        let t = ((s as u128 * *b as u128) % q as u128) as u64;
        let sum = *a + t; // both < q < 2^63, no overflow
        *a = if sum >= q { sum - q } else { sum };
    }
}

/// `xs[j] = (xs[j]·s) mod q` — the original odd-modulus `Zq::mul` loop.
pub fn scale_mod(xs: &mut [u64], s: u64, m: &Montgomery) {
    let q = m.q;
    for x in xs.iter_mut() {
        *x = ((*x as u128 * s as u128) % q as u128) as u64;
    }
}

/// `c += a·b mod q` — the original `slice_matmul_acc` body for odd `q`.
pub fn matmul_mod(
    c: &mut [u64],
    a: &[u64],
    b: &[u64],
    ar: usize,
    ac: usize,
    bc: usize,
    m: &Montgomery,
) {
    let q = m.q;
    const KB: usize = 64;
    let mut k0 = 0;
    while k0 < ac {
        let kend = (k0 + KB).min(ac);
        for i in 0..ar {
            let crow = &mut c[i * bc..(i + 1) * bc];
            for k in k0..kend {
                let aik = a[i * ac + k];
                if aik == 0 {
                    continue;
                }
                let brow = &b[k * bc..(k + 1) * bc];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    let t = ((aik as u128 * *bj as u128) % q as u128) as u64;
                    let sum = *cj + t;
                    *cj = if sum >= q { sum - q } else { sum };
                }
            }
        }
        k0 = kend;
    }
}
