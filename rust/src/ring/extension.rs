//! `Extension<R>` — the tower `GR_m = R[y]/(h(y))` over a Galois ring `R`,
//! i.e. `GR(p^e, D·m)` *presented as a degree-m extension of* `GR(p^e, D)`.
//!
//! This presentation is exactly what RMFE needs (Section III-A): `φ` embeds a
//! vector of base-ring values as the coefficients of an interpolated
//! polynomial in the generator `y`, and `ψ` reads coefficients back. A flat
//! representation of `GR(p^e, Dm)` would require explicit basis-change
//! matrices; the tower gives the maps for free.

use super::galois::ExtensibleRing;
use super::gfp::{Gfq, GfqElem};
use super::irreducible::find_irreducible;
use super::traits::Ring;
use super::matrix::Matrix;
use super::zq::Zq;
use crate::util::rng::Rng64;

/// Degree-`m` extension ring of a base Galois ring `R`.
#[derive(Clone, Debug)]
pub struct Extension<R: ExtensibleRing> {
    base: R,
    m: usize,
    /// Monic modulus `h` of degree `m` over the base ring, with `h̄`
    /// irreducible over the base's residue field. Length `m+1`.
    modulus: Vec<R::Elem>,
    /// The base's residue field (cached for exceptional-point enumeration).
    base_rf: Gfq,
}

/// Element: little-endian coefficients over the base ring, length `m`.
pub type ExtElem<R> = Vec<<R as Ring>::Elem>;

impl<R: ExtensibleRing> Extension<R> {
    /// Build `R[y]/(h)` with the lexicographically-first valid modulus
    /// (deterministic): `h̄` is the first monic irreducible of degree `m`
    /// over the residue field of `R`, digit-lifted.
    pub fn new(base: R, m: usize) -> Extension<R> {
        assert!(m >= 1);
        let base_rf = base.residue_field();
        let hbar = find_irreducible(&base_rf, m);
        let modulus: Vec<R::Elem> = hbar.iter().map(|c| base.lift_residue(c)).collect();
        Extension { base, m, modulus, base_rf }
    }

    /// Smallest extension of `base` whose exceptional set has at least
    /// `n_points` points, i.e. `m = ⌈log_{p^D}(n_points)⌉` (the paper's
    /// `m = ⌈(log_p N)/d⌉`).
    pub fn with_capacity(base: R, n_points: usize) -> Extension<R> {
        let pd = base.residue_size();
        let mut m = 1usize;
        let mut cap = pd;
        while cap < n_points as u128 {
            m += 1;
            cap = cap.saturating_mul(pd);
        }
        Extension::new(base, m)
    }

    pub fn base(&self) -> &R {
        &self.base
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn modulus(&self) -> &[R::Elem] {
        &self.modulus
    }

    /// Embed a base-ring element as the constant of the extension.
    pub fn from_base(&self, c: &R::Elem) -> ExtElem<R> {
        let mut v = vec![self.base.zero(); self.m];
        v[0] = c.clone();
        v
    }

    /// Element with the given low coefficients (padded with zeros).
    pub fn from_coeffs(&self, coeffs: &[R::Elem]) -> ExtElem<R> {
        assert!(coeffs.len() <= self.m);
        let mut v = coeffs.to_vec();
        v.resize(self.m, self.base.zero());
        v
    }

    /// Coefficient view (the ψ side of RMFE reads these).
    pub fn coeffs<'a>(&self, a: &'a ExtElem<R>) -> &'a [R::Elem] {
        a
    }

    /// Split an AoS extension matrix into its `m` coefficient planes over
    /// the base ring (`planes[k][i,j] = M[i,j][k]`). This *copies*; code
    /// that holds a [`crate::ring::plane::PlaneMatrix`] gets the same planes
    /// as zero-copy slices via [`crate::ring::plane::PlaneMatrix::plane`].
    pub fn planes(&self, mat: &Matrix<ExtElem<R>>) -> Vec<Matrix<R::Elem>> {
        (0..self.m).map(|k| mat.map(|e| e[k].clone())).collect()
    }

    /// Inverse of [`Extension::planes`] (takes the low `m` planes).
    pub fn from_planes(&self, planes: &[Matrix<R::Elem>]) -> Matrix<ExtElem<R>> {
        let (rows, cols) = (planes[0].rows, planes[0].cols);
        Matrix::from_fn(rows, cols, |i, j| {
            (0..self.m).map(|k| planes[k].at(i, j).clone()).collect()
        })
    }

    /// Reduce a stack of `2m−1` coefficient-plane matrices by the monic
    /// modulus, in place (the matrix-level analogue of [`Self::reduce_poly`]).
    fn reduce_planes(&self, planes: &mut Vec<Matrix<R::Elem>>) {
        let m = self.m;
        let base = &self.base;
        for k in (m..planes.len()).rev() {
            let top = planes[k].clone();
            for i in 0..m {
                if !base.is_zero(&self.modulus[i]) {
                    let neg = base.neg(&self.modulus[i]);
                    planes[k - m + i].axpy(base, &neg, &top);
                }
            }
        }
        planes.truncate(m);
    }

    /// Reduce a raw product (length ≤ 2m−1) by the monic modulus.
    fn reduce_poly(&self, mut prod: Vec<R::Elem>) -> ExtElem<R> {
        let m = self.m;
        for k in (m..prod.len()).rev() {
            let c = prod[k].clone();
            if self.base.is_zero(&c) {
                continue;
            }
            prod[k] = self.base.zero();
            for i in 0..m {
                if !self.base.is_zero(&self.modulus[i]) {
                    let delta = self.base.mul(&c, &self.modulus[i]);
                    prod[k - m + i] = self.base.sub(&prod[k - m + i], &delta);
                }
            }
        }
        prod.truncate(m);
        prod
    }
}

impl<R: ExtensibleRing> Ring for Extension<R> {
    type Elem = ExtElem<R>;

    #[inline]
    fn p(&self) -> u64 {
        self.base.p()
    }
    #[inline]
    fn e(&self) -> u32 {
        self.base.e()
    }
    #[inline]
    fn degree(&self) -> usize {
        self.base.degree() * self.m
    }

    fn zero(&self) -> Self::Elem {
        vec![self.base.zero(); self.m]
    }

    fn one(&self) -> Self::Elem {
        self.from_base(&self.base.one())
    }

    fn add(&self, a: &Self::Elem, b: &Self::Elem) -> Self::Elem {
        a.iter().zip(b).map(|(x, y)| self.base.add(x, y)).collect()
    }

    fn sub(&self, a: &Self::Elem, b: &Self::Elem) -> Self::Elem {
        a.iter().zip(b).map(|(x, y)| self.base.sub(x, y)).collect()
    }

    fn neg(&self, a: &Self::Elem) -> Self::Elem {
        a.iter().map(|x| self.base.neg(x)).collect()
    }

    fn mul(&self, a: &Self::Elem, b: &Self::Elem) -> Self::Elem {
        let m = self.m;
        if m == 1 {
            return vec![self.base.mul(&a[0], &b[0])];
        }
        let mut prod = vec![self.base.zero(); 2 * m - 1];
        for (i, ai) in a.iter().enumerate() {
            if self.base.is_zero(ai) {
                continue;
            }
            for (j, bj) in b.iter().enumerate() {
                self.base.mul_add_assign(&mut prod[i + j], ai, bj);
            }
        }
        self.reduce_poly(prod)
    }

    fn add_assign(&self, a: &mut Self::Elem, b: &Self::Elem) {
        for (x, y) in a.iter_mut().zip(b) {
            self.base.add_assign(x, y);
        }
    }

    fn is_zero(&self, a: &Self::Elem) -> bool {
        a.iter().all(|c| self.base.is_zero(c))
    }

    fn is_unit(&self, a: &Self::Elem) -> bool {
        // unit ⟺ a ≢ 0 mod p ⟺ some coefficient is ≢ 0 mod p, and in a
        // Galois ring "≢ 0 mod p" ⟺ unit (residue field).
        a.iter().any(|c| self.base.is_unit(c))
    }

    fn exceptional_points(&self, n: usize) -> anyhow::Result<Vec<Self::Elem>> {
        let cap = self.residue_size();
        anyhow::ensure!(
            (n as u128) <= cap,
            "{} has only {} exceptional points, {} requested",
            self.name(),
            cap,
            n
        );
        // Mixed-radix enumeration: index → m digits in base p^D, each digit
        // lifted from the base's residue field. Two distinct indices differ in
        // some digit, whose base-ring difference is a unit ⇒ the extension
        // difference is ≢ 0 mod p ⇒ a unit.
        let pd = self.base.residue_size();
        let mut pts = Vec::with_capacity(n);
        for idx in 0..n as u128 {
            let mut v = Vec::with_capacity(self.m);
            let mut rem = idx;
            for _ in 0..self.m {
                let digit = rem % pd;
                rem /= pd;
                v.push(self.base.lift_residue(&self.base_rf.element_from_index(digit)));
            }
            pts.push(v);
        }
        Ok(pts)
    }

    fn elem_bytes(&self) -> usize {
        self.base.elem_bytes() * self.m
    }

    fn write_elem(&self, a: &Self::Elem, out: &mut Vec<u8>) {
        for c in a {
            self.base.write_elem(c, out);
        }
    }

    fn read_elem(&self, buf: &[u8], pos: &mut usize) -> Self::Elem {
        (0..self.m).map(|_| self.base.read_elem(buf, pos)).collect()
    }

    fn random(&self, rng: &mut Rng64) -> Self::Elem {
        (0..self.m).map(|_| self.base.random(rng)).collect()
    }

    fn name(&self) -> String {
        format!(
            "GR({}^{}, {}·{}) [= {}[y]/h]",
            self.p(),
            self.e(),
            self.base.degree(),
            self.m,
            self.base.name()
        )
    }

    /// §Perf override: extension matmul as `m²` *base-ring* matmuls on
    /// coefficient planes + one plane-level modulus reduction. The base
    /// matmuls monomorphize to tight `u64` loops for `Zq`, removing all
    /// per-element `Vec` allocation from the worker hot path
    /// (~5× on GR(2^64,3) 128³ — see EXPERIMENTS.md §Perf).
    ///
    /// This AoS entry point still pays the plane extraction/reassembly per
    /// call. The coding/coordinator layers therefore keep matrices in the
    /// plane-major [`crate::ring::plane::PlaneMatrix`] end-to-end and use
    /// [`crate::ring::plane::PlaneMatrix::matmul`], which runs the same
    /// kernel directly on flat plane storage (asserted equivalent to this
    /// method in `ring::plane` tests and `property_tests.rs`); this method
    /// remains the reference implementation for AoS callers.
    fn mat_mul(
        &self,
        a: &Matrix<Self::Elem>,
        b: &Matrix<Self::Elem>,
    ) -> Matrix<Self::Elem> {
        assert_eq!(a.cols, b.rows, "inner dimensions must agree");
        let m = self.m;
        let base = &self.base;
        let ap = self.planes(a);
        let bp = self.planes(b);
        let mut planes: Vec<Matrix<R::Elem>> = (0..2 * m - 1)
            .map(|_| Matrix::zeros(base, a.rows, b.cols))
            .collect();
        for (i, api) in ap.iter().enumerate() {
            for (j, bpj) in bp.iter().enumerate() {
                let prod = base.mat_mul(api, bpj);
                planes[i + j].add_assign(base, &prod);
            }
        }
        self.reduce_planes(&mut planes);
        self.from_planes(&planes)
    }

    // NOTE (§Perf iteration 3, reverted): a plane-decomposed `mat_axpy`
    // override was measured ~1.3–1.6× SLOWER than the default elementwise
    // loop (the plane extraction + 2m−1 temporaries cost more memory traffic
    // than the per-element schoolbook saves). The default stands; see
    // EXPERIMENTS.md §Perf for the measurements.
}

/// `Extension<Zq>` can itself serve as a tower base (needed for concatenated
/// RMFEs, Lemma II.5): with scalar base coefficients the residue field is the
/// flat `GF(p)[y]/(h̄)`, directly expressible as a [`Gfq`]. Towers over
/// `Extension<GaloisRing>` would need a minimal-polynomial computation and
/// are not required by any construction in the paper.
impl ExtensibleRing for Extension<Zq> {
    fn residue_field(&self) -> Gfq {
        let p = self.p();
        let modulus: Vec<u64> = self.modulus.iter().map(|c| c % p).collect();
        Gfq::new(p, modulus)
    }
    fn lift_residue(&self, r: &GfqElem) -> ExtElem<Zq> {
        debug_assert_eq!(r.len(), self.m);
        r.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::traits::is_exceptional_sequence;
    use crate::ring::zq::Zq;
    use crate::ring::galois::GaloisRing;

    /// GR(2^64, 3) as a degree-3 extension of Z_2^64 — the paper's 8-worker ring.
    fn gr64_3() -> Extension<Zq> {
        Extension::new(Zq::z2e(64), 3)
    }

    #[test]
    fn construct_and_sizes() {
        let r = gr64_3();
        assert_eq!(r.degree(), 3);
        assert_eq!(r.residue_size(), 8);
        assert_eq!(r.elem_bytes(), 24);
    }

    #[test]
    fn capacity_picks_smallest_m() {
        // N=8 workers need m=3 over Z_2^e; N=16 need m=4 (paper §V.A).
        assert_eq!(Extension::with_capacity(Zq::z2e(64), 8).m(), 3);
        assert_eq!(Extension::with_capacity(Zq::z2e(64), 16).m(), 4);
        assert_eq!(Extension::with_capacity(Zq::z2e(64), 32).m(), 5);
        assert_eq!(Extension::with_capacity(Zq::z2e(64), 2).m(), 1);
        // over GR(2^e,2): residue 4, N=16 → m=2
        let base = GaloisRing::new(2, 32, 2);
        assert_eq!(Extension::with_capacity(base, 16).m(), 2);
    }

    #[test]
    fn ring_axioms_smoke() {
        let r = gr64_3();
        let mut rng = Rng64::seeded(21);
        for _ in 0..40 {
            let a = r.random(&mut rng);
            let b = r.random(&mut rng);
            let c = r.random(&mut rng);
            assert_eq!(r.mul(&a, &b), r.mul(&b, &a));
            assert_eq!(r.mul(&r.mul(&a, &b), &c), r.mul(&a, &r.mul(&b, &c)));
            assert_eq!(
                r.mul(&a, &r.add(&b, &c)),
                r.add(&r.mul(&a, &b), &r.mul(&a, &c))
            );
            assert_eq!(r.mul(&a, &r.one()), a);
        }
    }

    #[test]
    fn inverses_in_tower() {
        let r = gr64_3();
        let mut rng = Rng64::seeded(22);
        let mut tested = 0;
        while tested < 20 {
            let a = r.random(&mut rng);
            if !r.is_unit(&a) {
                continue;
            }
            let inv = r.inv(&a).unwrap();
            assert_eq!(r.mul(&a, &inv), r.one());
            tested += 1;
        }
    }

    #[test]
    fn inverses_in_tower_over_galois_base() {
        // GR(2^8, 2)[y]/(h), m=3 — residue field GF(64).
        let base = GaloisRing::new(2, 8, 2);
        let r = Extension::new(base, 3);
        assert_eq!(r.degree(), 6);
        let mut rng = Rng64::seeded(23);
        let mut tested = 0;
        while tested < 15 {
            let a = r.random(&mut rng);
            if !r.is_unit(&a) {
                continue;
            }
            assert_eq!(r.mul(&a, &r.inv(&a).unwrap()), r.one());
            tested += 1;
        }
    }

    #[test]
    fn exceptional_points_gr64_3() {
        let r = gr64_3();
        let pts = r.exceptional_points(8).unwrap();
        assert_eq!(pts.len(), 8);
        assert!(is_exceptional_sequence(&r, &pts));
        assert!(r.exceptional_points(9).is_err());
    }

    #[test]
    fn exceptional_points_gr64_4_sixteen_workers() {
        let r = Extension::new(Zq::z2e(64), 4);
        let pts = r.exceptional_points(16).unwrap();
        assert!(is_exceptional_sequence(&r, &pts));
    }

    #[test]
    fn exceptional_points_tower_base_gr() {
        let base = GaloisRing::new(2, 16, 2);
        let r = Extension::new(base, 2); // residue GF(16)
        let pts = r.exceptional_points(16).unwrap();
        assert!(is_exceptional_sequence(&r, &pts));
    }

    #[test]
    fn base_embedding_homomorphic() {
        let r = gr64_3();
        let zq = Zq::z2e(64);
        let a = 0xDEAD_BEEFu64;
        let b = 0x1234u64;
        assert_eq!(
            r.mul(&r.from_base(&a), &r.from_base(&b)),
            r.from_base(&zq.mul(&a, &b))
        );
    }

    #[test]
    fn serialization_roundtrip() {
        let r = gr64_3();
        let mut rng = Rng64::seeded(24);
        let a = r.random(&mut rng);
        let mut buf = Vec::new();
        r.write_elem(&a, &mut buf);
        assert_eq!(buf.len(), 24);
        let mut pos = 0;
        assert_eq!(r.read_elem(&buf, &mut pos), a);
    }

    #[test]
    fn odd_characteristic_tower() {
        let r = Extension::new(Zq::new(3, 3), 2); // GR(27, 2)
        let pts = r.exceptional_points(9).unwrap();
        assert!(is_exceptional_sequence(&r, &pts));
        let mut rng = Rng64::seeded(25);
        for _ in 0..10 {
            let a = r.random(&mut rng);
            if r.is_unit(&a) {
                assert_eq!(r.mul(&a, &r.inv(&a).unwrap()), r.one());
            }
        }
    }
}
