//! Residue-field machinery: `GF(p)`, `GF(p^d)` and polynomial arithmetic over
//! them. Used to *certify* defining polynomials (irreducibility mod `p`) when
//! constructing Galois rings and towers — not on any hot path, which is why
//! multiplication stays on the plain `u128 %` reduction here: the Montgomery
//! form that removes per-element division from odd-modulus hot loops lives in
//! [`super::zq::Montgomery`] and is wired into the runtime-dispatched slice
//! kernels ([`super::arch`]); construction-time certification doesn't need it.

/// The prime field `GF(p)`, elements as `u64 < p`.
#[derive(Clone, Debug, PartialEq)]
pub struct Gfp {
    pub p: u64,
}

impl Gfp {
    pub fn new(p: u64) -> Gfp {
        assert!(super::zq::is_small_prime(p), "{p} not prime");
        Gfp { p }
    }

    #[inline]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        let s = a + b;
        if s >= self.p {
            s - self.p
        } else {
            s
        }
    }

    #[inline]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        if a >= b {
            a - b
        } else {
            a + self.p - b
        }
    }

    #[inline]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        ((a as u128 * b as u128) % self.p as u128) as u64
    }

    pub fn pow(&self, mut a: u64, mut n: u128) -> u64 {
        let mut acc = 1u64;
        while n > 0 {
            if n & 1 == 1 {
                acc = self.mul(acc, a);
            }
            n >>= 1;
            if n > 0 {
                a = self.mul(a, a);
            }
        }
        acc
    }

    /// Inverse by Fermat (p is prime).
    pub fn inv(&self, a: u64) -> u64 {
        assert!(a % self.p != 0, "zero has no inverse");
        self.pow(a, (self.p - 2) as u128)
    }
}

/// The field `GF(p^d) = GF(p)[x]/(f̄)`, elements as coefficient vectors of
/// length `d` (little-endian: index i ↔ coefficient of x^i).
#[derive(Clone, Debug, PartialEq)]
pub struct Gfq {
    pub fp: Gfp,
    pub d: usize,
    /// Monic modulus of degree `d`, length `d+1`, coefficients `< p`.
    pub modulus: Vec<u64>,
}

pub type GfqElem = Vec<u64>;

impl Gfq {
    pub fn new(p: u64, modulus: Vec<u64>) -> Gfq {
        let d = modulus.len() - 1;
        assert!(d >= 1);
        assert_eq!(modulus[d], 1, "modulus must be monic");
        Gfq { fp: Gfp::new(p), d, modulus }
    }

    /// Field size `q = p^d`.
    pub fn size(&self) -> u128 {
        (self.fp.p as u128).pow(self.d as u32)
    }

    pub fn zero(&self) -> GfqElem {
        vec![0; self.d]
    }

    pub fn one(&self) -> GfqElem {
        let mut v = vec![0; self.d];
        v[0] = 1;
        v
    }

    pub fn is_zero(&self, a: &GfqElem) -> bool {
        a.iter().all(|&c| c == 0)
    }

    pub fn add(&self, a: &GfqElem, b: &GfqElem) -> GfqElem {
        a.iter().zip(b).map(|(&x, &y)| self.fp.add(x, y)).collect()
    }

    pub fn sub(&self, a: &GfqElem, b: &GfqElem) -> GfqElem {
        a.iter().zip(b).map(|(&x, &y)| self.fp.sub(x, y)).collect()
    }

    pub fn scale(&self, a: &GfqElem, s: u64) -> GfqElem {
        a.iter().map(|&x| self.fp.mul(x, s)).collect()
    }

    /// Schoolbook multiply + reduction by the modulus.
    pub fn mul(&self, a: &GfqElem, b: &GfqElem) -> GfqElem {
        let d = self.d;
        let mut prod = vec![0u64; 2 * d - 1];
        for (i, &ai) in a.iter().enumerate() {
            if ai == 0 {
                continue;
            }
            for (j, &bj) in b.iter().enumerate() {
                prod[i + j] = self.fp.add(prod[i + j], self.fp.mul(ai, bj));
            }
        }
        // Reduce: x^(d+k) ≡ −(modulus minus leading) · x^k
        for k in (d..2 * d - 1).rev() {
            let c = prod[k];
            if c == 0 {
                continue;
            }
            prod[k] = 0;
            for (d_i, m) in self.modulus.iter().enumerate().take(d) {
                let delta = self.fp.mul(c, *m);
                prod[k - d + d_i] = self.fp.sub(prod[k - d + d_i], delta);
            }
        }
        prod.truncate(d);
        prod
    }

    pub fn pow(&self, a: &GfqElem, mut n: u128) -> GfqElem {
        let mut base = a.clone();
        let mut acc = self.one();
        while n > 0 {
            if n & 1 == 1 {
                acc = self.mul(&acc, &base);
            }
            n >>= 1;
            if n > 0 {
                base = self.mul(&base, &base);
            }
        }
        acc
    }

    /// Inverse by Fermat: `a^(q−2)`.
    pub fn inv(&self, a: &GfqElem) -> GfqElem {
        assert!(!self.is_zero(a), "zero has no inverse");
        self.pow(a, self.size() - 2)
    }

    /// Enumerate the i-th field element as base-p digits (used for
    /// deterministic exceptional-point lifts and polynomial search).
    pub fn element_from_index(&self, mut idx: u128) -> GfqElem {
        let mut v = vec![0u64; self.d];
        for c in v.iter_mut() {
            *c = (idx % self.fp.p as u128) as u64;
            idx /= self.fp.p as u128;
        }
        v
    }
}

// ---------------------------------------------------------------------------
// Polynomials over GF(q) — only what Rabin's irreducibility test needs.
// Representation: little-endian coefficient vectors, no trailing zeros
// (except the zero polynomial = empty vec).
// ---------------------------------------------------------------------------

/// Trim trailing zeros.
pub fn fq_poly_trim(f: &Gfq, mut a: Vec<GfqElem>) -> Vec<GfqElem> {
    while let Some(last) = a.last() {
        if f.is_zero(last) {
            a.pop();
        } else {
            break;
        }
    }
    a
}

pub fn fq_poly_is_zero(a: &[GfqElem]) -> bool {
    a.is_empty()
}

pub fn fq_poly_deg(a: &[GfqElem]) -> isize {
    a.len() as isize - 1
}

pub fn fq_poly_add(f: &Gfq, a: &[GfqElem], b: &[GfqElem]) -> Vec<GfqElem> {
    let n = a.len().max(b.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = a.get(i).cloned().unwrap_or_else(|| f.zero());
        let y = b.get(i).cloned().unwrap_or_else(|| f.zero());
        out.push(f.add(&x, &y));
    }
    fq_poly_trim(f, out)
}

pub fn fq_poly_sub(f: &Gfq, a: &[GfqElem], b: &[GfqElem]) -> Vec<GfqElem> {
    let n = a.len().max(b.len());
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = a.get(i).cloned().unwrap_or_else(|| f.zero());
        let y = b.get(i).cloned().unwrap_or_else(|| f.zero());
        out.push(f.sub(&x, &y));
    }
    fq_poly_trim(f, out)
}

pub fn fq_poly_mul(f: &Gfq, a: &[GfqElem], b: &[GfqElem]) -> Vec<GfqElem> {
    if a.is_empty() || b.is_empty() {
        return vec![];
    }
    let mut out = vec![f.zero(); a.len() + b.len() - 1];
    for (i, ai) in a.iter().enumerate() {
        if f.is_zero(ai) {
            continue;
        }
        for (j, bj) in b.iter().enumerate() {
            let t = f.mul(ai, bj);
            out[i + j] = f.add(&out[i + j], &t);
        }
    }
    fq_poly_trim(f, out)
}

/// Remainder `a mod m`; `m` need not be monic (leading coeff inverted — GF(q)
/// is a field).
pub fn fq_poly_rem(f: &Gfq, a: &[GfqElem], m: &[GfqElem]) -> Vec<GfqElem> {
    assert!(!m.is_empty(), "division by zero polynomial");
    let mut r: Vec<GfqElem> = a.to_vec();
    let dm = m.len() - 1;
    let lead_inv = f.inv(m.last().unwrap());
    while r.len() > dm {
        r = fq_poly_trim(f, r);
        if r.len() <= dm {
            break;
        }
        let k = r.len() - 1 - dm; // shift
        let c = f.mul(r.last().unwrap(), &lead_inv);
        for (i, mi) in m.iter().enumerate() {
            let t = f.mul(&c, mi);
            r[k + i] = f.sub(&r[k + i], &t);
        }
        r = fq_poly_trim(f, r);
    }
    fq_poly_trim(f, r)
}

/// `base^n mod m` by square-and-multiply with polynomial arithmetic.
pub fn fq_poly_powmod(f: &Gfq, base: &[GfqElem], mut n: u128, m: &[GfqElem]) -> Vec<GfqElem> {
    let mut b = fq_poly_rem(f, base, m);
    let mut acc = vec![f.one()]; // the constant polynomial 1
    while n > 0 {
        if n & 1 == 1 {
            acc = fq_poly_rem(f, &fq_poly_mul(f, &acc, &b), m);
        }
        n >>= 1;
        if n > 0 {
            b = fq_poly_rem(f, &fq_poly_mul(f, &b, &b), m);
        }
    }
    acc
}

/// Monic gcd of two polynomials over GF(q).
pub fn fq_poly_gcd(f: &Gfq, a: &[GfqElem], b: &[GfqElem]) -> Vec<GfqElem> {
    let mut x = fq_poly_trim(f, a.to_vec());
    let mut y = fq_poly_trim(f, b.to_vec());
    while !y.is_empty() {
        let r = fq_poly_rem(f, &x, &y);
        x = y;
        y = r;
    }
    if let Some(last) = x.last().cloned() {
        let li = f.inv(&last);
        for c in x.iter_mut() {
            *c = f.mul(c, &li);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gf4() -> Gfq {
        // GF(4) = GF(2)[x]/(x^2 + x + 1)
        Gfq::new(2, vec![1, 1, 1])
    }

    #[test]
    fn gfp_basics() {
        let f = Gfp::new(7);
        assert_eq!(f.add(5, 4), 2);
        assert_eq!(f.sub(2, 5), 4);
        assert_eq!(f.mul(3, 5), 1);
        assert_eq!(f.inv(3), 5);
        assert_eq!(f.pow(3, 6), 1); // Fermat
    }

    #[test]
    fn gf4_is_a_field() {
        let f = gf4();
        assert_eq!(f.size(), 4);
        // Every nonzero element invertible, x * x = x + 1 etc.
        for i in 1..4u128 {
            let a = f.element_from_index(i);
            let inv = f.inv(&a);
            assert_eq!(f.mul(&a, &inv), f.one());
        }
        let x = vec![0, 1];
        let x2 = f.mul(&x, &x);
        assert_eq!(x2, vec![1, 1]); // x^2 = x + 1
    }

    #[test]
    fn gf4_mult_order() {
        let f = gf4();
        let x = vec![0u64, 1];
        assert_eq!(f.pow(&x, 3), f.one()); // |GF(4)*| = 3
        assert_ne!(f.pow(&x, 1), f.one());
    }

    #[test]
    fn gf9() {
        // GF(9) = GF(3)[x]/(x^2 + 1)
        let f = Gfq::new(3, vec![1, 0, 1]);
        assert_eq!(f.size(), 9);
        for i in 1..9u128 {
            let a = f.element_from_index(i);
            assert_eq!(f.mul(&a, &f.inv(&a)), f.one());
        }
    }

    #[test]
    fn poly_rem_and_gcd() {
        let f = gf4();
        // a = (y^2 + 1), m = (y + 1) over GF(4): a(1) = 0, so rem = 0
        let one = f.one();
        let a = vec![one.clone(), f.zero(), one.clone()];
        let m = vec![one.clone(), one.clone()];
        let r = fq_poly_rem(&f, &a, &m);
        assert!(fq_poly_is_zero(&r));
        let g = fq_poly_gcd(&f, &a, &m);
        assert_eq!(fq_poly_deg(&g), 1);
    }

    #[test]
    fn powmod_fermat_over_gf2() {
        // Over GF(2)[y] mod the irreducible y^3+y+1: y^(2^3) ≡ y.
        let f = Gfq::new(2, vec![1, 1]); // dummy GF(2) rep as Gfq with d=1: x+1 modulus
        let one = f.one();
        let zero = f.zero();
        // m(y) = y^3 + y + 1
        let m = vec![one.clone(), one.clone(), zero.clone(), one.clone()];
        let y = vec![zero.clone(), one.clone()];
        let yq = fq_poly_powmod(&f, &y, 8, &m);
        assert_eq!(fq_poly_trim(&f, yq), fq_poly_trim(&f, y));
    }
}
